//! Batched multi-macro serving through the graph compiler: the same edge
//! MLP as `edge_serve`, ingested into the compiler IR, calibrated, lowered
//! and placed ONCE on a pool of simulated macros, then served as a
//! [`cimsim::compiler::CompiledPlan`] — queued requests coalesce into
//! single pooled calls that fan out across worker threads. Compare the
//! reported occupancy/throughput with the single-backend `edge_serve`
//! example.
//!
//! Run: `cargo run --release --example edge_serve_batched [requests]`

use cimsim::compiler::{compile, CompileOptions, Graph};
use cimsim::config::{Config, EnhanceConfig};
use cimsim::coordinator::deployment::argmax;
use cimsim::coordinator::{Client, ServeConfig, ServeFrontend};
use cimsim::nn::dataset::BlobDataset;
use cimsim::nn::mlp::{train, Mlp};
use cimsim::nn::tensor::Tensor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_req: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let mut cfg = Config::default();
    cfg.enhance = EnhanceConfig::both();

    // Train the edge model in float.
    let mut ds = BlobDataset::new(12, 0.05, 21);
    let data: Vec<(Vec<f32>, usize)> =
        ds.batch(300).into_iter().map(|s| (s.image.data, s.label)).collect();
    let mut mlp = Mlp::new(&[144, 32, 10], 4);
    let acc = train(&mut mlp, &data, 8, 0.05, 2);
    println!("model trained (float acc {:.1}%)", acc * 100.0);

    // Compile onto the pool: ingest → calibrate → lower → place.
    let graph = Graph::from_mlp(&mlp);
    let cal: Vec<Tensor> = data
        .iter()
        .take(50)
        .map(|(x, _)| Tensor::from_vec(&[144], x.clone()))
        .collect();
    let plan = compile(graph, &cal, &cfg, &CompileOptions::default())?;
    println!("{}", plan.cost_report().table(&cfg).to_markdown());

    // Serve the compiled plan: tiles resident, batch fan-out across workers
    // (worker count is the plan's CompileOptions::workers — 0 = auto).
    let handle = ServeConfig::builder()
        .max_batch(32)
        .max_wait(std::time::Duration::from_millis(1))
        .serve(ServeFrontend::Plan(plan))?;
    println!("serving on {} (compiled plan, max batch 32, 1 ms window)", handle.addr);

    // 8 concurrent clients.
    let addr = handle.addr;
    let per_client = n_req / 8;
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let reqs: Vec<(Vec<f32>, usize)> = BlobDataset::new(12, 0.05, 100 + t)
            .batch(per_client)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).expect("connect");
            let mut correct = 0usize;
            for (x, y) in &reqs {
                if argmax(&c.infer(x).expect("infer")) == *y {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let metrics = handle.shutdown();
    println!(
        "accuracy on the compiled CIM plan under load: {:.1}% over {} requests",
        100.0 * correct as f64 / (per_client * 8) as f64,
        per_client * 8
    );
    let report = metrics.report(cfg.mac.clock_mhz * 1e6);
    println!("{}", report.render());
    println!(
        "batch occupancy: mean {:.1}, peak {} (occupancy > 1 ⇒ requests amortized one pooled call)",
        report.mean_batch, report.peak_batch
    );
    Ok(())
}
