//! Map a 4-bit ResNet-20 onto the CIM macro (the paper's Fig. 1 workload):
//! run every conv layer of a full inference through the tiled executor and
//! report per-layer SNR vs the exact digital pipeline, plus the end-to-end
//! energy/throughput accounting of the mapping.
//!
//! Run: `cargo run --release --example resnet20_cim [n_layers]`

use cimsim::config::{Config, EnhanceConfig};
use cimsim::mapping::executor::CimConv;
use cimsim::mapping::{CimBackend, DigitalBackend, NativeBackend};
use cimsim::nn::dataset::random_image;
use cimsim::nn::ops::relu;
use cimsim::nn::resnet::ResNet20;
use cimsim::nn::tensor::Tensor;

fn snr_db(reference: &Tensor, got: &Tensor) -> f64 {
    let mut sig = 0f64;
    let mut err = 0f64;
    for (r, g) in reference.data.iter().zip(&got.data) {
        sig += (*r as f64).powi(2);
        err += (*r as f64 - *g as f64).powi(2);
    }
    10.0 * (sig / err.max(1e-30)).log10()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_layers: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let mut cfg = Config::default();
    cfg.enhance = EnhanceConfig::both();

    let net = ResNet20::new(3);
    let image = random_image(&[3, 32, 32], 7);
    println!(
        "ResNet-20: {} conv layers, {:.1}M MACs per image; mapping {} layers onto the macro\n",
        net.conv_layers().len(),
        net.total_macs() as f64 / 1e6,
        n_layers
    );

    let mut cim = NativeBackend::new(cfg.clone());
    let mut dig = DigitalBackend::new(cfg.clone());

    println!(
        "{:<12} {:>12} {:>10} {:>12} {:>12} {:>10}",
        "layer", "shape", "tiles", "SNR (dB)", "µJ", "kcycles"
    );
    let mut x_cim = image.clone();
    let mut x_dig = image.clone();
    for (li, (name, layer)) in net.conv_layers().into_iter().enumerate() {
        if li >= n_layers {
            break;
        }
        // Activation calibration: max over the digital input (deployment
        // recipe); inputs to conv are post-ReLU non-negative.
        let cal = x_dig.max_abs().max(1e-6);
        let conv = CimConv::new(
            &layer.w,
            layer.b.clone(),
            layer.stride,
            layer.pad,
            cal,
            &cfg,
        );
        let e0 = cim.stats().energy_fj();
        let c0 = cim.stats().total_cycles;
        let y_cim = relu(conv.run(&mut cim, &x_cim)?);
        let y_dig = relu(conv.run(&mut dig, &x_dig)?);
        let snr = snr_db(&y_dig, &y_cim);
        println!(
            "{:<12} {:>12} {:>10} {:>12.1} {:>12.2} {:>10.1}",
            name,
            format!("{:?}", y_cim.shape),
            conv.linear.ops_per_vector(),
            snr,
            (cim.stats().energy_fj() - e0) * 1e-9,
            (cim.stats().total_cycles - c0) as f64 / 1e3,
        );
        x_cim = y_cim;
        x_dig = y_dig;
    }

    let st = cim.stats();
    let macs = st.core_ops as f64 * (cfg.mac.engines * cfg.mac.rows) as f64;
    println!(
        "\ntotals: {} core ops ({:.1}M MACs incl. padding), {:.1} µJ, {:.2} ms device time, {:.1} TOPS/W",
        st.core_ops,
        macs / 1e6,
        st.energy_fj() * 1e-9,
        st.total_cycles as f64 / (cfg.mac.clock_mhz * 1e6) * 1e3,
        2.0 * macs / (st.energy_fj() * 1e-15) / 1e12,
    );
    println!("boosted-clipping events: {} ({:.3}% of engine results)",
        st.clipped,
        100.0 * st.clipped as f64 / (st.core_ops as f64 * cfg.mac.engines as f64));
    Ok(())
}
