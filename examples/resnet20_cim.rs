//! Map a 4-bit ResNet-20 onto the CIM macro pool (the paper's Fig. 1
//! workload) — through the graph compiler: ingest the network into the IR,
//! calibrate + lower every layer, place the 282 tiles with the cost-model-
//! driven placer, then run a full CIFAR-shaped inference end to end on the
//! pool. Noise-free, the compiled execution is verified bit-identical to
//! the sequential per-layer `CimConv` path, and the per-layer cycle/energy
//! cost report (estimated vs observed) is printed.
//!
//! Run: `cargo run --release --example resnet20_cim [n_images]`

use cimsim::compiler::{calibrate, compile, CompileOptions, Graph, Op};
use cimsim::config::{Config, EnhanceConfig};
use cimsim::coordinator::deployment::argmax;
use cimsim::mapping::executor::CimConv;
use cimsim::mapping::NativeBackend;
use cimsim::nn::dataset::random_image;
use cimsim::nn::ops::{global_avg_pool, relu};
use cimsim::nn::resnet::ResNet20;
use cimsim::nn::tensor::Tensor;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_images: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let mut cfg = Config::default();
    cfg.enhance = EnhanceConfig::both();
    cfg.noise.enabled = false; // noise-free: bit-exact vs the sequential path

    let net = ResNet20::new(3);
    println!(
        "ResNet-20: {} conv layers + FC, {:.1}M MACs per image — compiling onto the pool\n",
        net.conv_layers().len(),
        net.total_macs() as f64 / 1e6
    );

    // ---- ingest → calibrate → lower → place ----
    let graph = Graph::from_resnet20(&net);
    let cal_imgs: Vec<Tensor> = (0..2).map(|i| random_image(&[3, 32, 32], 100 + i)).collect();
    let opts = CompileOptions { workers: 0, ..Default::default() };
    let mut plan = compile(graph.clone(), &cal_imgs, &cfg, &opts)?;
    println!("{}", plan.cost_report().table(&cfg).to_markdown());

    // ---- execute end to end on the pool ----
    let imgs: Vec<Tensor> = (0..n_images).map(|i| random_image(&[3, 32, 32], 7 + i as u64)).collect();
    let logits = plan.run_batch(&imgs)?;
    for (i, row) in logits.iter().enumerate() {
        println!("image {i}: argmax {} logits[0..4] {:?}", argmax(row), &row[..4]);
    }

    // ---- verify: bit-identical to the sequential per-layer CimConv path ----
    let cal = calibrate(&graph, &cal_imgs)?;
    let direct = sequential_reference(&net, &graph, &cal, &cfg, &imgs[0])?;
    assert_eq!(
        logits[0], direct,
        "compiled plan diverged from the sequential per-layer path"
    );
    println!("\nverified: compiled ≡ sequential per-layer CimConv path (bit-identical, noise-free)");

    // ---- per-layer observed accounting (cycles predicted vs measured) ----
    println!("\n{}", plan.observed_table().to_markdown());
    let st = plan.stats();
    let macs = st.core_ops as f64 * (cfg.mac.engines * cfg.mac.rows) as f64;
    println!(
        "totals: {} core ops ({:.1}M MACs incl. padding), {:.1} µJ, {:.2} ms device time/image, {:.1} TOPS/W",
        st.core_ops,
        macs / 1e6,
        st.energy_fj() * 1e-9,
        st.total_cycles as f64 / (cfg.mac.clock_mhz * 1e6) * 1e3 / n_images as f64,
        2.0 * macs / (st.energy_fj() * 1e-15) / 1e12,
    );
    Ok(())
}

/// The pre-compiler execution style: every conv through `CimConv` on a
/// single macro, residuals and pooling in the float digital domain, using
/// the compiler's own calibration values.
fn sequential_reference(
    net: &ResNet20,
    graph: &Graph,
    cal: &cimsim::compiler::Calibration,
    cfg: &Config,
    img: &Tensor,
) -> Result<Vec<f32>, Box<dyn std::error::Error>> {
    // Calibration max per layer name (from each conv's quantize node).
    let act_max = |name: &str| -> f32 {
        for node in &graph.nodes {
            if node.name == name {
                if let Op::Quantize { .. } = graph.nodes[node.inputs[0]].op {
                    return cal.act_max(node.inputs[0]);
                }
            }
        }
        panic!("layer `{name}` not found in graph");
    };
    let run = |be: &mut NativeBackend, l: &cimsim::nn::resnet::ConvLayer, name: &str, x: &Tensor| {
        CimConv::new(&l.w, l.b.clone(), l.stride, l.pad, act_max(name), cfg).run(be, x)
    };

    let mut be = NativeBackend::new(cfg.clone());
    let mut h = relu(run(&mut be, &net.stem, "stem", img)?);
    for (si, stage) in net.stages.iter().enumerate() {
        for (bi, block) in stage.iter().enumerate() {
            let p = format!("s{si}b{bi}");
            let a = relu(run(&mut be, &block.conv1, &format!("{p}.conv1"), &h)?);
            let a = run(&mut be, &block.conv2, &format!("{p}.conv2"), &a)?;
            let idn = match &block.proj {
                Some(proj) => run(&mut be, proj, &format!("{p}.proj"), &h)?,
                None => h.clone(),
            };
            let mut sum = a;
            for (o, i) in sum.data.iter_mut().zip(&idn.data) {
                *o += i;
            }
            h = relu(sum);
        }
    }
    let pooled = Tensor::from_vec(&[64], global_avg_pool(&h));
    // FC layer: same lowered layer the plan holds (last layer), sequentially.
    let fc_q = graph
        .nodes
        .iter()
        .position(|n| n.name == "fc")
        .map(|id| graph.nodes[id].inputs[0])
        .expect("fc node");
    let fc_cols = cimsim::compiler::transpose_rows_to_cols(&net.fc_w);
    let fc = cimsim::mapping::executor::CimLinear::new(
        &fc_cols,
        net.fc_b.clone(),
        cal.act_max(fc_q),
        cfg,
    );
    Ok(fc.run_batch(&mut be, &[pooled.data])?.remove(0))
}
