//! End-to-end driver (DESIGN.md "E2E"): train a small MLP in float on a
//! real (synthetic) 10-class image workload — logging the loss curve —
//! post-training-quantize it to the macro's 4-b formats, then compile it
//! through the graph compiler (ingest → calibrate → lower → place) and run
//! it on the macro pool in every enhancement mode, reporting accuracy,
//! throughput and energy. When `artifacts/` exists, the quantized
//! deployment also runs through the AOT-compiled XLA path.
//!
//! Run: `cargo run --release --example mlp_train_and_deploy`

use cimsim::compiler::{compile, CompileOptions, Graph};
use cimsim::config::{Config, EnhanceConfig};
use cimsim::coordinator::deployment::{argmax, MlpDeployment};
use cimsim::mapping::DigitalBackend;
use cimsim::nn::dataset::BlobDataset;
use cimsim::nn::mlp::Mlp;
use cimsim::nn::tensor::Tensor;
use cimsim::util::rng::{Rng, Xoshiro256};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = Config::default();

    // ---- 1. data + float training (a few hundred SGD steps) ----
    let mut ds = BlobDataset::new(12, 0.05, 17);
    let train_set: Vec<(Vec<f32>, usize)> =
        ds.batch(400).into_iter().map(|s| (s.image.data, s.label)).collect();
    let test_set: Vec<(Vec<f32>, usize)> =
        ds.batch(400).into_iter().map(|s| (s.image.data, s.label)).collect();

    let mut mlp = Mlp::new(&[144, 32, 10], 5);
    let mut order: Vec<usize> = (0..train_set.len()).collect();
    let mut rng = Xoshiro256::seeded(9);
    println!("== float training (SGD, lr 0.05) ==");
    let mut step = 0usize;
    for epoch in 0..8 {
        rng.shuffle(&mut order);
        let mut loss_sum = 0f32;
        for &i in &order {
            let (x, y) = &train_set[i];
            loss_sum += mlp.train_step(x, *y, 0.05);
            step += 1;
        }
        println!(
            "epoch {epoch} (step {step}): mean loss {:.4}, train acc {:.1}%",
            loss_sum / order.len() as f32,
            100.0 * cimsim::nn::mlp::accuracy(&mlp, &train_set)
        );
    }
    let float_acc = cimsim::nn::mlp::accuracy(&mlp, &test_set);
    println!("float test accuracy: {:.1}%\n", float_acc * 100.0);

    // ---- 2. post-training quantization to 4-b ----
    let cal: Vec<Vec<f32>> = train_set.iter().take(64).map(|(x, _)| x.clone()).collect();
    let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
    let xs: Vec<Vec<f32>> = test_set.iter().map(|(x, _)| x.clone()).collect();
    let digital_logits = dep.run_digital(&xs);
    let digital_acc = test_set
        .iter()
        .zip(&digital_logits)
        .filter(|((_, y), l)| argmax(l) == **&y)
        .count() as f64
        / test_set.len() as f64;
    println!("4-b quantized (exact digital) accuracy: {:.1}%\n", digital_acc * 100.0);

    // ---- 3. compile onto the macro pool, every enhancement mode ----
    println!("== graph-compiled deployment on the simulated CIM macro pool ==");
    let graph = Graph::from_mlp(&mlp);
    let cal_t: Vec<Tensor> =
        cal.iter().map(|x| Tensor::from_vec(&[144], x.clone())).collect();
    let xs_t: Vec<Tensor> = xs.iter().map(|x| Tensor::from_vec(&[144], x.clone())).collect();
    println!("{:<12} {:>9} {:>12} {:>12} {:>12} {:>10}", "mode", "accuracy", "core ops", "µJ total", "TOPS/W", "ms/img*");
    for enh in [
        EnhanceConfig::default(),
        EnhanceConfig::fold_only(),
        EnhanceConfig::boost_only(),
        EnhanceConfig::both(),
    ] {
        let mut c = cfg.clone();
        c.enhance = enh;
        let mut plan = compile(graph.clone(), &cal_t, &c, &CompileOptions::default())?;
        let t0 = Instant::now();
        let logits = plan.run_batch(&xs_t)?;
        let wall = t0.elapsed();
        let acc = test_set
            .iter()
            .zip(&logits)
            .filter(|((_, y), l)| argmax(l) == **&y)
            .count() as f64
            / test_set.len() as f64;
        let st = plan.stats();
        let ops = st.core_ops as f64 * (c.mac.engines * c.mac.rows * 2) as f64;
        let device_ms =
            st.total_cycles as f64 / (c.mac.clock_mhz * 1e6) * 1e3 / test_set.len() as f64;
        println!(
            "{:<12} {:>8.1}% {:>12} {:>12.2} {:>12.1} {:>10.4}",
            c.enhance.label(),
            acc * 100.0,
            st.core_ops,
            st.energy_fj() * 1e-9,
            ops / (st.energy_fj() * 1e-15) / 1e12,
            device_ms,
        );
        let _ = wall;
        // Placement + cost breakdown, once, for the fold+boost plan.
        if c.enhance.fold && c.enhance.boost {
            println!("\n{}", plan.cost_report().table(&c).to_markdown());
        }
    }
    println!("(*device time per image at {:.0} MHz; simulator wall time excluded)", cfg.mac.clock_mhz);

    // digital-backend sanity row (the quantized deployment bundle).
    let mut dig = DigitalBackend::new(cfg.clone());
    let dl = dep.run_native(&mut dig, &xs)?;
    let dacc = test_set.iter().zip(&dl).filter(|((_, y), l)| argmax(l) == **&y).count() as f64
        / test_set.len() as f64;
    println!("digital backend check: {:.1}% (must equal exact digital)\n", dacc * 100.0);

    // ---- 4. XLA artifact path (compiled L2/L1), if available ----
    run_xla_path(&cfg, &dep, &xs, &test_set);
    Ok(())
}

#[cfg(feature = "xla-runtime")]
fn run_xla_path(
    cfg: &Config,
    dep: &MlpDeployment,
    xs: &[Vec<f32>],
    test_set: &[(Vec<f32>, usize)],
) {
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.toml").exists() {
        println!("== XLA (AOT Pallas kernel) path, fold+boost ==");
        let mut c = cfg.clone();
        c.enhance = EnhanceConfig::both();
        match cimsim::runtime::xla_backend::XlaBackend::new(c.clone(), dir) {
            Ok(mut be) => {
                let sample: Vec<Vec<f32>> = xs.iter().take(64).cloned().collect();
                let t0 = Instant::now();
                let logits = dep.run_native(&mut be, &sample).expect("xla inference");
                let acc = test_set
                    .iter()
                    .take(64)
                    .zip(&logits)
                    .filter(|((_, y), l)| argmax(l) == **&y)
                    .count() as f64
                    / 64.0;
                println!(
                    "artifact {}: accuracy {:.1}% over 64 images ({:.2} s wall)",
                    be.artifact_name(),
                    acc * 100.0,
                    t0.elapsed().as_secs_f64()
                );
            }
            Err(e) => println!("skipping XLA path: {e}"),
        }
    } else {
        println!("artifacts/ missing — run `make artifacts` for the XLA path");
    }
}

#[cfg(not(feature = "xla-runtime"))]
fn run_xla_path(
    _cfg: &Config,
    _dep: &MlpDeployment,
    _xs: &[Vec<f32>],
    _test_set: &[(Vec<f32>, usize)],
) {
    println!("XLA path skipped: built without the `xla-runtime` feature");
}
