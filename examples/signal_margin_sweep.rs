//! Signal-margin study: how the 1σ readout error and the signal margin move
//! with the enhancement techniques and with the analog accumulation
//! parallelism (rows per conversion) — the trade Figs 1/2/4 revolve around.
//!
//! Run: `cargo run --release --example signal_margin_sweep`

use cimsim::config::{Config, EnhanceConfig};
use cimsim::harness::accuracy::sigma_error_pct;
use cimsim::util::table::{fmt_pct, fmt_sig, Table};

fn main() {
    let cfg = Config::default();

    let mut t = Table::new(
        "1σ readout error by mode (4000 random points)",
        &["mode", "DTC scale", "sigma (%FS)", "paper"],
    );
    for (enh, paper) in [
        (EnhanceConfig::default(), "1.30%"),
        (EnhanceConfig::fold_only(), "-"),
        (EnhanceConfig::boost_only(), "-"),
        (EnhanceConfig::both(), "0.64%"),
    ] {
        let mut c = cfg.clone();
        c.enhance = enh;
        t.row(&[
            c.enhance.label().to_string(),
            fmt_sig(c.enhance.dtc_scale(), 4),
            fmt_pct(sigma_error_pct(&c, 4000, 1) / 100.0),
            paper.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());

    let mut t2 = Table::new(
        "1σ error vs analog accumulation parallelism (fold+boost)",
        &["rows per conversion", "MAC range (units)", "sigma (%FS)"],
    );
    for rows in [16usize, 32, 64, 128, 256] {
        let mut c = cfg.clone();
        c.mac.rows = rows;
        c.enhance = EnhanceConfig::both();
        t2.row(&[
            rows.to_string(),
            c.mac.mac_range().to_string(),
            fmt_pct(sigma_error_pct(&c, 2500, 2) / 100.0),
        ]);
    }
    println!("{}", t2.to_markdown());
    println!("(the paper's choice of 64 rows balances readout amortization against margin)");
}
