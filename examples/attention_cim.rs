//! Run a full transformer encoder block (multi-head self-attention + FFN)
//! on the CIM macro pool — the dynamic-weight workload of DESIGN.md §10.
//!
//! The weight-stationary projections (per-head Q/K/V, output projection,
//! FFN) compile onto the shared pool exactly like any MLP/conv layer; the
//! two act×act products per head (`Q·Kᵀ`, `attn·V`) compile onto dedicated
//! dynamic tile grids whose operand is re-quantized and reloaded into the
//! array once per item. The example prints the reload-vs-compute cost
//! report, verifies the noise-free output against the float-graph golden
//! (within quantization tolerance), and checks the streamed (layer-
//! pipelined) execution bit-identical to the barrier path.
//!
//! Run: `cargo run --release --example attention_cim [seq]`

use cimsim::compiler::{compile, CompileOptions, Graph, StreamOptions};
use cimsim::config::{Config, EnhanceConfig};
use cimsim::nn::tensor::Tensor;
use cimsim::nn::transformer::TransformerBlock;
use cimsim::util::rng::{Rng, Xoshiro256};

fn snr_db(reference: &[f32], got: &[f32]) -> f64 {
    let (mut sig, mut err) = (0f64, 0f64);
    for (r, g) in reference.iter().zip(got) {
        sig += (*r as f64).powi(2);
        err += (*r as f64 - *g as f64).powi(2);
    }
    10.0 * (sig / err.max(1e-30)).log10()
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let seq: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(8).max(2);
    let (d_model, heads, d_ff) = (32usize, 4usize, 64usize);
    let mut cfg = Config::default();
    cfg.enhance = EnhanceConfig::both();
    cfg.noise.enabled = false; // quantization-only: comparable to the golden

    let block = TransformerBlock::new(d_model, heads, d_ff, 42);
    println!(
        "encoder block: d_model {d_model}, {heads} heads (d_head {}), d_ff {d_ff}, seq {seq}",
        block.d_head()
    );

    // ---- ingest → calibrate → lower → place ----
    let graph = Graph::from_transformer_block(&block, seq);
    let mut rng = Xoshiro256::seeded(7);
    let mut rand_x = || {
        Tensor::from_vec(
            &[seq, d_model],
            (0..seq * d_model).map(|_| rng.next_f32() - 0.5).collect(),
        )
    };
    let cal: Vec<Tensor> = (0..4).map(|_| rand_x()).collect();
    let opts = CompileOptions { workers: 0, ..Default::default() };
    let mut plan = compile(graph.clone(), &cal, &cfg, &opts)?;
    let report = plan.cost_report().clone();
    println!("\n{}", report.table(&cfg).to_markdown());
    println!(
        "reload share of device cycles: {:.1} % ({} dedicated dynamic shards)",
        report.reload_cycle_fraction() * 100.0,
        report.n_dynamic_shards
    );

    // ---- execute: barrier batch, then verify against the float golden ----
    let xs: Vec<Tensor> = (0..2).map(|_| rand_x()).collect();
    let out = plan.run_batch(&xs)?;
    let golden = graph.eval_float(&xs[0])?;
    let snr = snr_db(&golden[graph.output()].data, &out[0]);
    println!("\nnoise-free vs float golden: {snr:.1} dB SNR (4-b acts / 4-b weights)");
    assert!(
        snr > 5.0,
        "quantized block strayed too far from the float golden ({snr:.1} dB)"
    );

    // ---- streamed ≡ barrier, reloads as per-(item, tile) stage barriers ----
    let mut streamed = compile(graph.clone(), &cal, &cfg, &opts)?;
    let outcome = streamed.run_streamed_with(&xs, &StreamOptions { queue_cap: 2 })?;
    assert_eq!(outcome.outputs, out, "streamed diverged from barrier");
    println!(
        "verified: streamed ≡ barrier (bit-identical); peak busy stages {}",
        outcome.peak_busy
    );

    // ---- observed accounting: reloads counted, cycle prediction exact ----
    println!("\n{}", plan.observed_table().to_markdown());
    let reloads: u64 = plan
        .layers()
        .iter()
        .filter(|l| l.is_dynamic())
        .map(|l| l.observed().weight_loads)
        .sum();
    println!(
        "dynamic reloads: {reloads} tile swaps over {} items ({} dynamic layers)",
        xs.len(),
        plan.layers().iter().filter(|l| l.is_dynamic()).count()
    );
    for l in plan.layers() {
        assert_eq!(
            l.predicted_cycles(),
            l.observed().total_cycles,
            "cycle prediction must be exact for `{}`",
            l.name
        );
    }
    println!("verified: reload-aware cycle prediction exact for every layer");
    Ok(())
}
