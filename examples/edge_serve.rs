//! Edge-serving scenario: a trained quantized MLP served over TCP with
//! dynamic batching on the simulated macro; a multi-threaded client drives
//! load and the server reports latency/throughput/energy.
//!
//! Run: `cargo run --release --example edge_serve [requests]`

use cimsim::config::{Config, EnhanceConfig};
use cimsim::coordinator::deployment::{argmax, MlpDeployment};
use cimsim::coordinator::{Client, ServeConfig, ServeFrontend};
use cimsim::mapping::NativeBackend;
use cimsim::nn::dataset::BlobDataset;
use cimsim::nn::mlp::{train, Mlp};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_req: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let mut cfg = Config::default();
    cfg.enhance = EnhanceConfig::both();

    // Train + quantize the edge model.
    let mut ds = BlobDataset::new(12, 0.05, 21);
    let data: Vec<(Vec<f32>, usize)> =
        ds.batch(300).into_iter().map(|s| (s.image.data, s.label)).collect();
    let mut mlp = Mlp::new(&[144, 32, 10], 4);
    let acc = train(&mut mlp, &data, 8, 0.05, 2);
    let cal: Vec<Vec<f32>> = data.iter().take(50).map(|(x, _)| x.clone()).collect();
    let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
    println!("model trained (float acc {:.1}%), quantized to 4b:4b", acc * 100.0);

    // Serve on the simulated macro with dynamic batching.
    let backend = Box::new(NativeBackend::new(cfg.clone()));
    let handle = ServeConfig::builder()
        .max_batch(16)
        .max_wait(std::time::Duration::from_millis(1))
        .serve(ServeFrontend::Backend { deployment: dep, backend })?;
    println!("serving on {} (max batch 16, 1 ms window)", handle.addr);

    // 8 concurrent clients.
    let addr = handle.addr;
    let per_client = n_req / 8;
    let mut joins = Vec::new();
    for t in 0..8u64 {
        let reqs: Vec<(Vec<f32>, usize)> = BlobDataset::new(12, 0.05, 100 + t)
            .batch(per_client)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        joins.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr).expect("connect");
            let mut correct = 0usize;
            for (x, y) in &reqs {
                if argmax(&c.infer(x).expect("infer")) == *y {
                    correct += 1;
                }
            }
            correct
        }));
    }
    let correct: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let metrics = handle.shutdown();
    println!(
        "accuracy on CIM under load: {:.1}% over {} requests",
        100.0 * correct as f64 / (per_client * 8) as f64,
        per_client * 8
    );
    println!("{}", metrics.report(cfg.mac.clock_mhz * 1e6).render());
    Ok(())
}
