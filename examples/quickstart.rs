//! Quickstart: load weights into the simulated 16 Kb macro, run one core
//! operation, and compare the analog result against the exact digital MAC.
//!
//! Run: `cargo run --release --example quickstart`

use cimsim::cim::MacroSim;
use cimsim::config::{Config, EnhanceConfig};
use cimsim::energy::{core_op_energy, efficiency_tops_w};
use cimsim::util::rng::{Rng, Xoshiro256};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Configure the paper's macro with both signal-margin enhancements.
    let mut cfg = Config::default();
    cfg.enhance = EnhanceConfig::both();
    let mut sim = MacroSim::new(cfg.clone());

    // Load 64x16 signed 4-b weights into core 0 (a column per engine).
    let mut rng = Xoshiro256::seeded(7);
    let weights: Vec<Vec<i64>> = (0..cfg.mac.rows)
        .map(|_| (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect())
        .collect();
    sim.load_core(0, &weights)?;

    // One 64-way analog MAC + 9-b cell-embedded readout on random acts.
    let acts: Vec<i64> = (0..cfg.mac.rows).map(|_| rng.next_range_i64(0, 15)).collect();
    let result = sim.core_op(0, &acts, &mut rng)?;
    let exact = sim.golden(0, &acts)?;

    println!("engine |  exact MAC | chip code | reconstructed |  error");
    println!("-------+------------+-----------+---------------+-------");
    for e in 0..cfg.mac.engines {
        println!(
            "  {:>4} | {:>10} | {:>9} | {:>13.1} | {:>6.1}",
            e,
            exact[e],
            result.codes[e],
            result.values[e],
            result.values[e] - exact[e] as f64
        );
    }

    let energy = core_op_energy(&cfg, &result.stats);
    println!(
        "\nop took {} cycles ({:.1} ns at {:.0} MHz), {:.2} pJ -> {:.1} TOPS/W",
        result.stats.total_cycles,
        result.stats.total_cycles as f64 / cfg.mac.clock_mhz * 1e3,
        cfg.mac.clock_mhz,
        energy.total_fj() / 1e3,
        efficiency_tops_w(&cfg, &energy),
    );
    Ok(())
}
