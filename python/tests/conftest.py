"""pytest bootstrap: make `compile` and `tests.helpers` importable when
running from the python/ directory or the repo root."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))
