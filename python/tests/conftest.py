"""pytest bootstrap: make `compile` and `tests.helpers` importable when
running from the python/ directory or the repo root, and skip (rather than
fail collection of) the dependency-heavy modules when the optional test
deps are absent locally. CI installs `hypothesis` and `jax` and runs the
full suite (.github/workflows/ci.yml, `python` job)."""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

# The kernel/model tests import jax (+ pallas, interpret mode) and
# hypothesis at module scope; without these installed, collection itself
# would error. Skipping collection keeps a bare `pytest` green locally —
# test_environment.py always collects, so pytest never exits with
# "no tests ran".
MISSING_DEPS = [m for m in ("hypothesis", "jax") if importlib.util.find_spec(m) is None]

collect_ignore = ["test_kernel.py", "test_model.py"] if MISSING_DEPS else []
