"""Dependency-free canary: always collectable, so the suite reports a
green (possibly partially-skipped) run instead of pytest's exit code 5
("no tests ran") when the optional deps are missing locally."""

from conftest import MISSING_DEPS


def test_suite_visibility():
    if MISSING_DEPS:
        print(
            "optional deps missing (%s): kernel/model tests skipped — "
            "`pip install hypothesis jax` for the full suite"
            % ", ".join(MISSING_DEPS)
        )
    # The repo layout the sys.path bootstrap promises.
    import os

    here = os.path.dirname(os.path.abspath(__file__))
    assert os.path.isdir(os.path.join(here, "..", "compile"))


def test_full_suite_collected_when_deps_present():
    import conftest

    if not MISSING_DEPS:
        assert conftest.collect_ignore == []
    else:
        assert set(conftest.collect_ignore) == {"test_kernel.py", "test_model.py"}
