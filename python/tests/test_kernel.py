"""L1 correctness: the Pallas kernel against the pure-jnp oracle — the core
correctness signal of the compile path — plus hypothesis sweeps over shapes,
modes and input distributions."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cim_engine, ref
from compile.kernels.ref import ADC_BITS, KBITS, CoreParams

from helpers import ALL_MODES, random_inputs


@pytest.mark.parametrize("p", ALL_MODES, ids=lambda p: p.label())
@pytest.mark.parametrize("batch", [16, 48])
def test_pallas_matches_ref(p, batch):
    inputs = random_inputs(p, batch, seed=batch)
    c_ref, v_ref = ref.core_op(p, *inputs)
    c_pal, v_pal = cim_engine.core_op_pallas(p, *inputs)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_pal), atol=1e-3)


@pytest.mark.parametrize("p", ALL_MODES, ids=lambda p: p.label())
def test_noise_free_kernel_equals_ideal_quantizer(p):
    p0 = CoreParams(**{**p.__dict__, "noise": False})
    inputs = random_inputs(p0, 32, seed=7)
    acts, w = inputs[0], inputs[1]
    statics = cim_engine.zero_statics(p0)
    noise = cim_engine.zero_noise(p0, 32)
    codes, values = cim_engine.core_op_pallas(p0, acts, w, *statics, *noise)
    ideal = ref.ideal_codes(p0, acts, w)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(ideal))
    # Reconstruction bounded by half a quantization step (absent clipping).
    exact = np.einsum("br,re->be", np.asarray(acts), np.asarray(w))
    step = p0.adc_lsb / p0.dtc_scale
    unclipped = np.abs(np.asarray(ideal)) < 255
    err = np.abs(np.asarray(values) - exact)[unclipped]
    assert err.max() <= step / 2 + 1e-3


def test_codes_in_range_and_integer():
    p = CoreParams(fold=True, boost=True)
    inputs = random_inputs(p, 16, seed=3)
    codes, _ = cim_engine.core_op_pallas(p, *inputs)
    c = np.asarray(codes)
    assert c.min() >= -256 and c.max() <= 255
    np.testing.assert_array_equal(c, np.round(c))


def test_fold_escapes_small_pulse_noise():
    """The Fig. 4 mechanism: with ReLU-like (small) activations, fold+boost
    shrinks the MAC error dramatically."""
    rng = np.random.default_rng(11)
    batch = 64
    base = CoreParams()
    enh = CoreParams(fold=True, boost=True)
    # Small activations 0..3 (post-ReLU-like), shared across modes.
    acts = jnp.asarray(rng.integers(0, 4, (batch, 64)).astype(np.float32))
    w = jnp.asarray(rng.integers(-7, 8, (64, 16)).astype(np.float32))
    exact = np.einsum("br,re->be", np.asarray(acts), np.asarray(w))

    def rms_err(p):
        inputs = random_inputs(p, batch, seed=5)
        _, values = cim_engine.core_op_pallas(p, acts, w, *inputs[2:])
        if p.fold:
            pass  # reconstruction already restores the fold correction
        return float(np.sqrt(np.mean((np.asarray(values) - exact) ** 2)))

    e_base = rms_err(base)
    e_enh = rms_err(enh)
    assert e_enh < e_base / 1.5, f"baseline {e_base}, enhanced {e_enh}"


def test_zero_acts_zero_weights():
    p = CoreParams(noise=True)
    inputs = random_inputs(p, 16, seed=9)
    zero_acts = jnp.zeros_like(inputs[0])
    codes, _ = cim_engine.core_op_pallas(p, zero_acts, *inputs[1:])
    # No pulses → no discharge → mid-rise code −1 everywhere... except SA
    # offset/noise can flip the borderline comparison; codes stay within a
    # few LSB of the zero transition.
    c = np.asarray(codes)
    assert np.abs(c + 0.5).max() <= 4.5, c


@settings(max_examples=20, deadline=None)
@given(
    batch=st.sampled_from([16, 32]),
    fold=st.booleans(),
    boost=st.booleans(),
    sparsity=st.sampled_from([0.0, 0.5, 0.9]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_pallas_ref_agree(batch, fold, boost, sparsity, seed):
    p = CoreParams(fold=fold, boost=boost)
    inputs = random_inputs(p, batch, seed=seed, sparsity=sparsity)
    c_ref, v_ref = ref.core_op(p, *inputs)
    c_pal, v_pal = cim_engine.core_op_pallas(p, *inputs)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_pal))
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_pal), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_ideal_reconstruction_bound(seed):
    """Noise-free reconstruction error ≤ half step for every mode."""
    rng = np.random.default_rng(seed)
    acts = jnp.asarray(rng.integers(0, 16, (16, 64)).astype(np.float32))
    w = jnp.asarray(rng.integers(-7, 8, (64, 16)).astype(np.float32))
    exact = np.einsum("br,re->be", np.asarray(acts), np.asarray(w))
    for base in ALL_MODES:
        p = CoreParams(**{**base.__dict__, "noise": False})
        statics = cim_engine.zero_statics(p)
        noise = cim_engine.zero_noise(p, 16)
        codes, values = cim_engine.core_op_pallas(p, acts, w, *statics, *noise)
        ideal = ref.ideal_codes(p, acts, w)
        np.testing.assert_array_equal(np.asarray(codes), np.asarray(ideal))
        unclipped = np.abs(np.asarray(ideal)) < 255
        if unclipped.any():
            step = p.adc_lsb / p.dtc_scale
            err = np.abs(np.asarray(values) - exact)[unclipped]
            assert err.max() <= step / 2 + 1e-3, p.label()
