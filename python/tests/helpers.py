"""Shared helpers for the L1/L2 test suite."""

import numpy as np
import jax.numpy as jnp

from compile.kernels.ref import ADC_BITS, KBITS, CoreParams

ALL_MODES = [
    CoreParams(),
    CoreParams(fold=True),
    CoreParams(boost=True),
    CoreParams(fold=True, boost=True),
]


def random_inputs(p: CoreParams, batch: int, seed: int, *, sparsity=0.0):
    """Full random input bundle for one core op."""
    rng = np.random.default_rng(seed)
    acts = rng.integers(0, p.act_max + 1, (batch, p.rows)).astype(np.float32)
    if sparsity > 0:
        acts *= rng.random((batch, p.rows)) >= sparsity
    w = rng.integers(-7, 8, (p.rows, p.engines)).astype(np.float32)
    cell = rng.normal(0, 0.02, (p.rows, KBITS, p.engines)).astype(np.float32)
    sa = rng.normal(0, 8.0, p.engines).astype(np.float32)
    cap = rng.normal(0, 0.001, p.engines).astype(np.float32)
    step = rng.normal(0, 0.002, (p.engines, ADC_BITS - 1)).astype(np.float32)
    zj = rng.normal(0, 1, (batch, p.rows, KBITS)).astype(np.float32)
    zs = rng.normal(0, 1, (batch, p.engines, ADC_BITS - 1)).astype(np.float32)
    zc = rng.normal(0, 1, (batch, p.engines, ADC_BITS)).astype(np.float32)
    return tuple(jnp.asarray(a) for a in (acts, w, cell, sa, cap, step, zj, zs, zc))
