"""L2 model tests: tiled cim_matmul against exact integer matmul, and the
full quantized-MLP forward graph (shapes, determinism, digital-reference
agreement in the noise-free limit)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import ADC_BITS, KBITS, CoreParams


def statics_zero():
    c, r, e, k = model.CORES, model.ROWS, model.ENGINES, KBITS
    return (
        jnp.zeros((c, r, k, e), jnp.float32),
        jnp.zeros((c, e), jnp.float32),
        jnp.zeros((c, e), jnp.float32),
        jnp.zeros((c, e, ADC_BITS - 1), jnp.float32),
    )


def test_cim_matmul_tiles_and_accuracy():
    p = CoreParams(fold=True, boost=True, noise=False)
    rng = np.random.default_rng(4)
    b, k, n = 16, 144, 32
    acts = jnp.asarray(rng.integers(0, 16, (b, k)).astype(np.float32))
    w = jnp.asarray(rng.integers(-7, 8, (k, n)).astype(np.float32))
    n_tiles = model.mlp_tiles((k, n))[0]
    z = jnp.zeros((b, n_tiles * model.Z_PER_TILE), jnp.float32)
    out, used = model.cim_matmul(p, acts, w, statics_zero(), z, 0)
    assert used == n_tiles == 6  # 3 row tiles × 2 col tiles
    exact = np.asarray(acts) @ np.asarray(w)
    # Each of the 3 row tiles contributes ≤ step/2 quantization error.
    step = p.adc_lsb / p.dtc_scale
    bound = 3 * step / 2 + 1e-3
    assert np.abs(np.asarray(out) - exact).max() <= bound


def test_mlp_forward_shapes_and_determinism():
    p = CoreParams(fold=True, boost=True)
    fn = model.mlp_forward_fn(p)
    inputs = model.example_mlp_inputs(batch=16, seed=1)
    (logits1,) = fn(*inputs)
    (logits2,) = fn(*inputs)
    assert logits1.shape == (16, 10)
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))
    assert np.isfinite(np.asarray(logits1)).all()


def test_mlp_noise_free_matches_digital_reference():
    """With zero statics/noise the macro-MLP must track an exact integer
    quantized MLP within accumulated quantization steps."""
    p = CoreParams(fold=True, boost=True, noise=False)
    fn = model.mlp_forward_fn(p)
    x, w1, b1, w2, b2, scales, *_ = model.example_mlp_inputs(batch=16, seed=2)
    st = statics_zero()
    z = jnp.zeros((16, model.mlp_noise_len()), jnp.float32)
    (logits,) = fn(x, w1, b1, w2, b2, scales, *st, z)

    # Digital reference of the same quantized pipeline.
    a0, w1s, a1c, w2s = [float(v) for v in np.asarray(scales)]
    xq = np.clip(np.round(np.asarray(x) / a0), 0, 15)
    y1 = xq @ np.asarray(w1) * (a0 * w1s) + np.asarray(b1)
    y1 = np.maximum(y1, 0)
    hq = np.clip(np.round(y1 / (a1c / 15.0)), 0, 15)
    want = hq @ np.asarray(w2) * ((a1c / 15.0) * w2s) + np.asarray(b2)

    got = np.asarray(logits)
    # Error budget: layer1 ADC (3 row tiles × step/2 × scales) propagates
    # through requantization; allow a conservative absolute bound.
    step1 = p.adc_lsb / p.dtc_scale * (a0 * w1s) * 3
    step2 = p.adc_lsb / p.dtc_scale * ((a1c / 15.0) * w2s)
    # Requant can flip a hidden code by 1 → w2 row magnitude · scales.
    requant_slack = 7 * 2 * ((a1c / 15.0) * w2s) * 4
    bound = step1 * 50 + step2 + requant_slack  # dominated by requant flips
    assert np.abs(got - want).max() <= bound, (np.abs(got - want).max(), bound)


def test_mlp_jits_and_lowers():
    p = CoreParams(fold=True, boost=True)
    fn = model.mlp_forward_fn(p)
    from compile.aot import mlp_specs, to_hlo_text

    lowered = jax.jit(fn).lower(*mlp_specs(16))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 10_000


def test_macro_lowering_all_modes():
    from compile.aot import MODES, macro_specs, to_hlo_text

    for mode, p in MODES.items():
        fn = model.macro_op_fn(p)
        lowered = jax.jit(fn).lower(*macro_specs(16))
        text = to_hlo_text(lowered)
        assert "HloModule" in text, mode


def test_noise_bundle_length():
    # 7 tiles × 464 floats for the default MLP.
    assert model.mlp_tiles((144, 32, 10)) == [6, 1]
    assert model.mlp_noise_len() == 7 * 464
