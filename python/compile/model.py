"""L2 JAX model: the batched CIM macro op and a quantized MLP forward whose
every matrix product runs through the L1 Pallas kernel (tiled 64×16, cores
assigned round-robin) — the compute graph the Rust coordinator serves after
AOT lowering.

Python never runs at inference time: `aot.py` lowers these functions to HLO
text once; `rust/src/runtime` loads and executes them via PJRT.
"""

import jax.numpy as jnp

from .kernels import cim_engine
from .kernels.cim_engine import B_TILE
from .kernels.ref import ADC_BITS, KBITS, CoreParams

CORES = 4
ROWS = 64
ENGINES = 16

# Noise-bundle layout per tile (f32 per batch element).
Z_JIT = ROWS * KBITS           # 192
Z_STEP = ENGINES * (ADC_BITS - 1)  # 128
Z_CMP = ENGINES * ADC_BITS     # 144
Z_PER_TILE = Z_JIT + Z_STEP + Z_CMP  # 464


def macro_op_fn(p: CoreParams):
    """Returns the jittable single-core batched op:
    (acts [B,64], w [64,16], cell, sa, cap, step, z_jit, z_step, z_cmp)
    → (codes [B,16], values [B,16])."""

    def fn(acts, w, cell, sa, cap, step, z_jit, z_step, z_cmp):
        codes, values = cim_engine.core_op_pallas(
            p, acts, w, cell, sa, cap, step, z_jit, z_step, z_cmp
        )
        return codes, values

    return fn


def _slice_tile_noise(z, tile_idx, batch):
    """Carve one tile's (z_jit, z_step, z_cmp) out of the [B, NZ] bundle."""
    off = tile_idx * Z_PER_TILE
    zj = z[:, off:off + Z_JIT].reshape(batch, ROWS, KBITS)
    zs = z[:, off + Z_JIT:off + Z_JIT + Z_STEP].reshape(batch, ENGINES, ADC_BITS - 1)
    zc = z[:, off + Z_JIT + Z_STEP:off + Z_PER_TILE].reshape(batch, ENGINES, ADC_BITS)
    return zj, zs, zc


def _pad_to(x, rows, axis):
    pad = rows - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def cim_matmul(p: CoreParams, acts_q, w_q, statics, z, tile_base):
    """Tiled integer matrix product on the macro: acts_q [B,K] (0..15
    integer-valued f32) × w_q [K,N] (±7) → int-sum estimates [B,N] (product
    units). Tiles are mapped to cores round-robin starting at `tile_base`;
    returns (result, tiles_used)."""
    b, k = acts_q.shape
    n = w_q.shape[1]
    cell_all, sa_all, cap_all, step_all = statics
    n_rt = -(-k // ROWS)
    n_ct = -(-n // ENGINES)
    out = jnp.zeros((b, n_ct * ENGINES), jnp.float32)
    tile = 0
    for rt in range(n_rt):
        a_tile = _pad_to(acts_q[:, rt * ROWS:(rt + 1) * ROWS], ROWS, 1)
        for ct in range(n_ct):
            w_tile = _pad_to(
                _pad_to(w_q[rt * ROWS:(rt + 1) * ROWS, ct * ENGINES:(ct + 1) * ENGINES],
                        ROWS, 0),
                ENGINES, 1,
            )
            core = (tile_base + tile) % CORES
            zj, zs, zc = _slice_tile_noise(z, tile_base + tile, b)
            _, values = cim_engine.core_op_pallas(
                p, a_tile, w_tile,
                cell_all[core], sa_all[core], cap_all[core], step_all[core],
                zj, zs, zc,
            )
            out = out.at[:, ct * ENGINES:(ct + 1) * ENGINES].add(values)
            tile += 1
    return out[:, :n], tile


def mlp_tiles(dims):
    """Number of macro tiles each layer of an MLP consumes."""
    per_layer = []
    for k, n in zip(dims[:-1], dims[1:]):
        per_layer.append((-(-k // ROWS)) * (-(-n // ENGINES)))
    return per_layer


def mlp_forward_fn(p: CoreParams, dims=(144, 32, 10)):
    """Quantized-MLP forward through the macro.

    Inputs:
      x        [B, dims[0]]  raw features (≥0)
      w1_q     [dims0, dims1]  integer-valued f32 (±7)
      b1       [dims1]        float bias (real units)
      w2_q     [dims1, dims2]
      b2       [dims2]
      scales   [4]: a0_scale, w1_scale, a1_cal, w2_scale
      statics  cell [4,64,3,16], sa [4,16], cap [4,16], step [4,16,8]
      z        [B, n_tiles·Z_PER_TILE]  standard normals
    Output: logits [B, dims2].
    """
    t1, t2 = mlp_tiles(dims)

    def fn(x, w1_q, b1, w2_q, b2, scales, cell, sa, cap, step, z):
        statics = (cell, sa, cap, step)
        a0_scale = scales[0]
        w1_scale = scales[1]
        a1_cal = scales[2]
        w2_scale = scales[3]

        # Input quantization (unsigned 4-b).
        x_q = jnp.clip(jnp.round(x / a0_scale), 0, 15)

        # Layer 1 on the macro.
        s1, used = cim_matmul(p, x_q, w1_q, statics, z, 0)
        assert used == t1
        y1 = s1 * (a0_scale * w1_scale) + b1[None, :]
        y1 = jnp.maximum(y1, 0.0)

        # Re-quantize hidden activations (fixed calibration max).
        a1_scale = a1_cal / 15.0
        h_q = jnp.clip(jnp.round(y1 / a1_scale), 0, 15)

        # Layer 2 on the macro.
        s2, used2 = cim_matmul(p, h_q, w2_q, statics, z, t1)
        assert used2 == t2
        logits = s2 * (a1_scale * w2_scale) + b2[None, :]
        return (logits,)

    return fn


def mlp_noise_len(dims=(144, 32, 10)):
    return sum(mlp_tiles(dims)) * Z_PER_TILE


def example_mlp_inputs(batch=B_TILE, dims=(144, 32, 10), seed=0):
    """Deterministic example inputs with the right shapes (for lowering and
    tests)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random((batch, dims[0])).astype(np.float32))
    w1 = jnp.asarray(rng.integers(-7, 8, (dims[0], dims[1])).astype(np.float32))
    b1 = jnp.asarray(rng.normal(0, 0.1, dims[1]).astype(np.float32))
    w2 = jnp.asarray(rng.integers(-7, 8, (dims[1], dims[2])).astype(np.float32))
    b2 = jnp.asarray(rng.normal(0, 0.1, dims[2]).astype(np.float32))
    scales = jnp.asarray(np.array([1.0 / 15, 0.05, 4.0, 0.05], np.float32))
    cell = jnp.asarray(rng.normal(0, 0.02, (CORES, ROWS, KBITS, ENGINES)).astype(np.float32))
    sa = jnp.asarray(rng.normal(0, 8, (CORES, ENGINES)).astype(np.float32))
    cap = jnp.asarray(rng.normal(0, 0.001, (CORES, ENGINES)).astype(np.float32))
    step = jnp.asarray(rng.normal(0, 0.002, (CORES, ENGINES, ADC_BITS - 1)).astype(np.float32))
    z = jnp.asarray(rng.normal(0, 1, (batch, mlp_noise_len(dims))).astype(np.float32))
    return (x, w1, b1, w2, b2, scales, cell, sa, cap, step, z)
