"""L1 Pallas kernel: one CIM core operation (MAC phase + cell-embedded ADC),
vectorized over a batch of activation vectors.

Hardware adaptation (DESIGN.md §3 "Hardware-Adaptation"): the analog array
is modeled as a dense [rows=64, kbits=3, engines=16] discharge tensor held
in VMEM; the per-row accumulation is expressed as an MXU-shaped contraction
(`einsum brk,rke->bre` + row reduce), and the 9-step binary search is an
unrolled vector loop over the engine lanes. The grid tiles the batch
dimension; weights and per-instance statics use constant index maps
(weight-stationary, like the chip). `interpret=True` everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example).

VMEM budget per grid step (f32, B_TILE=16): weights 64·16, statics
64·3·16·4B ≈ 12 KiB, batch blocks ≈ (16·64 + 16·64·3 + 16·16·17)·4B ≈
230 KiB — far under the ~16 MiB VMEM of a real TPU core; the MXU would see
a 64-deep contraction per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .ref import ADC_BITS, KBITS, CoreParams

B_TILE = 16


def _kernel(p: CoreParams,
            acts_ref, w_ref, cell_ref, sa_ref, cap_ref, step_ref,
            zjit_ref, zstep_ref, zcmp_ref,
            codes_ref, values_ref):
    """Pallas kernel body: one batch tile through MAC + readout."""
    acts = acts_ref[...]          # [TB, R]
    w = w_ref[...]                # [R, E] signed
    cell = cell_ref[...]          # [R, K, E]
    sa = sa_ref[...]              # [E]
    cap = cap_ref[...]            # [E]
    step = step_ref[...]          # [E, 8]
    z_jit = zjit_ref[...]         # [TB, R, K]
    z_step = zstep_ref[...]       # [TB, E, 8]
    z_cmp = zcmp_ref[...]         # [TB, E, 9]

    w_bits, w_sign = ref.split_weights(w)
    rbl, rblb = ref.mac_phase(p, acts, w_bits, w_sign, cell, cap, z_jit)
    codes = ref.readout(p, rbl, rblb, sa, cap, step, z_step, z_cmp)
    codes_ref[...] = codes
    values_ref[...] = ref.reconstruct(p, codes, w)


def core_op_pallas(p: CoreParams, acts, w_signed, cell_mism, sa_off, cap,
                   step_static, z_jit, z_step, z_cmp):
    """Batched core op via pallas_call. Shapes as in `ref.core_op`; the batch
    must be a multiple of B_TILE (pad with zero rows otherwise)."""
    b, r = acts.shape
    e = w_signed.shape[1]
    assert b % B_TILE == 0, f"batch {b} must be a multiple of {B_TILE}"
    grid = (b // B_TILE,)

    bspec = lambda shape, bm: pl.BlockSpec(shape, bm)
    batch_map = lambda i: (i,) + (0,) * 0

    kernel = functools.partial(_kernel, p)
    codes, values = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B_TILE, r), lambda i: (i, 0)),          # acts
            pl.BlockSpec((r, e), lambda i: (0, 0)),               # weights
            pl.BlockSpec((r, KBITS, e), lambda i: (0, 0, 0)),     # cell mism
            pl.BlockSpec((e,), lambda i: (0,)),                   # sa offset
            pl.BlockSpec((e,), lambda i: (0,)),                   # cap mism
            pl.BlockSpec((e, ADC_BITS - 1), lambda i: (0, 0)),    # step static
            pl.BlockSpec((B_TILE, r, KBITS), lambda i: (i, 0, 0)),
            pl.BlockSpec((B_TILE, e, ADC_BITS - 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((B_TILE, e, ADC_BITS), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B_TILE, e), lambda i: (i, 0)),
            pl.BlockSpec((B_TILE, e), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, e), jnp.float32),
            jax.ShapeDtypeStruct((b, e), jnp.float32),
        ],
        interpret=True,
    )(acts, w_signed, cell_mism, sa_off, cap, step_static, z_jit, z_step, z_cmp)
    return codes, values


def zero_statics(p: CoreParams):
    """Ideal-instance statics (no fabrication mismatch)."""
    return (
        jnp.zeros((p.rows, KBITS, p.engines), jnp.float32),   # cell
        jnp.zeros((p.engines,), jnp.float32),                 # sa
        jnp.zeros((p.engines,), jnp.float32),                 # cap
        jnp.zeros((p.engines, ADC_BITS - 1), jnp.float32),    # step
    )


def zero_noise(p: CoreParams, batch: int):
    """Zero dynamic-noise draws (deterministic op)."""
    return (
        jnp.zeros((batch, p.rows, KBITS), jnp.float32),
        jnp.zeros((batch, p.engines, ADC_BITS - 1), jnp.float32),
        jnp.zeros((batch, p.engines, ADC_BITS), jnp.float32),
    )
