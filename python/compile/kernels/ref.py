"""Pure-jnp oracle for the CIM core operation (L1 correctness reference).

Mirrors `rust/src/cim/{engine,adc}.rs` exactly — the same discharge physics,
unit conventions (τ0 pulse widths, `u` voltage units) and the tie-down
mid-rise binary-search quantizer. The Pallas kernel in `cim_engine.py` is
checked against this module by pytest; the Rust native model is checked
against the AOT artifact of the kernel by `cargo test` — closing the
three-way equivalence loop.

All inputs are f32 tensors holding integer values where noted.
"""

from dataclasses import dataclass

import jax.numpy as jnp

KBITS = 3  # weight magnitude bits (4-b sign-magnitude)
ADC_BITS = 9


@dataclass(frozen=True)
class CoreParams:
    """Compile-time configuration baked into one artifact (one enhancement
    mode); mirrors `config::{MacroConfig, EnhanceConfig, NoiseConfig}`."""

    rows: int = 64
    engines: int = 16
    fold: bool = False
    boost: bool = False
    fold_offset: int = 8
    fold_gain: float = 1.875
    boost_gain: float = 2.0
    noise: bool = True
    sigma_t_floor: float = 3.40
    sigma_t_small: float = 48.5
    t_knee: float = 2.0
    t_pow: float = 1.0
    sigma_sa_cmp: float = 6.0
    sigma_step_rel: float = 0.004
    # Geometry-derived constants (defaults match the 16 Kb macro).
    vpp: float = 6720.0
    act_max: int = 15

    @property
    def dtc_scale(self) -> float:
        s = 1.0
        if self.fold:
            s *= self.fold_gain
        if self.boost:
            s *= self.boost_gain
        return s

    @property
    def fullscale(self) -> float:
        return 2.0 * self.vpp

    @property
    def adc_lsb(self) -> float:
        return self.fullscale / (1 << ADC_BITS)

    def label(self) -> str:
        return {(False, False): "baseline", (True, False): "fold",
                (False, True): "boost", (True, True): "fold_boost"}[(self.fold, self.boost)]


def split_weights(w_signed):
    """Signed weights [R, E] → (mag_bits [R, KBITS, E], sign [R, E] ±1)."""
    w = jnp.asarray(w_signed, jnp.float32)
    sign = jnp.where(w < 0, -1.0, 1.0)
    mag = jnp.abs(w)
    bits = jnp.stack(
        [jnp.floor(mag / (1 << k)) % 2.0 for k in range(KBITS)], axis=1
    )
    return bits.astype(jnp.float32), sign.astype(jnp.float32)


def mac_phase(p: CoreParams, acts, w_bits, w_sign, cell_mism, cap, z_jit):
    """MAC phase: per-engine RBL/RBLB discharge (u).

    acts      [B, R]      unsigned activations (integer-valued f32)
    w_bits    [R, K, E]   weight magnitude bits
    w_sign    [R, E]      ±1
    cell_mism [R, K, E]   relative branch mismatch
    cap       [E]         RBL/RBLB capacitor mismatch δ
    z_jit     [B, R, K]   standard normals (pulse-timing noise)
    returns (rbl_drop [B, E], rblb_drop [B, E])
    """
    s = p.dtc_scale
    a_eff = acts - (p.fold_offset if p.fold else 0)
    mag = jnp.abs(a_eff)  # [B, R]
    a_pos = a_eff > 0  # [B, R]

    # Per-bit pulse widths mag·2^k·s, built from scalar constants so the
    # expression stays pallas-capturable (no non-scalar closure constants).
    nominal = jnp.stack(
        [mag * (float(1 << k) * s) for k in range(KBITS)], axis=-1
    )  # [B, R, K]
    if p.noise:
        # Hyperbolic narrow-pulse penalty (mirrors cim::noise::jitter_sigma).
        sigma = jnp.where(
            nominal > 0,
            p.sigma_t_floor + p.sigma_t_small
            * (p.t_knee / jnp.maximum(nominal, 1e-20)) ** p.t_pow,
            0.0,
        )
        width = jnp.maximum(nominal + sigma * z_jit, 0.0)
    else:
        width = nominal

    # Per-cell discharge: width ⊗ (1+mism) gated by the weight bit.
    cellw = w_bits * (1.0 + cell_mism)  # [R, K, E]
    per_row = jnp.einsum("brk,rke->bre", width, cellw)  # [B, R, E]

    to_rbl = (a_pos[:, :, None] == (w_sign > 0)[None, :, :]).astype(jnp.float32)
    rbl = jnp.sum(per_row * to_rbl, axis=1)  # [B, E]
    rblb = jnp.sum(per_row * (1.0 - to_rbl), axis=1)

    # Capacitor mismatch and physical headroom clamp.
    rbl = jnp.minimum(rbl * (1.0 - cap)[None, :], p.vpp)
    rblb = jnp.minimum(rblb * (1.0 + cap)[None, :], p.vpp)
    return rbl, rblb


def readout(p: CoreParams, rbl_drop, rblb_drop, sa_off, cap, step_static, z_step, z_cmp):
    """Cell-embedded binary-search ADC, unrolled 9 steps.

    sa_off      [E]        static SA offset (u)
    step_static [E, 8]     static per-step relative error
    z_step      [B, E, 8]  dynamic step noise
    z_cmp       [B, E, 9]  SA comparison noise
    returns codes [B, E] (integer-valued f32, −256..255)
    """
    v_rbl = p.vpp - rbl_drop
    v_rblb = p.vpp - rblb_drop
    est_half = jnp.zeros_like(rbl_drop)
    for d in range(ADC_BITS):
        noise = p.sigma_sa_cmp * z_cmp[:, :, d] if p.noise else 0.0
        bit = (v_rblb - v_rbl) + sa_off[None, :] + noise > 0.0
        est_half = est_half + jnp.where(bit, 1.0, -1.0) * float(1 << (ADC_BITS - 1 - d))
        if d + 1 < ADC_BITS:
            nominal = p.fullscale / float(1 << (d + 2))
            err = step_static[None, :, d]
            if p.noise:
                err = err + p.sigma_step_rel * z_step[:, :, d]
            q = jnp.maximum(nominal * (1.0 + err), 0.0)
            v_rblb = jnp.where(bit, jnp.maximum(v_rblb - q * (1.0 + cap)[None, :], 0.0), v_rblb)
            v_rbl = jnp.where(bit, v_rbl, jnp.maximum(v_rbl - q * (1.0 - cap)[None, :], 0.0))
    return jnp.floor(est_half / 2.0)


def reconstruct(p: CoreParams, codes, w_signed):
    """Digital reconstruction: mid-rise dequant + fold correction."""
    col_sum = jnp.sum(jnp.asarray(w_signed, jnp.float32), axis=0)  # [E]
    corr = (p.fold_offset * col_sum)[None, :] if p.fold else 0.0
    return (codes + 0.5) * p.adc_lsb / p.dtc_scale + corr


def core_op(p: CoreParams, acts, w_signed, cell_mism, sa_off, cap, step_static,
            z_jit, z_step, z_cmp):
    """Full core operation. Returns (codes [B,E], values [B,E])."""
    w_bits, w_sign = split_weights(w_signed)
    rbl, rblb = mac_phase(p, acts, w_bits, w_sign, cell_mism, cap, z_jit)
    codes = readout(p, rbl, rblb, sa_off, cap, step_static, z_step, z_cmp)
    return codes, reconstruct(p, codes, w_signed)


def ideal_codes(p: CoreParams, acts, w_signed):
    """Noise-free golden: quantize the exact folded MAC (tie-down mid-rise),
    mirroring `cim::golden::ideal_code`."""
    w = jnp.asarray(w_signed, jnp.float32)
    a_eff = acts - (p.fold_offset if p.fold else 0)
    d = jnp.einsum("br,re->be", a_eff.astype(jnp.float32), w)
    x = d * p.dtc_scale / p.adc_lsb
    code = jnp.ceil(x) - 1.0
    half = float(1 << (ADC_BITS - 1))
    return jnp.clip(code, -half, half - 1)
