//! A single transformer encoder block (pre-quantization float reference) —
//! the dynamic-weight workload of DESIGN.md §10.
//!
//! Multi-head attention is stored **per head**: `wq/wk/wv[i]` are
//! `[d_model][d_head]` column-major weight matrices (`w_cols` layout, one
//! column per output) and `wo[i]` is `[d_head][d_model]`. The output
//! projection of the concatenated heads is expressed as a sum instead of a
//! concat — `concat(h_0…h_{H−1})·W_O = Σ_i h_i·W_O[i·d_head‥]` — because
//! the graph IR has no concat node, and the sum form maps each head's
//! output projection onto its own weight-stationary macro tile grid.
//! [`TransformerBlock::forward`] is the float golden
//! `Graph::from_transformer_block` is checked against.
//!
//! [`DecoderModel`] stacks blocks into a GPT-style causal decoder
//! (token embedding + positional table + N blocks + LM head); its
//! [`DecoderModel::forward_causal`] is the float golden behind
//! `Graph::from_decoder` and the KV-cache decode engine's calibration
//! (DESIGN.md §13).

use crate::nn::ops::{causal_softmax, layer_norm, softmax_last_dim};
use crate::nn::tensor::Tensor;
use crate::util::rng::{Rng, Xoshiro256};

/// LayerNorm epsilon shared by the float reference and the graph builder.
pub const LN_EPS: f32 = 1e-5;

/// Weights of one encoder block: H-head self-attention + 2-layer FFN, each
/// sublayer followed by a residual add and LayerNorm (post-norm).
pub struct TransformerBlock {
    pub d_model: usize,
    pub heads: usize,
    pub d_ff: usize,
    /// Per-head projections, `w_cols` layout `[d_model][d_head]`.
    pub wq: Vec<Tensor>,
    pub wk: Vec<Tensor>,
    pub wv: Vec<Tensor>,
    /// Per-head output projection rows, `[d_head][d_model]`.
    pub wo: Vec<Tensor>,
    pub bq: Vec<Vec<f32>>,
    pub bk: Vec<Vec<f32>>,
    pub bv: Vec<Vec<f32>>,
    /// Output-projection bias (applied once, not per head).
    pub b_o: Vec<f32>,
    pub ln1_gamma: Vec<f32>,
    pub ln1_beta: Vec<f32>,
    /// FFN expand, `[d_model][d_ff]`.
    pub w_ff1: Tensor,
    pub b_ff1: Vec<f32>,
    /// FFN contract, `[d_ff][d_model]`.
    pub w_ff2: Tensor,
    pub b_ff2: Vec<f32>,
    pub ln2_gamma: Vec<f32>,
    pub ln2_beta: Vec<f32>,
}

fn rand_cols(rows: usize, cols: usize, scale: f32, rng: &mut Xoshiro256) -> Tensor {
    Tensor::from_vec(
        &[rows, cols],
        (0..rows * cols).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect(),
    )
}

fn rand_vec(n: usize, scale: f32, rng: &mut Xoshiro256) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
}

/// `[rows_a][inner] × [inner][cols_b] → [rows_a][cols_b]` float matmul.
fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    assert_eq!(a.shape[1], b.shape[0], "matmul inner dims");
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[1]);
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for kk in 0..k {
            let av = a.at2(i, kk);
            for j in 0..n {
                *out.at2_mut(i, j) += av * b.at2(kk, j);
            }
        }
    }
    out
}

/// `a · bᵀ` for row-major `a [m][k]`, `b [n][k]` → `[m][n]` (Q·Kᵀ).
fn matmul_t(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape[1], b.shape[1], "matmul_t inner dims");
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[0]);
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += a.at2(i, kk) * b.at2(j, kk);
            }
            *out.at2_mut(i, j) = acc;
        }
    }
    out
}

fn add_bias_rows(t: &mut Tensor, bias: &[f32]) {
    let cols = t.shape[1];
    for row in t.data.chunks_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

impl TransformerBlock {
    /// Random small-scale init (weights ~ ±1/√fan_in, LN at γ=1, β=0 with a
    /// small perturbation) — a synthetic but representative block.
    pub fn new(d_model: usize, heads: usize, d_ff: usize, seed: u64) -> Self {
        assert!(heads > 0 && d_model % heads == 0, "d_model must divide into heads");
        let dh = d_model / heads;
        let mut rng = Xoshiro256::seeded(seed ^ 0x7A11_5EED);
        let sp = 1.0 / (d_model as f32).sqrt();
        let so = 1.0 / (dh as f32).sqrt();
        let per_head = |rows: usize, cols: usize, s: f32, rng: &mut Xoshiro256| -> Vec<Tensor> {
            (0..heads).map(|_| rand_cols(rows, cols, s, rng)).collect()
        };
        Self {
            d_model,
            heads,
            d_ff,
            wq: per_head(d_model, dh, sp, &mut rng),
            wk: per_head(d_model, dh, sp, &mut rng),
            wv: per_head(d_model, dh, sp, &mut rng),
            wo: per_head(dh, d_model, so, &mut rng),
            bq: (0..heads).map(|_| rand_vec(dh, 0.05, &mut rng)).collect(),
            bk: (0..heads).map(|_| rand_vec(dh, 0.05, &mut rng)).collect(),
            bv: (0..heads).map(|_| rand_vec(dh, 0.05, &mut rng)).collect(),
            b_o: rand_vec(d_model, 0.05, &mut rng),
            ln1_gamma: (0..d_model).map(|_| 1.0 + (rng.next_f32() - 0.5) * 0.1).collect(),
            ln1_beta: rand_vec(d_model, 0.05, &mut rng),
            w_ff1: rand_cols(d_model, d_ff, sp, &mut rng),
            b_ff1: rand_vec(d_ff, 0.05, &mut rng),
            w_ff2: rand_cols(d_ff, d_model, 1.0 / (d_ff as f32).sqrt(), &mut rng),
            b_ff2: rand_vec(d_model, 0.05, &mut rng),
            ln2_gamma: (0..d_model).map(|_| 1.0 + (rng.next_f32() - 0.5) * 0.1).collect(),
            ln2_beta: rand_vec(d_model, 0.05, &mut rng),
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.heads
    }

    /// Float reference forward: `x [seq][d_model] → [seq][d_model]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rank(), 2);
        assert_eq!(x.shape[1], self.d_model, "input width vs d_model");
        let dh = self.d_head();
        let mut attn = Tensor::zeros(&[x.shape[0], self.d_model]);
        for i in 0..self.heads {
            let mut q = matmul(x, &self.wq[i]);
            add_bias_rows(&mut q, &self.bq[i]);
            let mut k = matmul(x, &self.wk[i]);
            add_bias_rows(&mut k, &self.bk[i]);
            let mut v = matmul(x, &self.wv[i]);
            add_bias_rows(&mut v, &self.bv[i]);
            let scores = matmul_t(&q, &k).map(|s| s / (dh as f32).sqrt());
            let probs = softmax_last_dim(&scores);
            let ctx = matmul(&probs, &v);
            let head_out = matmul(&ctx, &self.wo[i]);
            for (a, h) in attn.data.iter_mut().zip(&head_out.data) {
                *a += h;
            }
        }
        add_bias_rows(&mut attn, &self.b_o);
        for (a, xv) in attn.data.iter_mut().zip(&x.data) {
            *a += xv;
        }
        let h1 = layer_norm(&attn, &self.ln1_gamma, &self.ln1_beta, LN_EPS);

        let mut f = matmul(&h1, &self.w_ff1);
        add_bias_rows(&mut f, &self.b_ff1);
        let f = f.map(|v| v.max(0.0));
        let mut f2 = matmul(&f, &self.w_ff2);
        add_bias_rows(&mut f2, &self.b_ff2);
        for (o, h) in f2.data.iter_mut().zip(&h1.data) {
            *o += h;
        }
        layer_norm(&f2, &self.ln2_gamma, &self.ln2_beta, LN_EPS)
    }

    /// Causal (autoregressive) float forward: identical to
    /// [`TransformerBlock::forward`] except row `i` of every head's score
    /// matrix only attends to columns `0..=i` ([`causal_softmax`]).
    pub fn forward_causal(&self, x: &Tensor) -> Tensor {
        self.forward_causal_traced(x).out
    }

    /// Causal forward that also returns the intermediates the decode
    /// engine's activation-boundary calibration needs (DESIGN.md §13):
    /// per-head post-bias Q rows, per-head context rows, the post-LN1
    /// hidden, and the post-ReLU FFN activation.
    pub fn forward_causal_traced(&self, x: &Tensor) -> CausalTrace {
        assert_eq!(x.rank(), 2);
        assert_eq!(x.shape[1], self.d_model, "input width vs d_model");
        let dh = self.d_head();
        let mut attn = Tensor::zeros(&[x.shape[0], self.d_model]);
        let mut qs = Vec::with_capacity(self.heads);
        let mut ctxs = Vec::with_capacity(self.heads);
        for i in 0..self.heads {
            let mut q = matmul(x, &self.wq[i]);
            add_bias_rows(&mut q, &self.bq[i]);
            let mut k = matmul(x, &self.wk[i]);
            add_bias_rows(&mut k, &self.bk[i]);
            let mut v = matmul(x, &self.wv[i]);
            add_bias_rows(&mut v, &self.bv[i]);
            let scores = matmul_t(&q, &k).map(|s| s / (dh as f32).sqrt());
            let probs = causal_softmax(&scores);
            let ctx = matmul(&probs, &v);
            let head_out = matmul(&ctx, &self.wo[i]);
            for (a, h) in attn.data.iter_mut().zip(&head_out.data) {
                *a += h;
            }
            qs.push(q);
            ctxs.push(ctx);
        }
        add_bias_rows(&mut attn, &self.b_o);
        for (a, xv) in attn.data.iter_mut().zip(&x.data) {
            *a += xv;
        }
        let h1 = layer_norm(&attn, &self.ln1_gamma, &self.ln1_beta, LN_EPS);

        let mut f = matmul(&h1, &self.w_ff1);
        add_bias_rows(&mut f, &self.b_ff1);
        let f_relu = f.map(|v| v.max(0.0));
        let mut f2 = matmul(&f_relu, &self.w_ff2);
        add_bias_rows(&mut f2, &self.b_ff2);
        for (o, h) in f2.data.iter_mut().zip(&h1.data) {
            *o += h;
        }
        let out = layer_norm(&f2, &self.ln2_gamma, &self.ln2_beta, LN_EPS);
        CausalTrace { q: qs, ctx: ctxs, h1, f_relu, out }
    }
}

/// Intermediates of one causal block forward, captured for the decode
/// engine's activation-boundary calibration (DESIGN.md §13).
pub struct CausalTrace {
    /// Per-head post-bias query rows `[seq][d_head]`.
    pub q: Vec<Tensor>,
    /// Per-head attention-context rows `[seq][d_head]`.
    pub ctx: Vec<Tensor>,
    /// Post-LN1 hidden `[seq][d_model]` (FFN-expand input boundary).
    pub h1: Tensor,
    /// Post-ReLU FFN activation `[seq][d_ff]` (FFN-contract boundary).
    pub f_relu: Tensor,
    /// Block output `[seq][d_model]`.
    pub out: Tensor,
}

/// A GPT-style causal decoder: token embedding + deterministic sinusoid
/// positional table + a stack of [`TransformerBlock`]s run causally + a
/// linear LM head over the vocabulary (DESIGN.md §13).
pub struct DecoderModel {
    pub d_model: usize,
    pub vocab: usize,
    /// Longest sequence the positional table (and any KV cache built from
    /// this model) supports.
    pub max_seq: usize,
    pub blocks: Vec<TransformerBlock>,
    /// Token embedding rows `[vocab][d_model]`.
    pub embed: Tensor,
    /// Sinusoid positional table `[max_seq][d_model]`.
    pub pos: Tensor,
    /// LM head, `w_cols` layout `[d_model][vocab]`.
    pub w_head: Tensor,
    pub b_head: Vec<f32>,
}

impl DecoderModel {
    /// Random small-scale init; blocks get decorrelated per-layer seeds.
    pub fn new(
        d_model: usize,
        heads: usize,
        d_ff: usize,
        vocab: usize,
        n_layers: usize,
        max_seq: usize,
        seed: u64,
    ) -> Self {
        assert!(n_layers > 0 && vocab > 0 && max_seq > 0);
        let mut rng = Xoshiro256::seeded(seed ^ 0xDEC0_DE);
        let s = 1.0 / (d_model as f32).sqrt();
        let embed = rand_cols(vocab, d_model, s, &mut rng);
        let w_head = rand_cols(d_model, vocab, s, &mut rng);
        let b_head = rand_vec(vocab, 0.05, &mut rng);
        // Classic fixed sinusoid table: bounded, deterministic, no training.
        let mut pos = Tensor::zeros(&[max_seq, d_model]);
        for p in 0..max_seq {
            for i in 0..d_model {
                let freq = 1.0 / 10_000f32.powf((2 * (i / 2)) as f32 / d_model as f32);
                let angle = p as f32 * freq;
                *pos.at2_mut(p, i) = if i % 2 == 0 { angle.sin() } else { angle.cos() };
            }
        }
        let blocks = (0..n_layers)
            .map(|l| TransformerBlock::new(d_model, heads, d_ff, seed.wrapping_add(l as u64 * 977)))
            .collect();
        Self { d_model, vocab, max_seq, blocks, embed, pos, w_head, b_head }
    }

    /// Embedding of one token at one position: token row + positional row.
    pub fn embed_token(&self, tok: usize, p: usize) -> Vec<f32> {
        assert!(tok < self.vocab, "token {tok} outside vocab {}", self.vocab);
        assert!(p < self.max_seq, "position {p} outside max_seq {}", self.max_seq);
        (0..self.d_model).map(|i| self.embed.at2(tok, i) + self.pos.at2(p, i)).collect()
    }

    /// Embed a whole token sequence into `[seq][d_model]`.
    pub fn embed_seq(&self, tokens: &[usize]) -> Tensor {
        let mut data = Vec::with_capacity(tokens.len() * self.d_model);
        for (p, &t) in tokens.iter().enumerate() {
            data.extend(self.embed_token(t, p));
        }
        Tensor::from_vec(&[tokens.len(), self.d_model], data)
    }

    /// Float golden: causal forward over a full prefix, returning the LM
    /// logits `[seq][vocab]` (row `i` = next-token logits after token `i`).
    pub fn forward_causal(&self, tokens: &[usize]) -> Tensor {
        let mut x = self.embed_seq(tokens);
        for block in &self.blocks {
            x = block.forward_causal(&x);
        }
        let mut logits = matmul(&x, &self.w_head);
        add_bias_rows(&mut logits, &self.b_head);
        logits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_determinism() {
        let block = TransformerBlock::new(16, 4, 32, 7);
        assert_eq!(block.d_head(), 4);
        let mut rng = Xoshiro256::seeded(3);
        let x = Tensor::from_vec(&[5, 16], (0..80).map(|_| rng.next_f32() - 0.5).collect());
        let y = block.forward(&x);
        assert_eq!(y.shape, vec![5, 16]);
        assert!(y.data.iter().all(|v| v.is_finite()));
        // Same weights, same input ⇒ same output (pure function).
        assert_eq!(block.forward(&x).data, y.data);
        // Post-norm output rows are normalized: mean ≈ β mean per row.
        let row0: &[f32] = &y.data[0..16];
        let mean = row0.iter().sum::<f32>() / 16.0;
        assert!(mean.abs() < 1.0, "post-LN row mean {mean} implausible");
    }

    #[test]
    #[should_panic]
    fn heads_must_divide_d_model() {
        let _ = TransformerBlock::new(10, 3, 8, 1);
    }

    /// Causality: appending a token must not change any earlier row of the
    /// causal forward — the invariant the KV-cache engine exploits.
    #[test]
    fn causal_forward_is_prefix_stable() {
        let model = DecoderModel::new(16, 4, 24, 11, 2, 8, 42);
        let toks = [3usize, 7, 1, 9, 0];
        let full = model.forward_causal(&toks);
        assert_eq!(full.shape, vec![5, 11]);
        for p in 1..toks.len() {
            let prefix = model.forward_causal(&toks[..p]);
            for r in 0..p {
                for c in 0..11 {
                    let (a, b) = (prefix.at2(r, c), full.at2(r, c));
                    assert!(
                        (a - b).abs() < 1e-5,
                        "row {r} col {c} drifted: {a} vs {b} (prefix {p})"
                    );
                }
            }
        }
    }

    /// On a single-token sequence the causal mask is a no-op, so causal and
    /// full forward agree exactly.
    #[test]
    fn causal_equals_full_on_length_one() {
        let block = TransformerBlock::new(8, 2, 12, 5);
        let mut rng = Xoshiro256::seeded(9);
        let x = Tensor::from_vec(&[1, 8], (0..8).map(|_| rng.next_f32() - 0.5).collect());
        let full = block.forward(&x);
        let causal = block.forward_causal(&x);
        for (a, b) in full.data.iter().zip(&causal.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    /// The per-head output-projection *sum* equals the textbook
    /// concat-then-project form.
    #[test]
    fn head_sum_equals_concat_projection() {
        let block = TransformerBlock::new(8, 2, 8, 11);
        let mut rng = Xoshiro256::seeded(5);
        // Two per-head context matrices [3][4].
        let c0 = rand_cols(3, 4, 1.0, &mut rng);
        let c1 = rand_cols(3, 4, 1.0, &mut rng);
        // Sum form.
        let mut sum = matmul(&c0, &block.wo[0]);
        let s1 = matmul(&c1, &block.wo[1]);
        for (a, b) in sum.data.iter_mut().zip(&s1.data) {
            *a += b;
        }
        // Concat form: [3][8] × [8][8] with W_O stacked row-wise.
        let mut cat = Tensor::zeros(&[3, 8]);
        let mut wo = Tensor::zeros(&[8, 8]);
        for r in 0..3 {
            for c in 0..4 {
                *cat.at2_mut(r, c) = c0.at2(r, c);
                *cat.at2_mut(r, c + 4) = c1.at2(r, c);
            }
        }
        for r in 0..4 {
            for c in 0..8 {
                *wo.at2_mut(r, c) = block.wo[0].at2(r, c);
                *wo.at2_mut(r + 4, c) = block.wo[1].at2(r, c);
            }
        }
        let want = matmul(&cat, &wo);
        for (a, b) in sum.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
