//! A single transformer encoder block (pre-quantization float reference) —
//! the dynamic-weight workload of DESIGN.md §10.
//!
//! Multi-head attention is stored **per head**: `wq/wk/wv[i]` are
//! `[d_model][d_head]` column-major weight matrices (`w_cols` layout, one
//! column per output) and `wo[i]` is `[d_head][d_model]`. The output
//! projection of the concatenated heads is expressed as a sum instead of a
//! concat — `concat(h_0…h_{H−1})·W_O = Σ_i h_i·W_O[i·d_head‥]` — because
//! the graph IR has no concat node, and the sum form maps each head's
//! output projection onto its own weight-stationary macro tile grid.
//! [`TransformerBlock::forward`] is the float golden
//! `Graph::from_transformer_block` is checked against.

use crate::nn::ops::{layer_norm, softmax_last_dim};
use crate::nn::tensor::Tensor;
use crate::util::rng::{Rng, Xoshiro256};

/// LayerNorm epsilon shared by the float reference and the graph builder.
pub const LN_EPS: f32 = 1e-5;

/// Weights of one encoder block: H-head self-attention + 2-layer FFN, each
/// sublayer followed by a residual add and LayerNorm (post-norm).
pub struct TransformerBlock {
    pub d_model: usize,
    pub heads: usize,
    pub d_ff: usize,
    /// Per-head projections, `w_cols` layout `[d_model][d_head]`.
    pub wq: Vec<Tensor>,
    pub wk: Vec<Tensor>,
    pub wv: Vec<Tensor>,
    /// Per-head output projection rows, `[d_head][d_model]`.
    pub wo: Vec<Tensor>,
    pub bq: Vec<Vec<f32>>,
    pub bk: Vec<Vec<f32>>,
    pub bv: Vec<Vec<f32>>,
    /// Output-projection bias (applied once, not per head).
    pub b_o: Vec<f32>,
    pub ln1_gamma: Vec<f32>,
    pub ln1_beta: Vec<f32>,
    /// FFN expand, `[d_model][d_ff]`.
    pub w_ff1: Tensor,
    pub b_ff1: Vec<f32>,
    /// FFN contract, `[d_ff][d_model]`.
    pub w_ff2: Tensor,
    pub b_ff2: Vec<f32>,
    pub ln2_gamma: Vec<f32>,
    pub ln2_beta: Vec<f32>,
}

fn rand_cols(rows: usize, cols: usize, scale: f32, rng: &mut Xoshiro256) -> Tensor {
    Tensor::from_vec(
        &[rows, cols],
        (0..rows * cols).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect(),
    )
}

fn rand_vec(n: usize, scale: f32, rng: &mut Xoshiro256) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
}

/// `[rows_a][inner] × [inner][cols_b] → [rows_a][cols_b]` float matmul.
fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    assert_eq!(a.shape[1], b.shape[0], "matmul inner dims");
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[1]);
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for kk in 0..k {
            let av = a.at2(i, kk);
            for j in 0..n {
                *out.at2_mut(i, j) += av * b.at2(kk, j);
            }
        }
    }
    out
}

/// `a · bᵀ` for row-major `a [m][k]`, `b [n][k]` → `[m][n]` (Q·Kᵀ).
fn matmul_t(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape[1], b.shape[1], "matmul_t inner dims");
    let (m, k, n) = (a.shape[0], a.shape[1], b.shape[0]);
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += a.at2(i, kk) * b.at2(j, kk);
            }
            *out.at2_mut(i, j) = acc;
        }
    }
    out
}

fn add_bias_rows(t: &mut Tensor, bias: &[f32]) {
    let cols = t.shape[1];
    for row in t.data.chunks_mut(cols) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

impl TransformerBlock {
    /// Random small-scale init (weights ~ ±1/√fan_in, LN at γ=1, β=0 with a
    /// small perturbation) — a synthetic but representative block.
    pub fn new(d_model: usize, heads: usize, d_ff: usize, seed: u64) -> Self {
        assert!(heads > 0 && d_model % heads == 0, "d_model must divide into heads");
        let dh = d_model / heads;
        let mut rng = Xoshiro256::seeded(seed ^ 0x7A11_5EED);
        let sp = 1.0 / (d_model as f32).sqrt();
        let so = 1.0 / (dh as f32).sqrt();
        let per_head = |rows: usize, cols: usize, s: f32, rng: &mut Xoshiro256| -> Vec<Tensor> {
            (0..heads).map(|_| rand_cols(rows, cols, s, rng)).collect()
        };
        Self {
            d_model,
            heads,
            d_ff,
            wq: per_head(d_model, dh, sp, &mut rng),
            wk: per_head(d_model, dh, sp, &mut rng),
            wv: per_head(d_model, dh, sp, &mut rng),
            wo: per_head(dh, d_model, so, &mut rng),
            bq: (0..heads).map(|_| rand_vec(dh, 0.05, &mut rng)).collect(),
            bk: (0..heads).map(|_| rand_vec(dh, 0.05, &mut rng)).collect(),
            bv: (0..heads).map(|_| rand_vec(dh, 0.05, &mut rng)).collect(),
            b_o: rand_vec(d_model, 0.05, &mut rng),
            ln1_gamma: (0..d_model).map(|_| 1.0 + (rng.next_f32() - 0.5) * 0.1).collect(),
            ln1_beta: rand_vec(d_model, 0.05, &mut rng),
            w_ff1: rand_cols(d_model, d_ff, sp, &mut rng),
            b_ff1: rand_vec(d_ff, 0.05, &mut rng),
            w_ff2: rand_cols(d_ff, d_model, 1.0 / (d_ff as f32).sqrt(), &mut rng),
            b_ff2: rand_vec(d_model, 0.05, &mut rng),
            ln2_gamma: (0..d_model).map(|_| 1.0 + (rng.next_f32() - 0.5) * 0.1).collect(),
            ln2_beta: rand_vec(d_model, 0.05, &mut rng),
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.heads
    }

    /// Float reference forward: `x [seq][d_model] → [seq][d_model]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.rank(), 2);
        assert_eq!(x.shape[1], self.d_model, "input width vs d_model");
        let dh = self.d_head();
        let mut attn = Tensor::zeros(&[x.shape[0], self.d_model]);
        for i in 0..self.heads {
            let mut q = matmul(x, &self.wq[i]);
            add_bias_rows(&mut q, &self.bq[i]);
            let mut k = matmul(x, &self.wk[i]);
            add_bias_rows(&mut k, &self.bk[i]);
            let mut v = matmul(x, &self.wv[i]);
            add_bias_rows(&mut v, &self.bv[i]);
            let scores = matmul_t(&q, &k).map(|s| s / (dh as f32).sqrt());
            let probs = softmax_last_dim(&scores);
            let ctx = matmul(&probs, &v);
            let head_out = matmul(&ctx, &self.wo[i]);
            for (a, h) in attn.data.iter_mut().zip(&head_out.data) {
                *a += h;
            }
        }
        add_bias_rows(&mut attn, &self.b_o);
        for (a, xv) in attn.data.iter_mut().zip(&x.data) {
            *a += xv;
        }
        let h1 = layer_norm(&attn, &self.ln1_gamma, &self.ln1_beta, LN_EPS);

        let mut f = matmul(&h1, &self.w_ff1);
        add_bias_rows(&mut f, &self.b_ff1);
        let f = f.map(|v| v.max(0.0));
        let mut f2 = matmul(&f, &self.w_ff2);
        add_bias_rows(&mut f2, &self.b_ff2);
        for (o, h) in f2.data.iter_mut().zip(&h1.data) {
            *o += h;
        }
        layer_norm(&f2, &self.ln2_gamma, &self.ln2_beta, LN_EPS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_determinism() {
        let block = TransformerBlock::new(16, 4, 32, 7);
        assert_eq!(block.d_head(), 4);
        let mut rng = Xoshiro256::seeded(3);
        let x = Tensor::from_vec(&[5, 16], (0..80).map(|_| rng.next_f32() - 0.5).collect());
        let y = block.forward(&x);
        assert_eq!(y.shape, vec![5, 16]);
        assert!(y.data.iter().all(|v| v.is_finite()));
        // Same weights, same input ⇒ same output (pure function).
        assert_eq!(block.forward(&x).data, y.data);
        // Post-norm output rows are normalized: mean ≈ β mean per row.
        let row0: &[f32] = &y.data[0..16];
        let mean = row0.iter().sum::<f32>() / 16.0;
        assert!(mean.abs() < 1.0, "post-LN row mean {mean} implausible");
    }

    #[test]
    #[should_panic]
    fn heads_must_divide_d_model() {
        let _ = TransformerBlock::new(10, 3, 8, 1);
    }

    /// The per-head output-projection *sum* equals the textbook
    /// concat-then-project form.
    #[test]
    fn head_sum_equals_concat_projection() {
        let block = TransformerBlock::new(8, 2, 8, 11);
        let mut rng = Xoshiro256::seeded(5);
        // Two per-head context matrices [3][4].
        let c0 = rand_cols(3, 4, 1.0, &mut rng);
        let c1 = rand_cols(3, 4, 1.0, &mut rng);
        // Sum form.
        let mut sum = matmul(&c0, &block.wo[0]);
        let s1 = matmul(&c1, &block.wo[1]);
        for (a, b) in sum.data.iter_mut().zip(&s1.data) {
            *a += b;
        }
        // Concat form: [3][8] × [8][8] with W_O stacked row-wise.
        let mut cat = Tensor::zeros(&[3, 8]);
        let mut wo = Tensor::zeros(&[8, 8]);
        for r in 0..3 {
            for c in 0..4 {
                *cat.at2_mut(r, c) = c0.at2(r, c);
                *cat.at2_mut(r, c + 4) = c1.at2(r, c);
            }
        }
        for r in 0..4 {
            for c in 0..8 {
                *wo.at2_mut(r, c) = block.wo[0].at2(r, c);
                *wo.at2_mut(r + 4, c) = block.wo[1].at2(r, c);
            }
        }
        let want = matmul(&cat, &wo);
        for (a, b) in sum.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
