//! Post-training quantization for the CIM macro's number formats:
//! * weights → signed sign-magnitude `±(2^(b−1)−1)` (±7 at 4-b),
//! * activations (post-ReLU) → unsigned `0..2^b−1` (0..15 at 4-b),
//! both with symmetric per-tensor power-free scales (max-abs calibration).

use crate::nn::tensor::Tensor;

/// Per-tensor quantization parameters: `real ≈ q · scale`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    /// Quantized integer range (inclusive).
    pub q_min: i64,
    pub q_max: i64,
}

impl QuantParams {
    /// Symmetric signed params for weights with `bits` total (sign-magnitude:
    /// the CIM array stores |w| ≤ 2^(bits−1)−1).
    pub fn signed(max_abs: f32, bits: u32) -> Self {
        let q_max = (1i64 << (bits - 1)) - 1;
        let scale = if max_abs > 0.0 { max_abs / q_max as f32 } else { 1.0 };
        Self { scale, q_min: -q_max, q_max }
    }

    /// Unsigned params for post-ReLU activations.
    pub fn unsigned(max: f32, bits: u32) -> Self {
        let q_max = (1i64 << bits) - 1;
        let scale = if max > 0.0 { max / q_max as f32 } else { 1.0 };
        Self { scale, q_min: 0, q_max }
    }

    /// Symmetric params for *signed* activations on the unsigned macro
    /// interface: values in `±max_abs` quantize to `−2^(b−1) .. 2^(b−1)−1`
    /// (−8..7 at 4-b). The layer executors shift these codes by the zero
    /// point `zp = −q_min` into the macro's unsigned range and restore
    /// `zp·Σw` digitally — the transformer path's activation format
    /// (DESIGN.md §10).
    pub fn signed_acts(max_abs: f32, bits: u32) -> Self {
        let q_max = (1i64 << (bits - 1)) - 1;
        let scale = if max_abs > 0.0 { max_abs / q_max as f32 } else { 1.0 };
        Self { scale, q_min: -(q_max + 1), q_max }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> i64 {
        let q = (x / self.scale).round() as i64;
        q.clamp(self.q_min, self.q_max)
    }

    /// The zero point that shifts these params' codes into the macro's
    /// unsigned window: 0 for unsigned params, `−q_min` (8 at 4-b) for
    /// [`QuantParams::signed_acts`]. THE single definition — the layer
    /// executors (`CimLinear::quantize_acts`, the compiled plan's row
    /// quantizer) and the `zp·Σw` digital restore all derive from here, so
    /// the format cannot drift between them (DESIGN.md §10).
    #[inline]
    pub fn zero_point(&self) -> i64 {
        (-self.q_min).max(0)
    }

    /// Quantize a vector into *macro codes*: [`QuantParams::quantize`] per
    /// element plus the [`QuantParams::zero_point`] shift.
    pub fn quantize_codes(&self, xs: &[f32]) -> Vec<i64> {
        let zp = self.zero_point();
        let mut q = self.quantize_vec(xs);
        if zp != 0 {
            for c in q.iter_mut() {
                *c += zp;
            }
        }
        q
    }

    #[inline]
    pub fn dequantize(&self, q: i64) -> f32 {
        q as f32 * self.scale
    }

    pub fn quantize_vec(&self, xs: &[f32]) -> Vec<i64> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }
}

/// Quantize a weight tensor (max-abs calibration).
pub fn quantize_weights(w: &Tensor, bits: u32) -> (Vec<i64>, QuantParams) {
    let p = QuantParams::signed(w.max_abs(), bits);
    (p.quantize_vec(&w.data), p)
}

/// Quantize a non-negative activation vector with a fixed calibration max
/// (clipping above it, as a deployed pipeline would).
pub fn quantize_acts(xs: &[f32], cal_max: f32, bits: u32) -> (Vec<i64>, QuantParams) {
    let p = QuantParams::unsigned(cal_max, bits);
    (p.quantize_vec(xs), p)
}

/// Mean-squared quantization error of a roundtrip (diagnostics/tests).
pub fn roundtrip_mse(xs: &[f32], p: &QuantParams) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter()
        .map(|&x| {
            let e = (x - p.dequantize(p.quantize(x))) as f64;
            e * e
        })
        .sum::<f64>()
        / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signed_range_is_sign_magnitude() {
        let p = QuantParams::signed(7.0, 4);
        assert_eq!(p.q_max, 7);
        assert_eq!(p.q_min, -7); // NOT −8: sign-magnitude array storage
        assert_eq!(p.quantize(7.0), 7);
        assert_eq!(p.quantize(-9.0), -7); // clamped
        assert_eq!(p.quantize(0.4), 0);
    }

    #[test]
    fn unsigned_range() {
        let p = QuantParams::unsigned(1.5, 4);
        assert_eq!(p.q_max, 15);
        assert_eq!(p.quantize(1.5), 15);
        assert_eq!(p.quantize(-0.3), 0);
        assert_eq!(p.quantize(0.75), 8);
    }

    #[test]
    fn roundtrip_error_within_half_step() {
        let p = QuantParams::signed(1.0, 4);
        for i in -20..=20 {
            let x = i as f32 * 0.05;
            let rt = p.dequantize(p.quantize(x));
            assert!((x - rt).abs() <= p.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn weight_quantization_uses_max_abs() {
        let w = Tensor::from_vec(&[2, 2], vec![0.1, -0.7, 0.35, 0.0]);
        let (q, p) = quantize_weights(&w, 4);
        assert_eq!(q[1], -7); // the max-abs element pins the scale
        assert_eq!(q[2], (0.35 / p.scale).round() as i64);
        assert!(roundtrip_mse(&w.data, &p) < (p.scale as f64 / 2.0).powi(2));
    }

    #[test]
    fn signed_acts_cover_negative_range() {
        let p = QuantParams::signed_acts(1.4, 4);
        assert_eq!((p.q_min, p.q_max), (-8, 7));
        assert_eq!(p.quantize(1.4), 7);
        assert_eq!(p.quantize(-1.4), -7);
        assert_eq!(p.quantize(-9.0), -8); // clamped at the asymmetric edge
        assert_eq!(p.quantize(0.0), 0);
        // Shifted by the zero point 8, every code lands in the macro's
        // unsigned 0..15 window.
        for i in -30..=30 {
            let q = p.quantize(i as f32 * 0.1) + 8;
            assert!((0..=15).contains(&q), "code {q}");
        }
    }

    #[test]
    fn zero_tensor_does_not_divide_by_zero() {
        let p = QuantParams::signed(0.0, 4);
        assert_eq!(p.quantize(0.0), 0);
        let p = QuantParams::unsigned(0.0, 4);
        assert_eq!(p.quantize(0.0), 0);
    }
}
