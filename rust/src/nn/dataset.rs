//! Deterministic synthetic datasets (the environment has no downloadable
//! corpora): 10-class "oriented blob" images for the end-to-end MLP
//! deployment example, and random CIFAR-shaped tensors for the ResNet-20
//! mapping experiments.

use crate::nn::tensor::Tensor;
use crate::util::rng::{Rng, Xoshiro256};

/// One labelled grayscale image.
#[derive(Clone, Debug)]
pub struct Sample {
    pub image: Tensor, // [1][H][W], values in [0,1]
    pub label: usize,
}

/// 10-class oriented-blob dataset: class k places an anisotropic gaussian
/// blob at angle kπ/10 around the image center, plus pixel noise. Linearly
/// non-trivial but learnable to high accuracy by a small MLP — a stand-in
/// for an MNIST-scale edge workload.
pub struct BlobDataset {
    pub side: usize,
    pub noise: f64,
    rng: Xoshiro256,
}

impl BlobDataset {
    pub fn new(side: usize, noise: f64, seed: u64) -> Self {
        Self { side, noise, rng: Xoshiro256::seeded(seed) }
    }

    pub fn sample(&mut self) -> Sample {
        let label = self.rng.next_below(10) as usize;
        let img = self.render(label);
        Sample { image: img, label }
    }

    pub fn batch(&mut self, n: usize) -> Vec<Sample> {
        (0..n).map(|_| self.sample()).collect()
    }

    fn render(&mut self, label: usize) -> Tensor {
        let s = self.side;
        let mut t = Tensor::zeros(&[1, s, s]);
        let angle = label as f64 * std::f64::consts::PI / 10.0;
        let (ca, sa) = (angle.cos(), angle.sin());
        // Blob center jitters a little; elongation along the class angle.
        let cx = s as f64 / 2.0 + self.rng.normal(0.0, 0.6);
        let cy = s as f64 / 2.0 + self.rng.normal(0.0, 0.6);
        let (sig_par, sig_perp) = (s as f64 / 3.2, s as f64 / 10.0);
        for y in 0..s {
            for x in 0..s {
                let dx = x as f64 - cx;
                let dy = y as f64 - cy;
                let par = dx * ca + dy * sa;
                let perp = -dx * sa + dy * ca;
                let v = (-(par * par) / (2.0 * sig_par * sig_par)
                    - (perp * perp) / (2.0 * sig_perp * sig_perp))
                    .exp();
                let noisy = v + self.rng.normal(0.0, self.noise);
                *t.at3_mut(0, y, x) = noisy.clamp(0.0, 1.0) as f32;
            }
        }
        t
    }
}

/// Random CIFAR-shaped input ([3][32][32], values [0,1]) for mapping
/// experiments that need realistic tensor shapes but not semantics.
pub fn random_image(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seeded(seed);
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.next_f32()).collect())
}

/// ReLU-like activation tensor: zeros with probability `p0`, otherwise
/// exponentially distributed small positive values (the distribution Fig. 4
/// derives the MAC-folding win from).
pub fn relu_like_acts(n: usize, p0: f64, mean: f64, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|_| {
            if rng.next_bool(p0) {
                0.0
            } else {
                (-mean * (1.0 - rng.next_f64()).ln()) as f32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_is_deterministic() {
        let mut a = BlobDataset::new(16, 0.05, 7);
        let mut b = BlobDataset::new(16, 0.05, 7);
        let sa = a.sample();
        let sb = b.sample();
        assert_eq!(sa.label, sb.label);
        assert_eq!(sa.image.data, sb.image.data);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of two different classes should differ substantially.
        let mut d = BlobDataset::new(16, 0.02, 3);
        let mut mean = vec![Tensor::zeros(&[1, 16, 16]); 10];
        let mut counts = [0usize; 10];
        for _ in 0..400 {
            let s = d.sample();
            counts[s.label] += 1;
            for (m, &v) in mean[s.label].data.iter_mut().zip(&s.image.data) {
                *m += v;
            }
        }
        for k in 0..10 {
            assert!(counts[k] > 10, "class {k} undersampled");
            for m in mean[k].data.iter_mut() {
                *m /= counts[k] as f32;
            }
        }
        let dist: f32 = mean[0]
            .data
            .iter()
            .zip(&mean[5].data)
            .map(|(a, b)| (a - b).powi(2))
            .sum();
        assert!(dist > 0.5, "classes 0/5 too similar: {dist}");
    }

    #[test]
    fn pixel_range_and_shape() {
        let mut d = BlobDataset::new(12, 0.1, 1);
        let s = d.sample();
        assert_eq!(s.image.shape, vec![1, 12, 12]);
        assert!(s.image.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(s.label < 10);
    }

    #[test]
    fn relu_like_sparsity() {
        let xs = relu_like_acts(20_000, 0.5, 0.3, 9);
        let zeros = xs.iter().filter(|&&x| x == 0.0).count() as f64 / xs.len() as f64;
        assert!((zeros - 0.5).abs() < 0.02, "{zeros}");
        let nz_mean: f64 = xs.iter().filter(|&&x| x > 0.0).map(|&x| x as f64).sum::<f64>()
            / xs.iter().filter(|&&x| x > 0.0).count() as f64;
        assert!((nz_mean - 0.3).abs() < 0.02, "{nz_mean}");
    }
}
