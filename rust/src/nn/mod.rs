//! Neural-network substrate: tensors, float reference ops, quantization to
//! the macro's 4-b formats, the workloads (MLP, ResNet-20, a transformer
//! encoder block), a trainer, and synthetic datasets. The CIM mapping lives
//! in `crate::mapping`.

pub mod dataset;
pub mod im2col;
pub mod mlp;
pub mod ops;
pub mod quant;
pub mod resnet;
pub mod tensor;
pub mod transformer;

pub use quant::QuantParams;
pub use tensor::Tensor;
