//! A small MLP (the end-to-end edge workload) with manual backprop training
//! — trained in float, post-training-quantized to the macro's 4-b formats,
//! then deployed on the simulated CIM macro by `mapping::executor`.

use crate::nn::ops::softmax;
use crate::nn::tensor::{matvec, Tensor};
use crate::util::rng::{Rng, Xoshiro256};

/// One fully-connected layer, weights [out][in].
#[derive(Clone, Debug)]
pub struct Linear {
    pub w: Tensor,
    pub b: Vec<f32>,
}

impl Linear {
    pub fn new_random(inp: usize, out: usize, rng: &mut Xoshiro256) -> Self {
        // He initialization.
        let std = (2.0 / inp as f64).sqrt();
        let data = (0..inp * out)
            .map(|_| (rng.normal(0.0, std)) as f32)
            .collect();
        Self { w: Tensor::from_vec(&[out, inp], data), b: vec![0.0; out] }
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        matvec(&self.w, x, Some(&self.b))
    }
}

/// MLP with ReLU between layers and raw logits at the output.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
}

impl Mlp {
    pub fn new(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2);
        let mut rng = Xoshiro256::seeded(seed);
        let layers = dims
            .windows(2)
            .map(|w| Linear::new_random(w[0], w[1], &mut rng))
            .collect();
        Self { layers }
    }

    /// Forward pass returning every layer's post-activation (index 0 = input).
    pub fn forward_trace(&self, x: &[f32]) -> Vec<Vec<f32>> {
        let mut acts = vec![x.to_vec()];
        for (i, l) in self.layers.iter().enumerate() {
            let mut z = l.forward(acts.last().unwrap());
            if i + 1 < self.layers.len() {
                for v in z.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            acts.push(z);
        }
        acts
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        self.forward_trace(x).pop().unwrap()
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let l = self.logits(x);
        l.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }

    /// One SGD step on a single example; returns the cross-entropy loss.
    /// (Plain backprop: dL/dz_out = softmax − onehot; ReLU gates gradients.)
    pub fn train_step(&mut self, x: &[f32], label: usize, lr: f32) -> f32 {
        let acts = self.forward_trace(x);
        let logits = acts.last().unwrap();
        let probs = softmax(logits);
        let loss = -probs[label].max(1e-12).ln();

        // delta for the output layer.
        let mut delta: Vec<f32> = probs
            .iter()
            .enumerate()
            .map(|(i, &p)| p - if i == label { 1.0 } else { 0.0 })
            .collect();

        for li in (0..self.layers.len()).rev() {
            let input = &acts[li];
            // Grad wrt weights: delta ⊗ input; wrt input: Wᵀ·delta.
            let (out, inp) = (self.layers[li].w.shape[0], self.layers[li].w.shape[1]);
            let mut dx = vec![0f32; inp];
            {
                let l = &mut self.layers[li];
                for o in 0..out {
                    let d = delta[o];
                    l.b[o] -= lr * d;
                    let row = &mut l.w.data[o * inp..(o + 1) * inp];
                    for (j, wj) in row.iter_mut().enumerate() {
                        dx[j] += *wj * d;
                        *wj -= lr * d * input[j];
                    }
                }
            }
            if li > 0 {
                // Gate through the ReLU of the previous layer's output.
                for (j, g) in dx.iter_mut().enumerate() {
                    if acts[li][j] <= 0.0 {
                        *g = 0.0;
                    }
                }
                delta = dx;
            }
        }
        loss
    }
}

/// Train on a labelled set for `epochs`, returning the final train accuracy.
pub fn train(
    mlp: &mut Mlp,
    data: &[(Vec<f32>, usize)],
    epochs: usize,
    lr: f32,
    seed: u64,
) -> f64 {
    let mut order: Vec<usize> = (0..data.len()).collect();
    let mut rng = Xoshiro256::seeded(seed);
    for _ in 0..epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let (x, y) = &data[i];
            mlp.train_step(x, *y, lr);
        }
    }
    accuracy(mlp, data)
}

pub fn accuracy(mlp: &Mlp, data: &[(Vec<f32>, usize)]) -> f64 {
    let correct = data.iter().filter(|(x, y)| mlp.predict(x) == *y).count();
    correct as f64 / data.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dataset::BlobDataset;

    fn blob_data(n: usize, seed: u64) -> Vec<(Vec<f32>, usize)> {
        let mut d = BlobDataset::new(12, 0.05, seed);
        d.batch(n)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect()
    }

    #[test]
    fn shapes_and_determinism() {
        let m = Mlp::new(&[8, 6, 4], 3);
        let m2 = Mlp::new(&[8, 6, 4], 3);
        assert_eq!(m.layers.len(), 2);
        let x = vec![0.5; 8];
        assert_eq!(m.logits(&x), m2.logits(&x));
        assert_eq!(m.logits(&x).len(), 4);
    }

    #[test]
    fn gradient_direction_reduces_loss() {
        let mut m = Mlp::new(&[4, 8, 3], 1);
        let x = vec![0.3, -0.2, 0.9, 0.1];
        let mut last = f32::INFINITY;
        for _ in 0..50 {
            let loss = m.train_step(&x, 2, 0.1);
            last = loss;
        }
        assert!(last < 0.05, "loss should collapse on one example: {last}");
        assert_eq!(m.predict(&x), 2);
    }

    #[test]
    fn learns_blob_dataset() {
        // End-to-end sanity: 144→32→10 MLP reaches ≥90% train accuracy on
        // 300 oriented-blob images within a few epochs.
        let data = blob_data(300, 11);
        let mut m = Mlp::new(&[144, 32, 10], 5);
        let acc = train(&mut m, &data, 8, 0.05, 99);
        assert!(acc >= 0.9, "train accuracy {acc}");
        // Held-out accuracy is also well above chance.
        let test = blob_data(200, 1234);
        let t = accuracy(&m, &test);
        assert!(t >= 0.75, "test accuracy {t}");
    }
}
