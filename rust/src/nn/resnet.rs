//! ResNet-20 (CIFAR-style) — the network the paper maps onto the CIM cores
//! for its comparison study ("mapping a 4-bit ResNet-20 to the CIM cores",
//! Fig. 1 footnote). Weights are synthetic (He-initialized, BN pre-folded):
//! the mapping/energy/accuracy-degradation experiments need realistic
//! shapes and value distributions, not a trained checkpoint.

use crate::nn::ops::{conv2d, global_avg_pool, relu};
use crate::nn::tensor::{matvec, Tensor};
use crate::util::rng::{Rng, Xoshiro256};

/// One conv layer's folded parameters.
#[derive(Clone, Debug)]
pub struct ConvLayer {
    pub w: Tensor, // [oc][ic][kh][kw]
    pub b: Vec<f32>,
    pub stride: usize,
    pub pad: usize,
}

impl ConvLayer {
    fn random(oc: usize, ic: usize, k: usize, stride: usize, rng: &mut Xoshiro256) -> Self {
        let fan_in = ic * k * k;
        let std = (2.0 / fan_in as f64).sqrt();
        let data = (0..oc * ic * k * k)
            .map(|_| rng.normal(0.0, std) as f32)
            .collect();
        Self {
            w: Tensor::from_vec(&[oc, ic, k, k], data),
            b: vec![0.0; oc],
            stride,
            pad: k / 2,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        conv2d(x, &self.w, Some(&self.b), self.stride, self.pad)
    }
}

/// Basic residual block: conv-relu-conv + identity (1×1 projection when the
/// shape changes), then ReLU.
#[derive(Clone, Debug)]
pub struct BasicBlock {
    pub conv1: ConvLayer,
    pub conv2: ConvLayer,
    pub proj: Option<ConvLayer>,
}

impl BasicBlock {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let h = relu(self.conv1.forward(x));
        let h = self.conv2.forward(&h);
        let idn = match &self.proj {
            Some(p) => p.forward(x),
            None => x.clone(),
        };
        assert_eq!(h.shape, idn.shape);
        let mut out = h;
        for (o, i) in out.data.iter_mut().zip(&idn.data) {
            *o += i;
        }
        relu(out)
    }
}

/// ResNet-20: stem conv + 3 stages × 3 blocks (16/32/64 channels) + GAP + FC.
#[derive(Clone, Debug)]
pub struct ResNet20 {
    pub stem: ConvLayer,
    pub stages: Vec<Vec<BasicBlock>>,
    pub fc_w: Tensor, // [10][64]
    pub fc_b: Vec<f32>,
}

impl ResNet20 {
    pub fn new(seed: u64) -> Self {
        let mut rng = Xoshiro256::seeded(seed);
        let stem = ConvLayer::random(16, 3, 3, 1, &mut rng);
        let mut stages = Vec::new();
        let chans = [16usize, 32, 64];
        let mut in_c = 16;
        for (si, &c) in chans.iter().enumerate() {
            let mut blocks = Vec::new();
            for bi in 0..3 {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let conv1 = ConvLayer::random(c, in_c, 3, stride, &mut rng);
                let conv2 = ConvLayer::random(c, c, 3, 1, &mut rng);
                let proj = if stride != 1 || in_c != c {
                    Some(ConvLayer::random(c, in_c, 1, stride, &mut rng))
                } else {
                    None
                };
                blocks.push(BasicBlock { conv1, conv2, proj });
                in_c = c;
            }
            stages.push(blocks);
        }
        let fc_w = Tensor::from_vec(
            &[10, 64],
            (0..640).map(|_| rng.normal(0.0, 0.1) as f32).collect(),
        );
        Self { stem, stages, fc_w, fc_b: vec![0.0; 10] }
    }

    pub fn forward(&self, x: &Tensor) -> Vec<f32> {
        let mut h = relu(self.stem.forward(x));
        for stage in &self.stages {
            for block in stage {
                h = block.forward(&h);
            }
        }
        let pooled = global_avg_pool(&h);
        matvec(&self.fc_w, &pooled, Some(&self.fc_b))
    }

    /// All conv layers in execution order with descriptive names — the
    /// mapping experiments iterate these.
    pub fn conv_layers(&self) -> Vec<(String, &ConvLayer)> {
        let mut v = vec![("stem".to_string(), &self.stem)];
        for (si, st) in self.stages.iter().enumerate() {
            for (bi, b) in st.iter().enumerate() {
                v.push((format!("s{si}b{bi}.conv1"), &b.conv1));
                v.push((format!("s{si}b{bi}.conv2"), &b.conv2));
                if let Some(p) = &b.proj {
                    v.push((format!("s{si}b{bi}.proj"), p));
                }
            }
        }
        v
    }

    /// Total MAC count for a 32×32×3 input (mapping/energy accounting):
    /// symbolic forward of the spatial dims, block structure respected
    /// (projection convs read the block *input*, not its output).
    pub fn total_macs(&self) -> usize {
        let conv_macs = |l: &ConvLayer, h: usize, w: usize| -> (usize, usize, usize) {
            let (oc, ic, kh, kw) = (l.w.shape[0], l.w.shape[1], l.w.shape[2], l.w.shape[3]);
            let oh = (h + 2 * l.pad - kh) / l.stride + 1;
            let ow = (w + 2 * l.pad - kw) / l.stride + 1;
            (oc * ic * kh * kw * oh * ow, oh, ow)
        };
        let (mut macs, mut h, mut w) = conv_macs(&self.stem, 32, 32);
        for stage in &self.stages {
            for block in stage {
                let (in_h, in_w) = (h, w);
                let (m1, h1, w1) = conv_macs(&block.conv1, in_h, in_w);
                let (m2, h2, w2) = conv_macs(&block.conv2, h1, w1);
                macs += m1 + m2;
                if let Some(p) = &block.proj {
                    let (mp, _, _) = conv_macs(p, in_h, in_w);
                    macs += mp;
                }
                h = h2;
                w = w2;
            }
        }
        macs + 64 * 10
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dataset::random_image;

    #[test]
    fn twenty_layers() {
        let net = ResNet20::new(1);
        // 3 stages × 3 blocks × 2 convs + stem = 19 convs + FC = ResNet-20;
        // plus 2 projection convs (stage transitions).
        let convs = net.conv_layers();
        let main: usize = convs.iter().filter(|(n, _)| !n.contains("proj")).count();
        assert_eq!(main, 19);
        let proj: usize = convs.iter().filter(|(n, _)| n.contains("proj")).count();
        assert_eq!(proj, 2);
    }

    #[test]
    fn forward_shape_and_determinism() {
        let net = ResNet20::new(7);
        let x = random_image(&[3, 32, 32], 3);
        let y1 = net.forward(&x);
        let y2 = net.forward(&x);
        assert_eq!(y1.len(), 10);
        assert_eq!(y1, y2);
        assert!(y1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stage_dims_shrink() {
        let net = ResNet20::new(2);
        let x = random_image(&[3, 32, 32], 4);
        let h = relu(net.stem.forward(&x));
        assert_eq!(h.shape, vec![16, 32, 32]);
        let h1 = net.stages[0][0].forward(&h);
        assert_eq!(h1.shape, vec![16, 32, 32]);
        let mut h2 = h1;
        for b in &net.stages[0][1..] {
            h2 = b.forward(&h2);
        }
        let h3 = net.stages[1][0].forward(&h2);
        assert_eq!(h3.shape, vec![32, 16, 16]);
    }

    #[test]
    fn mac_count_magnitude() {
        // ResNet-20 on CIFAR is ~40.5M MACs; the estimate must be within a
        // few percent.
        let net = ResNet20::new(1);
        let m = net.total_macs();
        assert!(m > 35_000_000 && m < 48_000_000, "{m}");
    }
}
