//! im2col: lower a convolution to a matrix product so conv layers map onto
//! the macro's column-engine dot products exactly like FC layers do.

use crate::nn::tensor::Tensor;

/// Expand `x` ([C][H][W]) into a patch matrix [positions][C·kh·kw] such that
/// `conv(x, w) == patches · w_flat` (with `w_flat` [C·kh·kw][out_c]).
pub fn im2col(x: &Tensor, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor {
    assert_eq!(x.rank(), 3);
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let k = c * kh * kw;
    let mut out = Tensor::zeros(&[oh * ow, k]);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            for ci in 0..c {
                for ky in 0..kh {
                    let y_in = (oy * stride + ky) as isize - pad as isize;
                    for kx in 0..kw {
                        let x_in = (ox * stride + kx) as isize - pad as isize;
                        let v = if y_in < 0 || y_in >= h as isize || x_in < 0 || x_in >= w as isize
                        {
                            0.0
                        } else {
                            x.at3(ci, y_in as usize, x_in as usize)
                        };
                        let col = (ci * kh + ky) * kw + kx;
                        *out.at2_mut(row, col) = v;
                    }
                }
            }
        }
    }
    out
}

/// Flatten conv weights [out_c][in_c][kh][kw] into [in_c·kh·kw][out_c]
/// (column per output channel — one CIM engine per output channel).
pub fn weights_to_cols(w: &Tensor) -> Tensor {
    assert_eq!(w.rank(), 4);
    let (oc, ic, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let k = ic * kh * kw;
    let mut out = Tensor::zeros(&[k, oc]);
    for o in 0..oc {
        for r in 0..k {
            *out.at2_mut(r, o) = w.data[o * k + r];
        }
    }
    out
}

/// Output spatial dims of a convolution.
pub fn conv_out_dims(h: usize, w: usize, kh: usize, kw: usize, stride: usize, pad: usize) -> (usize, usize) {
    ((h + 2 * pad - kh) / stride + 1, (w + 2 * pad - kw) / stride + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops::conv2d;
    use crate::util::rng::{Rng, Xoshiro256};

    fn random_tensor(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seeded(seed);
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect())
    }

    /// im2col · w_cols must equal direct convolution for random tensors.
    #[test]
    fn im2col_matmul_equals_conv() {
        for (stride, pad) in [(1, 0), (1, 1), (2, 1), (2, 0)] {
            let x = random_tensor(&[3, 8, 8], 42);
            let w = random_tensor(&[5, 3, 3, 3], 43);
            let direct = conv2d(&x, &w, None, stride, pad);
            let patches = im2col(&x, 3, 3, stride, pad);
            let wc = weights_to_cols(&w);
            let (oh, ow) = conv_out_dims(8, 8, 3, 3, stride, pad);
            assert_eq!(patches.shape, vec![oh * ow, 27]);
            for row in 0..oh * ow {
                for o in 0..5 {
                    let mut acc = 0f32;
                    for k in 0..27 {
                        acc += patches.at2(row, k) * wc.at2(k, o);
                    }
                    let (oy, ox) = (row / ow, row % ow);
                    let want = direct.at3(o, oy, ox);
                    assert!(
                        (acc - want).abs() < 1e-4,
                        "stride {stride} pad {pad} row {row} oc {o}: {acc} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn padding_produces_zero_borders() {
        let x = random_tensor(&[1, 2, 2], 1);
        let p = im2col(&x, 3, 3, 1, 1);
        // First patch (output 0,0): top-left 3×3 window has 5 padded zeros.
        let zeros = (0..9).filter(|&k| p.at2(0, k) == 0.0).count();
        assert_eq!(zeros, 5);
    }

    #[test]
    fn weight_flattening_layout() {
        let w = Tensor::from_vec(&[2, 1, 1, 2], vec![1., 2., 3., 4.]);
        let wc = weights_to_cols(&w);
        assert_eq!(wc.shape, vec![2, 2]);
        // column 0 = out-channel 0 weights [1,2]; column 1 = [3,4]
        assert_eq!(wc.at2(0, 0), 1.0);
        assert_eq!(wc.at2(1, 0), 2.0);
        assert_eq!(wc.at2(0, 1), 3.0);
        assert_eq!(wc.at2(1, 1), 4.0);
    }
}
