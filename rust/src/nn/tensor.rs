//! Minimal dense tensor (f32, row-major) — the substrate for the NN layers
//! mapped onto the CIM macro. Deliberately small: shapes up to 4-D, exact
//! indexing, no broadcasting magic.

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reshape without copying (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &mut self.data[i * c + j]
    }

    /// CHW indexing for rank-3 tensors.
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.rank(), 3);
        self.data[(c * self.shape[1] + h) * self.shape[2] + w]
    }

    #[inline]
    pub fn at3_mut(&mut self, c: usize, h: usize, w: usize) -> &mut f32 {
        debug_assert_eq!(self.rank(), 3);
        let (s1, s2) = (self.shape[1], self.shape[2]);
        &mut self.data[(c * s1 + h) * s2 + w]
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for x in self.data.iter_mut() {
            *x = f(*x);
        }
        self
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// y = W·x + b for row-major W [out][in].
pub fn matvec(w: &Tensor, x: &[f32], b: Option<&[f32]>) -> Vec<f32> {
    assert_eq!(w.rank(), 2);
    let (out, inp) = (w.shape[0], w.shape[1]);
    assert_eq!(x.len(), inp);
    let mut y = vec![0f32; out];
    for o in 0..out {
        let row = &w.data[o * inp..(o + 1) * inp];
        let mut acc = 0f32;
        for (wi, xi) in row.iter().zip(x) {
            acc += wi * xi;
        }
        y[o] = acc + b.map(|b| b[o]).unwrap_or(0.0);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_indexing() {
        let mut t = Tensor::zeros(&[2, 3]);
        *t.at2_mut(1, 2) = 5.0;
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.data[5], 5.0);
        let t3 = Tensor::from_vec(&[2, 2, 2], (0..8).map(|i| i as f32).collect());
        assert_eq!(t3.at3(1, 0, 1), 5.0);
        assert_eq!(t3.at3(0, 1, 0), 2.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(&[3, 2]);
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
    }

    #[test]
    #[should_panic]
    fn bad_reshape_panics() {
        let _ = Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn matvec_matches_manual() {
        let w = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let y = matvec(&w, &[1., 1., 1.], Some(&[10., 20.]));
        assert_eq!(y, vec![16.0, 35.0]);
        let y = matvec(&w, &[1., 0., -1.], None);
        assert_eq!(y, vec![-2.0, -2.0]);
    }

    #[test]
    fn map_and_maxabs_and_argmax() {
        let t = Tensor::from_vec(&[4], vec![-3.0, 1.0, 2.0, -0.5]).map(|x| x * 2.0);
        assert_eq!(t.max_abs(), 6.0);
        assert_eq!(t.argmax(), 2);
    }
}
