//! Float NN primitives (reference path): conv2d, linear, ReLU, pooling,
//! batch-norm folding, softmax. The CIM path replaces the inner dot products
//! of `conv2d`/`linear` via `mapping::executor`; this module is the golden.

use crate::nn::tensor::Tensor;

/// ReLU in place.
pub fn relu(t: Tensor) -> Tensor {
    t.map(|x| x.max(0.0))
}

/// Softmax over a 1-D tensor (numerically stable).
pub fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = xs.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Softmax over the last dimension of a rank-1 or rank-2 tensor (row-wise
/// for rank-2 — attention probabilities). The single definition behind the
/// graph IR's `Softmax` node, shared by `Graph::eval_float` and the
/// compiled-plan executor so the two cannot drift.
pub fn softmax_last_dim(t: &Tensor) -> Tensor {
    match t.rank() {
        1 => Tensor::from_vec(&t.shape, softmax(&t.data)),
        2 => {
            let (rows, cols) = (t.shape[0], t.shape[1]);
            let mut out = Vec::with_capacity(rows * cols);
            for r in 0..rows {
                out.extend(softmax(&t.data[r * cols..(r + 1) * cols]));
            }
            Tensor::from_vec(&t.shape, out)
        }
        r => panic!("softmax expects rank 1 or 2, got rank {r}"),
    }
}

/// Causal (lower-triangular) softmax over a square rank-2 score matrix:
/// row `i` is softmaxed over columns `0..=i` and zero elsewhere — the
/// autoregressive attention mask. The single definition behind the graph
/// IR's `CausalSoftmax` node, shared by `Graph::eval_float` and the
/// compiled-plan executor so the two cannot drift (DESIGN.md §13).
pub fn causal_softmax(t: &Tensor) -> Tensor {
    assert_eq!(t.rank(), 2, "causal_softmax expects a rank-2 score matrix");
    let (rows, cols) = (t.shape[0], t.shape[1]);
    assert_eq!(rows, cols, "causal_softmax expects square scores [s][s]");
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        let probs = softmax(&t.data[r * cols..r * cols + r + 1]);
        out[r * cols..r * cols + r + 1].copy_from_slice(&probs);
    }
    Tensor::from_vec(&t.shape, out)
}

/// Layer normalization over the last dimension of a rank-1 or rank-2
/// tensor: `y = (x − μ)/√(σ² + eps)·γ + β` per row, population variance.
/// The single definition behind the graph IR's `LayerNorm` node.
pub fn layer_norm(t: &Tensor, gamma: &[f32], beta: &[f32], eps: f32) -> Tensor {
    let cols = *t.shape.last().expect("layer_norm on a non-empty shape");
    assert!(t.rank() == 1 || t.rank() == 2, "layer_norm expects rank 1 or 2");
    assert_eq!(gamma.len(), cols, "gamma length vs last dim");
    assert_eq!(beta.len(), cols, "beta length vs last dim");
    let mut out = Vec::with_capacity(t.data.len());
    for row in t.data.chunks(cols) {
        let mean = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (i, &x) in row.iter().enumerate() {
            out.push((x - mean) * inv * gamma[i] + beta[i]);
        }
    }
    Tensor::from_vec(&t.shape, out)
}

/// 2-D convolution, CHW layout, stride `s`, symmetric zero padding `p`.
/// `w` is [out_c][in_c][kh][kw]; `x` is [in_c][h][w].
pub fn conv2d(x: &Tensor, w: &Tensor, b: Option<&[f32]>, stride: usize, pad: usize) -> Tensor {
    assert_eq!(x.rank(), 3);
    assert_eq!(w.rank(), 4);
    let (ic, ih, iw) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oc, wic, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert_eq!(ic, wic, "channel mismatch");
    let oh = (ih + 2 * pad - kh) / stride + 1;
    let ow = (iw + 2 * pad - kw) / stride + 1;
    let mut y = Tensor::zeros(&[oc, oh, ow]);
    for o in 0..oc {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = b.map(|b| b[o]).unwrap_or(0.0);
                for c in 0..ic {
                    for ky in 0..kh {
                        let y_in = (oy * stride + ky) as isize - pad as isize;
                        if y_in < 0 || y_in >= ih as isize {
                            continue;
                        }
                        for kx in 0..kw {
                            let x_in = (ox * stride + kx) as isize - pad as isize;
                            if x_in < 0 || x_in >= iw as isize {
                                continue;
                            }
                            acc += x.at3(c, y_in as usize, x_in as usize)
                                * w.data[((o * ic + c) * kh + ky) * kw + kx];
                        }
                    }
                }
                *y.at3_mut(o, oy, ox) = acc;
            }
        }
    }
    y
}

/// Global average pooling: [C][H][W] → [C].
pub fn global_avg_pool(x: &Tensor) -> Vec<f32> {
    assert_eq!(x.rank(), 3);
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let mut out = vec![0f32; c];
    for ci in 0..c {
        let mut s = 0f32;
        for y in 0..h {
            for xw in 0..w {
                s += x.at3(ci, y, xw);
            }
        }
        out[ci] = s / (h * w) as f32;
    }
    out
}

/// 2×2 average pooling with stride 2 (used when downsampling synthetic nets).
pub fn avg_pool2(x: &Tensor) -> Tensor {
    assert_eq!(x.rank(), 3);
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut y = Tensor::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let s = x.at3(ci, 2 * oy, 2 * ox)
                    + x.at3(ci, 2 * oy, 2 * ox + 1)
                    + x.at3(ci, 2 * oy + 1, 2 * ox)
                    + x.at3(ci, 2 * oy + 1, 2 * ox + 1);
                *y.at3_mut(ci, oy, ox) = s / 4.0;
            }
        }
    }
    y
}

/// Batch-norm parameters folded into the preceding conv's weights/bias:
/// ŵ = w·γ/σ, b̂ = (b − μ)·γ/σ + β. Standard deployment transformation —
/// the CIM macro only ever sees folded weights.
pub fn fold_batchnorm(
    w: &mut Tensor,
    b: &mut [f32],
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
) {
    assert_eq!(w.rank(), 4);
    let oc = w.shape[0];
    let per = w.data.len() / oc;
    for o in 0..oc {
        let g = gamma[o] / (var[o] + eps).sqrt();
        for k in 0..per {
            w.data[o * per + k] *= g;
        }
        b[o] = (b[o] - mean[o]) * g + beta[o];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1×1 kernel with weight 1 reproduces the input.
        let x = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let y = conv2d(&x, &w, None, 1, 0);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_known_3x3() {
        // All-ones 3×3 kernel, pad 1: center output = sum of 3×3 patch.
        let x = Tensor::from_vec(&[1, 3, 3], vec![1.; 9]);
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.; 9]);
        let y = conv2d(&x, &w, None, 1, 1);
        assert_eq!(y.shape, vec![1, 3, 3]);
        assert_eq!(y.at3(0, 1, 1), 9.0); // full patch
        assert_eq!(y.at3(0, 0, 0), 4.0); // corner sees 2×2
        assert_eq!(y.at3(0, 0, 1), 6.0); // edge sees 2×3
    }

    #[test]
    fn conv_stride_two() {
        let x = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![2.0]);
        let y = conv2d(&x, &w, None, 2, 0);
        assert_eq!(y.shape, vec![1, 2, 2]);
        assert_eq!(y.data, vec![0., 4., 16., 20.]);
    }

    #[test]
    fn conv_bias_and_channels() {
        let x = Tensor::from_vec(&[2, 2, 2], vec![1., 2., 3., 4., 10., 20., 30., 40.]);
        // 1×1 kernel summing both channels.
        let w = Tensor::from_vec(&[1, 2, 1, 1], vec![1.0, 0.1]);
        let y = conv2d(&x, &w, Some(&[100.0]), 1, 0);
        assert_eq!(y.data, vec![102.0, 104.0, 106.0, 108.0]);
    }

    #[test]
    fn relu_and_softmax() {
        let t = relu(Tensor::from_vec(&[4], vec![-1., 2., -3., 4.]));
        assert_eq!(t.data, vec![0., 2., 0., 4.]);
        let p = softmax(&[1.0, 1.0, 1.0, 1.0]);
        for &x in &p {
            assert!((x - 0.25).abs() < 1e-6);
        }
        let p = softmax(&[1000.0, 0.0]); // stability
        assert!((p[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_last_dim_is_rowwise() {
        let t = Tensor::from_vec(&[2, 2], vec![0.0, 0.0, 1000.0, 0.0]);
        let p = softmax_last_dim(&t);
        assert!((p.at2(0, 0) - 0.5).abs() < 1e-6);
        assert!((p.at2(1, 0) - 1.0).abs() < 1e-6);
        for r in 0..2 {
            let s: f32 = (0..2).map(|c| p.at2(r, c)).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_softmax_masks_the_upper_triangle() {
        let t = Tensor::from_vec(&[3, 3], vec![1.0, 9.0, 9.0, 0.5, 0.5, 9.0, 1.0, 2.0, 3.0]);
        let p = causal_softmax(&t);
        // Row 0: only the diagonal entry is live.
        assert_eq!(p.at2(0, 0), 1.0);
        assert_eq!(p.at2(0, 1), 0.0);
        assert_eq!(p.at2(0, 2), 0.0);
        // Row 1: softmax over the first two (equal) scores, col 2 masked.
        assert!((p.at2(1, 0) - 0.5).abs() < 1e-6);
        assert!((p.at2(1, 1) - 0.5).abs() < 1e-6);
        assert_eq!(p.at2(1, 2), 0.0);
        // Row 2 matches the unmasked softmax of the full row.
        let full = softmax(&[1.0, 2.0, 3.0]);
        for c in 0..3 {
            assert!((p.at2(2, c) - full[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn layer_norm_normalizes_each_row() {
        let t = Tensor::from_vec(&[2, 4], vec![1., 2., 3., 4., 10., 10., 10., 10.]);
        let gamma = vec![1.0; 4];
        let beta = vec![0.5; 4];
        let y = layer_norm(&t, &gamma, &beta, 1e-5);
        // Row 0: zero mean, unit variance before the affine.
        let row0: Vec<f32> = (0..4).map(|c| y.at2(0, c) - 0.5).collect();
        assert!(row0.iter().sum::<f32>().abs() < 1e-5);
        let var: f32 = row0.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-3, "var {var}");
        // Constant row collapses to beta.
        for c in 0..4 {
            assert!((y.at2(1, c) - 0.5).abs() < 1e-3);
        }
    }

    #[test]
    fn pooling() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1., 3., 5., 7.]);
        assert_eq!(global_avg_pool(&x), vec![4.0]);
        let y = avg_pool2(&x);
        assert_eq!(y.shape, vec![1, 1, 1]);
        assert_eq!(y.data, vec![4.0]);
    }

    #[test]
    fn bn_folding_matches_explicit_bn() {
        let mut w = Tensor::from_vec(&[1, 1, 1, 1], vec![2.0]);
        let mut b = vec![1.0];
        let (gamma, beta, mean, var) = (vec![0.5], vec![0.2], vec![3.0], vec![4.0]);
        let x = Tensor::from_vec(&[1, 1, 1], vec![5.0]);
        // Explicit: conv → y=11; bn: (11−3)·0.5/2 + 0.2 = 2.2.
        fold_batchnorm(&mut w, &mut b, &gamma, &beta, &mean, &var, 0.0);
        let y = conv2d(&x, &w, Some(&b), 1, 0);
        assert!((y.data[0] - 2.2).abs() < 1e-6);
    }
}
