//! `HwSpec` — one coherent description of a candidate CIM macro.
//!
//! Everything the analytic cost model needs to price a hardware point lives
//! here: array geometry and clocking ([`MacroConfig`]), the signal-margin
//! enhancement gains ([`EnhanceConfig`]), the calibrated component energy
//! constants ([`EnergyConfig`]), the published calibration anchors the
//! energy solver targets ([`CalibAnchors`]), the reference SAR ADC used for
//! baseline comparisons ([`SarAdcRef`]), and tech-node scaling hooks
//! ([`TechScale`]). The paper's macro is exactly
//! [`HwSpec::paper_default()`]; the design-space exploration harness
//! (`crate::explore`, DESIGN.md §15) sweeps everything else.
//!
//! [`crate::config::Config`] embeds an `HwSpec` and derefs to it, so
//! `cfg.mac.rows`-style access works unchanged across the codebase while
//! hardware-only consumers (`cim::timing`, `energy`, the placer) can take
//! `&HwSpec` directly — a `&Config` coerces.

use crate::config::ConfigError;
use crate::util::tomlcfg::Doc;

/// Macro geometry + clocking. Paper values are the defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct MacroConfig {
    /// Number of analog CIM cores in the macro (paper: 4).
    pub cores: usize,
    /// Column-wise dot-product engines per core (paper: 16).
    pub engines: usize,
    /// Weight rows accumulated per engine, i.e. the analog accumulation
    /// parallelism (paper: 64).
    pub rows: usize,
    /// Activation precision in bits (paper: 4, unsigned after ReLU).
    pub act_bits: u32,
    /// Weight precision in bits incl. sign (paper: 4 = 1 sign + 3 magnitude).
    pub weight_bits: u32,
    /// Readout precision of the cell-embedded ADC (paper: 9, signed).
    pub adc_bits: u32,
    /// Clock frequency in MHz (paper: 100–200; default to the max).
    pub clock_mhz: f64,
    /// DTC LSB as a fraction of the clock period: τ0 = T_clk · tau_frac.
    pub tau_frac: f64,
}

impl Default for MacroConfig {
    fn default() -> Self {
        Self {
            cores: 4,
            engines: 16,
            rows: 64,
            act_bits: 4,
            weight_bits: 4,
            adc_bits: 9,
            clock_mhz: 200.0,
            tau_frac: 1.0 / 16.0,
        }
    }
}

impl MacroConfig {
    /// Maximum unsigned activation value (15 for 4-b).
    pub fn act_max(&self) -> i64 {
        (1i64 << self.act_bits) - 1
    }

    /// Maximum weight magnitude (7 for 4-b sign-magnitude).
    pub fn w_mag_max(&self) -> i64 {
        (1i64 << (self.weight_bits - 1)) - 1
    }

    /// One-sided MAC dynamic range in product units without folding:
    /// rows · act_max · w_mag_max (paper: 64·15·7 = 6720).
    pub fn mac_range(&self) -> i64 {
        self.rows as i64 * self.act_max() * self.w_mag_max()
    }

    /// Bit-line voltage headroom VPP_MAC expressed in u. Chosen so that the
    /// unfolded worst-case MAC exactly fits (scale 1.0): 6720 u.
    pub fn vpp_units(&self) -> f64 {
        self.mac_range() as f64
    }

    /// Differential ADC full-scale in u (RBL−RBLB spans ±VPP).
    pub fn adc_fullscale_units(&self) -> f64 {
        2.0 * self.vpp_units()
    }

    /// Number of ADC output codes (512 for 9-b).
    pub fn adc_codes(&self) -> i64 {
        1i64 << self.adc_bits
    }

    /// ADC LSB in u (fixed in voltage regardless of DTC scale — this is the
    /// boosted-clipping invariant).
    pub fn adc_lsb_units(&self) -> f64 {
        self.adc_fullscale_units() / self.adc_codes() as f64
    }

    /// Weights stored per core (bits): engines·rows·weight_bits.
    pub fn core_kb(&self) -> f64 {
        (self.engines * self.rows * self.weight_bits as usize) as f64 / 1024.0
    }

    /// Total macro capacity in Kb (paper: 16).
    pub fn macro_kb(&self) -> f64 {
        self.core_kb() * self.cores as f64
    }

    /// MACs per macro operation (all cores fire together).
    pub fn macs_per_op(&self) -> usize {
        self.cores * self.engines * self.rows
    }

    /// Ops per macro operation (1 MAC = 2 ops, the paper's convention).
    pub fn ops_per_op(&self) -> usize {
        2 * self.macs_per_op()
    }
}

/// Signal-margin enhancement techniques (Fig. 4).
#[derive(Clone, Debug, PartialEq)]
pub struct EnhanceConfig {
    /// MAC-folding: subtract `fold_offset` from every activation and compute
    /// in sign-magnitude; restore `fold_offset·ΣW` digitally.
    pub fold: bool,
    /// Boosted-clipping: 2× DTC pulse resolution with fixed ADC full scale.
    pub boost: bool,
    /// The folded constant (paper: 8 = half the activation range).
    pub fold_offset: i64,
    /// DTC gain applied when folding (paper: ×1.87; exactly 13440/7168).
    pub fold_gain: f64,
    /// Extra DTC gain applied when boosting (paper: ×2).
    pub boost_gain: f64,
}

impl Default for EnhanceConfig {
    fn default() -> Self {
        Self {
            fold: false,
            boost: false,
            fold_offset: 8,
            fold_gain: 1.875,
            boost_gain: 2.0,
        }
    }
}

impl EnhanceConfig {
    pub fn both() -> Self {
        Self { fold: true, boost: true, ..Self::default() }
    }

    pub fn fold_only() -> Self {
        Self { fold: true, ..Self::default() }
    }

    pub fn boost_only() -> Self {
        Self { boost: true, ..Self::default() }
    }

    /// Effective DTC time scale s = τ/τ0.
    pub fn dtc_scale(&self) -> f64 {
        let mut s = 1.0;
        if self.fold {
            s *= self.fold_gain;
        }
        if self.boost {
            s *= self.boost_gain;
        }
        s
    }

    pub fn label(&self) -> &'static str {
        match (self.fold, self.boost) {
            (false, false) => "baseline",
            (true, false) => "fold",
            (false, true) => "boost",
            (true, true) => "fold+boost",
        }
    }
}

/// Component energy model constants, all in femtojoules, calibrated so that
/// dense 4b:4b random workloads measure 95.6 TOPS/W and 90 %-sparse ones
/// 137.5 TOPS/W, apportioned per the Fig. 7 power breakdown (see
/// `energy::calibrate`).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyConfig {
    /// Control logic energy per clock cycle per core, fJ.
    pub e_ctrl_cycle: f64,
    /// Sense-amp energy per comparison, fJ.
    pub e_sa_cmp: f64,
    /// DTC energy per generated pulse (fixed part), fJ.
    pub e_dtc_pulse: f64,
    /// DTC + driver energy per τ0-second of pulse width, fJ.
    pub e_dtc_tau: f64,
    /// Pulse-path energy per SL toggle, fJ.
    pub e_path_toggle: f64,
    /// Bit-line (MOM cap) discharge + precharge-restore energy per u, fJ.
    pub e_array_unit: f64,
    /// Fixed per-op array overhead (ADC readout discharge + precharge), fJ.
    pub e_array_fixed: f64,
    /// SRAM write energy per weight bit, fJ — the dynamic-weight reload
    /// cost (DESIGN.md §10). Not calibrated against the paper (it reports
    /// no write energy); a representative 28 nm SRAM write figure.
    pub e_w_write: f64,
    /// Area of the 16 Kb reference macro in mm² (paper: consistent 0.121
    /// from both ends of the 790–1136 TOPS/W/mm² range). Other capacities
    /// scale it linearly via [`HwSpec::macro_area_mm2`].
    pub area_mm2: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        // Frozen output of `cimsim calibrate` (see energy::calibrate tests).
        Self {
            e_ctrl_cycle: 25.5018,
            e_sa_cmp: 2.0,
            e_dtc_pulse: 7.9163,
            e_dtc_tau: 0.423183,
            e_path_toggle: 10.00279,
            e_array_unit: 0.0116119,
            e_array_fixed: 12269.08,
            e_w_write: 1.2,
            area_mm2: 0.121,
        }
    }
}

/// Published calibration anchors the energy solver (`energy::calibrate`)
/// targets: the paper's two measured efficiency points and the Fig. 7
/// power breakdown. These used to live as loose `pub const`s in
/// `energy::calibrate`; scoping them here lets a swept candidate carry its
/// own anchors (e.g. a ReRAM-flavored backend with a different breakdown).
#[derive(Clone, Debug, PartialEq)]
pub struct CalibAnchors {
    /// Measured dense (0 % sparsity) efficiency anchor, TOPS/W (paper: 95.6).
    pub dense_tops_w: f64,
    /// Measured sparse efficiency anchor, TOPS/W (paper: 137.5).
    pub sparse_tops_w: f64,
    /// Input-activation sparsity of the sparse anchor (paper: 90 %).
    pub sparse_fraction: f64,
    /// Fig. 7 average power breakdown at the dense anchor, fractions of the
    /// total in the order `[array, pulse path, DTC, SA + control]`.
    pub power_split: [f64; 4],
    /// Sense-amp comparison energy pinned during solving, fJ (the SA share
    /// is folded into the control term of the split).
    pub e_sa_fj: f64,
    /// Fraction of the DTC power attributed to fixed per-pulse cost (the
    /// remainder scales with pulse width).
    pub dtc_pulse_split: f64,
}

impl Default for CalibAnchors {
    fn default() -> Self {
        Self {
            dense_tops_w: 95.6,
            sparse_tops_w: 137.5,
            sparse_fraction: 0.9,
            power_split: [0.6475, 0.1793, 0.1419, 0.0313],
            e_sa_fj: 2.0,
            dtc_pulse_split: 0.5,
        }
    }
}

/// Reference 40 nm SAR ADC used by the published-baseline comparisons
/// (`energy::baselines`): a conventional readout to normalize competing
/// macros against. Previously the loose `SAR_*` consts.
#[derive(Clone, Debug, PartialEq)]
pub struct SarAdcRef {
    /// Unit DAC capacitance, fF.
    pub cu_ff: f64,
    /// Supply voltage, V.
    pub vdd: f64,
    /// Comparator + logic energy per decision, fJ.
    pub e_cmp_fj: f64,
}

impl Default for SarAdcRef {
    fn default() -> Self {
        Self { cu_ff: 1.8, vdd: 0.9, e_cmp_fj: 5.0 }
    }
}

/// Tech-node scaling hooks for swept candidates. The calibrated energy
/// constants describe the paper's 28 nm silicon; a sweep point at another
/// node multiplies them wholesale rather than re-deriving each one. Scales
/// are folded into the constants once by [`HwSpec::normalized`]; the paper
/// default's unit scales make normalization the identity.
#[derive(Clone, Debug, PartialEq)]
pub struct TechScale {
    /// Nominal process node, nm (informational; joins sweep reports).
    pub node_nm: f64,
    /// Multiplier applied to every energy constant (CV² scaling).
    pub energy_scale: f64,
    /// Multiplier applied to the reference macro area.
    pub area_scale: f64,
}

impl Default for TechScale {
    fn default() -> Self {
        Self { node_nm: 28.0, energy_scale: 1.0, area_scale: 1.0 }
    }
}

/// One complete candidate hardware point: everything the analytic cost
/// model consumes, and nothing the simulator-only layers (noise, runtime
/// knobs) need. See the module docs for the field groups.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HwSpec {
    pub mac: MacroConfig,
    pub enhance: EnhanceConfig,
    pub energy: EnergyConfig,
    pub anchors: CalibAnchors,
    pub sar: SarAdcRef,
    pub tech: TechScale,
}

impl HwSpec {
    /// The measured silicon of the source paper: 16 Kb, 4 cores × 16
    /// engines × 64 rows, 9-b cell-embedded ADC, 200 MHz, with the frozen
    /// calibrated energy constants. Identical to `HwSpec::default()`; the
    /// named constructor exists so call sites state intent and tests can
    /// assert the equivalence.
    pub fn paper_default() -> Self {
        Self::default()
    }

    /// Total silicon area of one macro instance in mm²: `energy.area_mm2`
    /// prices the paper's 16 Kb reference, capacity scales it linearly, and
    /// `tech.area_scale` rescales for other nodes.
    pub fn macro_area_mm2(&self) -> f64 {
        self.energy.area_mm2 * (self.mac.macro_kb() / 16.0) * self.tech.area_scale
    }

    /// Fold the tech-node hooks into the constants they scale, returning a
    /// spec with unit scales. Sweep candidates normalize once at load, so
    /// the cost model itself never special-cases tech scaling; the paper
    /// default is a fixed point of this map.
    pub fn normalized(&self) -> Self {
        let mut s = self.clone();
        let es = s.tech.energy_scale;
        s.energy.e_ctrl_cycle *= es;
        s.energy.e_sa_cmp *= es;
        s.energy.e_dtc_pulse *= es;
        s.energy.e_dtc_tau *= es;
        s.energy.e_path_toggle *= es;
        s.energy.e_array_unit *= es;
        s.energy.e_array_fixed *= es;
        s.energy.e_w_write *= es;
        s.energy.area_mm2 *= s.tech.area_scale;
        s.tech.energy_scale = 1.0;
        s.tech.area_scale = 1.0;
        s
    }

    /// Overlay recognized hardware keys from a parsed TOML document. The
    /// caller (`Config::overlay` or the explore sweep loader) has already
    /// rejected unknown keys against [`HW_KEYS`].
    pub fn overlay(&mut self, doc: &Doc) -> Result<(), ConfigError> {
        macro_rules! ov {
            ($field:expr, usize, $key:expr) => {
                if let Some(v) = doc.usize($key) { $field = v; }
            };
            ($field:expr, u32, $key:expr) => {
                if let Some(v) = doc.i64($key) { $field = v as u32; }
            };
            ($field:expr, i64, $key:expr) => {
                if let Some(v) = doc.i64($key) { $field = v; }
            };
            ($field:expr, f64, $key:expr) => {
                if let Some(v) = doc.f64($key) { $field = v; }
            };
            ($field:expr, bool, $key:expr) => {
                if let Some(v) = doc.bool($key) { $field = v; }
            };
        }
        ov!(self.mac.cores, usize, "macro.cores");
        ov!(self.mac.engines, usize, "macro.engines");
        ov!(self.mac.rows, usize, "macro.rows");
        ov!(self.mac.act_bits, u32, "macro.act_bits");
        ov!(self.mac.weight_bits, u32, "macro.weight_bits");
        ov!(self.mac.adc_bits, u32, "macro.adc_bits");
        ov!(self.mac.clock_mhz, f64, "macro.clock_mhz");
        ov!(self.mac.tau_frac, f64, "macro.tau_frac");
        ov!(self.enhance.fold, bool, "enhance.fold");
        ov!(self.enhance.boost, bool, "enhance.boost");
        ov!(self.enhance.fold_offset, i64, "enhance.fold_offset");
        ov!(self.enhance.fold_gain, f64, "enhance.fold_gain");
        ov!(self.enhance.boost_gain, f64, "enhance.boost_gain");
        ov!(self.energy.e_ctrl_cycle, f64, "energy.e_ctrl_cycle");
        ov!(self.energy.e_sa_cmp, f64, "energy.e_sa_cmp");
        ov!(self.energy.e_dtc_pulse, f64, "energy.e_dtc_pulse");
        ov!(self.energy.e_dtc_tau, f64, "energy.e_dtc_tau");
        ov!(self.energy.e_path_toggle, f64, "energy.e_path_toggle");
        ov!(self.energy.e_array_unit, f64, "energy.e_array_unit");
        ov!(self.energy.e_array_fixed, f64, "energy.e_array_fixed");
        ov!(self.energy.e_w_write, f64, "energy.e_w_write");
        ov!(self.energy.area_mm2, f64, "energy.area_mm2");
        ov!(self.anchors.dense_tops_w, f64, "anchors.dense_tops_w");
        ov!(self.anchors.sparse_tops_w, f64, "anchors.sparse_tops_w");
        ov!(self.anchors.sparse_fraction, f64, "anchors.sparse_fraction");
        ov!(self.anchors.power_split[0], f64, "anchors.split_array");
        ov!(self.anchors.power_split[1], f64, "anchors.split_path");
        ov!(self.anchors.power_split[2], f64, "anchors.split_dtc");
        ov!(self.anchors.power_split[3], f64, "anchors.split_sactrl");
        ov!(self.anchors.e_sa_fj, f64, "anchors.e_sa_fj");
        ov!(self.anchors.dtc_pulse_split, f64, "anchors.dtc_pulse_split");
        ov!(self.sar.cu_ff, f64, "sar.cu_ff");
        ov!(self.sar.vdd, f64, "sar.vdd");
        ov!(self.sar.e_cmp_fj, f64, "sar.e_cmp_fj");
        ov!(self.tech.node_nm, f64, "tech.node_nm");
        ov!(self.tech.energy_scale, f64, "tech.energy_scale");
        ov!(self.tech.area_scale, f64, "tech.area_scale");
        Ok(())
    }

    /// Serialize every hardware key as TOML that [`HwSpec::overlay`]
    /// re-reads exactly (floats print in Rust's shortest round-trip form).
    /// This is the explore harness's provenance format: each Pareto point
    /// records the spec that produced it.
    pub fn to_toml(&self) -> String {
        let m = &self.mac;
        let e = &self.enhance;
        let en = &self.energy;
        let a = &self.anchors;
        let s = &self.sar;
        let t = &self.tech;
        format!(
            "[macro]\n\
             cores = {}\nengines = {}\nrows = {}\n\
             act_bits = {}\nweight_bits = {}\nadc_bits = {}\n\
             clock_mhz = {}\ntau_frac = {}\n\
             \n[enhance]\n\
             fold = {}\nboost = {}\nfold_offset = {}\n\
             fold_gain = {}\nboost_gain = {}\n\
             \n[energy]\n\
             e_ctrl_cycle = {}\ne_sa_cmp = {}\ne_dtc_pulse = {}\n\
             e_dtc_tau = {}\ne_path_toggle = {}\ne_array_unit = {}\n\
             e_array_fixed = {}\ne_w_write = {}\narea_mm2 = {}\n\
             \n[anchors]\n\
             dense_tops_w = {}\nsparse_tops_w = {}\nsparse_fraction = {}\n\
             split_array = {}\nsplit_path = {}\nsplit_dtc = {}\nsplit_sactrl = {}\n\
             e_sa_fj = {}\ndtc_pulse_split = {}\n\
             \n[sar]\n\
             cu_ff = {}\nvdd = {}\ne_cmp_fj = {}\n\
             \n[tech]\n\
             node_nm = {}\nenergy_scale = {}\narea_scale = {}\n",
            m.cores,
            m.engines,
            m.rows,
            m.act_bits,
            m.weight_bits,
            m.adc_bits,
            m.clock_mhz,
            m.tau_frac,
            e.fold,
            e.boost,
            e.fold_offset,
            e.fold_gain,
            e.boost_gain,
            en.e_ctrl_cycle,
            en.e_sa_cmp,
            en.e_dtc_pulse,
            en.e_dtc_tau,
            en.e_path_toggle,
            en.e_array_unit,
            en.e_array_fixed,
            en.e_w_write,
            en.area_mm2,
            a.dense_tops_w,
            a.sparse_tops_w,
            a.sparse_fraction,
            a.power_split[0],
            a.power_split[1],
            a.power_split[2],
            a.power_split[3],
            a.e_sa_fj,
            a.dtc_pulse_split,
            s.cu_ff,
            s.vdd,
            s.e_cmp_fj,
            t.node_nm,
            t.energy_scale,
            t.area_scale,
        )
    }

    /// Validate the hardware description (geometry, precision ranges,
    /// gains, anchors, scales). `Config::validate` adds the noise checks.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let inv = |m: String| Err(ConfigError::Invalid(m));
        if self.mac.cores == 0 || self.mac.engines == 0 || self.mac.rows == 0 {
            return inv("macro geometry must be non-zero".into());
        }
        if !(1..=8).contains(&self.mac.act_bits) {
            return inv(format!("act_bits {} out of range 1..=8", self.mac.act_bits));
        }
        if !(2..=8).contains(&self.mac.weight_bits) {
            return inv(format!("weight_bits {} out of range 2..=8", self.mac.weight_bits));
        }
        if !(4..=12).contains(&self.mac.adc_bits) {
            return inv(format!("adc_bits {} out of range 4..=12", self.mac.adc_bits));
        }
        if self.mac.clock_mhz <= 0.0 || self.mac.tau_frac <= 0.0 {
            return inv("clock_mhz and tau_frac must be positive".into());
        }
        if self.enhance.fold_offset < 0 || self.enhance.fold_offset > self.mac.act_max() {
            return inv(format!(
                "fold_offset {} outside activation range",
                self.enhance.fold_offset
            ));
        }
        if self.enhance.fold_gain <= 0.0 || self.enhance.boost_gain <= 0.0 {
            return inv("enhancement gains must be positive".into());
        }
        if self.anchors.dense_tops_w <= 0.0 || self.anchors.sparse_tops_w <= 0.0 {
            return inv("anchor efficiencies must be positive".into());
        }
        if !(0.0..1.0).contains(&self.anchors.sparse_fraction) {
            return inv(format!(
                "anchors.sparse_fraction {} out of range [0, 1)",
                self.anchors.sparse_fraction
            ));
        }
        let split_sum: f64 = self.anchors.power_split.iter().sum();
        if self.anchors.power_split.iter().any(|&f| f <= 0.0)
            || (split_sum - 1.0).abs() > 1e-6
        {
            return inv(format!(
                "anchors power split must be positive fractions summing to 1 (got sum {split_sum})"
            ));
        }
        if !(0.0..=1.0).contains(&self.anchors.dtc_pulse_split) {
            return inv("anchors.dtc_pulse_split must be in [0, 1]".into());
        }
        if self.sar.cu_ff <= 0.0 || self.sar.vdd <= 0.0 || self.sar.e_cmp_fj <= 0.0 {
            return inv("sar reference parameters must be positive".into());
        }
        if self.tech.node_nm <= 0.0
            || self.tech.energy_scale <= 0.0
            || self.tech.area_scale <= 0.0
        {
            return inv("tech node and scales must be positive".into());
        }
        Ok(())
    }
}

/// Every TOML key [`HwSpec::overlay`] consumes, grouped by section. The
/// `Config` overlay and the explore sweep loader both reject anything else
/// so typos never silently fall back to defaults.
pub const HW_KEYS: &[&str] = &[
    "macro.cores",
    "macro.engines",
    "macro.rows",
    "macro.act_bits",
    "macro.weight_bits",
    "macro.adc_bits",
    "macro.clock_mhz",
    "macro.tau_frac",
    "enhance.fold",
    "enhance.boost",
    "enhance.fold_offset",
    "enhance.fold_gain",
    "enhance.boost_gain",
    "energy.e_ctrl_cycle",
    "energy.e_sa_cmp",
    "energy.e_dtc_pulse",
    "energy.e_dtc_tau",
    "energy.e_path_toggle",
    "energy.e_array_unit",
    "energy.e_array_fixed",
    "energy.e_w_write",
    "energy.area_mm2",
    "anchors.dense_tops_w",
    "anchors.sparse_tops_w",
    "anchors.sparse_fraction",
    "anchors.split_array",
    "anchors.split_path",
    "anchors.split_dtc",
    "anchors.split_sactrl",
    "anchors.e_sa_fj",
    "anchors.dtc_pulse_split",
    "sar.cu_ff",
    "sar.vdd",
    "sar.e_cmp_fj",
    "tech.node_nm",
    "tech.energy_scale",
    "tech.area_scale",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_is_default_and_valid() {
        let hw = HwSpec::paper_default();
        assert_eq!(hw, HwSpec::default());
        hw.validate().unwrap();
        assert!((hw.macro_area_mm2() - 0.121).abs() < 1e-12);
        // The anchors carry the paper's published numbers.
        assert_eq!(hw.anchors.dense_tops_w, 95.6);
        assert_eq!(hw.anchors.sparse_tops_w, 137.5);
        assert!((hw.anchors.power_split.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalization_is_identity_at_unit_scales() {
        let hw = HwSpec::paper_default();
        assert_eq!(hw.normalized(), hw);
    }

    #[test]
    fn normalization_folds_tech_scales() {
        let mut hw = HwSpec::paper_default();
        hw.tech.energy_scale = 0.5;
        hw.tech.area_scale = 2.0;
        let n = hw.normalized();
        assert_eq!(n.tech.energy_scale, 1.0);
        assert_eq!(n.tech.area_scale, 1.0);
        assert!((n.energy.e_ctrl_cycle - hw.energy.e_ctrl_cycle * 0.5).abs() < 1e-12);
        assert!((n.energy.area_mm2 - hw.energy.area_mm2 * 2.0).abs() < 1e-12);
        // Folding then measuring equals measuring with the hooks live.
        assert!((n.macro_area_mm2() - hw.macro_area_mm2()).abs() < 1e-12);
    }

    #[test]
    fn toml_serialization_round_trips() {
        let mut hw = HwSpec::paper_default();
        hw.mac.rows = 128;
        hw.mac.adc_bits = 7;
        hw.enhance.fold = true;
        hw.energy.e_w_write = 2.625;
        hw.anchors.sparse_fraction = 0.875;
        hw.tech.node_nm = 16.0;
        let text = hw.to_toml();
        let doc = Doc::parse(&text).unwrap();
        for k in doc.keys() {
            assert!(HW_KEYS.contains(&k), "serializer emitted unknown key {k}");
        }
        let mut back = HwSpec::default();
        back.overlay(&doc).unwrap();
        assert_eq!(back, hw);
        // And the serializer emits every known key, so defaults can't hide.
        for k in HW_KEYS {
            assert!(doc.get(k).is_some(), "serializer dropped {k}");
        }
    }

    #[test]
    fn validate_rejects_bad_anchor_split() {
        let mut hw = HwSpec::paper_default();
        hw.anchors.power_split = [0.5, 0.2, 0.2, 0.2];
        assert!(hw.validate().is_err());
    }
}
