//! Typed configuration for the whole simulator.
//!
//! Defaults reproduce the paper's macro exactly (16 Kb, 4 cores × 16
//! column-engines × 64 rows × 4-b weights, 9-b cell-embedded ADC, 200 MHz).
//! Every value can be overridden from a TOML file (`--config`) and from the
//! CLI. Units convention (see DESIGN.md §3):
//!
//! * **τ0** — the baseline DTC time LSB (= T_clk · `tau_frac`). All pulse
//!   widths are expressed in τ0.
//! * **u**  — the voltage drop one discharge branch causes in one τ0
//!   (`u = I0·τ0/C`). All voltages (headroom, noise, ADC steps) are in u.
//!
//! With these normalizations, one unit of the ideal product `act·|w|`
//! discharges exactly `scale` u, where `scale` is the configured DTC gain
//! (1.0 baseline, ×1.875 with MAC-folding, ×2 with boosted-clipping).

use crate::util::tomlcfg::Doc;
use std::path::Path;

/// Macro geometry + clocking. Paper values are the defaults.
#[derive(Clone, Debug, PartialEq)]
pub struct MacroConfig {
    /// Number of analog CIM cores in the macro (paper: 4).
    pub cores: usize,
    /// Column-wise dot-product engines per core (paper: 16).
    pub engines: usize,
    /// Weight rows accumulated per engine, i.e. the analog accumulation
    /// parallelism (paper: 64).
    pub rows: usize,
    /// Activation precision in bits (paper: 4, unsigned after ReLU).
    pub act_bits: u32,
    /// Weight precision in bits incl. sign (paper: 4 = 1 sign + 3 magnitude).
    pub weight_bits: u32,
    /// Readout precision of the cell-embedded ADC (paper: 9, signed).
    pub adc_bits: u32,
    /// Clock frequency in MHz (paper: 100–200; default to the max).
    pub clock_mhz: f64,
    /// DTC LSB as a fraction of the clock period: τ0 = T_clk · tau_frac.
    pub tau_frac: f64,
}

impl Default for MacroConfig {
    fn default() -> Self {
        Self {
            cores: 4,
            engines: 16,
            rows: 64,
            act_bits: 4,
            weight_bits: 4,
            adc_bits: 9,
            clock_mhz: 200.0,
            tau_frac: 1.0 / 16.0,
        }
    }
}

impl MacroConfig {
    /// Maximum unsigned activation value (15 for 4-b).
    pub fn act_max(&self) -> i64 {
        (1i64 << self.act_bits) - 1
    }

    /// Maximum weight magnitude (7 for 4-b sign-magnitude).
    pub fn w_mag_max(&self) -> i64 {
        (1i64 << (self.weight_bits - 1)) - 1
    }

    /// One-sided MAC dynamic range in product units without folding:
    /// rows · act_max · w_mag_max (paper: 64·15·7 = 6720).
    pub fn mac_range(&self) -> i64 {
        self.rows as i64 * self.act_max() * self.w_mag_max()
    }

    /// Bit-line voltage headroom VPP_MAC expressed in u. Chosen so that the
    /// unfolded worst-case MAC exactly fits (scale 1.0): 6720 u.
    pub fn vpp_units(&self) -> f64 {
        self.mac_range() as f64
    }

    /// Differential ADC full-scale in u (RBL−RBLB spans ±VPP).
    pub fn adc_fullscale_units(&self) -> f64 {
        2.0 * self.vpp_units()
    }

    /// Number of ADC output codes (512 for 9-b).
    pub fn adc_codes(&self) -> i64 {
        1i64 << self.adc_bits
    }

    /// ADC LSB in u (fixed in voltage regardless of DTC scale — this is the
    /// boosted-clipping invariant).
    pub fn adc_lsb_units(&self) -> f64 {
        self.adc_fullscale_units() / self.adc_codes() as f64
    }

    /// Weights stored per core (bits): engines·rows·weight_bits.
    pub fn core_kb(&self) -> f64 {
        (self.engines * self.rows * self.weight_bits as usize) as f64 / 1024.0
    }

    /// Total macro capacity in Kb (paper: 16).
    pub fn macro_kb(&self) -> f64 {
        self.core_kb() * self.cores as f64
    }

    /// MACs per macro operation (all cores fire together).
    pub fn macs_per_op(&self) -> usize {
        self.cores * self.engines * self.rows
    }

    /// Ops per macro operation (1 MAC = 2 ops, the paper's convention).
    pub fn ops_per_op(&self) -> usize {
        2 * self.macs_per_op()
    }
}

/// Signal-margin enhancement techniques (Fig. 4).
#[derive(Clone, Debug, PartialEq)]
pub struct EnhanceConfig {
    /// MAC-folding: subtract `fold_offset` from every activation and compute
    /// in sign-magnitude; restore `fold_offset·ΣW` digitally.
    pub fold: bool,
    /// Boosted-clipping: 2× DTC pulse resolution with fixed ADC full scale.
    pub boost: bool,
    /// The folded constant (paper: 8 = half the activation range).
    pub fold_offset: i64,
    /// DTC gain applied when folding (paper: ×1.87; exactly 13440/7168).
    pub fold_gain: f64,
    /// Extra DTC gain applied when boosting (paper: ×2).
    pub boost_gain: f64,
}

impl Default for EnhanceConfig {
    fn default() -> Self {
        Self {
            fold: false,
            boost: false,
            fold_offset: 8,
            fold_gain: 1.875,
            boost_gain: 2.0,
        }
    }
}

impl EnhanceConfig {
    pub fn both() -> Self {
        Self { fold: true, boost: true, ..Self::default() }
    }

    pub fn fold_only() -> Self {
        Self { fold: true, ..Self::default() }
    }

    pub fn boost_only() -> Self {
        Self { boost: true, ..Self::default() }
    }

    /// Effective DTC time scale s = τ/τ0.
    pub fn dtc_scale(&self) -> f64 {
        let mut s = 1.0;
        if self.fold {
            s *= self.fold_gain;
        }
        if self.boost {
            s *= self.boost_gain;
        }
        s
    }

    pub fn label(&self) -> &'static str {
        match (self.fold, self.boost) {
            (false, false) => "baseline",
            (true, false) => "fold",
            (false, true) => "boost",
            (true, true) => "fold+boost",
        }
    }
}

/// Statistical noise model (DESIGN.md §3). Calibrated values — derived by
/// `cimsim calibrate` against the paper's two measured accuracy anchors
/// (1σ = 1.3 % baseline, 0.64 % with both enhancements on 9 000 random
/// points) and frozen here; tests assert the freeze.
#[derive(Clone, Debug, PartialEq)]
pub struct NoiseConfig {
    /// Master switch (off = ideal analog computation; quantization only).
    pub enabled: bool,
    /// Relative per-branch discharge-current mismatch σ (static,
    /// "fabrication" — long-channel M0 keeps this small).
    pub sigma_cell: f64,
    /// Absolute pulse-timing error floor per discharge event, in τ0.
    pub sigma_t_floor: f64,
    /// Extra timing error for narrow pulses, in τ0: the full per-event σ is
    /// `floor + small·(knee/w_sec)^pow` where `w_sec` is the pulse width in
    /// τ0-seconds. This term is what MAC-folding escapes (Fig. 4).
    pub sigma_t_small: f64,
    /// Reference width of the narrow-pulse penalty, in τ0: the penalty is
    /// `sigma_t_small · (t_knee/width)^t_pow`.
    pub t_knee: f64,
    /// Decay exponent of the narrow-pulse penalty.
    pub t_pow: f64,
    /// Static sense-amp input offset σ per engine, in u.
    pub sigma_sa_static: f64,
    /// Dynamic sense-amp noise σ per comparison, in u.
    pub sigma_sa_cmp: f64,
    /// Relative error σ of each binary-search readout step magnitude
    /// (dynamic; branch + pulse noise of the readout discharge).
    pub sigma_step_rel: f64,
    /// Static relative mismatch of each engine's 9 readout step magnitudes
    /// (drives the DNL/INL signature at major code transitions).
    pub sigma_step_static: f64,
    /// Relative RBL/RBLB capacitor mismatch σ per engine (static).
    pub sigma_cap: f64,
    /// Seed for the static ("fabrication") noise draw.
    pub fab_seed: u64,
}

impl Default for NoiseConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            sigma_cell: 0.02,
            sigma_t_floor: 3.40,
            sigma_t_small: 48.5,
            t_knee: 2.0,
            t_pow: 1.0,
            sigma_sa_static: 8.0,
            sigma_sa_cmp: 6.0,
            sigma_step_rel: 0.004,
            sigma_step_static: 0.002,
            sigma_cap: 0.001,
            fab_seed: 0xC1A0_5EED,
        }
    }
}

impl NoiseConfig {
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// Component energy model constants, all in femtojoules, calibrated so that
/// dense 4b:4b random workloads measure 95.6 TOPS/W and 90 %-sparse ones
/// 137.5 TOPS/W, apportioned per the Fig. 7 power breakdown (see
/// `energy::calibrate`).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyConfig {
    /// Control logic energy per clock cycle per core, fJ.
    pub e_ctrl_cycle: f64,
    /// Sense-amp energy per comparison, fJ.
    pub e_sa_cmp: f64,
    /// DTC energy per generated pulse (fixed part), fJ.
    pub e_dtc_pulse: f64,
    /// DTC + driver energy per τ0-second of pulse width, fJ.
    pub e_dtc_tau: f64,
    /// Pulse-path energy per SL toggle, fJ.
    pub e_path_toggle: f64,
    /// Bit-line (MOM cap) discharge + precharge-restore energy per u, fJ.
    pub e_array_unit: f64,
    /// Fixed per-op array overhead (ADC readout discharge + precharge), fJ.
    pub e_array_fixed: f64,
    /// SRAM write energy per weight bit, fJ — the dynamic-weight reload
    /// cost (DESIGN.md §10). Not calibrated against the paper (it reports
    /// no write energy); a representative 28 nm SRAM write figure.
    pub e_w_write: f64,
    /// Macro area in mm² (paper: consistent 0.121 from both ends of the
    /// 790–1136 TOPS/W/mm² range).
    pub area_mm2: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        // Frozen output of `cimsim calibrate` (see energy::calibrate tests).
        Self {
            e_ctrl_cycle: 25.5018,
            e_sa_cmp: 2.0,
            e_dtc_pulse: 7.9163,
            e_dtc_tau: 0.423183,
            e_path_toggle: 10.00279,
            e_array_unit: 0.0116119,
            e_array_fixed: 12269.08,
            e_w_write: 1.2,
            area_mm2: 0.121,
        }
    }
}

/// Simulation/runtime knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Root seed for dynamic noise and workload generation.
    pub seed: u64,
    /// Worker threads for Monte-Carlo sweeps (0 = auto).
    pub workers: usize,
    /// Directory holding AOT HLO artifacts.
    pub artifacts_dir: String,
    /// Directory for harness outputs (tables, CSVs).
    pub out_dir: String,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            workers: 0,
            artifacts_dir: "artifacts".into(),
            out_dir: "out".into(),
        }
    }
}

/// Top-level configuration bundle.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub mac: MacroConfig,
    pub enhance: EnhanceConfig,
    pub noise: NoiseConfig,
    pub energy: EnergyConfig,
    pub sim: SimConfig,
}

#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Parse(crate::util::tomlcfg::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "config io error: {e}"),
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// Load from a TOML file, overlaying onto defaults.
    pub fn from_toml_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(ConfigError::Io)?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        let doc = Doc::parse(text).map_err(ConfigError::Parse)?;
        let mut c = Config::default();
        c.overlay(&doc)?;
        c.validate()?;
        Ok(c)
    }

    /// Overlay recognized keys from a parsed document. Unknown keys are an
    /// error so typos never silently fall back to defaults.
    pub fn overlay(&mut self, doc: &Doc) -> Result<(), ConfigError> {
        let known = |k: &str| KNOWN_KEYS.contains(&k);
        for k in doc.keys() {
            if !known(k) {
                return Err(ConfigError::Invalid(format!("unknown config key `{k}`")));
            }
        }
        macro_rules! ov {
            ($field:expr, usize, $key:expr) => {
                if let Some(v) = doc.usize($key) { $field = v; }
            };
            ($field:expr, u32, $key:expr) => {
                if let Some(v) = doc.i64($key) { $field = v as u32; }
            };
            ($field:expr, u64, $key:expr) => {
                if let Some(v) = doc.i64($key) { $field = v as u64; }
            };
            ($field:expr, i64, $key:expr) => {
                if let Some(v) = doc.i64($key) { $field = v; }
            };
            ($field:expr, f64, $key:expr) => {
                if let Some(v) = doc.f64($key) { $field = v; }
            };
            ($field:expr, bool, $key:expr) => {
                if let Some(v) = doc.bool($key) { $field = v; }
            };
            ($field:expr, str, $key:expr) => {
                if let Some(v) = doc.str($key) { $field = v.to_string(); }
            };
        }
        ov!(self.mac.cores, usize, "macro.cores");
        ov!(self.mac.engines, usize, "macro.engines");
        ov!(self.mac.rows, usize, "macro.rows");
        ov!(self.mac.act_bits, u32, "macro.act_bits");
        ov!(self.mac.weight_bits, u32, "macro.weight_bits");
        ov!(self.mac.adc_bits, u32, "macro.adc_bits");
        ov!(self.mac.clock_mhz, f64, "macro.clock_mhz");
        ov!(self.mac.tau_frac, f64, "macro.tau_frac");
        ov!(self.enhance.fold, bool, "enhance.fold");
        ov!(self.enhance.boost, bool, "enhance.boost");
        ov!(self.enhance.fold_offset, i64, "enhance.fold_offset");
        ov!(self.enhance.fold_gain, f64, "enhance.fold_gain");
        ov!(self.enhance.boost_gain, f64, "enhance.boost_gain");
        ov!(self.noise.enabled, bool, "noise.enabled");
        ov!(self.noise.sigma_cell, f64, "noise.sigma_cell");
        ov!(self.noise.sigma_t_floor, f64, "noise.sigma_t_floor");
        ov!(self.noise.sigma_t_small, f64, "noise.sigma_t_small");
        ov!(self.noise.t_knee, f64, "noise.t_knee");
        ov!(self.noise.t_pow, f64, "noise.t_pow");
        ov!(self.noise.sigma_sa_static, f64, "noise.sigma_sa_static");
        ov!(self.noise.sigma_sa_cmp, f64, "noise.sigma_sa_cmp");
        ov!(self.noise.sigma_step_rel, f64, "noise.sigma_step_rel");
        ov!(self.noise.sigma_step_static, f64, "noise.sigma_step_static");
        ov!(self.noise.sigma_cap, f64, "noise.sigma_cap");
        ov!(self.noise.fab_seed, u64, "noise.fab_seed");
        ov!(self.energy.e_ctrl_cycle, f64, "energy.e_ctrl_cycle");
        ov!(self.energy.e_sa_cmp, f64, "energy.e_sa_cmp");
        ov!(self.energy.e_dtc_pulse, f64, "energy.e_dtc_pulse");
        ov!(self.energy.e_dtc_tau, f64, "energy.e_dtc_tau");
        ov!(self.energy.e_path_toggle, f64, "energy.e_path_toggle");
        ov!(self.energy.e_array_unit, f64, "energy.e_array_unit");
        ov!(self.energy.e_array_fixed, f64, "energy.e_array_fixed");
        ov!(self.energy.e_w_write, f64, "energy.e_w_write");
        ov!(self.energy.area_mm2, f64, "energy.area_mm2");
        ov!(self.sim.seed, u64, "sim.seed");
        ov!(self.sim.workers, usize, "sim.workers");
        ov!(self.sim.artifacts_dir, str, "sim.artifacts_dir");
        ov!(self.sim.out_dir, str, "sim.out_dir");
        Ok(())
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let inv = |m: String| Err(ConfigError::Invalid(m));
        if self.mac.cores == 0 || self.mac.engines == 0 || self.mac.rows == 0 {
            return inv("macro geometry must be non-zero".into());
        }
        if !(1..=8).contains(&self.mac.act_bits) {
            return inv(format!("act_bits {} out of range 1..=8", self.mac.act_bits));
        }
        if !(2..=8).contains(&self.mac.weight_bits) {
            return inv(format!("weight_bits {} out of range 2..=8", self.mac.weight_bits));
        }
        if !(4..=12).contains(&self.mac.adc_bits) {
            return inv(format!("adc_bits {} out of range 4..=12", self.mac.adc_bits));
        }
        if self.mac.clock_mhz <= 0.0 || self.mac.tau_frac <= 0.0 {
            return inv("clock_mhz and tau_frac must be positive".into());
        }
        if self.enhance.fold_offset < 0 || self.enhance.fold_offset > self.mac.act_max() {
            return inv(format!("fold_offset {} outside activation range", self.enhance.fold_offset));
        }
        if self.enhance.fold_gain <= 0.0 || self.enhance.boost_gain <= 0.0 {
            return inv("enhancement gains must be positive".into());
        }
        for (name, v) in [
            ("sigma_cell", self.noise.sigma_cell),
            ("sigma_t_floor", self.noise.sigma_t_floor),
            ("sigma_t_small", self.noise.sigma_t_small),
            ("sigma_sa_static", self.noise.sigma_sa_static),
            ("sigma_sa_cmp", self.noise.sigma_sa_cmp),
            ("sigma_step_rel", self.noise.sigma_step_rel),
            ("sigma_step_static", self.noise.sigma_step_static),
            ("sigma_cap", self.noise.sigma_cap),
        ] {
            if v < 0.0 {
                return inv(format!("noise.{name} must be ≥ 0"));
            }
        }
        if self.noise.t_knee <= 0.0 || self.noise.t_pow <= 0.0 {
            return inv("noise.t_knee and t_pow must be > 0".into());
        }
        Ok(())
    }
}

const KNOWN_KEYS: &[&str] = &[
    "macro.cores",
    "macro.engines",
    "macro.rows",
    "macro.act_bits",
    "macro.weight_bits",
    "macro.adc_bits",
    "macro.clock_mhz",
    "macro.tau_frac",
    "enhance.fold",
    "enhance.boost",
    "enhance.fold_offset",
    "enhance.fold_gain",
    "enhance.boost_gain",
    "noise.enabled",
    "noise.sigma_cell",
    "noise.sigma_t_floor",
    "noise.sigma_t_small",
    "noise.t_knee",
    "noise.t_pow",
    "noise.sigma_sa_static",
    "noise.sigma_sa_cmp",
    "noise.sigma_step_rel",
    "noise.sigma_step_static",
    "noise.sigma_cap",
    "noise.fab_seed",
    "energy.e_ctrl_cycle",
    "energy.e_sa_cmp",
    "energy.e_dtc_pulse",
    "energy.e_dtc_tau",
    "energy.e_path_toggle",
    "energy.e_array_unit",
    "energy.e_array_fixed",
    "energy.e_w_write",
    "energy.area_mm2",
    "sim.seed",
    "sim.workers",
    "sim.artifacts_dir",
    "sim.out_dir",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_defaults() {
        let m = MacroConfig::default();
        assert_eq!(m.macro_kb(), 16.0); // 16 Kb macro
        assert_eq!(m.core_kb(), 4.0); // 4 Kb per core
        assert_eq!(m.mac_range(), 6720); // 64·15·7
        assert_eq!(m.adc_codes(), 512);
        assert_eq!(m.macs_per_op(), 4096);
        assert_eq!(m.ops_per_op(), 8192);
        assert!((m.adc_lsb_units() - 26.25).abs() < 1e-12);
    }

    #[test]
    fn fold_gain_matches_paper_ratio() {
        let e = EnhanceConfig::both();
        // 13440/7168 = 1.875 ≈ the paper's "1.87×".
        assert!((e.fold_gain - 13440.0 / 7168.0).abs() < 1e-12);
        assert!((e.dtc_scale() - 3.75).abs() < 1e-12);
        assert_eq!(e.label(), "fold+boost");
        assert_eq!(EnhanceConfig::default().label(), "baseline");
    }

    #[test]
    fn toml_overlay_roundtrip() {
        let c = Config::from_toml_str(
            r#"
            [macro]
            clock_mhz = 100.0
            rows = 32
            [enhance]
            fold = true
            boost = true
            [noise]
            sigma_cell = 0.01
            [sim]
            seed = 7
            out_dir = "results"
            "#,
        )
        .unwrap();
        assert_eq!(c.mac.clock_mhz, 100.0);
        assert_eq!(c.mac.rows, 32);
        assert!(c.enhance.fold && c.enhance.boost);
        assert_eq!(c.noise.sigma_cell, 0.01);
        assert_eq!(c.sim.seed, 7);
        assert_eq!(c.sim.out_dir, "results");
        // untouched defaults survive
        assert_eq!(c.mac.cores, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let e = Config::from_toml_str("[macro]\ncoars = 4\n").unwrap_err();
        match e {
            ConfigError::Invalid(m) => assert!(m.contains("macro.coars")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn validation_catches_bad_values() {
        assert!(Config::from_toml_str("[macro]\nact_bits = 9\n").is_err());
        assert!(Config::from_toml_str("[macro]\nclock_mhz = -1.0\n").is_err());
        assert!(Config::from_toml_str("[noise]\nsigma_cell = -0.1\n").is_err());
        assert!(Config::from_toml_str("[enhance]\nfold_offset = 99\n").is_err());
    }

    #[test]
    fn every_known_key_is_actually_consumed() {
        // Build a doc that sets every known key and confirm overlay accepts
        // each one (guards KNOWN_KEYS and the ov! table against drift).
        let mut by_section: std::collections::BTreeMap<&str, Vec<String>> = Default::default();
        for k in KNOWN_KEYS {
            let (section, key) = k.split_once('.').unwrap();
            let v = match *k {
                "sim.artifacts_dir" | "sim.out_dir" => "\"x\"".to_string(),
                "enhance.fold" | "enhance.boost" | "noise.enabled" => "true".to_string(),
                "macro.cores" | "macro.engines" | "macro.rows" => "2".to_string(),
                "macro.act_bits" | "macro.weight_bits" => "4".to_string(),
                "macro.adc_bits" => "9".to_string(),
                "enhance.fold_offset" => "8".to_string(),
                "noise.fab_seed" | "sim.seed" | "sim.workers" => "3".to_string(),
                "noise.t_knee" | "enhance.fold_gain" | "enhance.boost_gain" | "macro.clock_mhz"
                | "macro.tau_frac" | "energy.area_mm2" => "0.5".to_string(),
                _ => "0.25".to_string(),
            };
            by_section.entry(section).or_default().push(format!("{key} = {v}"));
        }
        let mut text = String::new();
        for (s, kvs) in by_section {
            text.push_str(&format!("[{s}]\n{}\n", kvs.join("\n")));
        }
        let c = Config::from_toml_str(&text).unwrap();
        assert_eq!(c.mac.cores, 2);
        assert_eq!(c.sim.artifacts_dir, "x");
        assert_eq!(c.energy.area_mm2, 0.5);
    }
}
