//! Typed configuration for the whole simulator.
//!
//! Defaults reproduce the paper's macro exactly (16 Kb, 4 cores × 16
//! column-engines × 64 rows × 4-b weights, 9-b cell-embedded ADC, 200 MHz).
//! Every value can be overridden from a TOML file (`--config`) and from the
//! CLI. Units convention (see DESIGN.md §3):
//!
//! * **τ0** — the baseline DTC time LSB (= T_clk · `tau_frac`). All pulse
//!   widths are expressed in τ0.
//! * **u**  — the voltage drop one discharge branch causes in one τ0
//!   (`u = I0·τ0/C`). All voltages (headroom, noise, ADC steps) are in u.
//!
//! With these normalizations, one unit of the ideal product `act·|w|`
//! discharges exactly `scale` u, where `scale` is the configured DTC gain
//! (1.0 baseline, ×1.875 with MAC-folding, ×2 with boosted-clipping).
//!
//! The hardware description itself lives in [`HwSpec`] (DESIGN.md §15):
//! [`Config`] embeds one under `hw` and [derefs](std::ops::Deref) to it, so
//! `cfg.mac.rows`-style access keeps working while the analytic layers
//! (`cim::timing`, `energy`, the placer) take `&HwSpec` directly.

mod hwspec;

pub use hwspec::{
    CalibAnchors, EnergyConfig, EnhanceConfig, HwSpec, MacroConfig, SarAdcRef, TechScale, HW_KEYS,
};

use crate::util::tomlcfg::Doc;
use std::path::Path;

/// Statistical noise model (DESIGN.md §3). Calibrated values — derived by
/// `cimsim calibrate` against the paper's two measured accuracy anchors
/// (1σ = 1.3 % baseline, 0.64 % with both enhancements on 9 000 random
/// points) and frozen here; tests assert the freeze.
#[derive(Clone, Debug, PartialEq)]
pub struct NoiseConfig {
    /// Master switch (off = ideal analog computation; quantization only).
    pub enabled: bool,
    /// Relative per-branch discharge-current mismatch σ (static,
    /// "fabrication" — long-channel M0 keeps this small).
    pub sigma_cell: f64,
    /// Absolute pulse-timing error floor per discharge event, in τ0.
    pub sigma_t_floor: f64,
    /// Extra timing error for narrow pulses, in τ0: the full per-event σ is
    /// `floor + small·(knee/w_sec)^pow` where `w_sec` is the pulse width in
    /// τ0-seconds. This term is what MAC-folding escapes (Fig. 4).
    pub sigma_t_small: f64,
    /// Reference width of the narrow-pulse penalty, in τ0: the penalty is
    /// `sigma_t_small · (t_knee/width)^t_pow`.
    pub t_knee: f64,
    /// Decay exponent of the narrow-pulse penalty.
    pub t_pow: f64,
    /// Static sense-amp input offset σ per engine, in u.
    pub sigma_sa_static: f64,
    /// Dynamic sense-amp noise σ per comparison, in u.
    pub sigma_sa_cmp: f64,
    /// Relative error σ of each binary-search readout step magnitude
    /// (dynamic; branch + pulse noise of the readout discharge).
    pub sigma_step_rel: f64,
    /// Static relative mismatch of each engine's 9 readout step magnitudes
    /// (drives the DNL/INL signature at major code transitions).
    pub sigma_step_static: f64,
    /// Relative RBL/RBLB capacitor mismatch σ per engine (static).
    pub sigma_cap: f64,
    /// Seed for the static ("fabrication") noise draw.
    pub fab_seed: u64,
}

impl Default for NoiseConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            sigma_cell: 0.02,
            sigma_t_floor: 3.40,
            sigma_t_small: 48.5,
            t_knee: 2.0,
            t_pow: 1.0,
            sigma_sa_static: 8.0,
            sigma_sa_cmp: 6.0,
            sigma_step_rel: 0.004,
            sigma_step_static: 0.002,
            sigma_cap: 0.001,
            fab_seed: 0xC1A0_5EED,
        }
    }
}

impl NoiseConfig {
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// Simulation/runtime knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Root seed for dynamic noise and workload generation.
    pub seed: u64,
    /// Worker threads for Monte-Carlo sweeps (0 = auto).
    pub workers: usize,
    /// Directory holding AOT HLO artifacts.
    pub artifacts_dir: String,
    /// Directory for harness outputs (tables, CSVs).
    pub out_dir: String,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            seed: 42,
            workers: 0,
            artifacts_dir: "artifacts".into(),
            out_dir: "out".into(),
        }
    }
}

/// Top-level configuration bundle: the hardware point ([`HwSpec`]) plus the
/// simulator-only layers (noise model, runtime knobs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    /// The candidate hardware. `Config` derefs here, so `cfg.mac`,
    /// `cfg.enhance` and `cfg.energy` read through transparently.
    pub hw: HwSpec,
    pub noise: NoiseConfig,
    pub sim: SimConfig,
}

impl std::ops::Deref for Config {
    type Target = HwSpec;

    fn deref(&self) -> &HwSpec {
        &self.hw
    }
}

impl std::ops::DerefMut for Config {
    fn deref_mut(&mut self) -> &mut HwSpec {
        &mut self.hw
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Io(std::io::Error),
    Parse(crate::util::tomlcfg::ParseError),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io(e) => write!(f, "config io error: {e}"),
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    /// A config running `hw` with default noise and runtime knobs — how the
    /// explore harness wraps a swept candidate for the compiler layers that
    /// take a full `Config`.
    pub fn from_hw(hw: HwSpec) -> Self {
        Self { hw, ..Self::default() }
    }

    /// Load from a TOML file, overlaying onto defaults.
    pub fn from_toml_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(ConfigError::Io)?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        let doc = Doc::parse(text).map_err(ConfigError::Parse)?;
        let mut c = Config::default();
        c.overlay(&doc)?;
        c.validate()?;
        Ok(c)
    }

    /// Overlay recognized keys from a parsed document: hardware sections
    /// via [`HwSpec::overlay`], noise/sim here. Unknown keys are an error
    /// so typos never silently fall back to defaults.
    pub fn overlay(&mut self, doc: &Doc) -> Result<(), ConfigError> {
        for k in doc.keys() {
            if !KNOWN_KEYS.contains(&k) && !HW_KEYS.contains(&k) {
                return Err(ConfigError::Invalid(format!("unknown config key `{k}`")));
            }
        }
        self.hw.overlay(doc)?;
        macro_rules! ov {
            ($field:expr, usize, $key:expr) => {
                if let Some(v) = doc.usize($key) { $field = v; }
            };
            ($field:expr, u64, $key:expr) => {
                if let Some(v) = doc.i64($key) { $field = v as u64; }
            };
            ($field:expr, f64, $key:expr) => {
                if let Some(v) = doc.f64($key) { $field = v; }
            };
            ($field:expr, bool, $key:expr) => {
                if let Some(v) = doc.bool($key) { $field = v; }
            };
            ($field:expr, str, $key:expr) => {
                if let Some(v) = doc.str($key) { $field = v.to_string(); }
            };
        }
        ov!(self.noise.enabled, bool, "noise.enabled");
        ov!(self.noise.sigma_cell, f64, "noise.sigma_cell");
        ov!(self.noise.sigma_t_floor, f64, "noise.sigma_t_floor");
        ov!(self.noise.sigma_t_small, f64, "noise.sigma_t_small");
        ov!(self.noise.t_knee, f64, "noise.t_knee");
        ov!(self.noise.t_pow, f64, "noise.t_pow");
        ov!(self.noise.sigma_sa_static, f64, "noise.sigma_sa_static");
        ov!(self.noise.sigma_sa_cmp, f64, "noise.sigma_sa_cmp");
        ov!(self.noise.sigma_step_rel, f64, "noise.sigma_step_rel");
        ov!(self.noise.sigma_step_static, f64, "noise.sigma_step_static");
        ov!(self.noise.sigma_cap, f64, "noise.sigma_cap");
        ov!(self.noise.fab_seed, u64, "noise.fab_seed");
        ov!(self.sim.seed, u64, "sim.seed");
        ov!(self.sim.workers, usize, "sim.workers");
        ov!(self.sim.artifacts_dir, str, "sim.artifacts_dir");
        ov!(self.sim.out_dir, str, "sim.out_dir");
        Ok(())
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        self.hw.validate()?;
        let inv = |m: String| Err(ConfigError::Invalid(m));
        for (name, v) in [
            ("sigma_cell", self.noise.sigma_cell),
            ("sigma_t_floor", self.noise.sigma_t_floor),
            ("sigma_t_small", self.noise.sigma_t_small),
            ("sigma_sa_static", self.noise.sigma_sa_static),
            ("sigma_sa_cmp", self.noise.sigma_sa_cmp),
            ("sigma_step_rel", self.noise.sigma_step_rel),
            ("sigma_step_static", self.noise.sigma_step_static),
            ("sigma_cap", self.noise.sigma_cap),
        ] {
            if v < 0.0 {
                return inv(format!("noise.{name} must be ≥ 0"));
            }
        }
        if self.noise.t_knee <= 0.0 || self.noise.t_pow <= 0.0 {
            return inv("noise.t_knee and t_pow must be > 0".into());
        }
        Ok(())
    }
}

/// Simulator-only keys ([`Config::overlay`] consumes these itself; the
/// hardware sections live in [`HW_KEYS`]).
const KNOWN_KEYS: &[&str] = &[
    "noise.enabled",
    "noise.sigma_cell",
    "noise.sigma_t_floor",
    "noise.sigma_t_small",
    "noise.t_knee",
    "noise.t_pow",
    "noise.sigma_sa_static",
    "noise.sigma_sa_cmp",
    "noise.sigma_step_rel",
    "noise.sigma_step_static",
    "noise.sigma_cap",
    "noise.fab_seed",
    "sim.seed",
    "sim.workers",
    "sim.artifacts_dir",
    "sim.out_dir",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry_defaults() {
        let m = MacroConfig::default();
        assert_eq!(m.macro_kb(), 16.0); // 16 Kb macro
        assert_eq!(m.core_kb(), 4.0); // 4 Kb per core
        assert_eq!(m.mac_range(), 6720); // 64·15·7
        assert_eq!(m.adc_codes(), 512);
        assert_eq!(m.macs_per_op(), 4096);
        assert_eq!(m.ops_per_op(), 8192);
        assert!((m.adc_lsb_units() - 26.25).abs() < 1e-12);
    }

    #[test]
    fn fold_gain_matches_paper_ratio() {
        let e = EnhanceConfig::both();
        // 13440/7168 = 1.875 ≈ the paper's "1.87×".
        assert!((e.fold_gain - 13440.0 / 7168.0).abs() < 1e-12);
        assert!((e.dtc_scale() - 3.75).abs() < 1e-12);
        assert_eq!(e.label(), "fold+boost");
        assert_eq!(EnhanceConfig::default().label(), "baseline");
    }

    #[test]
    fn config_derefs_to_its_hw_spec() {
        let mut c = Config::default();
        assert_eq!(c.hw, HwSpec::paper_default());
        // Read and write through the deref, as the whole codebase does.
        assert_eq!(c.mac.rows, 64);
        c.enhance = EnhanceConfig::both();
        assert!(c.hw.enhance.fold && c.hw.enhance.boost);
    }

    #[test]
    fn toml_overlay_roundtrip() {
        let c = Config::from_toml_str(
            r#"
            [macro]
            clock_mhz = 100.0
            rows = 32
            [enhance]
            fold = true
            boost = true
            [noise]
            sigma_cell = 0.01
            [sim]
            seed = 7
            out_dir = "results"
            "#,
        )
        .unwrap();
        assert_eq!(c.mac.clock_mhz, 100.0);
        assert_eq!(c.mac.rows, 32);
        assert!(c.enhance.fold && c.enhance.boost);
        assert_eq!(c.noise.sigma_cell, 0.01);
        assert_eq!(c.sim.seed, 7);
        assert_eq!(c.sim.out_dir, "results");
        // untouched defaults survive
        assert_eq!(c.mac.cores, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let e = Config::from_toml_str("[macro]\ncoars = 4\n").unwrap_err();
        match e {
            ConfigError::Invalid(m) => assert!(m.contains("macro.coars")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn validation_catches_bad_values() {
        assert!(Config::from_toml_str("[macro]\nact_bits = 9\n").is_err());
        assert!(Config::from_toml_str("[macro]\nclock_mhz = -1.0\n").is_err());
        assert!(Config::from_toml_str("[noise]\nsigma_cell = -0.1\n").is_err());
        assert!(Config::from_toml_str("[enhance]\nfold_offset = 99\n").is_err());
        assert!(Config::from_toml_str("[tech]\nenergy_scale = 0.0\n").is_err());
        assert!(Config::from_toml_str("[anchors]\nsparse_fraction = 1.5\n").is_err());
    }

    #[test]
    fn every_known_key_is_actually_consumed() {
        // Build a doc that sets every known key (hardware + simulator) and
        // confirm overlay accepts each one (guards the key tables and the
        // ov! lists against drift).
        let mut by_section: std::collections::BTreeMap<&str, Vec<String>> = Default::default();
        for k in KNOWN_KEYS.iter().chain(HW_KEYS) {
            let (section, key) = k.split_once('.').unwrap();
            let v = match *k {
                "sim.artifacts_dir" | "sim.out_dir" => "\"x\"".to_string(),
                "enhance.fold" | "enhance.boost" | "noise.enabled" => "true".to_string(),
                "macro.cores" | "macro.engines" | "macro.rows" => "2".to_string(),
                "macro.act_bits" | "macro.weight_bits" => "4".to_string(),
                "macro.adc_bits" => "9".to_string(),
                "enhance.fold_offset" => "8".to_string(),
                "noise.fab_seed" | "sim.seed" | "sim.workers" => "3".to_string(),
                // The four split fractions must sum to 1 for validation.
                "anchors.split_array" | "anchors.split_path" | "anchors.split_dtc"
                | "anchors.split_sactrl" => "0.25".to_string(),
                "noise.t_knee" | "enhance.fold_gain" | "enhance.boost_gain" | "macro.clock_mhz"
                | "macro.tau_frac" | "energy.area_mm2" | "tech.node_nm" | "tech.energy_scale"
                | "tech.area_scale" | "sar.cu_ff" | "sar.vdd" | "sar.e_cmp_fj"
                | "anchors.dense_tops_w" | "anchors.sparse_tops_w" => "0.5".to_string(),
                _ => "0.25".to_string(),
            };
            by_section.entry(section).or_default().push(format!("{key} = {v}"));
        }
        let mut text = String::new();
        for (s, kvs) in by_section {
            text.push_str(&format!("[{s}]\n{}\n", kvs.join("\n")));
        }
        let c = Config::from_toml_str(&text).unwrap();
        assert_eq!(c.mac.cores, 2);
        assert_eq!(c.sim.artifacts_dir, "x");
        assert_eq!(c.energy.area_mm2, 0.5);
        assert_eq!(c.anchors.power_split, [0.25; 4]);
        assert_eq!(c.tech.node_nm, 0.5);
    }
}
