//! Energy / power / area accounting (Fig. 5 sparsity curve, Fig. 6 table,
//! Fig. 7 breakdowns).
//!
//! The model is charge/activity based: every term is driven by a counter in
//! [`crate::cim::OpStats`], with constants calibrated once against the
//! paper's two measured anchors (dense → 95.6 TOPS/W, 90 %-sparse → 137.5
//! TOPS/W) and the Fig. 7 dense power breakdown (see [`calibrate`]).

pub mod area;
pub mod baselines;
pub mod calibrate;
pub mod fom;

use crate::cim::OpStats;
use crate::config::HwSpec;

/// Energy of one core op, split by the Fig. 7 power-breakdown groups (fJ).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Bit-line array discharge + precharge restore + sign logic.
    pub array_fj: f64,
    /// DTC + SL drivers.
    pub dtc_fj: f64,
    /// Pulse-path configuration network.
    pub path_fj: f64,
    /// Sense amps + control logic.
    pub sa_ctrl_fj: f64,
}

impl EnergyBreakdown {
    pub fn total_fj(&self) -> f64 {
        self.array_fj + self.dtc_fj + self.path_fj + self.sa_ctrl_fj
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.array_fj += o.array_fj;
        self.dtc_fj += o.dtc_fj;
        self.path_fj += o.path_fj;
        self.sa_ctrl_fj += o.sa_ctrl_fj;
    }

    /// Fractions in Fig. 7 order (array, pulse path, dtc, sa+ctrl).
    pub fn fractions(&self) -> [f64; 4] {
        let t = self.total_fj();
        if t == 0.0 {
            return [0.0; 4];
        }
        [self.array_fj / t, self.path_fj / t, self.dtc_fj / t, self.sa_ctrl_fj / t]
    }
}

/// Energy of one core operation from its activity counters.
pub fn core_op_energy(cfg: &HwSpec, s: &OpStats) -> EnergyBreakdown {
    let e = &cfg.energy;
    EnergyBreakdown {
        array_fj: e.e_array_unit * (s.mac_discharge_u + s.adc_discharge_u) + e.e_array_fixed,
        dtc_fj: e.e_dtc_pulse * s.dtc_pulses as f64 + e.e_dtc_tau * s.dtc_tau_sum,
        path_fj: e.e_path_toggle * s.sl_toggles as f64,
        sa_ctrl_fj: e.e_sa_cmp * s.sa_compares as f64
            + e.e_ctrl_cycle * s.total_cycles as f64,
    }
}

/// Energy of writing `tiles` full core weight arrays — the dynamic-weight
/// reload cost (DESIGN.md §10). Pure SRAM write activity, booked to the
/// array group: `tiles · rows · engines · weight_bits · e_w_write`.
pub fn weight_load_energy(cfg: &HwSpec, tiles: u64) -> EnergyBreakdown {
    let bits_per_core =
        (cfg.mac.rows * cfg.mac.engines * cfg.mac.weight_bits as usize) as f64;
    EnergyBreakdown {
        array_fj: tiles as f64 * bits_per_core * cfg.energy.e_w_write,
        ..EnergyBreakdown::default()
    }
}

/// TOPS/W for `ops` operations consuming `energy_fj`.
pub fn tops_per_watt(ops: f64, energy_fj: f64) -> f64 {
    // ops / (E[J]) = ops/s per W; /1e12 → TOPS/W. E[J] = fJ·1e−15.
    ops / (energy_fj * 1e-15) / 1e12
}

/// Energy efficiency of a workload characterized by a mean per-core-op
/// breakdown: all `cores` fire per macro op, each op is `ops_per_op` OPs.
pub fn efficiency_tops_w(cfg: &HwSpec, mean_core_op: &EnergyBreakdown) -> f64 {
    let ops = cfg.mac.ops_per_op() as f64;
    let macro_fj = mean_core_op.total_fj() * cfg.mac.cores as f64;
    tops_per_watt(ops, macro_fj)
}

/// Average power in µW at a given op issue rate (ops/s per core).
pub fn power_uw(cfg: &HwSpec, mean_core_op: &EnergyBreakdown, macro_ops_per_s: f64) -> f64 {
    mean_core_op.total_fj() * cfg.mac.cores as f64 * 1e-15 * macro_ops_per_s * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwSpec;

    fn stats_like_dense() -> OpStats {
        OpStats {
            max_width_tau0: 60.0,
            dtc_pulses: 180,
            dtc_tau_sum: 3360.0,
            sl_toggles: 360,
            mac_discharge_u: 26880.0,
            adc_discharge_u: 107100.0,
            sa_compares: 144,
            mac_cycles: 5,
            total_cycles: 15,
        }
    }

    #[test]
    fn tops_per_watt_math() {
        // 2048 ops at 21.42 pJ → 95.6 TOPS/W.
        let t = tops_per_watt(2048.0, 21.42e3);
        assert!((t - 95.6).abs() < 0.2, "{t}");
    }

    #[test]
    fn breakdown_sums_and_fractions() {
        let cfg = Config::default();
        let b = core_op_energy(&cfg, &stats_like_dense());
        assert!(b.total_fj() > 0.0);
        let f = b.fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // Array should dominate per Fig. 7.
        assert!(f[0] > 0.5, "array fraction {}", f[0]);
    }

    #[test]
    fn energy_is_monotone_in_activity() {
        let cfg = Config::default();
        let dense = core_op_energy(&cfg, &stats_like_dense());
        let mut sparse_stats = stats_like_dense();
        sparse_stats.dtc_pulses = 18;
        sparse_stats.dtc_tau_sum = 336.0;
        sparse_stats.sl_toggles = 36;
        sparse_stats.mac_discharge_u = 2688.0;
        let sparse = core_op_energy(&cfg, &sparse_stats);
        assert!(sparse.total_fj() < dense.total_fj());
        // Sparse still pays the fixed readout cost.
        assert!(sparse.array_fj > cfg.energy.e_array_fixed);
    }

    #[test]
    fn weight_load_energy_scales_with_tiles() {
        let cfg = Config::default();
        let one = weight_load_energy(&cfg, 1);
        // 64 rows × 16 engines × 4 b = 4096 bits per core.
        assert!((one.array_fj - 4096.0 * cfg.energy.e_w_write).abs() < 1e-9);
        assert_eq!(one.dtc_fj, 0.0);
        let five = weight_load_energy(&cfg, 5);
        assert!((five.total_fj() - 5.0 * one.total_fj()).abs() < 1e-9);
        // A reload costs well under a dense core op (writes are cheap
        // relative to the analog MAC + readout).
        assert!(one.total_fj() < core_op_energy(&cfg, &stats_like_dense()).total_fj());
    }
}
