//! The paper's figure of merit (Fig. 6 footnote 4):
//! `FoM = ACT(bit) × W(bit) × OUT-ratio × Throughput(TOPS/Kb) × EE(TOPS/W)`
//! evaluated at average performance, where
//! `OUT-ratio = readout precision / full output precision` per [7].

use crate::config::HwSpec;

/// Full output precision of an `act_bits × w_bits` MAC accumulated over
/// `rows` terms: act + w + log2(rows) bits.
pub fn full_output_bits(act_bits: u32, w_bits: u32, rows: usize) -> f64 {
    act_bits as f64 + w_bits as f64 + (rows as f64).log2()
}

/// OUT-ratio for the configured macro (9 / 14 for the default geometry).
pub fn out_ratio(cfg: &HwSpec) -> f64 {
    cfg.mac.adc_bits as f64
        / full_output_bits(cfg.mac.act_bits, cfg.mac.weight_bits, cfg.mac.rows)
}

/// The FoM at a given operating point.
pub fn fom(
    act_bits: u32,
    w_bits: u32,
    out_ratio: f64,
    gops_per_kb: f64,
    tops_per_watt: f64,
) -> f64 {
    act_bits as f64 * w_bits as f64 * out_ratio * (gops_per_kb / 1e3) * tops_per_watt
}

/// FoM from (min, max) performance ranges evaluated at the averages, the
/// paper's stated convention.
pub fn fom_avg(
    act_bits: u32,
    w_bits: u32,
    out_ratio: f64,
    gops_per_kb: (f64, f64),
    tops_w: (f64, f64),
) -> f64 {
    fom(
        act_bits,
        w_bits,
        out_ratio,
        0.5 * (gops_per_kb.0 + gops_per_kb.1),
        0.5 * (tops_w.0 + tops_w.1),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwSpec;

    #[test]
    fn default_out_ratio_is_9_over_14() {
        let cfg = Config::default();
        assert!((out_ratio(&cfg) - 9.0 / 14.0).abs() < 1e-12);
        assert!((full_output_bits(4, 4, 64) - 14.0).abs() < 1e-12);
    }

    #[test]
    fn published_6_fom_reproduces_with_unity_ratio() {
        // [6]: 4×4×1.0×0.00617×46.3 = 4.57 — confirms the paper computed
        // [6] with OUT-ratio 1 (full-precision readout).
        let f = fom(4, 4, 1.0, 6.17, 46.3);
        assert!((f - 4.57).abs() < 0.01, "{f}");
    }

    #[test]
    fn our_4b_fom_magnitude() {
        // With our measured ranges (6.82–8.53 GOPS/Kb, 95.6–137.5 TOPS/W)
        // and OUT-ratio 9/14 the FoM lands in the 9–10.5 region the paper
        // reports as 10.4 (see EXPERIMENTS.md for the gap discussion).
        let f = fom_avg(4, 4, 9.0 / 14.0, (6.82, 8.53), (95.6, 137.5));
        assert!(f > 8.5 && f < 11.0, "{f}");
    }

    #[test]
    fn fom_linear_in_each_factor() {
        let base = fom(4, 4, 0.5, 5.0, 100.0);
        assert!((fom(8, 4, 0.5, 5.0, 100.0) / base - 2.0).abs() < 1e-12);
        assert!((fom(4, 4, 1.0, 5.0, 100.0) / base - 2.0).abs() < 1e-12);
        assert!((fom(4, 4, 0.5, 10.0, 100.0) / base - 2.0).abs() < 1e-12);
    }
}
