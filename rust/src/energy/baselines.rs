//! Comparison models for the state-of-the-art designs the paper evaluates
//! against (Figs 1 and 6): published table rows plus an analytic SAR-ADC
//! energy model standing in for the paper's "post-simulation with TSMC
//! 40nm" readout-energy comparison (DESIGN.md §1 substitution table).

/// One comparison design (a row of Fig. 6 + the Fig. 1 axes).
#[derive(Clone, Debug)]
pub struct CimDesign {
    pub name: &'static str,
    pub reference: &'static str,
    pub tech_nm: u32,
    pub memory_kb: u32,
    pub freq_mhz: Option<(f64, f64)>,
    /// Activation / weight bits processed per analog MAC cycle.
    pub act_bits_per_cycle: u32,
    pub w_bits_per_cycle: u32,
    /// Full (extendable) ACT:W precision reported in the table.
    pub act_bits: u32,
    pub w_bits: u32,
    /// Analog accumulations per A-to-D conversion — the Fig. 1
    /// "parallelism" axis.
    pub acc_before_adc: u32,
    pub adc_bits: u32,
    /// Readout precision / full output precision (per [7]).
    pub out_ratio: f64,
    pub gops_per_kb: Option<(f64, f64)>,
    pub tops_w: (f64, f64),
    pub area_eff: Option<(f64, f64)>,
    /// Published FoMs where the paper reports them.
    pub fom_4b: Option<f64>,
    pub fom_8b: Option<f64>,
    /// Whether A-to-D is a separate SAR (true) or cell-embedded (false).
    pub separate_adc: bool,
}

/// The five comparison designs, straight from Fig. 6 plus the architectural
/// facts the paper's text states about them ([2]–[4], [6]: 2-b ACT × 1-b W
/// per cycle with limited accumulation; [5]: 8-b parallel charge-averaging
/// with an 8-b SAR).
pub fn published() -> Vec<CimDesign> {
    vec![
        CimDesign {
            name: "ISSCC'21 [2]",
            reference: "Su et al., 28nm 384kb 6T-SRAM CIM, 8b precision",
            tech_nm: 28,
            memory_kb: 384,
            freq_mhz: None,
            act_bits_per_cycle: 2,
            w_bits_per_cycle: 1,
            act_bits: 4,
            w_bits: 4,
            acc_before_adc: 16,
            adc_bits: 5,
            out_ratio: 1.0,
            gops_per_kb: None,
            tops_w: (60.28, 94.31),
            area_eff: None,
            fom_4b: None,
            fom_8b: None,
            separate_adc: true,
        },
        CimDesign {
            name: "ISSCC'21 [6]",
            reference: "Yue et al., 65nm CIM NN processor, zero skipping",
            tech_nm: 65,
            memory_kb: 64,
            freq_mhz: Some((25.0, 100.0)),
            act_bits_per_cycle: 2,
            w_bits_per_cycle: 1,
            act_bits: 4,
            w_bits: 4,
            acc_before_adc: 16,
            adc_bits: 5,
            out_ratio: 1.0,
            gops_per_kb: Some((6.17, 6.17)),
            tops_w: (46.3, 46.3),
            area_eff: Some((27.1, 27.1)),
            fom_4b: Some(4.57),
            fom_8b: Some(1.14),
            separate_adc: true,
        },
        CimDesign {
            name: "JSSC'22 [3]",
            reference: "Su et al., two-way transpose multibit 6T SRAM CIM",
            tech_nm: 28,
            memory_kb: 64,
            freq_mhz: None,
            act_bits_per_cycle: 2,
            w_bits_per_cycle: 1,
            act_bits: 4,
            w_bits: 4,
            acc_before_adc: 16,
            adc_bits: 5,
            out_ratio: 1.0,
            gops_per_kb: None,
            tops_w: (28.0, 30.4),
            area_eff: None,
            fom_4b: None,
            fom_8b: None,
            separate_adc: true,
        },
        CimDesign {
            name: "VLSI'22 [5]",
            reference: "Wang et al., 22nm C-2C ladder charge-domain CIM",
            tech_nm: 22,
            memory_kb: 128,
            freq_mhz: Some((145.0, 240.0)),
            act_bits_per_cycle: 8,
            w_bits_per_cycle: 8,
            act_bits: 8,
            w_bits: 8,
            acc_before_adc: 64,
            adc_bits: 8,
            out_ratio: 8.0 / 22.0,
            gops_per_kb: Some((4.69, 7.81)),
            tops_w: (15.5, 32.2),
            area_eff: Some((62.0, 128.8)),
            fom_4b: None,
            fom_8b: Some(1.69),
            separate_adc: true,
        },
        CimDesign {
            name: "ISSCC'22 [4]",
            reference: "Wu et al., 28nm 1Mb time-domain CIM 6T-SRAM",
            tech_nm: 28,
            memory_kb: 1024,
            freq_mhz: None,
            act_bits_per_cycle: 2,
            w_bits_per_cycle: 1,
            act_bits: 4,
            w_bits: 4,
            acc_before_adc: 32,
            adc_bits: 6,
            out_ratio: 1.0,
            gops_per_kb: Some((4.15, 4.85)),
            tops_w: (84.45, 112.6),
            area_eff: None,
            fom_4b: Some(5.6),
            fom_8b: Some(1.39),
            separate_adc: true,
        },
    ]
}

/// Energy of one N-bit SAR A-to-D conversion in fJ ("post-simulation, TSMC
/// 40nm" stand-in): binary-weighted DAC switching + comparator + logic.
///
/// * DAC: conventional switching dissipates ≈ α·2^N·C_u·V_DD² per
///   conversion; C_u is matching-limited, not kT/C-limited, for ≥ 8 b.
/// * Comparator + SAR logic: per-decision cost, N decisions.
pub fn sar_adc_energy_fj(bits: u32, cu_ff: f64, vdd: f64, e_cmp_fj: f64) -> f64 {
    let alpha = 0.66; // avg switching factor of the conventional ladder
    let dac = alpha * (1u64 << bits) as f64 * cu_ff * vdd * vdd; // fF·V² = fJ
    let cmp_logic = bits as f64 * e_cmp_fj;
    dac + cmp_logic
}

/// Default 40 nm SAR unit capacitance. Frozen from
/// `HwSpec::paper_default().sar.cu_ff`.
#[deprecated(note = "use `cfg.sar.cu_ff` (`config::SarAdcRef`)")]
pub const SAR_CU_FF: f64 = 1.8;
/// Default 40 nm SAR supply. Frozen from `HwSpec::paper_default().sar.vdd`.
#[deprecated(note = "use `cfg.sar.vdd` (`config::SarAdcRef`)")]
pub const SAR_VDD: f64 = 0.9;
/// Default 40 nm SAR comparator energy per decision. Frozen from
/// `HwSpec::paper_default().sar.e_cmp_fj`.
#[deprecated(note = "use `cfg.sar.e_cmp_fj` (`config::SarAdcRef`)")]
pub const SAR_E_CMP_FJ: f64 = 5.0;

/// Readout energy per MAC when a separate `bits`-b SAR (parameterized by
/// `sar`) serves `acc` accumulations per conversion.
pub fn sar_readout_fj_per_mac_with(sar: &crate::config::SarAdcRef, bits: u32, acc: u32) -> f64 {
    sar_adc_energy_fj(bits, sar.cu_ff, sar.vdd, sar.e_cmp_fj) / acc as f64
}

/// Readout energy per MAC under the paper-default reference SAR
/// ([`crate::config::HwSpec::paper_default`]'s `sar` field).
pub fn sar_readout_fj_per_mac(bits: u32, acc: u32) -> f64 {
    sar_readout_fj_per_mac_with(&crate::config::SarAdcRef::default(), bits, acc)
}

/// Number of analog MAC-ADC cycles + shift-add passes a design needs to
/// produce one full-precision `act_bits × w_bits` product term (the Fig. 1
/// parallelism penalty of low-precision-per-cycle designs).
pub fn cycles_for_full_precision(d: &CimDesign) -> u32 {
    let act_passes = d.act_bits.div_ceil(d.act_bits_per_cycle);
    let w_passes = d.w_bits.div_ceil(d.w_bits_per_cycle);
    act_passes * w_passes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_published_designs() {
        let v = published();
        assert_eq!(v.len(), 5);
        // Spot-check against Fig. 6 numbers.
        let by_name = |n: &str| v.iter().find(|d| d.name.contains(n)).unwrap().clone();
        assert_eq!(by_name("[6]").tops_w, (46.3, 46.3));
        assert_eq!(by_name("[5]").tech_nm, 22);
        assert_eq!(by_name("[4]").memory_kb, 1024);
        assert_eq!(by_name("[2]").tops_w.1, 94.31);
    }

    #[test]
    fn sar_energy_scales_exponentially_with_bits() {
        let sar = crate::config::SarAdcRef::default();
        let e8 = sar_adc_energy_fj(8, sar.cu_ff, sar.vdd, sar.e_cmp_fj);
        let e9 = sar_adc_energy_fj(9, sar.cu_ff, sar.vdd, sar.e_cmp_fj);
        assert!(e9 / e8 > 1.8 && e9 / e8 < 2.1);
        // 8-b, 40 nm-ish: a few hundred fJ.
        assert!(e8 > 200.0 && e8 < 500.0, "{e8}");
    }

    #[test]
    fn low_precision_designs_need_multiple_passes() {
        let v = published();
        for d in &v {
            let c = cycles_for_full_precision(d);
            if d.name.contains("[5]") {
                assert_eq!(c, 1, "8b-parallel design needs one pass");
            } else {
                assert_eq!(c, 8, "2b×1b per cycle → 2×4 passes for 4b×4b");
            }
        }
    }

    #[test]
    fn embedded_readout_amortizes_better_than_sar() {
        // Ours: high accumulation count with the bit-line pair reused; a
        // 9-b SAR serving only 16 accumulations costs much more per MAC.
        let sar_16acc = sar_readout_fj_per_mac(5, 16);
        let sar_64acc_9b = sar_readout_fj_per_mac(9, 64);
        assert!(sar_64acc_9b > sar_16acc, "9b SAR is the expensive case");
        // The explicit-parameter path agrees with the paper-default one.
        let sar = crate::config::SarAdcRef::default();
        assert_eq!(sar_readout_fj_per_mac_with(&sar, 9, 64), sar_64acc_9b);
    }

    /// The deprecated consts stay frozen at the paper-default SAR fields
    /// they re-export.
    #[test]
    #[allow(deprecated)]
    fn deprecated_sar_consts_match_paper_default() {
        let sar = crate::config::HwSpec::paper_default().sar;
        assert_eq!(SAR_CU_FF, sar.cu_ff);
        assert_eq!(SAR_VDD, sar.vdd);
        assert_eq!(SAR_E_CMP_FJ, sar.e_cmp_fj);
    }
}
