//! Energy-constant calibration (DESIGN.md §7).
//!
//! Two measured anchors from the paper fix the activity-dependent and fixed
//! array energy; the Fig. 7 dense power breakdown fixes the group split:
//!
//! * dense 4b:4b random inputs  → **95.6 TOPS/W**
//! * 90 %-sparse random inputs  → **137.5 TOPS/W**
//! * dense split: array/sign 64.75 %, pulse path 17.93 %, DTC+driver
//!   14.19 %, SA+control 3.13 %.
//!
//! Everything else in the energy model (the sparsity *curve* between the
//! anchors, enhancement-mode deltas, per-component sparsity response) is
//! then a prediction. `cimsim calibrate` prints the solved constants; the
//! solved values are frozen in `EnergyConfig::default` and
//! `calibration_is_frozen` asserts the freeze.

use crate::cim::{MacroSim, OpStats};
use crate::config::{Config, EnergyConfig};
use crate::util::rng::{Rng, Xoshiro256};

/// Paper anchor, frozen from `HwSpec::paper_default().anchors.dense_tops_w`.
#[deprecated(note = "use `cfg.anchors.dense_tops_w` (`config::CalibAnchors`)")]
pub const DENSE_TOPS_W: f64 = 95.6;
/// Paper anchor, frozen from `HwSpec::paper_default().anchors.sparse_tops_w`.
#[deprecated(note = "use `cfg.anchors.sparse_tops_w` (`config::CalibAnchors`)")]
pub const SPARSE_TOPS_W: f64 = 137.5;
/// Paper anchor, frozen from `HwSpec::paper_default().anchors.sparse_fraction`.
#[deprecated(note = "use `cfg.anchors.sparse_fraction` (`config::CalibAnchors`)")]
pub const SPARSE_FRACTION: f64 = 0.9;
/// Fig. 7 dense power breakdown: array, pulse path, DTC, SA+ctrl. Frozen
/// from `HwSpec::paper_default().anchors.power_split`.
#[deprecated(note = "use `cfg.anchors.power_split` (`config::CalibAnchors`)")]
pub const POWER_SPLIT: [f64; 4] = [0.6475, 0.1793, 0.1419, 0.0313];
/// SA comparison energy pinned a-priori (a 40 nm strong-arm latch is a few
/// fJ per decision). Frozen from `HwSpec::paper_default().anchors.e_sa_fj`.
#[deprecated(note = "use `cfg.anchors.e_sa_fj` (`config::CalibAnchors`)")]
pub const E_SA_FJ: f64 = 2.0;
/// Fraction of DTC energy attributed to the per-pulse fixed cost. Frozen
/// from `HwSpec::paper_default().anchors.dtc_pulse_split`.
#[deprecated(note = "use `cfg.anchors.dtc_pulse_split` (`config::CalibAnchors`)")]
pub const DTC_PULSE_SPLIT: f64 = 0.5;

/// Mean per-core-op activity for a random workload with the given input
/// sparsity (fraction of zero activations).
pub fn mean_stats(cfg: &Config, sparsity: f64, trials: usize, seed: u64) -> OpStats {
    let mut sim_cfg = cfg.clone();
    sim_cfg.noise.enabled = false; // activity counters, not accuracy
    let mut sim = MacroSim::new(sim_cfg.clone());
    let mut rng = Xoshiro256::seeded(seed);
    let rows = cfg.mac.rows;
    let w: Vec<Vec<i64>> = (0..rows)
        .map(|_| (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect())
        .collect();
    sim.load_core(0, &w).unwrap();

    let mut acc = OpStats::default();
    let mut cyc_sum = 0u64;
    let mut mac_cyc_sum = 0u64;
    for _ in 0..trials {
        let acts: Vec<i64> = (0..rows)
            .map(|_| {
                if rng.next_bool(sparsity) {
                    0
                } else {
                    rng.next_range_i64(1, cfg.mac.act_max())
                }
            })
            .collect();
        let r = sim.core_op(0, &acts, &mut rng).unwrap();
        // accumulate() maxes cycles; averages need the sum.
        cyc_sum += r.stats.total_cycles;
        mac_cyc_sum += r.stats.mac_cycles;
        acc.dtc_pulses += r.stats.dtc_pulses;
        acc.dtc_tau_sum += r.stats.dtc_tau_sum;
        acc.sl_toggles += r.stats.sl_toggles;
        acc.mac_discharge_u += r.stats.mac_discharge_u;
        acc.adc_discharge_u += r.stats.adc_discharge_u;
        acc.sa_compares += r.stats.sa_compares;
        acc.max_width_tau0 = acc.max_width_tau0.max(r.stats.max_width_tau0);
    }
    let n = trials as f64;
    OpStats {
        max_width_tau0: acc.max_width_tau0,
        dtc_pulses: (acc.dtc_pulses as f64 / n).round() as usize,
        dtc_tau_sum: acc.dtc_tau_sum / n,
        sl_toggles: (acc.sl_toggles as f64 / n).round() as usize,
        mac_discharge_u: acc.mac_discharge_u / n,
        adc_discharge_u: acc.adc_discharge_u / n,
        sa_compares: (acc.sa_compares as f64 / n).round() as usize,
        mac_cycles: ((mac_cyc_sum as f64) / n).round() as u64,
        total_cycles: ((cyc_sum as f64) / n).round() as u64,
    }
}

#[derive(Debug)]
pub struct CalibrationError(pub String);

impl std::fmt::Display for CalibrationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "calibration failed: {}", self.0)
    }
}

impl std::error::Error for CalibrationError {}

/// Solve the energy constants from the configured anchors
/// (`cfg.anchors`, the paper's published numbers by default — see module
/// docs).
pub fn solve(cfg: &Config) -> Result<EnergyConfig, CalibrationError> {
    let anchors = &cfg.anchors;
    let trials = 400;
    let dense = mean_stats(cfg, 0.0, trials, 0xCA11);
    let sparse = mean_stats(cfg, anchors.sparse_fraction, trials, 0xCA11);

    // Per-core-op energy targets (fJ): macro op = `cores` core ops.
    let ops = cfg.mac.ops_per_op() as f64 / cfg.mac.cores as f64;
    let e_dense = ops / anchors.dense_tops_w * 1e3; // ops / (TOPS/W) in fJ
    let e_sparse = ops / anchors.sparse_tops_w * 1e3;

    let [f_array, f_path, f_dtc, f_sactrl] = anchors.power_split;
    let a_d = f_array * e_dense;
    let p_d = f_path * e_dense;
    let d_d = f_dtc * e_dense;
    let s_d = f_sactrl * e_dense;

    let e_path_toggle = p_d / dense.sl_toggles as f64;
    let e_dtc_pulse = anchors.dtc_pulse_split * d_d / dense.dtc_pulses as f64;
    let e_dtc_tau = (1.0 - anchors.dtc_pulse_split) * d_d / dense.dtc_tau_sum;
    let e_sa_cmp = anchors.e_sa_fj;
    let e_ctrl_cycle = (s_d - e_sa_cmp * dense.sa_compares as f64) / dense.total_cycles as f64;
    if e_ctrl_cycle <= 0.0 {
        return Err(CalibrationError(format!(
            "control energy went non-positive ({e_ctrl_cycle:.3} fJ/cycle)"
        )));
    }

    // Variable (non-array) energy of the sparse workload with these constants.
    let v_sparse = e_dtc_pulse * sparse.dtc_pulses as f64
        + e_dtc_tau * sparse.dtc_tau_sum
        + e_path_toggle * sparse.sl_toggles as f64
        + e_sa_cmp * sparse.sa_compares as f64
        + e_ctrl_cycle * sparse.total_cycles as f64;

    // Two equations for the array term:
    //   e_u·dis_dense + e_fix = a_d
    //   e_u·dis_sparse + e_fix = e_sparse − v_sparse
    let dis_dense = dense.mac_discharge_u + dense.adc_discharge_u;
    let dis_sparse = sparse.mac_discharge_u + sparse.adc_discharge_u;
    let rhs_sparse = e_sparse - v_sparse;
    let denom = dis_dense - dis_sparse;
    if denom.abs() < 1e-6 {
        return Err(CalibrationError("workloads have identical discharge".into()));
    }
    let e_array_unit = (a_d - rhs_sparse) / denom;
    let e_array_fixed = a_d - e_array_unit * dis_dense;
    if e_array_unit <= 0.0 || e_array_fixed <= 0.0 {
        return Err(CalibrationError(format!(
            "array split infeasible (unit {e_array_unit:.4}, fixed {e_array_fixed:.1})"
        )));
    }

    Ok(EnergyConfig {
        e_ctrl_cycle,
        e_sa_cmp,
        e_dtc_pulse,
        e_dtc_tau,
        e_path_toggle,
        e_array_unit,
        e_array_fixed,
        // Not derivable from the paper's anchors (no write-energy figure):
        // the SRAM write constant passes through unchanged.
        e_w_write: cfg.energy.e_w_write,
        area_mm2: cfg.energy.area_mm2,
    })
}

/// Measured efficiency (TOPS/W) of a random workload at a given sparsity
/// under the configured energy constants.
pub fn measured_efficiency(cfg: &Config, sparsity: f64, trials: usize, seed: u64) -> f64 {
    let stats = mean_stats(cfg, sparsity, trials, seed);
    let b = super::core_op_energy(cfg, &stats);
    super::efficiency_tops_w(cfg, &b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn solver_hits_both_anchors() {
        let cfg = Config::default();
        let solved = solve(&cfg).unwrap();
        let mut c2 = cfg.clone();
        c2.energy = solved;
        let a = cfg.anchors.clone();
        let dense = measured_efficiency(&c2, 0.0, 400, 0xCA11);
        let sparse = measured_efficiency(&c2, a.sparse_fraction, 400, 0xCA11);
        assert!((dense - a.dense_tops_w).abs() < 1.0, "dense {dense}");
        assert!((sparse - a.sparse_tops_w).abs() < 2.0, "sparse {sparse}");
    }

    #[test]
    fn solver_reproduces_fig7_split_at_dense() {
        let cfg = Config::default();
        let solved = solve(&cfg).unwrap();
        let mut c2 = cfg.clone();
        c2.energy = solved;
        let stats = mean_stats(&c2, 0.0, 400, 0xCA11);
        let b = super::super::core_op_energy(&c2, &stats);
        let f = b.fractions();
        for (got, want) in f.iter().zip(cfg.anchors.power_split) {
            assert!((got - want).abs() < 0.01, "fraction {got} vs {want}");
        }
    }

    /// The frozen defaults in `EnergyConfig::default()` must match what the
    /// solver derives (re-freeze whenever the activity model changes).
    #[test]
    fn calibration_is_frozen() {
        let cfg = Config::default();
        let solved = solve(&cfg).unwrap();
        let frozen = cfg.energy.clone();
        let close = |a: f64, b: f64, tag: &str| {
            assert!(
                (a - b).abs() <= 0.02 * b.abs().max(1e-9),
                "{tag}: solved {a} vs frozen {b} — re-freeze EnergyConfig::default"
            );
        };
        close(solved.e_ctrl_cycle, frozen.e_ctrl_cycle, "e_ctrl_cycle");
        close(solved.e_sa_cmp, frozen.e_sa_cmp, "e_sa_cmp");
        close(solved.e_dtc_pulse, frozen.e_dtc_pulse, "e_dtc_pulse");
        close(solved.e_dtc_tau, frozen.e_dtc_tau, "e_dtc_tau");
        close(solved.e_path_toggle, frozen.e_path_toggle, "e_path_toggle");
        close(solved.e_array_unit, frozen.e_array_unit, "e_array_unit");
        close(solved.e_array_fixed, frozen.e_array_fixed, "e_array_fixed");
    }

    /// The deprecated consts must stay frozen at the paper-default anchor
    /// fields they re-export, so downstream code migrates without drift.
    #[test]
    #[allow(deprecated)]
    fn deprecated_consts_match_paper_default_anchors() {
        let a = crate::config::HwSpec::paper_default().anchors;
        assert_eq!(DENSE_TOPS_W, a.dense_tops_w);
        assert_eq!(SPARSE_TOPS_W, a.sparse_tops_w);
        assert_eq!(SPARSE_FRACTION, a.sparse_fraction);
        assert_eq!(POWER_SPLIT, a.power_split);
        assert_eq!(E_SA_FJ, a.e_sa_fj);
        assert_eq!(DTC_PULSE_SPLIT, a.dtc_pulse_split);
    }

    #[test]
    fn efficiency_monotone_in_sparsity() {
        let cfg = Config::default();
        let mut prev = 0.0;
        for s in [0.0, 0.3, 0.6, 0.9] {
            let e = measured_efficiency(&cfg, s, 150, 7);
            assert!(e > prev, "sparsity {s}: {e} ≤ {prev}");
            prev = e;
        }
    }
}

#[cfg(test)]
mod freeze_helper {
    /// `cargo test print_solved_constants -- --ignored --nocapture` prints
    /// the solver output for re-freezing `EnergyConfig::default`.
    #[test]
    #[ignore]
    fn print_solved_constants() {
        let cfg = crate::config::Config::default();
        let e = super::solve(&cfg).unwrap();
        println!("{e:#?}");
    }
}
