//! Area model (Fig. 7 area breakdown, Fig. 6 area-efficiency row).
//!
//! The paper's 790–1136 TOPS/W/mm² range is consistent with a single macro
//! area of 0.121 mm² at both efficiency endpoints; the Fig. 7 area breakdown
//! is partially illegible in the source text — the MOM-capacitor/pre-charge
//! share is taken as the remainder (documented in DESIGN.md §8).

use crate::config::HwSpec;

/// Fig. 7 area breakdown fractions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaBreakdown {
    pub sa_analog: f64,
    pub control: f64,
    pub storage: f64,
    pub mom_caps: f64,
}

pub const PAPER_AREA_BREAKDOWN: AreaBreakdown = AreaBreakdown {
    sa_analog: 0.3604,
    control: 0.0760,
    storage: 0.0036,
    mom_caps: 0.5600, // remainder assumption, see DESIGN.md §8
};

impl AreaBreakdown {
    pub fn total(&self) -> f64 {
        self.sa_analog + self.control + self.storage + self.mom_caps
    }

    /// Absolute component areas in mm² for a macro of `area_mm2`.
    pub fn absolute(&self, area_mm2: f64) -> [(&'static str, f64); 4] {
        [
            ("SA + analog modules", self.sa_analog * area_mm2),
            ("Control logic", self.control * area_mm2),
            ("Storage", self.storage * area_mm2),
            ("MOM caps + precharge", self.mom_caps * area_mm2),
        ]
    }
}

/// Normalized energy-based area efficiency, TOPS/W/mm² (the Fig. 6 metric
/// per [7]).
pub fn area_efficiency(cfg: &HwSpec, tops_per_watt: f64) -> f64 {
    tops_per_watt / cfg.energy.area_mm2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HwSpec;

    #[test]
    fn breakdown_sums_to_one() {
        assert!((PAPER_AREA_BREAKDOWN.total() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn paper_area_consistency() {
        // 95.6/0.121 ≈ 790 and 137.5/0.121 ≈ 1136 — the Fig. 6 range.
        let cfg = Config::default();
        let lo = area_efficiency(&cfg, 95.6);
        let hi = area_efficiency(&cfg, 137.5);
        assert!((lo - 790.0).abs() < 3.0, "{lo}");
        assert!((hi - 1136.0).abs() < 3.0, "{hi}");
    }

    #[test]
    fn absolute_areas() {
        let abs = PAPER_AREA_BREAKDOWN.absolute(0.121);
        let total: f64 = abs.iter().map(|(_, a)| a).sum();
        assert!((total - 0.121).abs() < 1e-12);
        assert_eq!(abs[0].0, "SA + analog modules");
    }
}
