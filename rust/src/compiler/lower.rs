//! Lowering: graph IR → tiled macro layers.
//!
//! Each `Conv2d`/`Linear` node (with its mandatory `Quantize` input) lowers
//! to a [`CimLinear`] — conv weights via the shared im2col lowering
//! (`nn::im2col::weights_to_cols`), linear weights directly — with
//! per-layer activation-range calibration: [`calibrate`] runs the float
//! graph over a calibration set and records each quantize boundary's
//! maximum activation, exactly the deployment recipe `CimConv::new` uses.

use crate::compiler::ir::{Graph, NodeId, Op};
use crate::config::Config;
use crate::mapping::executor::CimLinear;
use crate::nn::im2col::weights_to_cols;
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;

/// Compilation failure.
#[derive(Debug)]
pub enum CompileError {
    /// The graph violates a structural rule (missing quantize, bad shapes…).
    Structure(String),
    /// The pool rejected a placement or load.
    Macro(crate::cim::MacroError),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Structure(m) => write!(f, "compile error: {m}"),
            CompileError::Macro(e) => write!(f, "compile error: {e}"),
        }
    }
}

impl std::error::Error for CompileError {}

impl From<crate::cim::MacroError> for CompileError {
    fn from(e: crate::cim::MacroError) -> Self {
        CompileError::Macro(e)
    }
}

/// Per-node activation calibration: the maximum value seen at each
/// data-calibrated `Quantize` boundary over the calibration set.
#[derive(Clone, Debug)]
pub struct Calibration {
    act_max: Vec<f32>,
}

impl Calibration {
    /// The calibrated activation max of a quantize node (≥ a small floor so
    /// scales never divide by zero).
    pub fn act_max(&self, node: NodeId) -> f32 {
        self.act_max[node].max(1e-6)
    }
}

/// Run the float graph over `inputs` and record each `Quantize(None)`
/// node's input maximum. Graphs whose quantize params are all explicit
/// (e.g. [`Graph::from_deployment`]) calibrate fine on an empty set.
pub fn calibrate(graph: &Graph, inputs: &[Tensor]) -> Result<Calibration, CompileError> {
    let needs_data = graph
        .nodes
        .iter()
        .any(|n| matches!(n.op, Op::Quantize { params: None }));
    if needs_data && inputs.is_empty() {
        return Err(CompileError::Structure(
            "graph has data-calibrated Quantize nodes but no calibration inputs".into(),
        ));
    }
    let mut act_max = vec![0f32; graph.nodes.len()];
    for x in inputs {
        let vals = graph.eval_float(x).map_err(CompileError::Structure)?;
        for (id, node) in graph.nodes.iter().enumerate() {
            if let Op::Quantize { params: None } = node.op {
                let src = node.inputs[0];
                for &v in &vals[src].data {
                    if v > act_max[id] {
                        act_max[id] = v;
                    }
                }
            }
        }
    }
    Ok(Calibration { act_max })
}

/// What a lowered cim layer computes around its matmul.
#[derive(Clone, Copy, Debug)]
pub enum LayerKind {
    /// im2col convolution: per-position rows through the tiled linear, back
    /// to CHW.
    Conv { kh: usize, kw: usize, stride: usize, pad: usize, out_c: usize },
    /// One activation vector per batch item.
    Linear,
}

/// A `Conv2d`/`Linear` node lowered to a tiled macro layer, not yet placed.
#[derive(Clone, Debug)]
pub struct LoweredLayer {
    /// The compute node this lowers.
    pub node: NodeId,
    /// The node whose value feeds the layer (the quantize node's input —
    /// quantization happens inside the layer step).
    pub src: NodeId,
    pub name: String,
    pub kind: LayerKind,
    /// Activation quantization applied to the layer's input rows.
    pub qparams: QuantParams,
    /// The tiled integer layer (weights quantized, dequant policy per
    /// `w_params`: fused when calibrated, unit when explicit).
    pub lin: CimLinear,
    /// Activation vectors one network input generates (conv: `oh·ow`).
    pub vectors_per_input: usize,
}

/// Lower every compute node of the graph. `shapes` comes from
/// [`Graph::infer_shapes`]; `cal` from [`calibrate`].
pub fn lower(
    graph: &Graph,
    shapes: &[Vec<usize>],
    cal: &Calibration,
    cfg: &Config,
) -> Result<Vec<LoweredLayer>, CompileError> {
    let mut layers = Vec::new();
    for (id, node) in graph.nodes.iter().enumerate() {
        let (w_cols, bias, w_params, kind, vectors) = match &node.op {
            Op::Conv2d { w, bias, stride, pad, w_params } => {
                let out_shape = &shapes[id];
                (
                    weights_to_cols(w),
                    bias.clone(),
                    *w_params,
                    LayerKind::Conv {
                        kh: w.shape[2],
                        kw: w.shape[3],
                        stride: *stride,
                        pad: *pad,
                        out_c: w.shape[0],
                    },
                    out_shape[1] * out_shape[2],
                )
            }
            Op::Linear { w_cols, bias, w_params } => {
                (w_cols.clone(), bias.clone(), *w_params, LayerKind::Linear, 1)
            }
            _ => continue,
        };

        let q = node.inputs[0];
        let qparams = match &graph.nodes[q].op {
            Op::Quantize { params } => params.unwrap_or_else(|| {
                QuantParams::unsigned(cal.act_max(q), cfg.mac.act_bits)
            }),
            other => {
                return Err(CompileError::Structure(format!(
                    "`{}` must consume a Quantize node, found {}",
                    node.name,
                    other.kind()
                )));
            }
        };

        // Calibrated weights fuse dequant+bias into the layer (its activation
        // params are the quantize boundary's). Explicit weight params run the
        // layer at unit scales — the plane is quantized with the caller's
        // params first, then loaded with scale-1 params on both sides, so the
        // layer emits raw integer sums and the graph's Dequantize applies ALL
        // scaling exactly once — bit-identical to `MlpDeployment::run_native`.
        let lin = match w_params {
            None => {
                let wp = QuantParams::signed(w_cols.max_abs(), cfg.mac.weight_bits);
                CimLinear::with_params(&w_cols, bias, wp, qparams, cfg)
            }
            Some(wp) => {
                let w_q = Tensor::from_vec(
                    &w_cols.shape,
                    w_cols.data.iter().map(|&v| wp.quantize(v) as f32).collect(),
                );
                let unit_w = QuantParams { scale: 1.0, q_min: wp.q_min, q_max: wp.q_max };
                let unit_a =
                    QuantParams { scale: 1.0, q_min: qparams.q_min, q_max: qparams.q_max };
                CimLinear::with_params(&w_q, bias, unit_w, unit_a, cfg)
            }
        };

        layers.push(LoweredLayer {
            node: id,
            src: graph.nodes[q].inputs[0],
            name: node.name.clone(),
            kind,
            qparams,
            lin,
            vectors_per_input: vectors,
        });
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Mlp;
    use crate::nn::resnet::ResNet20;

    #[test]
    fn mlp_lowers_to_one_layer_per_linear() {
        let mlp = Mlp::new(&[20, 10, 4], 2);
        let g = Graph::from_mlp(&mlp);
        let shapes = g.infer_shapes().unwrap();
        let cal_x: Vec<Tensor> =
            (0..3).map(|i| Tensor::from_vec(&[20], vec![0.2 * (i + 1) as f32; 20])).collect();
        let cal = calibrate(&g, &cal_x).unwrap();
        let cfg = Config::default();
        let layers = lower(&g, &shapes, &cal, &cfg).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].lin.k, 20);
        assert_eq!(layers[0].lin.n, 10);
        assert!(matches!(layers[0].kind, LayerKind::Linear));
        // Hidden quantize calibrated from data: scale = max/15.
        let hidden_max = cal.act_max(g.nodes[layers[1].node].inputs[0]);
        assert!((layers[1].qparams.scale - hidden_max / 15.0).abs() < 1e-9);
    }

    #[test]
    fn resnet_lowering_counts_tiles() {
        let net = ResNet20::new(1);
        let g = Graph::from_resnet20(&net);
        let shapes = g.infer_shapes().unwrap();
        let cal_x = vec![crate::nn::dataset::random_image(&[3, 32, 32], 4)];
        let cal = calibrate(&g, &cal_x).unwrap();
        let cfg = Config::default();
        let layers = lower(&g, &shapes, &cal, &cfg).unwrap();
        assert_eq!(layers.len(), 22); // 21 convs + fc
        let tiles: usize =
            layers.iter().map(|l| l.lin.n_row_tiles() * l.lin.n_col_tiles()).sum();
        // Hand-counted for the default 64-row × 16-engine macro geometry.
        assert_eq!(tiles, 282);
        // Stem: K = 3·3·3 = 27, N = 16 → one tile; conv vectors = 32×32.
        let stem = layers.iter().find(|l| l.name == "stem").unwrap();
        assert_eq!(stem.lin.k, 27);
        assert_eq!(stem.vectors_per_input, 1024);
    }

    #[test]
    fn missing_quantize_is_a_structure_error() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![8] }, &[]);
        g.add(
            "fc",
            Op::Linear {
                w_cols: Tensor::zeros(&[8, 4]),
                bias: vec![0.0; 4],
                w_params: None,
            },
            &[x],
        );
        let shapes = g.infer_shapes().unwrap();
        let cal = Calibration { act_max: vec![0.0; g.nodes.len()] };
        assert!(matches!(
            lower(&g, &shapes, &cal, &Config::default()),
            Err(CompileError::Structure(_))
        ));
    }

    #[test]
    fn calibration_requires_data_only_when_needed() {
        let mlp = Mlp::new(&[6, 4, 2], 7);
        let g = Graph::from_mlp(&mlp);
        assert!(matches!(calibrate(&g, &[]), Err(CompileError::Structure(_))));
        let cal: Vec<Vec<f32>> = (0..3).map(|_| vec![0.5; 6]).collect();
        let dep = crate::coordinator::deployment::MlpDeployment::quantize(&mlp, &cal, 1.0);
        let gd = Graph::from_deployment(&dep);
        assert!(calibrate(&gd, &[]).is_ok());
    }
}
