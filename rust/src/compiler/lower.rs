//! Lowering: graph IR → tiled macro layers.
//!
//! Each `Conv2d`/`Linear` node (with its mandatory `Quantize` input) lowers
//! to a [`CimLinear`] — conv weights via the shared im2col lowering
//! (`nn::im2col::weights_to_cols`), linear weights directly — with
//! per-layer activation-range calibration: [`calibrate`] runs the float
//! graph over a calibration set and records each quantize boundary's
//! maximum activation, exactly the deployment recipe `CimConv::new` uses.

use crate::compiler::ir::{Graph, NodeId, Op};
use crate::config::Config;
use crate::mapping::executor::CimLinear;
use crate::nn::im2col::weights_to_cols;
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;

/// Compilation failure.
#[derive(Debug)]
pub enum CompileError {
    /// The graph violates a structural rule (missing quantize, bad shapes…).
    Structure(String),
    /// The pool rejected a placement or load.
    Macro(crate::cim::MacroError),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Structure(m) => write!(f, "compile error: {m}"),
            CompileError::Macro(e) => write!(f, "compile error: {e}"),
        }
    }
}

impl std::error::Error for CompileError {}

impl From<crate::cim::MacroError> for CompileError {
    fn from(e: crate::cim::MacroError) -> Self {
        CompileError::Macro(e)
    }
}

/// Per-node activation calibration: the value range seen at each
/// data-calibrated `Quantize` boundary over the calibration set. Boundaries
/// that ever go negative (transformer residual streams, Q/K projections)
/// lower to the signed-activation format ([`QuantParams::signed_acts`],
/// DESIGN.md §10); non-negative ones keep the paper's unsigned post-ReLU
/// format.
#[derive(Clone, Debug)]
pub struct Calibration {
    act_max: Vec<f32>,
    act_min: Vec<f32>,
}

impl Calibration {
    /// The calibrated activation max of a quantize node (≥ a small floor so
    /// scales never divide by zero).
    pub fn act_max(&self, node: NodeId) -> f32 {
        self.act_max[node].max(1e-6)
    }

    /// The calibrated activation minimum (≤ 0; exactly 0 for post-ReLU
    /// boundaries).
    pub fn act_min(&self, node: NodeId) -> f32 {
        self.act_min[node].min(0.0)
    }

    /// The quantization params this boundary calibrates to.
    pub fn params(&self, node: NodeId, act_bits: u32) -> QuantParams {
        if self.act_min(node) < 0.0 {
            let max_abs = self.act_max(node).max(-self.act_min(node));
            QuantParams::signed_acts(max_abs, act_bits)
        } else {
            QuantParams::unsigned(self.act_max(node), act_bits)
        }
    }
}

/// Run the float graph over `inputs` and record each `Quantize(None)`
/// node's input range. Graphs whose quantize params are all explicit
/// (e.g. [`Graph::from_deployment`]) calibrate fine on an empty set.
pub fn calibrate(graph: &Graph, inputs: &[Tensor]) -> Result<Calibration, CompileError> {
    let needs_data = graph
        .nodes
        .iter()
        .any(|n| matches!(n.op, Op::Quantize { params: None }));
    if needs_data && inputs.is_empty() {
        return Err(CompileError::Structure(
            "graph has data-calibrated Quantize nodes but no calibration inputs".into(),
        ));
    }
    let mut act_max = vec![0f32; graph.nodes.len()];
    let mut act_min = vec![0f32; graph.nodes.len()];
    for x in inputs {
        let vals = graph.eval_float(x).map_err(CompileError::Structure)?;
        for (id, node) in graph.nodes.iter().enumerate() {
            if let Op::Quantize { params: None } = node.op {
                let src = node.inputs[0];
                for &v in &vals[src].data {
                    if v > act_max[id] {
                        act_max[id] = v;
                    }
                    if v < act_min[id] {
                        act_min[id] = v;
                    }
                }
            }
        }
    }
    Ok(Calibration { act_max, act_min })
}

/// What a lowered cim layer computes around its matmul.
#[derive(Clone, Copy, Debug)]
pub enum LayerKind {
    /// im2col convolution: per-position rows through the tiled linear, back
    /// to CHW.
    Conv { kh: usize, kw: usize, stride: usize, pad: usize, out_c: usize },
    /// One activation vector per batch item (`[K] → [N]`).
    Linear,
    /// Row-wise linear over a `[S][K]` value → `[S][N]` (the transformer
    /// token dimension; `seq` is static from shape inference).
    Rowwise { seq: usize },
    /// Dynamic-weight act×act product (DESIGN.md §10): the right operand is
    /// re-quantized and reloaded into the placed tiles once per item before
    /// that item's `seq` rows stream.
    MatMul { seq: usize, transpose_b: bool },
}

impl LayerKind {
    /// Whether the layer's weights are runtime tensors (reload per call).
    pub fn is_dynamic(&self) -> bool {
        matches!(self, LayerKind::MatMul { .. })
    }

    /// Short shape label for telemetry series (`kind` label, DESIGN.md
    /// §12).
    pub fn label(&self) -> &'static str {
        match self {
            LayerKind::Conv { .. } => "conv",
            LayerKind::Linear => "linear",
            LayerKind::Rowwise { .. } => "rowwise",
            LayerKind::MatMul { .. } => "matmul",
        }
    }
}

/// A `Conv2d`/`Linear`/`MatMul` node lowered to a tiled macro layer, not
/// yet placed.
#[derive(Clone, Debug)]
pub struct LoweredLayer {
    /// The compute node this lowers.
    pub node: NodeId,
    /// The node whose value feeds the layer (the quantize node's input —
    /// quantization happens inside the layer step).
    pub src: NodeId,
    /// The runtime-weight operand node (dynamic `MatMul` layers only).
    pub b_src: Option<NodeId>,
    pub name: String,
    pub kind: LayerKind,
    /// Activation quantization applied to the layer's input rows.
    pub qparams: QuantParams,
    /// The tiled integer layer (weights quantized, dequant policy per
    /// `w_params`: fused when calibrated, unit when explicit). For dynamic
    /// layers this is the zero staging grid — shape only, values swapped
    /// per call.
    pub lin: CimLinear,
    /// Activation vectors one network input generates (conv: `oh·ow`;
    /// row-wise linear and matmul: `seq`).
    pub vectors_per_input: usize,
}

/// Lower every compute node of the graph. `shapes` comes from
/// [`Graph::infer_shapes`]; `cal` from [`calibrate`].
pub fn lower(
    graph: &Graph,
    shapes: &[Vec<usize>],
    cal: &Calibration,
    cfg: &Config,
) -> Result<Vec<LoweredLayer>, CompileError> {
    let mut layers = Vec::new();
    for (id, node) in graph.nodes.iter().enumerate() {
        let (w_cols, bias, w_params, kind, vectors, b_src) = match &node.op {
            Op::Conv2d { w, bias, stride, pad, w_params } => {
                let out_shape = &shapes[id];
                (
                    weights_to_cols(w),
                    bias.clone(),
                    *w_params,
                    LayerKind::Conv {
                        kh: w.shape[2],
                        kw: w.shape[3],
                        stride: *stride,
                        pad: *pad,
                        out_c: w.shape[0],
                    },
                    out_shape[1] * out_shape[2],
                    None,
                )
            }
            Op::Linear { w_cols, bias, w_params } => {
                // The quantize boundary's shape equals its input's.
                let in_shape = &shapes[node.inputs[0]];
                let (kind, vectors) = if in_shape.len() == 2 {
                    (LayerKind::Rowwise { seq: in_shape[0] }, in_shape[0])
                } else {
                    (LayerKind::Linear, 1)
                };
                (w_cols.clone(), bias.clone(), *w_params, kind, vectors, None)
            }
            Op::MatMul { transpose_b } => {
                let b = node.inputs[1];
                if matches!(graph.nodes[b].op, Op::Quantize { .. }) {
                    return Err(CompileError::Structure(format!(
                        "`{}`: the matmul weight operand is re-quantized per call and \
                         must not consume a Quantize node",
                        node.name
                    )));
                }
                let out_shape = &shapes[id];
                let (seq, n) = (out_shape[0], out_shape[1]);
                let k = shapes[b][if *transpose_b { 1 } else { 0 }];
                // Zero staging grid: shape fixes the tile geometry; values
                // (and the per-call weight scale) swap at run time.
                (
                    Tensor::zeros(&[k, n]),
                    vec![0.0; n],
                    None,
                    LayerKind::MatMul { seq, transpose_b: *transpose_b },
                    seq,
                    Some(b),
                )
            }
            _ => continue,
        };

        let q = node.inputs[0];
        let qparams = match &graph.nodes[q].op {
            Op::Quantize { params } => {
                params.unwrap_or_else(|| cal.params(q, cfg.mac.act_bits))
            }
            other => {
                return Err(CompileError::Structure(format!(
                    "`{}` must consume a Quantize node, found {}",
                    node.name,
                    other.kind()
                )));
            }
        };

        // Calibrated weights fuse dequant+bias into the layer (its activation
        // params are the quantize boundary's). Explicit weight params run the
        // layer at unit scales — the plane is quantized with the caller's
        // params first, then loaded with scale-1 params on both sides, so the
        // layer emits raw integer sums and the graph's Dequantize applies ALL
        // scaling exactly once — bit-identical to `MlpDeployment::run_native`.
        let lin = match w_params {
            None => {
                let wp = QuantParams::signed(w_cols.max_abs(), cfg.mac.weight_bits);
                CimLinear::with_params(&w_cols, bias, wp, qparams, cfg)
            }
            Some(wp) => {
                let w_q = Tensor::from_vec(
                    &w_cols.shape,
                    w_cols.data.iter().map(|&v| wp.quantize(v) as f32).collect(),
                );
                let unit_w = QuantParams { scale: 1.0, q_min: wp.q_min, q_max: wp.q_max };
                let unit_a =
                    QuantParams { scale: 1.0, q_min: qparams.q_min, q_max: qparams.q_max };
                CimLinear::with_params(&w_q, bias, unit_w, unit_a, cfg)
            }
        };

        layers.push(LoweredLayer {
            node: id,
            src: graph.nodes[q].inputs[0],
            b_src,
            name: node.name.clone(),
            kind,
            qparams,
            lin,
            vectors_per_input: vectors,
        });
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::mlp::Mlp;
    use crate::nn::resnet::ResNet20;

    #[test]
    fn mlp_lowers_to_one_layer_per_linear() {
        let mlp = Mlp::new(&[20, 10, 4], 2);
        let g = Graph::from_mlp(&mlp);
        let shapes = g.infer_shapes().unwrap();
        let cal_x: Vec<Tensor> =
            (0..3).map(|i| Tensor::from_vec(&[20], vec![0.2 * (i + 1) as f32; 20])).collect();
        let cal = calibrate(&g, &cal_x).unwrap();
        let cfg = Config::default();
        let layers = lower(&g, &shapes, &cal, &cfg).unwrap();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].lin.k, 20);
        assert_eq!(layers[0].lin.n, 10);
        assert!(matches!(layers[0].kind, LayerKind::Linear));
        // Hidden quantize calibrated from data: scale = max/15.
        let hidden_max = cal.act_max(g.nodes[layers[1].node].inputs[0]);
        assert!((layers[1].qparams.scale - hidden_max / 15.0).abs() < 1e-9);
    }

    #[test]
    fn resnet_lowering_counts_tiles() {
        let net = ResNet20::new(1);
        let g = Graph::from_resnet20(&net);
        let shapes = g.infer_shapes().unwrap();
        let cal_x = vec![crate::nn::dataset::random_image(&[3, 32, 32], 4)];
        let cal = calibrate(&g, &cal_x).unwrap();
        let cfg = Config::default();
        let layers = lower(&g, &shapes, &cal, &cfg).unwrap();
        assert_eq!(layers.len(), 22); // 21 convs + fc
        let tiles: usize =
            layers.iter().map(|l| l.lin.n_row_tiles() * l.lin.n_col_tiles()).sum();
        // Hand-counted for the default 64-row × 16-engine macro geometry.
        assert_eq!(tiles, 282);
        // Stem: K = 3·3·3 = 27, N = 16 → one tile; conv vectors = 32×32.
        let stem = layers.iter().find(|l| l.name == "stem").unwrap();
        assert_eq!(stem.lin.k, 27);
        assert_eq!(stem.vectors_per_input, 1024);
    }

    #[test]
    fn missing_quantize_is_a_structure_error() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![8] }, &[]);
        g.add(
            "fc",
            Op::Linear {
                w_cols: Tensor::zeros(&[8, 4]),
                bias: vec![0.0; 4],
                w_params: None,
            },
            &[x],
        );
        let shapes = g.infer_shapes().unwrap();
        let n = g.nodes.len();
        let cal = Calibration { act_max: vec![0.0; n], act_min: vec![0.0; n] };
        assert!(matches!(
            lower(&g, &shapes, &cal, &Config::default()),
            Err(CompileError::Structure(_))
        ));
    }

    /// Transformer lowering: per-head weight-stationary projections plus
    /// two dynamic `MatMul` layers per head; signed boundaries (the
    /// residual stream, Q values) calibrate to the signed-acts format while
    /// softmax probabilities stay unsigned.
    #[test]
    fn transformer_lowering_kinds_and_signed_boundaries() {
        use crate::nn::transformer::TransformerBlock;
        use crate::util::rng::{Rng, Xoshiro256};
        let block = TransformerBlock::new(16, 2, 24, 3);
        let seq = 4;
        let g = Graph::from_transformer_block(&block, seq);
        let shapes = g.infer_shapes().unwrap();
        let mut rng = Xoshiro256::seeded(2);
        let cal_x: Vec<Tensor> = (0..3)
            .map(|_| {
                Tensor::from_vec(
                    &[seq, 16],
                    (0..seq * 16).map(|_| rng.next_f32() - 0.5).collect(),
                )
            })
            .collect();
        let cal = calibrate(&g, &cal_x).unwrap();
        let cfg = Config::default();
        let layers = lower(&g, &shapes, &cal, &cfg).unwrap();
        // Per head: q/k/v/out projections + 2 matmuls; plus ffn1/ffn2.
        assert_eq!(layers.len(), 2 * 6 + 2);
        let dynamic: Vec<_> = layers.iter().filter(|l| l.kind.is_dynamic()).collect();
        assert_eq!(dynamic.len(), 4);
        for l in &dynamic {
            assert!(l.b_src.is_some());
            assert_eq!(l.vectors_per_input, seq);
        }
        // Q·Kᵀ staging grid is [d_head][seq]; attn·V is [seq][d_head].
        let score = layers.iter().find(|l| l.name == "h0.score").unwrap();
        assert!(matches!(score.kind, LayerKind::MatMul { seq: 4, transpose_b: true }));
        assert_eq!((score.lin.k, score.lin.n), (8, seq));
        let ctx = layers.iter().find(|l| l.name == "h0.ctx").unwrap();
        assert!(matches!(ctx.kind, LayerKind::MatMul { seq: 4, transpose_b: false }));
        assert_eq!((ctx.lin.k, ctx.lin.n), (seq, 8));
        // The residual-stream boundary sees negatives → signed acts
        // (q_min = −8); softmax probabilities stay unsigned (q_min = 0).
        let proj = layers.iter().find(|l| l.name == "h0.q").unwrap();
        assert_eq!(proj.qparams.q_min, -8);
        assert!(matches!(proj.kind, LayerKind::Rowwise { seq: 4 }));
        assert_eq!(ctx.qparams.q_min, 0);
        // Weight operand behind a Quantize is rejected.
        let mut bad = Graph::new();
        let x = bad.add("input", Op::Input { shape: vec![2, 4] }, &[]);
        let qa = bad.add("qa", Op::Quantize { params: None }, &[x]);
        let qb = bad.add("qb", Op::Quantize { params: None }, &[x]);
        bad.add("mm", Op::MatMul { transpose_b: true }, &[qa, qb]);
        let shapes = bad.infer_shapes().unwrap();
        assert!(matches!(
            lower(&bad, &shapes, &cal_tiny(&bad), &cfg),
            Err(CompileError::Structure(_))
        ));
    }

    fn cal_tiny(g: &Graph) -> Calibration {
        let n = g.nodes.len();
        Calibration { act_max: vec![1.0; n], act_min: vec![-1.0; n] }
    }

    #[test]
    fn calibration_requires_data_only_when_needed() {
        let mlp = Mlp::new(&[6, 4, 2], 7);
        let g = Graph::from_mlp(&mlp);
        assert!(matches!(calibrate(&g, &[]), Err(CompileError::Structure(_))));
        let cal: Vec<Vec<f32>> = (0..3).map(|_| vec![0.5; 6]).collect();
        let dep = crate::coordinator::deployment::MlpDeployment::quantize(&mlp, &cal, 1.0);
        let gd = Graph::from_deployment(&dep);
        assert!(calibrate(&gd, &[]).is_ok());
    }
}
