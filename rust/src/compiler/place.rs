//! Place & schedule: the cost-model-driven placer and the per-layer /
//! whole-network cost report.
//!
//! Cost model:
//! * **Cycles** — built on [`crate::cim::timing::op_cycles`]. The device's
//!   MAC window is scheduled from the *programmed* (nominal) DTC widths, so
//!   per-op cycles are an exact function of the quantized activation tile:
//!   [`predicted_tile_cycles`] reproduces the observed `OpStats` cycle sum
//!   exactly, noise on or off (asserted by `tests/compiler_equivalence.rs`).
//!   The placement-time static estimate uses the worst-case activation
//!   magnitude, an upper bound that is tight for dense workloads.
//! * **Energy** — built on [`crate::energy::core_op_energy`] over an
//!   estimated activity [`OpStats`]: exact terms where the model is exact
//!   (SA comparisons, cycle-driven control energy) and an
//!   [`ActivationProfile`]-driven estimate for the data-dependent charge
//!   terms (DTC pulses, array discharge — using each tile's actual Σ|w|).
//!
//! The placer packs tiles one at a time onto the shard with the lowest
//! accumulated estimated cycles that still has a free core, growing the
//! pool a shard at a time when none has — so layers reuse partially-filled
//! shards and a board of dies ends up load-balanced.

use crate::cim::engine::OpStats;
use crate::cim::timing::{self, op_cycles_for_acts, weight_load_cycles};
use crate::config::HwSpec;
use crate::energy::{core_op_energy, weight_load_energy};
use crate::mapping::executor::CimLinear;
use crate::pipeline::dynamic::DynamicLinear;
use crate::pipeline::pool::{MacroPool, PlacedLinear};
use crate::util::table::Table;

/// Assumed activation statistics for the data-dependent energy terms.
#[derive(Clone, Copy, Debug)]
pub struct ActivationProfile {
    /// Fraction of rows with a non-zero activation.
    pub density: f64,
    /// Mean magnitude of the non-zero activations (pre-folding, in codes).
    pub mean_mag: f64,
}

impl ActivationProfile {
    /// Dense random 4-b inputs (the paper's dense measurement condition).
    pub fn dense(cfg: &HwSpec) -> Self {
        Self { density: 1.0, mean_mag: cfg.mac.act_max() as f64 / 2.0 }
    }

    /// Post-ReLU-like inputs: half the rows zero, small magnitudes — the
    /// Fig. 5 sparsity operating point and the default for NN layers.
    pub fn relu_like(cfg: &HwSpec) -> Self {
        Self { density: 0.5, mean_mag: cfg.mac.act_max() as f64 / 4.0 }
    }
}

/// Worst-case effective activation magnitude after folding — what the
/// static cycle estimate schedules for.
fn worst_eff_mag(cfg: &HwSpec) -> i64 {
    if cfg.enhance.fold {
        cfg.enhance.fold_offset.max(cfg.mac.act_max() - cfg.enhance.fold_offset)
    } else {
        cfg.mac.act_max()
    }
}

/// Worst-case nominal pulse width in τ0 (largest effective magnitude on the
/// top weight-bit source line).
fn worst_width_tau0(cfg: &HwSpec) -> f64 {
    let kbits = (cfg.mac.weight_bits as usize).saturating_sub(1);
    if kbits == 0 {
        return 0.0;
    }
    worst_eff_mag(cfg) as f64 * (1u64 << (kbits - 1)) as f64 * cfg.enhance.dtc_scale()
}

/// Worst-case ADC clipping penalty in bits: how far the largest folded,
/// DTC-scaled MAC signal overshoots the conversion full scale —
/// `log2(rows · worst_eff_mag · w_mag_max · s / VPP)`, clamped at 0 when
/// the signal fits. This is the accuracy-proxy ingredient of the explore
/// harness (DESIGN.md §15): enhancement gains signal margin for typical
/// sparse outputs by letting the worst-case output clip.
pub fn worst_clip_penalty_bits(cfg: &HwSpec) -> f64 {
    let worst = (cfg.mac.rows as i64 * worst_eff_mag(cfg) * cfg.mac.w_mag_max()) as f64;
    let ratio = worst * cfg.enhance.dtc_scale() / cfg.mac.vpp_units();
    ratio.log2().max(0.0)
}

/// Static worst-case cycle count of one core op (upper bound; exact when
/// every tile has at least one worst-case-magnitude activation).
pub fn static_op_cycles(cfg: &HwSpec) -> u64 {
    timing::op_cycles(cfg, crate::cim::engine::mac_cycles(cfg, worst_width_tau0(cfg)))
}

/// Estimated activity counters of one core op on a tile whose weights sum
/// to `sum_abs_w` (Σ|w| over the rows×engines block), under `profile`.
pub fn estimated_op_stats(cfg: &HwSpec, profile: &ActivationProfile, sum_abs_w: f64) -> OpStats {
    let mac = &cfg.mac;
    let kbits = (mac.weight_bits as usize).saturating_sub(1);
    let s = cfg.enhance.dtc_scale();
    // With folding every row pulses (a zero activation folds to −offset);
    // `mag` is then the mean effective magnitude over all rows.
    let (active_frac, mag) = if cfg.enhance.fold {
        let off = cfg.enhance.fold_offset as f64;
        (1.0, profile.density * (profile.mean_mag - off).abs() + (1.0 - profile.density) * off)
    } else {
        (profile.density, profile.mean_mag)
    };
    let active_rows = active_frac * mac.rows as f64;
    let weight_levels = ((1u64 << kbits) - 1) as f64;

    let mut st = OpStats {
        dtc_pulses: (active_rows * kbits as f64).round() as usize,
        dtc_tau_sum: active_rows * mag * weight_levels * s,
        sl_toggles: 2 * (active_rows * kbits as f64).round() as usize,
        // E[Σ_r mag_r·|w_re|] over engines ≈ mean-eff-mag · Σ|w| (headroom
        // clamp ignored — an over-estimate for saturating workloads).
        mac_discharge_u: active_frac * mag * s * sum_abs_w,
        // Binary-search readout discharges ≈ half the differential full
        // scale per engine (each step halves the remaining range).
        adc_discharge_u: mac.engines as f64 * mac.adc_fullscale_units() / 2.0,
        sa_compares: mac.engines * mac.adc_bits as usize,
        max_width_tau0: worst_width_tau0(cfg),
        ..OpStats::default()
    };
    st.mac_cycles = crate::cim::engine::mac_cycles(cfg, st.max_width_tau0);
    st.total_cycles = timing::op_cycles(cfg, st.mac_cycles);
    st
}

/// Exact cycle cost of running quantized activation vectors through a tiled
/// layer: for every vector and row tile, the padded tile's op cycles times
/// the column-tile count. This is the number the device will report.
pub fn predicted_tile_cycles(cfg: &HwSpec, lin: &CimLinear, acts_q: &[Vec<i64>]) -> u64 {
    let rows = lin.rows_per_tile();
    let (n_rt, n_ct) = (lin.n_row_tiles(), lin.n_col_tiles());
    let mut tile = vec![0i64; rows];
    let mut total = 0u64;
    for acts in acts_q {
        debug_assert_eq!(acts.len(), lin.k);
        for rt in 0..n_rt {
            let r0 = rt * rows;
            let upper = (r0 + rows).min(lin.k);
            tile.fill(0);
            tile[..upper - r0].copy_from_slice(&acts[r0..upper]);
            total += n_ct as u64 * op_cycles_for_acts(cfg, &tile);
        }
    }
    total
}

/// Static per-layer cost estimate, produced at placement time.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerCost {
    pub name: String,
    pub kind: &'static str,
    pub k: usize,
    pub n: usize,
    pub n_rt: usize,
    pub n_ct: usize,
    /// Activation vectors one network input streams through the layer.
    pub vectors_per_input: usize,
    /// Worst-case *compute* device cycles per network input (serial-device
    /// total, MAC + readout only).
    pub est_cycles_per_input: u64,
    /// Profile-estimated compute energy per network input, fJ.
    pub est_energy_fj_per_input: f64,
    /// Weight-reload cycles per network input (dynamic layers swap their
    /// whole tile grid once per item; 0 for weight-stationary layers) —
    /// the reload-vs-compute breakout of DESIGN.md §10.
    pub est_reload_cycles_per_input: u64,
    /// Weight-reload (SRAM write) energy per network input, fJ.
    pub est_reload_energy_fj_per_input: f64,
    /// Dynamic-weight layer (per-call reload on dedicated shards).
    pub dynamic: bool,
    /// Distinct shards this layer's tiles landed on.
    pub shards_used: usize,
}

impl LayerCost {
    pub fn tiles(&self) -> usize {
        self.n_rt * self.n_ct
    }
}

/// Whole-network placement + cost summary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostReport {
    pub layers: Vec<LayerCost>,
    pub total_tiles: usize,
    /// Shards of the shared weight-stationary pool.
    pub n_shards: usize,
    /// Dedicated shards owned by dynamic-weight layers (DESIGN.md §10).
    pub n_dynamic_shards: usize,
    /// Weight SRAM held resident, Kb.
    pub weight_kb: f64,
}

impl CostReport {
    /// Compute (MAC + readout) cycles per input, reload excluded.
    pub fn total_est_cycles_per_input(&self) -> u64 {
        self.layers.iter().map(|l| l.est_cycles_per_input).sum()
    }

    /// Weight-reload cycles per input — the dynamic-weight tax.
    pub fn total_est_reload_cycles_per_input(&self) -> u64 {
        self.layers.iter().map(|l| l.est_reload_cycles_per_input).sum()
    }

    /// Total estimated energy per input, **reload (SRAM write) energy
    /// included** — unlike [`CostReport::total_est_cycles_per_input`],
    /// which stays compute-only and pairs with
    /// [`CostReport::total_est_reload_cycles_per_input`]. Energy has no
    /// such split accessor because every consumer (tables, benches) wants
    /// the all-in figure; derive time from compute + reload cycles when
    /// forming efficiency ratios.
    pub fn total_est_energy_fj_per_input(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.est_energy_fj_per_input + l.est_reload_energy_fj_per_input)
            .sum()
    }

    /// Fraction of estimated device cycles spent reloading weights —
    /// reload-bound vs compute-bound in one number.
    pub fn reload_cycle_fraction(&self) -> f64 {
        let reload = self.total_est_reload_cycles_per_input() as f64;
        let total = reload + self.total_est_cycles_per_input() as f64;
        if total == 0.0 {
            0.0
        } else {
            reload / total
        }
    }

    /// Render the per-layer breakdown (+ totals row) as a table; device
    /// time from the configured clock. Reload cycles (dynamic-weight
    /// layers) are broken out from compute cycles.
    pub fn table(&self, cfg: &HwSpec) -> Table {
        let ms = |cycles: u64| cycles as f64 / (cfg.mac.clock_mhz * 1e6) * 1e3;
        let mut t = Table::new(
            &format!(
                "compiled plan: {} layers, {} tiles on {} shards (+{} dedicated dynamic) \
                 ({:.0} Kb resident)",
                self.layers.len(),
                self.total_tiles,
                self.n_shards,
                self.n_dynamic_shards,
                self.weight_kb
            ),
            &[
                "layer", "kind", "KxN", "tiles", "shards", "vec/in", "est kcyc/in",
                "rld kcyc/in", "est ms/in", "est uJ/in",
            ],
        );
        for l in &self.layers {
            t.row(&[
                l.name.clone(),
                l.kind.to_string(),
                format!("{}x{}", l.k, l.n),
                l.tiles().to_string(),
                l.shards_used.to_string(),
                l.vectors_per_input.to_string(),
                format!("{:.1}", l.est_cycles_per_input as f64 / 1e3),
                format!("{:.1}", l.est_reload_cycles_per_input as f64 / 1e3),
                format!("{:.3}", ms(l.est_cycles_per_input + l.est_reload_cycles_per_input)),
                format!(
                    "{:.3}",
                    (l.est_energy_fj_per_input + l.est_reload_energy_fj_per_input) * 1e-9
                ),
            ]);
        }
        let total_cycles = self.total_est_cycles_per_input();
        let total_reload = self.total_est_reload_cycles_per_input();
        t.row(&[
            "TOTAL".into(),
            "-".into(),
            "-".into(),
            self.total_tiles.to_string(),
            self.n_shards.to_string(),
            "-".into(),
            format!("{:.1}", total_cycles as f64 / 1e3),
            format!("{:.1}", total_reload as f64 / 1e3),
            format!("{:.3}", ms(total_cycles + total_reload)),
            format!("{:.3}", self.total_est_energy_fj_per_input() * 1e-9),
        ]);
        t
    }
}

/// Core-slot accounting the placer packs against. [`MacroPool`] implements
/// it by building real `MacroSim` shards; [`VirtualPool`] implements the
/// identical allocation arithmetic with bare counters, so the explore
/// harness (DESIGN.md §15) can run the exact placement/cost code path for
/// thousands of candidate `HwSpec`s without instantiating simulators.
pub trait SlotHost {
    fn n_shards(&self) -> usize;
    /// Free (unclaimed) cores on a resident shard (0 for absent shards).
    fn free_cores_on(&self, shard: usize) -> usize;
    /// Grow to at least `n_shards` shards.
    fn grow_to(&mut self, n_shards: usize);
    /// Claim the first free core on a resident shard (`None` when absent
    /// or full).
    fn alloc_slot_on_shard(&mut self, shard: usize) -> Option<usize>;
}

impl SlotHost for MacroPool {
    fn n_shards(&self) -> usize {
        MacroPool::n_shards(self)
    }

    fn free_cores_on(&self, shard: usize) -> usize {
        MacroPool::free_cores_on(self, shard)
    }

    fn grow_to(&mut self, n_shards: usize) {
        MacroPool::grow_to(self, n_shards)
    }

    fn alloc_slot_on_shard(&mut self, shard: usize) -> Option<usize> {
        MacroPool::alloc_slot_on_shard(self, shard)
    }
}

/// Counters-only slot host: the allocation state of a [`MacroPool`] (shard
/// count, per-shard claimed cores, dense slot numbering) without the
/// simulator shards behind it. Placing a network on a `VirtualPool` visits
/// the same shard choices — and therefore produces the same [`LayerCost`]s
/// and [`CostReport`] — as placing it on a real pool of the same geometry.
#[derive(Clone, Debug)]
pub struct VirtualPool {
    cores: usize,
    used: Vec<usize>,
}

impl VirtualPool {
    /// An empty virtual board with `cores` slots per shard.
    pub fn new(cores: usize) -> Self {
        Self { cores: cores.max(1), used: Vec::new() }
    }

    /// Slots claimed so far.
    pub fn slots_loaded(&self) -> usize {
        self.used.iter().sum()
    }
}

impl SlotHost for VirtualPool {
    fn n_shards(&self) -> usize {
        self.used.len()
    }

    fn free_cores_on(&self, shard: usize) -> usize {
        self.used.get(shard).map_or(0, |&u| self.cores - u)
    }

    fn grow_to(&mut self, n_shards: usize) {
        if self.used.len() < n_shards {
            self.used.resize(n_shards, 0);
        }
    }

    fn alloc_slot_on_shard(&mut self, shard: usize) -> Option<usize> {
        let cores = self.cores;
        let u = self.used.get_mut(shard)?;
        if *u >= cores {
            return None;
        }
        let slot = shard * cores + *u;
        *u += 1;
        Some(slot)
    }
}

/// Shards a dedicated dynamic-weight mini-pool allocates for `tiles` tiles:
/// [`crate::pipeline::pool::PlacedLinear::place`] claims slots densely on a
/// fresh pool, growing one shard per `cores` tiles.
pub fn dynamic_pool_shards(cfg: &HwSpec, tiles: usize) -> usize {
    tiles.div_ceil(cfg.mac.cores.max(1))
}

/// The cost-model-driven placer: packs each tile onto the least-loaded
/// shard (by accumulated estimated cycles) with a free core, growing the
/// pool when every resident shard is full. `compile` pre-sizes the pool to
/// the network's exact shard count, so the least-loaded choice has every
/// die as a candidate and heavy layers' tiles spread across shards instead
/// of dense-filling one die at a time.
pub struct Placer {
    profile: ActivationProfile,
    shard_load: Vec<f64>,
}

impl Placer {
    pub fn new(profile: ActivationProfile) -> Self {
        Self { profile, shard_load: Vec::new() }
    }

    /// Pack one lowered layer's tiles onto `host` and return the chosen
    /// slots (in `(rt, ct)` order) plus the static cost estimate. This is
    /// the whole placement decision — [`Placer::place_layer`] adds only the
    /// weight loading, so a [`VirtualPool`] host reproduces a real pool's
    /// placement and costs exactly.
    pub fn plan_layer<H: SlotHost>(
        &mut self,
        host: &mut H,
        cfg: &HwSpec,
        lin: &CimLinear,
        name: &str,
        kind: &'static str,
        vectors_per_input: usize,
    ) -> (Vec<usize>, LayerCost) {
        let (n_rt, n_ct) = (lin.n_row_tiles(), lin.n_col_tiles());
        let op_cycles = static_op_cycles(cfg);
        let tile_cost = (op_cycles * vectors_per_input as u64) as f64;

        let mut slots = Vec::with_capacity(n_rt * n_ct);
        let mut shards_used = std::collections::BTreeSet::new();
        let mut est_energy_per_vector = 0f64;
        for rt in 0..n_rt {
            for ct in 0..n_ct {
                let sum_abs_w: f64 = lin
                    .tile_block(rt, ct)
                    .iter()
                    .flat_map(|row| row.iter())
                    .map(|&w| w.unsigned_abs() as f64)
                    .sum();
                let st = estimated_op_stats(cfg, &self.profile, sum_abs_w);
                est_energy_per_vector += core_op_energy(cfg, &st).total_fj();

                self.shard_load.resize(host.n_shards().max(self.shard_load.len()), 0.0);
                let mut best: Option<usize> = None;
                for s in 0..host.n_shards() {
                    if host.free_cores_on(s) == 0 {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => self.shard_load[s] < self.shard_load[b],
                    };
                    if better {
                        best = Some(s);
                    }
                }
                let shard = match best {
                    Some(s) => s,
                    None => {
                        let s = host.n_shards();
                        host.grow_to(s + 1);
                        self.shard_load.resize(s + 1, 0.0);
                        s
                    }
                };
                let slot = host
                    .alloc_slot_on_shard(shard)
                    .expect("placer picked a shard with a free core");
                self.shard_load[shard] += tile_cost;
                shards_used.insert(shard);
                slots.push(slot);
            }
        }

        let cost = LayerCost {
            name: name.to_string(),
            kind,
            k: lin.k,
            n: lin.n,
            n_rt,
            n_ct,
            vectors_per_input,
            est_cycles_per_input: vectors_per_input as u64 * n_rt as u64 * n_ct as u64 * op_cycles,
            est_energy_fj_per_input: vectors_per_input as f64 * est_energy_per_vector,
            est_reload_cycles_per_input: 0,
            est_reload_energy_fj_per_input: 0.0,
            dynamic: false,
            shards_used: shards_used.len(),
        };
        (slots, cost)
    }

    /// Place one lowered layer's tiles and return the placed layer plus its
    /// static cost estimate.
    pub fn place_layer(
        &mut self,
        pool: &mut MacroPool,
        lin: CimLinear,
        name: &str,
        kind: &'static str,
        vectors_per_input: usize,
    ) -> Result<(PlacedLinear, LayerCost), crate::cim::MacroError> {
        let cfg = pool.cfg().clone();
        let (slots, cost) = self.plan_layer(pool, &cfg, &lin, name, kind, vectors_per_input);
        let placed = PlacedLinear::place_with(lin, pool, slots)?;
        Ok((placed, cost))
    }

    /// Place a dynamic-weight layer (DESIGN.md §10): its tile grid goes on
    /// **dedicated shards** — a fresh [`DynamicLinear`] mini-pool whose
    /// fabrication draws as dies `fab_base…` — because a per-call reload
    /// must never invalidate a co-resident weight-stationary tile, and
    /// reload-heavy tiles would otherwise distort the shared board's
    /// estimated-cycle balance. Costs: the compute estimate assumes
    /// half-scale weights (the operand is unknown until run time); the
    /// reload estimate charges one full grid swap per input
    /// (`tiles × weight_load_cycles` + the SRAM write energy).
    pub fn place_dynamic_layer(
        &mut self,
        cfg: &crate::config::Config,
        lin: CimLinear,
        name: &str,
        vectors_per_input: usize,
        fab_base: usize,
    ) -> Result<(DynamicLinear, LayerCost), crate::cim::MacroError> {
        let mut cost = self.dynamic_layer_cost(cfg, &lin, name, vectors_per_input);
        let dyn_lin = DynamicLinear::place(lin, cfg, fab_base)?;
        debug_assert_eq!(cost.shards_used, dyn_lin.pool().n_shards());
        cost.shards_used = dyn_lin.pool().n_shards();
        Ok((dyn_lin, cost))
    }

    /// Static cost estimate of a dynamic-weight layer's grid without
    /// placing it — the shared primitive of [`Placer::place_dynamic_layer`]
    /// and the explore harness's virtual scorer. `shards_used` is the
    /// dedicated mini-pool's [`dynamic_pool_shards`] count.
    pub fn dynamic_layer_cost(
        &self,
        cfg: &HwSpec,
        lin: &CimLinear,
        name: &str,
        vectors_per_input: usize,
    ) -> LayerCost {
        let (n_rt, n_ct) = (lin.n_row_tiles(), lin.n_col_tiles());
        let tiles = (n_rt * n_ct) as u64;
        let op_cycles = static_op_cycles(cfg);
        // Unknown runtime weights: assume mean |w| = w_mag_max/2 per cell.
        let sum_abs_w =
            cfg.mac.rows as f64 * cfg.mac.engines as f64 * cfg.mac.w_mag_max() as f64 / 2.0;
        let st = estimated_op_stats(cfg, &self.profile, sum_abs_w);
        let est_energy_per_vector = tiles as f64 * core_op_energy(cfg, &st).total_fj();
        LayerCost {
            name: name.to_string(),
            kind: "matmul",
            k: lin.k,
            n: lin.n,
            n_rt,
            n_ct,
            vectors_per_input,
            est_cycles_per_input: vectors_per_input as u64 * tiles * op_cycles,
            est_energy_fj_per_input: vectors_per_input as f64 * est_energy_per_vector,
            est_reload_cycles_per_input: tiles * weight_load_cycles(cfg),
            est_reload_energy_fj_per_input: weight_load_energy(cfg, tiles).total_fj(),
            dynamic: true,
            shards_used: dynamic_pool_shards(cfg, n_rt * n_ct),
        }
    }

    /// Accumulated estimated cycles per shard (the balance the placer keeps).
    pub fn shard_load(&self) -> &[f64] {
        &self.shard_load
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::{Rng, Xoshiro256};

    fn rand_lin(cfg: &Config, k: usize, n: usize, seed: u64) -> CimLinear {
        let mut rng = Xoshiro256::seeded(seed);
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.next_f32() - 0.5).collect());
        CimLinear::new(&w, vec![0.0; n], 1.0, cfg)
    }

    #[test]
    fn static_estimate_is_paper_dense_cycle_count() {
        let cfg = Config::default();
        // Baseline dense worst case: act 15, top bit → 60 τ0 → 15 cycles.
        assert_eq!(static_op_cycles(&cfg), 15);
    }

    #[test]
    fn placer_balances_layers_across_shards() {
        let cfg = Config::default(); // 4 cores per shard
        let mut pool = MacroPool::new(cfg.clone());
        let mut placer = Placer::new(ActivationProfile::relu_like(&cfg));
        // Layer A: 6 tiles → grows to 2 shards (4 + 2).
        let (a, ca) = placer
            .place_layer(&mut pool, rand_lin(&cfg, 130, 20, 1), "a", "linear", 1)
            .unwrap();
        assert_eq!(a.n_tiles(), 6);
        assert_eq!(ca.tiles(), 6);
        assert_eq!(pool.n_shards(), 2);
        // Layer B: 2 tiles → must land on shard 1 (2 free cores, least load),
        // reusing the partially-filled shard instead of growing.
        let (b, cb) = placer
            .place_layer(&mut pool, rand_lin(&cfg, 64, 20, 2), "b", "linear", 1)
            .unwrap();
        assert_eq!(b.n_tiles(), 2);
        assert_eq!(pool.n_shards(), 2);
        assert_eq!(pool.slots_loaded(), 8);
        assert_eq!(cb.shards_used, 1);
        assert!(placer.shard_load()[1] > 0.0);
    }

    /// On a pre-grown pool (what `compile` provides) the least-loaded rule
    /// genuinely spreads a layer's tiles across dies.
    #[test]
    fn pre_grown_pool_spreads_tiles_by_load() {
        let cfg = Config::default();
        let mut pool = MacroPool::new(cfg.clone());
        pool.grow_to(2);
        let mut placer = Placer::new(ActivationProfile::relu_like(&cfg));
        let (placed, cost) = placer
            .place_layer(&mut pool, rand_lin(&cfg, 130, 20, 1), "a", "linear", 1)
            .unwrap();
        assert_eq!(placed.n_tiles(), 6);
        assert_eq!(cost.shards_used, 2);
        // 6 equal-cost tiles over 2 dies alternate: 3 + 3, loads equal.
        assert_eq!(pool.free_cores_on(0), 1);
        assert_eq!(pool.free_cores_on(1), 1);
        let loads = placer.shard_load();
        assert!((loads[0] - loads[1]).abs() < 1e-9, "{loads:?}");
    }

    #[test]
    fn estimated_energy_positive_and_profile_monotone() {
        let cfg = Config::default();
        let dense = estimated_op_stats(&cfg, &ActivationProfile::dense(&cfg), 3000.0);
        let sparse = estimated_op_stats(&cfg, &ActivationProfile::relu_like(&cfg), 3000.0);
        let ed = core_op_energy(&cfg, &dense).total_fj();
        let es = core_op_energy(&cfg, &sparse).total_fj();
        assert!(ed > 0.0 && es > 0.0);
        assert!(es < ed, "sparser profile must cost less: {es} vs {ed}");
        assert_eq!(dense.sa_compares, 16 * 9);
    }

    #[test]
    fn report_table_renders_with_totals() {
        let cfg = Config::default();
        let report = CostReport {
            layers: vec![
                LayerCost {
                    name: "fc0".into(),
                    kind: "linear",
                    k: 144,
                    n: 32,
                    n_rt: 3,
                    n_ct: 2,
                    vectors_per_input: 1,
                    est_cycles_per_input: 90,
                    est_energy_fj_per_input: 1.0e6,
                    est_reload_cycles_per_input: 0,
                    est_reload_energy_fj_per_input: 0.0,
                    dynamic: false,
                    shards_used: 2,
                },
                LayerCost {
                    name: "score".into(),
                    kind: "matmul",
                    k: 8,
                    n: 4,
                    n_rt: 1,
                    n_ct: 1,
                    vectors_per_input: 4,
                    est_cycles_per_input: 60,
                    est_energy_fj_per_input: 0.5e6,
                    est_reload_cycles_per_input: 64,
                    est_reload_energy_fj_per_input: 4915.2,
                    dynamic: true,
                    shards_used: 1,
                },
            ],
            total_tiles: 7,
            n_shards: 2,
            n_dynamic_shards: 1,
            weight_kb: 28.0,
        };
        let md = report.table(&cfg).to_markdown();
        assert!(md.contains("fc0"));
        assert!(md.contains("TOTAL"));
        assert!(md.contains("rld kcyc/in"));
        assert_eq!(report.total_est_cycles_per_input(), 150);
        assert_eq!(report.total_est_reload_cycles_per_input(), 64);
        let frac = report.reload_cycle_fraction();
        assert!((frac - 64.0 / 214.0).abs() < 1e-12, "{frac}");
    }

    /// Dynamic placement lands on a dedicated mini-pool and charges one
    /// grid swap per input in the estimate.
    #[test]
    fn dynamic_placement_uses_dedicated_shards_and_reload_cost() {
        let cfg = Config::default();
        let mut placer = Placer::new(ActivationProfile::relu_like(&cfg));
        // 100×20 → 2 row tiles × 2 col tiles = 4 tiles, 1 dedicated shard.
        let lin = rand_lin(&cfg, 100, 20, 9);
        let (dl, cost) = placer.place_dynamic_layer(&cfg, lin, "score", 3, 11).unwrap();
        assert!(cost.dynamic);
        assert_eq!(cost.tiles(), 4);
        assert_eq!(dl.pool().n_shards(), 1);
        assert_eq!(dl.pool().slots_loaded(), 4);
        assert_eq!(cost.est_reload_cycles_per_input, 4 * weight_load_cycles(&cfg));
        assert!(cost.est_reload_energy_fj_per_input > 0.0);
        assert_eq!(cost.est_cycles_per_input, 3 * 4 * static_op_cycles(&cfg));
        // The shared-board balance is untouched by dedicated placement.
        assert!(placer.shard_load().iter().all(|&l| l == 0.0));
    }
}
