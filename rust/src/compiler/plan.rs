//! The executable end of the compiler: [`CompiledPlan`] — a whole network
//! resident on a [`MacroPool`], executed batched through [`BatchExecutor`].
//!
//! `compile` runs the four stages (ingest happened when the graph was
//! built): shape inference + structure checks → calibration → lowering →
//! cost-model-driven placement. The resulting plan owns the pool (weights
//! loaded exactly once) and executes any batch of inputs with per-layer
//! cycle/energy accounting: `observed` device counters from the executor,
//! and the cost model's exact `predicted` cycles alongside (asserted equal
//! in `tests/compiler_equivalence.rs`).
//!
//! Determinism contract: with noise disabled, a compiled plan's outputs are
//! bit-identical to running each lowered layer sequentially through
//! `CimLinear::run_batch` / `CimConv::run` on a single macro, because the
//! per-layer arithmetic is expression-for-expression the same and the
//! batched executor is bit-identical to the sequential tiler.
//!
//! Two execution modes share that contract (DESIGN.md §9):
//! [`CompiledPlan::run_batch`] synchronizes at a barrier after every layer,
//! while [`CompiledPlan::run_streamed`] turns the plan into a pipeline of
//! per-layer stages over bounded queues ([`crate::sched`]) — each item
//! flows through the layers independently, and the per-op noise substream
//! key `(seed, epoch, item, tile)` makes the two modes bit-identical noise
//! on or off, for any worker count and any queue capacity.

use crate::compiler::ir::{dequantize, transpose_rows_to_cols, Graph, NodeId, Op};
use crate::compiler::lower::{calibrate, lower, CompileError, LayerKind, LoweredLayer};
use crate::compiler::place::{
    predicted_tile_cycles, ActivationProfile, CostReport, Placer, SlotHost, VirtualPool,
};
use crate::config::Config;
use crate::mapping::executor::{patches_to_rows, rows_to_chw, CimLinear};
use crate::mapping::{ExecStats, MapError};
use crate::nn::im2col::{conv_out_dims, im2col};
use crate::nn::ops::{causal_softmax, global_avg_pool, layer_norm, softmax_last_dim};
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;
use crate::pipeline::batch::{run_vector, StreamCtx, StreamKey};
use crate::pipeline::{BatchExecutor, DynamicLinear, MacroPool, PlacedLinear};
use crate::sched::{run_stages, StageGauge};
use crate::util::table::Table;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Fabrication-seed base for the first dedicated dynamic-weight shard:
/// far above any realistic shared-board size, so dedicated dies never
/// collide with the main pool's draw sequence (DESIGN.md §10).
const DYN_FAB_BASE: usize = 1 << 30;

/// Knobs for [`compile`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CompileOptions {
    /// Batch-executor worker threads (0 = auto).
    pub workers: usize,
    /// RNG seed for the executor's noise substreams (`None` derives from
    /// `cfg.sim.seed`).
    pub seed: Option<u64>,
    /// Activation profile for the placer's energy estimates (`None` =
    /// post-ReLU-like).
    pub profile: Option<ActivationProfile>,
}

/// Where a compiled layer's weights live (DESIGN.md §10).
enum LayerBacking {
    /// Weight-stationary tiles on the plan's shared pool (loaded once).
    Static(PlacedLinear),
    /// Dynamic-weight tiles on dedicated shards, swapped per call. The
    /// mutex is the "stage barrier per (item, tile)": whoever runs an item
    /// holds the layer — and therefore its whole tile grid — for the
    /// item's reload + rows, so a swap can never interleave with another
    /// item's ops. Contention is nil: the barrier path is single-threaded
    /// through a layer and the streaming scheduler gives each layer its
    /// own stage.
    Dynamic(Mutex<DynamicLinear>),
}

/// One placed network layer with its cumulative run accounting.
pub struct CompiledLayer {
    pub name: String,
    node: NodeId,
    src: NodeId,
    /// The runtime-weight operand node (dynamic layers only).
    b_src: Option<NodeId>,
    kind: LayerKind,
    qparams: QuantParams,
    backing: LayerBacking,
    n_tiles: usize,
    /// Activation vectors one network input generates through this layer
    /// (conv: `oh·ow`, linear: 1, row-wise/matmul: `seq`) — the streamed
    /// row-index stride.
    vectors_per_input: usize,
    observed: ExecStats,
    predicted_cycles: u64,
    /// Cached per-layer registry handles (`layer`/`kind` labels, DESIGN.md
    /// §12), fed at the same merge points as `observed` so the exported
    /// series equal it exactly.
    tele: crate::telemetry::LayerCounters,
}

impl CompiledLayer {
    /// The graph node this layer lowers.
    pub fn node(&self) -> NodeId {
        self.node
    }

    /// The resident quantized layer of a weight-stationary layer, `None`
    /// for dynamic-weight layers (whose `CimLinear` is a per-call staging
    /// value) — the total accessor for generic plan introspection.
    pub fn static_linear(&self) -> Option<&CimLinear> {
        match &self.backing {
            LayerBacking::Static(p) => Some(p.linear()),
            LayerBacking::Dynamic(_) => None,
        }
    }

    /// The resident quantized layer.
    ///
    /// # Panics
    /// For dynamic-weight layers — use [`CompiledLayer::static_linear`]
    /// (or check [`CompiledLayer::is_dynamic`]) when the plan may contain
    /// `MatMul` layers.
    pub fn linear(&self) -> &CimLinear {
        self.static_linear()
            .unwrap_or_else(|| panic!("layer `{}` has dynamic (per-call) weights", self.name))
    }

    pub fn qparams(&self) -> QuantParams {
        self.qparams
    }

    pub fn kind(&self) -> LayerKind {
        self.kind
    }

    /// Whether this layer reloads its weights per call (DESIGN.md §10).
    pub fn is_dynamic(&self) -> bool {
        matches!(self.backing, LayerBacking::Dynamic(_))
    }

    pub fn n_tiles(&self) -> usize {
        self.n_tiles
    }

    /// Activation vectors one network input generates through this layer.
    pub fn vectors_per_input(&self) -> usize {
        self.vectors_per_input
    }

    /// Device counters accumulated over every batch this layer ran. For
    /// dynamic layers, `weight_loads` counts the per-item reloads and
    /// `total_cycles` includes their reload cycles.
    pub fn observed(&self) -> &ExecStats {
        &self.observed
    }

    /// The cost model's cycle prediction for the same runs (exact: equals
    /// `observed().total_cycles`, reload cycles included).
    pub fn predicted_cycles(&self) -> u64 {
        self.predicted_cycles
    }
}

/// A compiled network resident on a macro pool.
///
/// A plan owns its pool (weights loaded exactly once at compile time) and
/// serves any number of batches with per-layer cycle/energy accounting:
///
/// ```
/// use cimsim::compiler::{compile, CompileOptions, Graph};
/// use cimsim::config::Config;
/// use cimsim::nn::mlp::Mlp;
/// use cimsim::nn::tensor::Tensor;
///
/// let mut cfg = Config::default();
/// cfg.noise.enabled = false;
/// let graph = Graph::from_mlp(&Mlp::new(&[10, 5, 3], 2));
/// let cal = vec![Tensor::from_vec(&[10], (0..10).map(|i| i as f32 / 10.0).collect())];
/// let mut plan = compile(graph, &cal, &cfg, &CompileOptions::default()).unwrap();
///
/// // Flat-vector serving form; batches of any size.
/// let out = plan.run_flat(&[vec![0.1; 10], vec![0.9; 10]]).unwrap();
/// assert_eq!((out.len(), out[0].len()), (2, 3));
///
/// // Device counters accumulate per layer and in total; the cost model's
/// // cycle prediction is exact (asserted in tests/compiler_equivalence.rs).
/// assert_eq!(
///     plan.stats().total_cycles,
///     plan.layers().iter().map(|l| l.predicted_cycles()).sum::<u64>(),
/// );
/// ```
///
/// Memory note: a plan keeps the ingested graph (float weights — backs
/// [`Graph::eval_float`] golden references) and each layer's tiled integer
/// planes (backs [`CompiledLayer::linear`] sequential references) alongside
/// the pool's loaded weights. For ResNet-20 that is a few MB total — a
/// deliberate simulator tradeoff of memory for introspection; only the pool
/// copy is touched on the execute hot path.
pub struct CompiledPlan {
    cfg: Config,
    graph: Graph,
    pool: MacroPool,
    exec: BatchExecutor,
    layers: Vec<CompiledLayer>,
    /// node id → compiled layer index (for `Conv2d`/`Linear` nodes).
    node_layer: Vec<Option<usize>>,
    /// Per node: the nodes whose *values* it reads at runtime (quantize
    /// boundaries resolved to their producers).
    data_src: Vec<Vec<NodeId>>,
    /// Last node id that reads each node's value (liveness for buffer reuse).
    last_use: Vec<usize>,
    output_node: NodeId,
    report: CostReport,
    stats: ExecStats,
    /// Cumulative per-stage gauges over every streamed run (DESIGN.md §9).
    stream_gauges: Vec<StageGauge>,
    /// Peak number of simultaneously busy stages over every streamed run.
    stream_peak_busy: usize,
}

/// Compile a graph onto a fresh [`MacroPool`]: calibrate on `cal_inputs`,
/// lower every layer, place tiles with the cost-model-driven placer, load
/// weights once.
pub fn compile(
    graph: Graph,
    cal_inputs: &[Tensor],
    cfg: &Config,
    opts: &CompileOptions,
) -> Result<CompiledPlan, CompileError> {
    let shapes = graph.infer_shapes().map_err(CompileError::Structure)?;
    check_quantize_structure(&graph)?;
    let cal = calibrate(&graph, cal_inputs)?;
    let lowered = lower(&graph, &shapes, &cal, cfg)?;

    let mut pool = MacroPool::new(cfg.clone());
    // Pre-size the pool to the exact shard count the weight-stationary
    // layers need, so the placer has every die as a candidate and genuinely
    // balances estimated per-shard work (instead of dense-filling one die
    // at a time). Dynamic layers live on dedicated shards and don't count.
    let needed_tiles: usize = lowered
        .iter()
        .filter(|l| !l.kind.is_dynamic())
        .map(|l| l.lin.n_row_tiles() * l.lin.n_col_tiles())
        .sum();
    pool.grow_to(needed_tiles.div_ceil(cfg.mac.cores.max(1)));
    let profile = opts.profile.unwrap_or_else(|| ActivationProfile::relu_like(cfg));
    let mut placer = Placer::new(profile);
    let mut layers = Vec::with_capacity(lowered.len());
    let mut node_layer = vec![None; graph.nodes.len()];
    let mut report_layers = Vec::with_capacity(lowered.len());
    let mut n_dynamic_shards = 0usize;
    for LoweredLayer { node, src, b_src, name, kind, qparams, lin, vectors_per_input } in lowered
    {
        let n_tiles = lin.n_row_tiles() * lin.n_col_tiles();
        let (backing, cost) = match kind {
            LayerKind::MatMul { .. } => {
                let (dyn_lin, cost) = placer.place_dynamic_layer(
                    cfg,
                    lin,
                    &name,
                    vectors_per_input,
                    DYN_FAB_BASE + n_dynamic_shards,
                )?;
                n_dynamic_shards += dyn_lin.pool().n_shards();
                (LayerBacking::Dynamic(Mutex::new(dyn_lin)), cost)
            }
            _ => {
                let kind_label = match kind {
                    LayerKind::Conv { .. } => "conv",
                    _ => "linear",
                };
                let (placed, cost) =
                    placer.place_layer(&mut pool, lin, &name, kind_label, vectors_per_input)?;
                (LayerBacking::Static(placed), cost)
            }
        };
        node_layer[node] = Some(layers.len());
        let tele = crate::telemetry::LayerCounters::for_layer(&name, kind.label());
        layers.push(CompiledLayer {
            name,
            node,
            src,
            b_src,
            kind,
            qparams,
            backing,
            n_tiles,
            vectors_per_input,
            observed: ExecStats::default(),
            predicted_cycles: 0,
            tele,
        });
        report_layers.push(cost);
    }

    let total_tiles: usize = layers.iter().map(|l| l.n_tiles).sum();
    let report = CostReport {
        layers: report_layers,
        total_tiles,
        n_shards: pool.n_shards(),
        n_dynamic_shards,
        weight_kb: total_tiles as f64 * cfg.mac.core_kb(),
    };

    let n = graph.nodes.len();
    let mut data_src: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for (id, node) in graph.nodes.iter().enumerate() {
        if let Some(li) = node_layer[id] {
            data_src[id] = match layers[li].b_src {
                // A dynamic layer reads its streamed operand AND its
                // runtime-weight operand.
                Some(b) => vec![layers[li].src, b],
                None => vec![layers[li].src],
            };
        } else if !matches!(node.op, Op::Quantize { .. }) {
            data_src[id] = node.inputs.clone();
        }
    }
    let output_node = graph.output();
    let mut last_use = vec![0usize; n];
    for (id, srcs) in data_src.iter().enumerate() {
        for &s in srcs {
            last_use[s] = last_use[s].max(id);
        }
    }
    last_use[output_node] = usize::MAX;

    let seed = opts.seed.unwrap_or(cfg.sim.seed ^ 0xC09B_11E5);
    let stats = ExecStats { weight_loads: total_tiles as u64, ..ExecStats::default() };
    // Placement loads count toward the device-wide series too, so the
    // exported totals equal `CompiledPlan::stats` from birth.
    crate::telemetry::device().record_stats(&stats);
    Ok(CompiledPlan {
        cfg: cfg.clone(),
        graph,
        pool,
        exec: BatchExecutor::new(opts.workers, seed),
        layers,
        node_layer,
        data_src,
        last_use,
        output_node,
        report,
        stats,
        stream_gauges: Vec::new(),
        stream_peak_busy: 0,
    })
}

/// Analytic cost of `graph` on `cfg` without building any simulator state:
/// calibrates, lowers, and runs [`compile`]'s placement loop against a
/// counters-only [`VirtualPool`]. The returned report is bit-identical to
/// `compile(..).cost_report()` for the same inputs (asserted by
/// `tests/hwspec_explore.rs`) — the exactness claim the explore harness
/// (DESIGN.md §15) rests on.
pub fn estimate_cost(
    graph: &Graph,
    cal_inputs: &[Tensor],
    cfg: &Config,
    opts: &CompileOptions,
) -> Result<CostReport, CompileError> {
    let shapes = graph.infer_shapes().map_err(CompileError::Structure)?;
    check_quantize_structure(graph)?;
    let cal = calibrate(graph, cal_inputs)?;
    let lowered = lower(graph, &shapes, &cal, cfg)?;
    Ok(estimate_cost_lowered(&lowered, cfg, opts))
}

/// The cost-only core of [`estimate_cost`]: place an already-lowered
/// network on a [`VirtualPool`] (same pre-sizing, same least-loaded shard
/// choices, same f64 accumulation order as [`compile`]) and return the
/// [`CostReport`]. The explore harness calls this once per candidate after
/// sharing a single calibration pass across the sweep.
pub fn estimate_cost_lowered(
    lowered: &[LoweredLayer],
    cfg: &Config,
    opts: &CompileOptions,
) -> CostReport {
    let mut pool = VirtualPool::new(cfg.mac.cores);
    let needed_tiles: usize = lowered
        .iter()
        .filter(|l| !l.kind.is_dynamic())
        .map(|l| l.lin.n_row_tiles() * l.lin.n_col_tiles())
        .sum();
    pool.grow_to(needed_tiles.div_ceil(cfg.mac.cores.max(1)));
    let profile = opts.profile.unwrap_or_else(|| ActivationProfile::relu_like(cfg));
    let mut placer = Placer::new(profile);
    let mut report_layers = Vec::with_capacity(lowered.len());
    let mut total_tiles = 0usize;
    let mut n_dynamic_shards = 0usize;
    for l in lowered {
        total_tiles += l.lin.n_row_tiles() * l.lin.n_col_tiles();
        let cost = if l.kind.is_dynamic() {
            let cost = placer.dynamic_layer_cost(cfg, &l.lin, &l.name, l.vectors_per_input);
            n_dynamic_shards += cost.shards_used;
            cost
        } else {
            let kind_label = match l.kind {
                LayerKind::Conv { .. } => "conv",
                _ => "linear",
            };
            let (_slots, cost) = placer.plan_layer(
                &mut pool,
                cfg,
                &l.lin,
                &l.name,
                kind_label,
                l.vectors_per_input,
            );
            cost
        };
        report_layers.push(cost);
    }
    CostReport {
        layers: report_layers,
        total_tiles,
        n_shards: pool.n_shards(),
        n_dynamic_shards,
        weight_kb: total_tiles as f64 * cfg.mac.core_kb(),
    }
}

/// `Quantize` nodes may only feed `Conv2d`/`Linear`/`MatMul` streamed
/// operands (they are fused into the placed layer), may not chain, and may
/// not be the graph output.
pub(crate) fn check_quantize_structure(graph: &Graph) -> Result<(), CompileError> {
    for node in &graph.nodes {
        let is_cim =
            matches!(node.op, Op::Conv2d { .. } | Op::Linear { .. } | Op::MatMul { .. });
        for (slot, &i) in node.inputs.iter().enumerate() {
            // A matmul's weight operand (input 1) is float: the lowerer
            // re-quantizes it per call, so a Quantize there is rejected
            // (by `lower`); only the streamed operand may be quantized.
            let is_boundary = is_cim && slot == 0;
            if matches!(graph.nodes[i].op, Op::Quantize { .. }) && !is_boundary {
                return Err(CompileError::Structure(format!(
                    "Quantize `{}` feeds non-layer `{}`",
                    graph.nodes[i].name, node.name
                )));
            }
        }
    }
    if matches!(graph.nodes[graph.output()].op, Op::Quantize { .. }) {
        return Err(CompileError::Structure("graph output is a Quantize node".into()));
    }
    Ok(())
}

/// Knobs for [`CompiledPlan::run_streamed_with`].
#[derive(Clone, Copy, Debug)]
pub struct StreamOptions {
    /// Capacity of each inter-stage queue (clamped to ≥ 1). Small values
    /// bound in-flight memory and propagate backpressure sooner; a handful
    /// of items per queue is enough to hide stage jitter — see DESIGN.md §9
    /// for the sizing argument.
    pub queue_cap: usize,
}

impl Default for StreamOptions {
    fn default() -> Self {
        Self { queue_cap: 4 }
    }
}

/// What one [`CompiledPlan::run_streamed_with`] call produced and observed.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    /// The output node's value per item — bit-identical to
    /// [`CompiledPlan::run_batch`] on the same epochs.
    pub outputs: Vec<Vec<f32>>,
    /// Wall-clock from run start to each item's completion, in admission
    /// order (the barrier path completes every item at the end; streaming
    /// completes early items while later ones are still in flight).
    pub item_latency: Vec<Duration>,
    /// Per-stage items/queue-depth gauges for this run.
    pub gauges: Vec<StageGauge>,
    /// Peak number of simultaneously busy stages (`> 1` ⇒ pipelined).
    pub peak_busy: usize,
}

/// One batch item in flight through the stage pipeline: its index, its
/// not-yet-consumed input tensor, and the per-node values produced so far
/// (liveness-pruned exactly like the barrier loop).
struct Flight {
    idx: usize,
    input: Option<Tensor>,
    values: Vec<Option<Tensor>>,
}

/// Per-stage run accounting, folded into the plan's cumulative counters
/// after the run (a stage exclusively owns its layer while running).
#[derive(Default)]
struct StageAcc {
    stats: ExecStats,
    predicted: u64,
}

impl CompiledPlan {
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn pool(&self) -> &MacroPool {
        &self.pool
    }

    pub fn workers(&self) -> usize {
        self.exec.workers()
    }

    pub fn layers(&self) -> &[CompiledLayer] {
        &self.layers
    }

    pub fn total_tiles(&self) -> usize {
        self.report.total_tiles
    }

    /// The placement-time cost estimates.
    pub fn cost_report(&self) -> &CostReport {
        &self.report
    }

    /// Cumulative device counters over every batch served.
    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
        for l in &mut self.layers {
            l.observed = ExecStats::default();
            l.predicted_cycles = 0;
        }
        self.stream_gauges.clear();
        self.stream_peak_busy = 0;
    }

    /// The network's input shape.
    pub fn input_shape(&self) -> Vec<usize> {
        self.graph.input_shape().expect("compiled graph has an input").to_vec()
    }

    /// Run a batch of inputs through the resident network; returns the
    /// output node's value per item, flattened.
    pub fn run_batch(&mut self, xs: &[Tensor]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_batch_owned(xs.to_vec())
    }

    /// Owned-input form of [`CompiledPlan::run_batch`] — the serving hot
    /// path: the batch is materialized exactly once.
    ///
    /// Non-layer ops evaluate per item through the SAME evaluator the
    /// streaming scheduler uses ([`CompiledPlan::eval_simple_node_item`]) —
    /// one source of truth for the barrier/streamed bit-identity contract —
    /// while each layer node runs the whole batch's rows through ONE
    /// `run_q` call (one epoch per layer invocation, DESIGN.md §9).
    pub fn run_batch_owned(&mut self, xs: Vec<Tensor>) -> Result<Vec<Vec<f32>>, MapError> {
        let n_nodes = self.graph.nodes.len();
        let mut flights: Vec<Flight> = xs
            .into_iter()
            .enumerate()
            .map(|(idx, t)| Flight {
                idx,
                input: Some(t),
                values: (0..n_nodes).map(|_| None).collect(),
            })
            .collect();
        for id in 0..n_nodes {
            if let Some(li) = self.node_layer[id] {
                self.run_layer_batch(li, &mut flights)?;
            } else {
                for fl in &mut flights {
                    self.eval_simple_node_item(id, fl)?;
                }
            }
            for fl in &mut flights {
                for &src in &self.data_src[id] {
                    if self.last_use[src] == id {
                        fl.values[src] = None;
                    }
                }
            }
        }
        let output_node = self.output_node;
        flights
            .iter_mut()
            .map(|fl| {
                fl.values[output_node]
                    .take()
                    .map(|t| t.data)
                    .ok_or_else(|| MapError::Shape("output value missing".into()))
            })
            .collect()
    }

    /// One placed layer over the whole batch — the barrier counterpart of
    /// [`CompiledPlan::run_layer_item`]: every item's (im2col →) quantized
    /// rows concatenate, in item order, into ONE `run_q` call, so row `r`
    /// of item `i` gets substream item index `i × vectors_per_input + r` —
    /// exactly the key the streamed path derives per item (DESIGN.md §9).
    ///
    /// Dynamic-weight layers instead reserve one epoch and run the items
    /// sequentially through the SAME per-item routine the streaming
    /// scheduler uses ([`CompiledPlan::run_dynamic_layer_item`]): each
    /// item's reload must complete before its rows stream (the per-(item,
    /// tile) barrier of DESIGN.md §10), so there is no cross-item
    /// parallelism to exploit on one tile grid — and the two execution
    /// modes share one code path, which is what keeps them bit-identical.
    fn run_layer_batch(&mut self, li: usize, flights: &mut [Flight]) -> Result<(), MapError> {
        let _span = crate::span!(
            "layer_batch",
            "layer" => &self.layers[li].name,
            "items" => flights.len(),
        );
        if self.layers[li].is_dynamic() {
            let epoch = self.exec.reserve_epochs(1);
            // Pooled context: per-request dynamic layers (the serve path)
            // reuse the executor's scratch instead of reallocating one per
            // call (DESIGN.md §14).
            let mut ctx = self.exec.acquire_ctx(&self.cfg);
            let mut acc = StageAcc::default();
            let mut res = Ok(());
            for fl in flights.iter_mut() {
                res = self.run_dynamic_layer_item(li, epoch, fl, &mut ctx, &mut acc);
                if res.is_err() {
                    break;
                }
            }
            self.exec.release_ctx(ctx);
            res?;
            let layer = &mut self.layers[li];
            layer.predicted_cycles += acc.predicted;
            layer.observed.merge(&acc.stats);
            layer.tele.record_stats(&acc.stats);
            self.stats.merge(&acc.stats);
            crate::telemetry::device().record_stats(&acc.stats);
            return Ok(());
        }
        let layer = &self.layers[li];
        let src = layer.src;
        let LayerBacking::Static(placed) = &layer.backing else {
            unreachable!("dynamic layers handled above")
        };
        let mut q: Vec<Vec<i64>> = Vec::new();
        let mut dims: Vec<(usize, usize)> = Vec::new();
        for fl in flights.iter() {
            let t = fl.values[src]
                .as_ref()
                .ok_or_else(|| MapError::Shape(format!("value of node {src} unavailable")))?;
            dims.push(quantize_layer_rows(layer, t, &mut q)?);
        }
        let predicted = predicted_tile_cycles(&self.cfg, placed.linear(), &q);
        let (rows, stats) = self.exec.run_q(&self.pool, placed, &q)?;
        {
            let layer = &mut self.layers[li];
            layer.predicted_cycles += predicted;
            layer.observed.merge(&stats);
            layer.tele.record_stats(&stats);
        }
        self.stats.merge(&stats);
        crate::telemetry::device().record_stats(&stats);
        assemble_layer_outputs(&self.layers[li], rows, &dims, flights);
        Ok(())
    }

    /// One dynamic-weight layer over ONE in-flight item (DESIGN.md §10):
    /// requantize the item's runtime weight operand, swap it into the
    /// dedicated tile grid, then stream the item's quantized rows with the
    /// standard `(seed, epoch, item × vectors_per_input + row, tile)`
    /// substream keys. The layer mutex is held for the whole item — the
    /// reload is a barrier per (item, tile) — and this ONE routine serves
    /// both the barrier path and the streaming scheduler, so the two modes
    /// cannot drift.
    fn run_dynamic_layer_item(
        &self,
        li: usize,
        epoch: u64,
        fl: &mut Flight,
        ctx: &mut StreamCtx,
        acc: &mut StageAcc,
    ) -> Result<(), MapError> {
        let layer = &self.layers[li];
        let _span = crate::span!(
            "dynamic_item",
            "layer" => &layer.name,
            "item" => fl.idx,
        );
        let LayerKind::MatMul { seq, transpose_b } = layer.kind else {
            unreachable!("dynamic layers are matmul layers")
        };
        let LayerBacking::Dynamic(cell) = &layer.backing else {
            unreachable!("dynamic layers carry a dynamic backing")
        };
        let b_src = layer.b_src.expect("dynamic layer has a weight operand");
        let b = fl.values[b_src]
            .as_ref()
            .ok_or_else(|| MapError::Shape(format!("value of node {b_src} unavailable")))?;
        let mut dl = cell.lock().expect("dynamic layer poisoned");
        let (k, n) = (dl.linear().k, dl.linear().n);
        let want_shape = if transpose_b { [n, k] } else { [k, n] };
        if b.shape != want_shape {
            return Err(MapError::Shape(format!(
                "matmul `{}` weight operand {:?} vs placed {:?}",
                layer.name, b.shape, want_shape
            )));
        }
        // Per-call requantization: max-abs signed at the macro's weight
        // precision, staged as a fresh tile grid, swapped in place. Only
        // the transposed form materializes a new tensor; attn·V passes the
        // operand through by reference.
        let transposed;
        let w_cols: &Tensor = if transpose_b {
            transposed = transpose_rows_to_cols(b);
            &transposed
        } else {
            b
        };
        let src = layer.src;
        let t = fl.values[src]
            .as_ref()
            .ok_or_else(|| MapError::Shape(format!("value of node {src} unavailable")))?;
        let mut q: Vec<Vec<i64>> = Vec::with_capacity(seq);
        quantize_layer_rows(layer, t, &mut q)?;
        let item_base = fl.idx as u64 * layer.vectors_per_input as u64;
        let seed = self.exec.seed();
        // Reload-to-results under ONE exclusive borrow of the grid
        // (`DynamicLinear::run_item`): the borrow checker itself enforces
        // the per-(item, tile) barrier — a concurrent stream behind the
        // layer mutex cannot interleave its reload between this item's swap
        // and its row ops (DESIGN.md §10; `tests/dynamic_contention.rs`).
        let rows =
            dl.run_item(w_cols, layer.qparams, &q, seed, epoch, item_base, ctx, &mut acc.stats)?;
        acc.predicted += dl.reload_cycles();
        acc.predicted += predicted_tile_cycles(&self.cfg, dl.linear(), &q);
        let mut data = Vec::with_capacity(seq * n);
        for row in rows {
            data.extend(row);
        }
        fl.values[layer.node] = Some(Tensor::from_vec(&[seq, n], data));
        Ok(())
    }

    fn flat_to_tensors(&self, xs: &[Vec<f32>]) -> Result<Vec<Tensor>, MapError> {
        let shape = self.input_shape();
        let len: usize = shape.iter().product();
        xs.iter()
            .map(|x| {
                if x.len() != len {
                    return Err(MapError::Shape(format!(
                        "request length {} vs plan input {len}",
                        x.len()
                    )));
                }
                Ok(Tensor::from_vec(&shape, x.clone()))
            })
            .collect()
    }

    /// Flat-vector convenience for serving: wraps each request into the
    /// plan's input shape.
    pub fn run_flat(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        let tensors = self.flat_to_tensors(xs)?;
        self.run_batch_owned(tensors)
    }

    /// Streamed (layer-pipelined) execution: same outputs as
    /// [`CompiledPlan::run_batch`], **bit for bit, noise on or off** —
    /// items flow through per-layer stages connected by bounded queues
    /// instead of synchronizing at a barrier after every layer
    /// (DESIGN.md §9; the identity is property-tested in
    /// `tests/stream_equivalence.rs`).
    pub fn run_streamed(&mut self, xs: &[Tensor]) -> Result<Vec<Vec<f32>>, MapError> {
        Ok(self.run_streamed_with(xs, &StreamOptions::default())?.outputs)
    }

    /// Flat-vector serving form of [`CompiledPlan::run_streamed`].
    pub fn run_streamed_flat(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        let tensors = self.flat_to_tensors(xs)?;
        self.run_streamed(&tensors)
    }

    /// [`CompiledPlan::run_streamed`] with explicit options, returning the
    /// per-item latencies and the pipeline gauges of the run.
    pub fn run_streamed_with(
        &mut self,
        xs: &[Tensor],
        opts: &StreamOptions,
    ) -> Result<StreamOutcome, MapError> {
        let n_layers = self.layers.len();
        if n_layers == 0 {
            // No compute stages: the barrier path IS the one-stage case.
            let t0 = Instant::now();
            let outputs = self.run_batch(xs)?;
            let d = t0.elapsed();
            return Ok(StreamOutcome {
                outputs,
                item_latency: vec![d; xs.len()],
                gauges: Vec::new(),
                peak_busy: usize::from(!xs.is_empty()),
            });
        }
        // Reserve one epoch per layer invocation up front — the exact
        // assignment the barrier path's per-layer `run_q` calls would have
        // made in node order (DESIGN.md §9).
        let epoch_base = self.exec.reserve_epochs(n_layers as u64);
        let n_nodes = self.graph.nodes.len();
        let defs = self.stage_defs();
        let names: Vec<String> = defs
            .iter()
            .map(|&(_, _, li)| match li {
                Some(i) => self.layers[i].name.clone(),
                None => "tail".to_string(),
            })
            .collect();
        let accs: Vec<Mutex<StageAcc>> =
            defs.iter().map(|_| Mutex::new(StageAcc::default())).collect();
        let out_slots: Vec<OnceLock<(Vec<f32>, Duration)>> =
            xs.iter().map(|_| OnceLock::new()).collect();
        let t0 = Instant::now();
        let run = {
            let this: &CompiledPlan = self;
            let defs = &defs;
            let accs = &accs;
            let out_slots = &out_slots;
            let output_node = this.output_node;
            run_stages(
                xs.iter().enumerate().map(|(idx, t)| Flight {
                    idx,
                    input: Some(t.clone()),
                    values: (0..n_nodes).map(|_| None).collect(),
                }),
                names,
                opts.queue_cap,
                move |stage| {
                    // Per-stage worker state: one kernel scratch, reused for
                    // every (item, row-tile) work unit this stage pulls.
                    let mut ctx = StreamCtx::new(&this.cfg);
                    let def = defs[stage];
                    move |fl: &mut Flight| {
                        let _span = crate::span!(
                            "stage_item",
                            "stage" => stage,
                            "item" => fl.idx,
                        );
                        let mut acc = accs[stage].lock().expect("stage accumulator poisoned");
                        this.eval_stage_item(def, epoch_base, fl, &mut ctx, &mut acc)
                    }
                },
                move |mut fl: Flight| {
                    if let Some(t) = fl.values[output_node].take() {
                        let _ = out_slots[fl.idx].set((t.data, t0.elapsed()));
                    }
                },
            )?
        };
        // Fold this run's per-stage accounting into the plan's cumulative
        // counters (stage s exclusively owned layer s during the run).
        for (def, acc) in defs.iter().zip(&accs) {
            let acc = acc.lock().expect("stage accumulator poisoned");
            if let Some(li) = def.2 {
                self.layers[li].observed.merge(&acc.stats);
                self.layers[li].predicted_cycles += acc.predicted;
                self.layers[li].tele.record_stats(&acc.stats);
            }
            self.stats.merge(&acc.stats);
            crate::telemetry::device().record_stats(&acc.stats);
        }
        if self.stream_gauges.len() == run.stages.len() {
            for (c, r) in self.stream_gauges.iter_mut().zip(&run.stages) {
                c.items += r.items;
                c.peak_queue = c.peak_queue.max(r.peak_queue);
            }
        } else {
            self.stream_gauges = run.stages.clone();
        }
        self.stream_peak_busy = self.stream_peak_busy.max(run.peak_busy);

        let mut outputs = Vec::with_capacity(xs.len());
        let mut item_latency = Vec::with_capacity(xs.len());
        for slot in out_slots {
            let (o, d) = slot
                .into_inner()
                .ok_or_else(|| MapError::Shape("streamed item produced no output".into()))?;
            outputs.push(o);
            item_latency.push(d);
        }
        Ok(StreamOutcome { outputs, item_latency, gauges: run.stages, peak_busy: run.peak_busy })
    }

    /// Rewind the executor's epoch counter so the next run replays the same
    /// noise epochs (DESIGN.md §9) — how tests and benches compare barrier
    /// and streamed execution draw for draw on one plan.
    pub fn set_epoch(&mut self, epoch: u64) {
        self.exec.set_epoch(epoch);
    }

    /// Cumulative per-stage gauges over every streamed run (empty until the
    /// first `run_streamed*` call).
    pub fn stream_gauges(&self) -> &[StageGauge] {
        &self.stream_gauges
    }

    /// Peak number of simultaneously busy stages over every streamed run —
    /// `> 1` is the observable proof that execution pipelined.
    pub fn stream_peak_busy(&self) -> usize {
        self.stream_peak_busy
    }

    /// Stage partition of the node order: compute stage `s` evaluates the
    /// nodes from just after the previous layer node through layer `s`'s
    /// node; a final `tail` stage holds any float ops after the last layer.
    fn stage_defs(&self) -> Vec<(usize, usize, Option<usize>)> {
        let n_nodes = self.graph.nodes.len();
        let mut defs = Vec::with_capacity(self.layers.len() + 1);
        let mut start = 0usize;
        for (li, l) in self.layers.iter().enumerate() {
            defs.push((start, l.node + 1, Some(li)));
            start = l.node + 1;
        }
        if start < n_nodes {
            defs.push((start, n_nodes, None));
        }
        defs
    }

    /// Evaluate one stage's node range for one in-flight item, applying the
    /// same per-node liveness sweep the barrier loop performs.
    fn eval_stage_item(
        &self,
        (start, end, _li): (usize, usize, Option<usize>),
        epoch_base: u64,
        fl: &mut Flight,
        ctx: &mut StreamCtx,
        acc: &mut StageAcc,
    ) -> Result<(), MapError> {
        for id in start..end {
            if let Some(li) = self.node_layer[id] {
                self.run_layer_item(li, epoch_base + li as u64, fl, ctx, acc)?;
            } else {
                self.eval_simple_node_item(id, fl)?;
            }
            for &src in &self.data_src[id] {
                if self.last_use[src] == id {
                    fl.values[src] = None;
                }
            }
        }
        Ok(())
    }

    /// Single-item evaluation of a non-layer node, with take-on-last-use
    /// liveness (`allow_take: false` forces a clone when the same node
    /// feeds both inputs). This is the ONE evaluator for float graph ops:
    /// the barrier path ([`CompiledPlan::run_batch_owned`]) and the
    /// streaming scheduler both call it per item, so the two execution
    /// modes cannot drift.
    fn eval_simple_node_item(&self, id: usize, fl: &mut Flight) -> Result<(), MapError> {
        let node = &self.graph.nodes[id];
        let last_use = &self.last_use;
        let arg = |values: &mut [Option<Tensor>],
                   i: usize,
                   allow_take: bool|
         -> Result<Tensor, MapError> {
            let src = node.inputs[i];
            let v = if allow_take && last_use[src] == id {
                values[src].take()
            } else {
                values[src].clone()
            };
            v.ok_or_else(|| MapError::Shape("value consumed too early".into()))
        };
        let out = match &node.op {
            Op::Input { shape } => {
                let t = fl.input.take().ok_or_else(|| {
                    MapError::Shape("graph has more than one Input node".into())
                })?;
                if t.shape != *shape {
                    return Err(MapError::Shape(format!(
                        "input shape {:?} vs plan {:?}",
                        t.shape, shape
                    )));
                }
                Some(t)
            }
            // Fused into the consuming layer; holds no value.
            Op::Quantize { .. } => None,
            Op::Dequantize { scale, bias } => {
                Some(dequantize(&arg(&mut fl.values, 0, true)?, *scale, bias))
            }
            Op::Relu => Some(arg(&mut fl.values, 0, true)?.map(|v| v.max(0.0))),
            Op::Add => {
                let distinct = node.inputs[0] != node.inputs[1];
                let a = arg(&mut fl.values, 0, distinct)?;
                let b = arg(&mut fl.values, 1, true)?;
                if a.shape != b.shape {
                    return Err(MapError::Shape(format!(
                        "add shapes {:?} vs {:?}",
                        a.shape, b.shape
                    )));
                }
                let mut t = a;
                for (o, i) in t.data.iter_mut().zip(&b.data) {
                    *o += i;
                }
                Some(t)
            }
            Op::GlobalAvgPool => {
                let t = arg(&mut fl.values, 0, true)?;
                let c = t.shape[0];
                Some(Tensor::from_vec(&[c], global_avg_pool(&t)))
            }
            Op::Softmax => Some(softmax_last_dim(&arg(&mut fl.values, 0, true)?)),
            Op::CausalSoftmax => Some(causal_softmax(&arg(&mut fl.values, 0, true)?)),
            Op::LayerNorm { gamma, beta, eps } => {
                Some(layer_norm(&arg(&mut fl.values, 0, true)?, gamma, beta, *eps))
            }
            Op::Conv2d { .. } | Op::Linear { .. } | Op::MatMul { .. } => {
                unreachable!("layer nodes are handled by node_layer")
            }
        };
        fl.values[id] = out;
        Ok(())
    }

    /// One placed layer over ONE in-flight item: (im2col →) quantize →
    /// per-row [`run_vector`] (prepare-once per row tile) (→ CHW). The row
    /// substream index is `item × vectors_per_input + row`, landing on the
    /// exact keys the barrier path assigns across its concatenated batch —
    /// which is what makes the two modes bit-identical with noise on
    /// (DESIGN.md §9). Dynamic-weight layers route through
    /// [`CompiledPlan::run_dynamic_layer_item`].
    fn run_layer_item(
        &self,
        li: usize,
        epoch: u64,
        fl: &mut Flight,
        ctx: &mut StreamCtx,
        acc: &mut StageAcc,
    ) -> Result<(), MapError> {
        let layer = &self.layers[li];
        if layer.is_dynamic() {
            return self.run_dynamic_layer_item(li, epoch, fl, ctx, acc);
        }
        let LayerBacking::Static(placed) = &layer.backing else {
            unreachable!("dynamic layers handled above")
        };
        let src = layer.src;
        let t = fl.values[src]
            .as_ref()
            .ok_or_else(|| MapError::Shape(format!("value of node {src} unavailable")))?;
        let mut q: Vec<Vec<i64>> = Vec::new();
        let out_dims = quantize_layer_rows(layer, t, &mut q)?;
        acc.predicted += predicted_tile_cycles(&self.cfg, placed.linear(), &q);
        let item_base = fl.idx as u64 * layer.vectors_per_input as u64;
        let seed = self.exec.seed();
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(q.len());
        for (r, acts) in q.iter().enumerate() {
            let key = StreamKey { seed, epoch, item: item_base + r as u64 };
            rows.push(run_vector(&self.pool, placed, key, acts, ctx, &mut acc.stats)?);
        }
        let out = match layer.kind {
            LayerKind::Conv { out_c, .. } => {
                let (oh, ow) = out_dims;
                rows_to_chw(&rows, out_c, oh, ow)
            }
            LayerKind::Linear => {
                let row = rows.pop().expect("linear layer yields one row");
                let n = row.len();
                Tensor::from_vec(&[n], row)
            }
            LayerKind::Rowwise { seq } => {
                let n = rows.first().map(|r| r.len()).unwrap_or(0);
                Tensor::from_vec(&[seq, n], rows.concat())
            }
            LayerKind::MatMul { .. } => unreachable!("dynamic layers handled above"),
        };
        fl.values[layer.node] = Some(out);
        Ok(())
    }

    /// Per-layer observed vs predicted run accounting (after at least one
    /// batch).
    pub fn observed_table(&self) -> Table {
        let mut t = Table::new(
            "per-layer run accounting (cumulative)",
            &["layer", "core ops", "reloads", "cycles", "predicted", "uJ", "clipped"],
        );
        for l in &self.layers {
            t.row(&[
                l.name.clone(),
                l.observed.core_ops.to_string(),
                if l.is_dynamic() { l.observed.weight_loads.to_string() } else { "-".into() },
                l.observed.total_cycles.to_string(),
                l.predicted_cycles.to_string(),
                format!("{:.3}", l.observed.energy_fj() * 1e-9),
                l.observed.clipped.to_string(),
            ]);
        }
        t.row(&[
            "TOTAL".into(),
            self.stats.core_ops.to_string(),
            self.layers
                .iter()
                .filter(|l| l.is_dynamic())
                .map(|l| l.observed.weight_loads)
                .sum::<u64>()
                .to_string(),
            self.stats.total_cycles.to_string(),
            self.layers.iter().map(|l| l.predicted_cycles).sum::<u64>().to_string(),
            format!("{:.3}", self.stats.energy_fj() * 1e-9),
            self.stats.clipped.to_string(),
        ]);
        t
    }
}

/// (im2col →) quantize ONE item's input value into activation rows for
/// `layer`, appending to `q`; returns the conv output dims (`(0, 0)` for
/// the vector kinds). Signed-activation boundaries shift their codes by
/// the zero point into the macro's unsigned window here — the executors
/// restore `zp·Σw` digitally (DESIGN.md §10). The single source of the
/// per-item row recipe — the barrier path
/// ([`CompiledPlan::run_batch_owned`]) and the streaming scheduler both
/// call it, so their rows (and therefore their substream keys, DESIGN.md
/// §9) cannot drift. Enforces the compile-time `vectors_per_input` stride
/// the keys rely on.
fn quantize_layer_rows(
    layer: &CompiledLayer,
    t: &Tensor,
    q: &mut Vec<Vec<i64>>,
) -> Result<(usize, usize), MapError> {
    let before = q.len();
    // One zero-point definition for codes and the digital restore alike
    // (`QuantParams::zero_point`, DESIGN.md §10).
    let codes = |xs: &[f32]| -> Vec<i64> { layer.qparams.quantize_codes(xs) };
    let mut dims = (0usize, 0usize);
    match layer.kind {
        LayerKind::Conv { kh, kw, stride, pad, .. } => {
            if t.rank() != 3 {
                return Err(MapError::Shape(format!(
                    "conv `{}` input must be CHW, got {:?}",
                    layer.name, t.shape
                )));
            }
            let patches = im2col(t, kh, kw, stride, pad);
            for row in patches_to_rows(&patches) {
                q.push(codes(&row));
            }
            dims = conv_out_dims(t.shape[1], t.shape[2], kh, kw, stride, pad);
        }
        LayerKind::Linear => q.push(codes(&t.data)),
        LayerKind::Rowwise { .. } | LayerKind::MatMul { .. } => {
            if t.rank() != 2 {
                return Err(MapError::Shape(format!(
                    "layer `{}` input must be [S][K], got {:?}",
                    layer.name, t.shape
                )));
            }
            let k = t.shape[1];
            for row in t.data.chunks(k) {
                q.push(codes(row));
            }
        }
    }
    if q.len() - before != layer.vectors_per_input {
        return Err(MapError::Shape(format!(
            "layer `{}`: {} activation vectors vs {} at compile time — \
             row indexing requires the static input shape",
            layer.name,
            q.len() - before,
            layer.vectors_per_input
        )));
    }
    Ok(dims)
}

/// Scatter a barrier `run_q`'s output rows back onto their flights: conv
/// rows reassemble to CHW per item, row-wise chunks of `seq` become
/// `[seq][N]`, plain linear is one row per item.
fn assemble_layer_outputs(
    layer: &CompiledLayer,
    rows: Vec<Vec<f32>>,
    dims: &[(usize, usize)],
    flights: &mut [Flight],
) {
    let node = layer.node;
    match layer.kind {
        LayerKind::Conv { out_c, .. } => {
            let mut offset = 0usize;
            for (fl, &(oh, ow)) in flights.iter_mut().zip(dims) {
                fl.values[node] =
                    Some(rows_to_chw(&rows[offset..offset + oh * ow], out_c, oh, ow));
                offset += oh * ow;
            }
        }
        LayerKind::Linear => {
            for (fl, r) in flights.iter_mut().zip(rows) {
                let n = r.len();
                fl.values[node] = Some(Tensor::from_vec(&[n], r));
            }
        }
        LayerKind::Rowwise { seq } => {
            let mut iter = rows.into_iter();
            for fl in flights.iter_mut() {
                let mut data = Vec::new();
                let mut n = 0usize;
                for _ in 0..seq {
                    let r = iter.next().expect("row count matches seq × batch");
                    n = r.len();
                    data.extend(r);
                }
                fl.values[node] = Some(Tensor::from_vec(&[seq, n], data));
            }
        }
        LayerKind::MatMul { .. } => {
            unreachable!("dynamic layers never take the batched run_q path")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::mapping::NativeBackend;
    use crate::nn::mlp::Mlp;
    use crate::util::rng::{Rng, Xoshiro256};

    fn cal_set(dim: usize, n: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..n)
            .map(|_| Tensor::from_vec(&[dim], (0..dim).map(|_| rng.next_f32()).collect()))
            .collect()
    }

    /// A compiled 2-layer MLP equals running its own lowered layers
    /// sequentially on a single macro (noise-free, any worker count).
    #[test]
    fn compiled_mlp_equals_sequential_layers() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let mlp = Mlp::new(&[30, 14, 6], 9);
        let g = Graph::from_mlp(&mlp);
        let cal = cal_set(30, 8, 3);
        let xs = cal_set(30, 5, 77);

        let mut plan =
            compile(g, &cal, &cfg, &CompileOptions { workers: 3, ..Default::default() }).unwrap();
        let got = plan.run_batch(&xs).unwrap();

        // Sequential reference: the SAME lowered layers, one macro, with the
        // MLP's float ops between them.
        let mut nat = NativeBackend::new(cfg.clone());
        let lin0 = plan.layers()[0].linear().clone();
        let lin1 = plan.layers()[1].linear().clone();
        for (x, out) in xs.iter().zip(&got) {
            let s0 = lin0.run_batch(&mut nat, &[x.data.clone()]).unwrap().remove(0);
            let h: Vec<f32> = s0.iter().map(|&v| v.max(0.0)).collect();
            let s1 = lin1.run_batch(&mut nat, &[h]).unwrap().remove(0);
            assert_eq!(out, &s1);
        }
        assert_eq!(
            plan.stats().core_ops as usize,
            (plan.layers()[0].n_tiles() + plan.layers()[1].n_tiles()) * xs.len()
        );
        assert_eq!(plan.stats().weight_loads as usize, plan.total_tiles());
    }

    /// Streamed execution is bit-identical to the barrier path on a fresh
    /// plan with the same seed — noise on and off (the full property lives
    /// in `tests/stream_equivalence.rs`).
    #[test]
    fn streamed_mlp_equals_barrier_bitwise() {
        for noise in [false, true] {
            let mut cfg = Config::default();
            cfg.noise.enabled = noise;
            cfg.enhance = EnhanceConfig::both();
            let mlp = Mlp::new(&[30, 14, 6], 9);
            let g = Graph::from_mlp(&mlp);
            let cal = cal_set(30, 8, 3);
            let xs = cal_set(30, 5, 77);
            let opts = CompileOptions { workers: 3, ..Default::default() };

            let mut barrier = compile(g.clone(), &cal, &cfg, &opts).unwrap();
            let mut streamed = compile(g, &cal, &cfg, &opts).unwrap();
            let want = barrier.run_batch(&xs).unwrap();
            let outcome = streamed
                .run_streamed_with(&xs, &StreamOptions { queue_cap: 2 })
                .unwrap();
            assert_eq!(outcome.outputs, want, "noise={noise}");
            assert_eq!(outcome.item_latency.len(), xs.len());
            assert!(outcome.gauges.len() >= streamed.layers().len());
            assert!(outcome.gauges.iter().all(|g| g.items == xs.len() as u64));
            // Integer device counters agree exactly; energy is the same sum
            // in a different association order, so compare relatively.
            assert_eq!(barrier.stats().core_ops, streamed.stats().core_ops);
            assert_eq!(barrier.stats().total_cycles, streamed.stats().total_cycles);
            assert_eq!(barrier.stats().clipped, streamed.stats().clipped);
            let (ea, eb) = (barrier.stats().energy_fj(), streamed.stats().energy_fj());
            assert!((ea - eb).abs() <= 1e-9 * ea.abs().max(1.0), "energy {ea} vs {eb}");
            // The exact cycle predictor holds for streamed execution too.
            let predicted: u64 =
                streamed.layers().iter().map(|l| l.predicted_cycles()).sum();
            assert_eq!(predicted, streamed.stats().total_cycles);
        }
    }

    /// A second streamed run advances the epochs: noisy outputs decorrelate
    /// instead of replaying one frozen draw, and the replayed epoch matches.
    #[test]
    fn streamed_epochs_advance_and_replay() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both();
        let mlp = Mlp::new(&[20, 8, 4], 2);
        let g = Graph::from_mlp(&mlp);
        let cal = cal_set(20, 6, 4);
        let xs = cal_set(20, 3, 5);
        let mut plan = compile(g, &cal, &cfg, &CompileOptions::default()).unwrap();
        let first = plan.run_streamed(&xs).unwrap();
        let second = plan.run_streamed(&xs).unwrap();
        assert_ne!(first, second, "successive streamed runs must decorrelate");
        plan.set_epoch(0);
        let replay = plan.run_streamed(&xs).unwrap();
        assert_eq!(replay, first, "epoch rewind must replay the draws");
    }

    #[test]
    fn bad_input_shapes_are_rejected() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let mlp = Mlp::new(&[8, 4, 2], 1);
        let g = Graph::from_mlp(&mlp);
        let mut plan =
            compile(g, &cal_set(8, 2, 1), &cfg, &CompileOptions::default()).unwrap();
        assert!(matches!(
            plan.run_flat(&[vec![0.0; 7]]),
            Err(MapError::Shape(_))
        ));
        assert!(matches!(
            plan.run_batch(&[Tensor::zeros(&[9])]),
            Err(MapError::Shape(_))
        ));
    }

    #[test]
    fn quantize_feeding_non_layer_is_rejected() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![4] }, &[]);
        let q = g.add("q", Op::Quantize { params: None }, &[x]);
        g.add("relu", Op::Relu, &[q]);
        let cfg = Config::default();
        let cal = cal_set(4, 2, 5);
        assert!(matches!(
            compile(g, &cal, &cfg, &CompileOptions::default()),
            Err(CompileError::Structure(_))
        ));
    }
}
