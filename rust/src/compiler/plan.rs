//! The executable end of the compiler: [`CompiledPlan`] — a whole network
//! resident on a [`MacroPool`], executed batched through [`BatchExecutor`].
//!
//! `compile` runs the four stages (ingest happened when the graph was
//! built): shape inference + structure checks → calibration → lowering →
//! cost-model-driven placement. The resulting plan owns the pool (weights
//! loaded exactly once) and executes any batch of inputs with per-layer
//! cycle/energy accounting: `observed` device counters from the executor,
//! and the cost model's exact `predicted` cycles alongside (asserted equal
//! in `tests/compiler_equivalence.rs`).
//!
//! Determinism contract: with noise disabled, a compiled plan's outputs are
//! bit-identical to running each lowered layer sequentially through
//! `CimLinear::run_batch` / `CimConv::run` on a single macro, because the
//! per-layer arithmetic is expression-for-expression the same and the
//! batched executor is bit-identical to the sequential tiler.

use crate::compiler::ir::{dequantize, Graph, NodeId, Op};
use crate::compiler::lower::{calibrate, lower, CompileError, LayerKind, LoweredLayer};
use crate::compiler::place::{predicted_tile_cycles, ActivationProfile, CostReport, Placer};
use crate::config::Config;
use crate::mapping::executor::{patches_to_rows, rows_to_chw, CimLinear};
use crate::mapping::{ExecStats, MapError};
use crate::nn::im2col::{conv_out_dims, im2col};
use crate::nn::ops::global_avg_pool;
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;
use crate::pipeline::{BatchExecutor, MacroPool, PlacedLinear};
use crate::util::table::Table;

/// Knobs for [`compile`].
#[derive(Clone, Copy, Debug, Default)]
pub struct CompileOptions {
    /// Batch-executor worker threads (0 = auto).
    pub workers: usize,
    /// RNG seed for the executor's noise substreams (`None` derives from
    /// `cfg.sim.seed`).
    pub seed: Option<u64>,
    /// Activation profile for the placer's energy estimates (`None` =
    /// post-ReLU-like).
    pub profile: Option<ActivationProfile>,
}

/// One placed network layer with its cumulative run accounting.
pub struct CompiledLayer {
    pub name: String,
    node: NodeId,
    src: NodeId,
    kind: LayerKind,
    qparams: QuantParams,
    placed: PlacedLinear,
    observed: ExecStats,
    predicted_cycles: u64,
}

impl CompiledLayer {
    /// The graph node this layer lowers.
    pub fn node(&self) -> NodeId {
        self.node
    }

    pub fn linear(&self) -> &CimLinear {
        self.placed.linear()
    }

    pub fn qparams(&self) -> QuantParams {
        self.qparams
    }

    pub fn kind(&self) -> LayerKind {
        self.kind
    }

    pub fn n_tiles(&self) -> usize {
        self.placed.n_tiles()
    }

    /// Device counters accumulated over every batch this layer ran.
    pub fn observed(&self) -> &ExecStats {
        &self.observed
    }

    /// The cost model's cycle prediction for the same runs (exact: equals
    /// `observed().total_cycles`).
    pub fn predicted_cycles(&self) -> u64 {
        self.predicted_cycles
    }
}

/// A compiled network resident on a macro pool.
///
/// A plan owns its pool (weights loaded exactly once at compile time) and
/// serves any number of batches with per-layer cycle/energy accounting:
///
/// ```
/// use cimsim::compiler::{compile, CompileOptions, Graph};
/// use cimsim::config::Config;
/// use cimsim::nn::mlp::Mlp;
/// use cimsim::nn::tensor::Tensor;
///
/// let mut cfg = Config::default();
/// cfg.noise.enabled = false;
/// let graph = Graph::from_mlp(&Mlp::new(&[10, 5, 3], 2));
/// let cal = vec![Tensor::from_vec(&[10], (0..10).map(|i| i as f32 / 10.0).collect())];
/// let mut plan = compile(graph, &cal, &cfg, &CompileOptions::default()).unwrap();
///
/// // Flat-vector serving form; batches of any size.
/// let out = plan.run_flat(&[vec![0.1; 10], vec![0.9; 10]]).unwrap();
/// assert_eq!((out.len(), out[0].len()), (2, 3));
///
/// // Device counters accumulate per layer and in total; the cost model's
/// // cycle prediction is exact (asserted in tests/compiler_equivalence.rs).
/// assert_eq!(
///     plan.stats().total_cycles,
///     plan.layers().iter().map(|l| l.predicted_cycles()).sum::<u64>(),
/// );
/// ```
///
/// Memory note: a plan keeps the ingested graph (float weights — backs
/// [`Graph::eval_float`] golden references) and each layer's tiled integer
/// planes (backs [`CompiledLayer::linear`] sequential references) alongside
/// the pool's loaded weights. For ResNet-20 that is a few MB total — a
/// deliberate simulator tradeoff of memory for introspection; only the pool
/// copy is touched on the execute hot path.
pub struct CompiledPlan {
    cfg: Config,
    graph: Graph,
    pool: MacroPool,
    exec: BatchExecutor,
    layers: Vec<CompiledLayer>,
    /// node id → compiled layer index (for `Conv2d`/`Linear` nodes).
    node_layer: Vec<Option<usize>>,
    /// Per node: the nodes whose *values* it reads at runtime (quantize
    /// boundaries resolved to their producers).
    data_src: Vec<Vec<NodeId>>,
    /// Last node id that reads each node's value (liveness for buffer reuse).
    last_use: Vec<usize>,
    output_node: NodeId,
    report: CostReport,
    stats: ExecStats,
}

/// Compile a graph onto a fresh [`MacroPool`]: calibrate on `cal_inputs`,
/// lower every layer, place tiles with the cost-model-driven placer, load
/// weights once.
pub fn compile(
    graph: Graph,
    cal_inputs: &[Tensor],
    cfg: &Config,
    opts: &CompileOptions,
) -> Result<CompiledPlan, CompileError> {
    let shapes = graph.infer_shapes().map_err(CompileError::Structure)?;
    check_quantize_structure(&graph)?;
    let cal = calibrate(&graph, cal_inputs)?;
    let lowered = lower(&graph, &shapes, &cal, cfg)?;

    let mut pool = MacroPool::new(cfg.clone());
    // Pre-size the pool to the exact shard count the lowered network needs,
    // so the placer has every die as a candidate and genuinely balances
    // estimated per-shard work (instead of dense-filling one die at a time).
    let needed_tiles: usize = lowered
        .iter()
        .map(|l| l.lin.n_row_tiles() * l.lin.n_col_tiles())
        .sum();
    pool.grow_to(needed_tiles.div_ceil(cfg.mac.cores.max(1)));
    let profile = opts.profile.unwrap_or_else(|| ActivationProfile::relu_like(cfg));
    let mut placer = Placer::new(profile);
    let mut layers = Vec::with_capacity(lowered.len());
    let mut node_layer = vec![None; graph.nodes.len()];
    let mut report_layers = Vec::with_capacity(lowered.len());
    for LoweredLayer { node, src, name, kind, qparams, lin, vectors_per_input } in lowered {
        let kind_label = match kind {
            LayerKind::Conv { .. } => "conv",
            LayerKind::Linear => "linear",
        };
        let (placed, cost) =
            placer.place_layer(&mut pool, lin, &name, kind_label, vectors_per_input)?;
        node_layer[node] = Some(layers.len());
        layers.push(CompiledLayer {
            name,
            node,
            src,
            kind,
            qparams,
            placed,
            observed: ExecStats::default(),
            predicted_cycles: 0,
        });
        report_layers.push(cost);
    }

    let total_tiles: usize = layers.iter().map(|l| l.placed.n_tiles()).sum();
    let report = CostReport {
        layers: report_layers,
        total_tiles,
        n_shards: pool.n_shards(),
        weight_kb: total_tiles as f64 * cfg.mac.core_kb(),
    };

    let n = graph.nodes.len();
    let mut data_src: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for (id, node) in graph.nodes.iter().enumerate() {
        if let Some(li) = node_layer[id] {
            data_src[id] = vec![layers[li].src];
        } else if !matches!(node.op, Op::Quantize { .. }) {
            data_src[id] = node.inputs.clone();
        }
    }
    let output_node = graph.output();
    let mut last_use = vec![0usize; n];
    for (id, srcs) in data_src.iter().enumerate() {
        for &s in srcs {
            last_use[s] = last_use[s].max(id);
        }
    }
    last_use[output_node] = usize::MAX;

    let seed = opts.seed.unwrap_or(cfg.sim.seed ^ 0xC09B_11E5);
    let stats = ExecStats { weight_loads: total_tiles as u64, ..ExecStats::default() };
    Ok(CompiledPlan {
        cfg: cfg.clone(),
        graph,
        pool,
        exec: BatchExecutor::new(opts.workers, seed),
        layers,
        node_layer,
        data_src,
        last_use,
        output_node,
        report,
        stats,
    })
}

/// `Quantize` nodes may only feed `Conv2d`/`Linear` (they are fused into
/// the placed layer), may not chain, and may not be the graph output.
fn check_quantize_structure(graph: &Graph) -> Result<(), CompileError> {
    for node in &graph.nodes {
        let is_cim = matches!(node.op, Op::Conv2d { .. } | Op::Linear { .. });
        for &i in &node.inputs {
            if matches!(graph.nodes[i].op, Op::Quantize { .. }) && !is_cim {
                return Err(CompileError::Structure(format!(
                    "Quantize `{}` feeds non-layer `{}`",
                    graph.nodes[i].name, node.name
                )));
            }
        }
    }
    if matches!(graph.nodes[graph.output()].op, Op::Quantize { .. }) {
        return Err(CompileError::Structure("graph output is a Quantize node".into()));
    }
    Ok(())
}

impl CompiledPlan {
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn pool(&self) -> &MacroPool {
        &self.pool
    }

    pub fn workers(&self) -> usize {
        self.exec.workers()
    }

    pub fn layers(&self) -> &[CompiledLayer] {
        &self.layers
    }

    pub fn total_tiles(&self) -> usize {
        self.report.total_tiles
    }

    /// The placement-time cost estimates.
    pub fn cost_report(&self) -> &CostReport {
        &self.report
    }

    /// Cumulative device counters over every batch served.
    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
        for l in &mut self.layers {
            l.observed = ExecStats::default();
            l.predicted_cycles = 0;
        }
    }

    /// The network's input shape.
    pub fn input_shape(&self) -> Vec<usize> {
        self.graph.input_shape().expect("compiled graph has an input").to_vec()
    }

    /// Run a batch of inputs through the resident network; returns the
    /// output node's value per item, flattened.
    pub fn run_batch(&mut self, xs: &[Tensor]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_batch_owned(xs.to_vec())
    }

    /// Owned-input form of [`CompiledPlan::run_batch`] — the serving hot
    /// path: the batch is materialized exactly once.
    pub fn run_batch_owned(&mut self, xs: Vec<Tensor>) -> Result<Vec<Vec<f32>>, MapError> {
        let mut input = Some(xs);
        let n_nodes = self.graph.nodes.len();
        let mut values: Vec<Option<Vec<Tensor>>> = (0..n_nodes).map(|_| None).collect();
        for id in 0..n_nodes {
            if let Some(li) = self.node_layer[id] {
                let src = self.layers[li].src;
                let items = values[src]
                    .as_ref()
                    .ok_or_else(|| MapError::Shape(format!("value of node {src} unavailable")))?;
                let (out, stats) =
                    run_layer(&self.cfg, &self.pool, &self.exec, &mut self.layers[li], items)?;
                self.stats.merge(&stats);
                values[id] = Some(out);
            } else {
                let node = &self.graph.nodes[id];
                // Fetch an input value, moving it on its final read
                // (liveness) instead of cloning; `allow_take: false` forces
                // a clone when the same node feeds two inputs.
                let arg = |values: &mut [Option<Vec<Tensor>>],
                           i: usize,
                           allow_take: bool|
                 -> Result<Vec<Tensor>, MapError> {
                    let src = node.inputs[i];
                    let v = if allow_take && self.last_use[src] == id {
                        values[src].take()
                    } else {
                        values[src].as_ref().cloned()
                    };
                    v.ok_or_else(|| MapError::Shape("value consumed too early".into()))
                };
                let out = match &node.op {
                    Op::Input { shape } => {
                        let batch = input.take().ok_or_else(|| {
                            MapError::Shape("graph has more than one Input node".into())
                        })?;
                        for t in &batch {
                            if t.shape != *shape {
                                return Err(MapError::Shape(format!(
                                    "input shape {:?} vs plan {:?}",
                                    t.shape, shape
                                )));
                            }
                        }
                        Some(batch)
                    }
                    // Fused into the consuming layer; holds no value.
                    Op::Quantize { .. } => None,
                    Op::Dequantize { scale, bias } => Some(
                        arg(&mut values, 0, true)?
                            .iter()
                            .map(|t| dequantize(t, *scale, bias))
                            .collect(),
                    ),
                    Op::Relu => Some(
                        arg(&mut values, 0, true)?
                            .into_iter()
                            .map(|t| t.map(|v| v.max(0.0)))
                            .collect(),
                    ),
                    Op::Add => {
                        let distinct = node.inputs[0] != node.inputs[1];
                        let a = arg(&mut values, 0, distinct)?;
                        let b = arg(&mut values, 1, true)?;
                        let mut out = Vec::with_capacity(a.len());
                        for (ta, tb) in a.into_iter().zip(&b) {
                            if ta.shape != tb.shape {
                                return Err(MapError::Shape(format!(
                                    "add shapes {:?} vs {:?}",
                                    ta.shape, tb.shape
                                )));
                            }
                            let mut t = ta;
                            for (o, i) in t.data.iter_mut().zip(&tb.data) {
                                *o += i;
                            }
                            out.push(t);
                        }
                        Some(out)
                    }
                    Op::GlobalAvgPool => Some(
                        arg(&mut values, 0, true)?
                            .iter()
                            .map(|t| {
                                let c = t.shape[0];
                                Tensor::from_vec(&[c], global_avg_pool(t))
                            })
                            .collect(),
                    ),
                    Op::Conv2d { .. } | Op::Linear { .. } => {
                        unreachable!("layer nodes are handled by node_layer")
                    }
                };
                values[id] = out;
            }
            for &src in &self.data_src[id] {
                if self.last_use[src] == id {
                    values[src] = None;
                }
            }
        }
        let out = values[self.output_node]
            .take()
            .ok_or_else(|| MapError::Shape("output value missing".into()))?;
        Ok(out.into_iter().map(|t| t.data).collect())
    }

    /// Flat-vector convenience for serving: wraps each request into the
    /// plan's input shape.
    pub fn run_flat(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        let shape = self.input_shape();
        let len: usize = shape.iter().product();
        let tensors: Vec<Tensor> = xs
            .iter()
            .map(|x| {
                if x.len() != len {
                    return Err(MapError::Shape(format!(
                        "request length {} vs plan input {len}",
                        x.len()
                    )));
                }
                Ok(Tensor::from_vec(&shape, x.clone()))
            })
            .collect::<Result<_, _>>()?;
        self.run_batch_owned(tensors)
    }

    /// Per-layer observed vs predicted run accounting (after at least one
    /// batch).
    pub fn observed_table(&self) -> Table {
        let mut t = Table::new(
            "per-layer run accounting (cumulative)",
            &["layer", "core ops", "cycles", "predicted", "uJ", "clipped"],
        );
        for l in &self.layers {
            t.row(&[
                l.name.clone(),
                l.observed.core_ops.to_string(),
                l.observed.total_cycles.to_string(),
                l.predicted_cycles.to_string(),
                format!("{:.3}", l.observed.energy_fj() * 1e-9),
                l.observed.clipped.to_string(),
            ]);
        }
        t.row(&[
            "TOTAL".into(),
            self.stats.core_ops.to_string(),
            self.stats.total_cycles.to_string(),
            self.layers.iter().map(|l| l.predicted_cycles).sum::<u64>().to_string(),
            format!("{:.3}", self.stats.energy_fj() * 1e-9),
            self.stats.clipped.to_string(),
        ]);
        t
    }
}

/// One placed layer over a batch of input values: (im2col →) quantize →
/// pooled tiled matmul (→ CHW). Updates the layer's observed counters and
/// the cost model's exact cycle prediction.
fn run_layer(
    cfg: &Config,
    pool: &MacroPool,
    exec: &BatchExecutor,
    layer: &mut CompiledLayer,
    items: &[Tensor],
) -> Result<(Vec<Tensor>, ExecStats), MapError> {
    let mut q: Vec<Vec<i64>> = Vec::new();
    let mut dims: Vec<(usize, usize)> = Vec::new();
    match layer.kind {
        LayerKind::Conv { kh, kw, stride, pad, .. } => {
            for t in items {
                if t.rank() != 3 {
                    return Err(MapError::Shape(format!(
                        "conv `{}` input must be CHW, got {:?}",
                        layer.name, t.shape
                    )));
                }
                let patches = im2col(t, kh, kw, stride, pad);
                for row in patches_to_rows(&patches) {
                    q.push(layer.qparams.quantize_vec(&row));
                }
                dims.push(conv_out_dims(t.shape[1], t.shape[2], kh, kw, stride, pad));
            }
        }
        LayerKind::Linear => {
            for t in items {
                q.push(layer.qparams.quantize_vec(&t.data));
            }
        }
    }
    layer.predicted_cycles += predicted_tile_cycles(cfg, layer.placed.linear(), &q);
    let (rows, stats) = exec.run_q(pool, &layer.placed, &q)?;
    layer.observed.merge(&stats);
    let out = match layer.kind {
        LayerKind::Conv { out_c, .. } => {
            let mut out = Vec::with_capacity(items.len());
            let mut offset = 0usize;
            for &(oh, ow) in &dims {
                out.push(rows_to_chw(&rows[offset..offset + oh * ow], out_c, oh, ow));
                offset += oh * ow;
            }
            out
        }
        LayerKind::Linear => rows
            .into_iter()
            .map(|r| {
                let n = r.len();
                Tensor::from_vec(&[n], r)
            })
            .collect(),
    };
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::mapping::NativeBackend;
    use crate::nn::mlp::Mlp;
    use crate::util::rng::{Rng, Xoshiro256};

    fn cal_set(dim: usize, n: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..n)
            .map(|_| Tensor::from_vec(&[dim], (0..dim).map(|_| rng.next_f32()).collect()))
            .collect()
    }

    /// A compiled 2-layer MLP equals running its own lowered layers
    /// sequentially on a single macro (noise-free, any worker count).
    #[test]
    fn compiled_mlp_equals_sequential_layers() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let mlp = Mlp::new(&[30, 14, 6], 9);
        let g = Graph::from_mlp(&mlp);
        let cal = cal_set(30, 8, 3);
        let xs = cal_set(30, 5, 77);

        let mut plan =
            compile(g, &cal, &cfg, &CompileOptions { workers: 3, ..Default::default() }).unwrap();
        let got = plan.run_batch(&xs).unwrap();

        // Sequential reference: the SAME lowered layers, one macro, with the
        // MLP's float ops between them.
        let mut nat = NativeBackend::new(cfg.clone());
        let lin0 = plan.layers()[0].linear().clone();
        let lin1 = plan.layers()[1].linear().clone();
        for (x, out) in xs.iter().zip(&got) {
            let s0 = lin0.run_batch(&mut nat, &[x.data.clone()]).unwrap().remove(0);
            let h: Vec<f32> = s0.iter().map(|&v| v.max(0.0)).collect();
            let s1 = lin1.run_batch(&mut nat, &[h]).unwrap().remove(0);
            assert_eq!(out, &s1);
        }
        assert_eq!(
            plan.stats().core_ops as usize,
            (plan.layers()[0].n_tiles() + plan.layers()[1].n_tiles()) * xs.len()
        );
        assert_eq!(plan.stats().weight_loads as usize, plan.total_tiles());
    }

    #[test]
    fn bad_input_shapes_are_rejected() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let mlp = Mlp::new(&[8, 4, 2], 1);
        let g = Graph::from_mlp(&mlp);
        let mut plan =
            compile(g, &cal_set(8, 2, 1), &cfg, &CompileOptions::default()).unwrap();
        assert!(matches!(
            plan.run_flat(&[vec![0.0; 7]]),
            Err(MapError::Shape(_))
        ));
        assert!(matches!(
            plan.run_batch(&[Tensor::zeros(&[9])]),
            Err(MapError::Shape(_))
        ));
    }

    #[test]
    fn quantize_feeding_non_layer_is_rejected() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![4] }, &[]);
        let q = g.add("q", Op::Quantize { params: None }, &[x]);
        g.add("relu", Op::Relu, &[q]);
        let cfg = Config::default();
        let cal = cal_set(4, 2, 5);
        assert!(matches!(
            compile(g, &cal, &cfg, &CompileOptions::default()),
            Err(CompileError::Structure(_))
        ));
    }
}
