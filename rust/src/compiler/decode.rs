//! Autoregressive KV-cache decoding on the macro pool (DESIGN.md §13).
//!
//! [`DecodePlan`] compiles a [`DecoderModel`] for token-at-a-time
//! execution: every *static* weight (per-head Wq/Wk/Wv/Wo, the FFN pair,
//! the LM head) is placed **once** on one shared [`MacroPool`] and never
//! reloads — reload-amortization falls out of the execution order, which
//! runs every head of a layer against its resident grids before moving to
//! the next layer. The *runtime* tensors of attention (the growing K/V
//! slabs) live per session on dedicated [`KvCache`] grids with incremental
//! running-max requantization and strip reloads.
//!
//! **Determinism (DESIGN.md §9/§13).** Every core op's noise key is
//! `(session_seed, step · SITES + site, 0, tile)`: the per-step epoch
//! stride `SITES` counts the fixed op sites of one token step (per block:
//! 6 per head — q, k, v, scores, context, out — plus ffn1/ffn2; plus the
//! LM head), and `session_seed` is derived from the plan seed and the
//! session id. A session's outputs are therefore a pure function of
//! `(plan, session id, token sequence)` — independent of co-resident
//! sessions, of barrier vs streamed scheduling, and replayable from
//! position zero (the stateless oracle of `tests/decode_equivalence.rs`).
//!
//! [`ContinuousBatcher`] adds token-level continuous batching: sessions
//! occupy slots, every [`ContinuousBatcher::step_all`] round advances each
//! active session by one token (prefill feeds prompt tokens through the
//! same step machinery), new requests join between rounds, and finished
//! sequences free their slot (dropping their KV grids). Streamed mode
//! pipelines the round through `sched::run_stages` with one stage per
//! block plus the head, names keyed by the generation step.

use crate::cim::MacroError;
use crate::config::Config;
use crate::mapping::executor::CimLinear;
use crate::mapping::{ExecStats, MapError};
use crate::nn::ops::{layer_norm, softmax};
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;
use crate::nn::transformer::{DecoderModel, LN_EPS};
use crate::pipeline::batch::{run_vector, StreamCtx, StreamKey};
use crate::pipeline::kv_cache::KvCache;
use crate::pipeline::pool::{MacroPool, PlacedLinear};
use crate::sched::run_stages;
use crate::util::rng::SplitMix64;

/// Greedy decoding: index of the largest logit (first wins ties — strict
/// `>` keeps the choice bit-deterministic across execution modes).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// Running min/max over calibration activations (the float traces).
#[derive(Clone, Copy)]
struct Range {
    lo: f32,
    hi: f32,
}

impl Range {
    fn new() -> Self {
        Self { lo: f32::INFINITY, hi: f32::NEG_INFINITY }
    }

    fn absorb(&mut self, xs: &[f32]) {
        for &v in xs {
            self.lo = self.lo.min(v);
            self.hi = self.hi.max(v);
        }
    }

    /// Mirror of `lower::Calibration::params`: signed zero-point format
    /// when the boundary goes negative, unsigned otherwise, 1e-6 floor.
    fn params(&self, bits: u32) -> QuantParams {
        let (lo, hi) = if self.lo.is_finite() { (self.lo, self.hi) } else { (0.0, 1.0) };
        if lo < 0.0 {
            QuantParams::signed_acts((-lo).max(hi).max(1e-6), bits)
        } else {
            QuantParams::unsigned(hi.max(1e-6), bits)
        }
    }
}

/// One head's static projection grids, resident on the shared pool.
struct HeadPlan {
    wq: PlacedLinear,
    wk: PlacedLinear,
    wv: PlacedLinear,
    wo: PlacedLinear,
}

/// One block's static grids plus the KV-cache activation boundaries.
struct BlockPlan {
    heads: Vec<HeadPlan>,
    ffn1: PlacedLinear,
    ffn2: PlacedLinear,
    /// Query boundary of the score grids (keys caches' act params).
    q_params: QuantParams,
}

/// A decoder compiled for autoregressive execution on the pool.
pub struct DecodePlan {
    model: DecoderModel,
    cfg: Config,
    seed: u64,
    pool: MacroPool,
    blocks: Vec<BlockPlan>,
    head: PlacedLinear,
    /// First noise site of each block within a step.
    site_base: Vec<u64>,
    /// Noise sites per token step (the per-step epoch stride).
    sites: u64,
    /// Softmax-probability boundary of every values cache (zp = 0).
    probs_params: QuantParams,
}

impl DecodePlan {
    /// Compile `model` for decoding: calibrate every activation boundary
    /// by running the **causal** float traces over `cal` (token
    /// sequences), then place all static grids on one shared pool. All
    /// boundary params are fixed here — only the KV caches' weight scales
    /// are running quantities at decode time (DESIGN.md §13).
    pub fn new(
        model: DecoderModel,
        cal: &[Vec<usize>],
        cfg: &Config,
        seed: Option<u64>,
    ) -> Result<Self, MacroError> {
        assert!(
            !cal.is_empty() && cal.iter().all(|s| !s.is_empty()),
            "decode calibration needs at least one non-empty token sequence"
        );
        let seed = seed.unwrap_or(cfg.sim.seed ^ 0xDEC0_DE5E);
        let l = model.blocks.len();
        assert!(l > 0, "decoder has no blocks");

        let mut x_r = vec![Range::new(); l];
        let mut q_r = vec![Range::new(); l];
        let mut ctx_r = vec![Range::new(); l];
        let mut h1_r = vec![Range::new(); l];
        let mut f_r = vec![Range::new(); l];
        let mut head_r = Range::new();
        for toks in cal {
            assert!(toks.len() <= model.max_seq, "calibration sequence longer than max_seq");
            let mut x = model.embed_seq(toks);
            for (b, blk) in model.blocks.iter().enumerate() {
                x_r[b].absorb(&x.data);
                let tr = blk.forward_causal_traced(&x);
                for t in &tr.q {
                    q_r[b].absorb(&t.data);
                }
                for t in &tr.ctx {
                    ctx_r[b].absorb(&t.data);
                }
                h1_r[b].absorb(&tr.h1.data);
                f_r[b].absorb(&tr.f_relu.data);
                x = tr.out;
            }
            head_r.absorb(&x.data);
        }

        let (wb, ab) = (cfg.mac.weight_bits, cfg.mac.act_bits);
        let mut pool = MacroPool::new(cfg.clone());
        let mut place = |pool: &mut MacroPool,
                         w: &Tensor,
                         bias: Vec<f32>,
                         ap: QuantParams|
         -> Result<PlacedLinear, MacroError> {
            let wp = QuantParams::signed(w.max_abs(), wb);
            PlacedLinear::place(CimLinear::with_params(w, bias, wp, ap, cfg), pool)
        };

        let mut blocks = Vec::with_capacity(l);
        let mut site_base = Vec::with_capacity(l);
        let mut site = 0u64;
        for (b, blk) in model.blocks.iter().enumerate() {
            let xp = x_r[b].params(ab);
            let cp = ctx_r[b].params(ab);
            let mut heads = Vec::with_capacity(blk.heads);
            for i in 0..blk.heads {
                heads.push(HeadPlan {
                    wq: place(&mut pool, &blk.wq[i], blk.bq[i].clone(), xp)?,
                    wk: place(&mut pool, &blk.wk[i], blk.bk[i].clone(), xp)?,
                    wv: place(&mut pool, &blk.wv[i], blk.bv[i].clone(), xp)?,
                    // b_o applies once after the head sum (digitally).
                    wo: place(&mut pool, &blk.wo[i], vec![0.0; blk.d_model], cp)?,
                });
            }
            let ffn1 = place(&mut pool, &blk.w_ff1, blk.b_ff1.clone(), h1_r[b].params(ab))?;
            let ffn2 = place(&mut pool, &blk.w_ff2, blk.b_ff2.clone(), f_r[b].params(ab))?;
            blocks.push(BlockPlan { heads, ffn1, ffn2, q_params: q_r[b].params(ab) });
            site_base.push(site);
            site += 6 * blk.heads as u64 + 2;
        }
        let head = place(&mut pool, &model.w_head, model.b_head.clone(), head_r.params(ab))?;
        let sites = site + 1; // the LM-head site closes each step

        Ok(Self {
            model,
            cfg: cfg.clone(),
            seed,
            pool,
            blocks,
            head,
            site_base,
            sites,
            probs_params: QuantParams::unsigned(1.0, ab),
        })
    }

    pub fn model(&self) -> &DecoderModel {
        &self.model
    }

    pub fn cfg(&self) -> &Config {
        &self.cfg
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn max_seq(&self) -> usize {
        self.model.max_seq
    }

    /// Noise sites per token step (the per-step epoch stride).
    pub fn sites(&self) -> u64 {
        self.sites
    }

    /// Static tiles resident on the shared pool.
    pub fn static_tiles(&self) -> usize {
        let mut n = self.head.n_tiles();
        for bp in &self.blocks {
            n += bp.ffn1.n_tiles() + bp.ffn2.n_tiles();
            for hp in &bp.heads {
                n += hp.wq.n_tiles() + hp.wk.n_tiles() + hp.wv.n_tiles() + hp.wo.n_tiles();
            }
        }
        n
    }

    /// Open a fresh session. Outputs are a pure function of
    /// `(plan, id, token sequence)`: the session seed derives from the
    /// plan seed and `id`, so re-opening the same id replays the exact
    /// noise draws — and distinct ids decorrelate, which is what makes
    /// co-batched sessions bit-equal to solo runs (DESIGN.md §13).
    pub fn session(&self, id: u64) -> Result<DecodeSession, MacroError> {
        let seed = SplitMix64::new(self.seed ^ id).next_u64();
        // Dedicated fab draws per session grid, far above the compiler's
        // dynamic-layer block (`plan::DYN_FAB_BASE` = 1<<30) and bounded
        // so the shard-index add can't overflow.
        let fab0 = (1usize << 31) + (((id as usize) & 0xF_FFFF) << 12);
        let mut gi = 0usize;
        let mut kv = Vec::with_capacity(self.blocks.len());
        for (b, bp) in self.blocks.iter().enumerate() {
            let dh = self.model.blocks[b].d_head();
            let mut k = Vec::with_capacity(bp.heads.len());
            let mut v = Vec::with_capacity(bp.heads.len());
            for _ in 0..bp.heads.len() {
                k.push(KvCache::keys(&self.cfg, dh, self.model.max_seq, fab0 + gi, bp.q_params)?);
                gi += 1;
                v.push(KvCache::values(
                    &self.cfg,
                    dh,
                    self.model.max_seq,
                    fab0 + gi,
                    self.probs_params,
                )?);
                gi += 1;
            }
            kv.push(BlockKv { k, v });
        }
        crate::telemetry::decode().sessions.inc();
        Ok(DecodeSession {
            id,
            seed,
            pos: 0,
            tokens: Vec::new(),
            kv,
            stats: ExecStats::default(),
            step_stats: ExecStats::default(),
            last_step: ExecStats::default(),
            ctx: StreamCtx::new(&self.cfg),
            last_logits: Vec::new(),
        })
    }

    fn run_static(
        &self,
        placed: &PlacedLinear,
        key: StreamKey,
        x: &[f32],
        ctx: &mut StreamCtx,
        stats: &mut ExecStats,
    ) -> Result<Vec<f32>, MapError> {
        let acts = placed.linear().quantize_acts(x);
        run_vector(&self.pool, placed, key, &acts, ctx, stats)
    }

    /// Start a token step: validate, reset the step's stats chunk, and
    /// embed `token` at the session's current position.
    pub fn begin_step(&self, s: &mut DecodeSession, token: usize) -> Result<Vec<f32>, MapError> {
        if s.pos >= self.model.max_seq {
            return Err(MapError::Shape(format!(
                "decode position {} at max_seq {}",
                s.pos, self.model.max_seq
            )));
        }
        if token >= self.model.vocab {
            return Err(MapError::Shape(format!(
                "token {token} outside vocab {}",
                self.model.vocab
            )));
        }
        s.step_stats = ExecStats::default();
        Ok(self.model.embed_token(token, s.pos))
    }

    /// Run block `b` of the current token step: all heads against the
    /// block's resident grids (q/k/v projections, KV append, ragged
    /// scores and context, output projection), then the FFN pair —
    /// digital softmax/LayerNorm/residuals exactly as the float model.
    pub fn step_block(
        &self,
        s: &mut DecodeSession,
        b: usize,
        x: Vec<f32>,
    ) -> Result<Vec<f32>, MapError> {
        let blk = &self.model.blocks[b];
        let bp = &self.blocks[b];
        let d = blk.d_model;
        let inv = 1.0 / (blk.d_head() as f32).sqrt();
        let seed = s.seed;
        let epoch0 = s.pos as u64 * self.sites + self.site_base[b];
        let _span = crate::span!("decode_block", "block" => b, "pos" => s.pos);

        let mut attn = vec![0f32; d];
        for (h, hp) in bp.heads.iter().enumerate() {
            let site = epoch0 + 6 * h as u64;
            let key = |o: u64| StreamKey { seed, epoch: site + o, item: 0 };
            let q = self.run_static(&hp.wq, key(0), &x, &mut s.ctx, &mut s.step_stats)?;
            let k = self.run_static(&hp.wk, key(1), &x, &mut s.ctx, &mut s.step_stats)?;
            let v = self.run_static(&hp.wv, key(2), &x, &mut s.ctx, &mut s.step_stats)?;
            // Appends reload weight strips: cycles/energy, no noise draws.
            s.kv[b].k[h].append(&k, &mut s.step_stats)?;
            s.kv[b].v[h].append(&v, &mut s.step_stats)?;
            let q_acts = s.kv[b].k[h].quantize_acts(&q);
            let scores = s.kv[b].k[h].run(key(3), &q_acts, &mut s.ctx, &mut s.step_stats)?;
            let scaled: Vec<f32> = scores.iter().map(|v| v * inv).collect();
            let probs = softmax(&scaled);
            let p_acts = s.kv[b].v[h].quantize_acts(&probs);
            let ctxv = s.kv[b].v[h].run(key(4), &p_acts, &mut s.ctx, &mut s.step_stats)?;
            let ho = self.run_static(&hp.wo, key(5), &ctxv, &mut s.ctx, &mut s.step_stats)?;
            for (a, o) in attn.iter_mut().zip(&ho) {
                *a += o;
            }
        }
        for (a, bo) in attn.iter_mut().zip(&blk.b_o) {
            *a += bo;
        }
        for (a, xv) in attn.iter_mut().zip(&x) {
            *a += xv;
        }
        let h1 = layer_norm(&Tensor::from_vec(&[d], attn), &blk.ln1_gamma, &blk.ln1_beta, LN_EPS);

        let site_f = epoch0 + 6 * bp.heads.len() as u64;
        let kf = |o: u64| StreamKey { seed, epoch: site_f + o, item: 0 };
        let f = self.run_static(&bp.ffn1, kf(0), &h1.data, &mut s.ctx, &mut s.step_stats)?;
        let f: Vec<f32> = f.iter().map(|v| v.max(0.0)).collect();
        let f2 = self.run_static(&bp.ffn2, kf(1), &f, &mut s.ctx, &mut s.step_stats)?;
        let res: Vec<f32> = f2.iter().zip(&h1.data).map(|(a, b)| a + b).collect();
        let out = layer_norm(&Tensor::from_vec(&[d], res), &blk.ln2_gamma, &blk.ln2_beta, LN_EPS);
        Ok(out.data)
    }

    /// Close a token step: LM head, session bookkeeping, and the per-step
    /// telemetry record (the decode series' single feed point).
    pub fn finish_step(
        &self,
        s: &mut DecodeSession,
        x: Vec<f32>,
        token: usize,
    ) -> Result<Vec<f32>, MapError> {
        let epoch = s.pos as u64 * self.sites + (self.sites - 1);
        let key = StreamKey { seed: s.seed, epoch, item: 0 };
        let logits = self.run_static(&self.head, key, &x, &mut s.ctx, &mut s.step_stats)?;
        s.tokens.push(token);
        s.pos += 1;
        s.last_logits.clone_from(&logits);
        crate::telemetry::decode().record_step(&s.step_stats);
        let chunk = std::mem::take(&mut s.step_stats);
        s.stats.merge(&chunk);
        s.last_step = chunk;
        Ok(logits)
    }

    /// One full token step: embed, every block, LM head. Returns the
    /// logits over the vocabulary.
    pub fn step(&self, s: &mut DecodeSession, token: usize) -> Result<Vec<f32>, MapError> {
        let mut x = self.begin_step(s, token)?;
        for b in 0..self.blocks.len() {
            x = self.step_block(s, b, x)?;
        }
        self.finish_step(s, x, token)
    }

    /// Barrier-mode convenience: feed the prompt token by token, then
    /// greedy-decode `n_gen` tokens. Step-for-step identical to what a
    /// [`ContinuousBatcher`] slot does for the same session (the last
    /// generated token is emitted without being fed back).
    pub fn generate(
        &self,
        s: &mut DecodeSession,
        prompt: &[usize],
        n_gen: usize,
    ) -> Result<Vec<usize>, MapError> {
        assert!(!prompt.is_empty(), "generate needs at least one prompt token");
        let mut generated = Vec::with_capacity(n_gen);
        let mut fed = 0usize;
        while fed < prompt.len() || generated.len() < n_gen {
            let tok = if fed < prompt.len() {
                prompt[fed]
            } else {
                *generated.last().expect("generation phase implies a generated token")
            };
            self.step(s, tok)?;
            if fed < prompt.len() {
                fed += 1;
            }
            if fed == prompt.len() && generated.len() < n_gen {
                generated.push(argmax(&s.last_logits));
            }
        }
        Ok(generated)
    }
}

/// One block's per-head KV caches.
struct BlockKv {
    k: Vec<KvCache>,
    v: Vec<KvCache>,
}

/// One sequence's decode state: KV grids, position, per-session RNG seed,
/// accumulated stats. Sessions are fully independent — they share only
/// the plan's read-only static pool.
pub struct DecodeSession {
    id: u64,
    seed: u64,
    pos: usize,
    tokens: Vec<usize>,
    kv: Vec<BlockKv>,
    /// Session totals (per-step chunks merged in step order).
    stats: ExecStats,
    /// The current step's chunk (reset by `begin_step`, folded and
    /// telemetry-recorded by `finish_step`).
    step_stats: ExecStats,
    /// The last completed step's chunk — the exact `ExecStats` that
    /// `finish_step` handed to the telemetry decode series, so replays
    /// can mirror the global counters' per-step accumulation order
    /// bit for bit (`tests/telemetry_e2e.rs`).
    last_step: ExecStats,
    ctx: StreamCtx,
    last_logits: Vec<f32>,
}

impl DecodeSession {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Tokens consumed so far (= the next step index).
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn tokens(&self) -> &[usize] {
        &self.tokens
    }

    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    /// The last completed token step's stats chunk (what the telemetry
    /// decode series recorded for it).
    pub fn last_step_stats(&self) -> &ExecStats {
        &self.last_step
    }

    pub fn last_logits(&self) -> &[f32] {
        &self.last_logits
    }

    /// KV-cache reloads across every grid (strip appends + rescales).
    pub fn kv_reloads(&self) -> u64 {
        self.kv
            .iter()
            .flat_map(|b| b.k.iter().chain(b.v.iter()))
            .map(|c| c.grid().reloads())
            .sum()
    }
}

/// A decode request: prompt tokens plus how many tokens to generate.
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub prompt: Vec<usize>,
    pub n_gen: usize,
}

/// A completed sequence leaving the batcher.
pub struct Finished {
    pub slot: usize,
    pub session_id: u64,
    pub prompt: Vec<usize>,
    pub generated: Vec<usize>,
    /// Token steps the session executed (prefill + decode).
    pub steps: u64,
    pub stats: ExecStats,
}

struct ActiveSeq {
    session: DecodeSession,
    prompt: Vec<usize>,
    fed: usize,
    n_gen: usize,
    generated: Vec<usize>,
}

/// A step item moving through the streamed round's stage pipeline — it
/// owns its sequence, so stages need no locking.
struct StepItem {
    slot: usize,
    seq: ActiveSeq,
    token: usize,
    x: Vec<f32>,
}

/// Token-level continuous batching over a [`DecodePlan`] (DESIGN.md §13).
///
/// Admission rules: a request takes the lowest free slot and keeps it for
/// its whole lifetime; `step_all` advances every occupied slot by exactly
/// one token step, in slot order; a sequence finishes the round its
/// generation budget fills, immediately freeing the slot (its KV grids
/// drop with it) for the next admission. Because sessions are independent
/// (own seed, own KV grids, `item = 0` keys), a sequence's logits are
/// bit-identical whether it ran solo or co-batched, in barrier or
/// streamed mode.
pub struct ContinuousBatcher<'a> {
    plan: &'a DecodePlan,
    slots: Vec<Option<ActiveSeq>>,
    streamed: bool,
    queue_cap: usize,
    next_id: u64,
    step: u64,
}

impl<'a> ContinuousBatcher<'a> {
    /// `streamed` selects `sched::run_stages` pipelining (one stage per
    /// block + the LM head, stage names keyed by generation step) over
    /// the sequential barrier loop; both are bit-identical.
    pub fn new(plan: &'a DecodePlan, max_slots: usize, streamed: bool, queue_cap: usize) -> Self {
        assert!(max_slots >= 1, "batcher needs at least one slot");
        Self {
            plan,
            slots: (0..max_slots).map(|_| None).collect(),
            streamed,
            queue_cap: queue_cap.max(1),
            next_id: 0,
            step: 0,
        }
    }

    /// Occupied slots.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_free_slot(&self) -> bool {
        self.slots.iter().any(|s| s.is_none())
    }

    /// Generation rounds run so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// The session id the next admission will receive (ids are assigned
    /// in admission order — the replay handle for solo comparisons).
    pub fn next_session_id(&self) -> u64 {
        self.next_id
    }

    /// Admit a request into the lowest free slot; `None` when full (the
    /// caller re-offers after a round frees slots).
    pub fn admit(&mut self, req: DecodeRequest) -> Result<Option<usize>, MacroError> {
        assert!(!req.prompt.is_empty(), "decode request needs at least one prompt token");
        let Some(slot) = self.slots.iter().position(|s| s.is_none()) else {
            return Ok(None);
        };
        let id = self.next_id;
        self.next_id += 1;
        let session = self.plan.session(id)?;
        self.slots[slot] = Some(ActiveSeq {
            session,
            prompt: req.prompt,
            fed: 0,
            n_gen: req.n_gen,
            generated: Vec::new(),
        });
        crate::telemetry::decode().active.set(self.active() as i64);
        Ok(Some(slot))
    }

    /// Advance every active sequence by one token step and return the
    /// sequences that finished this round.
    pub fn step_all(&mut self) -> Result<Vec<Finished>, MapError> {
        let mut items: Vec<StepItem> = Vec::new();
        for slot in 0..self.slots.len() {
            if let Some(mut seq) = self.slots[slot].take() {
                let token = if seq.fed < seq.prompt.len() {
                    seq.prompt[seq.fed]
                } else {
                    *seq.generated.last().expect("generating sequence has a last token")
                };
                let x = if self.streamed {
                    self.plan.begin_step(&mut seq.session, token)?
                } else {
                    Vec::new()
                };
                items.push(StepItem { slot, seq, token, x });
            }
        }
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let step = self.step;
        self.step += 1;
        crate::telemetry::decode().steps.inc();

        if self.streamed {
            let plan = self.plan;
            let n_blocks = plan.n_blocks();
            let mut names: Vec<String> =
                (0..n_blocks).map(|b| format!("decode.l{b}.s{step}")).collect();
            names.push(format!("decode.head.s{step}"));
            let mut done: Vec<StepItem> = Vec::with_capacity(items.len());
            run_stages(
                items,
                names,
                self.queue_cap,
                |stage| {
                    move |it: &mut StepItem| -> Result<(), MapError> {
                        let x = std::mem::take(&mut it.x);
                        if stage < n_blocks {
                            it.x = plan.step_block(&mut it.seq.session, stage, x)?;
                        } else {
                            plan.finish_step(&mut it.seq.session, x, it.token)?;
                        }
                        Ok(())
                    }
                },
                |it| done.push(it),
            )?;
            // Settle in slot order — the exact order the barrier mode
            // settles in, so batcher-level bookkeeping cannot drift.
            done.sort_by_key(|it| it.slot);
            items = done;
        } else {
            for it in items.iter_mut() {
                self.plan.step(&mut it.seq.session, it.token)?;
            }
        }

        let mut finished = Vec::new();
        for it in items {
            self.settle(it, &mut finished);
        }
        crate::telemetry::decode().active.set(self.active() as i64);
        Ok(finished)
    }

    /// Drive rounds until every active sequence completes (graceful
    /// drain), collecting the finishers.
    pub fn drain(&mut self) -> Result<Vec<Finished>, MapError> {
        let mut all = Vec::new();
        while self.active() > 0 {
            all.extend(self.step_all()?);
        }
        Ok(all)
    }

    fn settle(&mut self, it: StepItem, finished: &mut Vec<Finished>) {
        let StepItem { slot, mut seq, .. } = it;
        if seq.fed < seq.prompt.len() {
            seq.fed += 1;
        }
        if seq.fed == seq.prompt.len() {
            if seq.generated.len() < seq.n_gen {
                seq.generated.push(argmax(seq.session.last_logits()));
            }
            if seq.generated.len() >= seq.n_gen {
                finished.push(Finished {
                    slot,
                    session_id: seq.session.id(),
                    prompt: seq.prompt,
                    generated: seq.generated,
                    steps: seq.session.pos() as u64,
                    stats: seq.session.stats().clone(),
                });
                return;
            }
        }
        self.slots[slot] = Some(seq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;

    fn tiny_plan(noise: bool) -> DecodePlan {
        let mut cfg = Config::default();
        cfg.noise.enabled = noise;
        cfg.enhance = EnhanceConfig::both();
        let model = DecoderModel::new(16, 2, 24, 11, 2, 12, 42);
        let cal = vec![vec![1, 2, 3, 4, 5], vec![6, 7, 8]];
        DecodePlan::new(model, &cal, &cfg, Some(77)).unwrap()
    }

    /// A session's whole trajectory is a pure function of (plan, id,
    /// tokens): re-opening the same id replays logits AND stats bit for
    /// bit, noise on; a different id decorrelates the noise.
    #[test]
    fn session_replay_is_bit_exact_and_ids_decorrelate() {
        let plan = tiny_plan(true);
        let toks = [3usize, 1, 4, 1, 5];
        let mut a = plan.session(9).unwrap();
        let la: Vec<Vec<f32>> = toks.iter().map(|&t| plan.step(&mut a, t).unwrap()).collect();
        let mut b = plan.session(9).unwrap();
        let lb: Vec<Vec<f32>> = toks.iter().map(|&t| plan.step(&mut b, t).unwrap()).collect();
        assert_eq!(la, lb, "same id must replay exactly");
        assert_eq!(
            a.stats().energy_fj().to_bits(),
            b.stats().energy_fj().to_bits(),
            "replayed stats are bit-identical"
        );
        let mut c = plan.session(10).unwrap();
        let lc: Vec<Vec<f32>> = toks.iter().map(|&t| plan.step(&mut c, t).unwrap()).collect();
        assert_ne!(la, lc, "distinct ids must draw distinct noise");
    }

    /// Noise-free, the engine's logits stay close to the float decoder:
    /// the 4-b quantized pipeline tracks the reference direction.
    #[test]
    fn decode_tracks_float_model() {
        let plan = tiny_plan(false);
        let toks = [2usize, 9, 4, 7];
        let mut s = plan.session(0).unwrap();
        let mut got = Vec::new();
        for &t in &toks {
            got = plan.step(&mut s, t).unwrap();
        }
        let want = plan.model().forward_causal(&toks);
        let last = &want.data[(toks.len() - 1) * plan.model().vocab..];
        let (mut dot, mut ng, mut nw) = (0f64, 0f64, 0f64);
        for (g, w) in got.iter().zip(last) {
            dot += *g as f64 * *w as f64;
            ng += (*g as f64).powi(2);
            nw += (*w as f64).powi(2);
        }
        let cos = dot / (ng.sqrt() * nw.sqrt());
        assert!(cos > 0.5, "engine logits diverged from float reference: cos = {cos}");
        assert_eq!(got.len(), plan.model().vocab);
        assert_eq!(s.pos(), toks.len());
        assert!(s.kv_reloads() > 0, "appends must reload KV strips");
    }

    /// Continuous batching: a sequence's generated tokens are identical
    /// whether it runs solo (generate) or co-batched, barrier or
    /// streamed — and slots free for late joiners.
    #[test]
    fn batched_generation_equals_solo() {
        let plan = tiny_plan(true);
        let reqs = [
            DecodeRequest { prompt: vec![1, 2, 3], n_gen: 4 },
            DecodeRequest { prompt: vec![9, 8], n_gen: 6 },
        ];
        for streamed in [false, true] {
            let mut batcher = ContinuousBatcher::new(&plan, 2, streamed, 2);
            assert_eq!(batcher.next_session_id(), 0);
            for r in &reqs {
                batcher.admit(r.clone()).unwrap().expect("slot free");
            }
            // next_id continues 0,1,... per batcher; solo replay below uses
            // the same ids, so the noise draws match.
            let mut fins = batcher.drain().unwrap();
            fins.sort_by_key(|f| f.session_id);
            assert_eq!(fins.len(), 2);
            for (id, (f, r)) in fins.iter().zip(&reqs).enumerate() {
                let mut solo = plan.session(id as u64).unwrap();
                let want = plan.generate(&mut solo, &r.prompt, r.n_gen).unwrap();
                assert_eq!(f.generated, want, "streamed={streamed} id={id}");
                assert_eq!(
                    f.stats.energy_fj().to_bits(),
                    solo.stats().energy_fj().to_bits(),
                    "per-session stats are mode-invariant (streamed={streamed})"
                );
            }
            assert_eq!(batcher.active(), 0, "drain must free every slot");
        }
    }
}
