//! The compiler's graph IR: a small, explicitly-quantized dataflow graph
//! over CHW tensors and flat vectors.
//!
//! Nodes are appended in topological order (inputs must refer to earlier
//! nodes), so every pass is a single forward walk. Quantization boundaries
//! are explicit [`Op::Quantize`] nodes: every `Conv2d`/`Linear` must consume
//! one, and the lowerer fuses it into the placed layer (the macro's 4-b
//! activation interface). [`Op::Dequantize`] is the digital periphery's
//! affine return to float (`y = x·scale + bias`), used by graphs whose
//! layers run with unit scales (e.g. [`Graph::from_deployment`]).

use crate::coordinator::deployment::MlpDeployment;
use crate::nn::im2col::conv_out_dims;
use crate::nn::mlp::Mlp;
use crate::nn::ops::{conv2d, global_avg_pool};
use crate::nn::quant::QuantParams;
use crate::nn::resnet::{ConvLayer, ResNet20};
use crate::nn::tensor::Tensor;

/// Index of a node in [`Graph::nodes`].
pub type NodeId = usize;

/// One IR operation.
#[derive(Clone, Debug)]
pub enum Op {
    /// Network input placeholder; shape fixed at graph-build time.
    Input { shape: Vec<usize> },
    /// Activation quantization boundary feeding a `Conv2d`/`Linear`.
    /// `None` ⇒ the params are calibrated from data at compile time
    /// (unsigned, `act_bits`, max over the calibration set).
    Quantize { params: Option<QuantParams> },
    /// Affine return to float: `y = x·scale + bias` (`bias` may be empty;
    /// when present the value must be rank-1 with matching length).
    Dequantize { scale: f32, bias: Vec<f32> },
    /// Convolution, CHW in/out. `w` is `[oc][ic][kh][kw]`. With
    /// `w_params: None` the weights are float and quantized max-abs at
    /// compile time, and dequant+bias are fused into the placed layer;
    /// with explicit params (e.g. unit scales for pre-quantized integer
    /// planes) the layer emits raw integer sums and the graph must scale
    /// them back with a `Dequantize`.
    Conv2d {
        w: Tensor,
        bias: Vec<f32>,
        stride: usize,
        pad: usize,
        w_params: Option<QuantParams>,
    },
    /// Fully-connected layer; `w_cols` is `[K][N]` (column per output).
    /// Same `w_params` convention as `Conv2d`.
    Linear { w_cols: Tensor, bias: Vec<f32>, w_params: Option<QuantParams> },
    /// Elementwise max(x, 0).
    Relu,
    /// Elementwise residual add of two equal-shaped values.
    Add,
    /// `[C][H][W]` → `[C]` mean pool.
    GlobalAvgPool,
}

impl Op {
    /// Number of inputs the op consumes.
    pub fn arity(&self) -> usize {
        match self {
            Op::Input { .. } => 0,
            Op::Add => 2,
            _ => 1,
        }
    }

    /// Short kind label for reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Quantize { .. } => "quantize",
            Op::Dequantize { .. } => "dequantize",
            Op::Conv2d { .. } => "conv",
            Op::Linear { .. } => "linear",
            Op::Relu => "relu",
            Op::Add => "add",
            Op::GlobalAvgPool => "gap",
        }
    }
}

/// One graph node: an op, its input value ids, and a report-friendly name.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub name: String,
}

/// A whole-network dataflow graph. Built by the `from_*` ingest helpers or
/// by hand with [`Graph::add`].
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    output: Option<NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a node; its inputs must already exist (topological order by
    /// construction). The last node added becomes the output unless
    /// [`Graph::set_output`] overrides it.
    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        assert_eq!(inputs.len(), op.arity(), "op arity");
        for &i in inputs {
            assert!(i < id, "node inputs must precede the node (got {i} for {id})");
        }
        self.nodes.push(Node { op, inputs: inputs.to_vec(), name: name.into() });
        self.output = Some(id);
        id
    }

    pub fn set_output(&mut self, id: NodeId) {
        assert!(id < self.nodes.len());
        self.output = Some(id);
    }

    pub fn output(&self) -> NodeId {
        self.output.expect("empty graph has no output")
    }

    /// The graph's input shape (exactly one `Input` node is required).
    pub fn input_shape(&self) -> Result<&[usize], String> {
        let mut found = None;
        for n in &self.nodes {
            if let Op::Input { shape } = &n.op {
                if found.is_some() {
                    return Err("graph has more than one Input node".into());
                }
                found = Some(shape.as_slice());
            }
        }
        found.ok_or_else(|| "graph has no Input node".into())
    }

    /// Infer and validate every node's value shape.
    pub fn infer_shapes(&self) -> Result<Vec<Vec<usize>>, String> {
        self.input_shape()?;
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.nodes.len());
        for (id, node) in self.nodes.iter().enumerate() {
            let at = |i: usize| -> &Vec<usize> { &shapes[node.inputs[i]] };
            let err = |m: String| format!("node {id} `{}`: {m}", node.name);
            let shape = match &node.op {
                Op::Input { shape } => shape.clone(),
                Op::Quantize { .. } | Op::Relu => at(0).clone(),
                Op::Dequantize { bias, .. } => {
                    let s = at(0);
                    if !bias.is_empty() && (s.len() != 1 || s[0] != bias.len()) {
                        return Err(err(format!(
                            "dequantize bias length {} vs value shape {s:?}",
                            bias.len()
                        )));
                    }
                    s.clone()
                }
                Op::Conv2d { w, stride, pad, .. } => {
                    let s = at(0);
                    if s.len() != 3 {
                        return Err(err(format!("conv input must be CHW, got {s:?}")));
                    }
                    let (oc, ic, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                    if s[0] != ic {
                        return Err(err(format!("conv channels {} vs input {}", ic, s[0])));
                    }
                    let (oh, ow) = conv_out_dims(s[1], s[2], kh, kw, *stride, *pad);
                    vec![oc, oh, ow]
                }
                Op::Linear { w_cols, bias, .. } => {
                    let s = at(0);
                    let (k, n) = (w_cols.shape[0], w_cols.shape[1]);
                    if s.len() != 1 || s[0] != k {
                        return Err(err(format!("linear expects [{k}], got {s:?}")));
                    }
                    if bias.len() != n {
                        return Err(err(format!("linear bias {} vs N {n}", bias.len())));
                    }
                    vec![n]
                }
                Op::Add => {
                    if at(0) != at(1) {
                        return Err(err(format!("add shapes {:?} vs {:?}", at(0), at(1))));
                    }
                    at(0).clone()
                }
                Op::GlobalAvgPool => {
                    let s = at(0);
                    if s.len() != 3 {
                        return Err(err(format!("gap input must be CHW, got {s:?}")));
                    }
                    vec![s[0]]
                }
            };
            shapes.push(shape);
        }
        Ok(shapes)
    }

    /// Float reference evaluation of every node. Calibrated (`params:
    /// None`) `Quantize` nodes are identity — the unquantized float golden;
    /// explicit-param `Quantize` nodes emit their integer codes (as floats),
    /// so unit-scale graphs like [`Graph::from_deployment`] evaluate the
    /// quantized arithmetic exactly (matching `MlpDeployment::run_digital`).
    /// This is the golden path the equivalence tests compare against, and
    /// what calibration runs over.
    pub fn eval_float(&self, x: &Tensor) -> Result<Vec<Tensor>, String> {
        let mut vals: Vec<Tensor> = Vec::with_capacity(self.nodes.len());
        for (id, node) in self.nodes.iter().enumerate() {
            let at = |i: usize| -> &Tensor { &vals[node.inputs[i]] };
            let err = |m: String| format!("node {id} `{}`: {m}", node.name);
            let v = match &node.op {
                Op::Input { shape } => {
                    if x.shape != *shape {
                        return Err(err(format!("input {:?} vs graph {shape:?}", x.shape)));
                    }
                    x.clone()
                }
                Op::Quantize { params } => match params {
                    None => at(0).clone(),
                    Some(p) => Tensor::from_vec(
                        &at(0).shape,
                        at(0).data.iter().map(|&v| p.quantize(v) as f32).collect(),
                    ),
                },
                Op::Dequantize { scale, bias } => dequantize(at(0), *scale, bias),
                Op::Conv2d { w, bias, stride, pad, .. } => {
                    conv2d(at(0), w, Some(bias), *stride, *pad)
                }
                Op::Linear { w_cols, bias, .. } => {
                    let t = at(0);
                    let (k, n) = (w_cols.shape[0], w_cols.shape[1]);
                    if t.data.len() != k {
                        return Err(err(format!("linear input {} vs K {k}", t.data.len())));
                    }
                    let mut y = vec![0f32; n];
                    for (nn, yv) in y.iter_mut().enumerate() {
                        let mut acc = 0f32;
                        for (kk, &xv) in t.data.iter().enumerate() {
                            acc += xv * w_cols.at2(kk, nn);
                        }
                        *yv = acc + bias[nn];
                    }
                    Tensor::from_vec(&[n], y)
                }
                Op::Relu => at(0).clone().map(|v| v.max(0.0)),
                Op::Add => {
                    let (a, b) = (at(0), at(1));
                    if a.shape != b.shape {
                        return Err(err(format!("add {:?} vs {:?}", a.shape, b.shape)));
                    }
                    let mut out = a.clone();
                    for (o, i) in out.data.iter_mut().zip(&b.data) {
                        *o += i;
                    }
                    out
                }
                Op::GlobalAvgPool => {
                    let c = at(0).shape[0];
                    Tensor::from_vec(&[c], global_avg_pool(at(0)))
                }
            };
            vals.push(v);
        }
        Ok(vals)
    }

    // ---- ingest builders ----

    /// A float MLP as a calibrated graph: `Quantize → Linear (→ Relu)` per
    /// layer, dequant+bias fused into each layer.
    ///
    /// The typical flow is ingest → [`crate::compiler::compile`] → run:
    ///
    /// ```
    /// use cimsim::compiler::{compile, CompileOptions, Graph};
    /// use cimsim::config::Config;
    /// use cimsim::nn::mlp::Mlp;
    /// use cimsim::nn::tensor::Tensor;
    ///
    /// let mut cfg = Config::default();
    /// cfg.noise.enabled = false; // deterministic: quantization only
    /// let mlp = Mlp::new(&[8, 6, 4], 1);
    /// let graph = Graph::from_mlp(&mlp);
    ///
    /// // Calibrate activation ranges on a small set, lower + place + load.
    /// let cal = vec![Tensor::from_vec(&[8], (0..8).map(|i| i as f32 / 8.0).collect())];
    /// let mut plan = compile(graph, &cal, &cfg, &CompileOptions::default()).unwrap();
    ///
    /// let logits = plan
    ///     .run_batch(&[Tensor::from_vec(&[8], vec![0.25; 8])])
    ///     .unwrap();
    /// assert_eq!(logits[0].len(), 4);
    /// ```
    pub fn from_mlp(mlp: &Mlp) -> Self {
        let mut g = Graph::new();
        let d0 = mlp.layers[0].w.shape[1];
        let mut cur = g.add("input", Op::Input { shape: vec![d0] }, &[]);
        for (i, layer) in mlp.layers.iter().enumerate() {
            let q = g.add(format!("fc{i}.q"), Op::Quantize { params: None }, &[cur]);
            cur = g.add(
                format!("fc{i}"),
                Op::Linear {
                    w_cols: transpose_rows_to_cols(&layer.w),
                    bias: layer.b.clone(),
                    w_params: None,
                },
                &[q],
            );
            if i + 1 < mlp.layers.len() {
                cur = g.add(format!("fc{i}.relu"), Op::Relu, &[cur]);
            }
        }
        g
    }

    /// ResNet-20 (CIFAR-shaped) as a calibrated graph — the paper's Fig. 1
    /// mapping workload: stem + 3 stages × 3 residual blocks + GAP + FC.
    pub fn from_resnet20(net: &ResNet20) -> Self {
        let mut g = Graph::new();
        let mut cur = g.add("input", Op::Input { shape: vec![3, 32, 32] }, &[]);
        cur = add_conv(&mut g, "stem", &net.stem, cur);
        cur = g.add("stem.relu", Op::Relu, &[cur]);
        for (si, stage) in net.stages.iter().enumerate() {
            for (bi, block) in stage.iter().enumerate() {
                let p = format!("s{si}b{bi}");
                let block_in = cur;
                let h = add_conv(&mut g, format!("{p}.conv1"), &block.conv1, block_in);
                let h = g.add(format!("{p}.conv1.relu"), Op::Relu, &[h]);
                let h = add_conv(&mut g, format!("{p}.conv2"), &block.conv2, h);
                let idn = match &block.proj {
                    Some(proj) => add_conv(&mut g, format!("{p}.proj"), proj, block_in),
                    None => block_in,
                };
                let sum = g.add(format!("{p}.add"), Op::Add, &[h, idn]);
                cur = g.add(format!("{p}.relu"), Op::Relu, &[sum]);
            }
        }
        let gap = g.add("gap", Op::GlobalAvgPool, &[cur]);
        let q = g.add("fc.q", Op::Quantize { params: None }, &[gap]);
        g.add(
            "fc",
            Op::Linear {
                w_cols: transpose_rows_to_cols(&net.fc_w),
                bias: net.fc_b.clone(),
                w_params: None,
            },
            &[q],
        );
        g
    }

    /// A post-training-quantized [`MlpDeployment`] as a unit-scale graph:
    /// layers carry the integer weight planes with unit quantization params
    /// and explicit `Dequantize` nodes restore the deployment's scales —
    /// arithmetic identical, expression for expression, to
    /// [`MlpDeployment::run_native`], so the compiled plan is bit-identical
    /// to it noise-free.
    pub fn from_deployment(dep: &MlpDeployment) -> Self {
        let unit_w = QuantParams { scale: 1.0, q_min: -7, q_max: 7 };
        let a0 = QuantParams { scale: dep.a0_scale, q_min: 0, q_max: 15 };
        let a1_scale = dep.a1_cal / 15.0;
        let a1 = QuantParams { scale: a1_scale, q_min: 0, q_max: 15 };

        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![dep.dims[0]] }, &[]);
        let q0 = g.add("fc0.q", Op::Quantize { params: Some(a0) }, &[x]);
        let l0 = g.add(
            "fc0",
            Op::Linear {
                w_cols: dep.w1_q.clone(),
                bias: vec![0.0; dep.dims[1]],
                w_params: Some(unit_w),
            },
            &[q0],
        );
        let d0 = g.add(
            "fc0.deq",
            Op::Dequantize { scale: dep.a0_scale * dep.w1_scale, bias: dep.b1.clone() },
            &[l0],
        );
        let r0 = g.add("fc0.relu", Op::Relu, &[d0]);
        let q1 = g.add("fc1.q", Op::Quantize { params: Some(a1) }, &[r0]);
        let l1 = g.add(
            "fc1",
            Op::Linear {
                w_cols: dep.w2_q.clone(),
                bias: vec![0.0; dep.dims[2]],
                w_params: Some(unit_w),
            },
            &[q1],
        );
        g.add(
            "fc1.deq",
            Op::Dequantize { scale: a1_scale * dep.w2_scale, bias: dep.b2.clone() },
            &[l1],
        );
        g
    }
}

fn add_conv(g: &mut Graph, name: impl Into<String>, layer: &ConvLayer, input: NodeId) -> NodeId {
    let name = name.into();
    let q = g.add(format!("{name}.q"), Op::Quantize { params: None }, &[input]);
    g.add(
        name,
        Op::Conv2d {
            w: layer.w.clone(),
            bias: layer.b.clone(),
            stride: layer.stride,
            pad: layer.pad,
            w_params: None,
        },
        &[q],
    )
}

/// The `Dequantize` affine `y = x·scale + bias` — the single definition
/// shared by [`Graph::eval_float`] and the compiled-plan executor.
pub(crate) fn dequantize(t: &Tensor, scale: f32, bias: &[f32]) -> Tensor {
    if bias.is_empty() {
        t.clone().map(|v| v * scale)
    } else {
        Tensor::from_vec(
            &t.shape,
            t.data.iter().zip(bias).map(|(&v, &b)| v * scale + b).collect(),
        )
    }
}

/// Transpose `[out][in]` weights to `[in][out]` (one column per engine) —
/// the layout `CimLinear` consumes. Public so references built outside the
/// compiler (examples, tests) share the exact lowering layout.
pub fn transpose_rows_to_cols(w: &Tensor) -> Tensor {
    assert_eq!(w.rank(), 2);
    let (o, i) = (w.shape[0], w.shape[1]);
    let mut t = Tensor::zeros(&[i, o]);
    for oo in 0..o {
        for ii in 0..i {
            *t.at2_mut(ii, oo) = w.at2(oo, ii);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dataset::random_image;

    #[test]
    fn mlp_graph_shapes_and_float_eval_match_mlp() {
        let mlp = Mlp::new(&[12, 8, 4], 3);
        let g = Graph::from_mlp(&mlp);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![4]);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin().abs()).collect();
        let vals = g.eval_float(&Tensor::from_vec(&[12], x.clone())).unwrap();
        let want = mlp.logits(&x);
        let got = &vals[g.output()].data;
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn resnet_graph_matches_float_forward() {
        let net = ResNet20::new(5);
        let g = Graph::from_resnet20(&net);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![10]);
        // Conv node count: 19 main + 2 projections.
        let convs = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv2d { .. })).count();
        assert_eq!(convs, 21);
        let x = random_image(&[3, 32, 32], 9);
        let vals = g.eval_float(&x).unwrap();
        let want = net.forward(&x);
        let got = &vals[g.output()].data;
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn shape_errors_are_caught() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![3, 8, 8] }, &[]);
        let q = g.add("q", Op::Quantize { params: None }, &[x]);
        // 4-input-channel conv on a 3-channel value.
        g.add(
            "bad",
            Op::Conv2d {
                w: Tensor::zeros(&[2, 4, 3, 3]),
                bias: vec![0.0; 2],
                stride: 1,
                pad: 1,
                w_params: None,
            },
            &[q],
        );
        assert!(g.infer_shapes().is_err());
    }

    #[test]
    fn deployment_graph_structure() {
        let mlp = Mlp::new(&[6, 5, 3], 1);
        let cal: Vec<Vec<f32>> = (0..4).map(|i| vec![0.1 * (i as f32 + 1.0); 6]).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
        let g = Graph::from_deployment(&dep);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![3]);
        assert!(matches!(g.nodes[g.output()].op, Op::Dequantize { .. }));
        // Both linears carry explicit unit weight params.
        for n in &g.nodes {
            if let Op::Linear { w_params, .. } = &n.op {
                assert_eq!(w_params.unwrap().scale, 1.0);
            }
        }
        // The float golden of a unit-scale graph IS the quantized digital
        // reference (explicit-param Quantize nodes emit integer codes).
        let x: Vec<f32> = (0..6).map(|i| 0.15 * (i as f32 + 1.0)).collect();
        let want = dep.run_digital(&[x.clone()]).remove(0);
        let got = g.eval_float(&Tensor::from_vec(&[6], x)).unwrap();
        for (a, b) in got[g.output()].data.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
