//! The compiler's graph IR: a small, explicitly-quantized dataflow graph
//! over CHW tensors and flat vectors.
//!
//! Nodes are appended in topological order (inputs must refer to earlier
//! nodes), so every pass is a single forward walk. Quantization boundaries
//! are explicit [`Op::Quantize`] nodes: every `Conv2d`/`Linear` must consume
//! one, and the lowerer fuses it into the placed layer (the macro's 4-b
//! activation interface). [`Op::Dequantize`] is the digital periphery's
//! affine return to float (`y = x·scale + bias`), used by graphs whose
//! layers run with unit scales (e.g. [`Graph::from_deployment`]).

use crate::coordinator::deployment::MlpDeployment;
use crate::nn::im2col::conv_out_dims;
use crate::nn::mlp::Mlp;
use crate::nn::ops::{conv2d, global_avg_pool};
use crate::nn::quant::QuantParams;
use crate::nn::resnet::{ConvLayer, ResNet20};
use crate::nn::tensor::Tensor;
use crate::nn::transformer::TransformerBlock;

/// Index of a node in [`Graph::nodes`].
pub type NodeId = usize;

/// One IR operation.
#[derive(Clone, Debug)]
pub enum Op {
    /// Network input placeholder; shape fixed at graph-build time.
    Input { shape: Vec<usize> },
    /// Activation quantization boundary feeding a `Conv2d`/`Linear`.
    /// `None` ⇒ the params are calibrated from data at compile time
    /// (unsigned, `act_bits`, max over the calibration set).
    Quantize { params: Option<QuantParams> },
    /// Affine return to float: `y = x·scale + bias` (`bias` may be empty;
    /// when present the value must be rank-1 with matching length).
    Dequantize { scale: f32, bias: Vec<f32> },
    /// Convolution, CHW in/out. `w` is `[oc][ic][kh][kw]`. With
    /// `w_params: None` the weights are float and quantized max-abs at
    /// compile time, and dequant+bias are fused into the placed layer;
    /// with explicit params (e.g. unit scales for pre-quantized integer
    /// planes) the layer emits raw integer sums and the graph must scale
    /// them back with a `Dequantize`.
    Conv2d {
        w: Tensor,
        bias: Vec<f32>,
        stride: usize,
        pad: usize,
        w_params: Option<QuantParams>,
    },
    /// Fully-connected layer; `w_cols` is `[K][N]` (column per output).
    /// Accepts a `[K]` vector or a `[S][K]` row matrix (applied row-wise —
    /// the transformer token dimension). Same `w_params` convention as
    /// `Conv2d`.
    Linear { w_cols: Tensor, bias: Vec<f32>, w_params: Option<QuantParams> },
    /// Runtime×runtime matrix product (dynamic weights, DESIGN.md §10):
    /// input 0 is the `Quantize`d streamed operand `[S][K]`, input 1 the
    /// float operand that is re-quantized per call and written into the
    /// placed tiles — `[N][K]` with `transpose_b` (Q·Kᵀ), `[K][N]` without
    /// (attn·V). Output `[S][N]`.
    MatMul { transpose_b: bool },
    /// Softmax over the last dimension (row-wise on rank-2 values).
    Softmax,
    /// Causal (lower-triangular) softmax over a square `[s][s]` score
    /// matrix: row `i` softmaxes columns `0..=i`, zeros the rest — the
    /// autoregressive attention mask (DESIGN.md §13).
    CausalSoftmax,
    /// LayerNorm over the last dimension: `(x−μ)/√(σ²+eps)·γ + β`.
    LayerNorm { gamma: Vec<f32>, beta: Vec<f32>, eps: f32 },
    /// Elementwise max(x, 0).
    Relu,
    /// Elementwise residual add of two equal-shaped values.
    Add,
    /// `[C][H][W]` → `[C]` mean pool.
    GlobalAvgPool,
}

impl Op {
    /// Number of inputs the op consumes.
    pub fn arity(&self) -> usize {
        match self {
            Op::Input { .. } => 0,
            Op::Add | Op::MatMul { .. } => 2,
            _ => 1,
        }
    }

    /// Short kind label for reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Quantize { .. } => "quantize",
            Op::Dequantize { .. } => "dequantize",
            Op::Conv2d { .. } => "conv",
            Op::Linear { .. } => "linear",
            Op::MatMul { .. } => "matmul",
            Op::Softmax => "softmax",
            Op::CausalSoftmax => "causal_softmax",
            Op::LayerNorm { .. } => "layernorm",
            Op::Relu => "relu",
            Op::Add => "add",
            Op::GlobalAvgPool => "gap",
        }
    }
}

/// One graph node: an op, its input value ids, and a report-friendly name.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub name: String,
}

/// A whole-network dataflow graph. Built by the `from_*` ingest helpers or
/// by hand with [`Graph::add`].
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    output: Option<NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a node; its inputs must already exist (topological order by
    /// construction). The last node added becomes the output unless
    /// [`Graph::set_output`] overrides it.
    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        assert_eq!(inputs.len(), op.arity(), "op arity");
        for &i in inputs {
            assert!(i < id, "node inputs must precede the node (got {i} for {id})");
        }
        self.nodes.push(Node { op, inputs: inputs.to_vec(), name: name.into() });
        self.output = Some(id);
        id
    }

    pub fn set_output(&mut self, id: NodeId) {
        assert!(id < self.nodes.len());
        self.output = Some(id);
    }

    pub fn output(&self) -> NodeId {
        self.output.expect("empty graph has no output")
    }

    /// The graph's input shape (exactly one `Input` node is required).
    pub fn input_shape(&self) -> Result<&[usize], String> {
        let mut found = None;
        for n in &self.nodes {
            if let Op::Input { shape } = &n.op {
                if found.is_some() {
                    return Err("graph has more than one Input node".into());
                }
                found = Some(shape.as_slice());
            }
        }
        found.ok_or_else(|| "graph has no Input node".into())
    }

    /// Infer and validate every node's value shape.
    pub fn infer_shapes(&self) -> Result<Vec<Vec<usize>>, String> {
        self.input_shape()?;
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.nodes.len());
        for (id, node) in self.nodes.iter().enumerate() {
            let at = |i: usize| -> &Vec<usize> { &shapes[node.inputs[i]] };
            let err = |m: String| format!("node {id} `{}`: {m}", node.name);
            let shape = match &node.op {
                Op::Input { shape } => shape.clone(),
                Op::Quantize { .. } | Op::Relu => at(0).clone(),
                Op::Dequantize { bias, .. } => {
                    let s = at(0);
                    if !bias.is_empty() && (s.len() != 1 || s[0] != bias.len()) {
                        return Err(err(format!(
                            "dequantize bias length {} vs value shape {s:?}",
                            bias.len()
                        )));
                    }
                    s.clone()
                }
                Op::Conv2d { w, stride, pad, .. } => {
                    let s = at(0);
                    if s.len() != 3 {
                        return Err(err(format!("conv input must be CHW, got {s:?}")));
                    }
                    let (oc, ic, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
                    if s[0] != ic {
                        return Err(err(format!("conv channels {} vs input {}", ic, s[0])));
                    }
                    let (oh, ow) = conv_out_dims(s[1], s[2], kh, kw, *stride, *pad);
                    vec![oc, oh, ow]
                }
                Op::Linear { w_cols, bias, .. } => {
                    let s = at(0);
                    let (k, n) = (w_cols.shape[0], w_cols.shape[1]);
                    if bias.len() != n {
                        return Err(err(format!("linear bias {} vs N {n}", bias.len())));
                    }
                    match s.as_slice() {
                        [kk] if *kk == k => vec![n],
                        [rows, kk] if *kk == k => vec![*rows, n],
                        _ => {
                            return Err(err(format!(
                                "linear expects [{k}] or [S, {k}], got {s:?}"
                            )));
                        }
                    }
                }
                Op::MatMul { transpose_b } => {
                    let (a, b) = (at(0), at(1));
                    if a.len() != 2 || b.len() != 2 {
                        return Err(err(format!("matmul expects rank-2, got {a:?} × {b:?}")));
                    }
                    let k = a[1];
                    let n = if *transpose_b {
                        if b[1] != k {
                            return Err(err(format!("matmul inner dims {a:?} × {b:?}ᵀ")));
                        }
                        b[0]
                    } else {
                        if b[0] != k {
                            return Err(err(format!("matmul inner dims {a:?} × {b:?}")));
                        }
                        b[1]
                    };
                    vec![a[0], n]
                }
                Op::Softmax => {
                    let s = at(0);
                    if s.is_empty() || s.len() > 2 {
                        return Err(err(format!("softmax expects rank 1 or 2, got {s:?}")));
                    }
                    s.clone()
                }
                Op::CausalSoftmax => {
                    let s = at(0);
                    if s.len() != 2 || s[0] != s[1] {
                        return Err(err(format!(
                            "causal_softmax expects square [s][s] scores, got {s:?}"
                        )));
                    }
                    s.clone()
                }
                Op::LayerNorm { gamma, beta, .. } => {
                    let s = at(0);
                    let cols = *s.last().unwrap_or(&0);
                    if s.is_empty() || s.len() > 2 || gamma.len() != cols || beta.len() != cols
                    {
                        return Err(err(format!(
                            "layernorm γ/β length {} vs value shape {s:?}",
                            gamma.len()
                        )));
                    }
                    s.clone()
                }
                Op::Add => {
                    if at(0) != at(1) {
                        return Err(err(format!("add shapes {:?} vs {:?}", at(0), at(1))));
                    }
                    at(0).clone()
                }
                Op::GlobalAvgPool => {
                    let s = at(0);
                    if s.len() != 3 {
                        return Err(err(format!("gap input must be CHW, got {s:?}")));
                    }
                    vec![s[0]]
                }
            };
            shapes.push(shape);
        }
        Ok(shapes)
    }

    /// Float reference evaluation of every node. Calibrated (`params:
    /// None`) `Quantize` nodes are identity — the unquantized float golden;
    /// explicit-param `Quantize` nodes emit their integer codes (as floats),
    /// so unit-scale graphs like [`Graph::from_deployment`] evaluate the
    /// quantized arithmetic exactly (matching `MlpDeployment::run_digital`).
    /// This is the golden path the equivalence tests compare against, and
    /// what calibration runs over.
    pub fn eval_float(&self, x: &Tensor) -> Result<Vec<Tensor>, String> {
        let mut vals: Vec<Tensor> = Vec::with_capacity(self.nodes.len());
        for (id, node) in self.nodes.iter().enumerate() {
            let at = |i: usize| -> &Tensor { &vals[node.inputs[i]] };
            let err = |m: String| format!("node {id} `{}`: {m}", node.name);
            let v = match &node.op {
                Op::Input { shape } => {
                    if x.shape != *shape {
                        return Err(err(format!("input {:?} vs graph {shape:?}", x.shape)));
                    }
                    x.clone()
                }
                Op::Quantize { params } => match params {
                    None => at(0).clone(),
                    Some(p) => Tensor::from_vec(
                        &at(0).shape,
                        at(0).data.iter().map(|&v| p.quantize(v) as f32).collect(),
                    ),
                },
                Op::Dequantize { scale, bias } => dequantize(at(0), *scale, bias),
                Op::Conv2d { w, bias, stride, pad, .. } => {
                    conv2d(at(0), w, Some(bias), *stride, *pad)
                }
                Op::Linear { w_cols, bias, .. } => {
                    let t = at(0);
                    let (k, n) = (w_cols.shape[0], w_cols.shape[1]);
                    if *t.shape.last().unwrap_or(&0) != k || t.rank() > 2 {
                        return Err(err(format!("linear input {:?} vs K {k}", t.shape)));
                    }
                    let rows = t.data.len() / k;
                    let mut y = Vec::with_capacity(rows * n);
                    for row in t.data.chunks(k) {
                        for nn in 0..n {
                            let mut acc = 0f32;
                            for (kk, &xv) in row.iter().enumerate() {
                                acc += xv * w_cols.at2(kk, nn);
                            }
                            y.push(acc + bias[nn]);
                        }
                    }
                    if t.rank() == 1 {
                        Tensor::from_vec(&[n], y)
                    } else {
                        Tensor::from_vec(&[rows, n], y)
                    }
                }
                Op::MatMul { transpose_b } => {
                    let (a, b) = (at(0), at(1));
                    if a.rank() != 2 || b.rank() != 2 {
                        return Err(err(format!(
                            "matmul expects rank-2, got {:?} × {:?}",
                            a.shape, b.shape
                        )));
                    }
                    let (s, k) = (a.shape[0], a.shape[1]);
                    let n = if *transpose_b { b.shape[0] } else { b.shape[1] };
                    let inner_ok =
                        if *transpose_b { b.shape[1] == k } else { b.shape[0] == k };
                    if !inner_ok {
                        return Err(err(format!(
                            "matmul inner dims {:?} × {:?} (transpose_b={transpose_b})",
                            a.shape, b.shape
                        )));
                    }
                    let mut y = Vec::with_capacity(s * n);
                    for i in 0..s {
                        for j in 0..n {
                            let mut acc = 0f32;
                            for kk in 0..k {
                                let bv =
                                    if *transpose_b { b.at2(j, kk) } else { b.at2(kk, j) };
                                acc += a.at2(i, kk) * bv;
                            }
                            y.push(acc);
                        }
                    }
                    Tensor::from_vec(&[s, n], y)
                }
                Op::Softmax => crate::nn::ops::softmax_last_dim(at(0)),
                Op::CausalSoftmax => crate::nn::ops::causal_softmax(at(0)),
                Op::LayerNorm { gamma, beta, eps } => {
                    crate::nn::ops::layer_norm(at(0), gamma, beta, *eps)
                }
                Op::Relu => at(0).clone().map(|v| v.max(0.0)),
                Op::Add => {
                    let (a, b) = (at(0), at(1));
                    if a.shape != b.shape {
                        return Err(err(format!("add {:?} vs {:?}", a.shape, b.shape)));
                    }
                    let mut out = a.clone();
                    for (o, i) in out.data.iter_mut().zip(&b.data) {
                        *o += i;
                    }
                    out
                }
                Op::GlobalAvgPool => {
                    let c = at(0).shape[0];
                    Tensor::from_vec(&[c], global_avg_pool(at(0)))
                }
            };
            vals.push(v);
        }
        Ok(vals)
    }

    // ---- ingest builders ----

    /// A float MLP as a calibrated graph: `Quantize → Linear (→ Relu)` per
    /// layer, dequant+bias fused into each layer.
    ///
    /// The typical flow is ingest → [`crate::compiler::compile`] → run:
    ///
    /// ```
    /// use cimsim::compiler::{compile, CompileOptions, Graph};
    /// use cimsim::config::Config;
    /// use cimsim::nn::mlp::Mlp;
    /// use cimsim::nn::tensor::Tensor;
    ///
    /// let mut cfg = Config::default();
    /// cfg.noise.enabled = false; // deterministic: quantization only
    /// let mlp = Mlp::new(&[8, 6, 4], 1);
    /// let graph = Graph::from_mlp(&mlp);
    ///
    /// // Calibrate activation ranges on a small set, lower + place + load.
    /// let cal = vec![Tensor::from_vec(&[8], (0..8).map(|i| i as f32 / 8.0).collect())];
    /// let mut plan = compile(graph, &cal, &cfg, &CompileOptions::default()).unwrap();
    ///
    /// let logits = plan
    ///     .run_batch(&[Tensor::from_vec(&[8], vec![0.25; 8])])
    ///     .unwrap();
    /// assert_eq!(logits[0].len(), 4);
    /// ```
    pub fn from_mlp(mlp: &Mlp) -> Self {
        let mut g = Graph::new();
        let d0 = mlp.layers[0].w.shape[1];
        let mut cur = g.add("input", Op::Input { shape: vec![d0] }, &[]);
        for (i, layer) in mlp.layers.iter().enumerate() {
            let q = g.add(format!("fc{i}.q"), Op::Quantize { params: None }, &[cur]);
            cur = g.add(
                format!("fc{i}"),
                Op::Linear {
                    w_cols: transpose_rows_to_cols(&layer.w),
                    bias: layer.b.clone(),
                    w_params: None,
                },
                &[q],
            );
            if i + 1 < mlp.layers.len() {
                cur = g.add(format!("fc{i}.relu"), Op::Relu, &[cur]);
            }
        }
        g
    }

    /// ResNet-20 (CIFAR-shaped) as a calibrated graph — the paper's Fig. 1
    /// mapping workload: stem + 3 stages × 3 residual blocks + GAP + FC.
    pub fn from_resnet20(net: &ResNet20) -> Self {
        let mut g = Graph::new();
        let mut cur = g.add("input", Op::Input { shape: vec![3, 32, 32] }, &[]);
        cur = add_conv(&mut g, "stem", &net.stem, cur);
        cur = g.add("stem.relu", Op::Relu, &[cur]);
        for (si, stage) in net.stages.iter().enumerate() {
            for (bi, block) in stage.iter().enumerate() {
                let p = format!("s{si}b{bi}");
                let block_in = cur;
                let h = add_conv(&mut g, format!("{p}.conv1"), &block.conv1, block_in);
                let h = g.add(format!("{p}.conv1.relu"), Op::Relu, &[h]);
                let h = add_conv(&mut g, format!("{p}.conv2"), &block.conv2, h);
                let idn = match &block.proj {
                    Some(proj) => add_conv(&mut g, format!("{p}.proj"), proj, block_in),
                    None => block_in,
                };
                let sum = g.add(format!("{p}.add"), Op::Add, &[h, idn]);
                cur = g.add(format!("{p}.relu"), Op::Relu, &[sum]);
            }
        }
        let gap = g.add("gap", Op::GlobalAvgPool, &[cur]);
        let q = g.add("fc.q", Op::Quantize { params: None }, &[gap]);
        g.add(
            "fc",
            Op::Linear {
                w_cols: transpose_rows_to_cols(&net.fc_w),
                bias: net.fc_b.clone(),
                w_params: None,
            },
            &[q],
        );
        g
    }

    /// A transformer encoder block (H-head self-attention + FFN, post-norm)
    /// as a calibrated graph over `[seq][d_model]` values — the
    /// dynamic-weight workload (DESIGN.md §10).
    ///
    /// Every weight-stationary projection (`Wq/Wk/Wv`, per-head `Wo`, the
    /// FFN) lowers to its own tile grid; the two act×act products per head
    /// (`Q·Kᵀ` and `attn·V`) become [`Op::MatMul`] nodes whose right
    /// operand is re-quantized and reloaded into dedicated tiles per call.
    /// The concat-free output projection sums per-head `ctx_i · Wo_i`
    /// (exactly `concat(ctx)·W_O`; see [`TransformerBlock`]). The `1/√d_h`
    /// score scale rides on a bias-free [`Op::Dequantize`].
    pub fn from_transformer_block(block: &TransformerBlock, seq: usize) -> Self {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![seq, block.d_model] }, &[]);
        add_attention_block(&mut g, block, x, "", false);
        g
    }

    /// A multi-layer GPT-style causal decoder as a calibrated graph over a
    /// fixed-length `[seq][d_model]` embedded prefix: N attention blocks
    /// with [`Op::CausalSoftmax`] masks, then the LM head (DESIGN.md §13).
    ///
    /// Output is `[seq][vocab]` — row `i` the next-token logits after
    /// position `i`, matching [`DecoderModel::forward_causal`] on embedded
    /// inputs. This fixed-shape graph is the compile-path complement of the
    /// incremental KV-cache engine (`compiler::decode`): the engine owns
    /// ragged growth and running requantization; the graph gives the float
    /// golden and the barrier/streamed plan coverage for causal attention.
    ///
    /// [`DecoderModel::forward_causal`]: crate::nn::transformer::DecoderModel::forward_causal
    pub fn from_decoder(model: &crate::nn::transformer::DecoderModel, seq: usize) -> Self {
        assert!(seq >= 1 && seq <= model.max_seq, "seq {seq} vs max_seq {}", model.max_seq);
        let mut g = Graph::new();
        let mut cur = g.add("input", Op::Input { shape: vec![seq, model.d_model] }, &[]);
        for (l, block) in model.blocks.iter().enumerate() {
            cur = add_attention_block(&mut g, block, cur, &format!("l{l}."), true);
        }
        let hq = g.add("head.quant", Op::Quantize { params: None }, &[cur]);
        g.add(
            "head",
            Op::Linear {
                w_cols: model.w_head.clone(),
                bias: model.b_head.clone(),
                w_params: None,
            },
            &[hq],
        );
        g
    }

    /// A post-training-quantized [`MlpDeployment`] as a unit-scale graph:
    /// layers carry the integer weight planes with unit quantization params
    /// and explicit `Dequantize` nodes restore the deployment's scales —
    /// arithmetic identical, expression for expression, to
    /// [`MlpDeployment::run_native`], so the compiled plan is bit-identical
    /// to it noise-free.
    pub fn from_deployment(dep: &MlpDeployment) -> Self {
        let unit_w = QuantParams { scale: 1.0, q_min: -7, q_max: 7 };
        let a0 = QuantParams { scale: dep.a0_scale, q_min: 0, q_max: 15 };
        let a1_scale = dep.a1_cal / 15.0;
        let a1 = QuantParams { scale: a1_scale, q_min: 0, q_max: 15 };

        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![dep.dims[0]] }, &[]);
        let q0 = g.add("fc0.q", Op::Quantize { params: Some(a0) }, &[x]);
        let l0 = g.add(
            "fc0",
            Op::Linear {
                w_cols: dep.w1_q.clone(),
                bias: vec![0.0; dep.dims[1]],
                w_params: Some(unit_w),
            },
            &[q0],
        );
        let d0 = g.add(
            "fc0.deq",
            Op::Dequantize { scale: dep.a0_scale * dep.w1_scale, bias: dep.b1.clone() },
            &[l0],
        );
        let r0 = g.add("fc0.relu", Op::Relu, &[d0]);
        let q1 = g.add("fc1.q", Op::Quantize { params: Some(a1) }, &[r0]);
        let l1 = g.add(
            "fc1",
            Op::Linear {
                w_cols: dep.w2_q.clone(),
                bias: vec![0.0; dep.dims[2]],
                w_params: Some(unit_w),
            },
            &[q1],
        );
        g.add(
            "fc1.deq",
            Op::Dequantize { scale: a1_scale * dep.w2_scale, bias: dep.b2.clone() },
            &[l1],
        );
        g
    }
}

/// Append one H-head attention + FFN block (post-norm) rooted at `x`,
/// returning the block-output node. `prefix` namespaces the node names
/// (empty for the single-block encoder graph, `"l{N}."` per decoder
/// layer); `causal` selects [`Op::CausalSoftmax`] over [`Op::Softmax`].
/// Shared by [`Graph::from_transformer_block`] and [`Graph::from_decoder`]
/// so the two builders cannot drift structurally.
fn add_attention_block(
    g: &mut Graph,
    block: &TransformerBlock,
    x: NodeId,
    prefix: &str,
    causal: bool,
) -> NodeId {
    use crate::nn::transformer::LN_EPS;
    let (d, h, dh) = (block.d_model, block.heads, block.d_head());
    let quant = |g: &mut Graph, name: String, src: NodeId| -> NodeId {
        g.add(name, Op::Quantize { params: None }, &[src])
    };
    let mut attn = None;
    for i in 0..h {
        let p = format!("{prefix}h{i}");
        let linear = |w: &Tensor, b: &[f32]| Op::Linear {
            w_cols: w.clone(),
            bias: b.to_vec(),
            w_params: None,
        };
        let qq = quant(g, format!("{p}.q.quant"), x);
        let qi = g.add(format!("{p}.q"), linear(&block.wq[i], &block.bq[i]), &[qq]);
        let kq = quant(g, format!("{p}.k.quant"), x);
        let ki = g.add(format!("{p}.k"), linear(&block.wk[i], &block.bk[i]), &[kq]);
        let vq = quant(g, format!("{p}.v.quant"), x);
        let vi = g.add(format!("{p}.v"), linear(&block.wv[i], &block.bv[i]), &[vq]);

        let sq = quant(g, format!("{p}.score.quant"), qi);
        let scores = g.add(format!("{p}.score"), Op::MatMul { transpose_b: true }, &[sq, ki]);
        let scaled = g.add(
            format!("{p}.scale"),
            Op::Dequantize { scale: 1.0 / (dh as f32).sqrt(), bias: vec![] },
            &[scores],
        );
        let probs = if causal {
            g.add(format!("{p}.softmax"), Op::CausalSoftmax, &[scaled])
        } else {
            g.add(format!("{p}.softmax"), Op::Softmax, &[scaled])
        };
        let pq = quant(g, format!("{p}.ctx.quant"), probs);
        let ctx = g.add(format!("{p}.ctx"), Op::MatMul { transpose_b: false }, &[pq, vi]);

        let oq = quant(g, format!("{p}.out.quant"), ctx);
        // The shared output bias is applied once (on head 0's slice).
        let ob = if i == 0 { block.b_o.clone() } else { vec![0.0; d] };
        let oi = g.add(
            format!("{p}.out"),
            Op::Linear { w_cols: block.wo[i].clone(), bias: ob, w_params: None },
            &[oq],
        );
        attn = Some(match attn {
            None => oi,
            Some(acc) => g.add(format!("{prefix}attn.sum{i}"), Op::Add, &[acc, oi]),
        });
    }
    let res1 = g.add(format!("{prefix}res1"), Op::Add, &[x, attn.expect("at least one head")]);
    let ln1 = g.add(
        format!("{prefix}ln1"),
        Op::LayerNorm {
            gamma: block.ln1_gamma.clone(),
            beta: block.ln1_beta.clone(),
            eps: LN_EPS,
        },
        &[res1],
    );
    let fq = quant(g, format!("{prefix}ffn1.quant"), ln1);
    let f1 = g.add(
        format!("{prefix}ffn1"),
        Op::Linear { w_cols: block.w_ff1.clone(), bias: block.b_ff1.clone(), w_params: None },
        &[fq],
    );
    let f1r = g.add(format!("{prefix}ffn1.relu"), Op::Relu, &[f1]);
    let f2q = quant(g, format!("{prefix}ffn2.quant"), f1r);
    let f2 = g.add(
        format!("{prefix}ffn2"),
        Op::Linear { w_cols: block.w_ff2.clone(), bias: block.b_ff2.clone(), w_params: None },
        &[f2q],
    );
    let res2 = g.add(format!("{prefix}res2"), Op::Add, &[ln1, f2]);
    g.add(
        format!("{prefix}ln2"),
        Op::LayerNorm {
            gamma: block.ln2_gamma.clone(),
            beta: block.ln2_beta.clone(),
            eps: LN_EPS,
        },
        &[res2],
    )
}

fn add_conv(g: &mut Graph, name: impl Into<String>, layer: &ConvLayer, input: NodeId) -> NodeId {
    let name = name.into();
    let q = g.add(format!("{name}.q"), Op::Quantize { params: None }, &[input]);
    g.add(
        name,
        Op::Conv2d {
            w: layer.w.clone(),
            bias: layer.b.clone(),
            stride: layer.stride,
            pad: layer.pad,
            w_params: None,
        },
        &[q],
    )
}

/// The `Dequantize` affine `y = x·scale + bias` — the single definition
/// shared by [`Graph::eval_float`] and the compiled-plan executor.
pub(crate) fn dequantize(t: &Tensor, scale: f32, bias: &[f32]) -> Tensor {
    if bias.is_empty() {
        t.clone().map(|v| v * scale)
    } else {
        Tensor::from_vec(
            &t.shape,
            t.data.iter().zip(bias).map(|(&v, &b)| v * scale + b).collect(),
        )
    }
}

/// Transpose `[out][in]` weights to `[in][out]` (one column per engine) —
/// the layout `CimLinear` consumes. Public so references built outside the
/// compiler (examples, tests) share the exact lowering layout.
pub fn transpose_rows_to_cols(w: &Tensor) -> Tensor {
    assert_eq!(w.rank(), 2);
    let (o, i) = (w.shape[0], w.shape[1]);
    let mut t = Tensor::zeros(&[i, o]);
    for oo in 0..o {
        for ii in 0..i {
            *t.at2_mut(ii, oo) = w.at2(oo, ii);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::dataset::random_image;

    #[test]
    fn mlp_graph_shapes_and_float_eval_match_mlp() {
        let mlp = Mlp::new(&[12, 8, 4], 3);
        let g = Graph::from_mlp(&mlp);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![4]);
        let x: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin().abs()).collect();
        let vals = g.eval_float(&Tensor::from_vec(&[12], x.clone())).unwrap();
        let want = mlp.logits(&x);
        let got = &vals[g.output()].data;
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn resnet_graph_matches_float_forward() {
        let net = ResNet20::new(5);
        let g = Graph::from_resnet20(&net);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![10]);
        // Conv node count: 19 main + 2 projections.
        let convs = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv2d { .. })).count();
        assert_eq!(convs, 21);
        let x = random_image(&[3, 32, 32], 9);
        let vals = g.eval_float(&x).unwrap();
        let want = net.forward(&x);
        let got = &vals[g.output()].data;
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    /// The transformer graph's float eval equals the block's own float
    /// forward (including the concat-free head sum), and the new ops infer
    /// the right shapes.
    #[test]
    fn transformer_graph_matches_block_forward() {
        let block = TransformerBlock::new(16, 2, 24, 5);
        let seq = 4;
        let g = Graph::from_transformer_block(&block, seq);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![seq, 16]);
        // 2 MatMul nodes per head, one Softmax per head.
        let mm = g.nodes.iter().filter(|n| matches!(n.op, Op::MatMul { .. })).count();
        assert_eq!(mm, 4);
        let sm = g.nodes.iter().filter(|n| matches!(n.op, Op::Softmax)).count();
        assert_eq!(sm, 2);
        let mut rng = crate::util::rng::Xoshiro256::seeded(9);
        let x = Tensor::from_vec(
            &[seq, 16],
            (0..seq * 16).map(|_| crate::util::rng::Rng::next_f32(&mut rng) - 0.5).collect(),
        );
        let vals = g.eval_float(&x).unwrap();
        let want = block.forward(&x);
        for (a, b) in vals[g.output()].data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    /// The decoder graph's float eval equals the model's own causal
    /// forward — the CausalSoftmax node and the stacked-block builder
    /// reproduce the float golden exactly.
    #[test]
    fn decoder_graph_matches_causal_forward() {
        use crate::nn::transformer::DecoderModel;
        let model = DecoderModel::new(12, 2, 20, 9, 2, 8, 33);
        let seq = 5;
        let g = Graph::from_decoder(&model, seq);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![seq, 9]);
        let cs = g.nodes.iter().filter(|n| matches!(n.op, Op::CausalSoftmax)).count();
        assert_eq!(cs, 2 * 2, "one causal softmax per head per layer");
        let toks = [1usize, 4, 0, 7, 2];
        let x = model.embed_seq(&toks);
        let vals = g.eval_float(&x).unwrap();
        let want = model.forward_causal(&toks);
        for (a, b) in vals[g.output()].data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn causal_softmax_shape_rule_requires_square() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![3, 4] }, &[]);
        g.add("cs", Op::CausalSoftmax, &[x]);
        assert!(g.infer_shapes().is_err(), "non-square scores must be rejected");
    }

    #[test]
    fn matmul_and_norm_shape_errors_are_caught() {
        // Mismatched inner dims.
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![3, 4] }, &[]);
        let q = g.add("q", Op::Quantize { params: None }, &[x]);
        g.add("mm", Op::MatMul { transpose_b: false }, &[q, x]);
        // [3][4] × [3][4] without transpose: inner 4 vs 3 mismatch.
        assert!(g.infer_shapes().is_err());

        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![3, 4] }, &[]);
        g.add("ln", Op::LayerNorm { gamma: vec![1.0; 3], beta: vec![0.0; 3], eps: 1e-5 }, &[x]);
        // γ/β sized for the wrong dimension.
        assert!(g.infer_shapes().is_err());
    }

    #[test]
    fn rowwise_linear_infers_and_evaluates() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![3, 4] }, &[]);
        let q = g.add("q", Op::Quantize { params: None }, &[x]);
        g.add(
            "fc",
            Op::Linear {
                w_cols: Tensor::from_vec(&[4, 2], (0..8).map(|i| i as f32 * 0.1).collect()),
                bias: vec![1.0, -1.0],
                w_params: None,
            },
            &[q],
        );
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![3, 2]);
        let x = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32).collect());
        let vals = g.eval_float(&x).unwrap();
        // Row 0 = [0,1,2,3]: col 0 = Σ i·w[i][0] = 0·0 + 1·.2 + 2·.4 + 3·.6 = 2.8.
        assert!((vals[g.output()].at2(0, 0) - (2.8 + 1.0)).abs() < 1e-5);
    }

    #[test]
    fn shape_errors_are_caught() {
        let mut g = Graph::new();
        let x = g.add("input", Op::Input { shape: vec![3, 8, 8] }, &[]);
        let q = g.add("q", Op::Quantize { params: None }, &[x]);
        // 4-input-channel conv on a 3-channel value.
        g.add(
            "bad",
            Op::Conv2d {
                w: Tensor::zeros(&[2, 4, 3, 3]),
                bias: vec![0.0; 2],
                stride: 1,
                pad: 1,
                w_params: None,
            },
            &[q],
        );
        assert!(g.infer_shapes().is_err());
    }

    #[test]
    fn deployment_graph_structure() {
        let mlp = Mlp::new(&[6, 5, 3], 1);
        let cal: Vec<Vec<f32>> = (0..4).map(|i| vec![0.1 * (i as f32 + 1.0); 6]).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
        let g = Graph::from_deployment(&dep);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output()], vec![3]);
        assert!(matches!(g.nodes[g.output()].op, Op::Dequantize { .. }));
        // Both linears carry explicit unit weight params.
        for n in &g.nodes {
            if let Op::Linear { w_params, .. } = &n.op {
                assert_eq!(w_params.unwrap().scale, 1.0);
            }
        }
        // The float golden of a unit-scale graph IS the quantized digital
        // reference (explicit-param Quantize nodes emit integer codes).
        let x: Vec<f32> = (0..6).map(|i| 0.15 * (i as f32 + 1.0)).collect();
        let want = dep.run_digital(&[x.clone()]).remove(0);
        let got = g.eval_float(&Tensor::from_vec(&[6], x)).unwrap();
        for (a, b) in got[g.output()].data.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
