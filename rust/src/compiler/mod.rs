//! Graph compiler: lower whole quantized networks onto the macro pool.
//!
//! The paper's headline claim is system-level — its Fig. 1 comparison maps
//! a 4-bit ResNet-20 onto the CIM cores. This module is the bridge from a
//! network description to that execution:
//!
//! ```text
//!   ingest                lower                  place               execute
//!   ──────                ─────                  ─────               ───────
//!   nn::Mlp        ┌──► Graph IR ──► CimLinear tiles ──► MacroPool slots ──► CompiledPlan
//!   nn::ResNet20 ──┤    (Conv2d,     (im2col lowering,   (cost-model-driven  (BatchExecutor,
//!   MlpDeployment ─┘     Linear,      per-layer act       placer: balance    per-layer cycle/
//!                        Relu, Add,   calibration via     est. cycles across energy accounting,
//!                        GAP, Quant/  nn::quant)          shards, auto-grow) InferenceEngine)
//!                        Dequant)
//! ```
//!
//! * **Ingest** — [`Graph::from_mlp`], [`Graph::from_resnet20`] build
//!   calibrated float graphs; [`Graph::from_transformer_block`] builds an
//!   MHA+FFN encoder block (the dynamic-weight workload, DESIGN.md §10);
//!   [`Graph::from_deployment`] builds the unit-scale graph of a
//!   post-training-quantized MLP bundle (the arithmetic of
//!   `MlpDeployment::run_native`, expression for expression).
//! * **Lower** — every `Quantize → Conv2d/Linear` pair becomes a tiled
//!   [`crate::mapping::executor::CimLinear`] (convs via the shared im2col
//!   path), with activation ranges calibrated by running the float graph
//!   over a calibration set; boundaries that go negative calibrate to the
//!   signed-activation zero-point format. `Quantize → MatMul` pairs become
//!   *dynamic-weight* tiles: the right operand is re-quantized per call
//!   and reloaded into the placed grid (DESIGN.md §10).
//! * **Place** — the pool is pre-sized to the network's exact shard count,
//!   then [`place::Placer`] packs each tile onto the shard with the least
//!   accumulated estimated cycles that still has a free core (growing only
//!   as a fallback), using [`crate::cim::timing::op_cycles`] +
//!   [`crate::energy::core_op_energy`] for the estimates; dynamic layers
//!   get dedicated shards ([`crate::pipeline::DynamicLinear`]) and their
//!   reload cycles/energy are broken out in [`CostReport`].
//! * **Execute** — [`CompiledPlan::run_batch`] streams batches through the
//!   resident pool via [`crate::pipeline::BatchExecutor`]; noise-free the
//!   result is bit-identical to the sequential per-layer macro path. The
//!   plan implements `coordinator::server::InferenceEngine`, so
//!   `serve --plan` serves any compiled network.
//! * **Decode** — [`DecodePlan`] compiles a GPT-style
//!   [`crate::nn::transformer::DecoderModel`] for autoregressive KV-cache
//!   execution (DESIGN.md §13): static weights resident once, per-session
//!   [`crate::pipeline::KvCache`] grids for the growing K/V slabs, and
//!   [`ContinuousBatcher`] for token-level continuous batching
//!   (`serve --decode`).
//!
//! **Sizing (ResNet-20, default 16 Kb macro geometry):** 22 layers lower to
//! 282 tiles (64 rows × 16 engines each) ⇒ 282 slots = 71 shards at 4
//! cores/shard, ~1.1 Mb of weight SRAM held resident; one CIFAR image
//! streams 9 409 activation vectors (im2col positions + the FC vector)
//! through the pool.
//!
//! Execution rides the bit-plane fast-path kernel end to end (DESIGN.md
//! §4): `CompiledPlan::run_batch` → `BatchExecutor` → one kernel
//! preparation per (item, row tile), closed-form integer dot products
//! noise-free. See [`Graph::from_mlp`] and [`CompiledPlan`] for runnable
//! ingest-to-logits examples; `cargo bench --bench compiler_resnet`
//! measures compile + forward throughput (`BENCH_compiler.json`).

pub mod decode;
pub mod ir;
pub mod lower;
pub mod place;
pub mod plan;

pub use decode::{
    argmax, ContinuousBatcher, DecodePlan, DecodeRequest, DecodeSession, Finished,
};
pub use ir::{transpose_rows_to_cols, Graph, Node, NodeId, Op};
pub use lower::{calibrate, lower, Calibration, CompileError, LayerKind, LoweredLayer};
pub use place::{ActivationProfile, CostReport, LayerCost, Placer, SlotHost, VirtualPool};
pub use plan::{
    compile, estimate_cost, estimate_cost_lowered, CompileOptions, CompiledLayer, CompiledPlan,
    StreamOptions, StreamOutcome,
};
