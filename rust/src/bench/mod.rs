//! Criterion-lite measurement harness (the offline environment vendors no
//! `criterion`). Each `rust/benches/*.rs` target sets `harness = false` and
//! drives this module, which provides warmup, adaptive iteration-count
//! selection, and robust summary statistics.

use std::time::{Duration, Instant};

/// Summary statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub samples: Vec<f64>, // seconds per iteration, one per sample batch
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn throughput_line(&self, items_per_iter: f64, unit: &str) -> String {
        let per_sec = items_per_iter / self.mean_s;
        format!(
            "{:<44} {:>12}/iter  {:>14} {}/s",
            self.name,
            fmt_duration(self.mean_s),
            fmt_sig3(per_sec),
            unit
        )
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  σ {:>9}  ({} iters × {} samples)",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.p50_s),
            fmt_duration(self.p95_s),
            fmt_duration(self.std_s),
            self.iters,
            self.samples.len(),
        )
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub samples: usize,
    quiet: bool,
}

impl Default for Bench {
    fn default() -> Self {
        // CIMSIM_BENCH_FAST=1 trims times for CI smoke runs.
        let fast = std::env::var("CIMSIM_BENCH_FAST").ok().as_deref() == Some("1");
        Self {
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            samples: if fast { 8 } else { 20 },
            quiet: false,
        }
    }
}

impl Bench {
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Measure `f`, which performs ONE logical iteration per call. The runner
    /// first estimates the per-call cost during warmup, then picks an
    /// iteration count per sample so each sample batch runs long enough for
    /// the clock to be trustworthy.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 3 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est_per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let per_sample_target = (self.measure.as_secs_f64() / self.samples as f64).max(1e-4);
        let iters = ((per_sample_target / est_per_iter).ceil() as u64).clamp(1, 10_000_000);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let m = summarize(name, iters, samples);
        if !self.quiet {
            println!("{}", m.report_line());
        }
        m
    }

    /// Variant for benchmarks whose single iteration is already long (>~50ms):
    /// runs `f` exactly `n` times with no inner loop.
    pub fn run_slow<F: FnMut()>(&self, name: &str, n: usize, mut f: F) -> Measurement {
        f(); // single warmup
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n.max(2) {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = summarize(name, 1, samples);
        if !self.quiet {
            println!("{}", m.report_line());
        }
        m
    }
}

fn summarize(name: &str, iters: u64, mut samples: Vec<f64>) -> Measurement {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n as f64;
    Measurement {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        p50_s: percentile(&samples, 0.50),
        p95_s: percentile(&samples, 0.95),
        min_s: samples[0],
        samples,
    }
}

/// Percentile on pre-sorted data with linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn fmt_duration(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

fn fmt_sig3(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{:.2}", x)
    }
}

/// Prevent the optimizer from discarding a computed value (std-only blackbox).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Absolute path of a `BENCH_*.json` trajectory file at the repository root
/// (one directory above this crate), so benches land their rows in the same
/// place whether `cargo bench` runs from the workspace root or `rust/`.
pub fn bench_json_path(file_name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join(file_name)
}

/// The build profile a measurement ran under — recorded in every JSON row so
/// a debug-profile smoke number is never mistaken for a release bench.
pub fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Whether `CIMSIM_BENCH_FAST=1` trimmed this run — the same switch
/// [`Bench::default`] consults. Recorded in every JSON row's provenance
/// (`"fast"`) so a smoke-depth number is never mistaken for a full bench.
pub fn fast_mode() -> bool {
    std::env::var("CIMSIM_BENCH_FAST").ok().as_deref() == Some("1")
}

/// The host's available hardware parallelism — recorded in every JSON row's
/// provenance (`"threads"`) so numbers from differently-sized machines are
/// never silently compared. Excluded from the bench gate's row identity.
pub fn host_threads() -> i64 {
    std::thread::available_parallelism().map(|n| n.get() as i64).unwrap_or(1)
}

/// The shared provenance tail every bench row ends with: build profile,
/// measurement source, the dispatched MAC kernel tier, host thread count,
/// and the fast-mode flag.
pub fn provenance_fields() -> [JsonField<'static>; 5] {
    [
        JsonField::Str("profile", build_profile()),
        JsonField::Str("source", "measured"),
        JsonField::Str("kernel", crate::cim::simd::kernel_tier().name()),
        JsonField::Int("threads", host_threads()),
        JsonField::Str("fast", if fast_mode() { "1" } else { "0" }),
    ]
}

/// One field of a [`json_row`] (the environment vendors no `serde`).
pub enum JsonField<'a> {
    Str(&'a str, &'a str),
    Int(&'a str, i64),
    Num(&'a str, f64),
}

/// Render a flat JSON object, escaping string values. Benchmarks emit one
/// row per comparison so results diff cleanly across machines/commits.
pub fn json_row(fields: &[JsonField]) -> String {
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let body: Vec<String> = fields
        .iter()
        .map(|f| match f {
            JsonField::Str(k, v) => format!("\"{}\": \"{}\"", esc(k), esc(v)),
            JsonField::Int(k, v) => format!("\"{}\": {v}", esc(k)),
            JsonField::Num(k, v) => {
                if v.is_finite() {
                    format!("\"{}\": {v:.6}", esc(k))
                } else {
                    format!("\"{}\": null", esc(k))
                }
            }
        })
        .collect();
    format!("{{{}}}", body.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_fast() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            samples: 5,
            quiet: true,
        };
        let mut acc = 0u64;
        let m = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.mean_s > 0.0 && m.mean_s < 1e-3);
        assert_eq!(m.samples.len(), 5);
        assert!(m.p50_s <= m.p95_s);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.0), "2.000 s");
        assert_eq!(fmt_duration(2e-3), "2.000 ms");
        assert_eq!(fmt_duration(2e-6), "2.000 µs");
        assert_eq!(fmt_duration(2e-9), "2.0 ns");
    }

    #[test]
    fn json_row_renders_and_escapes() {
        let row = json_row(&[
            JsonField::Str("bench", "pipeline \"pooled\""),
            JsonField::Int("batch", 64),
            JsonField::Num("speedup", 3.25),
            JsonField::Num("bad", f64::NAN),
        ]);
        assert!(row.starts_with('{') && row.ends_with('}'));
        assert!(row.contains("\"bench\": \"pipeline \\\"pooled\\\"\""));
        assert!(row.contains("\"batch\": 64"));
        assert!(row.contains("\"speedup\": 3.250000"));
        assert!(row.contains("\"bad\": null"));
    }

    #[test]
    fn run_slow_collects_n_samples() {
        let b = Bench::default().quiet();
        let m = b.run_slow("slow", 3, || {
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(m.samples.len(), 3);
        assert!(m.mean_s >= 1e-3);
    }
}
