//! Measurement analysis: summary statistics, histograms, and ADC linearity
//! (transfer curve / DNL / INL) used by the Fig. 1–7 harness.

pub mod linearity;
pub mod stats;

pub use linearity::{Linearity, Transfer, Transitions};
pub use stats::{linfit, Histogram, Stats};
