//! Summary statistics and histograms used by every accuracy experiment.

/// Streaming mean/variance/extrema accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        for x in xs {
            self.push(x);
        }
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Stats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += d * n2 / n;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// RMS of the pushed values (√(mean²+var)).
    pub fn rms(&self) -> f64 {
        (self.mean * self.mean + self.var()).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to the edge
/// bins (counted, never dropped — the harness reports clip rates from this).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins] }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * n as f64).floor() as i64).clamp(0, n as i64 - 1) as usize;
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Fraction of samples in bin `i`.
    pub fn frac(&self, i: usize) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.bins[i] as f64 / t as f64
        }
    }
}

/// Ordinary least squares fit y = a + b·x, returning (a, b, r²).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let (mut sxx, mut sxy, mut syy) = (0.0, 0.0, 0.0);
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut s = Stats::new();
        s.extend(xs.iter().copied());
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 5.0;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 16.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Stats::new();
        all.extend(xs.iter().copied());
        let mut a = Stats::new();
        let mut b = Stats::new();
        a.extend(xs[..37].iter().copied());
        b.extend(xs[37..].iter().copied());
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn rms_identity() {
        let mut s = Stats::new();
        s.extend([3.0, -3.0, 3.0, -3.0]);
        assert!((s.rms() - 3.0).abs() < 1e-12);
        assert!(s.mean().abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamping() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(0.5); // bin 0
        h.push(9.99); // bin 9
        h.push(-5.0); // clamps to 0
        h.push(50.0); // clamps to 9
        h.push(5.0); // bin 5
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
        assert_eq!(h.bins[5], 1);
        assert_eq!(h.total(), 5);
        assert!((h.bin_center(5) - 5.5).abs() < 1e-12);
        assert!((h.frac(0) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.5 * x).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.5).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-12);
    }
}
