//! ADC linearity metrics: transfer curve, DNL and INL (Fig. 5).
//!
//! DNL/INL are *static* linearity metrics: chip measurement averages dynamic
//! noise away, which in the simulator corresponds to sweeping the transfer
//! with dynamic noise zeroed while fabrication mismatch stays active. The
//! transition level T(k) is the input at which the output first reaches code
//! k; DNL(k) = (T(k+1) − T(k))/LSB − 1 and INL is measured against the
//! endpoint-fit line, both in LSB.

/// A measured static transfer: monotone input sweep with the observed code
/// per input.
#[derive(Clone, Debug)]
pub struct Transfer {
    pub inputs: Vec<f64>,
    pub codes: Vec<i32>,
}

/// Transition levels extracted from a static transfer: `levels[i]` is the
/// input at which the code first reaches `first_code + 1 + i`.
#[derive(Clone, Debug)]
pub struct Transitions {
    pub first_code: i32,
    pub levels: Vec<f64>,
}

#[derive(Clone, Debug, Default)]
pub struct Linearity {
    /// DNL per code bin, LSB.
    pub dnl: Vec<f64>,
    /// INL per transition (endpoint fit), LSB.
    pub inl: Vec<f64>,
    pub dnl_max_abs: f64,
    pub inl_max_abs: f64,
}

impl Transfer {
    /// Extract code-transition levels. The sweep must be fine enough that
    /// every code in the covered range is visited; codes may glitch locally
    /// (non-monotone ADC) — the first crossing is used, the standard
    /// convention for a sweep measurement.
    pub fn transitions(&self) -> Transitions {
        assert_eq!(self.inputs.len(), self.codes.len());
        assert!(!self.inputs.is_empty());
        let first_code = *self.codes.iter().min().unwrap();
        let last_code = *self.codes.iter().max().unwrap();
        let mut levels = Vec::new();
        let mut reached = first_code;
        for (i, &c) in self.codes.iter().enumerate() {
            while reached < c && reached < last_code {
                reached += 1;
                // Midpoint between this sample and the previous one.
                let x = if i == 0 {
                    self.inputs[0]
                } else {
                    0.5 * (self.inputs[i - 1] + self.inputs[i])
                };
                levels.push(x);
            }
        }
        Transitions { first_code, levels }
    }
}

impl Transitions {
    /// Compute DNL/INL in units of `lsb`. Requires ≥ 3 transition levels.
    pub fn linearity(&self, lsb: f64) -> Linearity {
        let t = &self.levels;
        if t.len() < 3 {
            return Linearity::default();
        }
        let n = t.len();
        let mut dnl = Vec::with_capacity(n - 1);
        for i in 0..n - 1 {
            dnl.push((t[i + 1] - t[i]) / lsb - 1.0);
        }
        // Endpoint-fit INL: line through (0, t[0]) .. (n−1, t[n−1]).
        let slope = (t[n - 1] - t[0]) / (n - 1) as f64;
        let mut inl = Vec::with_capacity(n);
        for (i, &x) in t.iter().enumerate() {
            inl.push((x - (t[0] + slope * i as f64)) / lsb);
        }
        let dnl_max_abs = dnl.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        let inl_max_abs = inl.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        Linearity { dnl, inl, dnl_max_abs, inl_max_abs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a transfer for an ideal mid-rise ADC with the given LSB.
    fn ideal_transfer(lsb: f64, lo: f64, hi: f64, step: f64) -> Transfer {
        let mut inputs = Vec::new();
        let mut codes = Vec::new();
        let mut x = lo;
        while x <= hi {
            inputs.push(x);
            codes.push((x / lsb).ceil() as i32 - 1);
            x += step;
        }
        Transfer { inputs, codes }
    }

    #[test]
    fn ideal_adc_has_zero_dnl_inl() {
        let lsb = 26.25;
        let tr = ideal_transfer(lsb, -10.0 * lsb, 10.0 * lsb, lsb / 50.0);
        let t = tr.transitions();
        let lin = t.linearity(lsb);
        assert!(lin.dnl_max_abs < 0.05, "dnl {}", lin.dnl_max_abs);
        assert!(lin.inl_max_abs < 0.05, "inl {}", lin.inl_max_abs);
        // 20 codes → 20 transitions (roughly).
        assert!(t.levels.len() >= 19);
    }

    #[test]
    fn detects_a_wide_code() {
        // Stretch code 2 to span [2,5) — three LSB wide instead of one.
        let lsb = 1.0;
        let mut inputs = Vec::new();
        let mut codes = Vec::new();
        let mut x: f64 = 0.0;
        while x < 10.0 {
            let c = if x < 3.0 {
                (x / lsb).ceil() as i32 - 1
            } else if x < 5.0 {
                2
            } else {
                ((x - 2.0) / lsb).ceil() as i32 - 1
            };
            inputs.push(x);
            codes.push(c);
            x += 0.01;
        }
        let lin = Transfer { inputs, codes }.transitions().linearity(lsb);
        // The stretched bin reads ≈ +2 LSB DNL.
        let max_dnl = lin.dnl.iter().cloned().fold(f64::MIN, f64::max);
        assert!((max_dnl - 2.0).abs() < 0.1, "max dnl {max_dnl}");
        assert!(lin.inl_max_abs > 0.4);
    }

    #[test]
    fn transition_positions_are_midpoints() {
        let tr = Transfer {
            inputs: vec![0.0, 1.0, 2.0, 3.0],
            codes: vec![0, 0, 1, 1],
        };
        let t = tr.transitions();
        assert_eq!(t.first_code, 0);
        assert_eq!(t.levels, vec![1.5]);
    }

    #[test]
    fn too_few_transitions_yield_default() {
        let tr = Transfer { inputs: vec![0.0, 1.0], codes: vec![0, 1] };
        let lin = tr.transitions().linearity(1.0);
        assert!(lin.dnl.is_empty());
    }
}
