//! Layer executors: run quantized linear / conv layers on a [`CimBackend`],
//! weight-stationary per tile, with digital partial-sum accumulation across
//! row tiles — the deployment flow of the paper's edge-AI story.

use crate::mapping::{CimBackend, MapError};
use crate::nn::im2col::{conv_out_dims, im2col, weights_to_cols};
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;

/// Split an im2col patch matrix (`[positions][K]`) into per-position
/// activation rows — the shape the batched executors consume. Shared by
/// [`CimConv::run`] and the graph compiler's conv lowering so there is a
/// single source of truth for the im2col→matmul tiling.
pub fn patches_to_rows(patches: &Tensor) -> Vec<Vec<f32>> {
    assert_eq!(patches.rank(), 2);
    let (n_pos, k) = (patches.shape[0], patches.shape[1]);
    (0..n_pos).map(|r| patches.data[r * k..(r + 1) * k].to_vec()).collect()
}

/// Reassemble executor output rows (`[positions][out_c]`, row-major over
/// output positions) into a CHW tensor. Inverse of the im2col position
/// ordering; shared by [`CimConv::run`] and the compiled-plan executor.
pub fn rows_to_chw(rows: &[Vec<f32>], out_c: usize, oh: usize, ow: usize) -> Tensor {
    assert_eq!(rows.len(), oh * ow, "position count vs output dims");
    let mut out = Tensor::zeros(&[out_c, oh, ow]);
    for (pos, row) in rows.iter().enumerate() {
        let (oy, ox) = (pos / ow, pos % ow);
        for (c, &v) in row.iter().enumerate() {
            *out.at3_mut(c, oy, ox) = v;
        }
    }
    out
}

/// A quantized K×N matrix product prepared for the macro: weights tiled into
/// 64-row × 16-engine blocks.
#[derive(Clone, Debug)]
pub struct CimLinear {
    pub k: usize,
    pub n: usize,
    pub w_params: QuantParams,
    pub a_params: QuantParams,
    pub bias: Vec<f32>,
    /// Tiles in (row_tile, col_tile) order: `tiles[rt][ct]` is a padded
    /// rows×engines signed weight block.
    tiles: Vec<Vec<Vec<Vec<i64>>>>,
    /// Σ_k w_q[k][n] per output column — the digital constant behind the
    /// signed-activation zero-point correction (DESIGN.md §10).
    col_sums: Vec<i64>,
    rows_per_tile: usize,
    engines_per_tile: usize,
}

impl CimLinear {
    /// Build from float weights `w_cols` ([K][N], column per output) with
    /// max-abs weight quantization and a fixed activation calibration max.
    pub fn new(
        w_cols: &Tensor,
        bias: Vec<f32>,
        act_cal_max: f32,
        cfg: &crate::config::Config,
    ) -> Self {
        let w_params = QuantParams::signed(w_cols.max_abs(), cfg.mac.weight_bits);
        let a_params = QuantParams::unsigned(act_cal_max, cfg.mac.act_bits);
        Self::with_params(w_cols, bias, w_params, a_params, cfg)
    }

    /// Build with explicit quantization params (the bit-serial extension
    /// needs exact scale-1 digit planes).
    pub fn with_params(
        w_cols: &Tensor,
        bias: Vec<f32>,
        w_params: QuantParams,
        a_params: QuantParams,
        cfg: &crate::config::Config,
    ) -> Self {
        assert_eq!(w_cols.rank(), 2);
        let (k, n) = (w_cols.shape[0], w_cols.shape[1]);
        assert_eq!(bias.len(), n);
        let (rows, engines) = (cfg.mac.rows, cfg.mac.engines);
        let n_rt = k.div_ceil(rows);
        let n_ct = n.div_ceil(engines);
        let mut tiles = vec![vec![vec![vec![0i64; engines]; rows]; n_ct]; n_rt];
        let mut col_sums = vec![0i64; n];
        for kk in 0..k {
            for nn in 0..n {
                let q = w_params.quantize(w_cols.at2(kk, nn));
                tiles[kk / rows][nn / engines][kk % rows][nn % engines] = q;
                col_sums[nn] += q;
            }
        }
        Self {
            k,
            n,
            w_params,
            a_params,
            bias,
            tiles,
            col_sums,
            rows_per_tile: rows,
            engines_per_tile: engines,
        }
    }

    pub fn n_row_tiles(&self) -> usize {
        self.tiles.len()
    }

    pub fn n_col_tiles(&self) -> usize {
        self.tiles.first().map(|t| t.len()).unwrap_or(0)
    }

    /// The padded rows×engines signed weight block of tile `(rt, ct)` — the
    /// unit the pipeline pins to a pool shard.
    pub fn tile_block(&self, rt: usize, ct: usize) -> &[Vec<i64>] {
        &self.tiles[rt][ct]
    }

    pub fn rows_per_tile(&self) -> usize {
        self.rows_per_tile
    }

    pub fn engines_per_tile(&self) -> usize {
        self.engines_per_tile
    }

    /// Core ops needed per activation vector.
    pub fn ops_per_vector(&self) -> usize {
        self.n_row_tiles() * self.n_col_tiles()
    }

    /// Σ_k w_q[k][col] of the quantized plane (zero-point correction term).
    pub fn col_sum(&self, col: usize) -> i64 {
        self.col_sums[col]
    }

    /// The activation zero point ([`QuantParams::zero_point`] of
    /// `a_params`): 0 for unsigned (post-ReLU) params, 8 at 4-b for
    /// [`QuantParams::signed_acts`]. Quantized codes are shifted by this
    /// amount into the macro's unsigned window, and the executors restore
    /// `zp·Σw` digitally (DESIGN.md §10).
    pub fn act_zero(&self) -> i64 {
        self.a_params.zero_point()
    }

    /// Quantize a float activation vector (length K) into macro codes
    /// ([`QuantParams::quantize_codes`]: quantization plus the zero-point
    /// shift).
    pub fn quantize_acts(&self, x: &[f32]) -> Vec<i64> {
        assert_eq!(x.len(), self.k);
        self.a_params.quantize_codes(x)
    }

    /// Run a batch of quantized activation vectors, weight-stationary: every
    /// tile is loaded once and all vectors stream through it (the chip's
    /// usage pattern). Cores are assigned round-robin per tile. The whole
    /// per-tile batch goes through `CimBackend::core_op_batch`, which the
    /// native backend serves with the bit-plane batch kernel
    /// (`MacroSim::core_op_batch_into`) — bit-identical to per-op calls.
    pub fn run_batch_q(
        &self,
        backend: &mut dyn CimBackend,
        acts_q: &[Vec<i64>],
    ) -> Result<Vec<Vec<f32>>, MapError> {
        let cores = backend.config().mac.cores;
        let mut out = vec![vec![0f32; self.n]; acts_q.len()];
        let deq = self.a_params.scale * self.w_params.scale;
        let mut tile_idx = 0usize;
        for (rt, row_tiles) in self.tiles.iter().enumerate() {
            let r0 = rt * self.rows_per_tile;
            for (ct, block) in row_tiles.iter().enumerate() {
                let core = tile_idx % cores;
                tile_idx += 1;
                backend.load_core(core, block)?;
                let c0 = ct * self.engines_per_tile;
                // Slice + zero-pad this row tile's activations (whole batch)
                // and stream them through the resident tile in one call.
                let tile_batch: Vec<Vec<i64>> = acts_q
                    .iter()
                    .map(|acts| {
                        assert_eq!(acts.len(), self.k, "activation length");
                        let mut tile_acts = vec![0i64; self.rows_per_tile];
                        let upper = (r0 + self.rows_per_tile).min(self.k);
                        tile_acts[..upper - r0].copy_from_slice(&acts[r0..upper]);
                        tile_acts
                    })
                    .collect();
                let results = backend.core_op_batch(core, &tile_batch)?;
                for (b, vals) in results.iter().enumerate() {
                    for (e, &v) in vals.iter().enumerate() {
                        let col = c0 + e;
                        if col < self.n {
                            out[b][col] += v as f32 * deq;
                        }
                    }
                }
            }
        }
        // Signed-activation zero-point restore (`zp·Σw` per column), then
        // bias — the exact expression order `pipeline::batch::run_vector`
        // uses, so the two executors stay bit-identical (DESIGN.md §10).
        let zp = self.act_zero();
        for row in out.iter_mut() {
            if zp != 0 {
                for (o, &cs) in row.iter_mut().zip(&self.col_sums) {
                    *o -= (zp * cs) as f32 * deq;
                }
            }
            for (o, b) in row.iter_mut().zip(&self.bias) {
                *o += b;
            }
        }
        Ok(out)
    }

    /// Float-in/float-out convenience: quantize, run, dequantize.
    pub fn run_batch(
        &self,
        backend: &mut dyn CimBackend,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>, MapError> {
        let q: Vec<Vec<i64>> = xs.iter().map(|x| self.quantize_acts(x)).collect();
        self.run_batch_q(backend, &q)
    }
}

/// A conv layer prepared for the macro: im2col + [`CimLinear`].
#[derive(Clone, Debug)]
pub struct CimConv {
    pub linear: CimLinear,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub out_c: usize,
}

impl CimConv {
    /// From float conv weights [oc][ic][kh][kw].
    pub fn new(
        w: &Tensor,
        bias: Vec<f32>,
        stride: usize,
        pad: usize,
        act_cal_max: f32,
        cfg: &crate::config::Config,
    ) -> Self {
        assert_eq!(w.rank(), 4);
        let (oc, _, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
        let w_cols = weights_to_cols(w);
        let linear = CimLinear::new(&w_cols, bias, act_cal_max, cfg);
        Self { linear, kh, kw, stride, pad, out_c: oc }
    }

    /// Run the conv on a CHW input, returning the CHW output. The lowering
    /// (im2col → per-position rows → tiled linear → CHW) is the same path the
    /// graph compiler's conv nodes execute.
    pub fn run(&self, backend: &mut dyn CimBackend, x: &Tensor) -> Result<Tensor, MapError> {
        let patches = im2col(x, self.kh, self.kw, self.stride, self.pad);
        let xs = patches_to_rows(&patches);
        let y = self.linear.run_batch(backend, &xs)?;
        let (oh, ow) = conv_out_dims(x.shape[1], x.shape[2], self.kh, self.kw, self.stride, self.pad);
        Ok(rows_to_chw(&y, self.out_c, oh, ow))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::mapping::{DigitalBackend, NativeBackend};
    use crate::nn::ops::conv2d;
    use crate::util::rng::{Rng, Xoshiro256};

    fn rand_cols(k: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seeded(seed);
        Tensor::from_vec(&[k, n], (0..k * n).map(|_| (rng.next_f32() - 0.5)).collect())
    }

    /// Digital backend through the tiler must equal the exact quantized
    /// matrix product for any K/N (incl. non-multiples of 64/16).
    #[test]
    fn tiled_digital_equals_exact_int_product() {
        for (k, n) in [(64, 16), (100, 20), (37, 5), (130, 33), (64, 1)] {
            let cfg = Config::default();
            let w = rand_cols(k, n, k as u64 * 31 + n as u64);
            let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
            let lin = CimLinear::new(&w, bias.clone(), 1.0, &cfg);
            let mut be = DigitalBackend::new(cfg.clone());
            let mut rng = Xoshiro256::seeded(9);
            let xs: Vec<Vec<f32>> =
                (0..3).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();
            let got = lin.run_batch(&mut be, &xs).unwrap();
            for (b, x) in xs.iter().enumerate() {
                let aq = lin.quantize_acts(x);
                for col in 0..n {
                    let mut acc = 0i64;
                    for kk in 0..k {
                        let wq = lin.w_params.quantize(w.at2(kk, col));
                        acc += aq[kk] * wq;
                    }
                    let want =
                        acc as f32 * lin.a_params.scale * lin.w_params.scale + bias[col];
                    assert!(
                        (got[b][col] - want).abs() < 1e-3,
                        "k={k} n={n} b={b} col={col}: {} vs {want}",
                        got[b][col]
                    );
                }
            }
            assert_eq!(
                be.stats().core_ops as usize,
                lin.ops_per_vector() * xs.len()
            );
        }
    }

    /// Noise-free native backend approximates the digital product within the
    /// per-tile quantization step bound.
    #[test]
    fn native_tracks_digital_within_quantization() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let (k, n) = (130, 20);
        let w = rand_cols(k, n, 5);
        let lin = CimLinear::new(&w, vec![0.0; n], 1.0, &cfg);
        let mut nat = NativeBackend::new(cfg.clone());
        let mut dig = DigitalBackend::new(cfg.clone());
        let mut rng = Xoshiro256::seeded(11);
        let xs: Vec<Vec<f32>> = (0..2).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();
        let a = lin.run_batch(&mut nat, &xs).unwrap();
        let b = lin.run_batch(&mut dig, &xs).unwrap();
        // Per row tile the ADC contributes ≤ half a step of error.
        let step_units = cfg.mac.adc_lsb_units() / cfg.enhance.dtc_scale();
        let bound = lin.n_row_tiles() as f32
            * (step_units as f32 / 2.0)
            * lin.a_params.scale
            * lin.w_params.scale
            + 1e-4;
        for (ra, rb) in a.iter().zip(&b) {
            for (va, vb) in ra.iter().zip(rb) {
                assert!((va - vb).abs() <= bound, "{va} vs {vb} (bound {bound})");
            }
        }
    }

    /// Signed activations through the zero-point shift + digital `zp·Σw`
    /// restore equal the exact signed integer product on the digital
    /// backend — the transformer path's activation format (DESIGN.md §10).
    #[test]
    fn signed_acts_zero_point_equals_exact_signed_product() {
        use crate::nn::quant::QuantParams;
        for (k, n) in [(64, 16), (100, 20), (37, 5)] {
            let cfg = Config::default();
            let w = rand_cols(k, n, 7 * k as u64 + n as u64);
            let wp = QuantParams::signed(w.max_abs(), cfg.mac.weight_bits);
            let ap = QuantParams::signed_acts(1.0, cfg.mac.act_bits);
            let lin = CimLinear::with_params(&w, vec![0.0; n], wp, ap, &cfg);
            assert_eq!(lin.act_zero(), 8);
            let mut be = DigitalBackend::new(cfg.clone());
            let mut rng = Xoshiro256::seeded(33);
            // Signed inputs spanning the calibrated range.
            let xs: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..k).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
                .collect();
            let got = lin.run_batch(&mut be, &xs).unwrap();
            for (b, x) in xs.iter().enumerate() {
                for col in 0..n {
                    let mut acc = 0i64;
                    for kk in 0..k {
                        acc += lin.a_params.quantize(x[kk]) * lin.w_params.quantize(w.at2(kk, col));
                    }
                    let want = acc as f32 * lin.a_params.scale * lin.w_params.scale;
                    assert!(
                        (got[b][col] - want).abs() < 1e-3,
                        "k={k} n={n} b={b} col={col}: {} vs {want}",
                        got[b][col]
                    );
                }
            }
        }
    }

    /// Full conv layer on the digital backend equals the quantized reference
    /// convolution.
    #[test]
    fn cim_conv_matches_quantized_conv() {
        let cfg = Config::default();
        let mut rng = Xoshiro256::seeded(21);
        let x = Tensor::from_vec(&[3, 6, 6], (0..108).map(|_| rng.next_f32()).collect());
        let wf = Tensor::from_vec(
            &[8, 3, 3, 3],
            (0..8 * 27).map(|_| rng.next_f32() - 0.5).collect(),
        );
        let conv = CimConv::new(&wf, vec![0.0; 8], 1, 1, 1.0, &cfg);
        let mut be = DigitalBackend::new(cfg.clone());
        let got = conv.run(&mut be, &x).unwrap();

        // Reference: quantize both operands with the same params, run float
        // conv on the dequantized values.
        let wq = Tensor::from_vec(
            wf.shape.clone().as_slice(),
            wf.data
                .iter()
                .map(|&v| conv.linear.w_params.dequantize(conv.linear.w_params.quantize(v)))
                .collect(),
        );
        let xq = Tensor::from_vec(
            x.shape.clone().as_slice(),
            x.data
                .iter()
                .map(|&v| conv.linear.a_params.dequantize(conv.linear.a_params.quantize(v)))
                .collect(),
        );
        let want = conv2d(&xq, &wq, None, 1, 1);
        assert_eq!(got.shape, want.shape);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }
}
