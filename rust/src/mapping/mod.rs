//! Mapping NN layers onto the CIM macro: backends, tiling, layer executors
//! and the 8-b bit-serial precision extension.
//!
//! A layer's `K×N` integer matrix product is tiled into 64-row × 16-engine
//! core operations (zero-padded at the edges); partial sums are accumulated
//! digitally across row tiles, exactly as the chip's digital periphery
//! would.

pub mod bitserial;
pub mod executor;

use crate::cim::{golden, MacroError, MacroSim};
use crate::config::Config;
use crate::energy::{core_op_energy, EnergyBreakdown};
use crate::util::rng::Xoshiro256;

#[derive(Debug)]
pub enum MapError {
    Macro(MacroError),
    Shape(String),
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::Macro(e) => write!(f, "{e}"),
            MapError::Shape(m) => write!(f, "shape error: {m}"),
        }
    }
}

impl std::error::Error for MapError {}

impl From<MacroError> for MapError {
    fn from(e: MacroError) -> Self {
        MapError::Macro(e)
    }
}

/// Cumulative execution statistics of a backend.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    pub core_ops: u64,
    pub weight_loads: u64,
    /// Sum of per-op cycles (macro ops on different cores may overlap; the
    /// coordinator models concurrency — this is the serial device total).
    pub total_cycles: u64,
    pub energy: EnergyBreakdown,
    /// Engine results whose folded MAC fell outside the boosted readout
    /// range (boosted-clipping events).
    pub clipped: u64,
}

impl ExecStats {
    pub fn energy_fj(&self) -> f64 {
        self.energy.total_fj()
    }

    /// Fold another counter set in (the pipeline merges per-worker stats).
    pub fn merge(&mut self, o: &ExecStats) {
        self.core_ops += o.core_ops;
        self.weight_loads += o.weight_loads;
        self.total_cycles += o.total_cycles;
        self.energy.add(&o.energy);
        self.clipped += o.clipped;
    }
}

/// Per-op accounting shared by every macro-model backend: counters, energy,
/// and the boosted-clipping scan against the ideal folded MAC.
pub fn account_core_op(
    cfg: &Config,
    weights: &crate::cim::CoreWeights,
    acts: &[i64],
    op_stats: &crate::cim::OpStats,
    stats: &mut ExecStats,
) {
    let mut folded = Vec::new();
    account_core_op_into(cfg, weights, acts, op_stats, stats, &mut folded);
}

/// Buffer-reusing form of [`account_core_op`]: the batched pipeline calls
/// this with one per-worker scratch so its per-op hot path stays
/// allocation-free even with the boosted-clipping scan enabled.
pub fn account_core_op_into(
    cfg: &Config,
    weights: &crate::cim::CoreWeights,
    acts: &[i64],
    op_stats: &crate::cim::OpStats,
    stats: &mut ExecStats,
    folded_scratch: &mut Vec<i64>,
) {
    stats.core_ops += 1;
    stats.total_cycles += op_stats.total_cycles;
    stats.energy.add(&core_op_energy(cfg, op_stats));
    if cfg.enhance.boost {
        golden::mac_folded_into(cfg, weights, acts, folded_scratch);
        for &d in folded_scratch.iter() {
            if golden::clips(cfg, d) {
                stats.clipped += 1;
            }
        }
    }
}

/// Anything that can act as the 4-core CIM macro for the executors.
pub trait CimBackend {
    fn config(&self) -> &Config;
    fn load_core(&mut self, core: usize, w: &[Vec<i64>]) -> Result<(), MapError>;
    /// One core op on unsigned activations; returns reconstructed MAC
    /// estimates (product units) per engine.
    fn core_op(&mut self, core: usize, acts: &[i64]) -> Result<Vec<f64>, MapError>;

    /// Batched core ops (default: loop). The XLA backend overrides this to
    /// amortize one compiled execution across the whole batch.
    fn core_op_batch(&mut self, core: usize, acts: &[Vec<i64>]) -> Result<Vec<Vec<f64>>, MapError> {
        acts.iter().map(|a| self.core_op(core, a)).collect()
    }

    fn stats(&self) -> &ExecStats;
    fn reset_stats(&mut self);
}

/// The native behavioral-model backend.
pub struct NativeBackend {
    pub sim: MacroSim,
    rng: Xoshiro256,
    stats: ExecStats,
    scratch: crate::cim::OpScratch,
    op: crate::cim::CoreOpResult,
    /// Reusable per-batch results + folded-MAC scratch for the batched path.
    ops: Vec<crate::cim::CoreOpResult>,
    folded: Vec<i64>,
}

impl NativeBackend {
    pub fn new(cfg: Config) -> Self {
        let rng = Xoshiro256::seeded(cfg.sim.seed ^ 0xBACC_E4D);
        let scratch = crate::cim::OpScratch::new(&cfg.mac);
        Self {
            sim: MacroSim::new(cfg),
            rng,
            stats: ExecStats::default(),
            scratch,
            op: crate::cim::CoreOpResult::default(),
            ops: Vec::new(),
            folded: Vec::new(),
        }
    }
}

impl CimBackend for NativeBackend {
    fn config(&self) -> &Config {
        &self.sim.cfg
    }

    fn load_core(&mut self, core: usize, w: &[Vec<i64>]) -> Result<(), MapError> {
        self.sim.load_core(core, w)?;
        self.stats.weight_loads += 1;
        Ok(())
    }

    fn core_op(&mut self, core: usize, acts: &[i64]) -> Result<Vec<f64>, MapError> {
        self.sim
            .core_op_into(core, acts, &mut self.rng, &mut self.scratch, &mut self.op)?;
        let w = self.sim.core_weights(core)?;
        account_core_op(&self.sim.cfg, w, acts, &self.op.stats, &mut self.stats);
        Ok(self.op.values.clone())
    }

    /// Batched override: stream the whole batch through the resident core
    /// with [`MacroSim::core_op_batch_into`] (one kernel preparation per
    /// vector, reused result buffers). Draw-for-draw identical to the
    /// default per-op loop, so results match it bit for bit.
    fn core_op_batch(&mut self, core: usize, acts: &[Vec<i64>]) -> Result<Vec<Vec<f64>>, MapError> {
        self.sim
            .core_op_batch_into(core, acts, &mut self.rng, &mut self.scratch, &mut self.ops)?;
        let w = self.sim.core_weights(core)?;
        let mut res = Vec::with_capacity(acts.len());
        for (a, op) in acts.iter().zip(&self.ops) {
            account_core_op_into(
                &self.sim.cfg,
                w,
                a,
                &op.stats,
                &mut self.stats,
                &mut self.folded,
            );
            res.push(op.values.clone());
        }
        Ok(res)
    }

    fn stats(&self) -> &ExecStats {
        &self.stats
    }

    fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
    }
}

/// Exact-integer digital backend: same interface, no analog effects — the
/// accuracy baseline every CIM experiment compares against.
pub struct DigitalBackend {
    cfg: Config,
    weights: Vec<Option<Vec<Vec<i64>>>>,
    stats: ExecStats,
}

impl DigitalBackend {
    pub fn new(cfg: Config) -> Self {
        let weights = (0..cfg.mac.cores).map(|_| None).collect();
        Self { cfg, weights, stats: ExecStats::default() }
    }
}

impl CimBackend for DigitalBackend {
    fn config(&self) -> &Config {
        &self.cfg
    }

    fn load_core(&mut self, core: usize, w: &[Vec<i64>]) -> Result<(), MapError> {
        if core >= self.cfg.mac.cores {
            return Err(MapError::Macro(MacroError::BadCore(core)));
        }
        if w.len() != self.cfg.mac.rows || w.iter().any(|r| r.len() != self.cfg.mac.engines) {
            return Err(MapError::Shape(format!(
                "weights {}×{} vs core {}×{}",
                w.len(),
                w.first().map(|r| r.len()).unwrap_or(0),
                self.cfg.mac.rows,
                self.cfg.mac.engines
            )));
        }
        self.weights[core] = Some(w.to_vec());
        self.stats.weight_loads += 1;
        Ok(())
    }

    fn core_op(&mut self, core: usize, acts: &[i64]) -> Result<Vec<f64>, MapError> {
        let w = self.weights[core]
            .as_ref()
            .ok_or(MapError::Macro(MacroError::NoWeights(core)))?;
        let engines = self.cfg.mac.engines;
        let mut out = vec![0f64; engines];
        for (r, &a) in acts.iter().enumerate() {
            if a == 0 {
                continue;
            }
            for (e, o) in out.iter_mut().enumerate() {
                *o += (a * w[r][e]) as f64;
            }
        }
        self.stats.core_ops += 1;
        Ok(out)
    }

    fn stats(&self) -> &ExecStats {
        &self.stats
    }

    fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::rng::Rng;

    fn rand_weights(cfg: &Config, seed: u64) -> Vec<Vec<i64>> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..cfg.mac.rows)
            .map(|_| (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect())
            .collect()
    }

    #[test]
    fn native_and_digital_agree_without_noise() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let w = rand_weights(&cfg, 1);
        let mut nat = NativeBackend::new(cfg.clone());
        let mut dig = DigitalBackend::new(cfg.clone());
        nat.load_core(0, &w).unwrap();
        dig.load_core(0, &w).unwrap();
        let mut rng = Xoshiro256::seeded(3);
        for _ in 0..20 {
            let acts: Vec<i64> = (0..cfg.mac.rows).map(|_| rng.next_range_i64(0, 15)).collect();
            let a = nat.core_op(0, &acts).unwrap();
            let b = dig.core_op(0, &acts).unwrap();
            let step = cfg.mac.adc_lsb_units() / cfg.enhance.dtc_scale();
            for e in 0..cfg.mac.engines {
                assert!((a[e] - b[e]).abs() <= step / 2.0 + 1e-9, "{} vs {}", a[e], b[e]);
            }
        }
        assert_eq!(nat.stats().core_ops, 20);
        assert!(nat.stats().energy_fj() > 0.0);
        assert_eq!(dig.stats().core_ops, 20);
    }

    #[test]
    fn stats_reset() {
        let cfg = Config::default();
        let mut nat = NativeBackend::new(cfg.clone());
        nat.load_core(0, &rand_weights(&cfg, 2)).unwrap();
        let acts = vec![5i64; cfg.mac.rows];
        nat.core_op(0, &acts).unwrap();
        assert_eq!(nat.stats().core_ops, 1);
        nat.reset_stats();
        assert_eq!(nat.stats().core_ops, 0);
        assert_eq!(nat.stats().energy_fj(), 0.0);
    }

    #[test]
    fn digital_validates_shapes() {
        let cfg = Config::default();
        let mut dig = DigitalBackend::new(cfg.clone());
        let bad = vec![vec![0i64; 3]; 2];
        assert!(matches!(dig.load_core(0, &bad), Err(MapError::Shape(_))));
        let acts = vec![0i64; cfg.mac.rows];
        assert!(dig.core_op(0, &acts).is_err()); // no weights
    }
}
