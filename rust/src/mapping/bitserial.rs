//! 8-b precision extension ("extendable precision" in Fig. 6): activations
//! split into two radix-16 nibbles, weights into two radix-8 signed digits;
//! four 4-b macro passes are combined by digital shift-add. This feeds the
//! Fig. 6 8-b FoM row.
//!
//! Weight digits d1, d0 ∈ [−7, 7] represent w = 8·d1 + d0, covering ±63
//! (an effective 7-b signed weight — the macro's sign-magnitude array
//! cannot hold ±127 in two 4-b passes; documented in DESIGN.md §8).

use crate::mapping::executor::CimLinear;
use crate::mapping::{CimBackend, MapError};
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;

/// Signed radix-8 digit decomposition: w = 8·hi + lo, hi/lo ∈ [−7, 7].
pub fn weight_digits(w: i64) -> (i64, i64) {
    assert!((-63..=63).contains(&w), "8b-extension weight {w} out of ±63");
    let mut hi = (w as f64 / 8.0).round() as i64;
    hi = hi.clamp(-7, 7);
    let mut lo = w - 8 * hi;
    if lo > 7 {
        hi += 1;
        lo = w - 8 * hi;
    } else if lo < -7 {
        hi -= 1;
        lo = w - 8 * hi;
    }
    debug_assert!((-7..=7).contains(&hi) && (-7..=7).contains(&lo), "w={w} hi={hi} lo={lo}");
    (hi, lo)
}

/// Unsigned radix-16 nibble decomposition: a = 16·hi + lo, hi/lo ∈ [0, 15].
pub fn act_nibbles(a: i64) -> (i64, i64) {
    assert!((0..=255).contains(&a), "8b activation {a} out of range");
    (a >> 4, a & 0xF)
}

/// An 8-b K×N layer lowered to four 4-b CIM passes.
pub struct BitSerialLinear {
    pub k: usize,
    pub n: usize,
    pub w_params: QuantParams, // 8-b weights (±63 effective)
    pub a_params: QuantParams, // 8-b activations (0..255)
    pub bias: Vec<f32>,
    /// Four sub-layers: (act-nibble, weight-digit) ∈ {hi,lo}².
    pass_hi_w: CimLinear,
    pass_lo_w: CimLinear,
}

impl BitSerialLinear {
    pub fn new(
        w_cols: &Tensor,
        bias: Vec<f32>,
        act_cal_max: f32,
        cfg: &crate::config::Config,
    ) -> Self {
        assert_eq!(w_cols.rank(), 2);
        let (k, n) = (w_cols.shape[0], w_cols.shape[1]);
        // 8-b params: weights ±63 (radix-8 digit pair), acts 0..255.
        let w_params = QuantParams { scale: w_cols.max_abs().max(1e-30) / 63.0, q_min: -63, q_max: 63 };
        let a_params = QuantParams { scale: act_cal_max.max(1e-30) / 255.0, q_min: 0, q_max: 255 };

        // Build the two weight-digit planes as float tensors whose 4-b
        // quantization is exact (scale 1, values already in ±7).
        let mut hi = Tensor::zeros(&[k, n]);
        let mut lo = Tensor::zeros(&[k, n]);
        for kk in 0..k {
            for nn in 0..n {
                let wq = w_params.quantize(w_cols.at2(kk, nn));
                let (h, l) = weight_digits(wq);
                *hi.at2_mut(kk, nn) = h as f32;
                *lo.at2_mut(kk, nn) = l as f32;
            }
        }
        // Digit planes hold exact integers in ±7: quantize with scale
        // exactly 1 so the passes are lossless.
        let unit_w = QuantParams { scale: 1.0, q_min: -7, q_max: 7 };
        let unit_a = QuantParams { scale: 1.0, q_min: 0, q_max: 15 };
        let pass_hi_w = CimLinear::with_params(&hi, vec![0.0; n], unit_w, unit_a, cfg);
        let pass_lo_w = CimLinear::with_params(&lo, vec![0.0; n], unit_w, unit_a, cfg);
        Self { k, n, w_params, a_params, bias, pass_hi_w, pass_lo_w }
    }

    /// Core ops per activation vector (4 passes worth).
    pub fn ops_per_vector(&self) -> usize {
        2 * (self.pass_hi_w.ops_per_vector() + self.pass_lo_w.ops_per_vector())
    }

    /// Run a batch of float vectors through the 4-pass pipeline.
    pub fn run_batch(
        &self,
        backend: &mut dyn CimBackend,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>, MapError> {
        let b = xs.len();
        // Quantize to 8-b, split nibbles.
        let mut a_hi = vec![vec![0i64; self.k]; b];
        let mut a_lo = vec![vec![0i64; self.k]; b];
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), self.k);
            for (j, &v) in x.iter().enumerate() {
                let q = self.a_params.quantize(v);
                let (h, l) = act_nibbles(q);
                a_hi[i][j] = h;
                a_lo[i][j] = l;
            }
        }
        // Four passes with shift weights 16·8, 16·1, 1·8, 1·1. The sub-layer
        // dequantization scales are (a_sub · w_sub) = 1·1 when the digit
        // planes quantize with scale 1; recover raw integer sums by dividing
        // the sub-scales back out.
        let runs = [
            (&a_hi, &self.pass_hi_w, 128.0f32),
            (&a_hi, &self.pass_lo_w, 16.0),
            (&a_lo, &self.pass_hi_w, 8.0),
            (&a_lo, &self.pass_lo_w, 1.0),
        ];
        let mut acc = vec![vec![0f32; self.n]; b];
        for (acts, layer, shift) in runs {
            let sub_scale = layer.a_params.scale * layer.w_params.scale;
            let y = layer.run_batch_q(backend, acts)?;
            for (bi, row) in y.iter().enumerate() {
                for (ni, &v) in row.iter().enumerate() {
                    acc[bi][ni] += v / sub_scale * shift;
                }
            }
        }
        // Dequantize to real units and add bias.
        let deq = self.a_params.scale * self.w_params.scale;
        for row in acc.iter_mut() {
            for (o, bia) in row.iter_mut().zip(&self.bias) {
                *o = *o * deq + bia;
            }
        }
        Ok(acc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::mapping::DigitalBackend;
    use crate::util::rng::{Rng, Xoshiro256};

    #[test]
    fn digit_decomposition_roundtrips() {
        for w in -63..=63 {
            let (h, l) = weight_digits(w);
            assert_eq!(8 * h + l, w, "w={w}");
            assert!((-7..=7).contains(&h) && (-7..=7).contains(&l));
        }
        for a in 0..=255 {
            let (h, l) = act_nibbles(a);
            assert_eq!(16 * h + l, a);
            assert!((0..=15).contains(&h) && (0..=15).contains(&l));
        }
    }

    #[test]
    fn bitserial_digital_equals_exact_8b_product() {
        let cfg = Config::default();
        let (k, n) = (100, 10);
        let mut rng = Xoshiro256::seeded(77);
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.next_f32() - 0.5).collect());
        let bias: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let layer = BitSerialLinear::new(&w, bias.clone(), 1.0, &cfg);
        let xs: Vec<Vec<f32>> = (0..2).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();
        let mut be = DigitalBackend::new(cfg.clone());
        let got = layer.run_batch(&mut be, &xs).unwrap();
        for (bi, x) in xs.iter().enumerate() {
            for col in 0..n {
                let mut acc = 0i64;
                for kk in 0..k {
                    let aq = layer.a_params.quantize(x[kk]);
                    let wq = layer.w_params.quantize(w.at2(kk, col));
                    acc += aq * wq;
                }
                let want =
                    acc as f32 * layer.a_params.scale * layer.w_params.scale + bias[col];
                let g = got[bi][col];
                assert!((g - want).abs() < 2e-2 * want.abs().max(1.0), "{g} vs {want}");
            }
        }
        assert_eq!(be.stats().core_ops as usize, layer.ops_per_vector() * xs.len());
    }

    #[test]
    fn four_passes_cost_4x() {
        let cfg = Config::default();
        let w = Tensor::from_vec(&[64, 16], vec![0.25; 64 * 16]);
        let l8 = BitSerialLinear::new(&w, vec![0.0; 16], 1.0, &cfg);
        let l4 = crate::mapping::executor::CimLinear::new(&w, vec![0.0; 16], 1.0, &cfg);
        assert_eq!(l8.ops_per_vector(), 4 * l4.ops_per_vector());
    }
}
