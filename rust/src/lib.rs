//! `cimsim` — a production-quality behavioral reproduction of
//! *"A 137.5 TOPS/W SRAM Compute-in-Memory Macro with 9-b Memory
//! Cell-Embedded ADCs and Signal Margin Enhancement Techniques for AI Edge
//! Applications"* (Wang et al., 2023).
//!
//! Three-layer architecture (see README.md):
//! * **L3 (this crate)** — coordinator: macro behavioral model, NN mapping,
//!   edge-inference serving, energy/area accounting, experiment harness.
//! * **L2/L1 (python, build-time only)** — JAX model + Pallas kernel,
//!   AOT-lowered to HLO text and executed here through the `xla` crate
//!   (PJRT CPU) by `runtime` — gated behind the `xla-runtime` feature, since
//!   the offline build image vendors no external crates.
//!
//! # Pipeline architecture
//!
//! The paper's macro wins by amortizing one cell-embedded readout over
//! 64-way parallel analog accumulation. The [`pipeline`] module mirrors that
//! at system scale with three layers:
//!
//! * **Pool** — [`pipeline::MacroPool`] owns N weight-stationary
//!   [`cim::MacroSim`] shards. A layer's tiles are pinned one-per-slot
//!   (`shard × core`) by [`pipeline::PlacedLinear`], so weights load once
//!   and only activations move — the chip's usage pattern.
//! * **Shard** — each shard is an independent die (own fabrication draw);
//!   ops are read-only on the shards, so any number of threads stream
//!   activations concurrently.
//! * **Batch** — [`pipeline::BatchExecutor`] fans a `[batch][features]`
//!   matrix across worker threads (`util::threadpool`), one RNG substream +
//!   one reusable [`cim::OpScratch`] per worker: zero per-op allocation.
//!
//! `coordinator::server::serve_pipeline` puts a dynamic batcher in front:
//! queued jobs coalesce (up to `ServeConfig::max_batch`) into one pooled
//! pipeline call. **Sizing:** `max_batch` bounds tail latency — keep it at
//! (requests/s × batch window) or a small multiple of the worker count;
//! `ServeConfig::workers = 0` auto-sizes to the machine (one worker per
//! core, capped at 32). Throughput scales with workers until the batch is
//! thinner than the worker count; `cargo bench --bench pipeline_throughput`
//! prints the machine's actual curve and writes `BENCH_pipeline.json`.
//!
//! # Compiler layer
//!
//! The [`compiler`] module turns whole networks into pool-resident plans:
//!
//! ```text
//!   IR  ──lower──►  tiles  ──place──►  slots  ──execute──►  logits
//!  (Conv2d/Linear/  (im2col +          (cost-model-driven   (BatchExecutor,
//!   Relu/Add/GAP/    per-layer act      placer balances      per-layer
//!   Quant/Dequant)   calibration)       shards, auto-grows)  cycle/energy)
//! ```
//!
//! `Graph::from_mlp` / `Graph::from_resnet20` / `Graph::from_deployment`
//! ingest the stock workloads; [`compiler::compile`] calibrates, lowers,
//! places and loads weights once; [`compiler::CompiledPlan`] executes
//! batches bit-identically (noise-free) to the sequential per-layer macro
//! path and serves through `serve --plan`. **Sizing example:** ResNet-20
//! lowers to 282 weight-stationary tiles → 71 shards (4-core dies) and
//! ~1.1 Mb of resident weight SRAM; a CIFAR image streams 9 409 activation
//! vectors (47 361 core ops) through the pool — ~0.7 M estimated
//! worst-case device cycles in baseline mode (15 per dense op).
//! [`pipeline::PipelineDeployment`] is now one instance of a compiled plan
//! (the deployment graph, unit scales + explicit dequantize nodes).
//!
//! # Hot-path kernel
//!
//! Every MAC op — per-request, pooled, or compiled — runs on the bit-plane
//! fast-path kernel (DESIGN.md §4): [`cim::BitPlanes`] packs per-engine row
//! bitmasks + sign masks at weight-load time, [`cim::KernelScratch`] hoists
//! the activation-side work (folding, masks, pulse widths, jitter σ) out of
//! the per-op loop, and noise-free execution with the paper's dyadic DTC
//! gains collapses to integer dot products. The legacy scalar kernel
//! (`cim::engine::mac_phase_into`) remains as the bit-exact oracle;
//! `tests/kernel_equivalence.rs` property-tests the two against each other
//! across all enhancement modes, noise on and off. Measured numbers:
//! `BENCH_kernel.json` (`cargo bench --bench kernel_hotpath`), README
//! "Performance".
//!
//! Unit conventions, calibration assumptions and declared reproduction
//! deviations live in the repo-root `DESIGN.md` (§1–§8), which the code
//! cites by section; `tests/docs_refs.rs` keeps the citations resolving.

pub mod analysis;
pub mod bench;
pub mod cim;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod harness;
pub mod mapping;
pub mod nn;
pub mod pipeline;
pub mod runtime;
pub mod util;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
