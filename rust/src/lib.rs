//! `cimsim` — a production-quality behavioral reproduction of
//! *"A 137.5 TOPS/W SRAM Compute-in-Memory Macro with 9-b Memory
//! Cell-Embedded ADCs and Signal Margin Enhancement Techniques for AI Edge
//! Applications"* (Wang et al., 2023).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: macro behavioral model, NN mapping,
//!   edge-inference serving, energy/area accounting, experiment harness.
//! * **L2/L1 (python, build-time only)** — JAX model + Pallas kernel,
//!   AOT-lowered to HLO text and executed here through the `xla` crate
//!   (PJRT CPU) by `runtime`.

pub mod analysis;
pub mod bench;
pub mod cim;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod harness;
pub mod mapping;
pub mod nn;
pub mod runtime;
pub mod util;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
