//! `cimsim` — a production-quality behavioral reproduction of
//! *"A 137.5 TOPS/W SRAM Compute-in-Memory Macro with 9-b Memory
//! Cell-Embedded ADCs and Signal Margin Enhancement Techniques for AI Edge
//! Applications"* (Wang et al., 2023).
//!
//! Three-layer architecture (see README.md):
//! * **L3 (this crate)** — coordinator: macro behavioral model, NN mapping,
//!   edge-inference serving, energy/area accounting, experiment harness.
//! * **L2/L1 (python, build-time only)** — JAX model + Pallas kernel,
//!   AOT-lowered to HLO text and executed here through the `xla` crate
//!   (PJRT CPU) by `runtime` — gated behind the `xla-runtime` feature, since
//!   the offline build image vendors no external crates.
//!
//! # Pipeline architecture
//!
//! The paper's macro wins by amortizing one cell-embedded readout over
//! 64-way parallel analog accumulation. The [`pipeline`] module mirrors that
//! at system scale with three layers:
//!
//! * **Pool** — [`pipeline::MacroPool`] owns N weight-stationary
//!   [`cim::MacroSim`] shards. A layer's tiles are pinned one-per-slot
//!   (`shard × core`) by [`pipeline::PlacedLinear`], so weights load once
//!   and only activations move — the chip's usage pattern.
//! * **Shard** — each shard is an independent die (own fabrication draw);
//!   ops are read-only on the shards, so any number of threads stream
//!   activations concurrently.
//! * **Batch** — [`pipeline::BatchExecutor`] fans a `[batch][features]`
//!   matrix across worker threads (`util::threadpool`), one reusable
//!   [`pipeline::StreamCtx`] per worker: zero per-op allocation. Noise
//!   draws come from substreams keyed `(seed, epoch, item, tile)`
//!   ([`pipeline::noise_stream`], DESIGN.md §9), so results are
//!   independent of the worker count and of how a batch is split.
//!
//! # Serving runtime and streaming scheduler
//!
//! All serve front-ends (`serve`, `serve --pipeline`, `serve --plan`,
//! `serve --stream`) share one runtime: a bounded admission queue
//! ([`sched::BoundedQueue`], `ServeConfig::max_queue`) whose full state
//! backpressures the TCP client instead of growing memory, a dynamic
//! batcher (`max_batch` per `max_wait` window), and graceful drain —
//! `ServerHandle::shutdown` completes everything already admitted before
//! returning `Metrics` (execution latency and queue wait reported
//! separately, from bounded reservoirs).
//!
//! With `ServeConfig::stream` (CLI `serve --stream --max-queue N`), a
//! compiled plan executes through the **streaming scheduler** ([`sched`],
//! DESIGN.md §9): per-layer stages over bounded queues, items pipelining
//! through the network independently — bit-identical to the barrier
//! `run_batch`, noise on or off, via [`compiler::CompiledPlan::run_streamed`].
//! **Sizing:** `max_batch` bounds tail latency — keep it at (requests/s ×
//! batch window) or a small multiple of the worker count; `max_queue` is
//! the drop-free burst you want absorbed; `ServeConfig::workers = 0`
//! auto-sizes to the machine (one worker per core, capped at 32).
//! `cargo bench --bench pipeline_throughput` prints the machine's actual
//! batching curve (`BENCH_pipeline.json`); `cargo bench --bench
//! stream_latency` writes the barrier-vs-streamed p50/p99 comparison on
//! ResNet-20 (`BENCH_stream.json`).
//!
//! # Compiler layer
//!
//! The [`compiler`] module turns whole networks into pool-resident plans:
//!
//! ```text
//!   IR  ──lower──►  tiles  ──place──►  slots  ──execute──►  logits
//!  (Conv2d/Linear/  (im2col +          (cost-model-driven   (BatchExecutor,
//!   Relu/Add/GAP/    per-layer act      placer balances      per-layer
//!   Quant/Dequant)   calibration)       shards, auto-grows)  cycle/energy)
//! ```
//!
//! `Graph::from_mlp` / `Graph::from_resnet20` / `Graph::from_deployment`
//! ingest the stock workloads; [`compiler::compile`] calibrates, lowers,
//! places and loads weights once; [`compiler::CompiledPlan`] executes
//! batches bit-identically (noise-free) to the sequential per-layer macro
//! path and serves through `serve --plan`. **Sizing example:** ResNet-20
//! lowers to 282 weight-stationary tiles → 71 shards (4-core dies) and
//! ~1.1 Mb of resident weight SRAM; a CIFAR image streams 9 409 activation
//! vectors (47 361 core ops) through the pool — ~0.7 M estimated
//! worst-case device cycles in baseline mode (15 per dense op).
//! [`pipeline::PipelineDeployment`] is now one instance of a compiled plan
//! (the deployment graph, unit scales + explicit dequantize nodes).
//!
//! # Dynamic-weight workloads (transformers)
//!
//! Attention multiplies two runtime tensors, so one operand must be
//! written into the array mid-inference (DESIGN.md §10):
//! `Graph::from_transformer_block` ingests an MHA+FFN encoder block whose
//! `Quantize → MatMul` pairs lower to [`pipeline::DynamicLinear`] grids on
//! dedicated shards — per-call max-abs requantization, weight swap through
//! [`pipeline::MacroPool::reload_slot`] (the `BitPlanes` view rebuilds, so
//! the kernel is untouched), reload cycles/energy charged to the device
//! counters and broken out in the cost report. Signed activation
//! boundaries ride a zero-point shift with a digital `zp·Σw` restore.
//! Streamed execution treats each item's reload as a stage barrier and
//! stays bit-identical to the barrier path
//! (`tests/dynamic_weights.rs`); `cargo bench --bench attention_block`
//! writes the reload-bound vs compute-bound rows (`BENCH_attention.json`).
//!
//! # Hot-path kernel
//!
//! Every MAC op — per-request, pooled, or compiled — runs on the bit-plane
//! fast-path kernel (DESIGN.md §4): [`cim::BitPlanes`] packs per-engine row
//! bitmasks + sign masks at weight-load time, [`cim::KernelScratch`] hoists
//! the activation-side work (folding, masks, pulse widths, jitter σ) out of
//! the per-op loop, and noise-free execution with the paper's dyadic DTC
//! gains collapses to integer dot products. The legacy scalar kernel
//! (`cim::engine::mac_phase_into`) remains as the bit-exact oracle;
//! `tests/kernel_equivalence.rs` property-tests the two against each other
//! across all enhancement modes, noise on and off. Measured numbers:
//! `BENCH_kernel.json` (`cargo bench --bench kernel_hotpath`), README
//! "Performance".
//!
//! # Observability
//!
//! The [`telemetry`] module (DESIGN.md §12) is a zero-dependency
//! observability layer: a process-global metric [`telemetry::Registry`]
//! (counters / gauges / log2-bucket histograms, labeled per-layer,
//! per-stage, and pool series fed at the engine's own `ExecStats` merge
//! points), a Prometheus text + JSON HTTP exporter behind
//! `serve --metrics-addr` (`GET /metrics`, `GET /metrics.json`), and
//! [`crate::span!`] tracing spans exported as Chrome `trace_event` JSON
//! (`cimsim trace`, loadable in Perfetto). Tracing is off by default and
//! its disabled path is a single relaxed atomic load, so kernel hot-path
//! numbers are untouched (`BENCH_telemetry.json`,
//! `cargo bench --bench telemetry_overhead`).
//!
//! Unit conventions, calibration assumptions and declared reproduction
//! deviations live in the repo-root `DESIGN.md` (§1–§12), which the code
//! cites by section; `tests/docs_refs.rs` keeps the citations resolving.

pub mod analysis;
pub mod bench;
pub mod cim;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod explore;
pub mod harness;
pub mod mapping;
pub mod nn;
pub mod pipeline;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod util;

/// Crate version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
