//! TOML-subset parser for configuration files (no `serde`/`toml` crates in
//! the offline environment).
//!
//! Supported grammar — everything `cimsim.toml` needs:
//!
//! ```toml
//! # comment
//! top_level = 1.5
//! [section]
//! int = 3            ; i64
//! float = 2.5e-3     ; f64
//! flag = true        ; bool
//! name = "string"    ; quoted string
//! list = [1, 2, 3]   ; homogeneous number arrays
//! [section.sub]      ; nested tables via dotted headers
//! ```
//!
//! Values are stored flat under dotted keys (`section.sub.key`) which keeps
//! extraction trivial and order-independent.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parsed document: flat map of dotted keys to values.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    map: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut map = BTreeMap::new();
        let mut prefix = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated table header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(ParseError { line: lineno, msg: "empty table name".into() });
                }
                prefix = format!("{name}.");
                continue;
            }
            let (key, rhs) = line.split_once('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ParseError { line: lineno, msg: "empty key".into() });
            }
            let value = parse_value(rhs.trim(), lineno)?;
            let full = format!("{prefix}{key}");
            if map.insert(full.clone(), value).is_some() {
                return Err(ParseError { line: lineno, msg: format!("duplicate key `{full}`") });
            }
        }
        Ok(Doc { map })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// Insert or replace a dotted key — programmatic `Doc` construction,
    /// used by the explore harness to build per-candidate overlay docs
    /// without a TOML round-trip.
    pub fn set(&mut self, key: &str, value: Value) {
        self.map.insert(key.to_string(), value);
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }

    pub fn usize(&self, key: &str) -> Option<usize> {
        self.i64(key).and_then(|v| usize::try_from(v).ok())
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    /// Keys under `section.` (for unknown-key validation).
    pub fn section_keys<'a>(&'a self, section: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let pfx = format!("{section}.");
        self.map
            .keys()
            .filter(move |k| k.starts_with(&pfx))
            .map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(tok: &str, lineno: usize) -> Result<Value, ParseError> {
    let err = |msg: String| ParseError { line: lineno, msg };
    if tok.is_empty() {
        return Err(err("missing value".into()));
    }
    if tok == "true" {
        return Ok(Value::Bool(true));
    }
    if tok == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = tok.strip_prefix('"') {
        let inner = body
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quote in string".into()));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(body) = tok.strip_prefix('[') {
        let inner = body
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim(), lineno))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    // Number: int if it parses as i64 and contains no float syntax.
    let looks_float = tok.contains('.') || tok.contains('e') || tok.contains('E');
    if !looks_float {
        if let Ok(i) = tok.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    tok.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| err(format!("cannot parse value `{tok}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_supported_types() {
        let doc = Doc::parse(
            r#"
            # top comment
            top = 1
            [noise]
            sigma_i = 0.015        # mismatch
            enabled = true
            label = "per-cell"
            weights = [1, 2.5, 3]
            [noise.sub]
            deep = -4
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64("top"), Some(1));
        assert_eq!(doc.f64("noise.sigma_i"), Some(0.015));
        assert_eq!(doc.bool("noise.enabled"), Some(true));
        assert_eq!(doc.str("noise.label"), Some("per-cell"));
        assert_eq!(doc.i64("noise.sub.deep"), Some(-4));
        match doc.get("noise.weights").unwrap() {
            Value::Array(items) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[1].as_f64(), Some(2.5));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = Doc::parse("a = 3\nb = 3.0\nc = 1e-3\n").unwrap();
        assert_eq!(doc.get("a"), Some(&Value::Int(3)));
        assert_eq!(doc.get("b"), Some(&Value::Float(3.0)));
        assert_eq!(doc.f64("c"), Some(1e-3));
        // Int coerces to f64 on request.
        assert_eq!(doc.f64("a"), Some(3.0));
        // Float does not silently become int.
        assert_eq!(doc.i64("b"), None);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.str("s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Doc::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Doc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Doc::parse("a = 1\na = 2\n").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn section_key_listing() {
        let doc = Doc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        let keys: Vec<&str> = doc.section_keys("a").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }
}
