//! Deterministic pseudo-random number generation.
//!
//! The build environment vendors no `rand` crate, so `cimsim` carries its own
//! generators. Determinism matters more than cryptographic quality here: every
//! experiment in the paper-reproduction harness is seeded so that
//! `EXPERIMENTS.md` numbers are exactly re-derivable.
//!
//! * [`SplitMix64`] — tiny stream used for seeding and cheap decorrelation.
//! * [`Xoshiro256`] — xoshiro256** 1.0 (Blackman/Vigna), the workhorse.
//! * [`Rng::next_gaussian`] — Box–Muller with cached second variate.

/// SplitMix64 (Steele, Lea, Flood). Used to expand a single `u64` seed into
/// the 256-bit xoshiro state and to derive independent per-stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — public-domain reference algorithm by David Blackman and
/// Sebastiano Vigna (<https://prng.di.unimi.it/xoshiro256starstar.c>).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second Box–Muller variate (see [`Rng::next_gaussian`]).
    gauss_spare: Option<f64>,
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation, so that even
    /// small/sequential seeds yield well-mixed states.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent generator for a named sub-stream. Used to give
    /// each noise source / worker thread its own decorrelated stream while
    /// staying a pure function of (seed, label).
    pub fn substream(&self, label: &str) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a 64
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        let mut sm = SplitMix64::new(self.s[0] ^ h.rotate_left(17));
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }
}

/// Uniform + gaussian sampling interface implemented by all generators.
pub trait Rng {
    fn next_u64(&mut self) -> u64;

    /// Uniform in [0, 1).
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire rejection).
    #[inline]
    fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    fn next_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.next_below(span) as i64
    }

    /// Standard normal variate. Implementations may cache the Box–Muller pair.
    fn next_gaussian(&mut self) -> f64;

    /// Normal with given mean / standard deviation.
    #[inline]
    fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.next_gaussian()
    }

    /// Bernoulli(p).
    #[inline]
    fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T])
    where
        Self: Sized,
    {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        SplitMix64::next_u64(self)
    }

    fn next_gaussian(&mut self) -> f64 {
        box_muller_single(self)
    }
}

impl Rng for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    fn next_gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        let (g0, g1) = box_muller_pair(self);
        self.gauss_spare = Some(g1);
        g0
    }
}

fn box_muller_pair<R: Rng + ?Sized>(rng: &mut R) -> (f64, f64) {
    // Marsaglia polar method: one ln+sqrt per pair, no sin/cos (≈2× faster
    // than trigonometric Box–Muller; ~21% rejection).
    loop {
        let x = 2.0 * rng.next_f64() - 1.0;
        let y = 2.0 * rng.next_f64() - 1.0;
        let s = x * x + y * y;
        if s > 0.0 && s < 1.0 {
            let f = (-2.0 * s.ln() / s).sqrt();
            return (x * f, y * f);
        }
    }
}

fn box_muller_single<R: Rng + ?Sized>(rng: &mut R) -> f64 {
    box_muller_pair(rng).0
}

/// Fill `out` with N(0, sigma) samples.
pub fn fill_gaussian<R: Rng>(rng: &mut R, sigma: f64, out: &mut [f32]) {
    for x in out.iter_mut() {
        *x = (sigma * rng.next_gaussian()) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the published algorithm.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        let mut c = Xoshiro256::seeded(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn substreams_are_decorrelated_and_stable() {
        let root = Xoshiro256::seeded(7);
        let mut s1 = root.substream("jitter");
        let mut s2 = root.substream("mismatch");
        let mut s1b = root.substream("jitter");
        assert_eq!(s1.next_u64(), s1b.next_u64());
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Xoshiro256::seeded(9);
        for _ in 0..10_000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
            let k = r.next_below(7);
            assert!(k < 7);
            let v = r.next_range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
        }
    }

    #[test]
    fn next_below_is_unbiased_enough() {
        let mut r = Xoshiro256::seeded(1);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seeded(3);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sum2 += g * g;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fill_gaussian_scales_sigma() {
        let mut r = Xoshiro256::seeded(11);
        let mut buf = vec![0f32; 50_000];
        fill_gaussian(&mut r, 2.5, &mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!((var.sqrt() - 2.5).abs() < 0.05);
    }
}
