//! Minimal declarative command-line parser (the environment vendors no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! typed extraction with defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub value_name: Option<&'static str>, // None => boolean flag
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Specification of a subcommand.
#[derive(Clone, Debug, Default)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    /// Name of a trailing positional argument, if the command takes one.
    pub positional: Option<&'static str>,
}

/// Parsed arguments for one invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownCommand(String),
    UnknownOption(String, String),
    MissingValue(String),
    BadValue {
        opt: String,
        value: String,
        expected: &'static str,
    },
    HelpRequested(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownCommand(c) => write!(f, "unknown command `{c}` (try `help`)"),
            CliError::UnknownOption(cmd, o) => write!(f, "unknown option `{o}` for `{cmd}`"),
            CliError::MissingValue(o) => write!(f, "option `{o}` expects a value"),
            CliError::BadValue { opt, value, expected } => {
                write!(f, "option `{opt}`: cannot parse `{value}` as {expected}")
            }
            CliError::HelpRequested(text) => write!(f, "{text}"),
        }
    }
}

impl std::error::Error for CliError {}

/// A full CLI: program name, blurb, and subcommands.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub commands: Vec<CmdSpec>,
}

impl Cli {
    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.program, self.about);
        let _ = writeln!(s, "USAGE: {} <command> [options]\n", self.program);
        let _ = writeln!(s, "COMMANDS:");
        for c in &self.commands {
            let _ = writeln!(s, "  {:<14} {}", c.name, c.about);
        }
        let _ = writeln!(s, "\nRun `{} <command> --help` for command options.", self.program);
        s
    }

    pub fn command_help(&self, cmd: &CmdSpec) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} {} — {}\n", self.program, cmd.name, cmd.about);
        let mut usage = format!("USAGE: {} {} [options]", self.program, cmd.name);
        if let Some(p) = cmd.positional {
            let _ = write!(usage, " <{p}>");
        }
        let _ = writeln!(s, "{usage}\n\nOPTIONS:");
        for o in &cmd.opts {
            let lhs = match o.value_name {
                Some(v) => format!("--{} <{}>", o.name, v),
                None => format!("--{}", o.name),
            };
            let dflt = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  {:<28} {}{}", lhs, o.help, dflt);
        }
        s
    }

    /// Parse `argv[1..]`. `help`/`--help`/`-h` produce `HelpRequested` with the
    /// rendered text so the caller can print it and exit 0.
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        if argv.is_empty() {
            return Err(CliError::HelpRequested(self.help_text()));
        }
        let cmd_name = argv[0].as_str();
        if cmd_name == "help" || cmd_name == "--help" || cmd_name == "-h" {
            if let Some(sub) = argv.get(1) {
                if let Some(c) = self.commands.iter().find(|c| c.name == sub.as_str()) {
                    return Err(CliError::HelpRequested(self.command_help(c)));
                }
            }
            return Err(CliError::HelpRequested(self.help_text()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| CliError::UnknownCommand(cmd_name.to_string()))?;

        let mut args = Args {
            cmd: cmd.name.to_string(),
            ..Default::default()
        };
        // Pre-fill defaults.
        for o in &cmd.opts {
            if let (Some(_), Some(d)) = (o.value_name, o.default) {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError::HelpRequested(self.command_help(cmd)));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = cmd
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::UnknownOption(cmd.name.into(), tok.clone()))?;
                match spec.value_name {
                    None => {
                        args.flags.insert(name.to_string(), true);
                    }
                    Some(_) => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError::MissingValue(name.into()))?
                            }
                        };
                        args.values.insert(name.to_string(), v);
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_string(&self, name: &str) -> String {
        self.values.get(name).cloned().unwrap_or_default()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        let raw = self.values.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        raw.parse::<T>().map_err(|_| CliError::BadValue {
            opt: name.into(),
            value: raw.clone(),
            expected: std::any::type_name::<T>(),
        })
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get_parse(name)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get_parse(name)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get_parse(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            program: "cimsim",
            about: "test cli",
            commands: vec![
                CmdSpec {
                    name: "run",
                    about: "run things",
                    opts: vec![
                        OptSpec { name: "steps", value_name: Some("N"), default: Some("10"), help: "step count" },
                        OptSpec { name: "fast", value_name: None, default: None, help: "go fast" },
                        OptSpec { name: "label", value_name: Some("S"), default: None, help: "tag" },
                    ],
                    positional: Some("input"),
                },
                CmdSpec { name: "info", about: "print info", opts: vec![], positional: None },
            ],
        }
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let a = cli().parse(&v(&["run"])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 10);
        assert!(!a.flag("fast"));

        let a = cli().parse(&v(&["run", "--steps", "42", "--fast", "file.bin"])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 42);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["file.bin".to_string()]);
    }

    #[test]
    fn parses_equals_form() {
        let a = cli().parse(&v(&["run", "--steps=7", "--label=x"])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 7);
        assert_eq!(a.get("label"), Some("x"));
    }

    #[test]
    fn rejects_unknowns() {
        assert!(matches!(cli().parse(&v(&["nope"])), Err(CliError::UnknownCommand(_))));
        assert!(matches!(
            cli().parse(&v(&["run", "--bogus"])),
            Err(CliError::UnknownOption(..))
        ));
        assert!(matches!(
            cli().parse(&v(&["run", "--label"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_value_reports_type() {
        let a = cli().parse(&v(&["run", "--steps", "zebra"])).unwrap();
        assert!(matches!(a.get_usize("steps"), Err(CliError::BadValue { .. })));
    }

    #[test]
    fn help_paths() {
        assert!(matches!(cli().parse(&v(&[])), Err(CliError::HelpRequested(_))));
        assert!(matches!(cli().parse(&v(&["help"])), Err(CliError::HelpRequested(_))));
        match cli().parse(&v(&["run", "--help"])) {
            Err(CliError::HelpRequested(t)) => assert!(t.contains("--steps")),
            other => panic!("expected help, got {other:?}"),
        }
    }
}
