//! Foundation utilities built in-repo because the offline build environment
//! vendors no `rand`/`clap`/`serde`/`rayon`/`proptest` (see DESIGN.md §2).

pub mod cli;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod threadpool;
pub mod tomlcfg;
