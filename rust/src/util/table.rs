//! Markdown / CSV table emission for the experiment harness. Every figure and
//! table reproduction renders through this module so `EXPERIMENTS.md` and the
//! bench output share one formatting path.

/// A simple column-oriented table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {} in table `{}`",
            cells.len(),
            self.headers.len(),
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// GitHub-flavoured markdown rendering with aligned columns.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// RFC-4180-ish CSV (quotes cells containing commas/quotes/newlines).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write both renderings under `dir/<slug>.{md,csv}` and return the paths.
    pub fn write_to(&self, dir: &std::path::Path, slug: &str) -> std::io::Result<[std::path::PathBuf; 2]> {
        std::fs::create_dir_all(dir)?;
        let md = dir.join(format!("{slug}.md"));
        let csv = dir.join(format!("{slug}.csv"));
        std::fs::write(&md, self.to_markdown())?;
        std::fs::write(&csv, self.to_csv())?;
        Ok([md, csv])
    }
}

/// Format helpers used across the harness so units render consistently.
pub fn fmt_sig(x: f64, digits: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec, x)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

pub fn fmt_range(lo: f64, hi: f64, digits: usize) -> String {
    format!("{}–{}", fmt_sig(lo, digits), fmt_sig(hi, digits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_rendering_aligns() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row_strs(&["alpha", "1"]).row_strs(&["b", "22222"]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| alpha | 1     |"));
        assert!(md.contains("| b     | 22222 |"));
        // separator row present
        assert!(md.lines().nth(3).unwrap().starts_with("|--"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.row_strs(&["x,y", "quo\"te"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"quo\"\"te\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn sig_digit_formatting() {
        assert_eq!(fmt_sig(137.54321, 4), "137.5");
        assert_eq!(fmt_sig(0.0123456, 3), "0.0123");
        assert_eq!(fmt_sig(95.6, 3), "95.6");
        assert_eq!(fmt_pct(0.0064), "0.64%");
        assert_eq!(fmt_range(95.6, 137.5, 4), "95.60–137.5");
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("cimsim_table_test");
        let mut t = Table::new("T", &["a"]);
        t.row_strs(&["1"]);
        let [md, csv] = t.write_to(&dir, "t").unwrap();
        assert!(std::fs::read_to_string(md).unwrap().contains("### T"));
        assert!(std::fs::read_to_string(csv).unwrap().starts_with("a\n"));
    }
}
