//! Scoped data-parallel helpers (no `rayon` in the offline environment).
//!
//! The workloads here are embarrassingly parallel Monte-Carlo sweeps, so a
//! simple static chunking over `std::thread::scope` is all that is needed.
//! Each worker gets its own decorrelated RNG substream from the caller.

/// Number of worker threads to use by default: all cores, capped so the
/// simulator never oversubscribes small CI machines.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 32)
}

/// Run `f(chunk_index, start, end)` over `[0, n)` split into `workers`
/// contiguous chunks, collecting the per-chunk results in order.
pub fn parallel_chunks<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        return vec![f(0, 0, n)];
    }
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<T>> = (0..workers).map(|_| None).collect();
    std::thread::scope(|s| {
        let f = &f;
        let mut handles = Vec::with_capacity(workers);
        for (w, slot) in out.iter_mut().enumerate() {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            handles.push(s.spawn(move || {
                *slot = Some(f(w, start, end));
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    out.into_iter().map(|o| o.expect("chunk missing")).collect()
}

/// Map each index in `[0, n)` to a value in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        let f = &f;
        for (w, piece) in out.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                for (j, slot) in piece.iter_mut().enumerate() {
                    *slot = f(w * chunk + j);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let n = 1003;
        let hits = AtomicUsize::new(0);
        let parts = parallel_chunks(n, 7, |_, start, end| {
            hits.fetch_add(end - start, Ordering::SeqCst);
            (start, end)
        });
        assert_eq!(hits.load(Ordering::SeqCst), n);
        // Contiguous, ordered, non-overlapping.
        let mut expect = 0;
        for (s, e) in parts {
            assert_eq!(s, expect);
            assert!(e >= s);
            expect = e;
        }
        assert_eq!(expect, n);
    }

    #[test]
    fn map_matches_serial() {
        let serial: Vec<usize> = (0..257).map(|i| i * i).collect();
        let par = parallel_map(257, 5, |i| i * i);
        assert_eq!(serial, par);
    }

    #[test]
    fn single_worker_and_empty_are_fine() {
        assert_eq!(parallel_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_map(3, 1, |i| i), vec![0, 1, 2]);
        let parts = parallel_chunks(5, 100, |_, s, e| e - s);
        assert_eq!(parts.iter().sum::<usize>(), 5);
    }

    #[test]
    fn default_workers_sane() {
        let w = default_workers();
        assert!(w >= 1 && w <= 32);
    }
}
