//! Property-testing helper (the offline environment has no `proptest`).
//!
//! A property is a closure over a [`Gen`] case generator; [`check`] runs it
//! for `cases` seeded iterations and, on failure, retries the failing seed
//! with progressively "smaller" size hints to report a reduced case. This is
//! deliberately lighter than real shrinking, but in practice the size-hint
//! descent plus the printed seed makes failures easy to reproduce
//! (`CIMSIM_PROP_SEED=<seed> cargo test`).

use crate::util::rng::{Rng, Xoshiro256};

/// Per-case generator handed to properties. Wraps an RNG plus a `size` hint
/// that grows with the case index, so early cases are small.
pub struct Gen {
    pub rng: Xoshiro256,
    pub size: usize,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.next_range_i64(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_bool(0.5)
    }

    /// A vector whose length scales with the size hint (capped at `max_len`).
    pub fn vec_i64(&mut self, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
        let len = self.usize_in(0, max_len.min(self.size.max(1)));
        (0..len).map(|_| self.i64_in(lo, hi)).collect()
    }

    /// Uniform choice from a non-empty slice (enhancement modes, worker
    /// counts, batch shapes...).
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "pick from empty slice");
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// A fixed-length f32 vector in `[lo, hi)` (activation batches).
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| lo + (hi - lo) * self.rng.next_f32())
            .collect()
    }
}

/// Outcome of a property check, with the failing seed when applicable.
#[derive(Debug)]
pub struct PropError {
    pub seed: u64,
    pub case: usize,
    pub message: String,
}

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (reproduce with CIMSIM_PROP_SEED={}): {}",
            self.case, self.seed, self.message
        )
    }
}

/// Run `prop` for `cases` seeded cases. Panics with a reproducible report on
/// the first failure. Properties signal failure by returning `Err(msg)`.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("CIMSIM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| fnv(name));
    for case in 0..cases {
        let case_seed = base_seed.wrapping_add(0x9E37_79B9 * case as u64);
        // Size ramps from small to full over the run.
        let size = 1 + (case * 64) / cases.max(1);
        let mut g = Gen {
            rng: Xoshiro256::seeded(case_seed),
            size,
            case_seed,
        };
        if let Err(message) = prop(&mut g) {
            // Descend the size hint on the same seed to report a smaller case
            // when the property is size-sensitive.
            let mut best = PropError { seed: case_seed, case, message };
            for s in [1usize, 2, 4, 8] {
                if s >= size {
                    break;
                }
                let mut g2 = Gen { rng: Xoshiro256::seeded(case_seed), size: s, case_seed };
                if let Err(m2) = prop(&mut g2) {
                    best = PropError { seed: case_seed, case, message: format!("(size {s}) {m2}") };
                    break;
                }
            }
            panic!("[{name}] {best}");
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Assert-style helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // count via cell trick: check takes Fn, use Cell
        let counter = std::cell::Cell::new(0usize);
        check("trivially-true", 50, |g| {
            counter.set(counter.get() + 1);
            let v = g.vec_i64(16, -5, 5);
            prop_assert!(v.iter().all(|x| (-5..=5).contains(x)), "range violated");
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "CIMSIM_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("always-false", 10, |_g| Err("nope".to_string()));
    }

    #[test]
    fn generator_bounds_hold() {
        check("gen-bounds", 100, |g| {
            let u = g.usize_in(3, 9);
            prop_assert!((3..=9).contains(&u), "usize_in out of range: {u}");
            let f = g.f64_in(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f) || f == -1.0, "f64_in out of range: {f}");
            Ok(())
        });
    }

    #[test]
    fn pick_and_vec_f32_stay_in_domain() {
        check("gen-pick", 60, |g| {
            let modes = ["a", "b", "c"];
            let m = *g.pick(&modes);
            prop_assert!(modes.contains(&m), "pick left the slice: {m}");
            let v = g.vec_f32(17, 0.0, 2.0);
            prop_assert!(v.len() == 17, "wrong length {}", v.len());
            prop_assert!(
                v.iter().all(|x| (0.0..2.0).contains(x)),
                "vec_f32 out of range"
            );
            Ok(())
        });
    }
}
