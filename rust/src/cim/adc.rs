//! The memory cell-embedded 9-b ADC: a binary-search readout that reuses the
//! same discharge mechanism as the MAC phase (Fig. 3).
//!
//! After the MAC phase leaves a differential voltage on RBL/RBLB, the SA
//! compares the pair once per cycle; after each of the first `bits−1`
//! comparisons the *higher* line is discharged by FS/2^(d+2) — realized on
//! silicon by activating a configured number of the sign-bit cells' 64
//! discharge branches for a configured pulse width. The lines converge to a
//! common voltage and the comparison history is the signed output code
//! (mid-rise quantizer, transitions at multiples of one LSB).
//!
//! Because MAC and A-to-D share one discharge mechanism, gain error is
//! common-mode — the linearity that lets the design support 64-way analog
//! accumulation. The `sar_reference` ablation in `harness::ablation` breaks
//! exactly this sharing.

use crate::cim::engine::MacPhase;
use crate::cim::noise::{Fabrication, NoiseDraw};
use crate::config::Config;

/// Result of reading out every engine of one core.
#[derive(Clone, Debug)]
pub struct Readout {
    /// Signed output code per engine, in `−2^(bits−1) ..= 2^(bits−1)−1`.
    pub codes: Vec<i32>,
    /// Total readout discharge per the op (u), for the energy model.
    pub adc_discharge_u: f64,
    /// SA comparisons performed.
    pub sa_compares: usize,
}

/// Binary-search readout of one core's MAC result.
pub fn readout(
    cfg: &Config,
    core: usize,
    mac: &MacPhase,
    fab: &Fabrication,
    draw: &NoiseDraw,
) -> Readout {
    let mut codes = Vec::with_capacity(cfg.mac.engines);
    let (adc_discharge_u, sa_compares) = readout_into(cfg, core, mac, fab, draw, &mut codes);
    Readout { codes, adc_discharge_u, sa_compares }
}

/// Buffer-reusing form of [`readout`]: clears and refills `codes`, returning
/// `(adc_discharge_u, sa_compares)`. Identical arithmetic to the allocating
/// form — the pipeline hot path uses it to run allocation-free per op.
pub fn readout_into(
    cfg: &Config,
    core: usize,
    mac: &MacPhase,
    fab: &Fabrication,
    draw: &NoiseDraw,
    codes: &mut Vec<i32>,
) -> (f64, usize) {
    let m = &cfg.mac;
    let bits = m.adc_bits as usize;
    let vpp = m.vpp_units();
    let fs = m.adc_fullscale_units();
    let noise_on = cfg.noise.enabled;

    codes.clear();
    let mut total_dis = 0.0;
    let mut compares = 0;

    for e in 0..m.engines {
        let delta = fab.cap(core, e) as f64;
        let mut v_rbl = vpp - mac.rbl_drop[e];
        let mut v_rblb = vpp - mac.rblb_drop[e];
        let sa_static = fab.sa_off(core, e) as f64;

        // Sign convention: positive products discharge RBL (engine.rs), so a
        // positive MAC leaves RBLB the *higher* line — the SA reports
        // sign(V_RBLB − V_RBL) and the search discharges the higher line.
        //
        // est_half accumulates the search midpoint in half-LSB units:
        // Σ_d ±2^(bits−1−d) is always odd, and code = est_half.div_euclid(2).
        let mut est_half: i64 = 0;
        for d in 0..bits {
            let sa_noise = if noise_on {
                cfg.noise.sigma_sa_cmp * draw.cmp(e, d) as f64
            } else {
                0.0
            };
            let bit = (v_rblb - v_rbl) + sa_static + sa_noise > 0.0;
            compares += 1;
            est_half += if bit { 1 } else { -1 } * (1i64 << (bits - 1 - d));

            if d + 1 < bits {
                // Discharge the higher line by FS/2^(d+2), with the static
                // per-step mismatch (shared discharge mechanism ⇒ these
                // errors mirror the MAC cells') and dynamic step noise.
                let nominal = fs / (1u64 << (d + 2)) as f64;
                let err = if noise_on {
                    fab.step(core, e, d) as f64
                        + cfg.noise.sigma_step_rel * draw.step(e, d) as f64
                } else {
                    0.0
                };
                let mut q = nominal * (1.0 + err);
                if q < 0.0 {
                    q = 0.0;
                }
                total_dis += q;
                if bit {
                    v_rblb = (v_rblb - q * (1.0 + delta)).max(0.0);
                } else {
                    v_rbl = (v_rbl - q * (1.0 - delta)).max(0.0);
                }
            }
        }
        codes.push(est_half.div_euclid(2) as i32);
    }

    (total_dis, compares)
}

/// Ideal (noise-free, infinite-precision comparator) code for a differential
/// voltage `v_diff` in u: mid-rise quantization with transitions at integer
/// multiples of the LSB, *ties broken downward* (`ceil(x) − 1`) — exactly
/// what the `> 0` comparator of the binary search converges to absent noise.
pub fn ideal_code_from_voltage(cfg: &Config, v_diff: f64) -> i32 {
    let lsb = cfg.mac.adc_lsb_units();
    let half = cfg.mac.adc_codes() / 2;
    let code = (v_diff / lsb).ceil() as i64 - 1;
    code.clamp(-half, half - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::engine::MacPhase;
    use crate::cim::engine::OpStats;
    use crate::cim::noise::{Fabrication, NoiseDraw};
    use crate::config::Config;

    fn ideal_cfg() -> Config {
        let mut c = Config::default();
        c.noise.enabled = false;
        c
    }

    /// Build a MacPhase with a prescribed differential voltage on engine 0.
    fn phase_with_diff(cfg: &Config, v_diff: f64) -> MacPhase {
        let n = cfg.mac.engines;
        let mut rbl = vec![0.0; n];
        let mut rblb = vec![0.0; n];
        for e in 0..n {
            // diff = V(RBLB) − V(RBL) = rbl_drop − rblb_drop
            if v_diff >= 0.0 {
                rbl[e] = v_diff;
            } else {
                rblb[e] = -v_diff;
            }
        }
        MacPhase { rbl_drop: rbl, rblb_drop: rblb, stats: OpStats::default() }
    }

    #[test]
    fn binary_search_matches_ideal_quantizer() {
        let cfg = ideal_cfg();
        let fab = Fabrication::ideal(&cfg.mac);
        let draw = NoiseDraw::zeros(&cfg.mac);
        let lsb = cfg.mac.adc_lsb_units();
        for &v in &[
            0.0,
            0.4 * lsb,
            1.0 * lsb,
            1.5 * lsb,
            -0.4 * lsb,
            -1.0 * lsb,
            100.3 * lsb,
            -100.7 * lsb,
            255.2 * lsb,
            -255.9 * lsb,
        ] {
            let m = phase_with_diff(&cfg, v);
            let r = readout(&cfg, 0, &m, &fab, &draw);
            let want = ideal_code_from_voltage(&cfg, v);
            assert_eq!(r.codes[0], want, "v_diff = {v} u ({} lsb)", v / lsb);
        }
    }

    #[test]
    fn full_scale_clips_to_code_extremes() {
        let cfg = ideal_cfg();
        let fab = Fabrication::ideal(&cfg.mac);
        let draw = NoiseDraw::zeros(&cfg.mac);
        let vpp = cfg.mac.vpp_units();
        let m = phase_with_diff(&cfg, vpp); // max positive differential
        let r = readout(&cfg, 0, &m, &fab, &draw);
        assert_eq!(r.codes[0], 255);
        let m = phase_with_diff(&cfg, -vpp);
        let r = readout(&cfg, 0, &m, &fab, &draw);
        assert_eq!(r.codes[0], -256);
    }

    #[test]
    fn lines_converge_after_readout() {
        // Re-run the search manually to confirm convergence within 1 LSB.
        let cfg = ideal_cfg();
        let fab = Fabrication::ideal(&cfg.mac);
        let draw = NoiseDraw::zeros(&cfg.mac);
        let lsb = cfg.mac.adc_lsb_units();
        let v = 37.3 * lsb;
        let m = phase_with_diff(&cfg, v);
        // After readout the residual differential is < 1 LSB: verify via the
        // reconstruction identity |v − (code+0.5)·lsb| ≤ lsb/2.
        let r = readout(&cfg, 0, &m, &fab, &draw);
        let recon = (r.codes[0] as f64 + 0.5) * lsb;
        assert!((v - recon).abs() <= lsb / 2.0 + 1e-9);
    }

    #[test]
    fn discharge_energy_is_code_independent() {
        // The search always applies the same nominal step ladder, so ADC
        // discharge is ~fixed — the paper's energy advantage over SAR
        // (re-using the precharged MAC caps) shows up in the energy model.
        let cfg = ideal_cfg();
        let fab = Fabrication::ideal(&cfg.mac);
        let draw = NoiseDraw::zeros(&cfg.mac);
        let lsb = cfg.mac.adc_lsb_units();
        let r1 = readout(&cfg, 0, &phase_with_diff(&cfg, 3.0 * lsb), &fab, &draw);
        let r2 = readout(&cfg, 0, &phase_with_diff(&cfg, -200.0 * lsb), &fab, &draw);
        assert!((r1.adc_discharge_u - r2.adc_discharge_u).abs() < 1e-9);
        assert_eq!(r1.sa_compares, cfg.mac.engines * 9);
    }

    #[test]
    fn sa_offset_shifts_transfer() {
        let mut cfg = Config::default();
        // Only a large static SA offset; everything else off.
        cfg.noise.sigma_cell = 0.0;
        cfg.noise.sigma_t_floor = 0.0;
        cfg.noise.sigma_t_small = 0.0;
        cfg.noise.sigma_sa_cmp = 0.0;
        cfg.noise.sigma_step_rel = 0.0;
        cfg.noise.sigma_step_static = 0.0;
        cfg.noise.sigma_cap = 0.0;
        cfg.noise.sigma_sa_static = 60.0; // ≈ 2.3 LSB
        let fab = Fabrication::draw(&cfg.mac, &cfg.noise);
        let draw = NoiseDraw::zeros(&cfg.mac);
        let m = phase_with_diff(&cfg, 0.0);
        let r = readout(&cfg, 0, &m, &fab, &draw);
        // Some engines must deviate from the ideal code (σ ≈ 2.3 LSB).
        let ideal = ideal_code_from_voltage(&cfg, 0.0);
        assert!(r.codes.iter().any(|&c| c != ideal));
        // ... and each code error is bounded by that engine's own offset
        // (the offset acts as a pure input shift).
        let lsb = cfg.mac.adc_lsb_units();
        for (e, &c) in r.codes.iter().enumerate() {
            let shift_lsb = (fab.sa_off(0, e) as f64 / lsb).abs().ceil() as i32 + 1;
            assert!((c - ideal).abs() <= shift_lsb, "engine {e}: code {c}");
        }
    }
}
