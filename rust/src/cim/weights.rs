//! Weight storage for one CIM core, mirroring the 9-T cell array layout:
//! each of the `rows × engines` weights is stored sign-magnitude (W[3] sign
//! bit in the sign-control column, W[2:0] magnitude in the three MAC-cell
//! columns).

use crate::config::MacroConfig;

/// Weights resident in one core's SRAM array.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreWeights {
    pub rows: usize,
    pub engines: usize,
    /// Magnitude |w| per (row, engine), row-major, each in `0..=w_mag_max`.
    mag: Vec<u8>,
    /// Sign per (row, engine): +1 or −1 (W[3]). Zero weights store +1.
    sign: Vec<i8>,
    /// Column sums Σ_r w[r][e] — the digital fold-correction constant
    /// `fold_offset · col_sum` is computed from these at load time.
    col_sum: Vec<i64>,
}

#[derive(Debug)]
pub enum WeightError {
    Shape { expected: (usize, usize), got: (usize, usize) },
    Range { row: usize, engine: usize, value: i64, max: i64 },
}

impl std::fmt::Display for WeightError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WeightError::Shape { expected, got } => {
                write!(f, "weight shape {got:?} != core shape {expected:?}")
            }
            WeightError::Range { row, engine, value, max } => write!(
                f,
                "weight {value} at (row {row}, engine {engine}) outside ±{max}"
            ),
        }
    }
}

impl std::error::Error for WeightError {}

impl CoreWeights {
    /// Load signed integer weights (row-major `[row][engine]`). Values must
    /// fit the sign-magnitude range ±w_mag_max (±7 for 4-b).
    pub fn from_signed(cfg: &MacroConfig, w: &[Vec<i64>]) -> Result<Self, WeightError> {
        let (rows, engines) = (cfg.rows, cfg.engines);
        if w.len() != rows || w.iter().any(|r| r.len() != engines) {
            let got = (w.len(), w.first().map(|r| r.len()).unwrap_or(0));
            return Err(WeightError::Shape { expected: (rows, engines), got });
        }
        let max = cfg.w_mag_max();
        let mut mag = vec![0u8; rows * engines];
        let mut sign = vec![1i8; rows * engines];
        let mut col_sum = vec![0i64; engines];
        for (r, row) in w.iter().enumerate() {
            for (e, &v) in row.iter().enumerate() {
                if v.abs() > max {
                    return Err(WeightError::Range { row: r, engine: e, value: v, max });
                }
                mag[r * engines + e] = v.unsigned_abs() as u8;
                sign[r * engines + e] = if v < 0 { -1 } else { 1 };
                col_sum[e] += v;
            }
        }
        Ok(Self { rows, engines, mag, sign, col_sum })
    }

    /// Flat constructor used by generators (values validated the same way).
    pub fn from_flat(cfg: &MacroConfig, flat: &[i64]) -> Result<Self, WeightError> {
        assert_eq!(flat.len(), cfg.rows * cfg.engines, "flat weight length");
        let rows: Vec<Vec<i64>> = flat.chunks(cfg.engines).map(|c| c.to_vec()).collect();
        Self::from_signed(cfg, &rows)
    }

    #[inline]
    pub fn mag(&self, row: usize, engine: usize) -> u8 {
        self.mag[row * self.engines + engine]
    }

    #[inline]
    pub fn sign(&self, row: usize, engine: usize) -> i8 {
        self.sign[row * self.engines + engine]
    }

    #[inline]
    pub fn value(&self, row: usize, engine: usize) -> i64 {
        self.sign(row, engine) as i64 * self.mag(row, engine) as i64
    }

    /// Whether magnitude bit `k` (0..3) of the weight is set — i.e. whether
    /// the 9-T cell in bit-column `k` discharges when its SL pulses.
    #[inline]
    pub fn mag_bit(&self, row: usize, engine: usize, k: u32) -> bool {
        (self.mag(row, engine) >> k) & 1 == 1
    }

    /// Σ_r w[r][e] for the fold correction.
    #[inline]
    pub fn col_sum(&self, engine: usize) -> i64 {
        self.col_sum[engine]
    }

    /// Total set magnitude bits (storage activity metric).
    pub fn set_bits(&self) -> usize {
        self.mag.iter().map(|m| m.count_ones() as usize).sum()
    }

    /// Dense row-major signed values (for golden comparisons / export).
    pub fn to_signed(&self) -> Vec<Vec<i64>> {
        (0..self.rows)
            .map(|r| (0..self.engines).map(|e| self.value(r, e)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;

    fn cfg() -> MacroConfig {
        MacroConfig::default()
    }

    fn ramp_weights(cfg: &MacroConfig) -> Vec<Vec<i64>> {
        (0..cfg.rows)
            .map(|r| {
                (0..cfg.engines)
                    .map(|e| (((r * 31 + e * 7) % 15) as i64) - 7)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn sign_magnitude_roundtrip() {
        let c = cfg();
        let w = ramp_weights(&c);
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        assert_eq!(cw.to_signed(), w);
        // spot-check bit extraction: value -5 = sign -1, mag 0b101
        let (mut r5, mut e5) = (usize::MAX, usize::MAX);
        'outer: for (r, row) in w.iter().enumerate() {
            for (e, &v) in row.iter().enumerate() {
                if v == -5 {
                    (r5, e5) = (r, e);
                    break 'outer;
                }
            }
        }
        assert_ne!(r5, usize::MAX, "ramp should contain -5");
        assert_eq!(cw.sign(r5, e5), -1);
        assert_eq!(cw.mag(r5, e5), 5);
        assert!(cw.mag_bit(r5, e5, 0));
        assert!(!cw.mag_bit(r5, e5, 1));
        assert!(cw.mag_bit(r5, e5, 2));
    }

    #[test]
    fn col_sums_match_manual() {
        let c = cfg();
        let w = ramp_weights(&c);
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        for e in 0..c.engines {
            let manual: i64 = (0..c.rows).map(|r| w[r][e]).sum();
            assert_eq!(cw.col_sum(e), manual);
        }
    }

    #[test]
    fn rejects_out_of_range_and_bad_shape() {
        let c = cfg();
        let mut w = ramp_weights(&c);
        w[3][5] = 8; // > +7
        assert!(matches!(
            CoreWeights::from_signed(&c, &w),
            Err(WeightError::Range { row: 3, engine: 5, value: 8, .. })
        ));
        let short = vec![vec![0i64; c.engines]; c.rows - 1];
        assert!(matches!(
            CoreWeights::from_signed(&c, &short),
            Err(WeightError::Shape { .. })
        ));
    }

    #[test]
    fn minus_seven_and_plus_seven_ok() {
        let c = cfg();
        let mut w = vec![vec![0i64; c.engines]; c.rows];
        w[0][0] = -7;
        w[1][1] = 7;
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        assert_eq!(cw.value(0, 0), -7);
        assert_eq!(cw.value(1, 1), 7);
        assert_eq!(cw.set_bits(), 6);
    }
}
