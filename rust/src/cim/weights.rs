//! Weight storage for one CIM core, mirroring the 9-T cell array layout:
//! each of the `rows × engines` weights is stored sign-magnitude (W[3] sign
//! bit in the sign-control column, W[2:0] magnitude in the three MAC-cell
//! columns).
//!
//! Besides the dense row-major store, every loaded core carries a
//! precomputed [`BitPlanes`] structure-of-arrays view (DESIGN.md §4): packed
//! per-engine row bitmasks (one per weight bit, plus the union and the sign
//! column) and an engine-major signed-value column. It is built once at load
//! time and backs the bit-plane fast-path kernel
//! (`engine::mac_phase_prepared_into`) — the columnwise evaluation order of
//! the silicon, where each engine walks only its set rows.

use crate::config::MacroConfig;

/// Bit-plane SoA view of one core's weights, built once at load time.
///
/// For each engine the row dimension is packed into `u64` bitmask words:
/// one mask per magnitude bit `k` (the "bit plane" — which rows' 9-T cells
/// discharge when the bit-`k` SL pulses), their union (`any`), and the sign
/// column (rows stored with W[3] = positive). The engine-major signed value
/// column (`val`) feeds the closed-form noise-free integer path.
///
/// Layout invariant: masks are engine-major (`engine` outer, word inner) so
/// one engine's walk touches contiguous memory; `plane` nests `k` between
/// engine and word.
#[derive(Clone, Debug, PartialEq)]
pub struct BitPlanes {
    rows: usize,
    kbits: usize,
    /// `u64` bitmask words per row dimension (`rows.div_ceil(64)`).
    words: usize,
    /// Per `(engine, k)`: rows whose magnitude bit `k` is set,
    /// `[(engine·kbits + k)·words ..]`.
    plane: Vec<u64>,
    /// Per engine: union of all magnitude planes (rows with `|w| ≠ 0`).
    any: Vec<u64>,
    /// Per engine: rows whose stored sign is positive.
    sign_pos: Vec<u64>,
    /// Engine-major signed weight values, `[engine·rows + row]`.
    val: Vec<i16>,
}

impl BitPlanes {
    /// Bit-widths beyond `MAX_KBITS` magnitude bits (9-b sign-magnitude
    /// weights) don't fit the kernels' stack plane cache (`[u64; 8]`).
    pub const MAX_KBITS: usize = 8;

    fn build(cfg: &MacroConfig, mag: &[u8], sign: &[i8]) -> Result<Self, WeightError> {
        let (rows, engines) = (cfg.rows, cfg.engines);
        let kbits = cfg.weight_bits as usize - 1;
        // The kernels cache one 64-row window of plane words on the stack
        // ([u64; 8]). The config layer validates weight_bits ≤ 8, but a
        // hand-built or future-loader config must surface an error here
        // rather than abort a serving process.
        if kbits > Self::MAX_KBITS {
            return Err(WeightError::Precision { weight_bits: cfg.weight_bits });
        }
        let words = rows.div_ceil(64);
        let mut planes = Self {
            rows,
            kbits,
            words,
            plane: vec![0; engines * kbits * words],
            any: vec![0; engines * words],
            sign_pos: vec![0; engines * words],
            val: vec![0; engines * rows],
        };
        for r in 0..rows {
            let (wi, bit) = (r / 64, (r % 64) as u32);
            for e in 0..engines {
                let m = mag[r * engines + e];
                let s = sign[r * engines + e];
                planes.val[e * rows + r] = if s < 0 { -(m as i16) } else { m as i16 };
                if s > 0 {
                    planes.sign_pos[e * words + wi] |= 1u64 << bit;
                }
                if m != 0 {
                    planes.any[e * words + wi] |= 1u64 << bit;
                }
                for k in 0..kbits {
                    if (m >> k) & 1 == 1 {
                        planes.plane[(e * kbits + k) * words + wi] |= 1u64 << bit;
                    }
                }
            }
        }
        Ok(planes)
    }

    #[inline]
    pub fn words(&self) -> usize {
        self.words
    }

    #[inline]
    pub fn kbits(&self) -> usize {
        self.kbits
    }

    /// One 64-row window of the union mask for `engine`.
    #[inline]
    pub fn any_word(&self, engine: usize, wi: usize) -> u64 {
        self.any[engine * self.words + wi]
    }

    /// One 64-row window of the positive-sign mask for `engine`.
    #[inline]
    pub fn sign_word(&self, engine: usize, wi: usize) -> u64 {
        self.sign_pos[engine * self.words + wi]
    }

    /// One 64-row window of the bit-`k` plane for `engine`.
    #[inline]
    pub fn plane_word(&self, engine: usize, k: usize, wi: usize) -> u64 {
        self.plane[(engine * self.kbits + k) * self.words + wi]
    }

    /// The engine-major signed value column (length `rows`).
    #[inline]
    pub fn val_col(&self, engine: usize) -> &[i16] {
        &self.val[engine * self.rows..(engine + 1) * self.rows]
    }

    /// The whole contiguous word run of the union mask for `engine`
    /// (length `words`) — the SIMD tiers consume runs, not single words.
    #[inline]
    pub fn any_words(&self, engine: usize) -> &[u64] {
        &self.any[engine * self.words..(engine + 1) * self.words]
    }

    /// The whole contiguous word run of the positive-sign mask for `engine`.
    #[inline]
    pub fn sign_words(&self, engine: usize) -> &[u64] {
        &self.sign_pos[engine * self.words..(engine + 1) * self.words]
    }

    /// The whole contiguous word run of the bit-`k` plane for `engine`.
    #[inline]
    pub fn plane_words(&self, engine: usize, k: usize) -> &[u64] {
        let base = (engine * self.kbits + k) * self.words;
        &self.plane[base..base + self.words]
    }
}

/// Weights resident in one core's SRAM array.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreWeights {
    pub rows: usize,
    pub engines: usize,
    /// Magnitude |w| per (row, engine), row-major, each in `0..=w_mag_max`.
    mag: Vec<u8>,
    /// Sign per (row, engine): +1 or −1 (W[3]). Zero weights store +1.
    sign: Vec<i8>,
    /// Column sums Σ_r w[r][e] — the digital fold-correction constant
    /// `fold_offset · col_sum` is computed from these at load time.
    col_sum: Vec<i64>,
    /// Precomputed bit-plane SoA view for the fast-path kernel.
    planes: BitPlanes,
}

#[derive(Debug)]
pub enum WeightError {
    Shape { expected: (usize, usize), got: (usize, usize) },
    Range { row: usize, engine: usize, value: i64, max: i64 },
    /// `weight_bits` exceeds the kernels' `[u64; 8]` plane cache.
    Precision { weight_bits: u32 },
}

impl std::fmt::Display for WeightError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WeightError::Shape { expected, got } => {
                write!(f, "weight shape {got:?} != core shape {expected:?}")
            }
            WeightError::Range { row, engine, value, max } => write!(
                f,
                "weight {value} at (row {row}, engine {engine}) outside ±{max}"
            ),
            WeightError::Precision { weight_bits } => write!(
                f,
                "weight_bits {weight_bits} exceeds the kernel plane cache ({} magnitude bits)",
                BitPlanes::MAX_KBITS
            ),
        }
    }
}

impl std::error::Error for WeightError {}

impl CoreWeights {
    /// Load signed integer weights (row-major `[row][engine]`). Values must
    /// fit the sign-magnitude range ±w_mag_max (±7 for 4-b).
    pub fn from_signed(cfg: &MacroConfig, w: &[Vec<i64>]) -> Result<Self, WeightError> {
        let (rows, engines) = (cfg.rows, cfg.engines);
        if w.len() != rows || w.iter().any(|r| r.len() != engines) {
            let got = (w.len(), w.first().map(|r| r.len()).unwrap_or(0));
            return Err(WeightError::Shape { expected: (rows, engines), got });
        }
        let max = cfg.w_mag_max();
        let mut mag = vec![0u8; rows * engines];
        let mut sign = vec![1i8; rows * engines];
        let mut col_sum = vec![0i64; engines];
        for (r, row) in w.iter().enumerate() {
            for (e, &v) in row.iter().enumerate() {
                if v.abs() > max {
                    return Err(WeightError::Range { row: r, engine: e, value: v, max });
                }
                mag[r * engines + e] = v.unsigned_abs() as u8;
                sign[r * engines + e] = if v < 0 { -1 } else { 1 };
                col_sum[e] += v;
            }
        }
        let planes = BitPlanes::build(cfg, &mag, &sign)?;
        Ok(Self { rows, engines, mag, sign, col_sum, planes })
    }

    /// Flat constructor used by generators (values validated the same way).
    pub fn from_flat(cfg: &MacroConfig, flat: &[i64]) -> Result<Self, WeightError> {
        assert_eq!(flat.len(), cfg.rows * cfg.engines, "flat weight length");
        let rows: Vec<Vec<i64>> = flat.chunks(cfg.engines).map(|c| c.to_vec()).collect();
        Self::from_signed(cfg, &rows)
    }

    #[inline]
    pub fn mag(&self, row: usize, engine: usize) -> u8 {
        self.mag[row * self.engines + engine]
    }

    #[inline]
    pub fn sign(&self, row: usize, engine: usize) -> i8 {
        self.sign[row * self.engines + engine]
    }

    #[inline]
    pub fn value(&self, row: usize, engine: usize) -> i64 {
        self.sign(row, engine) as i64 * self.mag(row, engine) as i64
    }

    /// Whether magnitude bit `k` (0..3) of the weight is set — i.e. whether
    /// the 9-T cell in bit-column `k` discharges when its SL pulses.
    #[inline]
    pub fn mag_bit(&self, row: usize, engine: usize, k: u32) -> bool {
        (self.mag(row, engine) >> k) & 1 == 1
    }

    /// Σ_r w[r][e] for the fold correction.
    #[inline]
    pub fn col_sum(&self, engine: usize) -> i64 {
        self.col_sum[engine]
    }

    /// The precomputed bit-plane SoA view (built once at load time).
    #[inline]
    pub fn planes(&self) -> &BitPlanes {
        &self.planes
    }

    /// Total set magnitude bits (storage activity metric).
    pub fn set_bits(&self) -> usize {
        self.mag.iter().map(|m| m.count_ones() as usize).sum()
    }

    /// Dense row-major signed values (for golden comparisons / export).
    pub fn to_signed(&self) -> Vec<Vec<i64>> {
        (0..self.rows)
            .map(|r| (0..self.engines).map(|e| self.value(r, e)).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;

    fn cfg() -> MacroConfig {
        MacroConfig::default()
    }

    fn ramp_weights(cfg: &MacroConfig) -> Vec<Vec<i64>> {
        (0..cfg.rows)
            .map(|r| {
                (0..cfg.engines)
                    .map(|e| (((r * 31 + e * 7) % 15) as i64) - 7)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn sign_magnitude_roundtrip() {
        let c = cfg();
        let w = ramp_weights(&c);
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        assert_eq!(cw.to_signed(), w);
        // spot-check bit extraction: value -5 = sign -1, mag 0b101
        let (mut r5, mut e5) = (usize::MAX, usize::MAX);
        'outer: for (r, row) in w.iter().enumerate() {
            for (e, &v) in row.iter().enumerate() {
                if v == -5 {
                    (r5, e5) = (r, e);
                    break 'outer;
                }
            }
        }
        assert_ne!(r5, usize::MAX, "ramp should contain -5");
        assert_eq!(cw.sign(r5, e5), -1);
        assert_eq!(cw.mag(r5, e5), 5);
        assert!(cw.mag_bit(r5, e5, 0));
        assert!(!cw.mag_bit(r5, e5, 1));
        assert!(cw.mag_bit(r5, e5, 2));
    }

    #[test]
    fn col_sums_match_manual() {
        let c = cfg();
        let w = ramp_weights(&c);
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        for e in 0..c.engines {
            let manual: i64 = (0..c.rows).map(|r| w[r][e]).sum();
            assert_eq!(cw.col_sum(e), manual);
        }
    }

    #[test]
    fn rejects_out_of_range_and_bad_shape() {
        let c = cfg();
        let mut w = ramp_weights(&c);
        w[3][5] = 8; // > +7
        assert!(matches!(
            CoreWeights::from_signed(&c, &w),
            Err(WeightError::Range { row: 3, engine: 5, value: 8, .. })
        ));
        let short = vec![vec![0i64; c.engines]; c.rows - 1];
        assert!(matches!(
            CoreWeights::from_signed(&c, &short),
            Err(WeightError::Shape { .. })
        ));
    }

    /// The SoA planes must agree bit-for-bit with the dense accessors for
    /// every (row, engine, bit) — the fast-path kernel trusts this.
    #[test]
    fn bit_planes_match_dense_accessors() {
        let c = cfg();
        let w = ramp_weights(&c);
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        let p = cw.planes();
        assert_eq!(p.words(), 1); // 64 rows
        assert_eq!(p.kbits(), 3);
        for e in 0..c.engines {
            let col = p.val_col(e);
            for r in 0..c.rows {
                let (wi, bit) = (r / 64, r % 64);
                assert_eq!(col[r] as i64, cw.value(r, e), "val ({r},{e})");
                assert_eq!(
                    (p.any_word(e, wi) >> bit) & 1 == 1,
                    cw.mag(r, e) != 0,
                    "any ({r},{e})"
                );
                assert_eq!(
                    (p.sign_word(e, wi) >> bit) & 1 == 1,
                    cw.sign(r, e) > 0,
                    "sign ({r},{e})"
                );
                for k in 0..3 {
                    assert_eq!(
                        (p.plane_word(e, k, wi) >> bit) & 1 == 1,
                        cw.mag_bit(r, e, k as u32),
                        "plane ({r},{e},{k})"
                    );
                }
            }
        }
    }

    /// Non-multiple-of-64 row counts pack into the right number of words.
    #[test]
    fn bit_planes_handle_odd_row_counts() {
        let mut c = cfg();
        c.rows = 70;
        let w: Vec<Vec<i64>> = (0..c.rows)
            .map(|r| (0..c.engines).map(|e| ((r + e) % 15) as i64 - 7).collect())
            .collect();
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        let p = cw.planes();
        assert_eq!(p.words(), 2);
        for e in 0..c.engines {
            for r in 0..c.rows {
                let (wi, bit) = (r / 64, r % 64);
                assert_eq!((p.any_word(e, wi) >> bit) & 1 == 1, cw.mag(r, e) != 0);
            }
            // Rows past the configured count stay zero in every mask.
            for ghost in c.rows..128 {
                let (wi, bit) = (ghost / 64, ghost % 64);
                assert_eq!((p.any_word(e, wi) >> bit) & 1, 0);
                assert_eq!((p.sign_word(e, wi) >> bit) & 1, 0);
            }
        }
    }

    /// A precision the plane cache can't hold must come back as a
    /// `WeightError`, never a panic — a serving process loading a bad
    /// config has to survive it (ISSUE 6 satellite).
    #[test]
    fn oversized_weight_bits_error_instead_of_panicking() {
        let mut c = cfg();
        c.weight_bits = 12; // kbits 11 > the [u64; 8] plane cache
        let w = vec![vec![1i64; c.engines]; c.rows];
        match CoreWeights::from_signed(&c, &w) {
            Err(WeightError::Precision { weight_bits: 12 }) => {}
            other => panic!("expected Precision error, got {other:?}"),
        }
        let msg = CoreWeights::from_signed(&c, &w).unwrap_err().to_string();
        assert!(msg.contains("weight_bits 12"), "{msg}");
    }

    #[test]
    fn minus_seven_and_plus_seven_ok() {
        let c = cfg();
        let mut w = vec![vec![0i64; c.engines]; c.rows];
        w[0][0] = -7;
        w[1][1] = 7;
        let cw = CoreWeights::from_signed(&c, &w).unwrap();
        assert_eq!(cw.value(0, 0), -7);
        assert_eq!(cw.value(1, 1), 7);
        assert_eq!(cw.set_bits(), 6);
    }
}
