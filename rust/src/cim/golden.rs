//! Exact digital reference for the CIM pipeline: integer MAC, the ideal
//! fold/boost/clip quantization transfer, and value reconstruction. Every
//! accuracy experiment measures the analog model against this module.

use crate::cim::weights::CoreWeights;
use crate::config::{Config, EnhanceConfig};

/// Exact integer dot products: `Σ_r act[r]·w[r][e]` per engine.
pub fn mac_exact(weights: &CoreWeights, acts: &[i64]) -> Vec<i64> {
    let mut out = Vec::new();
    mac_exact_into(weights, acts, &mut out);
    out
}

/// Buffer-reusing form of [`mac_exact`] (the batched pipeline's per-op
/// accounting path is allocation-free).
pub fn mac_exact_into(weights: &CoreWeights, acts: &[i64], out: &mut Vec<i64>) {
    assert_eq!(acts.len(), weights.rows);
    out.clear();
    out.resize(weights.engines, 0);
    for (r, &a) in acts.iter().enumerate() {
        if a == 0 {
            continue;
        }
        for (e, o) in out.iter_mut().enumerate() {
            *o += a * weights.value(r, e);
        }
    }
}

/// The *folded* dot product the analog array actually computes:
/// `Σ_r (act[r] − off)·w[r][e]` (== unfolded when folding is disabled).
pub fn mac_folded(cfg: &Config, weights: &CoreWeights, acts: &[i64]) -> Vec<i64> {
    let mut out = Vec::new();
    mac_folded_into(cfg, weights, acts, &mut out);
    out
}

/// Buffer-reusing form of [`mac_folded`].
pub fn mac_folded_into(cfg: &Config, weights: &CoreWeights, acts: &[i64], out: &mut Vec<i64>) {
    mac_exact_into(weights, acts, out);
    let off = if cfg.enhance.fold { cfg.enhance.fold_offset } else { 0 };
    if off != 0 {
        for (e, o) in out.iter_mut().enumerate() {
            *o -= off * weights.col_sum(e);
        }
    }
}

/// DTC scale as an exact rational `(num, den)` when the configured gains are
/// the paper defaults (1.875 = 15/8, boost 2). Returns `None` for
/// non-default gains, in which case quantization falls back to f64.
pub fn scale_fraction(e: &EnhanceConfig) -> Option<(i64, i64)> {
    let frac = |x: f64| -> Option<(i64, i64)> {
        // Recognize small dyadic rationals exactly (covers 1.875, 2.0, 3.75).
        for den in [1i64, 2, 4, 8, 16] {
            let num = x * den as f64;
            if (num - num.round()).abs() < 1e-12 {
                return Some((num.round() as i64, den));
            }
        }
        None
    };
    frac(e.dtc_scale())
}

/// Unclamped ideal code for a folded MAC value `d` (product units):
/// mid-rise quantization of `d·s` against the fixed ADC LSB with code
/// transitions at integer multiples of the LSB and *ties broken downward*
/// (`ceil(x) − 1`), matching the binary search's `> 0` comparator. Exact
/// integer arithmetic for the default (dyadic) gains.
fn ideal_code_unclamped(cfg: &Config, d: i64) -> i64 {
    match scale_fraction(&cfg.enhance) {
        Some((num, den)) => {
            // x = d·(num/den)/(fs/codes) = d·num·codes/(den·fs);
            // ceil(n/m) − 1 == (n − 1).div_euclid(m) for m > 0.
            let fs = 2 * cfg.mac.mac_range();
            let numer = d as i128 * num as i128 * cfg.mac.adc_codes() as i128;
            let denom = den as i128 * fs as i128;
            (numer - 1).div_euclid(denom) as i64
        }
        None => {
            let s = cfg.enhance.dtc_scale();
            (d as f64 * s / cfg.mac.adc_lsb_units()).ceil() as i64 - 1
        }
    }
}

/// Ideal output code for a folded MAC value `d`, clipped to the code range.
pub fn ideal_code(cfg: &Config, d: i64) -> i32 {
    let half = cfg.mac.adc_codes() / 2;
    ideal_code_unclamped(cfg, d).clamp(-half, half - 1) as i32
}

/// Reconstruct the digital MAC estimate from an output code: mid-rise
/// dequantization back to product units, plus the fold-correction constant
/// `off·Σw` restored digitally (computed at weight-load time on the chip).
pub fn reconstruct(cfg: &Config, weights: &CoreWeights, engine: usize, code: i32) -> f64 {
    let s = cfg.enhance.dtc_scale();
    let deq = (code as f64 + 0.5) * cfg.mac.adc_lsb_units() / s;
    let corr = if cfg.enhance.fold {
        (cfg.enhance.fold_offset * weights.col_sum(engine)) as f64
    } else {
        0.0
    };
    deq + corr
}

/// End-to-end ideal pipeline: what a noise-free chip returns for `acts`,
/// in reconstructed product units (per engine).
pub fn ideal_pipeline(cfg: &Config, weights: &CoreWeights, acts: &[i64]) -> Vec<f64> {
    mac_folded(cfg, weights, acts)
        .iter()
        .enumerate()
        .map(|(e, &d)| reconstruct(cfg, weights, e, ideal_code(cfg, d)))
        .collect()
}

/// Whether a folded MAC value clips in the current configuration (only
/// possible with boosting, by design).
pub fn clips(cfg: &Config, d: i64) -> bool {
    let half = cfg.mac.adc_codes() / 2;
    let c = ideal_code_unclamped(cfg, d);
    c < -half || c > half - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, EnhanceConfig};
    use crate::util::rng::{Rng, Xoshiro256};

    fn random_setup(seed: u64, cfg: &Config) -> (CoreWeights, Vec<i64>) {
        let mut rng = Xoshiro256::seeded(seed);
        let w: Vec<Vec<i64>> = (0..cfg.mac.rows)
            .map(|_| (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect())
            .collect();
        let acts: Vec<i64> = (0..cfg.mac.rows).map(|_| rng.next_range_i64(0, 15)).collect();
        (CoreWeights::from_signed(&cfg.mac, &w).unwrap(), acts)
    }

    #[test]
    fn folded_equals_exact_minus_correction() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::fold_only();
        let (w, acts) = random_setup(1, &cfg);
        let exact = mac_exact(&w, &acts);
        let folded = mac_folded(&cfg, &w, &acts);
        for e in 0..cfg.mac.engines {
            assert_eq!(folded[e], exact[e] - 8 * w.col_sum(e));
        }
    }

    #[test]
    fn scale_fractions_for_all_default_modes() {
        assert_eq!(scale_fraction(&EnhanceConfig::default()), Some((1, 1)));
        assert_eq!(scale_fraction(&EnhanceConfig::fold_only()), Some((15, 8)));
        assert_eq!(scale_fraction(&EnhanceConfig::boost_only()), Some((2, 1)));
        assert_eq!(scale_fraction(&EnhanceConfig::both()), Some((15, 4)));
        let weird = EnhanceConfig { fold: true, fold_gain: 1.8701, ..EnhanceConfig::default() };
        assert_eq!(scale_fraction(&weird), None);
    }

    #[test]
    fn ideal_code_rational_matches_float() {
        for enh in [
            EnhanceConfig::default(),
            EnhanceConfig::fold_only(),
            EnhanceConfig::boost_only(),
            EnhanceConfig::both(),
        ] {
            let mut cfg = Config::default();
            cfg.enhance = enh;
            let s = cfg.enhance.dtc_scale();
            for d in (-7000..7000).step_by(137) {
                let rational = ideal_code(&cfg, d);
                let float = ((d as f64 * s / cfg.mac.adc_lsb_units()).ceil() as i64 - 1)
                    .clamp(-256, 255) as i32;
                assert_eq!(rational, float, "d={d} mode={}", cfg.enhance.label());
            }
        }
    }

    #[test]
    fn fold_quantization_step_is_14_units() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::fold_only();
        // s = 15/8, LSB = 26.25 u ⇒ one code per 14 product units, with
        // transitions AT multiples of 14 breaking downward (mid-rise,
        // matching the comparator's `> 0`).
        assert_eq!(ideal_code(&cfg, 0), -1);
        assert_eq!(ideal_code(&cfg, 1), 0);
        assert_eq!(ideal_code(&cfg, 13), 0);
        assert_eq!(ideal_code(&cfg, 14), 0);
        assert_eq!(ideal_code(&cfg, 15), 1);
        assert_eq!(ideal_code(&cfg, -1), -1);
        assert_eq!(ideal_code(&cfg, -13), -1);
        assert_eq!(ideal_code(&cfg, -14), -2);
        assert_eq!(ideal_code(&cfg, -15), -2);
    }

    #[test]
    fn boost_clips_beyond_1792() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both(); // s = 15/4 ⇒ 7 units per code
        assert_eq!(ideal_code(&cfg, 1791), 255);
        assert_eq!(ideal_code(&cfg, 1792), 255); // exactly at +FS/2 (tie down)
        assert!(!clips(&cfg, 1792));
        assert!(clips(&cfg, 1793));
        assert!(!clips(&cfg, 1785));
        assert_eq!(ideal_code(&cfg, -1792), -256);
        assert!(clips(&cfg, -1793));
    }

    #[test]
    fn reconstruction_error_bounded_by_half_step() {
        // |reconstruct(ideal_code(d)) − d| ≤ step/2 when not clipping.
        for enh in [EnhanceConfig::default(), EnhanceConfig::fold_only(), EnhanceConfig::both()] {
            let mut cfg = Config::default();
            cfg.enhance = enh;
            let (w, acts) = random_setup(3, &cfg);
            let step = cfg.mac.adc_lsb_units() / cfg.enhance.dtc_scale();
            let folded = mac_folded(&cfg, &w, &acts);
            let exact = mac_exact(&w, &acts);
            let recon = ideal_pipeline(&cfg, &w, &acts);
            for e in 0..cfg.mac.engines {
                if clips(&cfg, folded[e]) {
                    continue;
                }
                let err = (recon[e] - exact[e] as f64).abs();
                assert!(err <= step / 2.0 + 1e-9, "err {err} vs step {step}");
            }
        }
    }

    #[test]
    fn property_pipeline_consistent_across_modes() {
        crate::util::proptest::check("golden-modes", 60, |g| {
            let mut cfg = Config::default();
            cfg.enhance = match g.usize_in(0, 3) {
                0 => EnhanceConfig::default(),
                1 => EnhanceConfig::fold_only(),
                2 => EnhanceConfig::boost_only(),
                _ => EnhanceConfig::both(),
            };
            let mut rng = Xoshiro256::seeded(g.case_seed ^ 0xABCD);
            let w: Vec<Vec<i64>> = (0..cfg.mac.rows)
                .map(|_| (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect())
                .collect();
            let acts: Vec<i64> =
                (0..cfg.mac.rows).map(|_| rng.next_range_i64(0, 15)).collect();
            let w = CoreWeights::from_signed(&cfg.mac, &w).unwrap();
            let folded = mac_folded(&cfg, &w, &acts);
            let exact = mac_exact(&w, &acts);
            let step = cfg.mac.adc_lsb_units() / cfg.enhance.dtc_scale();
            for e in 0..cfg.mac.engines {
                // folded must stay within the representable analog range
                crate::prop_assert!(
                    folded[e].abs() <= cfg.mac.mac_range(),
                    "folded {} exceeds range",
                    folded[e]
                );
                if !clips(&cfg, folded[e]) {
                    let recon = reconstruct(&cfg, &w, e, ideal_code(&cfg, folded[e]));
                    let err = (recon - exact[e] as f64).abs();
                    crate::prop_assert!(
                        err <= step / 2.0 + 1e-9,
                        "mode {} engine {e}: err {err} > step/2 {}",
                        cfg.enhance.label(),
                        step / 2.0
                    );
                }
            }
            Ok(())
        });
    }
}
