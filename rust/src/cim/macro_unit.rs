//! The 16 Kb CIM macro facade: 4 cores × 16 engines × 64 rows, weight
//! loading, and the full MAC + readout operation (native backend).

use crate::cim::adc::readout_into;
use crate::cim::engine::{
    mac_phase_batch_into, mac_phase_prepared_into, ActRangeError, BatchKernelScratch,
    KernelScratch, MacPhase, OpStats,
};
use crate::cim::golden;
use crate::cim::noise::{Fabrication, NoiseDraw};
use crate::cim::timing::finalize_cycles;
use crate::cim::weights::{CoreWeights, WeightError};
use crate::config::{Config, MacroConfig};
use crate::util::rng::Rng;

/// Result of one core operation.
#[derive(Clone, Debug, Default)]
pub struct CoreOpResult {
    /// Raw signed ADC codes per engine.
    pub codes: Vec<i32>,
    /// Digitally reconstructed MAC estimates (product units), including the
    /// fold correction.
    pub values: Vec<f64>,
    pub stats: OpStats,
}

/// Reusable per-worker buffers for the allocation-free op path
/// ([`MacroSim::core_op_into`]): the dynamic noise draw, the MAC-phase
/// line-drop vectors, and the bit-plane kernel's prepared activation state.
/// One `OpScratch` per thread; never shared across differently-shaped
/// configurations.
#[derive(Clone, Debug)]
pub struct OpScratch {
    /// The per-op dynamic noise draw (redrawn in place when noise is on).
    pub draw: NoiseDraw,
    phase: MacPhase,
    kernel: KernelScratch,
    /// Batch-transposed activation state (noise-free closed form only).
    batch_kernel: BatchKernelScratch,
    /// Per-item phases of the batched kernel.
    batch_phase: Vec<MacPhase>,
    /// Replay buffer for the batched-prepared fallback path (keeps the warm
    /// loop allocation-free — DESIGN.md §14).
    acts_buf: Vec<i64>,
}

impl OpScratch {
    pub fn new(mac: &MacroConfig) -> Self {
        Self {
            draw: NoiseDraw::zeros(mac),
            phase: MacPhase::default(),
            kernel: KernelScratch::new(mac),
            batch_kernel: BatchKernelScratch::default(),
            batch_phase: Vec::new(),
            acts_buf: Vec::new(),
        }
    }

    /// Intra-op worker threads for the popcount kernels (single-tile and
    /// batched) — see [`KernelScratch::set_workers`]. Bit-identical results
    /// for every worker count; persists across prepares.
    pub fn set_workers(&mut self, workers: usize) {
        self.kernel.set_workers(workers);
        self.batch_kernel.set_workers(workers);
    }

    /// Force the closed form through the PR-3 per-row walk — see
    /// [`KernelScratch::set_row_walk`]. Bench trajectory / test witness only.
    pub fn set_row_walk(&mut self, on: bool) {
        self.kernel.set_row_walk(on);
    }

    /// Pin both kernels (single-tile and batched) to one tier — see
    /// [`KernelScratch::set_tier`] (DESIGN.md §14). Panics on a tier this
    /// host cannot run; persists across prepares.
    pub fn set_tier(&mut self, tier: crate::cim::simd::KernelTier) {
        self.kernel.set_tier(tier);
        self.batch_kernel.set_tier(tier);
    }

    /// The tier the batched kernel is pinned to.
    #[inline]
    pub fn tier(&self) -> crate::cim::simd::KernelTier {
        self.batch_kernel.tier()
    }

    /// Load one activation tile into the kernel scratch (validation, folding,
    /// row masks, nominal pulse widths — see [`KernelScratch::prepare`]).
    /// One preparation serves any number of
    /// [`MacroSim::core_op_prepared_into`] / [`crate::pipeline::MacroPool::op_prepared_into`]
    /// calls on any shard of the same configuration — the batched executors
    /// prepare once per `(batch item, row tile)` and stream every column
    /// tile through it.
    pub fn prepare(&mut self, cfg: &Config, acts: &[i64]) -> Result<(), MacroError> {
        self.kernel
            .prepare(cfg, acts)
            .map_err(|ActRangeError { row, value }| MacroError::BadAct { row, value })
    }

    /// Load a whole batch of activation tiles into the batch-transposed
    /// kernel scratch (DESIGN.md §11). One preparation serves any number of
    /// [`MacroSim::core_op_batch_prepared_into`] /
    /// [`crate::pipeline::MacroPool::op_batch_prepared_into`] calls on any
    /// shard — the batched executors prepare once per row tile and stream
    /// every (item, column tile) pair through it. Noise-free configs only.
    pub fn prepare_batch(&mut self, cfg: &Config, batch: &[Vec<i64>]) -> Result<(), MacroError> {
        self.batch_kernel
            .prepare_batch(cfg, batch)
            .map_err(|ActRangeError { row, value }| MacroError::BadAct { row, value })
    }
}

/// A simulated macro instance: configuration + one static fabrication draw
/// + the resident weights of each core.
pub struct MacroSim {
    pub cfg: Config,
    pub fab: Fabrication,
    weights: Vec<Option<CoreWeights>>,
}

#[derive(Debug)]
pub enum MacroError {
    NoWeights(usize),
    BadCore(usize),
    /// A pool-wide slot id (`shard × cores + core`) with no resident shard.
    BadSlot(usize),
    Weights(WeightError),
    BadAct { row: usize, value: i64 },
}

impl std::fmt::Display for MacroError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MacroError::NoWeights(c) => write!(f, "core {c} has no weights loaded"),
            MacroError::BadCore(c) => write!(f, "core index {c} out of range"),
            MacroError::BadSlot(s) => {
                write!(f, "pool slot {s} is beyond the resident shards")
            }
            MacroError::Weights(e) => write!(f, "{e}"),
            MacroError::BadAct { row, value } => {
                write!(f, "activation {value} at row {row} out of range")
            }
        }
    }
}

impl std::error::Error for MacroError {}

impl From<WeightError> for MacroError {
    fn from(e: WeightError) -> Self {
        MacroError::Weights(e)
    }
}

impl MacroSim {
    pub fn new(cfg: Config) -> Self {
        let fab = Fabrication::draw(&cfg.mac, &cfg.noise);
        let weights = (0..cfg.mac.cores).map(|_| None).collect();
        Self { cfg, fab, weights }
    }

    /// Load signed weights (`[row][engine]`) into one core.
    pub fn load_core(&mut self, core: usize, w: &[Vec<i64>]) -> Result<(), MacroError> {
        if core >= self.cfg.mac.cores {
            return Err(MacroError::BadCore(core));
        }
        self.weights[core] = Some(CoreWeights::from_signed(&self.cfg.mac, w)?);
        Ok(())
    }

    pub fn load_core_weights(&mut self, core: usize, w: CoreWeights) -> Result<(), MacroError> {
        if core >= self.cfg.mac.cores {
            return Err(MacroError::BadCore(core));
        }
        self.weights[core] = Some(w);
        Ok(())
    }

    pub fn core_weights(&self, core: usize) -> Result<&CoreWeights, MacroError> {
        self.weights
            .get(core)
            .ok_or(MacroError::BadCore(core))?
            .as_ref()
            .ok_or(MacroError::NoWeights(core))
    }

    /// Readout + reconstruction tail shared by every op form: readout into
    /// `out.codes`, stats assembly, golden reconstruction into `out.values`.
    /// No allocation when the buffers already have capacity.
    fn finish_op(
        &self,
        core: usize,
        w: &CoreWeights,
        phase: &MacPhase,
        draw: &NoiseDraw,
        out: &mut CoreOpResult,
    ) {
        let (adc_discharge_u, sa_compares) =
            readout_into(&self.cfg, core, phase, &self.fab, draw, &mut out.codes);
        out.stats = phase.stats.clone();
        out.stats.adc_discharge_u = adc_discharge_u;
        out.stats.sa_compares = sa_compares;
        finalize_cycles(&self.cfg, &mut out.stats);
        out.values.clear();
        for (e, &c) in out.codes.iter().enumerate() {
            out.values.push(golden::reconstruct(&self.cfg, w, e, c));
        }
    }

    /// One core operation with an explicit noise draw (the form shared with
    /// the XLA backend — identical draws give identical results).
    pub fn core_op_with_noise(
        &self,
        core: usize,
        acts: &[i64],
        draw: &NoiseDraw,
    ) -> Result<CoreOpResult, MacroError> {
        let w = self.core_weights(core)?;
        let mut kernel = KernelScratch::new(&self.cfg.mac);
        kernel
            .prepare(&self.cfg, acts)
            .map_err(|ActRangeError { row, value }| MacroError::BadAct { row, value })?;
        let mut phase = MacPhase::default();
        let mut out = CoreOpResult::default();
        mac_phase_prepared_into(&self.cfg, core, w, &self.fab, draw, &mut kernel, &mut phase);
        self.finish_op(core, w, &phase, draw, &mut out);
        Ok(out)
    }

    /// Zero-allocation hot path for the batched pipeline: redraws the
    /// scratch's noise in place (when noise is on), prepares the bit-plane
    /// kernel for this activation tile, and writes codes/values/stats into
    /// `out`. Identical results to [`MacroSim::core_op`] given the same RNG
    /// state.
    pub fn core_op_into<R: Rng>(
        &self,
        core: usize,
        acts: &[i64],
        rng: &mut R,
        scratch: &mut OpScratch,
        out: &mut CoreOpResult,
    ) -> Result<(), MacroError> {
        if self.cfg.noise.enabled {
            scratch.draw.redraw(rng);
        }
        let w = self.core_weights(core)?;
        scratch.prepare(&self.cfg, acts)?;
        mac_phase_prepared_into(
            &self.cfg,
            core,
            w,
            &self.fab,
            &scratch.draw,
            &mut scratch.kernel,
            &mut scratch.phase,
        );
        self.finish_op(core, w, &scratch.phase, &scratch.draw, out);
        Ok(())
    }

    /// One op against the scratch's previously [`OpScratch::prepare`]d
    /// activation tile: the per-op cost is just the (optional) noise redraw
    /// plus the engine-major kernel walk. The batched executors call this
    /// once per column tile after a single preparation per row tile.
    pub fn core_op_prepared_into<R: Rng>(
        &self,
        core: usize,
        rng: &mut R,
        scratch: &mut OpScratch,
        out: &mut CoreOpResult,
    ) -> Result<(), MacroError> {
        if self.cfg.noise.enabled {
            scratch.draw.redraw(rng);
        }
        let w = self.core_weights(core)?;
        mac_phase_prepared_into(
            &self.cfg,
            core,
            w,
            &self.fab,
            &scratch.draw,
            &mut scratch.kernel,
            &mut scratch.phase,
        );
        self.finish_op(core, w, &scratch.phase, &scratch.draw, out);
        Ok(())
    }

    /// Batched form of [`MacroSim::core_op_into`]: streams a whole batch of
    /// activation vectors through one resident core, reusing the scratch and
    /// growing `outs` in place (`outs[i]` is the result of `batch[i]`).
    /// Draw-for-draw identical to calling `core_op_into` in a loop with the
    /// same RNG, so noisy results match the sequential path bit for bit.
    ///
    /// Noise-free under the closed-form envelope with an ideal fabrication,
    /// the whole batch runs through one transposed preparation and the
    /// popcount batch kernel (DESIGN.md §11) — per-item results stay
    /// bit-identical, and no RNG draws are consumed either way.
    pub fn core_op_batch_into<R: Rng>(
        &self,
        core: usize,
        batch: &[Vec<i64>],
        rng: &mut R,
        scratch: &mut OpScratch,
        outs: &mut Vec<CoreOpResult>,
    ) -> Result<(), MacroError> {
        if KernelScratch::closed_form_capable(&self.cfg)
            && self.fab.is_ideal()
            && scratch.batch_kernel.tier().batched()
        {
            return self.core_op_batch_closed_form(core, batch, scratch, outs);
        }
        outs.resize_with(batch.len(), CoreOpResult::default);
        for (acts, out) in batch.iter().zip(outs.iter_mut()) {
            if self.cfg.noise.enabled {
                scratch.draw.redraw(rng);
            }
            // Weights are resolved per item (a cheap index) rather than
            // hoisted, so even the error paths consume RNG draws exactly
            // like a loop of `core_op_into` (redraw precedes the lookup).
            let w = self.core_weights(core)?;
            scratch.prepare(&self.cfg, acts)?;
            mac_phase_prepared_into(
                &self.cfg,
                core,
                w,
                &self.fab,
                &scratch.draw,
                &mut scratch.kernel,
                &mut scratch.phase,
            );
            self.finish_op(core, w, &scratch.phase, &scratch.draw, out);
        }
        Ok(())
    }

    /// Closed-form batch op: one transposed preparation + the popcount batch
    /// kernel + the per-item op tail, in item order. Caller guarantees the
    /// closed-form envelope and an ideal fabrication.
    fn core_op_batch_closed_form(
        &self,
        core: usize,
        batch: &[Vec<i64>],
        scratch: &mut OpScratch,
        outs: &mut Vec<CoreOpResult>,
    ) -> Result<(), MacroError> {
        outs.resize_with(batch.len(), CoreOpResult::default);
        let w = self.core_weights(core)?;
        scratch
            .batch_kernel
            .prepare_batch(&self.cfg, batch)
            .map_err(|ActRangeError { row, value }| MacroError::BadAct { row, value })?;
        scratch.batch_phase.resize_with(batch.len(), MacPhase::default);
        mac_phase_batch_into(&self.cfg, w, &self.fab, &scratch.batch_kernel, &mut scratch.batch_phase);
        for (phase, out) in scratch.batch_phase.iter().zip(outs.iter_mut()) {
            self.finish_op(core, w, phase, &scratch.draw, out);
        }
        Ok(())
    }

    /// Batched op against the scratch's previously
    /// [`OpScratch::prepare_batch`]ed activation tiles: the closed-form
    /// popcount batch kernel when the envelope holds and the fabrication is
    /// ideal, else a per-item re-preparation through the general walk (the
    /// stored tiles are replayed, so results still match the sequential
    /// prepared path bit for bit). Noise-free configs only — noise draws are
    /// keyed per (item, tile) by the executors and cannot be replayed from a
    /// batched op.
    pub fn core_op_batch_prepared_into(
        &self,
        core: usize,
        scratch: &mut OpScratch,
        outs: &mut Vec<CoreOpResult>,
    ) -> Result<(), MacroError> {
        assert!(
            !self.cfg.noise.enabled,
            "batched prepared ops are noise-free only (per-item noise streams)"
        );
        let w = self.core_weights(core)?;
        let b = scratch.batch_kernel.batch();
        outs.resize_with(b, CoreOpResult::default);
        if scratch.batch_kernel.closed_form() && self.fab.is_ideal() {
            scratch.batch_phase.resize_with(b, MacPhase::default);
            mac_phase_batch_into(
                &self.cfg,
                w,
                &self.fab,
                &scratch.batch_kernel,
                &mut scratch.batch_phase,
            );
            for (phase, out) in scratch.batch_phase.iter().zip(outs.iter_mut()) {
                self.finish_op(core, w, phase, &scratch.draw, out);
            }
            return Ok(());
        }
        // Fallback (noise-free but non-ideal fab, non-dyadic gains, or a
        // non-batched tier pin): replay each stored tile through the
        // single-tile prepared path. The replay goes through the scratch's
        // reused buffer, not a fresh Vec — the warm loop stays
        // allocation-free (DESIGN.md §14).
        for i in 0..b {
            let OpScratch { draw, phase, kernel, batch_kernel, acts_buf, .. } = scratch;
            acts_buf.clear();
            acts_buf.extend_from_slice(batch_kernel.item_acts(i));
            kernel
                .prepare(&self.cfg, acts_buf)
                .map_err(|ActRangeError { row, value }| MacroError::BadAct { row, value })?;
            mac_phase_prepared_into(&self.cfg, core, w, &self.fab, draw, kernel, phase);
            self.finish_op(core, w, phase, draw, &mut outs[i]);
        }
        Ok(())
    }

    /// One core operation, drawing fresh dynamic noise from `rng`.
    pub fn core_op<R: Rng>(
        &self,
        core: usize,
        acts: &[i64],
        rng: &mut R,
    ) -> Result<CoreOpResult, MacroError> {
        let draw = if self.cfg.noise.enabled {
            NoiseDraw::draw(&self.cfg.mac, rng)
        } else {
            NoiseDraw::zeros(&self.cfg.mac)
        };
        self.core_op_with_noise(core, acts, &draw)
    }

    /// Full macro operation: every loaded core fires in parallel on its own
    /// activation vector. Returns per-core results in core order.
    pub fn macro_op<R: Rng>(
        &self,
        acts_per_core: &[Vec<i64>],
        rng: &mut R,
    ) -> Result<Vec<CoreOpResult>, MacroError> {
        assert_eq!(acts_per_core.len(), self.cfg.mac.cores);
        let mut out = Vec::with_capacity(self.cfg.mac.cores);
        for (c, acts) in acts_per_core.iter().enumerate() {
            out.push(self.core_op(c, acts, rng)?);
        }
        Ok(out)
    }

    /// Exact digital reference for a loaded core.
    pub fn golden(&self, core: usize, acts: &[i64]) -> Result<Vec<i64>, MacroError> {
        Ok(golden::mac_exact(self.core_weights(core)?, acts))
    }

    /// Ideal (noise-free chip) codes for a loaded core.
    pub fn ideal_codes(&self, core: usize, acts: &[i64]) -> Result<Vec<i32>, MacroError> {
        let w = self.core_weights(core)?;
        Ok(golden::mac_folded(&self.cfg, w, acts)
            .iter()
            .map(|&d| golden::ideal_code(&self.cfg, d))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, EnhanceConfig};
    use crate::util::rng::Xoshiro256;

    fn random_weights(cfg: &Config, seed: u64) -> Vec<Vec<i64>> {
        let mut rng = Xoshiro256::seeded(seed);
        (0..cfg.mac.rows)
            .map(|_| (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect())
            .collect()
    }

    fn random_acts(cfg: &Config, seed: u64) -> Vec<i64> {
        let mut rng = Xoshiro256::seeded(seed.wrapping_mul(31));
        (0..cfg.mac.rows).map(|_| rng.next_range_i64(0, 15)).collect()
    }

    /// With noise disabled the full analog pipeline must agree with the
    /// ideal-code golden model EXACTLY, in every enhancement mode.
    #[test]
    fn noise_free_pipeline_matches_golden_all_modes() {
        for enh in [
            EnhanceConfig::default(),
            EnhanceConfig::fold_only(),
            EnhanceConfig::boost_only(),
            EnhanceConfig::both(),
        ] {
            let mut cfg = Config::default();
            cfg.noise.enabled = false;
            cfg.enhance = enh;
            let mut sim = MacroSim::new(cfg.clone());
            sim.load_core(0, &random_weights(&cfg, 11)).unwrap();
            let mut rng = Xoshiro256::seeded(5);
            for t in 0..50 {
                let acts = random_acts(&cfg, t);
                let got = sim.core_op(0, &acts, &mut rng).unwrap();
                let want = sim.ideal_codes(0, &acts).unwrap();
                assert_eq!(got.codes, want, "mode {} trial {t}", cfg.enhance.label());
            }
        }
    }

    #[test]
    fn reconstruction_tracks_exact_mac() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::fold_only();
        let mut sim = MacroSim::new(cfg.clone());
        sim.load_core(0, &random_weights(&cfg, 3)).unwrap();
        let acts = random_acts(&cfg, 9);
        let mut rng = Xoshiro256::seeded(1);
        let got = sim.core_op(0, &acts, &mut rng).unwrap();
        let exact = sim.golden(0, &acts).unwrap();
        let step = cfg.mac.adc_lsb_units() / cfg.enhance.dtc_scale(); // 14 units
        for e in 0..cfg.mac.engines {
            let err = (got.values[e] - exact[e] as f64).abs();
            assert!(err <= step / 2.0 + 1e-9, "engine {e}: err {err}");
        }
    }

    #[test]
    fn unloaded_core_and_bad_inputs_error() {
        let cfg = Config::default();
        let sim = MacroSim::new(cfg.clone());
        let acts = vec![0i64; cfg.mac.rows];
        assert!(matches!(sim.core_op_with_noise(0, &acts, &NoiseDraw::zeros(&cfg.mac)),
            Err(MacroError::NoWeights(0))));
        let mut sim = MacroSim::new(cfg.clone());
        sim.load_core(0, &random_weights(&cfg, 1)).unwrap();
        let mut bad = acts.clone();
        bad[7] = 16;
        assert!(matches!(
            sim.core_op_with_noise(0, &bad, &NoiseDraw::zeros(&cfg.mac)),
            Err(MacroError::BadAct { row: 7, value: 16 })
        ));
        assert!(matches!(sim.load_core(9, &random_weights(&cfg, 1)), Err(MacroError::BadCore(9))));
    }

    #[test]
    fn macro_op_runs_all_cores() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let mut sim = MacroSim::new(cfg.clone());
        for c in 0..cfg.mac.cores {
            sim.load_core(c, &random_weights(&cfg, c as u64)).unwrap();
        }
        let acts: Vec<Vec<i64>> = (0..cfg.mac.cores)
            .map(|c| random_acts(&cfg, 100 + c as u64))
            .collect();
        let mut rng = Xoshiro256::seeded(2);
        let res = sim.macro_op(&acts, &mut rng).unwrap();
        assert_eq!(res.len(), 4);
        for (c, r) in res.iter().enumerate() {
            assert_eq!(r.codes, sim.ideal_codes(c, &acts[c]).unwrap());
            assert_eq!(r.stats.sa_compares, 16 * 9);
            assert!(r.stats.total_cycles >= 11);
        }
    }

    /// The batched core-op path consumes the RNG draw-for-draw like the
    /// sequential per-op path: same seed ⇒ bit-identical results.
    #[test]
    fn batched_core_ops_match_sequential_rng_stream() {
        for noise in [false, true] {
            let mut cfg = Config::default();
            cfg.noise.enabled = noise;
            cfg.enhance = EnhanceConfig::both();
            let mut sim = MacroSim::new(cfg.clone());
            sim.load_core(2, &random_weights(&cfg, 13)).unwrap();
            let batch: Vec<Vec<i64>> = (0..6).map(|t| random_acts(&cfg, 50 + t)).collect();

            let mut rng_a = Xoshiro256::seeded(99);
            let mut scratch_a = OpScratch::new(&cfg.mac);
            let mut seq = Vec::new();
            for acts in &batch {
                let mut out = CoreOpResult::default();
                sim.core_op_into(2, acts, &mut rng_a, &mut scratch_a, &mut out).unwrap();
                seq.push(out);
            }

            let mut rng_b = Xoshiro256::seeded(99);
            let mut scratch_b = OpScratch::new(&cfg.mac);
            let mut outs = Vec::new();
            sim.core_op_batch_into(2, &batch, &mut rng_b, &mut scratch_b, &mut outs).unwrap();
            assert_eq!(outs.len(), seq.len());
            for (i, (a, b)) in seq.iter().zip(&outs).enumerate() {
                assert_eq!(a.codes, b.codes, "noise={noise} item {i}");
                assert_eq!(a.values, b.values, "noise={noise} item {i}");
                assert_eq!(a.stats, b.stats, "noise={noise} item {i}");
            }
        }
    }

    /// `prepare` once + prepared ops across shards/cores equals the
    /// self-preparing op form (the pipeline's per-row-tile amortization).
    #[test]
    fn prepared_op_reuse_across_cores() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::fold_only();
        let mut sim = MacroSim::new(cfg.clone());
        for c in 0..cfg.mac.cores {
            sim.load_core(c, &random_weights(&cfg, 70 + c as u64)).unwrap();
        }
        let acts = random_acts(&cfg, 5);
        let mut rng = Xoshiro256::seeded(4);
        let mut scratch = OpScratch::new(&cfg.mac);
        scratch.prepare(&cfg, &acts).unwrap();
        let mut out = CoreOpResult::default();
        for c in 0..cfg.mac.cores {
            sim.core_op_prepared_into(c, &mut rng, &mut scratch, &mut out).unwrap();
            let want = sim.core_op(c, &acts, &mut rng).unwrap();
            assert_eq!(out.codes, want.codes, "core {c}");
            assert_eq!(out.values, want.values, "core {c}");
        }
    }

    #[test]
    fn same_noise_draw_is_reproducible() {
        let cfg = Config::default();
        let mut sim = MacroSim::new(cfg.clone());
        sim.load_core(0, &random_weights(&cfg, 5)).unwrap();
        let acts = random_acts(&cfg, 5);
        let mut rng = Xoshiro256::seeded(77);
        let draw = NoiseDraw::draw(&cfg.mac, &mut rng);
        let a = sim.core_op_with_noise(0, &acts, &draw).unwrap();
        let b = sim.core_op_with_noise(0, &acts, &draw).unwrap();
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.stats, b.stats);
    }

    /// Statistical sanity: with default noise the measured codes stay close
    /// to ideal (within a few LSB) — full calibration is tested in harness.
    #[test]
    fn noisy_codes_near_ideal() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both();
        let mut sim = MacroSim::new(cfg.clone());
        sim.load_core(0, &random_weights(&cfg, 21)).unwrap();
        let mut rng = Xoshiro256::seeded(9);
        let mut worst = 0i32;
        for t in 0..100 {
            let acts = random_acts(&cfg, 1000 + t);
            let got = sim.core_op(0, &acts, &mut rng).unwrap();
            let want = sim.ideal_codes(0, &acts).unwrap();
            for e in 0..cfg.mac.engines {
                worst = worst.max((got.codes[e] - want[e]).abs());
            }
        }
        assert!(worst <= 40, "worst code error {worst} implausibly large");
        assert!(worst >= 1, "noise should perturb at least one code");
    }
}
