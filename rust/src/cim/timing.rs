//! Cycle/throughput model (DESIGN.md §3). One macro op =
//! 1 precharge cycle + MAC phase (pulse-width dependent) + `adc_bits`
//! readout cycles. The Fig. 6 throughput range (6.82–8.53 GOPS/Kb) emerges
//! from the activation-magnitude dependence of the MAC phase.

use crate::cim::engine::OpStats;
use crate::config::Config;

/// Total cycles for a core op with the given MAC-phase cycle count.
#[inline]
pub fn op_cycles(cfg: &Config, mac_cycles: u64) -> u64 {
    1 + mac_cycles + cfg.mac.adc_bits as u64
}

/// Fill `stats.total_cycles` from its MAC-phase fields.
pub fn finalize_cycles(cfg: &Config, stats: &mut OpStats) {
    stats.total_cycles = op_cycles(cfg, stats.mac_cycles);
}

/// Seconds for `cycles` at the configured clock.
#[inline]
pub fn cycles_to_seconds(cfg: &Config, cycles: u64) -> f64 {
    cycles as f64 / (cfg.mac.clock_mhz * 1e6)
}

/// Throughput in GOPS for one macro op (all cores fire together) that took
/// `cycles` clock cycles.
pub fn gops(cfg: &Config, cycles: u64) -> f64 {
    let ops = cfg.mac.ops_per_op() as f64;
    ops / cycles_to_seconds(cfg, cycles) / 1e9
}

/// Memory-normalized throughput, GOPS/Kb (the Fig. 6 metric).
pub fn gops_per_kb(cfg: &Config, cycles: u64) -> f64 {
    gops(cfg, cycles) / cfg.mac.macro_kb()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn paper_throughput_range_emerges() {
        let cfg = Config::default(); // 200 MHz
        // Dense 4-b inputs: widest pulse 15·4 = 60 τ0 → 5 MAC cycles →
        // 15 total → 6.82 GOPS/Kb (paper's lower bound).
        let dense = op_cycles(&cfg, crate::cim::engine::mac_cycles(&cfg, 60.0));
        assert_eq!(dense, 15);
        let g = gops_per_kb(&cfg, dense);
        assert!((g - 6.826).abs() < 0.01, "dense {g}");
        // Small-activation inputs (≤3): widest 12 τ0 → 2 MAC cycles →
        // 12 total → 8.53 GOPS/Kb (paper's upper bound).
        let sparse = op_cycles(&cfg, crate::cim::engine::mac_cycles(&cfg, 12.0));
        assert_eq!(sparse, 12);
        let g = gops_per_kb(&cfg, sparse);
        assert!((g - 8.533).abs() < 0.01, "sparse {g}");
    }

    #[test]
    fn gops_scales_with_clock() {
        let mut cfg = Config::default();
        let at200 = gops(&cfg, 15);
        cfg.mac.clock_mhz = 100.0;
        let at100 = gops(&cfg, 15);
        assert!((at200 / at100 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn seconds_conversion() {
        let cfg = Config::default();
        // 200 MHz → 5 ns per cycle.
        assert!((cycles_to_seconds(&cfg, 1) - 5e-9).abs() < 1e-15);
    }
}
