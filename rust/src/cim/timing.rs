//! Cycle/throughput model (DESIGN.md §3). One macro op =
//! 1 precharge cycle + MAC phase (pulse-width dependent) + `adc_bits`
//! readout cycles. The Fig. 6 throughput range (6.82–8.53 GOPS/Kb) emerges
//! from the activation-magnitude dependence of the MAC phase. All
//! functions take the hardware point (`&HwSpec`); a `&Config` coerces.

use crate::cim::engine::OpStats;
use crate::config::HwSpec;

/// Total cycles for a core op with the given MAC-phase cycle count.
#[inline]
pub fn op_cycles(cfg: &HwSpec, mac_cycles: u64) -> u64 {
    1 + mac_cycles + cfg.mac.adc_bits as u64
}

/// Fill `stats.total_cycles` from its MAC-phase fields.
pub fn finalize_cycles(cfg: &HwSpec, stats: &mut OpStats) {
    stats.total_cycles = op_cycles(cfg, stats.mac_cycles);
}

/// Exact cycle count of one core op given its (padded, rows-long) unsigned
/// activation tile — the compiler's cost-model primitive.
///
/// The controller allots the MAC window from the *programmed* DTC codes
/// (nominal pulse widths), so the cycle count depends only on the
/// activations and the configuration — never on the noise realization.
/// This mirrors `engine::mac_phase_into` width accounting exactly: every
/// row whose folded activation is non-zero pulses, and the widest pulse is
/// the top weight-bit SL of the largest effective magnitude.
pub fn op_cycles_for_acts(cfg: &HwSpec, acts: &[i64]) -> u64 {
    let kbits = (cfg.mac.weight_bits as usize).saturating_sub(1);
    let s = cfg.enhance.dtc_scale();
    let mut wmax = 0.0f64;
    if kbits > 0 {
        let top = (1u64 << (kbits - 1)) as f64;
        for &a in acts {
            let eff = crate::cim::engine::effective_act(cfg, a);
            if eff != 0 {
                wmax = wmax.max(eff.unsigned_abs() as f64 * top * s);
            }
        }
    }
    op_cycles(cfg, crate::cim::engine::mac_cycles(cfg, wmax))
}

/// Cycles to (re)program one core's weight array: the SRAM writes one full
/// word-line row (16 engines × 4-b sign-magnitude cells) per clock cycle,
/// so a core reload costs `rows` cycles. This is the reload-cycle primitive
/// of the dynamic-weight execution path (DESIGN.md §10): a weight swap on a
/// placed tile charges `weight_load_cycles` to the device total, exactly
/// like a MAC op charges [`op_cycles`].
#[inline]
pub fn weight_load_cycles(cfg: &HwSpec) -> u64 {
    cfg.mac.rows as u64
}

/// Seconds for `cycles` at the configured clock.
#[inline]
pub fn cycles_to_seconds(cfg: &HwSpec, cycles: u64) -> f64 {
    cycles as f64 / (cfg.mac.clock_mhz * 1e6)
}

/// Throughput in GOPS for one macro op (all cores fire together) that took
/// `cycles` clock cycles.
pub fn gops(cfg: &HwSpec, cycles: u64) -> f64 {
    let ops = cfg.mac.ops_per_op() as f64;
    ops / cycles_to_seconds(cfg, cycles) / 1e9
}

/// Memory-normalized throughput, GOPS/Kb (the Fig. 6 metric).
pub fn gops_per_kb(cfg: &HwSpec, cycles: u64) -> f64 {
    gops(cfg, cycles) / cfg.mac.macro_kb()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn paper_throughput_range_emerges() {
        let cfg = Config::default(); // 200 MHz
        // Dense 4-b inputs: widest pulse 15·4 = 60 τ0 → 5 MAC cycles →
        // 15 total → 6.82 GOPS/Kb (paper's lower bound).
        let dense = op_cycles(&cfg, crate::cim::engine::mac_cycles(&cfg, 60.0));
        assert_eq!(dense, 15);
        let g = gops_per_kb(&cfg, dense);
        assert!((g - 6.826).abs() < 0.01, "dense {g}");
        // Small-activation inputs (≤3): widest 12 τ0 → 2 MAC cycles →
        // 12 total → 8.53 GOPS/Kb (paper's upper bound).
        let sparse = op_cycles(&cfg, crate::cim::engine::mac_cycles(&cfg, 12.0));
        assert_eq!(sparse, 12);
        let g = gops_per_kb(&cfg, sparse);
        assert!((g - 8.533).abs() < 0.01, "sparse {g}");
    }

    #[test]
    fn gops_scales_with_clock() {
        let mut cfg = Config::default();
        let at200 = gops(&cfg, 15);
        cfg.mac.clock_mhz = 100.0;
        let at100 = gops(&cfg, 15);
        assert!((at200 / at100 - 2.0).abs() < 1e-9);
    }

    /// The activation-based predictor reproduces the device's own cycle
    /// accounting exactly — noise-free and noisy (nominal-width invariant),
    /// in every enhancement mode.
    #[test]
    fn op_cycles_for_acts_matches_device() {
        use crate::cim::MacroSim;
        use crate::config::EnhanceConfig;
        use crate::util::rng::{Rng, Xoshiro256};
        for noise in [false, true] {
            for enh in [
                EnhanceConfig::default(),
                EnhanceConfig::fold_only(),
                EnhanceConfig::boost_only(),
                EnhanceConfig::both(),
            ] {
                let mut cfg = Config::default();
                cfg.noise.enabled = noise;
                cfg.enhance = enh;
                let mut sim = MacroSim::new(cfg.clone());
                let mut rng = Xoshiro256::seeded(31);
                let w: Vec<Vec<i64>> = (0..cfg.mac.rows)
                    .map(|_| {
                        (0..cfg.mac.engines).map(|_| rng.next_range_i64(-7, 7)).collect()
                    })
                    .collect();
                sim.load_core(0, &w).unwrap();
                for t in 0..12u64 {
                    // Include all-zero and sparse tiles (padding patterns).
                    let acts: Vec<i64> = (0..cfg.mac.rows)
                        .map(|r| {
                            if t == 0 || r % 3 == 0 {
                                0
                            } else {
                                rng.next_range_i64(0, 15)
                            }
                        })
                        .collect();
                    let got = sim.core_op(0, &acts, &mut rng).unwrap();
                    assert_eq!(
                        got.stats.total_cycles,
                        op_cycles_for_acts(&cfg, &acts),
                        "noise={noise} mode={} t={t}",
                        cfg.enhance.label()
                    );
                }
            }
        }
    }

    #[test]
    fn seconds_conversion() {
        let cfg = Config::default();
        // 200 MHz → 5 ns per cycle.
        assert!((cycles_to_seconds(&cfg, 1) - 5e-9).abs() < 1e-15);
    }
}
