//! Behavioral model of the paper's 16 Kb SRAM CIM macro: time-modulated
//! discharge MAC, memory cell-embedded binary-search ADC, MAC-folding and
//! boosted-clipping signal-margin enhancements, plus the exact digital
//! golden reference. See DESIGN.md §3 for the unit conventions and noise
//! model, and DESIGN.md §4 for the two MAC-phase kernels: the reference
//! scalar loop (`engine::mac_phase_into`) and the bit-plane fast path
//! (`engine::mac_phase_prepared_into` over `weights::BitPlanes`), which are
//! bit-identical by construction and property-tested against each other in
//! `tests/kernel_equivalence.rs`.

pub mod adc;
pub mod engine;
pub mod golden;
pub mod macro_unit;
pub mod noise;
pub mod simd;
pub mod timing;
pub mod weights;

pub use engine::{BatchKernelScratch, KernelScratch, OpStats};
pub use macro_unit::{CoreOpResult, MacroError, MacroSim, OpScratch};
pub use noise::{Fabrication, NoiseDraw};
pub use simd::KernelTier;
pub use weights::{BitPlanes, CoreWeights};

/// Signal-margin metrics (Fig. 2 right): SM = step − 2σ′ with the step in
/// volts (u) and σ′ the measured MAC-result noise standard deviation in u.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SignalMargin {
    /// Effective MAC step n·μ0 in u (one output code worth of voltage).
    pub step_u: f64,
    /// Measured noise σ′ in u.
    pub sigma_u: f64,
}

impl SignalMargin {
    pub fn margin_u(&self) -> f64 {
        self.step_u - 2.0 * self.sigma_u
    }

    /// Positive margin ⇒ a 2σ noise excursion cannot flip an output code.
    pub fn is_safe(&self) -> bool {
        self.margin_u() > 0.0
    }
}

/// The MAC step for a configuration: ADC LSB referred to the bit-line, which
/// grows with the DTC scale (×1.875 fold, ×2 boost) — the quantity the
/// paper's enhancement techniques enlarge.
pub fn mac_step_u(cfg: &crate::config::Config) -> f64 {
    // One output code spans lsb_u of differential voltage; per *product
    // unit* the analog signal is s·u, so in signal-referred terms the step
    // stays lsb_u — the enhancement gain appears as more volts per unit of
    // MAC dynamic range. We report the paper's definition:
    // step = VPP / (MAC dynamic range expressed in codes).
    cfg.mac.adc_lsb_units()
}

/// Volts (u) of bit-line signal per unit of folded MAC value — the "MAC step
/// size n·μ0" axis of Fig. 2/4: larger is better for signal margin.
pub fn step_per_unit_u(cfg: &crate::config::Config) -> f64 {
    cfg.enhance.dtc_scale()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, EnhanceConfig};

    #[test]
    fn fold_enlarges_step_by_1_87x() {
        let mut base = Config::default();
        base.enhance = EnhanceConfig::default();
        let mut fold = Config::default();
        fold.enhance = EnhanceConfig::fold_only();
        let ratio = step_per_unit_u(&fold) / step_per_unit_u(&base);
        assert!((ratio - 1.875).abs() < 1e-12, "paper: 1.87×, exact 1.875");
    }

    #[test]
    fn boost_doubles_step_on_top() {
        let mut fold = Config::default();
        fold.enhance = EnhanceConfig::fold_only();
        let mut both = Config::default();
        both.enhance = EnhanceConfig::both();
        assert!((step_per_unit_u(&both) / step_per_unit_u(&fold) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn signal_margin_sign() {
        let safe = SignalMargin { step_u: 26.25, sigma_u: 10.0 };
        assert!(safe.is_safe());
        let unsafe_ = SignalMargin { step_u: 26.25, sigma_u: 14.0 };
        assert!(!unsafe_.is_safe());
        assert!((safe.margin_u() - 6.25).abs() < 1e-12);
    }
}
