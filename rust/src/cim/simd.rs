//! Runtime-dispatched SIMD tiers for the bit-plane popcount MAC kernel
//! (DESIGN.md §14).
//!
//! The closed-form kernel (DESIGN.md §11) reduces every (act-bit `j`,
//! weight-bit `k`) plane pair to two horizontal popcounts over the same
//! word stream: `total = Σ popcount(a ∧ w)` and `diff = Σ popcount(a ∧ w ∧
//! x)`, where `x` is the per-engine XOR of the activation-sign and
//! weight-sign masks (a set bit = the signs disagree, so the product
//! discharges RBLB). [`and_popcount_split`] is that fused primitive, in
//! several implementations — "tiers" — selected once per process:
//!
//! | tier       | implementation                              | availability |
//! |------------|---------------------------------------------|--------------|
//! | `scalar`   | general pulse walk (closed form disabled)   | always       |
//! | `walk`     | PR-3 per-row `trailing_zeros` walk          | always       |
//! | `popcount` | per-word `u64::count_ones` loop (PR 6)      | always       |
//! | `swar`     | batched SWAR nibble counts, Harley-Seal-style deferred reduction | always |
//! | `avx2`     | Muła nibble-LUT `vpshufb` + `vpsadbw`       | x86-64 with AVX2 |
//! | `avx512`   | `vpopcntq` (AVX-512 VPOPCNTDQ)              | x86-64 with AVX512F+VPOPCNTDQ, `avx512` cargo feature |
//! | `neon`     | `vcnt.8` + widening pairwise adds           | aarch64      |
//!
//! Every tier accumulates the same integer partials in exact integer
//! arithmetic — reassociating a sum of popcounts is exact, unlike f64 — so
//! the final scaled f64 expressions of DESIGN.md §11 are unchanged and all
//! tiers are bit-identical to the scalar oracle (property-tested in
//! `tests/kernel_equivalence.rs`).
//!
//! Dispatch: [`kernel_tier`] resolves once per process — the
//! `CIMSIM_KERNEL` environment variable when set (failing fast on an
//! unknown or unavailable tier; no silent fallback), best-available
//! detection via `is_x86_feature_detected!` otherwise — caches the choice,
//! and publishes it as the `cim_kernel_tier` info gauge. Individual
//! scratches can still be pinned to any *available* tier with `set_tier`
//! (the bench sweep and the equivalence suite use this).

use std::sync::OnceLock;

/// Longest per-engine word run the kernel routes through the SIMD tiers
/// using a stack-allocated XOR-stream buffer (64 words = 4096 rows, far
/// above any configured geometry). Longer runs fall back to the per-word
/// popcount arm rather than allocating on the hot path.
pub const MAX_RUN_WORDS: usize = 64;

/// One implementation tier of the MAC kernel. `Scalar`/`Walk`/`Popcount`
/// name the pre-existing kernel arms (general walk, PR-3 row walk, PR-6
/// per-word popcount); the rest select [`and_popcount_split`] backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelTier {
    Scalar,
    Walk,
    Popcount,
    Swar,
    Avx2,
    Avx512,
    Neon,
}

impl KernelTier {
    pub const ALL: [KernelTier; 7] = [
        KernelTier::Scalar,
        KernelTier::Walk,
        KernelTier::Popcount,
        KernelTier::Swar,
        KernelTier::Avx2,
        KernelTier::Avx512,
        KernelTier::Neon,
    ];

    /// Stable lowercase name — the `CIMSIM_KERNEL` value, the telemetry
    /// gauge label, and the bench-row `kernel` field.
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Walk => "walk",
            KernelTier::Popcount => "popcount",
            KernelTier::Swar => "swar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Avx512 => "avx512",
            KernelTier::Neon => "neon",
        }
    }

    /// Whether the tier evaluates the closed-form integer path at all
    /// (`scalar` deliberately disables it to force the general pulse walk).
    #[inline]
    pub fn closed_form(self) -> bool {
        !matches!(self, KernelTier::Scalar)
    }

    /// Whether the tier supports the batch-transposed kernel
    /// (`mac_phase_batch_into`); the row walk has no batched arm.
    #[inline]
    pub fn batched(self) -> bool {
        !matches!(self, KernelTier::Scalar | KernelTier::Walk)
    }

    /// Whether the tier routes plane pairs through [`and_popcount_split`]
    /// word runs (as opposed to the named pre-existing kernel arms).
    #[inline]
    pub fn simd(self) -> bool {
        matches!(
            self,
            KernelTier::Swar | KernelTier::Avx2 | KernelTier::Avx512 | KernelTier::Neon
        )
    }

    /// Whether this tier can run on this host *as compiled* (CPU features,
    /// target architecture, cargo features, Miri).
    pub fn available(self) -> bool {
        match self {
            KernelTier::Scalar | KernelTier::Walk | KernelTier::Popcount | KernelTier::Swar => {
                true
            }
            KernelTier::Avx2 => hw_avx2(),
            KernelTier::Avx512 => hw_avx512(),
            KernelTier::Neon => hw_neon(),
        }
    }

    /// Human-readable reason a tier is unavailable (used by the fail-fast
    /// override error). Meaningless for available tiers.
    pub fn unavailable_reason(self) -> &'static str {
        if cfg!(miri) && self.simd() && !matches!(self, KernelTier::Swar) {
            return "hardware SIMD tiers are disabled under Miri";
        }
        match self {
            KernelTier::Avx2 => "host CPU does not report AVX2",
            KernelTier::Avx512 if cfg!(feature = "avx512") => {
                "host CPU does not report AVX-512F + VPOPCNTDQ"
            }
            KernelTier::Avx512 => "built without the `avx512` cargo feature",
            KernelTier::Neon => "NEON requires an aarch64 host",
            _ => "always available",
        }
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelTier {
    type Err = ();

    fn from_str(s: &str) -> Result<Self, ()> {
        let s = s.trim().to_ascii_lowercase();
        KernelTier::ALL
            .iter()
            .copied()
            .find(|t| t.name() == s)
            .ok_or(())
    }
}

#[cfg(all(target_arch = "x86_64", not(miri)))]
fn hw_avx2() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(not(all(target_arch = "x86_64", not(miri))))]
fn hw_avx2() -> bool {
    false
}

#[cfg(all(target_arch = "x86_64", feature = "avx512", not(miri)))]
fn hw_avx512() -> bool {
    std::is_x86_feature_detected!("avx512f")
        && std::is_x86_feature_detected!("avx512vpopcntdq")
}

#[cfg(not(all(target_arch = "x86_64", feature = "avx512", not(miri))))]
fn hw_avx512() -> bool {
    false
}

fn hw_neon() -> bool {
    // NEON is baseline on aarch64 targets; no runtime probe needed.
    cfg!(all(target_arch = "aarch64", not(miri)))
}

/// Best tier this host supports: widest vector popcount first, portable
/// SWAR as the floor.
pub fn detect() -> KernelTier {
    if hw_avx512() {
        KernelTier::Avx512
    } else if hw_avx2() {
        KernelTier::Avx2
    } else if hw_neon() {
        KernelTier::Neon
    } else {
        KernelTier::Swar
    }
}

static TIER: OnceLock<KernelTier> = OnceLock::new();

fn resolve() -> Result<KernelTier, String> {
    match std::env::var("CIMSIM_KERNEL") {
        Ok(name) => {
            let tier: KernelTier = name.parse().map_err(|()| {
                format!(
                    "CIMSIM_KERNEL={name}: unknown kernel tier (expected one of \
                     scalar/walk/popcount/swar/avx2/avx512/neon)"
                )
            })?;
            if !tier.available() {
                return Err(format!(
                    "CIMSIM_KERNEL={name}: tier `{tier}` is not available on this host \
                     ({}); refusing to fall back silently",
                    tier.unavailable_reason()
                ));
            }
            Ok(tier)
        }
        Err(_) => Ok(detect()),
    }
}

/// The process-wide kernel tier, resolved once (env override or
/// detection), with the choice published to the `cim_kernel_tier` info
/// gauge. Errors instead of panicking on a bad `CIMSIM_KERNEL` — the CLI
/// calls this early to fail fast with a readable message.
pub fn try_kernel_tier() -> Result<KernelTier, String> {
    if let Some(&t) = TIER.get() {
        return Ok(t);
    }
    let resolved = resolve()?;
    let t = *TIER.get_or_init(|| {
        crate::telemetry::global()
            .gauge_family(
                "cim_kernel_tier",
                "Dispatched MAC kernel tier (info gauge: 1 on the active tier label)",
                &["tier"],
            )
            .with(&[resolved.name()])
            .set(1);
        resolved
    });
    Ok(t)
}

/// Infallible form of [`try_kernel_tier`] for library-internal call sites;
/// panics with the same message on a bad `CIMSIM_KERNEL`.
pub fn kernel_tier() -> KernelTier {
    match try_kernel_tier() {
        Ok(t) => t,
        Err(e) => panic!("{e}"),
    }
}

/// Fused AND + popcount horizontal sums over equal-length word runs:
/// returns `(Σ popcount(a[i] ∧ b[i]), Σ popcount(a[i] ∧ b[i] ∧ x[i]))`.
///
/// Exact for every tier — the counts are integers and integer addition
/// reassociates freely — so tier choice can never change kernel output.
/// Non-SIMD tiers route to the portable SWAR backend (they never call this
/// in the kernel, but the primitive stays total for tests and benches).
#[inline]
pub fn and_popcount_split(tier: KernelTier, a: &[u64], b: &[u64], x: &[u64]) -> (u64, u64) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), x.len());
    debug_assert!(tier.available(), "dispatched an unavailable tier");
    match tier {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        // SAFETY: `available()` checked AVX2 via `is_x86_feature_detected!`
        // before this tier could be selected or pinned.
        KernelTier::Avx2 => unsafe { avx2_split(a, b, x) },
        #[cfg(all(target_arch = "x86_64", feature = "avx512", not(miri)))]
        // SAFETY: as above, for AVX-512F + VPOPCNTDQ.
        KernelTier::Avx512 => unsafe { avx512_split(a, b, x) },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        KernelTier::Neon => neon_split(a, b, x),
        _ => swar_split(a, b, x),
    }
}

/// Per-byte popcounts of `w`, one count per byte lane (0..=8 each): the
/// classic SWAR reduction stopped before the horizontal multiply.
#[inline(always)]
fn nibble_counts(w: u64) -> u64 {
    let x = w - ((w >> 1) & 0x5555_5555_5555_5555);
    let x = (x & 0x3333_3333_3333_3333) + ((x >> 2) & 0x3333_3333_3333_3333);
    (x + (x >> 4)) & 0x0f0f_0f0f_0f0f_0f0f
}

/// Portable SWAR backend: byte-lane counts accumulate across up to 31
/// words (8·31 = 248 ≤ 255, no lane overflow) before one widening
/// horizontal reduction — the Harley-Seal idea of deferring the expensive
/// reduction across a block, in stable scalar Rust.
fn swar_split(a: &[u64], b: &[u64], x: &[u64]) -> (u64, u64) {
    const BLOCK: usize = 31;
    let n = a.len();
    let (mut total, mut diff) = (0u64, 0u64);
    let mut i = 0;
    while i < n {
        let end = (i + BLOCK).min(n);
        let (mut am, mut ad) = (0u64, 0u64);
        while i < end {
            let m = a[i] & b[i];
            am += nibble_counts(m);
            ad += nibble_counts(m & x[i]);
            i += 1;
        }
        total += horizontal_bytes(am);
        diff += horizontal_bytes(ad);
    }
    (total, diff)
}

/// Sum the 8 byte lanes of a SWAR accumulator. Widen to u16 lanes first:
/// the lane *sum* can reach 8·248 = 1984, past a byte, so the one-multiply
/// byte trick would truncate.
#[inline(always)]
fn horizontal_bytes(acc: u64) -> u64 {
    let pairs = (acc & 0x00ff_00ff_00ff_00ff) + ((acc >> 8) & 0x00ff_00ff_00ff_00ff);
    (pairs.wrapping_mul(0x0001_0001_0001_0001)) >> 48
}

/// AVX2 backend: Muła's nibble-LUT byte popcount (`vpshufb` against a
/// 0..=4 table for each nibble) with `vpsadbw` folding the byte counts
/// into u64 lanes every iteration, 4 words per vector.
#[cfg(all(target_arch = "x86_64", not(miri)))]
#[target_feature(enable = "avx2")]
unsafe fn avx2_split(a: &[u64], b: &[u64], x: &[u64]) -> (u64, u64) {
    use core::arch::x86_64::*;
    let n = a.len();
    #[rustfmt::skip]
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low = _mm256_set1_epi8(0x0f);
    let zero = _mm256_setzero_si256();
    let mut accm = _mm256_setzero_si256();
    let mut accd = _mm256_setzero_si256();
    let mut i = 0;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
        let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
        let vx = _mm256_loadu_si256(x.as_ptr().add(i) as *const __m256i);
        let m = _mm256_and_si256(va, vb);
        let d = _mm256_and_si256(m, vx);
        let cm = _mm256_add_epi8(
            _mm256_shuffle_epi8(lut, _mm256_and_si256(m, low)),
            _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi64::<4>(m), low)),
        );
        let cd = _mm256_add_epi8(
            _mm256_shuffle_epi8(lut, _mm256_and_si256(d, low)),
            _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi64::<4>(d), low)),
        );
        accm = _mm256_add_epi64(accm, _mm256_sad_epu8(cm, zero));
        accd = _mm256_add_epi64(accd, _mm256_sad_epu8(cd, zero));
        i += 4;
    }
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, accm);
    let mut total: u64 = lanes.iter().sum();
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, accd);
    let mut diff: u64 = lanes.iter().sum();
    while i < n {
        let m = a[i] & b[i];
        total += m.count_ones() as u64;
        diff += (m & x[i]).count_ones() as u64;
        i += 1;
    }
    (total, diff)
}

/// AVX-512 backend: native 64-bit-lane popcount (`vpopcntq`), 8 words per
/// vector. Compiled only with the off-by-default `avx512` cargo feature
/// (the intrinsics need a newer stable rustc than the crate's MSRV).
#[cfg(all(target_arch = "x86_64", feature = "avx512", not(miri)))]
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
unsafe fn avx512_split(a: &[u64], b: &[u64], x: &[u64]) -> (u64, u64) {
    use core::arch::x86_64::*;
    let n = a.len();
    let mut accm = _mm512_setzero_si512();
    let mut accd = _mm512_setzero_si512();
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm512_loadu_si512(a.as_ptr().add(i) as *const _);
        let vb = _mm512_loadu_si512(b.as_ptr().add(i) as *const _);
        let vx = _mm512_loadu_si512(x.as_ptr().add(i) as *const _);
        let m = _mm512_and_si512(va, vb);
        let d = _mm512_and_si512(m, vx);
        accm = _mm512_add_epi64(accm, _mm512_popcnt_epi64(m));
        accd = _mm512_add_epi64(accd, _mm512_popcnt_epi64(d));
        i += 8;
    }
    let mut total = _mm512_reduce_add_epi64(accm) as u64;
    let mut diff = _mm512_reduce_add_epi64(accd) as u64;
    while i < n {
        let m = a[i] & b[i];
        total += m.count_ones() as u64;
        diff += (m & x[i]).count_ones() as u64;
        i += 1;
    }
    (total, diff)
}

/// NEON backend: `vcnt.8` byte popcounts with a widening horizontal add
/// per 2-word vector (byte counts ≤ 8 each; the u16 horizontal sum tops
/// out at 128, far from overflow).
#[cfg(all(target_arch = "aarch64", not(miri)))]
fn neon_split(a: &[u64], b: &[u64], x: &[u64]) -> (u64, u64) {
    use core::arch::aarch64::*;
    let n = a.len();
    let (mut total, mut diff) = (0u64, 0u64);
    let mut i = 0;
    // SAFETY: NEON is baseline on aarch64; loads stay in-bounds (i + 2 <= n).
    unsafe {
        while i + 2 <= n {
            let m = vandq_u64(vld1q_u64(a.as_ptr().add(i)), vld1q_u64(b.as_ptr().add(i)));
            let d = vandq_u64(m, vld1q_u64(x.as_ptr().add(i)));
            total += vaddvq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(m)))) as u64;
            diff += vaddvq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(d)))) as u64;
            i += 2;
        }
    }
    while i < n {
        let m = a[i] & b[i];
        total += m.count_ones() as u64;
        diff += (m & x[i]).count_ones() as u64;
        i += 1;
    }
    (total, diff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn reference(a: &[u64], b: &[u64], x: &[u64]) -> (u64, u64) {
        let (mut total, mut diff) = (0u64, 0u64);
        for i in 0..a.len() {
            let m = a[i] & b[i];
            total += m.count_ones() as u64;
            diff += (m & x[i]).count_ones() as u64;
        }
        (total, diff)
    }

    fn testable_tiers() -> Vec<KernelTier> {
        KernelTier::ALL
            .iter()
            .copied()
            .filter(|t| t.simd() && t.available())
            .collect()
    }

    /// Every available SIMD tier matches the per-word reference on random,
    /// degenerate, and boundary-length inputs — including lengths around
    /// the vector width, the SWAR block (31), and a single top-word bit.
    #[test]
    fn every_available_tier_matches_reference() {
        let mut rng = Xoshiro256::seeded(0xC1A0_5EED);
        let lens =
            [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 30, 31, 32, 33, 62, 63, 64, 65, 100];
        for &len in &lens {
            for pattern in 0..4 {
                let gen = |rng: &mut Xoshiro256, fill: u64| -> Vec<u64> {
                    match pattern {
                        0 => (0..len).map(|_| rng.next_u64()).collect(),
                        1 => vec![0u64; len],
                        2 => vec![fill; len],
                        // Single bit in the top word only.
                        _ => {
                            let mut v = vec![0u64; len];
                            if let Some(last) = v.last_mut() {
                                *last = 1u64 << (rng.next_below(64));
                            }
                            v
                        }
                    }
                };
                let a = gen(&mut rng, u64::MAX);
                let b = gen(&mut rng, u64::MAX);
                let x = gen(&mut rng, 0xAAAA_AAAA_AAAA_AAAA);
                let want = reference(&a, &b, &x);
                for tier in testable_tiers() {
                    let got = and_popcount_split(tier, &a, &b, &x);
                    assert_eq!(got, want, "tier {tier} len {len} pattern {pattern}");
                }
            }
        }
    }

    /// All-ones runs longer than one SWAR block stress the byte-lane
    /// saturation bound (31 words × 8 = 248 per lane) and the widening
    /// horizontal reduction (block sums up to 1984 > u8).
    #[test]
    fn swar_block_boundary_is_exact() {
        for len in [30usize, 31, 32, 61, 62, 63, 93, 124] {
            let ones = vec![u64::MAX; len];
            let (total, diff) = swar_split(&ones, &ones, &ones);
            assert_eq!(total, 64 * len as u64, "len {len}");
            assert_eq!(diff, 64 * len as u64, "len {len}");
            let zeros = vec![0u64; len];
            assert_eq!(swar_split(&ones, &ones, &zeros), (64 * len as u64, 0));
        }
    }

    #[test]
    fn detect_returns_an_available_simd_tier() {
        let t = detect();
        assert!(t.available(), "detected tier must be available");
        assert!(t.simd(), "detection never picks a scalar arm");
    }

    #[test]
    fn tier_names_round_trip_and_unknown_is_rejected() {
        for t in KernelTier::ALL {
            assert_eq!(t.name().parse::<KernelTier>(), Ok(t));
            assert_eq!(t.name().to_uppercase().parse::<KernelTier>(), Ok(t));
        }
        assert!("sse9000".parse::<KernelTier>().is_err());
        assert!("".parse::<KernelTier>().is_err());
    }

    #[test]
    fn unavailable_tiers_carry_a_reason() {
        for t in KernelTier::ALL {
            if !t.available() {
                assert!(
                    !t.unavailable_reason().is_empty(),
                    "tier {t} must explain its unavailability"
                );
            }
        }
    }

    #[test]
    fn tier_capability_flags_are_consistent() {
        use KernelTier::*;
        assert!(!Scalar.closed_form() && !Scalar.batched() && !Scalar.simd());
        assert!(Walk.closed_form() && !Walk.batched() && !Walk.simd());
        assert!(Popcount.closed_form() && Popcount.batched() && !Popcount.simd());
        for t in [Swar, Avx2, Avx512, Neon] {
            assert!(t.closed_form() && t.batched() && t.simd(), "tier {t}");
        }
        // The portable floor is unconditionally available.
        assert!(Swar.available());
    }

    #[test]
    fn kernel_tier_resolves_and_is_stable() {
        // Whatever the environment forced (the CI tier matrix sets
        // CIMSIM_KERNEL), the resolved tier must be available and cached.
        let t = kernel_tier();
        assert!(t.available());
        assert_eq!(kernel_tier(), t);
        assert_eq!(try_kernel_tier(), Ok(t));
    }
}
