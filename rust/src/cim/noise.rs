//! Noise machinery: the static "fabrication" draw for a macro instance and
//! the per-operation dynamic noise draw.
//!
//! Both are plain arrays of standard-normal variates scaled at use-site, so
//! the native Rust model and the AOT-compiled XLA/Pallas model can consume
//! *identical* noise tensors — the equivalence tests rely on this.

use crate::config::{Config, MacroConfig, NoiseConfig};
use crate::util::rng::{fill_gaussian, Rng, Xoshiro256};

/// Per-event pulse-timing σ in τ0, as a function of the pulse width in
/// τ0-seconds: an absolute floor plus a hyperbolically-decaying narrow-pulse
/// penalty `small·knee/width` (slew-limited pulse shaping: the delivered
/// charge of a narrow pulse deviates inversely with its width). This curve
/// is the mechanism behind the MAC-folding win (Fig. 4): folding (and
/// boosting) widen the pulses, escaping the narrow-pulse region.
#[inline]
pub fn jitter_sigma(noise: &NoiseConfig, width_tau0: f64) -> f64 {
    if width_tau0 <= 0.0 {
        return 0.0; // no pulse, no event, no noise
    }
    if noise.t_pow == 1.0 {
        // Hot-path special case: the default exponent needs no powf.
        noise.sigma_t_floor + noise.sigma_t_small * noise.t_knee / width_tau0
    } else {
        noise.sigma_t_floor + noise.sigma_t_small * (noise.t_knee / width_tau0).powf(noise.t_pow)
    }
}

/// Static per-instance mismatch ("fabrication"): drawn once from
/// `noise.fab_seed`, shared by every op the instance runs.
#[derive(Clone, Debug)]
pub struct Fabrication {
    cores: usize,
    rows: usize,
    engines: usize,
    /// Relative discharge-current mismatch per MAC cell branch,
    /// indexed `[core][row][bit k][engine]` (engine contiguous innermost to
    /// match the per-SL inner loops).
    cell: Vec<f32>,
    /// Static SA input offset per `[core][engine]`, in u.
    sa_off: Vec<f32>,
    /// Relative RBL-vs-RBLB capacitor mismatch per `[core][engine]`:
    /// discharges on RBL scale by (1+δ), on RBLB by (1−δ).
    cap: Vec<f32>,
    /// Static relative error of each readout step magnitude,
    /// `[core][engine][step 0..8]` (8 discharge steps follow the first 8 of
    /// 9 comparisons).
    step: Vec<f32>,
    /// Whether every MAC-phase static error (cell-current and capacitor
    /// mismatch) is exactly zero — precomputed once so the closed-form
    /// noise-free kernel path can gate on it per op for free.
    mac_ideal: bool,
}

impl Fabrication {
    pub fn draw(mac: &MacroConfig, noise: &NoiseConfig) -> Self {
        let root = Xoshiro256::seeded(noise.fab_seed);
        let kbits = 3.max(mac.weight_bits as usize - 1);
        let n_cell = mac.cores * mac.rows * kbits * mac.engines;
        let n_eng = mac.cores * mac.engines;
        let mut cell = vec![0f32; n_cell];
        let mut sa_off = vec![0f32; n_eng];
        let mut cap = vec![0f32; n_eng];
        let mut step = vec![0f32; n_eng * 8];
        fill_gaussian(&mut root.substream("cell"), noise.sigma_cell, &mut cell);
        fill_gaussian(&mut root.substream("sa"), noise.sigma_sa_static, &mut sa_off);
        fill_gaussian(&mut root.substream("cap"), noise.sigma_cap, &mut cap);
        fill_gaussian(&mut root.substream("step"), noise.sigma_step_static, &mut step);
        if !noise.enabled {
            // Ideal instance: zero all static error.
            cell.iter_mut().for_each(|x| *x = 0.0);
            sa_off.iter_mut().for_each(|x| *x = 0.0);
            cap.iter_mut().for_each(|x| *x = 0.0);
            step.iter_mut().for_each(|x| *x = 0.0);
        }
        let mac_ideal = cell.iter().all(|&x| x == 0.0) && cap.iter().all(|&x| x == 0.0);
        Self {
            cores: mac.cores,
            rows: mac.rows,
            engines: mac.engines,
            cell,
            sa_off,
            cap,
            step,
            mac_ideal,
        }
    }

    pub fn ideal(mac: &MacroConfig) -> Self {
        Self::draw(mac, &NoiseConfig::disabled())
    }

    /// True when every MAC-phase static mismatch entry (`cell`, `cap`) is
    /// exactly zero, i.e. each discharge branch is nominal. The bit-plane
    /// kernel's closed-form path requires this (every line-drop term is then
    /// an exactly-representable dyadic and summation order is immaterial).
    #[inline]
    pub fn is_ideal(&self) -> bool {
        self.mac_ideal
    }

    #[inline]
    pub fn cell(&self, core: usize, row: usize, k: usize, engine: usize) -> f32 {
        let kbits = self.cell.len() / (self.cores * self.rows * self.engines);
        self.cell[((core * self.rows + row) * kbits + k) * self.engines + engine]
    }

    /// Raw slice for one (core,row,bit): per-engine mismatch, used by hot loops.
    #[inline]
    pub fn cell_row(&self, core: usize, row: usize, k: usize) -> &[f32] {
        let kbits = self.cell.len() / (self.cores * self.rows * self.engines);
        let base = ((core * self.rows + row) * kbits + k) * self.engines;
        &self.cell[base..base + self.engines]
    }

    #[inline]
    pub fn sa_off(&self, core: usize, engine: usize) -> f32 {
        self.sa_off[core * self.engines + engine]
    }

    #[inline]
    pub fn cap(&self, core: usize, engine: usize) -> f32 {
        self.cap[core * self.engines + engine]
    }

    #[inline]
    pub fn step(&self, core: usize, engine: usize, d: usize) -> f32 {
        self.step[(core * self.engines + engine) * 8 + d]
    }

    /// Flat views for exporting to the XLA path (same memory order as the
    /// kernel inputs).
    pub fn cell_flat(&self) -> &[f32] {
        &self.cell
    }
    pub fn sa_off_flat(&self) -> &[f32] {
        &self.sa_off
    }
    pub fn cap_flat(&self) -> &[f32] {
        &self.cap
    }
    pub fn step_flat(&self) -> &[f32] {
        &self.step
    }
}

/// Dynamic standard-normal noise for ONE core operation. Scaled at use-site:
/// * `z_jit[row][k]`   — pulse-timing error of the (row, bit) SL pulse
///   (shared by all engines of the core, as the SL is shared);
/// * `z_step[engine][d]` — readout-step charge error, d ∈ 0..8;
/// * `z_cmp[engine][d]`  — SA comparison noise, d ∈ 0..9.
#[derive(Clone, Debug, Default)]
pub struct NoiseDraw {
    pub z_jit: Vec<f32>,
    pub z_step: Vec<f32>,
    pub z_cmp: Vec<f32>,
    pub rows: usize,
    pub kbits: usize,
    pub engines: usize,
}

impl NoiseDraw {
    pub fn zeros(mac: &MacroConfig) -> Self {
        let kbits = mac.weight_bits as usize - 1;
        Self {
            z_jit: vec![0.0; mac.rows * kbits],
            z_step: vec![0.0; mac.engines * 8],
            z_cmp: vec![0.0; mac.engines * 9],
            rows: mac.rows,
            kbits,
            engines: mac.engines,
        }
    }

    pub fn draw<R: Rng>(mac: &MacroConfig, rng: &mut R) -> Self {
        let mut d = Self::zeros(mac);
        d.redraw(rng);
        d
    }

    /// Refill in place (hot path: avoids the three allocations of `draw`).
    pub fn redraw<R: Rng>(&mut self, rng: &mut R) {
        fill_gaussian(rng, 1.0, &mut self.z_jit);
        fill_gaussian(rng, 1.0, &mut self.z_step);
        fill_gaussian(rng, 1.0, &mut self.z_cmp);
    }

    #[inline]
    pub fn jit(&self, row: usize, k: usize) -> f32 {
        self.z_jit[row * self.kbits + k]
    }

    #[inline]
    pub fn step(&self, engine: usize, d: usize) -> f32 {
        self.z_step[engine * 8 + d]
    }

    #[inline]
    pub fn cmp(&self, engine: usize, d: usize) -> f32 {
        self.z_cmp[engine * 9 + d]
    }
}

/// Convenience: a fabrication + per-op RNG bundle for a configured instance.
pub fn op_rng(cfg: &Config, op_index: u64) -> Xoshiro256 {
    Xoshiro256::seeded(cfg.sim.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(op_index + 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn jitter_sigma_shape() {
        let n = NoiseConfig::default();
        assert_eq!(jitter_sigma(&n, 0.0), 0.0);
        let narrow = jitter_sigma(&n, 1.0);
        let wide = jitter_sigma(&n, 60.0);
        assert!(narrow > wide, "narrow pulses must be noisier");
        // Wide pulses approach the floor (hyperbolic tail: within
        // small·knee/60 of it).
        assert!(wide - n.sigma_t_floor <= n.sigma_t_small * n.t_knee / 60.0 + 1e-12);
        // Monotone decreasing.
        let mut prev = f64::INFINITY;
        for w in [0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0] {
            let s = jitter_sigma(&n, w);
            assert!(s <= prev);
            prev = s;
        }
    }

    #[test]
    fn fabrication_deterministic_in_seed() {
        let cfg = Config::default();
        let f1 = Fabrication::draw(&cfg.mac, &cfg.noise);
        let f2 = Fabrication::draw(&cfg.mac, &cfg.noise);
        assert_eq!(f1.cell_flat(), f2.cell_flat());
        assert_eq!(f1.sa_off_flat(), f2.sa_off_flat());
        let mut other = cfg.noise.clone();
        other.fab_seed ^= 1;
        let f3 = Fabrication::draw(&cfg.mac, &other);
        assert_ne!(f1.cell_flat(), f3.cell_flat());
    }

    #[test]
    fn fabrication_shapes_and_stats() {
        let cfg = Config::default();
        let f = Fabrication::draw(&cfg.mac, &cfg.noise);
        assert_eq!(f.cell_flat().len(), 4 * 64 * 3 * 16);
        assert_eq!(f.sa_off_flat().len(), 4 * 16);
        assert_eq!(f.step_flat().len(), 4 * 16 * 8);
        // Sample std close to configured sigma.
        let v: f64 = f
            .cell_flat()
            .iter()
            .map(|&x| (x as f64).powi(2))
            .sum::<f64>()
            / f.cell_flat().len() as f64;
        assert!((v.sqrt() - cfg.noise.sigma_cell).abs() < 0.15 * cfg.noise.sigma_cell);
    }

    #[test]
    fn disabled_noise_is_all_zero() {
        let cfg = Config::default();
        let f = Fabrication::ideal(&cfg.mac);
        assert!(f.cell_flat().iter().all(|&x| x == 0.0));
        assert!(f.sa_off_flat().iter().all(|&x| x == 0.0));
        assert!(f.is_ideal());
        let d = NoiseDraw::zeros(&cfg.mac);
        assert!(d.z_jit.iter().all(|&x| x == 0.0));
        // A real draw with the default sigmas is not ideal.
        assert!(!Fabrication::draw(&cfg.mac, &cfg.noise).is_ideal());
        // Enabled noise with zero cell/cap sigma still counts as MAC-ideal
        // (SA offsets do not enter the MAC phase).
        let mut zero_mac = cfg.noise.clone();
        zero_mac.sigma_cell = 0.0;
        zero_mac.sigma_cap = 0.0;
        assert!(Fabrication::draw(&cfg.mac, &zero_mac).is_ideal());
    }

    #[test]
    fn indexing_is_consistent_with_flat_layout() {
        let cfg = Config::default();
        let f = Fabrication::draw(&cfg.mac, &cfg.noise);
        // cell(core,row,k,engine) must match the documented flat order.
        let (c, r, k, e) = (2, 17, 1, 9);
        let flat = f.cell_flat()[((c * 64 + r) * 3 + k) * 16 + e];
        assert_eq!(f.cell(c, r, k, e), flat);
        assert_eq!(f.cell_row(c, r, k)[e], flat);
        let d = NoiseDraw::zeros(&cfg.mac);
        assert_eq!(d.z_jit.len(), 64 * 3);
        assert_eq!(d.z_cmp.len(), 16 * 9);
    }
}
