//! Serving metrics: latency percentiles, queue-wait accounting, throughput,
//! pipeline-stage gauges, and per-request energy pulled from the backend's
//! activity counters.
//!
//! Latency samples go through a fixed-size **reservoir** (Vitter's
//! algorithm R with a deterministic SplitMix64 stream), so a serve loop
//! that runs for days holds a bounded, uniformly-sampled subset instead of
//! one `f64` per request forever. Queue-wait time (admission → batch
//! start) is recorded separately from execution time (batch start → batch
//! done), because under backpressure the two diverge: a saturated server
//! shows flat execution latency and growing queue wait.

use crate::sched::StageGauge;
use crate::util::rng::{Rng, SplitMix64};
use std::time::Duration;

/// Samples the reservoir holds; large enough that p99 over it is stable,
/// small enough that a long-running server's memory stays flat.
const RESERVOIR_CAP: usize = 4096;

/// Fixed-size uniform sample of a stream (algorithm R). Deterministic: the
/// replacement stream is seeded per reservoir, so identical request
/// sequences report identical percentiles.
#[derive(Clone, Debug)]
pub struct Reservoir {
    samples: Vec<f64>,
    seen: u64,
    sum: f64,
    rng: SplitMix64,
}

impl Reservoir {
    pub fn new(seed: u64) -> Self {
        Self { samples: Vec::new(), seen: 0, sum: 0.0, rng: SplitMix64::new(seed) }
    }

    pub fn record(&mut self, v: f64) {
        self.seen += 1;
        self.sum += v;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(v);
        } else {
            let j = self.rng.next_below(self.seen);
            if (j as usize) < RESERVOIR_CAP {
                self.samples[j as usize] = v;
            }
        }
    }

    /// Values ever recorded (not the held sample count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Samples currently held — bounded by the reservoir capacity.
    pub fn held(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.sum / self.seen as f64
        }
    }

    /// Percentile over the held sample (0 when empty). For several
    /// quantiles at once use [`Reservoir::percentiles`], which sorts once.
    pub fn percentile(&self, q: f64) -> f64 {
        self.percentiles(&[q])[0]
    }

    /// Several percentiles from ONE clone-and-sort of the held sample —
    /// `report()` asks for five quantiles per reservoir, and sorting per
    /// quantile was the dominant cost of building a report.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<f64> {
        if self.samples.is_empty() {
            return vec![0.0; qs.len()];
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN latency"));
        qs.iter().map(|&q| crate::bench::percentile(&sorted, q)).collect()
    }
}

#[derive(Clone, Debug)]
pub struct Metrics {
    /// Per-request execution latency (batch start → batch done), µs.
    exec_us: Reservoir,
    /// Per-request queue wait (admission → batch start), µs.
    wait_us: Reservoir,
    pub requests: u64,
    pub batches: u64,
    /// Largest batch coalesced by the dynamic batcher — occupancy > 1 means
    /// the batched serve loop actually amortized work across requests.
    pub peak_batch: u64,
    /// Deepest the admission queue ever got (backpressure pressure gauge).
    pub peak_queue_depth: u64,
    /// Peak number of simultaneously busy pipeline stages reported by the
    /// engine (`> 1` ⇒ streamed execution actually pipelined).
    pub peak_stages_busy: u64,
    /// Per-stage items/queue gauges from the engine (streamed plans only).
    pub stages: Vec<StageGauge>,
    pub core_ops: u64,
    pub energy_fj: f64,
    pub device_cycles: u64,
    /// Weight tile loads + dynamic reloads attributed to served batches.
    pub weight_loads: u64,
    pub wall: Duration,
}

impl Default for Metrics {
    fn default() -> Self {
        Self {
            exec_us: Reservoir::new(0x5EED_EC0),
            wait_us: Reservoir::new(0x5EED_3A17),
            requests: 0,
            batches: 0,
            peak_batch: 0,
            peak_queue_depth: 0,
            peak_stages_busy: 0,
            stages: Vec::new(),
            core_ops: 0,
            energy_fj: 0.0,
            device_cycles: 0,
            weight_loads: 0,
            wall: Duration::default(),
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct MetricsReport {
    pub requests: u64,
    pub batches: u64,
    /// Mean batch occupancy (requests per coalesced batch).
    pub mean_batch: f64,
    pub peak_batch: u64,
    pub peak_queue_depth: u64,
    pub peak_stages_busy: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Queue-wait percentiles + mean, separate from execution latency.
    pub wait_p50_ms: f64,
    pub wait_p99_ms: f64,
    pub mean_wait_ms: f64,
    /// Latency samples the bounded reservoirs currently hold
    /// (execution, wait) — how much data backs the percentiles above.
    pub samples_held_exec: usize,
    pub samples_held_wait: usize,
    pub throughput_rps: f64,
    pub energy_uj_per_req: f64,
    pub device_cycles: u64,
    pub weight_loads: u64,
    /// Busy device-equivalents: device cycles consumed per wall-clock
    /// cycle. With N shards executing in parallel this legitimately
    /// exceeds 1.0 (N devices' worth of work per second) — it is NOT a
    /// 0..=1 utilization; see [`MetricsReport::device_utilization`].
    pub device_equivalents: f64,
}

impl Metrics {
    /// Record one coalesced batch's execution latency (charged to each of
    /// its requests, like the wire round-trip the clients observed).
    pub fn record_batch(&mut self, batch_size: usize, latency: Duration) {
        self.batches += 1;
        self.requests += batch_size as u64;
        self.peak_batch = self.peak_batch.max(batch_size as u64);
        for _ in 0..batch_size {
            self.exec_us.record(latency.as_secs_f64() * 1e6);
        }
    }

    /// Record one request's queue wait (admission → batch start).
    pub fn record_wait(&mut self, wait: Duration) {
        self.wait_us.record(wait.as_secs_f64() * 1e6);
    }

    /// Latency samples currently held — bounded regardless of how long the
    /// serve loop has been running.
    pub fn samples_held(&self) -> (usize, usize) {
        (self.exec_us.held(), self.wait_us.held())
    }

    pub fn report(&self, clock_hz: f64) -> MetricsReport {
        let wall_s = self.wall.as_secs_f64().max(1e-12);
        // One sort per reservoir, not one per quantile.
        let exec = self.exec_us.percentiles(&[0.50, 0.95, 0.99]);
        let wait = self.wait_us.percentiles(&[0.50, 0.99]);
        MetricsReport {
            requests: self.requests,
            batches: self.batches,
            mean_batch: self.requests as f64 / self.batches.max(1) as f64,
            peak_batch: self.peak_batch,
            peak_queue_depth: self.peak_queue_depth,
            peak_stages_busy: self.peak_stages_busy,
            p50_ms: exec[0] / 1e3,
            p95_ms: exec[1] / 1e3,
            p99_ms: exec[2] / 1e3,
            wait_p50_ms: wait[0] / 1e3,
            wait_p99_ms: wait[1] / 1e3,
            mean_wait_ms: self.wait_us.mean() / 1e3,
            samples_held_exec: self.exec_us.held(),
            samples_held_wait: self.wait_us.held(),
            throughput_rps: self.requests as f64 / wall_s,
            energy_uj_per_req: self.energy_fj * 1e-9 / self.requests.max(1) as f64,
            device_cycles: self.device_cycles,
            weight_loads: self.weight_loads,
            device_equivalents: (self.device_cycles as f64 / clock_hz) / wall_s,
        }
    }
}

impl MetricsReport {
    /// Single-device-equivalent utilization, clamped to 0..=1. The raw
    /// (unclamped) parallel figure is [`MetricsReport::device_equivalents`].
    pub fn device_utilization(&self) -> f64 {
        self.device_equivalents.min(1.0)
    }

    pub fn render(&self) -> String {
        format!(
            "requests {}  batches {} (mean {:.1}, peak {})  p50 {:.2} ms  p95 {:.2} ms  \
             p99 {:.2} ms  wait p50 {:.2} / p99 {:.2} ms (mean {:.2} ms)  \
             samples held {}/{}  queue peak {}  stages busy peak {}  \
             throughput {:.1} req/s  energy {:.4} µJ/req  device cycles {}  \
             weight loads {}  device-equivalents {:.2}",
            self.requests,
            self.batches,
            self.mean_batch,
            self.peak_batch,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.wait_p50_ms,
            self.wait_p99_ms,
            self.mean_wait_ms,
            self.samples_held_exec,
            self.samples_held_wait,
            self.peak_queue_depth,
            self.peak_stages_busy,
            self.throughput_rps,
            self.energy_uj_per_req,
            self.device_cycles,
            self.weight_loads,
            self.device_equivalents
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_throughput() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_batch(1, Duration::from_micros(i * 100));
        }
        m.wall = Duration::from_secs(1);
        m.energy_fj = 1e9; // 1 µJ total
        let r = m.report(200e6);
        assert_eq!(r.requests, 100);
        assert!((r.p50_ms - 5.05).abs() < 0.15, "{}", r.p50_ms);
        assert!(r.p99_ms > r.p95_ms && r.p95_ms > r.p50_ms);
        assert!((r.throughput_rps - 100.0).abs() < 1e-9);
        assert!((r.energy_uj_per_req - 0.01).abs() < 1e-12);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::default();
        m.record_batch(16, Duration::from_millis(2));
        m.record_batch(8, Duration::from_millis(1));
        let r = m.report(200e6);
        assert_eq!(r.requests, 24);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 12.0).abs() < 1e-12);
        assert_eq!(r.peak_batch, 16);
    }

    /// The regression the reservoir exists for: a long-running serve loop
    /// must hold bounded latency state no matter how many requests passed.
    #[test]
    fn latency_memory_is_bounded() {
        let mut m = Metrics::default();
        for i in 0..200_000u64 {
            m.record_batch(1, Duration::from_micros(100 + i % 97));
            m.record_wait(Duration::from_micros(i % 31));
        }
        let (exec_held, wait_held) = m.samples_held();
        assert!(exec_held <= RESERVOIR_CAP, "exec reservoir grew to {exec_held}");
        assert!(wait_held <= RESERVOIR_CAP, "wait reservoir grew to {wait_held}");
        assert_eq!(m.requests, 200_000);
        let r = m.report(200e6);
        // The uniform sample keeps the percentiles in the true range.
        assert!(r.p50_ms >= 0.100 && r.p50_ms <= 0.197, "{}", r.p50_ms);
        assert!(r.wait_p99_ms <= 0.031, "{}", r.wait_p99_ms);
    }

    #[test]
    fn wait_is_reported_separately_from_execution() {
        let mut m = Metrics::default();
        m.record_batch(2, Duration::from_millis(4));
        m.record_wait(Duration::from_millis(1));
        m.record_wait(Duration::from_millis(3));
        let r = m.report(200e6);
        assert!((r.p50_ms - 4.0).abs() < 1e-9);
        assert!((r.wait_p50_ms - 2.0).abs() < 1e-6, "{}", r.wait_p50_ms);
        assert!((r.mean_wait_ms - 2.0).abs() < 1e-6);
        assert!((r.wait_p99_ms - 2.96).abs() < 0.05, "{}", r.wait_p99_ms);
    }

    /// With N shards burning cycles in parallel, cycles-per-wall-second can
    /// exceed the clock: `device_equivalents` reports that raw figure
    /// (> 1.0), while `device_utilization()` clamps to a 0..=1 fraction.
    #[test]
    fn parallel_shards_exceed_one_device_equivalent() {
        let mut m = Metrics::default();
        m.record_batch(4, Duration::from_millis(1));
        m.wall = Duration::from_secs(1);
        // 4 shards × 200 MHz for the full second = 8e8 cycles.
        m.device_cycles = 800_000_000;
        let r = m.report(200e6);
        assert!((r.device_equivalents - 4.0).abs() < 1e-9, "{}", r.device_equivalents);
        assert_eq!(r.device_utilization(), 1.0, "clamped single-device view");

        let mut idle = Metrics::default();
        idle.record_batch(1, Duration::from_millis(1));
        idle.wall = Duration::from_secs(1);
        idle.device_cycles = 100_000_000; // half the 200 MHz clock
        let r = idle.report(200e6);
        assert!((r.device_equivalents - 0.5).abs() < 1e-9);
        assert!((r.device_utilization() - 0.5).abs() < 1e-9, "below 1.0 passes through");
    }

    /// `render()` must surface the fields the report computes: mean wait,
    /// reservoir occupancy, device cycles, and weight loads.
    #[test]
    fn render_includes_wait_samples_and_device_counters() {
        let mut m = Metrics::default();
        m.record_batch(2, Duration::from_millis(4));
        m.record_wait(Duration::from_millis(1));
        m.record_wait(Duration::from_millis(3));
        m.wall = Duration::from_secs(1);
        m.device_cycles = 12_345;
        m.weight_loads = 67;
        let s = m.report(200e6).render();
        assert!(s.contains("mean 2.00 ms"), "{s}");
        assert!(s.contains("samples held 2/2"), "{s}");
        assert!(s.contains("device cycles 12345"), "{s}");
        assert!(s.contains("weight loads 67"), "{s}");
        assert!(s.contains("device-equivalents"), "{s}");
    }

    /// `percentiles` (one sort) must agree with repeated `percentile` calls.
    #[test]
    fn batched_percentiles_match_single_calls() {
        let mut r = Reservoir::new(11);
        for i in 0..5_000 {
            r.record(((i * 37) % 1009) as f64);
        }
        let qs = [0.5, 0.95, 0.99];
        let batch = r.percentiles(&qs);
        for (q, b) in qs.iter().zip(&batch) {
            assert_eq!(r.percentile(*q), *b);
        }
        assert_eq!(Reservoir::new(3).percentiles(&qs), vec![0.0; 3]);
    }

    #[test]
    fn reservoir_is_deterministic() {
        let fill = |seed: u64| {
            let mut r = Reservoir::new(seed);
            for i in 0..10_000 {
                r.record((i % 113) as f64);
            }
            r.percentile(0.5)
        };
        assert_eq!(fill(7), fill(7));
    }
}
