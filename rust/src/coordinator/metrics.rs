//! Serving metrics: latency percentiles, throughput, and per-request energy
//! pulled from the backend's activity counters.

use std::time::Duration;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<f64>,
    pub requests: u64,
    pub batches: u64,
    /// Largest batch coalesced by the dynamic batcher — occupancy > 1 means
    /// the batched serve loop actually amortized work across requests.
    pub peak_batch: u64,
    pub core_ops: u64,
    pub energy_fj: f64,
    pub device_cycles: u64,
    pub wall: Duration,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsReport {
    pub requests: u64,
    pub batches: u64,
    /// Mean batch occupancy (requests per coalesced batch).
    pub mean_batch: f64,
    pub peak_batch: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
    pub energy_uj_per_req: f64,
    pub device_utilization: f64,
}

impl Metrics {
    pub fn record_batch(&mut self, batch_size: usize, latency: Duration) {
        self.batches += 1;
        self.requests += batch_size as u64;
        self.peak_batch = self.peak_batch.max(batch_size as u64);
        for _ in 0..batch_size {
            self.latencies_us.push(latency.as_secs_f64() * 1e6);
        }
    }

    pub fn report(&self, clock_hz: f64) -> MetricsReport {
        let mut lat = self.latencies_us.clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            crate::bench::percentile(&lat, q) / 1e3
        };
        let wall_s = self.wall.as_secs_f64().max(1e-12);
        MetricsReport {
            requests: self.requests,
            batches: self.batches,
            mean_batch: self.requests as f64 / self.batches.max(1) as f64,
            peak_batch: self.peak_batch,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            throughput_rps: self.requests as f64 / wall_s,
            energy_uj_per_req: self.energy_fj * 1e-9 / self.requests.max(1) as f64,
            device_utilization: (self.device_cycles as f64 / clock_hz) / wall_s,
        }
    }
}

impl MetricsReport {
    pub fn render(&self) -> String {
        format!(
            "requests {}  batches {} (mean {:.1}, peak {})  p50 {:.2} ms  p95 {:.2} ms  \
             p99 {:.2} ms  throughput {:.1} req/s  energy {:.4} µJ/req  device-util {:.1}%",
            self.requests,
            self.batches,
            self.mean_batch,
            self.peak_batch,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.throughput_rps,
            self.energy_uj_per_req,
            100.0 * self.device_utilization
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_throughput() {
        let mut m = Metrics::default();
        for i in 1..=100 {
            m.record_batch(1, Duration::from_micros(i * 100));
        }
        m.wall = Duration::from_secs(1);
        m.energy_fj = 1e9; // 1 µJ total
        let r = m.report(200e6);
        assert_eq!(r.requests, 100);
        assert!((r.p50_ms - 5.05).abs() < 0.15, "{}", r.p50_ms);
        assert!(r.p99_ms > r.p95_ms && r.p95_ms > r.p50_ms);
        assert!((r.throughput_rps - 100.0).abs() < 1e-9);
        assert!((r.energy_uj_per_req - 0.01).abs() < 1e-12);
    }

    #[test]
    fn batch_accounting() {
        let mut m = Metrics::default();
        m.record_batch(16, Duration::from_millis(2));
        m.record_batch(8, Duration::from_millis(1));
        let r = m.report(200e6);
        assert_eq!(r.requests, 24);
        assert_eq!(r.batches, 2);
        assert!((r.mean_batch - 12.0).abs() < 1e-12);
        assert_eq!(r.peak_batch, 16);
    }
}
