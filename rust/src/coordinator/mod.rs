//! L3 coordinator: the edge-AI serving story around the macro — deployment
//! quantization, dynamic batching, TCP serving and metrics.

pub mod deployment;
pub mod metrics;
pub mod server;

pub use deployment::MlpDeployment;
pub use metrics::{Metrics, MetricsReport};
pub use server::{
    serve_engine, serve_frontend, BackendEngine, Client, InferenceEngine, ServeConfig,
    ServeConfigBuilder, ServeFrontend, ServerHandle,
};
#[allow(deprecated)]
pub use server::{serve, serve_decode, serve_pipeline, serve_plan};
