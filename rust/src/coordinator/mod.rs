//! L3 coordinator: the edge-AI serving story around the macro — deployment
//! quantization, dynamic batching, TCP serving and metrics.

pub mod deployment;
pub mod metrics;
pub mod server;

pub use deployment::MlpDeployment;
pub use metrics::{Metrics, MetricsReport};
pub use server::{
    serve, serve_decode, serve_engine, serve_pipeline, serve_plan, BackendEngine, Client,
    InferenceEngine, ServeConfig, ServerHandle,
};
