//! Deployment bridge: a float-trained MLP → the quantized parameter bundle
//! both inference paths consume (native tiled executor, and the AOT
//! `mlp_fwd` artifact whose graph implements the identical pipeline).

use crate::config::Config;
use crate::mapping::executor::CimLinear;
use crate::mapping::{CimBackend, MapError};
use crate::nn::mlp::Mlp;
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;

/// Quantized MLP ready for the macro: integer weight planes + the four
/// scales the L2 graph takes (`a0_scale, w1_scale, a1_cal, w2_scale`).
#[derive(Clone, Debug)]
pub struct MlpDeployment {
    pub dims: [usize; 3],
    /// Integer-valued weights, column-major per layer: `[K][N]` in ±7.
    pub w1_q: Tensor,
    pub b1: Vec<f32>,
    pub w2_q: Tensor,
    pub b2: Vec<f32>,
    pub a0_scale: f32,
    pub w1_scale: f32,
    pub a1_cal: f32,
    pub w2_scale: f32,
}

impl MlpDeployment {
    /// Post-training quantization. `cal_inputs` drives the hidden-activation
    /// calibration (max over the set, the deployment-standard recipe).
    pub fn quantize(mlp: &Mlp, cal_inputs: &[Vec<f32>], input_max: f32) -> Self {
        assert_eq!(mlp.layers.len(), 2, "deployment expects a 2-layer MLP");
        let l1 = &mlp.layers[0];
        let l2 = &mlp.layers[1];
        let dims = [l1.w.shape[1], l1.w.shape[0], l2.w.shape[0]];

        // Transpose [out][in] → [in][out] (column per engine).
        let to_cols = |w: &Tensor| -> Tensor {
            let (o, i) = (w.shape[0], w.shape[1]);
            let mut t = Tensor::zeros(&[i, o]);
            for oo in 0..o {
                for ii in 0..i {
                    *t.at2_mut(ii, oo) = w.at2(oo, ii);
                }
            }
            t
        };
        let w1_cols = to_cols(&l1.w);
        let w2_cols = to_cols(&l2.w);
        let p1 = QuantParams::signed(w1_cols.max_abs(), 4);
        let p2 = QuantParams::signed(w2_cols.max_abs(), 4);
        let quantize_plane = |t: &Tensor, p: &QuantParams| -> Tensor {
            Tensor::from_vec(
                &t.shape,
                t.data.iter().map(|&v| p.quantize(v) as f32).collect(),
            )
        };

        // Hidden calibration: max post-ReLU activation over the cal set.
        let mut a1_cal = 1e-6f32;
        for x in cal_inputs {
            let acts = mlp.forward_trace(x);
            for &v in &acts[1] {
                a1_cal = a1_cal.max(v);
            }
        }

        Self {
            dims,
            w1_q: quantize_plane(&w1_cols, &p1),
            b1: l1.b.clone(),
            w2_q: quantize_plane(&w2_cols, &p2),
            b2: l2.b.clone(),
            a0_scale: input_max / 15.0,
            w1_scale: p1.scale,
            a1_cal,
            w2_scale: p2.scale,
        }
    }

    /// Native-path inference: the same quantized pipeline as the `mlp_fwd`
    /// artifact, executed through the tiled executor on any backend.
    pub fn run_native(
        &self,
        backend: &mut dyn CimBackend,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>, MapError> {
        let cfg: Config = backend.config().clone();
        let unit_a = QuantParams { scale: 1.0, q_min: 0, q_max: 15 };
        let unit_w = QuantParams { scale: 1.0, q_min: -7, q_max: 7 };
        let lin1 = CimLinear::with_params(
            &self.w1_q,
            vec![0.0; self.dims[1]],
            unit_w,
            unit_a,
            &cfg,
        );
        let lin2 = CimLinear::with_params(
            &self.w2_q,
            vec![0.0; self.dims[2]],
            unit_w,
            unit_a,
            &cfg,
        );

        // Layer 1: quantize input, integer product, dequant + bias + ReLU.
        let x_q: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| {
                x.iter()
                    .map(|&v| (v / self.a0_scale).round().clamp(0.0, 15.0))
                    .collect()
            })
            .collect();
        let s1 = lin1.run_batch(backend, &x_q)?;
        let a1_scale = self.a1_cal / 15.0;
        let h_q: Vec<Vec<f32>> = s1
            .iter()
            .map(|row| {
                row.iter()
                    .zip(&self.b1)
                    .map(|(&s, &b)| {
                        let y = s * (self.a0_scale * self.w1_scale) + b;
                        (y.max(0.0) / a1_scale).round().clamp(0.0, 15.0)
                    })
                    .collect()
            })
            .collect();
        // Layer 2.
        let s2 = lin2.run_batch(backend, &h_q)?;
        Ok(s2
            .iter()
            .map(|row| {
                row.iter()
                    .zip(&self.b2)
                    .map(|(&s, &b)| s * (a1_scale * self.w2_scale) + b)
                    .collect()
            })
            .collect())
    }

    /// Exact digital reference of the quantized pipeline (no macro).
    pub fn run_digital(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        xs.iter()
            .map(|x| {
                let x_q: Vec<f32> = x
                    .iter()
                    .map(|&v| (v / self.a0_scale).round().clamp(0.0, 15.0))
                    .collect();
                let mut h = vec![0f32; self.dims[1]];
                for (n, hv) in h.iter_mut().enumerate() {
                    let mut acc = 0f32;
                    for k in 0..self.dims[0] {
                        acc += x_q[k] * self.w1_q.at2(k, n);
                    }
                    let y = acc * (self.a0_scale * self.w1_scale) + self.b1[n];
                    let a1_scale = self.a1_cal / 15.0;
                    *hv = (y.max(0.0) / a1_scale).round().clamp(0.0, 15.0);
                }
                (0..self.dims[2])
                    .map(|n| {
                        let mut acc = 0f32;
                        for (k, &hv) in h.iter().enumerate() {
                            acc += hv * self.w2_q.at2(k, n);
                        }
                        acc * ((self.a1_cal / 15.0) * self.w2_scale) + self.b2[n]
                    })
                    .collect()
            })
            .collect()
    }

    /// Flattened inputs for the `mlp_fwd` artifact (scales vector order
    /// matches `python/compile/model.py`).
    pub fn scales(&self) -> [f32; 4] {
        [self.a0_scale, self.w1_scale, self.a1_cal, self.w2_scale]
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::mapping::DigitalBackend;
    use crate::nn::dataset::BlobDataset;
    use crate::nn::mlp::{train, Mlp};

    fn trained_setup() -> (Mlp, Vec<(Vec<f32>, usize)>, MlpDeployment) {
        let mut d = BlobDataset::new(12, 0.05, 17);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(250)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 32, 10], 5);
        let acc = train(&mut mlp, &data, 6, 0.05, 9);
        assert!(acc > 0.85, "float training failed: {acc}");
        let cal: Vec<Vec<f32>> = data.iter().take(50).map(|(x, _)| x.clone()).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
        (mlp, data, dep)
    }

    #[test]
    fn digital_quantized_accuracy_close_to_float() {
        let (mlp, data, dep) = trained_setup();
        let xs: Vec<Vec<f32>> = data.iter().map(|(x, _)| x.clone()).collect();
        let logits = dep.run_digital(&xs);
        let q_acc = data
            .iter()
            .zip(&logits)
            .filter(|((_, y), l)| argmax(l) == *y)
            .count() as f64
            / data.len() as f64;
        let f_acc = crate::nn::mlp::accuracy(&mlp, &data);
        assert!(
            q_acc >= f_acc - 0.1,
            "4-b quantization lost too much: float {f_acc}, quant {q_acc}"
        );
    }

    #[test]
    fn native_digital_backend_equals_run_digital() {
        let (_, data, dep) = trained_setup();
        let xs: Vec<Vec<f32>> = data.iter().take(20).map(|(x, _)| x.clone()).collect();
        let mut be = DigitalBackend::new(Config::default());
        let a = dep.run_native(&mut be, &xs).unwrap();
        let b = dep.run_digital(&xs);
        for (ra, rb) in a.iter().zip(&b) {
            for (va, vb) in ra.iter().zip(rb) {
                assert!((va - vb).abs() < 1e-3, "{va} vs {vb}");
            }
        }
    }

    #[test]
    fn weights_fit_macro_format() {
        let (_, _, dep) = trained_setup();
        for t in [&dep.w1_q, &dep.w2_q] {
            for &v in &t.data {
                assert_eq!(v, v.round());
                assert!((-7.0..=7.0).contains(&v));
            }
        }
        assert!(dep.a1_cal > 0.0);
    }
}
