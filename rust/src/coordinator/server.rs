//! Edge-inference TCP server: accepts float feature vectors, batches them
//! dynamically (size- or timeout-triggered), runs the deployed quantized
//! MLP on an [`InferenceEngine`], and streams logits back.
//!
//! Three engines ship: [`BackendEngine`] (the classic single-macro
//! `CimBackend` path, via [`serve`]), the pooled batched pipeline
//! (`pipeline::PipelineDeployment`, via [`serve_pipeline`]) which coalesces
//! up to `ServeConfig::max_batch` queued jobs into ONE pipeline call that
//! fans the batch across worker threads, and — since the graph compiler —
//! ANY compiled network ([`crate::compiler::CompiledPlan`], via
//! [`serve_plan`] / `serve --plan`), not just the two-layer MLP deployment.
//!
//! Wire protocol (little-endian):
//!   request  = u32 magic (0xC1A0_0001) | u32 n | n × f32
//!   response = u32 magic (0xC1A0_0002) | u32 n | n × f32
//! One request per round-trip per connection; connections are persistent.

use crate::config::Config;
use crate::coordinator::deployment::MlpDeployment;
use crate::coordinator::metrics::Metrics;
use crate::mapping::{CimBackend, MapError};
use crate::pipeline::PipelineDeployment;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub const REQ_MAGIC: u32 = 0xC1A0_0001;
pub const RESP_MAGIC: u32 = 0xC1A0_0002;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// Worker threads for the batched pipeline engine (0 = auto). Ignored by
    /// the single-backend engine.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { max_batch: 16, batch_timeout: Duration::from_millis(2), workers: 0 }
    }
}

/// A batch-inference engine the serve loop drives: one call per coalesced
/// batch, plus cumulative device counters the loop diffs for metrics.
pub trait InferenceEngine: Send {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError>;
    fn core_ops(&self) -> u64;
    fn energy_fj(&self) -> f64;
    fn device_cycles(&self) -> u64;
}

/// The classic path: a quantized MLP on a single `CimBackend`.
pub struct BackendEngine {
    pub dep: MlpDeployment,
    pub backend: Box<dyn CimBackend + Send>,
}

impl InferenceEngine for BackendEngine {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.dep.run_native(&mut *self.backend, xs)
    }

    fn core_ops(&self) -> u64 {
        self.backend.stats().core_ops
    }

    fn energy_fj(&self) -> f64 {
        self.backend.stats().energy_fj()
    }

    fn device_cycles(&self) -> u64 {
        self.backend.stats().total_cycles
    }
}

impl InferenceEngine for PipelineDeployment {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_batch(xs)
    }

    fn core_ops(&self) -> u64 {
        self.stats().core_ops
    }

    fn energy_fj(&self) -> f64 {
        self.stats().energy_fj()
    }

    fn device_cycles(&self) -> u64 {
        self.stats().total_cycles
    }
}

/// Any compiled network is a serving engine: requests are flat feature
/// vectors reshaped to the plan's input shape.
impl InferenceEngine for crate::compiler::CompiledPlan {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_flat(xs)
    }

    fn core_ops(&self) -> u64 {
        self.stats().core_ops
    }

    fn energy_fj(&self) -> f64 {
        self.stats().energy_fj()
    }

    fn device_cycles(&self) -> u64 {
        self.stats().total_cycles
    }
}

struct Job {
    input: Vec<f32>,
    reply: Sender<Vec<f32>>,
}

/// Handle to a running server.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<Metrics>>,
}

impl ServerHandle {
    /// Stop the server and return its accumulated metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop.
        let _ = TcpStream::connect(self.addr);
        self.join.take().map(|j| j.join().expect("server thread")).unwrap_or_default()
    }
}

/// Start serving on an ephemeral local port with the classic single-backend
/// engine. The backend and deployment move into the inference thread.
pub fn serve(
    deployment: MlpDeployment,
    backend: Box<dyn CimBackend + Send>,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    serve_engine(Box::new(BackendEngine { dep: deployment, backend }), cfg)
}

/// Batched pipeline serving: builds a `PipelineDeployment` (weights placed
/// once on a macro pool) and coalesces queued jobs — up to
/// `ServeConfig::max_batch` per window — into one pooled pipeline call.
pub fn serve_pipeline(
    deployment: MlpDeployment,
    sim_cfg: Config,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    let engine =
        PipelineDeployment::new(deployment, sim_cfg, cfg.workers).map_err(std::io::Error::other)?;
    serve_engine(Box::new(engine), cfg)
}

/// Serve any compiled network: the plan (weights already resident on its
/// pool) becomes the batch-inference engine behind the dynamic batcher —
/// the `serve --plan` path.
///
/// Note: a plan's worker-thread count is a compile-time property
/// (`CompileOptions::workers`); `ServeConfig::workers` is ignored on this
/// path (it only configures engines the server builds itself, as
/// [`serve_pipeline`] does).
pub fn serve_plan(
    plan: crate::compiler::CompiledPlan,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    serve_engine(Box::new(plan), cfg)
}

/// Start serving on an ephemeral local port with any [`InferenceEngine`].
pub fn serve_engine(
    mut engine: Box<dyn InferenceEngine>,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let (job_tx, job_rx) = channel::<Job>();

    // Inference thread: dynamic batcher + device.
    let stop_inf = stop.clone();
    let inference = std::thread::spawn(move || {
        let mut metrics = Metrics::default();
        let t_start = Instant::now();
        loop {
            let batch = collect_batch(&job_rx, &cfg, &stop_inf);
            if batch.is_empty() {
                if stop_inf.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            let t0 = Instant::now();
            let inputs: Vec<Vec<f32>> = batch.iter().map(|j| j.input.clone()).collect();
            let ops_before = engine.core_ops();
            let energy_before = engine.energy_fj();
            let cycles_before = engine.device_cycles();
            match engine.infer_batch(&inputs) {
                Ok(logits) => {
                    for (job, row) in batch.iter().zip(logits) {
                        let _ = job.reply.send(row);
                    }
                }
                Err(e) => {
                    // A single malformed input must not poison the whole
                    // coalesced batch: retry each job alone so only the
                    // offending request gets an empty reply.
                    eprintln!("batch inference error: {e}; retrying jobs individually");
                    for job in &batch {
                        let row = engine
                            .infer_batch(std::slice::from_ref(&job.input))
                            .ok()
                            .and_then(|mut rows| rows.pop())
                            .unwrap_or_default();
                        let _ = job.reply.send(row);
                    }
                }
            }
            metrics.record_batch(batch.len(), t0.elapsed());
            metrics.core_ops += engine.core_ops() - ops_before;
            metrics.energy_fj += engine.energy_fj() - energy_before;
            metrics.device_cycles += engine.device_cycles() - cycles_before;
        }
        metrics.wall = t_start.elapsed();
        metrics
    });

    // Accept loop thread.
    let stop_acc = stop.clone();
    let join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            if stop_acc.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let tx = job_tx.clone();
                    std::thread::spawn(move || {
                        let _ = handle_connection(s, tx);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
        }
        drop(job_tx);
        inference.join().expect("inference thread")
    });

    Ok(ServerHandle { addr, stop, join: Some(join) })
}

fn collect_batch(rx: &Receiver<Job>, cfg: &ServeConfig, stop: &AtomicBool) -> Vec<Job> {
    let mut batch = Vec::new();
    // Block for the first job (with a stop-poll heartbeat)...
    loop {
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(job) => {
                batch.push(job);
                break;
            }
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    return batch;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return batch,
        }
    }
    // ... then fill until max_batch or the batching window closes.
    let deadline = Instant::now() + cfg.batch_timeout;
    while batch.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(job) => batch.push(job),
            Err(_) => break,
        }
    }
    batch
}

fn handle_connection(mut s: TcpStream, jobs: Sender<Job>) -> std::io::Result<()> {
    s.set_nodelay(true)?;
    loop {
        let mut head = [0u8; 8];
        if s.read_exact(&mut head).is_err() {
            return Ok(()); // client hung up
        }
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        if magic != REQ_MAGIC || n > 1 << 20 {
            return Ok(()); // protocol error: drop connection
        }
        let mut buf = vec![0u8; n * 4];
        s.read_exact(&mut buf)?;
        let input: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let (reply_tx, reply_rx) = channel();
        if jobs.send(Job { input, reply: reply_tx }).is_err() {
            return Ok(()); // server stopping
        }
        let logits = reply_rx.recv().unwrap_or_default();
        let mut out = Vec::with_capacity(8 + logits.len() * 4);
        out.extend_from_slice(&RESP_MAGIC.to_le_bytes());
        out.extend_from_slice(&(logits.len() as u32).to_le_bytes());
        for v in &logits {
            out.extend_from_slice(&v.to_le_bytes());
        }
        s.write_all(&out)?;
    }
}

/// Blocking client for the wire protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    pub fn infer(&mut self, x: &[f32]) -> std::io::Result<Vec<f32>> {
        let mut msg = Vec::with_capacity(8 + x.len() * 4);
        msg.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        msg.extend_from_slice(&(x.len() as u32).to_le_bytes());
        for v in x {
            msg.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&msg)?;
        let mut head = [0u8; 8];
        self.stream.read_exact(&mut head)?;
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        if magic != RESP_MAGIC {
            return Err(std::io::Error::other("bad response magic"));
        }
        let mut buf = vec![0u8; n * 4];
        self.stream.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::coordinator::deployment::argmax;
    use crate::mapping::DigitalBackend;
    use crate::nn::dataset::BlobDataset;
    use crate::nn::mlp::{train, Mlp};

    #[test]
    fn end_to_end_serve_roundtrip() {
        let mut d = BlobDataset::new(12, 0.05, 3);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(200)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 32, 10], 5);
        train(&mut mlp, &data, 6, 0.05, 9);
        let cal: Vec<Vec<f32>> = data.iter().take(40).map(|(x, _)| x.clone()).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
        let expected = dep.run_digital(&[data[0].0.clone()]);

        let backend = Box::new(DigitalBackend::new(Config::default()));
        let handle = serve(dep, backend, ServeConfig::default()).unwrap();

        let mut client = Client::connect(handle.addr).unwrap();
        let logits = client.infer(&data[0].0).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(argmax(&logits), argmax(&expected[0]));

        // Concurrent clients exercise the batcher.
        let addr = handle.addr;
        let mut joins = Vec::new();
        for t in 0..4 {
            let x = data[t + 1].0.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for _ in 0..5 {
                    let l = c.infer(&x).unwrap();
                    assert_eq!(l.len(), 10);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }

        let metrics = handle.shutdown();
        assert!(metrics.requests >= 21, "requests {}", metrics.requests);
        let report = metrics.report(200e6);
        assert!(report.throughput_rps > 0.0);
    }

    /// The pooled pipeline front-end answers the wire protocol with the same
    /// logits as a direct (noise-free) pipeline call.
    #[test]
    fn pipeline_serve_roundtrip() {
        let mut d = BlobDataset::new(12, 0.05, 8);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(150)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 32, 10], 2);
        train(&mut mlp, &data, 4, 0.05, 3);
        let cal: Vec<Vec<f32>> = data.iter().take(30).map(|(x, _)| x.clone()).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);

        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let expected = {
            let mut pipe =
                crate::pipeline::PipelineDeployment::new(dep.clone(), cfg.clone(), 2).unwrap();
            pipe.run_batch(&[data[0].0.clone()]).unwrap()
        };

        let handle = serve_pipeline(
            dep,
            cfg,
            ServeConfig { workers: 2, ..ServeConfig::default() },
        )
        .unwrap();
        let mut client = Client::connect(handle.addr).unwrap();
        let logits = client.infer(&data[0].0).unwrap();
        assert_eq!(logits, expected[0]);

        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 1);
        assert!(metrics.core_ops > 0);
        assert!(metrics.energy_fj > 0.0);
    }

    /// A graph-compiled MLP behind the wire protocol answers with the same
    /// logits as a direct (noise-free) plan invocation.
    #[test]
    fn compiled_plan_serve_roundtrip() {
        use crate::compiler::{compile, CompileOptions, Graph};
        use crate::nn::tensor::Tensor;

        let mut d = BlobDataset::new(12, 0.05, 13);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(120)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 16, 10], 6);
        train(&mut mlp, &data, 3, 0.05, 7);
        let cal: Vec<Tensor> = data
            .iter()
            .take(20)
            .map(|(x, _)| Tensor::from_vec(&[144], x.clone()))
            .collect();

        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let graph = Graph::from_mlp(&mlp);
        let opts = CompileOptions { workers: 2, ..Default::default() };
        let expected = {
            let mut plan = compile(graph.clone(), &cal, &cfg, &opts).unwrap();
            plan.run_flat(&[data[0].0.clone()]).unwrap()
        };

        let plan = compile(graph, &cal, &cfg, &opts).unwrap();
        let handle = serve_plan(plan, ServeConfig::default()).unwrap();
        let mut client = Client::connect(handle.addr).unwrap();
        let logits = client.infer(&data[0].0).unwrap();
        assert_eq!(logits, expected[0]);
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 1);
        assert!(metrics.core_ops > 0);
    }
}
