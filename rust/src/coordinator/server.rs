//! Edge-inference TCP server: accepts float feature vectors, batches them
//! dynamically (size- or timeout-triggered), runs the deployed network on
//! an [`InferenceEngine`], and streams logits back.
//!
//! The batch front-ends ([`serve`], [`serve_pipeline`], [`serve_plan`])
//! share ONE runtime (DESIGN.md §9): a **bounded admission queue**
//! ([`crate::sched::BoundedQueue`]) that connection handlers push into —
//! blocking when full, which is backpressure all the way to the TCP client
//! — and a batcher thread that coalesces up to [`ServeConfig::max_batch`]
//! admitted jobs per [`ServeConfig::max_wait`] window into one engine
//! call. With [`ServeConfig::stream`] set, plan-backed engines execute
//! each coalesced batch through the streaming scheduler
//! ([`crate::compiler::CompiledPlan::run_streamed`]), so items pipeline
//! across the network's layers; per-stage occupancy and queue gauges land
//! in [`Metrics`].
//!
//! **Graceful drain.** [`ServerHandle::shutdown`] stops accepting new
//! connections and closes the admission queue — which, by the queue's
//! drain contract, refuses *new* requests (they get an empty-logits reply)
//! but completes **everything already admitted** before the server returns
//! its metrics. Queued-but-unserved work is never dropped.
//!
//! [`serve_decode`] reuses the same queue, wire protocol, and drain
//! contract for autoregressive generation, but replaces the coalescing
//! batcher with token-level continuous batching (DESIGN.md §13).
//!
//! Wire protocol (little-endian):
//!   request  = u32 magic (0xC1A0_0001) | u32 n | n × f32
//!   response = u32 magic (0xC1A0_0002) | u32 n | n × f32
//! One request per round-trip per connection; connections are persistent.
//! An empty response (`n == 0`) means the request was refused (shutdown in
//! progress) or failed individually.

use crate::config::Config;
use crate::coordinator::deployment::MlpDeployment;
use crate::coordinator::metrics::Metrics;
use crate::mapping::{CimBackend, MapError};
use crate::pipeline::PipelineDeployment;
use crate::sched::{BoundedQueue, StageGauge};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub const REQ_MAGIC: u32 = 0xC1A0_0001;
pub const RESP_MAGIC: u32 = 0xC1A0_0002;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Most requests one coalesced batch may hold.
    pub max_batch: usize,
    /// Longest the batcher waits to fill a batch after its first job
    /// (bounds added latency under light load).
    pub max_wait: Duration,
    /// Admission queue capacity: requests beyond it block their connection
    /// handler (backpressure to the client) instead of growing memory.
    pub max_queue: usize,
    /// Worker threads for engines the server builds itself (0 = auto).
    pub workers: usize,
    /// Execute coalesced batches through the streaming scheduler
    /// (layer-pipelined; plan-backed engines only — the classic
    /// single-backend engine falls back to the barrier path).
    pub stream: bool,
    /// Bind a metrics HTTP side listener here (e.g. `"127.0.0.1:9184"`,
    /// port 0 for ephemeral — resolve with `ServerHandle::metrics_addr`):
    /// `GET /metrics` (Prometheus text) and `GET /metrics.json` serve the
    /// global telemetry registry while the server is live (DESIGN.md §12).
    pub metrics_addr: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            max_queue: 256,
            workers: 0,
            stream: false,
            metrics_addr: None,
        }
    }
}

impl ServeConfig {
    /// Start a builder at the defaults — the one construction path for
    /// serve configuration (`ServeConfig { .. }` literals and the
    /// positional entry points are deprecated in its favor).
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }
}

/// Builder for [`ServeConfig`], with [`ServeConfigBuilder::serve`] as the
/// unified typed-front-end entry point:
///
/// ```no_run
/// use cimsim::coordinator::{ServeConfig, ServeFrontend};
/// # fn demo(plan: cimsim::compiler::CompiledPlan) -> std::io::Result<()> {
/// let handle = ServeConfig::builder()
///     .max_batch(32)
///     .stream(true)
///     .serve(ServeFrontend::Plan(plan))?;
/// # drop(handle); Ok(()) }
/// ```
#[derive(Clone, Debug)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    /// Most requests one coalesced batch may hold.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Longest the batcher waits to fill a batch after its first job.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.cfg.max_wait = d;
        self
    }

    /// Admission queue capacity (backpressure bound).
    pub fn max_queue(mut self, n: usize) -> Self {
        self.cfg.max_queue = n;
        self
    }

    /// Worker threads for engines the server builds itself (0 = auto).
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    /// Execute coalesced batches through the streaming scheduler.
    pub fn stream(mut self, on: bool) -> Self {
        self.cfg.stream = on;
        self
    }

    /// Bind a metrics HTTP side listener (DESIGN.md §12).
    pub fn metrics_addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.metrics_addr = Some(addr.into());
        self
    }

    /// Option-valued variant of [`ServeConfigBuilder::metrics_addr`] for
    /// callers plumbing an optional CLI flag through.
    pub fn metrics_addr_opt(mut self, addr: Option<String>) -> Self {
        self.cfg.metrics_addr = addr;
        self
    }

    /// Finish without serving (for call sites that hold a config).
    pub fn build(self) -> ServeConfig {
        self.cfg
    }

    /// Start serving `frontend` on an ephemeral local port with this
    /// configuration.
    pub fn serve(self, frontend: ServeFrontend) -> std::io::Result<ServerHandle> {
        serve_frontend(frontend, self.cfg)
    }
}

/// What to serve — the typed selection the four positional entry points
/// (`serve`, `serve_pipeline`, `serve_plan`, `serve_decode`) used to
/// encode by function name.
pub enum ServeFrontend {
    /// The classic path: a quantized MLP on a single `CimBackend`.
    Backend { deployment: MlpDeployment, backend: Box<dyn CimBackend + Send> },
    /// Pooled batched pipeline (weights placed once on a macro pool).
    Pipeline { deployment: MlpDeployment, sim: Config },
    /// Any graph-compiled plan (weights resident on its pool).
    Plan(crate::compiler::CompiledPlan),
    /// Autoregressive decode with token-level continuous batching
    /// (DESIGN.md §13); `max_batch` is the slot count.
    Decode(crate::compiler::DecodePlan),
    /// A custom [`InferenceEngine`].
    Engine(Box<dyn InferenceEngine>),
}

/// Serve a typed front end — the single dispatch behind
/// [`ServeConfigBuilder::serve`] and the deprecated positional wrappers.
pub fn serve_frontend(frontend: ServeFrontend, cfg: ServeConfig) -> std::io::Result<ServerHandle> {
    match frontend {
        ServeFrontend::Backend { deployment, backend } => {
            serve_engine(Box::new(BackendEngine { dep: deployment, backend }), cfg)
        }
        ServeFrontend::Pipeline { deployment, sim } => {
            let engine = PipelineDeployment::new(deployment, sim, cfg.workers)
                .map_err(std::io::Error::other)?;
            serve_engine(Box::new(engine), cfg)
        }
        ServeFrontend::Plan(plan) => serve_engine(Box::new(plan), cfg),
        ServeFrontend::Decode(plan) => serve_decode_impl(plan, cfg),
        ServeFrontend::Engine(engine) => serve_engine(engine, cfg),
    }
}

/// A batch-inference engine the serve loop drives: one call per coalesced
/// batch, plus cumulative device counters the loop diffs for metrics.
pub trait InferenceEngine: Send {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError>;

    /// Streamed (layer-pipelined) batch execution; engines without a
    /// streaming path fall back to the barrier call.
    fn infer_batch_streamed(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.infer_batch(xs)
    }

    fn core_ops(&self) -> u64;
    fn energy_fj(&self) -> f64;
    fn device_cycles(&self) -> u64;

    /// Cumulative weight tile loads + dynamic reloads (0 for engines that
    /// don't track them).
    fn weight_loads(&self) -> u64 {
        0
    }

    /// Cumulative per-stage gauges (streamed plans; empty otherwise).
    fn stage_gauges(&self) -> Vec<StageGauge> {
        Vec::new()
    }

    /// Peak number of simultaneously busy pipeline stages (0 when the
    /// engine never streamed).
    fn peak_stages_busy(&self) -> u64 {
        0
    }
}

/// The classic path: a quantized MLP on a single `CimBackend`.
pub struct BackendEngine {
    pub dep: MlpDeployment,
    pub backend: Box<dyn CimBackend + Send>,
}

impl InferenceEngine for BackendEngine {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.dep.run_native(&mut *self.backend, xs)
    }

    fn core_ops(&self) -> u64 {
        self.backend.stats().core_ops
    }

    fn energy_fj(&self) -> f64 {
        self.backend.stats().energy_fj()
    }

    fn device_cycles(&self) -> u64 {
        self.backend.stats().total_cycles
    }

    fn weight_loads(&self) -> u64 {
        self.backend.stats().weight_loads
    }
}

impl InferenceEngine for PipelineDeployment {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_batch(xs)
    }

    fn infer_batch_streamed(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_batch_streamed(xs)
    }

    fn core_ops(&self) -> u64 {
        self.stats().core_ops
    }

    fn energy_fj(&self) -> f64 {
        self.stats().energy_fj()
    }

    fn device_cycles(&self) -> u64 {
        self.stats().total_cycles
    }

    fn weight_loads(&self) -> u64 {
        self.stats().weight_loads
    }

    fn stage_gauges(&self) -> Vec<StageGauge> {
        self.plan().stream_gauges().to_vec()
    }

    fn peak_stages_busy(&self) -> u64 {
        self.plan().stream_peak_busy() as u64
    }
}

/// Any compiled network is a serving engine: requests are flat feature
/// vectors reshaped to the plan's input shape.
impl InferenceEngine for crate::compiler::CompiledPlan {
    fn infer_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_flat(xs)
    }

    fn infer_batch_streamed(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.run_streamed_flat(xs)
    }

    fn core_ops(&self) -> u64 {
        self.stats().core_ops
    }

    fn energy_fj(&self) -> f64 {
        self.stats().energy_fj()
    }

    fn device_cycles(&self) -> u64 {
        self.stats().total_cycles
    }

    fn weight_loads(&self) -> u64 {
        self.stats().weight_loads
    }

    fn stage_gauges(&self) -> Vec<StageGauge> {
        self.stream_gauges().to_vec()
    }

    fn peak_stages_busy(&self) -> u64 {
        self.stream_peak_busy() as u64
    }
}

struct Job {
    input: Vec<f32>,
    reply: Sender<Vec<f32>>,
    admitted: Instant,
}

/// Handle to a running server.
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    jobs: Arc<BoundedQueue<Job>>,
    join: Option<std::thread::JoinHandle<()>>,
    /// Serve-loop metrics, shared with the inference thread so they are
    /// pollable live ([`ServerHandle::metrics_snapshot`]).
    metrics: Arc<Mutex<Metrics>>,
    started: Instant,
    exporter: Option<crate::telemetry::export::ExporterHandle>,
}

impl ServerHandle {
    /// Stop the server and return its accumulated metrics. New requests are
    /// refused from here on; everything already admitted to the queue is
    /// completed first (graceful drain — regression-tested in
    /// `tests/stream_equivalence.rs`).
    pub fn shutdown(mut self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop; it closes the admission queue once it
        // stops, which drains the batcher.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            j.join().expect("server thread");
        }
        if let Some(e) = self.exporter.take() {
            e.shutdown();
        }
        self.metrics.lock().expect("metrics poisoned").clone()
    }

    /// Live metrics without stopping the server: a clone of the serve-loop
    /// counters so far, with `wall` set to the current uptime. Drain-time
    /// fields (stage gauges, peak queue depth, peak busy stages) are
    /// finalized by [`ServerHandle::shutdown`] and read 0/empty here.
    pub fn metrics_snapshot(&self) -> Metrics {
        let mut m = self.metrics.lock().expect("metrics poisoned").clone();
        m.wall = self.started.elapsed();
        m
    }

    /// Address of the metrics HTTP listener, when `metrics_addr` was
    /// configured (resolves port 0 to the actual bound port).
    pub fn metrics_addr(&self) -> Option<std::net::SocketAddr> {
        self.exporter.as_ref().map(|e| e.addr)
    }

    /// Requests admitted to the queue so far (each is guaranteed an answer
    /// even across shutdown).
    pub fn admitted(&self) -> u64 {
        self.jobs.pushed()
    }

    /// Requests currently waiting in the admission queue.
    pub fn queue_depth(&self) -> usize {
        self.jobs.len()
    }
}

/// Start serving on an ephemeral local port with the classic single-backend
/// engine. The backend and deployment move into the inference thread.
#[deprecated(note = "use `ServeConfig::builder().serve(ServeFrontend::Backend { .. })`")]
pub fn serve(
    deployment: MlpDeployment,
    backend: Box<dyn CimBackend + Send>,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    serve_frontend(ServeFrontend::Backend { deployment, backend }, cfg)
}

/// Batched pipeline serving: builds a `PipelineDeployment` (weights placed
/// once on a macro pool) and coalesces queued jobs — up to
/// `ServeConfig::max_batch` per window — into one pooled pipeline call
/// (streamed through the plan scheduler when `cfg.stream` is set).
#[deprecated(note = "use `ServeConfig::builder().serve(ServeFrontend::Pipeline { .. })`")]
pub fn serve_pipeline(
    deployment: MlpDeployment,
    sim_cfg: Config,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    serve_frontend(ServeFrontend::Pipeline { deployment, sim: sim_cfg }, cfg)
}

/// Serve any compiled network: the plan (weights already resident on its
/// pool) becomes the batch-inference engine behind the dynamic batcher —
/// the `serve --plan` / `serve --stream` path.
///
/// Note: a plan's worker-thread count is a compile-time property
/// (`CompileOptions::workers`); `ServeConfig::workers` is ignored on this
/// path (it only configures engines the server builds itself, as
/// [`serve_pipeline`] does).
#[deprecated(note = "use `ServeConfig::builder().serve(ServeFrontend::Plan(plan))`")]
pub fn serve_plan(
    plan: crate::compiler::CompiledPlan,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    serve_frontend(ServeFrontend::Plan(plan), cfg)
}

/// Autoregressive decode serving (DESIGN.md §13): the inference thread
/// runs token-level **continuous batching** over a
/// [`crate::compiler::DecodePlan`] — every round advances each active
/// sequence by one token, new requests join between rounds whenever a
/// slot is free (admission never stalls generation: the queue is polled,
/// not awaited, while sequences are active), and finished sequences free
/// their slot immediately. `ServeConfig::max_batch` is the slot count;
/// `ServeConfig::stream` pipelines each round across the decoder's layers
/// via the staged scheduler. Graceful drain: shutdown stops admissions
/// but every admitted sequence decodes to completion.
///
/// Wire payload over the shared protocol: request = `[n_gen, prompt
/// token ids...]` as f32; reply = the generated token ids as f32 (empty
/// = refused or malformed). Sequences are deterministic per admission
/// index (DESIGN.md §9/§13), so sequential requests replay bit-exactly.
#[deprecated(note = "use `ServeConfig::builder().serve(ServeFrontend::Decode(plan))`")]
pub fn serve_decode(
    plan: crate::compiler::DecodePlan,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    serve_frontend(ServeFrontend::Decode(plan), cfg)
}

fn serve_decode_impl(
    plan: crate::compiler::DecodePlan,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    use crate::compiler::ContinuousBatcher;
    use std::collections::HashMap;

    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let jobs: Arc<BoundedQueue<Job>> = Arc::new(BoundedQueue::new(cfg.max_queue));
    let metrics = Arc::new(Mutex::new(Metrics::default()));
    let started = Instant::now();
    let exporter = match cfg.metrics_addr.as_deref() {
        Some(bind) => Some(crate::telemetry::export::spawn_exporter(bind)?),
        None => None,
    };

    let reg = crate::telemetry::global();
    let tele_requests =
        reg.counter("cim_serve_requests_total", "Requests answered by the serve loop");
    let tele_queue =
        reg.gauge("cim_serve_queue_depth", "Admission-queue depth at last batch pull");
    let tele_wait_us = reg.histogram(
        "cim_wait_latency_us",
        "Per-request queue wait (admission to batch start), microseconds",
    );

    struct Pending {
        reply: Sender<Vec<f32>>,
        admitted: Instant,
    }

    let jobs_inf = jobs.clone();
    let metrics_inf = metrics.clone();
    let serve_cfg = cfg;
    let inference = std::thread::spawn(move || {
        let t_start = Instant::now();
        let slots = serve_cfg.max_batch.max(1);
        let mut batcher = ContinuousBatcher::new(&plan, slots, serve_cfg.stream, slots);
        let mut pending: HashMap<u64, Pending> = HashMap::new();
        let mut closed = false;
        loop {
            // Admission window between token rounds: block when idle, poll
            // when generating — requests join mid-generation without ever
            // stalling the active sequences' token cadence.
            while !closed && batcher.has_free_slot() {
                let job = if batcher.active() == 0 {
                    match jobs_inf.pop() {
                        Some(j) => Some(j),
                        None => {
                            closed = true; // queue closed and drained
                            None
                        }
                    }
                } else {
                    jobs_inf.pop_deadline(Instant::now())
                };
                let Some(job) = job else { break };
                tele_queue.set(jobs_inf.len() as i64);
                match parse_decode_request(&job.input, &plan) {
                    Some(req) => {
                        let id = batcher.next_session_id();
                        match batcher.admit(req) {
                            Ok(Some(_slot)) => {
                                let wait = job.admitted.elapsed();
                                tele_wait_us.observe(wait.as_micros() as u64);
                                metrics_inf.lock().expect("metrics poisoned").record_wait(wait);
                                pending.insert(
                                    id,
                                    Pending { reply: job.reply, admitted: job.admitted },
                                );
                            }
                            // has_free_slot() held, so a full batcher is
                            // unreachable; refuse defensively either way.
                            Ok(None) => {
                                let _ = job.reply.send(Vec::new());
                            }
                            Err(e) => {
                                eprintln!("decode admission error: {e}");
                                let _ = job.reply.send(Vec::new());
                            }
                        }
                    }
                    None => {
                        // Malformed request: empty reply, connection lives.
                        let _ = job.reply.send(Vec::new());
                    }
                }
            }
            if batcher.active() == 0 {
                if closed {
                    break;
                }
                continue;
            }
            let _span = crate::span!("decode_round", "active" => batcher.active());
            match batcher.step_all() {
                Ok(finished) => {
                    for f in finished {
                        let Some(p) = pending.remove(&f.session_id) else { continue };
                        // Account BEFORE the reply goes out: a client that
                        // scrapes /metrics right after its reply must
                        // already see its sequence in every counter.
                        {
                            let mut m = metrics_inf.lock().expect("metrics poisoned");
                            m.record_batch(1, p.admitted.elapsed());
                            m.core_ops += f.stats.core_ops;
                            m.energy_fj += f.stats.energy_fj();
                            m.device_cycles += f.stats.total_cycles;
                            m.weight_loads += f.stats.weight_loads;
                        }
                        tele_requests.inc();
                        let out: Vec<f32> = f.generated.iter().map(|&t| t as f32).collect();
                        let _ = p.reply.send(out);
                    }
                }
                Err(e) => {
                    // A failed round poisons every in-flight sequence:
                    // refuse them all and start a fresh batcher.
                    eprintln!("decode round error: {e}; dropping active sequences");
                    for (_, p) in pending.drain() {
                        let _ = p.reply.send(Vec::new());
                    }
                    batcher = ContinuousBatcher::new(&plan, slots, serve_cfg.stream, slots);
                }
            }
        }
        let mut m = metrics_inf.lock().expect("metrics poisoned");
        m.peak_queue_depth = jobs_inf.peak_depth() as u64;
        m.wall = t_start.elapsed();
    });

    let stop_acc = stop.clone();
    let jobs_acc = jobs.clone();
    let join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let stopping = stop_acc.load(Ordering::SeqCst);
            match stream {
                Ok(s) => {
                    let q = jobs_acc.clone();
                    std::thread::spawn(move || {
                        let _ = handle_connection(s, &q);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
            if stopping {
                break;
            }
        }
        jobs_acc.close();
        inference.join().expect("inference thread");
    });

    Ok(ServerHandle { addr, stop, jobs, join: Some(join), metrics, started, exporter })
}

/// Decode-request payload: `[n_gen, prompt tokens...]`, every value a
/// non-negative integer-valued f32, tokens inside the vocabulary, and the
/// sequence's total step count within the model's context window.
fn parse_decode_request(
    input: &[f32],
    plan: &crate::compiler::DecodePlan,
) -> Option<crate::compiler::DecodeRequest> {
    let int_ok = |v: f32| v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v < (1u32 << 24) as f32;
    if input.len() < 2 || !int_ok(input[0]) {
        return None;
    }
    let n_gen = input[0] as usize;
    let vocab = plan.model().vocab;
    let mut prompt = Vec::with_capacity(input.len() - 1);
    for &v in &input[1..] {
        if !int_ok(v) || (v as usize) >= vocab {
            return None;
        }
        prompt.push(v as usize);
    }
    // Steps consumed = prompt + generated-and-fed-back tokens.
    if prompt.len() + n_gen.saturating_sub(1) > plan.max_seq() {
        return None;
    }
    Some(crate::compiler::DecodeRequest { prompt, n_gen })
}

/// Start serving on an ephemeral local port with any [`InferenceEngine`].
pub fn serve_engine(
    mut engine: Box<dyn InferenceEngine>,
    cfg: ServeConfig,
) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let jobs: Arc<BoundedQueue<Job>> = Arc::new(BoundedQueue::new(cfg.max_queue));
    let metrics = Arc::new(Mutex::new(Metrics::default()));
    let started = Instant::now();

    // Metrics HTTP side listener (scrapes the global telemetry registry);
    // a bad bind address fails server startup, not silently.
    let exporter = match cfg.metrics_addr.as_deref() {
        Some(bind) => Some(crate::telemetry::export::spawn_exporter(bind)?),
        None => None,
    };

    // Serve-loop series on the global registry (DESIGN.md §12). Handles
    // are resolved once here and moved into the inference thread.
    let reg = crate::telemetry::global();
    let tele_requests =
        reg.counter("cim_serve_requests_total", "Requests answered by the serve loop");
    let tele_batches = reg.counter("cim_serve_batches_total", "Coalesced batches executed");
    let tele_queue =
        reg.gauge("cim_serve_queue_depth", "Admission-queue depth at last batch pull");
    let tele_exec_us = reg.histogram(
        "cim_exec_latency_us",
        "Per-batch execution latency (batch start to done), microseconds",
    );
    let tele_wait_us = reg.histogram(
        "cim_wait_latency_us",
        "Per-request queue wait (admission to batch start), microseconds",
    );

    // Inference thread: dynamic batcher + device. Exits when the admission
    // queue is closed AND drained — the graceful-drain contract.
    let jobs_inf = jobs.clone();
    let metrics_inf = metrics.clone();
    let inference = std::thread::spawn(move || {
        let t_start = Instant::now();
        loop {
            let batch = collect_batch(&jobs_inf, &cfg);
            if batch.is_empty() {
                break; // closed and drained
            }
            tele_queue.set(jobs_inf.len() as i64);
            let _span = crate::span!("serve_batch", "items" => batch.len());
            let t0 = Instant::now();
            for job in &batch {
                let wait = t0.duration_since(job.admitted);
                tele_wait_us.observe(wait.as_micros() as u64);
                crate::telemetry::trace::record_complete(
                    "queue_wait",
                    job.admitted,
                    wait.as_micros() as u64,
                );
            }
            let inputs: Vec<Vec<f32>> = batch.iter().map(|j| j.input.clone()).collect();
            let ops_before = engine.core_ops();
            let energy_before = engine.energy_fj();
            let cycles_before = engine.device_cycles();
            let loads_before = engine.weight_loads();
            let result = if cfg.stream {
                engine.infer_batch_streamed(&inputs)
            } else {
                engine.infer_batch(&inputs)
            };
            let rows = match result {
                Ok(logits) => logits,
                Err(e) => {
                    // A single malformed input must not poison the whole
                    // coalesced batch: retry each job alone so only the
                    // offending request gets an empty reply.
                    eprintln!("batch inference error: {e}; retrying jobs individually");
                    batch
                        .iter()
                        .map(|job| {
                            engine
                                .infer_batch(std::slice::from_ref(&job.input))
                                .ok()
                                .and_then(|mut rows| rows.pop())
                                .unwrap_or_default()
                        })
                        .collect()
                }
            };
            let latency = t0.elapsed();
            // Account BEFORE sending replies: a client that scrapes
            // `/metrics` right after its reply must already see its batch
            // in every counter (the e2e exactness test depends on this).
            {
                let mut m = metrics_inf.lock().expect("metrics poisoned");
                m.record_batch(batch.len(), latency);
                for job in &batch {
                    m.record_wait(t0.duration_since(job.admitted));
                }
                m.core_ops += engine.core_ops() - ops_before;
                m.energy_fj += engine.energy_fj() - energy_before;
                m.device_cycles += engine.device_cycles() - cycles_before;
                m.weight_loads += engine.weight_loads() - loads_before;
            }
            tele_requests.add(batch.len() as u64);
            tele_batches.inc();
            tele_exec_us.observe(latency.as_micros() as u64);
            for (job, row) in batch.iter().zip(rows) {
                let _ = job.reply.send(row);
            }
        }
        let mut m = metrics_inf.lock().expect("metrics poisoned");
        m.peak_queue_depth = jobs_inf.peak_depth() as u64;
        m.stages = engine.stage_gauges();
        m.peak_stages_busy = engine.peak_stages_busy();
        m.wall = t_start.elapsed();
    });

    // Accept loop thread. On stop it closes the admission queue: new pushes
    // are refused (empty reply), the batcher drains what was admitted. A
    // connection that raced the shutdown nudge still gets a handler, so its
    // requests take the refusal path instead of a silent TCP close (only
    // connections never accepted — still in the OS backlog — are dropped).
    let stop_acc = stop.clone();
    let jobs_acc = jobs.clone();
    let join = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let stopping = stop_acc.load(Ordering::SeqCst);
            match stream {
                Ok(s) => {
                    let q = jobs_acc.clone();
                    std::thread::spawn(move || {
                        let _ = handle_connection(s, &q);
                    });
                }
                Err(e) => eprintln!("accept error: {e}"),
            }
            if stopping {
                break;
            }
        }
        jobs_acc.close();
        inference.join().expect("inference thread");
    });

    Ok(ServerHandle { addr, stop, jobs, join: Some(join), metrics, started, exporter })
}

/// Pull one batch off the admission queue: block for the first job, then
/// fill until `max_batch` or the `max_wait` window closes. Empty only when
/// the queue is closed and fully drained.
fn collect_batch(jobs: &BoundedQueue<Job>, cfg: &ServeConfig) -> Vec<Job> {
    let mut batch = Vec::new();
    match jobs.pop() {
        Some(job) => batch.push(job),
        None => return batch,
    }
    let deadline = Instant::now() + cfg.max_wait;
    while batch.len() < cfg.max_batch {
        match jobs.pop_deadline(deadline) {
            Some(job) => batch.push(job),
            None => break,
        }
    }
    batch
}

fn handle_connection(mut s: TcpStream, jobs: &BoundedQueue<Job>) -> std::io::Result<()> {
    s.set_nodelay(true)?;
    // Per-connection request/response buffers, reused across the keep-alive
    // loop: after the first request a connection's steady state allocates
    // only the Job's input vector it hands off (the job outlives this frame
    // — DESIGN.md §14).
    let mut buf: Vec<u8> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    loop {
        let mut head = [0u8; 8];
        if s.read_exact(&mut head).is_err() {
            return Ok(()); // client hung up
        }
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        if magic != REQ_MAGIC || n > 1 << 20 {
            return Ok(()); // protocol error: drop connection
        }
        buf.resize(n * 4, 0);
        s.read_exact(&mut buf)?;
        let input: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let (reply_tx, reply_rx) = channel();
        // Blocking push = backpressure: a full admission queue holds the
        // connection (and thus the client) until a slot frees up. Refusal
        // (queue closed at shutdown) is the push's Err — an individually
        // failed request also gets an empty reply, but keeps its connection.
        let (logits, refused) =
            match jobs.push(Job { input, reply: reply_tx, admitted: Instant::now() }) {
                Ok(()) => (reply_rx.recv().unwrap_or_default(), false),
                Err(_job) => (Vec::new(), true),
            };
        out.clear();
        out.extend_from_slice(&RESP_MAGIC.to_le_bytes());
        out.extend_from_slice(&(logits.len() as u32).to_le_bytes());
        for v in &logits {
            out.extend_from_slice(&v.to_le_bytes());
        }
        s.write_all(&out)?;
        if refused {
            return Ok(()); // server is stopping; close the connection
        }
    }
}

/// Blocking client for the wire protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Self { stream })
    }

    pub fn infer(&mut self, x: &[f32]) -> std::io::Result<Vec<f32>> {
        let mut msg = Vec::with_capacity(8 + x.len() * 4);
        msg.extend_from_slice(&REQ_MAGIC.to_le_bytes());
        msg.extend_from_slice(&(x.len() as u32).to_le_bytes());
        for v in x {
            msg.extend_from_slice(&v.to_le_bytes());
        }
        self.stream.write_all(&msg)?;
        let mut head = [0u8; 8];
        self.stream.read_exact(&mut head)?;
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        let n = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        if magic != RESP_MAGIC {
            return Err(std::io::Error::other("bad response magic"));
        }
        let mut buf = vec![0u8; n * 4];
        self.stream.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::coordinator::deployment::argmax;
    use crate::mapping::DigitalBackend;
    use crate::nn::dataset::BlobDataset;
    use crate::nn::mlp::{train, Mlp};

    #[test]
    fn end_to_end_serve_roundtrip() {
        let mut d = BlobDataset::new(12, 0.05, 3);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(200)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 32, 10], 5);
        train(&mut mlp, &data, 6, 0.05, 9);
        let cal: Vec<Vec<f32>> = data.iter().take(40).map(|(x, _)| x.clone()).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
        let expected = dep.run_digital(&[data[0].0.clone()]);

        let backend = Box::new(DigitalBackend::new(Config::default()));
        let handle = ServeConfig::builder()
            .serve(ServeFrontend::Backend { deployment: dep, backend })
            .unwrap();

        let mut client = Client::connect(handle.addr).unwrap();
        let logits = client.infer(&data[0].0).unwrap();
        assert_eq!(logits.len(), 10);
        assert_eq!(argmax(&logits), argmax(&expected[0]));

        // Live snapshot without shutdown: batches are accounted before
        // their replies go out, so the answered request is already visible.
        let live = handle.metrics_snapshot();
        assert!(live.requests >= 1, "live requests {}", live.requests);
        assert!(live.core_ops > 0);
        assert!(live.wall > Duration::default());
        assert!(handle.metrics_addr().is_none(), "no metrics listener configured");

        // Concurrent clients exercise the batcher.
        let addr = handle.addr;
        let mut joins = Vec::new();
        for t in 0..4 {
            let x = data[t + 1].0.clone();
            joins.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for _ in 0..5 {
                    let l = c.infer(&x).unwrap();
                    assert_eq!(l.len(), 10);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }

        let metrics = handle.shutdown();
        assert!(metrics.requests >= 21, "requests {}", metrics.requests);
        let report = metrics.report(200e6);
        assert!(report.throughput_rps > 0.0);
    }

    /// The pooled pipeline front-end answers the wire protocol with the same
    /// logits as a direct (noise-free) pipeline call — barrier and streamed.
    #[test]
    fn pipeline_serve_roundtrip() {
        let mut d = BlobDataset::new(12, 0.05, 8);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(150)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 32, 10], 2);
        train(&mut mlp, &data, 4, 0.05, 3);
        let cal: Vec<Vec<f32>> = data.iter().take(30).map(|(x, _)| x.clone()).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);

        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let expected = {
            let mut pipe =
                crate::pipeline::PipelineDeployment::new(dep.clone(), cfg.clone(), 2).unwrap();
            pipe.run_batch(&[data[0].0.clone()]).unwrap()
        };

        for stream in [false, true] {
            let handle = ServeConfig::builder()
                .workers(2)
                .stream(stream)
                .serve(ServeFrontend::Pipeline { deployment: dep.clone(), sim: cfg.clone() })
                .unwrap();
            let mut client = Client::connect(handle.addr).unwrap();
            let logits = client.infer(&data[0].0).unwrap();
            assert_eq!(logits, expected[0], "stream={stream}");

            let metrics = handle.shutdown();
            assert_eq!(metrics.requests, 1);
            assert!(metrics.core_ops > 0);
            assert!(metrics.energy_fj > 0.0);
            if stream {
                assert!(!metrics.stages.is_empty(), "streamed serving must report stages");
            }
        }
    }

    /// A graph-compiled MLP behind the wire protocol answers with the same
    /// logits as a direct (noise-free) plan invocation.
    #[test]
    fn compiled_plan_serve_roundtrip() {
        use crate::compiler::{compile, CompileOptions, Graph};
        use crate::nn::tensor::Tensor;

        let mut d = BlobDataset::new(12, 0.05, 13);
        let data: Vec<(Vec<f32>, usize)> = d
            .batch(120)
            .into_iter()
            .map(|s| (s.image.data, s.label))
            .collect();
        let mut mlp = Mlp::new(&[144, 16, 10], 6);
        train(&mut mlp, &data, 3, 0.05, 7);
        let cal: Vec<Tensor> = data
            .iter()
            .take(20)
            .map(|(x, _)| Tensor::from_vec(&[144], x.clone()))
            .collect();

        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let graph = Graph::from_mlp(&mlp);
        let opts = CompileOptions { workers: 2, ..Default::default() };
        let expected = {
            let mut plan = compile(graph.clone(), &cal, &cfg, &opts).unwrap();
            plan.run_flat(&[data[0].0.clone()]).unwrap()
        };

        let plan = compile(graph, &cal, &cfg, &opts).unwrap();
        let handle =
            ServeConfig::builder().serve(ServeFrontend::Plan(plan)).unwrap();
        let mut client = Client::connect(handle.addr).unwrap();
        let logits = client.infer(&data[0].0).unwrap();
        assert_eq!(logits, expected[0]);
        let metrics = handle.shutdown();
        assert_eq!(metrics.requests, 1);
        assert!(metrics.core_ops > 0);
    }
}
