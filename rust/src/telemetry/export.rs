//! Registry exporters: Prometheus text exposition, a JSON snapshot, and a
//! hand-rolled HTTP listener serving both (DESIGN.md §12).
//!
//! The HTTP side is deliberately minimal — same zero-dependency TCP stack
//! as `coordinator::server`, answering `GET /metrics` (text format 0.0.4)
//! and `GET /metrics.json`, one short-lived connection per scrape. The
//! listener runs on its own thread next to the serve loop
//! (`serve --metrics-addr`), so metrics are pollable while the server is
//! live, without `shutdown()`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::registry::{bucket_upper, Entry, Family, Histogram, Metric, Registry};

/// Finite f64 in Rust's shortest-roundtrip decimal form (parses back to
/// the identical bits — the e2e exactness test relies on this); non-finite
/// renders as its Prometheus spelling.
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "NaN".into()
    } else if v > 0.0 {
        "+Inf".into()
    } else {
        "-Inf".into()
    }
}

fn prom_escape_label(v: &str, out: &mut String) {
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn prom_labels(names: &[&str], values: &[String]) -> String {
    let mut out = String::from("{");
    for (i, (n, v)) in names.iter().zip(values).enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(n);
        out.push_str("=\"");
        prom_escape_label(v, &mut out);
        out.push('"');
    }
    out.push('}');
    out
}

fn prom_histogram(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    // Cumulative buckets with inclusive `le` bounds; the label block (if
    // any) keeps its braces, so `le` is spliced into them.
    let counts = h.bucket_counts();
    let mut cum = 0u64;
    for (i, c) in counts.iter().enumerate() {
        cum += c;
        if *c == 0 && bucket_upper(i).is_some() {
            continue; // sparse: only materialized + the mandatory +Inf
        }
        let le = match bucket_upper(i) {
            Some(u) => u.to_string(),
            None => "+Inf".into(),
        };
        let lbl = if labels.is_empty() {
            format!("{{le=\"{le}\"}}")
        } else {
            format!("{},le=\"{le}\"}}", &labels[..labels.len() - 1])
        };
        out.push_str(&format!("{name}_bucket{lbl} {cum}\n"));
    }
    out.push_str(&format!("{name}_sum{labels} {}\n", fmt_f64(h.sum())));
    out.push_str(&format!("{name}_count{labels} {}\n", h.count()));
}

fn family_block<T: Metric>(
    out: &mut String,
    name: &str,
    fam: &Family<T>,
    mut one: impl FnMut(&mut String, &str, &str, &T),
) {
    for (values, m) in fam.series() {
        let labels = prom_labels(fam.label_names(), &values);
        one(out, name, &labels, &m);
    }
}

/// The registry in Prometheus text exposition format 0.0.4.
pub fn render_prometheus(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, slot) in reg.snapshot() {
        let kind = match &slot.entry {
            Entry::Counter(_) | Entry::FloatCounter(_) => "counter",
            Entry::CounterFamily(_) | Entry::FloatCounterFamily(_) => "counter",
            Entry::Gauge(_) | Entry::GaugeFamily(_) => "gauge",
            Entry::Histogram(_) | Entry::HistogramFamily(_) => "histogram",
        };
        out.push_str(&format!("# HELP {name} {}\n# TYPE {name} {kind}\n", slot.help));
        match &slot.entry {
            Entry::Counter(c) => out.push_str(&format!("{name} {}\n", c.get())),
            Entry::FloatCounter(c) => out.push_str(&format!("{name} {}\n", fmt_f64(c.get()))),
            Entry::Gauge(g) => out.push_str(&format!("{name} {}\n", g.get())),
            Entry::Histogram(h) => prom_histogram(&mut out, name, "", h),
            Entry::CounterFamily(f) => family_block(&mut out, name, f, |o, n, l, m| {
                o.push_str(&format!("{n}{l} {}\n", m.get()))
            }),
            Entry::FloatCounterFamily(f) => family_block(&mut out, name, f, |o, n, l, m| {
                o.push_str(&format!("{n}{l} {}\n", fmt_f64(m.get())))
            }),
            Entry::GaugeFamily(f) => family_block(&mut out, name, f, |o, n, l, m| {
                o.push_str(&format!("{n}{l} {}\n", m.get()))
            }),
            Entry::HistogramFamily(f) => {
                family_block(&mut out, name, f, |o, n, l, m| prom_histogram(o, n, l, m))
            }
        }
    }
    out
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

fn json_series(
    out: &mut Vec<String>,
    name: &str,
    kind: &str,
    labels: Option<(&[&str], &[String])>,
    value: String,
) {
    let mut obj = format!("{{\"name\":{},\"type\":{}", json_str(name), json_str(kind));
    if let Some((names, values)) = labels {
        obj.push_str(",\"labels\":{");
        for (i, (n, v)) in names.iter().zip(values).enumerate() {
            if i > 0 {
                obj.push(',');
            }
            obj.push_str(&format!("{}:{}", json_str(n), json_str(v)));
        }
        obj.push('}');
    }
    obj.push_str(&format!(",\"value\":{value}}}"));
    out.push(obj);
}

fn json_hist_value(h: &Histogram) -> String {
    let counts = h.bucket_counts();
    let mut buckets = Vec::new();
    for (i, c) in counts.iter().enumerate() {
        if *c == 0 {
            continue;
        }
        let le = match bucket_upper(i) {
            Some(u) => u.to_string(),
            None => "\"+Inf\"".into(),
        };
        buckets.push(format!("[{le},{c}]"));
    }
    format!(
        "{{\"count\":{},\"sum\":{},\"buckets\":[{}]}}",
        h.count(),
        json_f64(h.sum()),
        buckets.join(",")
    )
}

/// The registry as a JSON snapshot: `{"metrics":[{name,type,labels?,value}…]}`.
pub fn render_json(reg: &Registry) -> String {
    let mut series: Vec<String> = Vec::new();
    for (name, slot) in reg.snapshot() {
        match &slot.entry {
            Entry::Counter(c) => {
                json_series(&mut series, name, "counter", None, c.get().to_string())
            }
            Entry::FloatCounter(c) => {
                json_series(&mut series, name, "counter", None, json_f64(c.get()))
            }
            Entry::Gauge(g) => json_series(&mut series, name, "gauge", None, g.get().to_string()),
            Entry::Histogram(h) => {
                json_series(&mut series, name, "histogram", None, json_hist_value(h))
            }
            Entry::CounterFamily(f) => {
                for (values, m) in f.series() {
                    json_series(
                        &mut series,
                        name,
                        "counter",
                        Some((f.label_names(), &values)),
                        m.get().to_string(),
                    );
                }
            }
            Entry::FloatCounterFamily(f) => {
                for (values, m) in f.series() {
                    json_series(
                        &mut series,
                        name,
                        "counter",
                        Some((f.label_names(), &values)),
                        json_f64(m.get()),
                    );
                }
            }
            Entry::GaugeFamily(f) => {
                for (values, m) in f.series() {
                    json_series(
                        &mut series,
                        name,
                        "gauge",
                        Some((f.label_names(), &values)),
                        m.get().to_string(),
                    );
                }
            }
            Entry::HistogramFamily(f) => {
                for (values, m) in f.series() {
                    json_series(
                        &mut series,
                        name,
                        "histogram",
                        Some((f.label_names(), &values)),
                        json_hist_value(&m),
                    );
                }
            }
        }
    }
    format!("{{\"metrics\":[{}]}}", series.join(","))
}

/// Running metrics HTTP listener (see [`spawn_exporter`]).
#[derive(Debug)]
pub struct ExporterHandle {
    /// Actual bound address (port 0 resolves here).
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

impl ExporterHandle {
    /// Stop the listener and join its thread.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept() so the loop observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ExporterHandle {
    fn drop(&mut self) {
        if self.join.is_some() {
            self.stop_inner();
        }
    }
}

fn http_response(status: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn handle_scrape(mut stream: TcpStream, reg: &Registry) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    // Read until the end of the request head (or cap / timeout); only the
    // request line matters.
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let path = head.lines().next().and_then(|l| l.split_whitespace().nth(1)).unwrap_or("");
    let reply = match path {
        "/metrics" => http_response(
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            &render_prometheus(reg),
        ),
        "/metrics.json" => http_response("200 OK", "application/json", &render_json(reg)),
        _ => http_response("404 Not Found", "text/plain; charset=utf-8", "see /metrics or /metrics.json\n"),
    };
    let _ = stream.write_all(&reply);
    let _ = stream.flush();
}

/// Bind `addr` (e.g. `127.0.0.1:9184`, port 0 for ephemeral) and serve
/// the **global** registry over HTTP until the handle shuts down.
pub fn spawn_exporter(addr: &str) -> std::io::Result<ExporterHandle> {
    let listener = TcpListener::bind(addr)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let join = std::thread::Builder::new()
        .name("cimsim-metrics".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(stream) = conn {
                    handle_scrape(stream, super::global());
                }
            }
        })
        .expect("spawn metrics exporter thread");
    Ok(ExporterHandle { addr, stop, join: Some(join) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_text_rendering() {
        let r = Registry::new();
        r.counter("t_ops_total", "total ops").add(42);
        r.float_counter("t_energy_fj_total", "energy").add(1.5);
        r.gauge("t_depth", "queue depth").set(-3);
        let h = r.histogram("t_lat_us", "latency");
        h.observe(0);
        h.observe(3);
        h.observe(900);
        let fam = r.counter_family("t_layer_total", "per layer", &["layer", "kind"]);
        fam.with(&["fc1", "linear"]).add(7);
        fam.with(&["we\"ird\\l\nabel", "conv"]).inc();

        let text = render_prometheus(&r);
        assert!(text.contains("# HELP t_ops_total total ops\n# TYPE t_ops_total counter\nt_ops_total 42\n"));
        assert!(text.contains("t_energy_fj_total 1.5\n"));
        assert!(text.contains("# TYPE t_depth gauge\nt_depth -3\n"));
        // Histogram: cumulative buckets, inclusive le, mandatory +Inf.
        assert!(text.contains("t_lat_us_bucket{le=\"0\"} 1\n"));
        assert!(text.contains("t_lat_us_bucket{le=\"3\"} 2\n"));
        assert!(text.contains("t_lat_us_bucket{le=\"1023\"} 3\n"));
        assert!(text.contains("t_lat_us_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("t_lat_us_sum 903\n"));
        assert!(text.contains("t_lat_us_count 3\n"));
        assert!(text.contains("t_layer_total{layer=\"fc1\",kind=\"linear\"} 7\n"));
        // Label values escape backslash, quote, and newline.
        assert!(text.contains("t_layer_total{layer=\"we\\\"ird\\\\l\\nabel\",kind=\"conv\"} 1\n"));
        // Deterministic: names render in sorted order.
        let pos = |needle: &str| text.find(needle).unwrap();
        assert!(pos("t_depth") < pos("t_energy_fj_total"));
        assert!(pos("t_energy_fj_total") < pos("t_lat_us"));
    }

    #[test]
    fn float_rendering_roundtrips_exactly() {
        for v in [0.0f64, 1.5, 1.0 / 3.0, 1234567.89012345, 4.0e9 + 0.125] {
            let s = fmt_f64(v);
            assert_eq!(s.parse::<f64>().unwrap().to_bits(), v.to_bits(), "{s}");
        }
        assert_eq!(fmt_f64(f64::NAN), "NaN");
        assert_eq!(fmt_f64(f64::INFINITY), "+Inf");
    }

    #[test]
    fn json_rendering_is_parseable_shape() {
        let r = Registry::new();
        r.counter("t_a_total", "a").add(5);
        let h = r.histogram("t_h_us", "h");
        h.observe(7);
        let fam = r.gauge_family("t_g", "g", &["stage"]);
        fam.with(&["fc1"]).set(2);
        let json = render_json(&r);
        assert!(json.starts_with("{\"metrics\":["));
        assert!(json.contains("{\"name\":\"t_a_total\",\"type\":\"counter\",\"value\":5}"));
        assert!(json.contains("\"labels\":{\"stage\":\"fc1\"}"));
        assert!(json.contains("\"buckets\":[[7,1]]"));
        // Balanced braces/brackets (cheap well-formedness check).
        let opens = json.matches('{').count() + json.matches('[').count();
        let closes = json.matches('}').count() + json.matches(']').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn exporter_serves_scrapes_over_tcp() {
        // Global registry: use names unique to this test.
        super::super::global().counter("t_export_probe_total", "probe").add(11);
        let handle = spawn_exporter("127.0.0.1:0").unwrap();
        let get = |path: &str| {
            let mut s = TcpStream::connect(handle.addr).unwrap();
            s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };
        let text = get("/metrics");
        assert!(text.starts_with("HTTP/1.1 200 OK"));
        assert!(text.contains("text/plain; version=0.0.4"));
        assert!(text.contains("t_export_probe_total 11"));
        let json = get("/metrics.json");
        assert!(json.starts_with("HTTP/1.1 200 OK"));
        assert!(json.contains("\"t_export_probe_total\""));
        let missing = get("/nope");
        assert!(missing.starts_with("HTTP/1.1 404"));
        handle.shutdown();
    }
}
