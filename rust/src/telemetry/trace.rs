//! Lightweight tracing spans with a Chrome `trace_event` exporter
//! (DESIGN.md §12).
//!
//! A span is `(name, start, duration, labels)` captured by an RAII guard
//! created through the [`crate::span!`] macro. Spans land in one global
//! bounded ring buffer (oldest dropped first) and export as Chrome
//! `trace_event` JSON — loadable in Perfetto / `chrome://tracing` — via
//! [`export_chrome_json`].
//!
//! **Disabled-path cost.** Tracing is off by default. The macro's first
//! action is [`enabled`] — one `Relaxed` atomic load — and when it returns
//! false *nothing else happens*: no `Instant::now()`, no label
//! stringification (label expressions sit inside the enabled branch), no
//! allocation, and crucially no RNG interaction, so noisy-mode outputs
//! are bit-identical with the instrumentation compiled in (asserted by
//! `tests/telemetry_hotpath.rs`, measured by `benches/telemetry_overhead`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Ring-buffer capacity: spans beyond this evict the oldest.
pub const TRACE_RING_CAP: usize = 65_536;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING: Mutex<VecDeque<SpanEvent>> = Mutex::new(VecDeque::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Small dense per-thread id for the trace `tid` field (ThreadId has
    /// no stable numeric accessor on MSRV).
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Process-relative time origin; first use pins it.
fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Is span recording on? One `Relaxed` load — this is the *entire*
/// disabled-path cost of an instrumentation site.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on/off. Enabling pins the time origin so the first
/// span does not pay for it.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// One completed span.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Microseconds since the process trace epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    pub tid: u64,
    pub labels: Vec<(&'static str, String)>,
}

/// RAII span: records on drop (if it was started). Bind it —
/// `let _span = telemetry::span!("name");` — or it ends immediately.
#[derive(Debug)]
pub struct SpanGuard(Option<ActiveSpan>);

#[derive(Debug)]
struct ActiveSpan {
    name: &'static str,
    start: Instant,
    labels: Vec<(&'static str, String)>,
}

impl SpanGuard {
    /// The disabled no-op guard: nothing recorded on drop.
    #[inline(always)]
    pub fn noop() -> Self {
        SpanGuard(None)
    }

    /// A live span starting now. Callers go through [`crate::span!`],
    /// which checks [`enabled`] first so labels are never even built on
    /// the disabled path.
    pub fn started(name: &'static str, labels: Vec<(&'static str, String)>) -> Self {
        SpanGuard(Some(ActiveSpan { name, start: Instant::now(), labels }))
    }

    /// Label-free convenience used by the macro's no-label arm.
    #[inline(always)]
    pub fn new_if_enabled(name: &'static str) -> Self {
        if enabled() {
            Self::started(name, Vec::new())
        } else {
            Self::noop()
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(span) = self.0.take() {
            let dur_us = span.start.elapsed().as_micros() as u64;
            let ts_us = span.start.duration_since(epoch()).as_micros() as u64;
            let ev = SpanEvent {
                name: span.name,
                ts_us,
                dur_us,
                tid: TID.with(|t| *t),
                labels: span.labels,
            };
            let mut ring = RING.lock().unwrap();
            if ring.len() >= TRACE_RING_CAP {
                ring.pop_front();
            }
            ring.push_back(ev);
        }
    }
}

/// Record a span with (name, labels) at the `ts..ts+dur` window measured
/// by the caller — for spans whose start predates the guard (queue waits).
pub fn record_complete(name: &'static str, start: Instant, dur_us: u64) {
    if !enabled() {
        return;
    }
    let ts_us = start.duration_since(epoch()).as_micros() as u64;
    let ev = SpanEvent { name, ts_us, dur_us, tid: TID.with(|t| *t), labels: Vec::new() };
    let mut ring = RING.lock().unwrap();
    if ring.len() >= TRACE_RING_CAP {
        ring.pop_front();
    }
    ring.push_back(ev);
}

/// Number of spans currently buffered.
pub fn len() -> usize {
    RING.lock().unwrap().len()
}

/// Drop all buffered spans.
pub fn clear() {
    RING.lock().unwrap().clear();
}

/// Copy of the buffered spans, oldest first.
pub fn snapshot() -> Vec<SpanEvent> {
    RING.lock().unwrap().iter().cloned().collect()
}

fn json_escape(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Buffered spans as Chrome `trace_event` JSON (the `{"traceEvents":[…]}`
/// object form): complete (`"ph":"X"`) events with µs timestamps, one
/// `tid` per OS thread. Load in Perfetto (ui.perfetto.dev) or
/// `chrome://tracing`.
pub fn export_chrome_json() -> String {
    let spans = snapshot();
    let mut out = String::with_capacity(64 + spans.len() * 96);
    out.push_str("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("{\"name\":\"");
        json_escape(s.name, &mut out);
        out.push_str(&format!(
            "\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{}",
            s.tid, s.ts_us, s.dur_us
        ));
        if !s.labels.is_empty() {
            out.push_str(",\"args\":{");
            for (j, (k, v)) in s.labels.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                out.push('"');
                json_escape(k, &mut out);
                out.push_str("\":\"");
                json_escape(v, &mut out);
                out.push('"');
            }
            out.push('}');
        }
        out.push('}');
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Record a span over the enclosed scope. First arm: name only. Second
/// arm: `span!("name", "key" => value, …)` — label expressions are
/// evaluated (and allocated) **only when tracing is enabled**; the
/// disabled path is a single relaxed atomic load either way.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::telemetry::trace::SpanGuard::new_if_enabled($name)
    };
    ($name:expr, $($k:literal => $v:expr),+ $(,)?) => {
        if $crate::telemetry::trace::enabled() {
            $crate::telemetry::trace::SpanGuard::started(
                $name,
                vec![$(($k, $v.to_string())),+],
            )
        } else {
            $crate::telemetry::trace::SpanGuard::noop()
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test fn: the ring and enabled flag are process-global and the
    // harness runs #[test]s in parallel threads.
    #[test]
    fn span_lifecycle_ring_and_export() {
        assert!(!enabled(), "tracing must default to off");
        {
            let _g = crate::span!("t_disabled");
        }
        assert_eq!(len(), 0, "disabled spans record nothing");

        set_enabled(true);
        {
            let _g = crate::span!("t_outer", "layer" => "fc1", "items" => 3);
            let _inner = crate::span!("t_inner");
        }
        record_complete("t_wait", Instant::now(), 17);
        set_enabled(false);
        let spans = snapshot();
        assert_eq!(spans.len(), 3);
        // Guards record on drop: inner closes before outer.
        assert_eq!(spans[0].name, "t_inner");
        assert_eq!(spans[1].name, "t_outer");
        assert_eq!(spans[1].labels[0], ("layer", "fc1".to_string()));
        assert_eq!(spans[1].labels[1], ("items", "3".to_string()));
        assert_eq!(spans[2].dur_us, 17);
        assert!(spans[1].ts_us <= spans[0].ts_us, "outer starts before inner");

        let json = export_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"t_outer\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"args\":{\"layer\":\"fc1\",\"items\":\"3\"}"));

        clear();
        assert_eq!(len(), 0);

        // Ring stays bounded under overflow.
        set_enabled(true);
        for _ in 0..(TRACE_RING_CAP + 10) {
            record_complete("t_flood", Instant::now(), 1);
        }
        set_enabled(false);
        assert_eq!(len(), TRACE_RING_CAP);
        clear();
    }

    #[test]
    fn json_escaping_is_safe() {
        let mut out = String::new();
        json_escape("a\"b\\c\nd\u{1}", &mut out);
        assert_eq!(out, "a\\\"b\\\\c\\nd\\u0001");
    }
}
