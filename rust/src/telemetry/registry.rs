//! Metric registry: named counters, gauges, and log2-bucket histograms
//! with labeled families (DESIGN.md §12).
//!
//! Everything here is zero-dependency and lock-free on the *record* path:
//! a metric handle is an `Arc` around one or more atomics, so `inc`/`add`/
//! `observe` are single `Relaxed` RMW operations. Locks exist only on the
//! *registration* path (get-or-register a name, materialize a label set),
//! which callers hit once and cache — the compiler caches per-layer
//! handles at `compile()` time, the serve loop caches its handles at
//! startup.
//!
//! Counters for device work (`core_ops`, `device_cycles`) are plain `u64`
//! adds, so a registry series fed at the same merge points as an
//! [`crate::mapping::ExecStats`] equals it exactly. Energy is f64; to keep
//! the exported `cim_energy_fj_total` bit-identical to
//! `ExecStats::energy_fj()`, the device series tracks the four
//! [`crate::energy::EnergyBreakdown`] components separately (see
//! [`super::DeviceCounters`]) — per-component running sums reproduce the
//! component-wise `EnergyBreakdown::add` merges, and the exporter re-sums
//! components in `total_fj()` order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic `u64` counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Monotonic `f64` counter (bits in an `AtomicU64`, CAS-loop add).
///
/// When fed from a single thread (all current call sites: the plan merge
/// points and the serve loop run their accounting single-threaded), the
/// accumulation order — and therefore the exact f64 value — matches a
/// plain `f64 +=` running sum.
#[derive(Debug)]
pub struct FloatCounter {
    bits: AtomicU64,
}

impl Default for FloatCounter {
    fn default() -> Self {
        FloatCounter { bits: AtomicU64::new(0f64.to_bits()) }
    }
}

impl FloatCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&self, d: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Overwrite the value. Not for general use — exists so derived
    /// series (e.g. the exact component re-sum behind
    /// `cim_energy_fj_total`) can be refreshed to a computed value.
    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }
}

/// Instantaneous `i64` gauge.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.v.fetch_add(d, Ordering::Relaxed);
    }

    /// Ratchet: keep the maximum ever set (peak gauges).
    #[inline]
    pub fn set_max(&self, v: i64) {
        self.v.fetch_max(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Bucket count of a [`Histogram`]: one underflow/zero bucket plus one
/// bucket per `u64` bit length.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// Log2-bucket histogram over `u64` observations (e.g. microseconds).
///
/// Bucket 0 holds exact zeros; bucket `i` (1 ≤ i ≤ 64) holds values with
/// bit length `i`, i.e. `2^(i-1) ≤ v < 2^i` — upper bound `2^i - 1`
/// inclusive, matching the Prometheus `le` convention. Buckets are plain
/// atomic counts, so histograms merge by addition and aggregate across
/// shards/processes without resampling — unlike the reservoir percentiles
/// in `coordinator::metrics`, which must be computed where the samples
/// live.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    count: AtomicU64,
    sum: FloatCounter,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: FloatCounter::new(),
        }
    }
}

/// Bucket index of one observation: 0 for 0, else the bit length of `v`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// Inclusive upper bound of bucket `i`, or `None` for the +Inf bucket.
pub fn bucket_upper(i: usize) -> Option<u64> {
    match i {
        0 => Some(0),
        _ if i < HISTOGRAM_BUCKETS - 1 => Some((1u64 << i) - 1),
        _ => None,
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.add(v as f64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum.get()
    }

    /// Per-bucket counts (non-cumulative), index = [`bucket_index`].
    pub fn bucket_counts(&self) -> [u64; HISTOGRAM_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Fold another histogram in (buckets, count, and sum all add).
    pub fn merge_from(&self, other: &Histogram) {
        for (i, b) in other.bucket_counts().iter().enumerate() {
            if *b > 0 {
                self.buckets[i].fetch_add(*b, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.add(other.sum());
    }

    /// Upper bound of the bucket holding the `q`-quantile (0 ≤ q ≤ 1) —
    /// a ≤2× overestimate by construction of the log2 buckets. Returns 0
    /// for an empty histogram; the top bucket reports `u64::MAX`.
    pub fn quantile_upper(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, b) in self.bucket_counts().iter().enumerate() {
            cum += b;
            if cum >= rank {
                return bucket_upper(i).unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }
}

/// A metric type a [`Family`] can materialize per label set.
pub trait Metric: Send + Sync + std::fmt::Debug + 'static {
    fn new_metric() -> Self;
}

impl Metric for Counter {
    fn new_metric() -> Self {
        Counter::new()
    }
}

impl Metric for FloatCounter {
    fn new_metric() -> Self {
        FloatCounter::new()
    }
}

impl Metric for Gauge {
    fn new_metric() -> Self {
        Gauge::new()
    }
}

impl Metric for Histogram {
    fn new_metric() -> Self {
        Histogram::new()
    }
}

/// Labeled family of one metric type: `name{l1="…", l2="…"}` series.
///
/// `with(values)` get-or-creates the series for one label-value tuple and
/// returns its `Arc` handle; callers cache the handle so the record path
/// never touches the family lock.
#[derive(Debug)]
pub struct Family<T: Metric> {
    label_names: &'static [&'static str],
    series: Mutex<BTreeMap<Vec<String>, Arc<T>>>,
}

impl<T: Metric> Family<T> {
    fn new(label_names: &'static [&'static str]) -> Self {
        Family { label_names, series: Mutex::new(BTreeMap::new()) }
    }

    pub fn label_names(&self) -> &'static [&'static str] {
        self.label_names
    }

    /// Get-or-create the series with these label values (positional, one
    /// per label name).
    pub fn with(&self, values: &[&str]) -> Arc<T> {
        assert_eq!(
            values.len(),
            self.label_names.len(),
            "label value count mismatch: family has labels {:?}, got {values:?}",
            self.label_names
        );
        let key: Vec<String> = values.iter().map(|s| s.to_string()).collect();
        let mut map = self.series.lock().unwrap();
        map.entry(key).or_insert_with(|| Arc::new(T::new_metric())).clone()
    }

    /// Snapshot of every materialized series, label-sorted.
    pub fn series(&self) -> Vec<(Vec<String>, Arc<T>)> {
        self.series.lock().unwrap().iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }
}

/// One registered entry (single metric or labeled family).
#[derive(Debug, Clone)]
pub(crate) enum Entry {
    Counter(Arc<Counter>),
    FloatCounter(Arc<FloatCounter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    CounterFamily(Arc<Family<Counter>>),
    FloatCounterFamily(Arc<Family<FloatCounter>>),
    GaugeFamily(Arc<Family<Gauge>>),
    HistogramFamily(Arc<Family<Histogram>>),
}

#[derive(Debug, Clone)]
pub(crate) struct Slot {
    pub(crate) help: &'static str,
    pub(crate) entry: Entry,
}

/// Named collection of metrics. One process-global instance lives behind
/// [`super::global`]; tests construct private registries with
/// [`Registry::new`].
///
/// Registration is idempotent get-or-register keyed on the metric name;
/// re-registering a name as a *different* type is a programming error and
/// panics. Names are `BTreeMap`-ordered so the exported text is
/// deterministic.
#[derive(Debug, Default)]
pub struct Registry {
    pub(crate) slots: Mutex<BTreeMap<&'static str, Slot>>,
}

macro_rules! register_single {
    ($fn_name:ident, $ty:ty, $variant:ident) => {
        pub fn $fn_name(&self, name: &'static str, help: &'static str) -> Arc<$ty> {
            let mut slots = self.slots.lock().unwrap();
            let slot = slots.entry(name).or_insert_with(|| Slot {
                help,
                entry: Entry::$variant(Arc::new(<$ty>::new_metric())),
            });
            match &slot.entry {
                Entry::$variant(m) => m.clone(),
                other => panic!(
                    "metric {name:?} already registered with a different type ({other:?})"
                ),
            }
        }
    };
}

macro_rules! register_family {
    ($fn_name:ident, $ty:ty, $variant:ident) => {
        pub fn $fn_name(
            &self,
            name: &'static str,
            help: &'static str,
            labels: &'static [&'static str],
        ) -> Arc<Family<$ty>> {
            let mut slots = self.slots.lock().unwrap();
            let slot = slots.entry(name).or_insert_with(|| Slot {
                help,
                entry: Entry::$variant(Arc::new(Family::new(labels))),
            });
            match &slot.entry {
                Entry::$variant(f) => {
                    assert_eq!(
                        f.label_names(),
                        labels,
                        "metric {name:?} re-registered with different labels"
                    );
                    f.clone()
                }
                other => panic!(
                    "metric {name:?} already registered with a different type ({other:?})"
                ),
            }
        }
    };
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    register_single!(counter, Counter, Counter);
    register_single!(float_counter, FloatCounter, FloatCounter);
    register_single!(gauge, Gauge, Gauge);
    register_single!(histogram, Histogram, Histogram);

    register_family!(counter_family, Counter, CounterFamily);
    register_family!(float_counter_family, FloatCounter, FloatCounterFamily);
    register_family!(gauge_family, Gauge, GaugeFamily);
    register_family!(histogram_family, Histogram, HistogramFamily);

    /// Name-sorted snapshot of every registered slot (for the exporters).
    pub(crate) fn snapshot(&self) -> Vec<(&'static str, Slot)> {
        self.slots.lock().unwrap().iter().map(|(n, s)| (*n, s.clone())).collect()
    }

    /// Number of registered names (families count once).
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let r = Registry::new();
        let c = r.counter("t_ops_total", "ops");
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        // Idempotent get-or-register returns the same underlying series.
        let c2 = r.counter("t_ops_total", "ops");
        c2.inc();
        assert_eq!(c.get(), 43);

        let g = r.gauge("t_depth", "queue depth");
        g.set(7);
        g.add(-2);
        assert_eq!(g.get(), 5);
        g.set_max(3);
        assert_eq!(g.get(), 5, "set_max never lowers");
        g.set_max(9);
        assert_eq!(g.get(), 9);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn float_counter_matches_sequential_sum() {
        let f = FloatCounter::new();
        let mut reference = 0f64;
        for i in 0..100 {
            let d = 0.1 * (i as f64) + 0.7;
            f.add(d);
            reference += d;
        }
        // Single-threaded adds reproduce a running `+=` bit-exactly.
        assert_eq!(f.get().to_bits(), reference.to_bits());
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // Zero goes to the dedicated zero bucket.
        assert_eq!(bucket_index(0), 0);
        // Powers of two open a new bucket; `2^i - 1` closes bucket i.
        for i in 1..=63usize {
            let lo = 1u64 << (i - 1);
            assert_eq!(bucket_index(lo), i, "2^{} opens bucket {i}", i - 1);
            assert_eq!(bucket_index((1u64 << i) - 1), i, "2^{i}-1 closes bucket {i}");
        }
        assert_eq!(bucket_index(u64::MAX), 64);
        // `bucket_upper` is the inclusive `le` bound; top bucket is +Inf.
        assert_eq!(bucket_upper(0), Some(0));
        assert_eq!(bucket_upper(1), Some(1));
        assert_eq!(bucket_upper(4), Some(15));
        assert_eq!(bucket_upper(64), None);
        // Every representable value lands in the bucket its bound names.
        for v in [0u64, 1, 2, 3, 4, 1023, 1024, 1025, u64::MAX / 2, u64::MAX] {
            let i = bucket_index(v);
            if let Some(upper) = bucket_upper(i) {
                assert!(v <= upper);
            }
            if i > 0 {
                assert!(v > bucket_upper(i - 1).unwrap());
            }
        }
    }

    #[test]
    fn histogram_observe_merge_and_quantile() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [0u64, 1, 2, 3, 900, 1000] {
            a.observe(v);
        }
        for v in [4u64, 1_000_000] {
            b.observe(v);
        }
        assert_eq!(a.count(), 6);
        assert_eq!(a.sum(), 1906.0);
        a.merge_from(&b);
        assert_eq!(a.count(), 8);
        assert_eq!(a.sum(), 1906.0 + 1_000_004.0);
        let counts = a.bucket_counts();
        assert_eq!(counts[0], 1, "one zero");
        assert_eq!(counts[1], 1, "v=1");
        assert_eq!(counts[2], 2, "v=2,3");
        assert_eq!(counts[3], 1, "v=4");
        assert_eq!(counts[10], 2, "v=900,1000 in [512,1023]");
        assert_eq!(counts[20], 1, "v=1e6 in [2^19,2^20-1]");
        // Quantile upper bounds are bucket bounds: the median of the 8
        // observations sits in bucket 2 (le=3), the max in bucket 20.
        assert_eq!(a.quantile_upper(0.5), 3);
        assert_eq!(a.quantile_upper(1.0), (1 << 20) - 1);
        assert_eq!(Histogram::new().quantile_upper(0.5), 0);
    }

    #[test]
    fn family_label_handling() {
        let r = Registry::new();
        let fam = r.counter_family("t_layer_ops_total", "per-layer ops", &["layer", "kind"]);
        let fc1 = fam.with(&["fc1", "linear"]);
        let conv = fam.with(&["conv0", "conv"]);
        fc1.add(5);
        conv.add(2);
        // Same label values → same series.
        fam.with(&["fc1", "linear"]).inc();
        assert_eq!(fc1.get(), 6);
        assert_eq!(conv.get(), 2);
        let series = fam.series();
        assert_eq!(series.len(), 2);
        // BTreeMap order: label-value tuples sort lexicographically.
        assert_eq!(series[0].0, vec!["conv0".to_string(), "conv".to_string()]);
        assert_eq!(series[1].0, vec!["fc1".to_string(), "linear".to_string()]);
        // Re-registering the family is idempotent and shares state.
        let fam2 = r.counter_family("t_layer_ops_total", "per-layer ops", &["layer", "kind"]);
        fam2.with(&["fc1", "linear"]).inc();
        assert_eq!(fc1.get(), 7);
        assert_eq!(r.len(), 1);
    }

    #[test]
    #[should_panic(expected = "label value count mismatch")]
    fn family_rejects_wrong_label_count() {
        let r = Registry::new();
        let fam = r.counter_family("t_bad_total", "x", &["layer", "kind"]);
        fam.with(&["only-one"]);
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_mismatch_panics() {
        let r = Registry::new();
        r.counter("t_same_name", "as counter");
        r.gauge("t_same_name", "as gauge");
    }
}
