//! Zero-dependency observability: metric registry, Prometheus/JSON
//! export, and tracing spans (DESIGN.md §12).
//!
//! Three pieces:
//!
//! * [`registry`] — process-global [`Registry`] of counters / gauges /
//!   log2-bucket histograms with labeled families; lock-free atomics on
//!   the record path.
//! * [`export`] — Prometheus text exposition (`GET /metrics`), a JSON
//!   snapshot (`GET /metrics.json`), and the hand-rolled HTTP listener
//!   behind `serve --metrics-addr`.
//! * [`trace`] — the [`crate::span!`] RAII span macro, a bounded span
//!   ring buffer, and a Chrome `trace_event` exporter (`cimsim trace`).
//!
//! The device-facing series are fed at the same points the engine merges
//! its [`ExecStats`] (compiler plan merge sites, `MacroPool` slot
//! loads, the `sched` stage runtime), so `/metrics` and the engine's own
//! accounting agree exactly — one source of truth, two read paths.

pub mod export;
pub mod registry;
pub mod trace;

pub use registry::{Counter, Family, FloatCounter, Gauge, Histogram, Registry};

use std::sync::{Arc, OnceLock};

use crate::mapping::ExecStats;

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-global registry every instrumentation site records into
/// and the exporters render from.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

/// Cached handles for the process-wide device counters, fed wherever the
/// engine merges an [`ExecStats`] chunk into its own totals.
///
/// Energy is tracked as the four [`crate::energy::EnergyBreakdown`]
/// components (one [`FloatCounter`] each): per-component running sums
/// reproduce `EnergyBreakdown::add` bit-exactly, and [`Self::energy_fj`]
/// re-sums them in `total_fj()` order, so the exported
/// `cim_energy_fj_total` equals `ExecStats::energy_fj()` exactly for a
/// single-plan process (the e2e test asserts this).
#[derive(Debug)]
pub struct DeviceCounters {
    pub core_ops: Arc<Counter>,
    pub device_cycles: Arc<Counter>,
    pub weight_loads: Arc<Counter>,
    pub clipped: Arc<Counter>,
    energy_array_fj: Arc<FloatCounter>,
    energy_dtc_fj: Arc<FloatCounter>,
    energy_path_fj: Arc<FloatCounter>,
    energy_sa_ctrl_fj: Arc<FloatCounter>,
    /// Derived series: refreshed to the exact component re-sum on every
    /// `record_stats` (a chunk-total running sum would round differently
    /// than `EnergyBreakdown::add` and drift off `ExecStats::energy_fj`).
    energy_fj_total: Arc<FloatCounter>,
    pub slot_loads: Arc<Counter>,
    pub slot_reloads: Arc<Counter>,
    pub slots_claimed: Arc<Gauge>,
    pub exec_items: Arc<Counter>,
}

impl DeviceCounters {
    fn new(reg: &Registry) -> Self {
        DeviceCounters {
            core_ops: reg.counter("cim_core_ops_total", "Macro core operations executed"),
            device_cycles: reg
                .counter("cim_device_cycles_total", "Serial device cycles (per-op sum)"),
            weight_loads: reg
                .counter("cim_weight_loads_total", "Weight tile loads + dynamic reloads"),
            clipped: reg.counter("cim_clipped_total", "Boosted-readout clipping events"),
            energy_array_fj: reg
                .float_counter("cim_energy_array_fj_total", "Array discharge energy (fJ)"),
            energy_dtc_fj: reg.float_counter("cim_energy_dtc_fj_total", "DTC + SL driver energy (fJ)"),
            energy_path_fj: reg
                .float_counter("cim_energy_path_fj_total", "Pulse-path config energy (fJ)"),
            energy_sa_ctrl_fj: reg
                .float_counter("cim_energy_sa_ctrl_fj_total", "Sense-amp + control energy (fJ)"),
            energy_fj_total: reg.float_counter(
                "cim_energy_fj_total",
                "Total device energy (fJ), exact component re-sum",
            ),
            slot_loads: reg.counter("cim_pool_slot_loads_total", "MacroPool slot weight loads"),
            slot_reloads: reg
                .counter("cim_pool_slot_reloads_total", "MacroPool in-place slot reloads"),
            slots_claimed: reg.gauge("cim_pool_slots_claimed", "MacroPool slots currently claimed"),
            exec_items: reg
                .counter("cim_exec_items_total", "Batch items dispatched by BatchExecutor"),
        }
    }

    /// Fold one merged [`ExecStats`] chunk in — call exactly where the
    /// chunk merges into engine totals, so both stay equal.
    pub fn record_stats(&self, s: &ExecStats) {
        self.core_ops.add(s.core_ops);
        self.device_cycles.add(s.total_cycles);
        self.weight_loads.add(s.weight_loads);
        self.clipped.add(s.clipped);
        self.energy_array_fj.add(s.energy.array_fj);
        self.energy_dtc_fj.add(s.energy.dtc_fj);
        self.energy_path_fj.add(s.energy.path_fj);
        self.energy_sa_ctrl_fj.add(s.energy.sa_ctrl_fj);
        self.refresh_energy_total();
    }

    /// Exact total-energy re-sum in `EnergyBreakdown::total_fj` order.
    pub fn energy_fj(&self) -> f64 {
        self.energy_array_fj.get()
            + self.energy_dtc_fj.get()
            + self.energy_path_fj.get()
            + self.energy_sa_ctrl_fj.get()
    }

    fn refresh_energy_total(&self) {
        // Store (not add): the series mirrors the component re-sum.
        self.energy_fj_total.set(self.energy_fj());
    }
}

static DEVICE: OnceLock<DeviceCounters> = OnceLock::new();

/// Cached process-wide device counter handles (global registry).
pub fn device() -> &'static DeviceCounters {
    DEVICE.get_or_init(|| DeviceCounters::new(global()))
}

/// Cached handles for the autoregressive-decode series (DESIGN.md §13),
/// fed once per token step at `DecodePlan::finish_step` — the exact point
/// each session merges its per-step [`ExecStats`] — so the decode series
/// equal the summed session stats bit for bit (same per-step chunks,
/// same order; `tests/telemetry_e2e.rs`).
#[derive(Debug)]
pub struct DecodeCounters {
    /// Generation rounds (`ContinuousBatcher::step_all` calls with work).
    pub steps: Arc<Counter>,
    /// Token steps executed (one per session per round, prefill included).
    pub tokens: Arc<Counter>,
    /// Decode sessions created.
    pub sessions: Arc<Counter>,
    /// Sessions currently holding a batcher slot.
    pub active: Arc<Gauge>,
    pub core_ops: Arc<Counter>,
    pub device_cycles: Arc<Counter>,
    /// Static-grid loads + KV-cache strip/rescale reloads, decode only.
    pub weight_loads: Arc<Counter>,
    pub clipped: Arc<Counter>,
    energy_array_fj: Arc<FloatCounter>,
    energy_dtc_fj: Arc<FloatCounter>,
    energy_path_fj: Arc<FloatCounter>,
    energy_sa_ctrl_fj: Arc<FloatCounter>,
    /// Derived: exact component re-sum on every record (see
    /// [`DeviceCounters`] for why a running total would drift).
    energy_fj_total: Arc<FloatCounter>,
}

impl DecodeCounters {
    fn new(reg: &Registry) -> Self {
        DecodeCounters {
            steps: reg.counter("cim_decode_steps_total", "Continuous-batching generation rounds"),
            tokens: reg.counter("cim_decode_tokens_total", "Decoder token steps executed"),
            sessions: reg.counter("cim_decode_sessions_total", "Decode sessions created"),
            active: reg.gauge("cim_decode_active_sessions", "Sessions holding a batcher slot"),
            core_ops: reg.counter("cim_decode_core_ops_total", "Core ops on the decode path"),
            device_cycles: reg
                .counter("cim_decode_device_cycles_total", "Device cycles on the decode path"),
            weight_loads: reg.counter(
                "cim_decode_weight_loads_total",
                "Weight tile loads (static grids + KV-cache reloads) on the decode path",
            ),
            clipped: reg.counter("cim_decode_clipped_total", "Clipping events on the decode path"),
            energy_array_fj: reg
                .float_counter("cim_decode_energy_array_fj_total", "Decode array energy (fJ)"),
            energy_dtc_fj: reg
                .float_counter("cim_decode_energy_dtc_fj_total", "Decode DTC energy (fJ)"),
            energy_path_fj: reg
                .float_counter("cim_decode_energy_path_fj_total", "Decode pulse-path energy (fJ)"),
            energy_sa_ctrl_fj: reg.float_counter(
                "cim_decode_energy_sa_ctrl_fj_total",
                "Decode sense-amp + control energy (fJ)",
            ),
            energy_fj_total: reg.float_counter(
                "cim_decode_energy_fj_total",
                "Total decode energy (fJ), exact component re-sum",
            ),
        }
    }

    /// Fold one token step's [`ExecStats`] in and bump the token counter.
    pub fn record_step(&self, s: &ExecStats) {
        self.tokens.inc();
        self.core_ops.add(s.core_ops);
        self.device_cycles.add(s.total_cycles);
        self.weight_loads.add(s.weight_loads);
        self.clipped.add(s.clipped);
        self.energy_array_fj.add(s.energy.array_fj);
        self.energy_dtc_fj.add(s.energy.dtc_fj);
        self.energy_path_fj.add(s.energy.path_fj);
        self.energy_sa_ctrl_fj.add(s.energy.sa_ctrl_fj);
        self.energy_fj_total.set(self.energy_fj());
    }

    /// Exact total-energy re-sum in `EnergyBreakdown::total_fj` order.
    pub fn energy_fj(&self) -> f64 {
        self.energy_array_fj.get()
            + self.energy_dtc_fj.get()
            + self.energy_path_fj.get()
            + self.energy_sa_ctrl_fj.get()
    }
}

static DECODE: OnceLock<DecodeCounters> = OnceLock::new();

/// Cached process-wide decode counter handles (global registry).
pub fn decode() -> &'static DecodeCounters {
    DECODE.get_or_init(|| DecodeCounters::new(global()))
}

/// Cached per-layer counter handles (`layer`, `kind` labels), created
/// once at plan-compile time and recorded at the plan's per-layer
/// `ExecStats` merge points — per-layer cycle/op series therefore equal
/// `CompiledLayer::observed()` exactly.
#[derive(Debug, Clone)]
pub struct LayerCounters {
    pub core_ops: Arc<Counter>,
    pub device_cycles: Arc<Counter>,
    pub weight_loads: Arc<Counter>,
    pub energy_fj: Arc<FloatCounter>,
}

impl LayerCounters {
    /// Handles for one `(layer, kind)` label pair on the global registry.
    pub fn for_layer(layer: &str, kind: &str) -> Self {
        let reg = global();
        let labels: &[&str] = &["layer", "kind"];
        let values = &[layer, kind];
        LayerCounters {
            core_ops: reg
                .counter_family("cim_layer_core_ops_total", "Core ops per layer", labels)
                .with(values),
            device_cycles: reg
                .counter_family("cim_layer_device_cycles_total", "Device cycles per layer", labels)
                .with(values),
            weight_loads: reg
                .counter_family("cim_layer_weight_loads_total", "Weight reloads per layer", labels)
                .with(values),
            energy_fj: reg
                .float_counter_family("cim_layer_energy_fj_total", "Energy per layer (fJ)", labels)
                .with(values),
        }
    }

    /// Fold one per-layer [`ExecStats`] chunk in (same call sites as
    /// `CompiledLayer::observed.merge`).
    pub fn record_stats(&self, s: &ExecStats) {
        self.core_ops.add(s.core_ops);
        self.device_cycles.add(s.total_cycles);
        self.weight_loads.add(s.weight_loads);
        self.energy_fj.add(s.energy.total_fj());
    }
}

/// Record one finished `sched::run_stages` run into the per-stage
/// families: items per stage, peak bounded-queue depth per stage, and the
/// run's peak concurrently-busy stage count.
pub fn record_stage_run(gauges: &[crate::sched::StageGauge], peak_busy: usize) {
    let reg = global();
    let items = reg.counter_family("cim_stage_items_total", "Items completed per stage", &["stage"]);
    let peak_q =
        reg.gauge_family("cim_stage_peak_queue", "Peak bounded-queue depth per stage", &["stage"]);
    for g in gauges {
        items.with(&[&g.name]).add(g.items);
        peak_q.with(&[&g.name]).set_max(g.peak_queue as i64);
    }
    reg.gauge("cim_stages_busy_peak", "Peak concurrently busy stages")
        .set_max(peak_busy as i64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyBreakdown;

    #[test]
    fn device_counters_track_exec_stats_exactly() {
        // Private registry: same code path as the global one without
        // cross-test interference.
        let reg = Registry::new();
        let dev = DeviceCounters::new(&reg);
        let mut total = ExecStats::default();
        for i in 0..50u64 {
            let chunk = ExecStats {
                core_ops: i,
                weight_loads: i % 3,
                total_cycles: 10 * i + 7,
                energy: EnergyBreakdown {
                    array_fj: 0.3 * i as f64 + 0.1,
                    dtc_fj: 0.07 * i as f64,
                    path_fj: 1.0 / (i as f64 + 3.0),
                    sa_ctrl_fj: 2.5,
                },
                clipped: i % 2,
            };
            total.merge(&chunk);
            dev.record_stats(&chunk);
        }
        assert_eq!(dev.core_ops.get(), total.core_ops);
        assert_eq!(dev.device_cycles.get(), total.total_cycles);
        assert_eq!(dev.weight_loads.get(), total.weight_loads);
        assert_eq!(dev.clipped.get(), total.clipped);
        // Bit-exact energy: component-wise accumulation + total_fj-order
        // re-sum reproduces ExecStats::energy_fj exactly.
        assert_eq!(dev.energy_fj().to_bits(), total.energy_fj().to_bits());
        assert_eq!(dev.energy_fj_total.get().to_bits(), total.energy_fj().to_bits());
    }

    /// The decode series fold per-step `ExecStats` chunks exactly like the
    /// device series — same per-component accumulation, same bit-exact
    /// total re-sum — and count one token per recorded step.
    #[test]
    fn decode_counters_track_step_stats_exactly() {
        let reg = Registry::new();
        let dec = DecodeCounters::new(&reg);
        let mut total = ExecStats::default();
        for i in 0..40u64 {
            let chunk = ExecStats {
                core_ops: 2 * i + 1,
                weight_loads: i % 5,
                total_cycles: 13 * i,
                energy: EnergyBreakdown {
                    array_fj: 0.21 * i as f64,
                    dtc_fj: 1.0 / (i as f64 + 2.0),
                    path_fj: 0.5,
                    sa_ctrl_fj: 0.031 * i as f64 + 0.2,
                },
                clipped: i % 4,
            };
            total.merge(&chunk);
            dec.record_step(&chunk);
        }
        assert_eq!(dec.tokens.get(), 40);
        assert_eq!(dec.core_ops.get(), total.core_ops);
        assert_eq!(dec.device_cycles.get(), total.total_cycles);
        assert_eq!(dec.weight_loads.get(), total.weight_loads);
        assert_eq!(dec.clipped.get(), total.clipped);
        assert_eq!(dec.energy_fj().to_bits(), total.energy_fj().to_bits());
        assert_eq!(dec.energy_fj_total.get().to_bits(), total.energy_fj().to_bits());
    }

    #[test]
    fn layer_counters_register_on_global() {
        let lc = LayerCounters::for_layer("t_mod_fc", "linear");
        let chunk = ExecStats {
            core_ops: 4,
            total_cycles: 99,
            energy: EnergyBreakdown { array_fj: 1.0, ..Default::default() },
            ..Default::default()
        };
        lc.record_stats(&chunk);
        assert_eq!(lc.core_ops.get() % 4, 0);
        assert!(lc.device_cycles.get() >= 99);
        // Same labels → same series.
        let again = LayerCounters::for_layer("t_mod_fc", "linear");
        let before = again.core_ops.get();
        lc.core_ops.add(4);
        assert_eq!(again.core_ops.get(), before + 4);
    }
}
