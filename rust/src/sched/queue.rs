//! A bounded MPMC queue with close semantics — the backpressure primitive of
//! the streaming scheduler and the serve runtime (DESIGN.md §9).
//!
//! * [`BoundedQueue::push`] blocks while the queue is full, which is how
//!   backpressure propagates: a slow stage fills its input queue, the
//!   upstream stage blocks on `push`, and so on back to the admission edge
//!   (a TCP connection handler, or the feeder of a streamed plan run).
//! * [`BoundedQueue::close`] marks the end of the stream: pending and future
//!   `push`es return the item to the caller, and `pop` drains what is
//!   already queued before reporting exhaustion with `None`. This is the
//!   graceful-drain contract — closing never discards admitted items.
//! * Depth gauges (`peak_depth`, `pushed`) are recorded lock-free so the
//!   serve loop can report realized queue pressure without touching the
//!   mutex.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A blocking bounded queue. See the module docs for the push/close/drain
/// contract.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    peak: AtomicUsize,
    pushed: AtomicU64,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap: cap.max(1),
            peak: AtomicUsize::new(0),
            pushed: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deepest the queue has ever been.
    pub fn peak_depth(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Items ever admitted (successful pushes).
    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    /// Blocking push: waits while the queue is full. Returns the item back
    /// when the queue is closed (nothing is admitted past a close).
    pub fn push(&self, v: T) -> Result<(), T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if g.closed {
                return Err(v);
            }
            if g.items.len() < self.cap {
                break;
            }
            g = self.not_full.wait(g).expect("queue poisoned");
        }
        g.items.push_back(v);
        let depth = g.items.len();
        drop(g);
        self.peak.fetch_max(depth, Ordering::Relaxed);
        self.pushed.fetch_add(1, Ordering::Relaxed);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: waits for an item; `None` only once the queue is closed
    /// AND fully drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(v) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(v);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).expect("queue poisoned");
        }
    }

    /// Pop with a deadline: `None` on timeout or on closed-and-drained —
    /// either way the caller's batching window is over.
    pub fn pop_deadline(&self, deadline: Instant) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(v) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(v);
            }
            if g.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g2, res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue poisoned");
            g = g2;
            if res.timed_out() {
                // One last drain check before giving up the window.
                if let Some(v) = g.items.pop_front() {
                    drop(g);
                    self.not_full.notify_one();
                    return Some(v);
                }
                return None;
            }
        }
    }

    /// Close the queue: wakes every blocked pusher (they get their item
    /// back) and lets poppers drain the remainder.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.closed = true;
        drop(g);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().expect("queue poisoned").closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_and_gauges() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.peak_depth(), 5);
        assert_eq!(q.pushed(), 5);
        let got: Vec<i32> = (0..5).map(|_| q.pop().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn push_blocks_until_pop_then_backpressure_releases() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push(3).is_ok());
        // Give the pusher time to block on the full queue.
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 2, "third push must be blocked");
        assert_eq!(q.pop(), Some(1));
        assert!(pusher.join().unwrap());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_drains_admitted_and_refuses_new() {
        let q = BoundedQueue::new(4);
        q.push("a").unwrap();
        q.push("b").unwrap();
        q.close();
        assert_eq!(q.push("c"), Err("c"), "post-close push must refuse");
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None, "closed and drained");
    }

    #[test]
    fn close_wakes_blocked_pusher() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(7u32).unwrap();
        let qa = q.clone();
        // Either blocks on the full queue until close wakes it, or (if close
        // lands first) is refused outright — refused both ways.
        let pusher = std::thread::spawn(move || qa.push(8).is_err());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(pusher.join().unwrap(), "push across close must be refused");
        // The admitted item still drains after close; then exhaustion.
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_popper() {
        let q = Arc::new(BoundedQueue::<u8>::new(1));
        let qa = q.clone();
        // Either blocks on the empty queue until close wakes it, or observes
        // the closed-and-drained state directly — `None` both ways.
        let popper = std::thread::spawn(move || qa.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(popper.join().unwrap(), None);
    }

    #[test]
    fn pop_deadline_times_out_and_still_drains() {
        let q = BoundedQueue::new(2);
        let t0 = Instant::now();
        assert_eq!(
            q.pop_deadline(Instant::now() + Duration::from_millis(15)),
            Option::<u8>::None
        );
        assert!(t0.elapsed() >= Duration::from_millis(10));
        q.push(9u8).unwrap();
        assert_eq!(q.pop_deadline(Instant::now() + Duration::from_millis(15)), Some(9));
    }
}
