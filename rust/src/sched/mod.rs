//! Streaming plan scheduler — layer-pipelined execution over bounded queues
//! (DESIGN.md §9).
//!
//! The paper's macro keeps its analog array busy by sharing the discharge
//! branches between MAC and readout: there is no idle ADC stage. The
//! software analogue is this module: instead of a hard barrier after every
//! network layer (all 71 shards of a ResNet-20 placement idling while the
//! slowest tile of layer *k* finishes), a compiled plan becomes a pipeline
//! of per-layer **stages** connected by [`BoundedQueue`]s, and each batch
//! item flows through the stages independently — item A can be in layer 3
//! while item B is still in layer 1.
//!
//! The module is deliberately generic: [`run_stages`] knows nothing about
//! tensors or layers. It owns the runtime mechanics —
//!
//! * one worker thread per stage, pulling items from the stage's input
//!   queue (work units inside a stage are `(item, row-tile)` preparations;
//!   see `compiler::plan::run_streamed` and `pipeline::batch::run_vector`);
//! * bounded inter-stage queues, so a slow stage backpressures its
//!   upstream instead of buffering unboundedly;
//! * occupancy accounting ([`Occupancy`], peak number of simultaneously
//!   busy stages — the pipelining proof) and per-stage queue-depth gauges
//!   ([`StageGauge`]);
//! * abort-on-error with full drain: the first stage error wins, every
//!   queue is drained (never deadlocked on a full queue), and the error is
//!   returned to the caller;
//! * panic hygiene: a panicking stage closes every queue on unwind so the
//!   sibling stages and the feeder exit instead of blocking forever.
//!
//! `coordinator::server` reuses [`BoundedQueue`] as the serve admission
//! queue: TCP connection handlers block on `push` when the queue is full
//! (backpressure to the client) and `ServerHandle::shutdown` closes the
//! queue, which by the drain contract completes everything already
//! admitted before the server returns its metrics.

pub mod queue;

pub use queue::BoundedQueue;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Post-run accounting for one pipeline stage.
#[derive(Clone, Debug, Default)]
pub struct StageGauge {
    pub name: String,
    /// Items this stage processed.
    pub items: u64,
    /// Deepest its input queue ever got.
    pub peak_queue: usize,
}

/// Lock-free gauge of how many stages are busy right now, tracking the peak.
/// Peak > 1 is the observable proof that execution actually pipelined.
#[derive(Debug, Default)]
pub struct Occupancy {
    busy: AtomicUsize,
    peak: AtomicUsize,
}

impl Occupancy {
    pub fn enter(&self) {
        let now = self.busy.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(now, Ordering::SeqCst);
    }

    pub fn exit(&self) {
        self.busy.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn busy(&self) -> usize {
        self.busy.load(Ordering::SeqCst)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }
}

/// What a [`run_stages`] run observed.
#[derive(Clone, Debug, Default)]
pub struct RunGauges {
    pub stages: Vec<StageGauge>,
    /// Peak number of simultaneously busy stages.
    pub peak_busy: usize,
}

/// On unwind (a panicking stage worker), close every queue so sibling
/// stages and the feeder drain out instead of blocking forever; the panic
/// then propagates normally through `std::thread::scope`.
struct PanicDrain<'a, T> {
    abort: &'a AtomicBool,
    queues: &'a [BoundedQueue<T>],
}

impl<T> Drop for PanicDrain<'_, T> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.abort.store(true, Ordering::SeqCst);
            for q in self.queues {
                q.close();
            }
        }
    }
}

/// Drive `feed` through a pipeline of `names.len()` stages connected by
/// bounded queues of capacity `queue_cap`.
///
/// `make_stage(s)` is called once *inside* stage `s`'s worker thread and
/// returns that stage's (stateful) item processor — per-stage scratch
/// buffers live there, reused across items with zero steady-state
/// allocation. `finish` receives every item that completed the last stage,
/// in completion order (FIFO: single-threaded stages over FIFO queues
/// preserve admission order).
///
/// The first stage error aborts the run: remaining items are drained (not
/// processed) and the error is returned. Items the feeder had not yet
/// admitted are simply never fed.
pub fn run_stages<I, E, F, W, D>(
    feed: impl IntoIterator<Item = I>,
    names: Vec<String>,
    queue_cap: usize,
    make_stage: F,
    finish: D,
) -> Result<RunGauges, E>
where
    I: Send,
    E: Send,
    F: Fn(usize) -> W + Sync,
    W: FnMut(&mut I) -> Result<(), E>,
    D: FnMut(I) + Send,
{
    let n = names.len();
    assert!(n >= 1, "a pipeline needs at least one stage");
    let queues: Vec<BoundedQueue<I>> = (0..n).map(|_| BoundedQueue::new(queue_cap)).collect();
    let done: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let occ = Occupancy::default();
    let abort = AtomicBool::new(false);
    let err: Mutex<Option<E>> = Mutex::new(None);
    let finish = Mutex::new(finish);

    std::thread::scope(|s| {
        let queues = &queues;
        let done = &done;
        let occ = &occ;
        let abort = &abort;
        let err = &err;
        let finish = &finish;
        let make_stage = &make_stage;
        for stage in 0..n {
            s.spawn(move || {
                let _drain = PanicDrain { abort, queues };
                let mut work = make_stage(stage);
                let in_q = &queues[stage];
                let out_q = queues.get(stage + 1);
                while let Some(mut item) = in_q.pop() {
                    if abort.load(Ordering::Relaxed) {
                        continue; // drain mode: keep upstream pushes unblocked
                    }
                    occ.enter();
                    let r = work(&mut item);
                    occ.exit();
                    match r {
                        Ok(()) => {
                            done[stage].fetch_add(1, Ordering::Relaxed);
                            match out_q {
                                // Err only while aborting — dropping is fine.
                                Some(q) => drop(q.push(item)),
                                None => {
                                    let mut f = finish.lock().expect("finish poisoned");
                                    (*f)(item);
                                }
                            }
                        }
                        Err(e) => {
                            let mut slot = err.lock().expect("error slot poisoned");
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            abort.store(true, Ordering::Relaxed);
                        }
                    }
                }
                // Input exhausted: cascade the close downstream.
                if let Some(q) = out_q {
                    q.close();
                }
            });
        }
        // Feed on the calling thread; `push` blocking on a full first queue
        // is the backpressure edge.
        for item in feed {
            if abort.load(Ordering::Relaxed) || queues[0].push(item).is_err() {
                break;
            }
        }
        queues[0].close();
    });

    if let Some(e) = err.into_inner().expect("error slot poisoned") {
        return Err(e);
    }
    let stages: Vec<StageGauge> = names
        .into_iter()
        .zip(queues.iter().zip(&done))
        .map(|(name, (q, d))| StageGauge {
            name,
            items: d.load(Ordering::Relaxed),
            peak_queue: q.peak_depth(),
        })
        .collect();
    let peak_busy = occ.peak();
    // Post-run (off the per-item path): fold this run's per-stage items,
    // queue peaks, and occupancy into the global telemetry families
    // (DESIGN.md §12).
    crate::telemetry::record_stage_run(&stages, peak_busy);
    Ok(RunGauges { stages, peak_busy })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn items_traverse_all_stages_in_order() {
        let finished = Mutex::new(Vec::new());
        let gauges = run_stages(
            (0..20).map(|i| (i, 0u32)),
            vec!["a".into(), "b".into(), "c".into()],
            2,
            |_stage| {
                |item: &mut (i32, u32)| {
                    item.1 += 1;
                    Ok::<(), String>(())
                }
            },
            |item| finished.lock().unwrap().push(item),
        )
        .unwrap();
        let got = finished.into_inner().unwrap();
        // FIFO order preserved end to end; every item saw all three stages.
        assert_eq!(got.iter().map(|&(i, _)| i).collect::<Vec<_>>(), (0..20).collect::<Vec<_>>());
        assert!(got.iter().all(|&(_, hops)| hops == 3));
        assert_eq!(gauges.stages.len(), 3);
        assert!(gauges.stages.iter().all(|g| g.items == 20));
        assert!(gauges.peak_busy >= 1);
    }

    #[test]
    fn pipelining_overlaps_stages() {
        // Two stages that each sleep: with more than a couple of items the
        // occupancy gauge must observe both busy at once.
        let gauges = run_stages(
            0..8,
            vec!["slow1".into(), "slow2".into()],
            2,
            |_stage| {
                |_item: &mut i32| {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    Ok::<(), String>(())
                }
            },
            |_item| {},
        )
        .unwrap();
        assert!(
            gauges.peak_busy > 1,
            "two sleeping stages over 8 items must overlap (peak {})",
            gauges.peak_busy
        );
    }

    #[test]
    fn first_error_aborts_without_deadlock() {
        let finished = AtomicUsize::new(0);
        let res = run_stages(
            0..100,
            vec!["s0".into(), "s1".into()],
            1, // tight queues: the drain path is what prevents deadlock
            |stage| {
                move |item: &mut i32| {
                    if stage == 1 && *item == 3 {
                        Err(format!("boom at {item}"))
                    } else {
                        Ok(())
                    }
                }
            },
            |_item| {
                finished.fetch_add(1, Ordering::Relaxed);
            },
        );
        assert_eq!(res.unwrap_err(), "boom at 3");
        assert!(finished.load(Ordering::Relaxed) < 100, "run must not complete after abort");
    }

    #[test]
    fn single_stage_degenerate_case_works() {
        let sum = AtomicUsize::new(0);
        let g = run_stages(
            1..=10usize,
            vec!["only".into()],
            4,
            |_| |_item: &mut usize| Ok::<(), ()>(()),
            |item| {
                sum.fetch_add(item, Ordering::Relaxed);
            },
        )
        .unwrap();
        assert_eq!(sum.load(Ordering::Relaxed), 55);
        assert_eq!(g.stages[0].items, 10);
    }
}
