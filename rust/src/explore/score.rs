//! Analytic scoring of one hardware candidate on one workload.
//!
//! No simulation runs here: the score is
//! [`crate::compiler::estimate_cost_lowered`]'s [`CostReport`] — the exact
//! placement-time cost model (`compile` produces the bit-identical report,
//! asserted by `tests/hwspec_explore.rs`) — reduced to the four sweep
//! objectives:
//!
//! * **TOPS/W** — padded device ops per input over estimated energy per
//!   input (reload energy included).
//! * **Latency** — serial-device milliseconds per input at the candidate's
//!   clock (compute + reload cycles).
//! * **Area** — resident shards (shared + dedicated dynamic) times the
//!   candidate's [`HwSpec::macro_area_mm2`].
//! * **Accuracy proxy** — effective output bits: ADC resolution minus the
//!   worst-case clipping penalty the DTC gain buys its signal margin with,
//!   capped by the full-precision output width (DESIGN.md §15).

use crate::compiler::place::{worst_clip_penalty_bits, CostReport};
use crate::config::HwSpec;
use crate::energy::fom::full_output_bits;
use crate::energy::tops_per_watt;

/// One scored candidate: the sweep label, the geometry summary, the four
/// objectives, and the raw cost-model totals they were derived from.
#[derive(Clone, Debug)]
pub struct ExplorePoint {
    pub label: String,
    pub rows: usize,
    pub engines: usize,
    pub cores: usize,
    pub adc_bits: u32,
    /// Objective: throughput efficiency (maximize).
    pub tops_w: f64,
    /// Objective: serial-device latency per input, ms (minimize).
    pub latency_ms: f64,
    /// Objective: resident silicon, mm² (minimize).
    pub area_mm2: f64,
    /// Objective: accuracy proxy in effective output bits (maximize).
    pub accuracy_bits: f64,
    /// Compute + reload device cycles per input.
    pub cycles_per_input: u64,
    /// All-in estimated energy per input, fJ.
    pub energy_fj_per_input: f64,
    pub total_tiles: usize,
    pub n_shards: usize,
    pub n_dynamic_shards: usize,
    /// On the Pareto frontier of the sweep (set by
    /// [`crate::explore::pareto::mark_frontier`]).
    pub on_frontier: bool,
}

/// Accuracy proxy in effective output bits — see DESIGN.md §15 for the
/// derivation. The ADC resolves `adc_bits`; the DTC gain `s` scales the
/// worst-case folded MAC signal to `worst · s / vpp` of the conversion
/// range, and everything past full scale clips, costing
/// `log2(worst · s / vpp)` worst-case bits (zero when the signal fits).
/// The proxy is that effective resolution, capped by the full-precision
/// output width `act_bits + weight_bits + log2(rows)`.
pub fn accuracy_proxy_bits(hw: &HwSpec) -> f64 {
    let adc = hw.mac.adc_bits as f64;
    let full = full_output_bits(hw.mac.act_bits, hw.mac.weight_bits, hw.mac.rows);
    (adc - worst_clip_penalty_bits(hw)).min(full)
}

/// Reduce a candidate's [`CostReport`] to an [`ExplorePoint`].
pub fn score(label: String, hw: &HwSpec, report: &CostReport) -> ExplorePoint {
    let cycles = report.total_est_cycles_per_input() + report.total_est_reload_cycles_per_input();
    let energy_fj = report.total_est_energy_fj_per_input();
    // Padded device ops per input: every placed tile fires rows×engines
    // MACs per vector regardless of logical shape — the same convention as
    // the paper's TOPS numbers (and `MacroConfig::ops_per_op` per core).
    let ops_per_tile_op = 2.0 * hw.mac.rows as f64 * hw.mac.engines as f64;
    let ops: f64 = report
        .layers
        .iter()
        .map(|l| (l.vectors_per_input * l.n_rt * l.n_ct) as f64 * ops_per_tile_op)
        .sum();
    let shards = report.n_shards + report.n_dynamic_shards;
    ExplorePoint {
        label,
        rows: hw.mac.rows,
        engines: hw.mac.engines,
        cores: hw.mac.cores,
        adc_bits: hw.mac.adc_bits,
        tops_w: tops_per_watt(ops, energy_fj),
        latency_ms: crate::cim::timing::cycles_to_seconds(hw, cycles) * 1e3,
        area_mm2: shards as f64 * hw.macro_area_mm2(),
        accuracy_bits: accuracy_proxy_bits(hw),
        cycles_per_input: cycles,
        energy_fj_per_input: energy_fj,
        total_tiles: report.total_tiles,
        n_shards: report.n_shards,
        n_dynamic_shards: report.n_dynamic_shards,
        on_frontier: false,
    }
}

impl ExplorePoint {
    /// One flat JSON object (the environment vendors no `serde`).
    pub fn to_json(&self) -> String {
        use crate::bench::{json_row, JsonField};
        json_row(&[
            JsonField::Str("label", &self.label),
            JsonField::Int("rows", self.rows as i64),
            JsonField::Int("engines", self.engines as i64),
            JsonField::Int("cores", self.cores as i64),
            JsonField::Int("adc_bits", self.adc_bits as i64),
            JsonField::Num("tops_w", self.tops_w),
            JsonField::Num("latency_ms", self.latency_ms),
            JsonField::Num("area_mm2", self.area_mm2),
            JsonField::Num("accuracy_bits", self.accuracy_bits),
            JsonField::Int("cycles_per_input", self.cycles_per_input as i64),
            JsonField::Num("energy_fj_per_input", self.energy_fj_per_input),
            JsonField::Int("total_tiles", self.total_tiles as i64),
            JsonField::Int("n_shards", self.n_shards as i64),
            JsonField::Int("n_dynamic_shards", self.n_dynamic_shards as i64),
            JsonField::Int("on_frontier", i64::from(self.on_frontier)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_proxy_is_adc_bits_without_enhancement() {
        let mut hw = HwSpec::paper_default();
        hw.enhance = crate::config::EnhanceConfig { fold: false, boost: false, ..hw.enhance };
        // s = 1 and the worst-case signal exactly fills VPP: no penalty.
        assert_eq!(accuracy_proxy_bits(&hw), hw.mac.adc_bits as f64);
    }

    #[test]
    fn accuracy_proxy_monotone_in_adc_bits_and_penalizes_boost_clipping() {
        let base = HwSpec::paper_default();
        let mut more = base.clone();
        more.mac.adc_bits = 10;
        assert!(accuracy_proxy_bits(&more) > accuracy_proxy_bits(&base));
        // Paper default (fold+boost): gain 3.75× vs folding's 15/8 range
        // shrink leaves exactly the boost factor 2× past full scale — one
        // worst-case bit traded for typical-case signal margin.
        assert!((accuracy_proxy_bits(&base) - (base.mac.adc_bits as f64 - 1.0)).abs() < 1e-12);
    }
}
