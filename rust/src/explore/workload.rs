//! The fixed workload menu the sweep scores against: one representative
//! network per roadmap workload class, each with a deterministic
//! calibration set (seeded [`crate::util::rng::Xoshiro256`] data), so two
//! runs of the same sweep produce identical frontiers.

use crate::compiler::Graph;
use crate::nn::mlp::Mlp;
use crate::nn::resnet::ResNet20;
use crate::nn::tensor::Tensor;
use crate::nn::transformer::{DecoderModel, TransformerBlock};
use crate::util::rng::{Rng, Xoshiro256};

/// A named candidate workload for `cimsim explore`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// 3-layer MLP on 12×12 inputs (the training-demo shape).
    Mlp,
    /// The paper's Fig. 1 mapping workload: CIFAR-shaped ResNet-20.
    Resnet20,
    /// One MHA+FFN encoder block — the dynamic-weight (`MatMul`) workload.
    Transformer,
    /// A 2-layer GPT-style causal decoder prefix (the KV-cache class,
    /// scored here as its fixed-shape compile-path graph).
    Decode,
}

impl Workload {
    pub const ALL: [Workload; 4] =
        [Workload::Mlp, Workload::Resnet20, Workload::Transformer, Workload::Decode];

    /// CLI name (`--workload <name>`).
    pub fn name(self) -> &'static str {
        match self {
            Workload::Mlp => "mlp",
            Workload::Resnet20 => "resnet20",
            Workload::Transformer => "transformer",
            Workload::Decode => "decode",
        }
    }

    pub fn from_name(name: &str) -> Option<Workload> {
        Self::ALL.into_iter().find(|w| w.name() == name)
    }

    /// Build the graph plus its deterministic calibration inputs.
    pub fn build(self) -> (Graph, Vec<Tensor>) {
        match self {
            Workload::Mlp => {
                let mlp = Mlp::new(&[144, 32, 10], 7);
                let graph = Graph::from_mlp(&mlp);
                let cal = (0..4).map(|i| random_vec(144, 0x3A11 + i)).collect();
                (graph, cal)
            }
            Workload::Resnet20 => {
                let net = ResNet20::new(3);
                let graph = Graph::from_resnet20(&net);
                let cal = vec![crate::nn::dataset::random_image(&[3, 32, 32], 21)];
                (graph, cal)
            }
            Workload::Transformer => {
                let block = TransformerBlock::new(32, 4, 64, 42);
                let seq = 8;
                let graph = Graph::from_transformer_block(&block, seq);
                let cal = (0..3).map(|i| random_seq(seq, 32, 0x7E11 + i)).collect();
                (graph, cal)
            }
            Workload::Decode => {
                let model = DecoderModel::new(16, 2, 32, 32, 2, 24, 42);
                let seq = 16;
                let graph = Graph::from_decoder(&model, seq);
                let mut rng = Xoshiro256::seeded(0xDE_C0DE);
                let cal = (0..3)
                    .map(|_| {
                        let toks: Vec<usize> =
                            (0..seq).map(|_| (rng.next_u64() % 32) as usize).collect();
                        model.embed_seq(&toks)
                    })
                    .collect();
                (graph, cal)
            }
        }
    }
}

fn random_vec(n: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seeded(seed);
    Tensor::from_vec(&[n], (0..n).map(|_| rng.next_f32()).collect())
}

fn random_seq(seq: usize, d: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256::seeded(seed);
    Tensor::from_vec(&[seq, d], (0..seq * d).map(|_| (rng.next_f32() - 0.5) * 2.0).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip_and_graphs_build() {
        for w in Workload::ALL {
            assert_eq!(Workload::from_name(w.name()), Some(w));
            let (graph, cal) = w.build();
            assert!(!graph.nodes.is_empty());
            assert!(!cal.is_empty());
            graph.infer_shapes().unwrap();
        }
        assert_eq!(Workload::from_name("nope"), None);
    }
}
