//! Design-space exploration harness (DESIGN.md §15).
//!
//! The paper's 137.5 TOPS/W macro is one point in a hardware design
//! space: array geometry, ADC resolution, DTC gains, and energy constants
//! all trade off against latency, area, and accuracy. This module sweeps
//! that space analytically:
//!
//! 1. [`space::SweepSpace`] — candidate [`crate::config::HwSpec`] points
//!    from a TOML grid file (or the built-in 96-point default grid);
//! 2. [`workload::Workload`] — one calibrated graph per workload class
//!    (MLP, ResNet-20, transformer block, decode);
//! 3. [`score`] — each candidate is lowered with the real compiler and
//!    costed by [`crate::compiler::estimate_cost_lowered`], the *exact*
//!    noise-free placement cost model `compile` itself reports
//!    (bit-identical, asserted by `tests/hwspec_explore.rs`) — no
//!    simulation in the inner loop;
//! 4. [`pareto`] — the frontier of TOPS/W × latency × area ×
//!    accuracy-proxy, emitted as JSON by `cimsim explore`.
//!
//! Calibration (float network evaluation) runs **once** per sweep — it is
//! hardware-independent — so the per-candidate loop is lower + place
//! arithmetic only, thousands of points per second
//! (`BENCH_explore.json`).

pub mod pareto;
pub mod score;
pub mod space;
pub mod workload;

pub use pareto::{dominates, frontier_consistent, mark_frontier};
pub use score::{accuracy_proxy_bits, ExplorePoint};
pub use space::{Axis, Candidate, Expansion, SpaceError, SweepSpace};
pub use workload::Workload;

use crate::compiler::lower::{calibrate, lower, CompileError};
use crate::compiler::plan::check_quantize_structure;
use crate::compiler::{estimate_cost_lowered, CompileOptions};
use crate::config::Config;

/// A sweep failure: the space didn't expand or the workload didn't
/// compile at the base point.
#[derive(Debug)]
pub enum ExploreError {
    Space(SpaceError),
    Compile(CompileError),
}

impl std::fmt::Display for ExploreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExploreError::Space(e) => write!(f, "{e}"),
            ExploreError::Compile(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ExploreError {}

impl From<SpaceError> for ExploreError {
    fn from(e: SpaceError) -> Self {
        ExploreError::Space(e)
    }
}

impl From<CompileError> for ExploreError {
    fn from(e: CompileError) -> Self {
        ExploreError::Compile(e)
    }
}

/// A completed sweep: every scored candidate (frontier flags set), plus
/// the combinations that were skipped and why.
#[derive(Debug)]
pub struct SweepResult {
    pub workload: Workload,
    pub points: Vec<ExplorePoint>,
    pub n_frontier: usize,
    /// `(label, reason)` per skipped candidate: failed [`crate::config::HwSpec::validate`]
    /// or failed to lower the workload (e.g. activation bits too narrow).
    pub skipped: Vec<(String, String)>,
}

impl SweepResult {
    /// The whole sweep as one JSON document.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> =
            self.points.iter().map(|p| format!("    {}", p.to_json())).collect();
        let skipped: Vec<String> = self
            .skipped
            .iter()
            .map(|(label, reason)| {
                use crate::bench::{json_row, JsonField};
                format!(
                    "    {}",
                    json_row(&[
                        JsonField::Str("label", label),
                        JsonField::Str("reason", reason),
                    ])
                )
            })
            .collect();
        format!(
            "{{\n  \"workload\": \"{}\",\n  \"n_points\": {},\n  \"n_frontier\": {},\n  \
             \"points\": [\n{}\n  ],\n  \"skipped\": [\n{}\n  ]\n}}\n",
            self.workload.name(),
            self.points.len(),
            self.n_frontier,
            rows.join(",\n"),
            skipped.join(",\n"),
        )
    }

    /// Just the frontier, in scoring order.
    pub fn frontier(&self) -> impl Iterator<Item = &ExplorePoint> {
        self.points.iter().filter(|p| p.on_frontier)
    }
}

/// Run a sweep: expand `space`, score every valid candidate on `workload`
/// with the exact analytic cost model, and mark the Pareto frontier.
///
/// ```
/// use cimsim::explore::{run_sweep, SweepSpace, Workload};
///
/// let space = SweepSpace::parse("[sweep]\nmacro.rows = [32, 64]\n").unwrap();
/// let result = run_sweep(Workload::Mlp, &space).unwrap();
/// assert_eq!(result.points.len(), 2);
/// assert!(result.n_frontier >= 1);
/// ```
pub fn run_sweep(workload: Workload, space: &SweepSpace) -> Result<SweepResult, ExploreError> {
    let (graph, cal_inputs) = workload.build();
    let shapes = graph.infer_shapes().map_err(CompileError::Structure)?;
    check_quantize_structure(&graph)?;
    // Calibration is float evaluation of the workload graph — independent
    // of the candidate hardware, so it runs once for the whole sweep.
    let cal = calibrate(&graph, &cal_inputs)?;

    let expansion = space.expand()?;
    let mut skipped = expansion.skipped;
    let opts = CompileOptions::default();
    let mut points = Vec::with_capacity(expansion.candidates.len());
    for Candidate { label, hw } in expansion.candidates {
        let cfg = Config::from_hw(hw);
        match lower(&graph, &shapes, &cal, &cfg) {
            Ok(lowered) => {
                let report = estimate_cost_lowered(&lowered, &cfg, &opts);
                points.push(score::score(label, &cfg.hw, &report));
            }
            Err(e) => skipped.push((label, e.to_string())),
        }
    }
    let n_frontier = mark_frontier(&mut points);
    Ok(SweepResult { workload, points, n_frontier, skipped })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_point_sweep_scores_and_marks_a_consistent_frontier() {
        let space = SweepSpace::parse("[sweep]\nmacro.rows = [32, 64, 128]\n").unwrap();
        let result = run_sweep(Workload::Mlp, &space).unwrap();
        assert_eq!(result.points.len(), 3);
        assert!(result.n_frontier >= 1);
        assert!(frontier_consistent(&result.points));
        assert!(result.points.iter().all(|p| {
            p.tops_w > 0.0 && p.latency_ms > 0.0 && p.area_mm2 > 0.0 && p.accuracy_bits > 0.0
        }));
        let json = result.to_json();
        assert!(json.contains("\"workload\": \"mlp\""));
        assert!(json.contains("\"n_points\": 3"));
    }
}
