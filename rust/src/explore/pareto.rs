//! Pareto dominance over the four sweep objectives.
//!
//! Point `a` **dominates** `b` iff `a` is at least as good on every
//! objective (TOPS/W ↑, latency ↓, area ↓, accuracy bits ↑) and strictly
//! better on at least one. The frontier is the set of non-dominated
//! points. Equal-objective duplicates don't dominate each other, so ties
//! all stay on the frontier (DESIGN.md §15).

use crate::explore::score::ExplorePoint;

/// `a` dominates `b` under (TOPS/W ↑, latency ↓, area ↓, accuracy ↑).
pub fn dominates(a: &ExplorePoint, b: &ExplorePoint) -> bool {
    let ge = a.tops_w >= b.tops_w
        && a.latency_ms <= b.latency_ms
        && a.area_mm2 <= b.area_mm2
        && a.accuracy_bits >= b.accuracy_bits;
    let gt = a.tops_w > b.tops_w
        || a.latency_ms < b.latency_ms
        || a.area_mm2 < b.area_mm2
        || a.accuracy_bits > b.accuracy_bits;
    ge && gt
}

/// Set `on_frontier` on every non-dominated point; returns the frontier
/// size. O(n²), fine at sweep scale (hundreds of points).
pub fn mark_frontier(points: &mut [ExplorePoint]) -> usize {
    let n = points.len();
    let mut on = vec![true; n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&points[j], &points[i]) {
                on[i] = false;
                break;
            }
        }
    }
    let mut count = 0;
    for (p, flag) in points.iter_mut().zip(&on) {
        p.on_frontier = *flag;
        if *flag {
            count += 1;
        }
    }
    count
}

/// Dominance consistency of a marked sweep — what `explore-smoke` asserts:
/// no frontier point is dominated by any point, and every off-frontier
/// point is dominated by some frontier point.
pub fn frontier_consistent(points: &[ExplorePoint]) -> bool {
    points.iter().all(|p| {
        if p.on_frontier {
            !points.iter().any(|q| dominates(q, p))
        } else {
            points.iter().any(|q| q.on_frontier && dominates(q, p))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(tops_w: f64, latency_ms: f64, area_mm2: f64, accuracy_bits: f64) -> ExplorePoint {
        ExplorePoint {
            label: String::new(),
            rows: 64,
            engines: 16,
            cores: 4,
            adc_bits: 9,
            tops_w,
            latency_ms,
            area_mm2,
            accuracy_bits,
            cycles_per_input: 0,
            energy_fj_per_input: 0.0,
            total_tiles: 0,
            n_shards: 0,
            n_dynamic_shards: 0,
            on_frontier: false,
        }
    }

    #[test]
    fn dominance_is_strict_and_ties_survive() {
        let a = point(100.0, 1.0, 1.0, 9.0);
        let worse = point(90.0, 2.0, 1.0, 9.0);
        let tie = point(100.0, 1.0, 1.0, 9.0);
        let tradeoff = point(120.0, 2.0, 1.0, 9.0);
        assert!(dominates(&a, &worse));
        assert!(!dominates(&worse, &a));
        assert!(!dominates(&a, &tie) && !dominates(&tie, &a));
        assert!(!dominates(&a, &tradeoff) && !dominates(&tradeoff, &a));
    }

    #[test]
    fn frontier_marks_non_dominated_points_consistently() {
        let mut pts = vec![
            point(100.0, 1.0, 1.0, 9.0), // frontier
            point(90.0, 2.0, 1.0, 9.0),  // dominated by [0]
            point(120.0, 2.0, 1.0, 9.0), // frontier (tops_w tradeoff)
            point(100.0, 1.0, 2.0, 9.0), // dominated by [0]
        ];
        let n = mark_frontier(&mut pts);
        assert_eq!(n, 2);
        assert!(pts[0].on_frontier && pts[2].on_frontier);
        assert!(!pts[1].on_frontier && !pts[3].on_frontier);
        assert!(frontier_consistent(&pts));
        // Corrupt a flag: consistency must fail.
        pts[1].on_frontier = true;
        assert!(!frontier_consistent(&pts));
    }
}
