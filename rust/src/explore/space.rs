//! Sweep-space definition: which hardware keys vary, over which values.
//!
//! A space file is the same TOML subset `cimsim.toml` uses
//! ([`crate::util::tomlcfg`]), with two sections:
//!
//! ```toml
//! [base]                      # fixed overrides applied to every candidate
//! macro.clock_mhz = 250.0
//!
//! [sweep]                     # axes; the sweep is the cross product
//! macro.rows     = [32, 64, 128, 256]
//! macro.engines  = [8, 16, 32]
//! macro.cores    = [2, 4]
//! macro.adc_bits = [7, 8, 9, 10]
//! ```
//!
//! Keys are the dotted [`crate::config::HW_KEYS`] names. Every candidate
//! starts from [`HwSpec::paper_default`], applies `[base]`, then one value
//! per axis, and must pass [`HwSpec::validate`]; combinations that don't
//! (e.g. a `fold_offset` outside a swept `act_bits` range) are skipped
//! with a recorded reason rather than aborting the sweep.

use crate::config::{HwSpec, HW_KEYS};
use crate::util::tomlcfg::{Doc, ParseError, Value};

/// Integer-typed hardware keys: sweep/base values must be TOML ints
/// ([`HwSpec::overlay`] ignores floats for these, which would silently
/// no-op the axis).
const INT_KEYS: &[&str] = &[
    "macro.cores",
    "macro.engines",
    "macro.rows",
    "macro.act_bits",
    "macro.weight_bits",
    "macro.adc_bits",
    "enhance.fold_offset",
];

/// Boolean-typed hardware keys.
const BOOL_KEYS: &[&str] = &["enhance.fold", "enhance.boost"];

/// A sweep-space or expansion error. Syntax errors keep the TOML parser's
/// line numbers; semantic errors name the offending key.
#[derive(Debug)]
pub enum SpaceError {
    /// TOML syntax error (carries the 1-based line number).
    Parse(ParseError),
    /// Structurally valid TOML that doesn't describe a sweep space.
    Invalid(String),
}

impl std::fmt::Display for SpaceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpaceError::Parse(e) => write!(f, "{e}"),
            SpaceError::Invalid(msg) => write!(f, "invalid sweep space: {msg}"),
        }
    }
}

impl std::error::Error for SpaceError {}

impl From<ParseError> for SpaceError {
    fn from(e: ParseError) -> Self {
        SpaceError::Parse(e)
    }
}

/// One sweep axis: a hardware key and its candidate values.
#[derive(Clone, Debug, PartialEq)]
pub struct Axis {
    pub key: String,
    pub values: Vec<Value>,
}

/// A parsed sweep space: fixed `[base]` overrides plus `[sweep]` axes.
/// Axes are held in sorted key order, so expansion is deterministic
/// regardless of file layout.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SweepSpace {
    pub base: Vec<(String, Value)>,
    pub axes: Vec<Axis>,
}

/// One expanded candidate: a human-readable `key=value` label and the
/// validated hardware point.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub label: String,
    pub hw: HwSpec,
}

/// The result of expanding a [`SweepSpace`]: valid candidates plus the
/// `(label, reason)` of every grid combination that failed validation —
/// surfaced so a sweep never silently shrinks.
#[derive(Clone, Debug, Default)]
pub struct Expansion {
    pub candidates: Vec<Candidate>,
    pub skipped: Vec<(String, String)>,
}

fn check_value_type(key: &str, v: &Value) -> Result<(), SpaceError> {
    let ok = if INT_KEYS.contains(&key) {
        matches!(v, Value::Int(_))
    } else if BOOL_KEYS.contains(&key) {
        matches!(v, Value::Bool(_))
    } else {
        matches!(v, Value::Int(_) | Value::Float(_))
    };
    if ok {
        Ok(())
    } else {
        Err(SpaceError::Invalid(format!("wrong value type for `{key}`: {v:?}")))
    }
}

fn check_hw_key(key: &str) -> Result<(), SpaceError> {
    if HW_KEYS.contains(&key) {
        Ok(())
    } else {
        Err(SpaceError::Invalid(format!("unknown hardware key `{key}`")))
    }
}

impl SweepSpace {
    /// Parse a space file. Syntax errors carry line numbers; unknown keys,
    /// wrong value types, and empty axes are rejected.
    pub fn parse(text: &str) -> Result<SweepSpace, SpaceError> {
        let doc = Doc::parse(text)?;
        let mut base = Vec::new();
        let mut axes = Vec::new();
        for key in doc.keys() {
            let v = doc.get(key).expect("listed key resolves");
            if let Some(hw_key) = key.strip_prefix("base.") {
                check_hw_key(hw_key)?;
                check_value_type(hw_key, v)?;
                base.push((hw_key.to_string(), v.clone()));
            } else if let Some(hw_key) = key.strip_prefix("sweep.") {
                check_hw_key(hw_key)?;
                let values = match v {
                    Value::Array(items) if items.is_empty() => {
                        return Err(SpaceError::Invalid(format!("empty axis `{hw_key}`")));
                    }
                    Value::Array(items) => items.clone(),
                    scalar => vec![scalar.clone()],
                };
                for item in &values {
                    check_value_type(hw_key, item)?;
                }
                axes.push(Axis { key: hw_key.to_string(), values });
            } else {
                return Err(SpaceError::Invalid(format!(
                    "key `{key}` is outside [base]/[sweep]"
                )));
            }
        }
        // `Doc` iterates sorted; keep that order explicit for readers.
        base.sort_by(|a, b| a.0.cmp(&b.0));
        axes.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(SweepSpace { base, axes })
    }

    /// The built-in grid: array geometry × parallelism × ADC resolution
    /// around the paper's point (which the grid contains), 96 candidates.
    pub fn default_grid() -> SweepSpace {
        let ints = |xs: &[i64]| xs.iter().map(|&i| Value::Int(i)).collect::<Vec<_>>();
        SweepSpace {
            base: Vec::new(),
            axes: vec![
                Axis { key: "macro.adc_bits".into(), values: ints(&[7, 8, 9, 10]) },
                Axis { key: "macro.cores".into(), values: ints(&[2, 4]) },
                Axis { key: "macro.engines".into(), values: ints(&[8, 16, 32]) },
                Axis { key: "macro.rows".into(), values: ints(&[32, 64, 128, 256]) },
            ],
        }
    }

    /// Grid size before validation (product of axis lengths; 1 when there
    /// are no axes — the base point alone).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|a| a.values.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        false // a space always expands to at least the base point
    }

    /// Serialize back to the space-file TOML ([`SweepSpace::parse`] of the
    /// output reproduces `self` — asserted by the round-trip tests).
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        if !self.base.is_empty() {
            out.push_str("[base]\n");
            for (k, v) in &self.base {
                out.push_str(&format!("{k} = {}\n", fmt_value(v)));
            }
        }
        out.push_str("[sweep]\n");
        for axis in &self.axes {
            let vals: Vec<String> = axis.values.iter().map(fmt_value).collect();
            out.push_str(&format!("{} = [{}]\n", axis.key, vals.join(", ")));
        }
        out
    }

    /// Expand the cross product into validated hardware points. Axis
    /// values cycle with the last axis fastest (row-major over the sorted
    /// axes), so candidate order is deterministic.
    pub fn expand(&self) -> Result<Expansion, SpaceError> {
        let mut base_doc = Doc::default();
        for (k, v) in &self.base {
            base_doc.set(k, v.clone());
        }
        let mut base_hw = HwSpec::paper_default();
        base_hw
            .overlay(&base_doc)
            .map_err(|e| SpaceError::Invalid(format!("[base] overlay failed: {e}")))?;

        let n = self.len();
        let mut out = Expansion::default();
        for idx in 0..n {
            // Mixed-radix digits of `idx`, last axis fastest.
            let mut rem = idx;
            let mut picks = vec![0usize; self.axes.len()];
            for (a, axis) in self.axes.iter().enumerate().rev() {
                picks[a] = rem % axis.values.len();
                rem /= axis.values.len();
            }
            let mut doc = Doc::default();
            let mut label_parts = Vec::with_capacity(self.axes.len());
            for (a, axis) in self.axes.iter().enumerate() {
                let v = &axis.values[picks[a]];
                doc.set(&axis.key, v.clone());
                label_parts.push(format!("{}={}", axis.key, fmt_value(v)));
            }
            let label =
                if label_parts.is_empty() { "base".to_string() } else { label_parts.join(" ") };
            let mut hw = base_hw.clone();
            hw.overlay(&doc)
                .map_err(|e| SpaceError::Invalid(format!("axis overlay failed: {e}")))?;
            match hw.validate() {
                Ok(()) => out.candidates.push(Candidate { label, hw }),
                Err(e) => out.skipped.push((label, e.to_string())),
            }
        }
        Ok(out)
    }
}

fn fmt_value(v: &Value) -> String {
    match v {
        Value::Int(i) => format!("{i}"),
        Value::Float(f) => {
            // Keep a float marker so parse → serialize → parse preserves
            // the Int/Float distinction.
            let s = format!("{f}");
            if s.contains('.') || s.contains('e') || s.contains('E') {
                s
            } else {
                format!("{s}.0")
            }
        }
        Value::Bool(b) => format!("{b}"),
        Value::Str(s) => format!("\"{s}\""),
        Value::Array(items) => {
            let inner: Vec<String> = items.iter().map(fmt_value).collect();
            format!("[{}]", inner.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_contains_the_paper_point_and_enough_of_them() {
        let space = SweepSpace::default_grid();
        assert!(space.len() >= 64, "grid {} < 64 points", space.len());
        let exp = space.expand().unwrap();
        assert_eq!(exp.candidates.len(), space.len(), "default grid all-valid");
        let paper = HwSpec::paper_default();
        assert!(
            exp.candidates.iter().any(|c| c.hw == paper),
            "paper point missing from the default grid"
        );
    }

    #[test]
    fn parse_serialize_parse_round_trips() {
        let space = SweepSpace::parse(
            "[base]\nmacro.clock_mhz = 250.0\n[sweep]\nmacro.rows = [32, 64]\nmacro.adc_bits = [8, 9]\n",
        )
        .unwrap();
        let re = SweepSpace::parse(&space.to_toml()).unwrap();
        assert_eq!(space, re);
        assert_eq!(space.len(), 4);
    }

    #[test]
    fn rejects_unknown_keys_wrong_types_and_bad_syntax() {
        let e = SweepSpace::parse("[sweep]\nmacro.rowz = [1]\n").unwrap_err();
        assert!(matches!(e, SpaceError::Invalid(ref m) if m.contains("macro.rowz")), "{e}");
        let e = SweepSpace::parse("[sweep]\nmacro.rows = [64.5]\n").unwrap_err();
        assert!(matches!(e, SpaceError::Invalid(_)), "{e}");
        let e = SweepSpace::parse("[sweep]\nmacro.rows = []\n").unwrap_err();
        assert!(matches!(e, SpaceError::Invalid(ref m) if m.contains("empty axis")), "{e}");
        let e = SweepSpace::parse("[other]\nx = 1\n").unwrap_err();
        assert!(matches!(e, SpaceError::Invalid(_)), "{e}");
        // Syntax errors keep the TOML parser's line numbers.
        let e = SweepSpace::parse("[sweep]\nbroken\n").unwrap_err();
        match e {
            SpaceError::Parse(p) => assert_eq!(p.line, 2),
            other => panic!("expected parse error, got {other}"),
        }
    }

    #[test]
    fn invalid_combinations_skip_with_reasons() {
        // act_bits=2 makes fold_offset=8 (the paper default) out of range.
        let space = SweepSpace::parse("[sweep]\nmacro.act_bits = [2, 4]\n").unwrap();
        let exp = space.expand().unwrap();
        assert_eq!(exp.candidates.len() + exp.skipped.len(), 2);
        assert_eq!(exp.skipped.len(), 1, "skipped: {:?}", exp.skipped);
    }
}
