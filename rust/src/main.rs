//! `cimsim` CLI — leader entrypoint of the L3 coordinator.

use cimsim::config::{Config, EnhanceConfig};
use cimsim::coordinator::{Client, MlpDeployment, ServeConfig, ServeFrontend};
use cimsim::harness::{ablation, accuracy, figs};
use cimsim::mapping::NativeBackend;
use cimsim::nn::dataset::BlobDataset;
use cimsim::nn::mlp::{train, Mlp};
use cimsim::util::cli::{Args, Cli, CliError, CmdSpec, OptSpec};
use std::path::Path;

fn spec() -> Cli {
    let common = |mut opts: Vec<OptSpec>| -> Vec<OptSpec> {
        opts.push(OptSpec { name: "config", value_name: Some("FILE"), default: None, help: "TOML config file" });
        opts.push(OptSpec { name: "fold", value_name: None, default: None, help: "enable MAC-folding" });
        opts.push(OptSpec { name: "boost", value_name: None, default: None, help: "enable boosted-clipping" });
        opts.push(OptSpec { name: "enhanced", value_name: None, default: None, help: "enable both enhancements" });
        opts.push(OptSpec { name: "seed", value_name: Some("N"), default: Some("42"), help: "simulation seed" });
        opts.push(OptSpec { name: "out", value_name: Some("DIR"), default: Some("out"), help: "output directory for tables" });
        opts
    };
    Cli {
        program: "cimsim",
        about: "16Kb SRAM CIM macro simulator (Wang et al. 2023 reproduction)",
        commands: vec![
            CmdSpec { name: "info", about: "print macro geometry + operating point", opts: common(vec![]), positional: None },
            CmdSpec {
                name: "fig",
                about: "reproduce a paper figure (tables to stdout + out/)",
                opts: common(vec![
                    OptSpec { name: "id", value_name: Some("0-7"), default: Some("0"), help: "figure id (0 = all)" },
                    OptSpec { name: "quick", value_name: None, default: None, help: "reduced sample counts" },
                ]),
                positional: None,
            },
            CmdSpec { name: "ablation", about: "run the design-choice ablations", opts: common(vec![]), positional: None },
            CmdSpec {
                name: "calibrate",
                about: "re-derive the noise + energy calibration constants",
                opts: common(vec![OptSpec { name: "points", value_name: Some("N"), default: Some("3000"), help: "points per measurement" }]),
                positional: None,
            },
            CmdSpec {
                name: "sigma",
                about: "9K-point 1-sigma error measurement (Fig. 5a)",
                opts: common(vec![OptSpec { name: "points", value_name: Some("N"), default: Some("9000"), help: "test points" }]),
                positional: None,
            },
            CmdSpec {
                name: "serve",
                about: "serve a trained+quantized MLP over TCP on the simulated macro",
                opts: common(vec![
                    OptSpec { name: "requests", value_name: Some("N"), default: Some("256"), help: "demo client requests" },
                    OptSpec { name: "batch", value_name: Some("N"), default: Some("16"), help: "max dynamic batch" },
                    OptSpec { name: "pipeline", value_name: None, default: None, help: "serve on the pooled batched pipeline" },
                    OptSpec { name: "plan", value_name: None, default: None, help: "serve a graph-compiled plan (compiler path)" },
                    OptSpec { name: "stream", value_name: None, default: None, help: "layer-pipelined streamed execution (implies --plan)" },
                    OptSpec { name: "decode", value_name: None, default: None, help: "serve autoregressive LLM decoding (KV-cache continuous batching)" },
                    OptSpec { name: "gen", value_name: Some("N"), default: Some("8"), help: "tokens to generate per decode request" },
                    OptSpec { name: "layers", value_name: Some("N"), default: Some("2"), help: "decoder layers (--decode)" },
                    OptSpec { name: "prompt-len", value_name: Some("N"), default: Some("4"), help: "prompt tokens per decode request" },
                    OptSpec { name: "max-queue", value_name: Some("N"), default: Some("256"), help: "admission queue bound (backpressure)" },
                    OptSpec { name: "workers", value_name: Some("N"), default: Some("0"), help: "pipeline worker threads (0 = auto)" },
                    OptSpec { name: "metrics-addr", value_name: Some("ADDR"), default: None, help: "bind a Prometheus /metrics listener (e.g. 127.0.0.1:9184, port 0 = ephemeral)" },
                ]),
                positional: None,
            },
            CmdSpec {
                name: "trace",
                about: "record a span trace of a streamed plan run (Chrome trace_event JSON)",
                opts: common(vec![
                    OptSpec { name: "trace-out", value_name: Some("FILE"), default: Some("trace.json"), help: "trace output file (open in Perfetto / chrome://tracing)" },
                    OptSpec { name: "batch", value_name: Some("N"), default: Some("16"), help: "items per traced batch" },
                    OptSpec { name: "workers", value_name: Some("N"), default: Some("2"), help: "plan worker threads" },
                ]),
                positional: None,
            },
            CmdSpec {
                name: "explore",
                about: "sweep a hardware design space, emit the Pareto frontier JSON",
                opts: common(vec![
                    OptSpec { name: "workload", value_name: Some("NAME"), default: Some("resnet20"), help: "mlp | resnet20 | transformer | decode" },
                    OptSpec { name: "space", value_name: Some("FILE"), default: None, help: "sweep-space TOML (default: built-in 96-point grid)" },
                    OptSpec { name: "json-out", value_name: Some("FILE"), default: None, help: "sweep JSON path (default: <out>/explore_<workload>.json)" },
                    OptSpec { name: "frontier-only", value_name: None, default: None, help: "print only Pareto-frontier points" },
                ]),
                positional: None,
            },
            CmdSpec { name: "selftest", about: "quick end-to-end smoke test", opts: common(vec![]), positional: None },
        ],
    }
}

fn build_config(args: &Args) -> Result<Config, Box<dyn std::error::Error>> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_toml_file(Path::new(path))?,
        None => Config::default(),
    };
    if args.flag("enhanced") {
        cfg.enhance = EnhanceConfig::both();
    }
    if args.flag("fold") {
        cfg.enhance.fold = true;
    }
    if args.flag("boost") {
        cfg.enhance.boost = true;
    }
    cfg.sim.seed = args.get_u64("seed")?;
    cfg.sim.out_dir = args.get_string("out");
    Ok(cfg)
}

fn emit_tables(cfg: &Config, slug: &str, tables: &[cimsim::util::table::Table]) {
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.to_markdown());
        let _ = t.write_to(Path::new(&cfg.sim.out_dir), &format!("{slug}_{i}"));
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = spec();
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(CliError::HelpRequested(text)) => {
            println!("{text}");
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    // Resolve the MAC kernel tier up front: a bad CIMSIM_KERNEL override
    // fails fast here with the full error instead of panicking mid-run.
    let tier = cimsim::cim::simd::try_kernel_tier()?;
    let cfg = build_config(args)?;
    match args.cmd.as_str() {
        "info" => {
            println!("cimsim v{} — {} mode", cimsim::VERSION, cfg.enhance.label());
            println!("kernel: {tier} (override with CIMSIM_KERNEL=scalar|walk|popcount|swar|avx2|avx512|neon)");
            println!(
                "macro: {} cores x {} engines x {} rows = {:.0} Kb, {}b:{}b, {}-b readout",
                cfg.mac.cores, cfg.mac.engines, cfg.mac.rows, cfg.mac.macro_kb(),
                cfg.mac.act_bits, cfg.mac.weight_bits, cfg.mac.adc_bits
            );
            println!(
                "clock {:.0} MHz, area {} mm2, {} MACs ({} OPS) per macro op",
                cfg.mac.clock_mhz, cfg.energy.area_mm2,
                cfg.mac.macs_per_op(), cfg.mac.ops_per_op()
            );
            let our = figs::measure_our_row(&cfg);
            println!(
                "measured: {:.2}-{:.2} GOPS/Kb, {:.1}-{:.1} TOPS/W, 4b FoM {:.1}, 8b FoM {:.2}",
                our.gops_kb_dense, our.gops_kb_sparse,
                our.tops_w_dense, our.tops_w_sparse, our.fom_4b, our.fom_8b
            );
        }
        "fig" => {
            let id = args.get_usize("id")?;
            let tables = figs::run_figure(&cfg, id, args.flag("quick"));
            emit_tables(&cfg, &format!("fig{id}"), &tables);
        }
        "ablation" => {
            let tables = ablation::run_all(&cfg);
            emit_tables(&cfg, "ablation", &tables);
        }
        "calibrate" => {
            let n = args.get_usize("points")?;
            println!("solving energy constants against the Fig. 5/6 anchors...");
            let e = cimsim::energy::calibrate::solve(&cfg)?;
            println!("{e:#?}");
            println!("solving noise constants against 1.30% / 0.64% ...");
            let nz = accuracy::calibrate_noise(&cfg, n).map_err(std::io::Error::other)?;
            println!(
                "sigma_t_small = {:.4}\nsigma_t_floor = {:.4}",
                nz.sigma_t_small, nz.sigma_t_floor
            );
        }
        "sigma" => {
            let n = args.get_usize("points")?;
            for enh in [EnhanceConfig::default(), EnhanceConfig::both()] {
                let mut c = cfg.clone();
                c.enhance = enh;
                println!(
                    "{:<11} {:.4}% (paper: {})",
                    c.enhance.label(),
                    accuracy::sigma_error_pct(&c, n, 0xF1C5),
                    if c.enhance.fold { "0.64%" } else { "1.30%" }
                );
            }
        }
        "serve" => {
            let mut c = cfg.clone();
            c.enhance = EnhanceConfig::both();
            println!("kernel tier: {tier}");
            if args.flag("decode") {
                return serve_decode_demo(args, &c);
            }
            println!("training the edge MLP (144-32-10) on the blob dataset...");
            let mut d = BlobDataset::new(12, 0.05, c.sim.seed);
            let data: Vec<(Vec<f32>, usize)> =
                d.batch(300).into_iter().map(|s| (s.image.data, s.label)).collect();
            let mut mlp = Mlp::new(&[144, 32, 10], c.sim.seed ^ 1);
            let acc = train(&mut mlp, &data, 8, 0.05, c.sim.seed ^ 2);
            println!("float train accuracy: {:.1}%", acc * 100.0);
            let cal: Vec<Vec<f32>> = data.iter().take(50).map(|(x, _)| x.clone()).collect();
            let max_batch = args.get_usize("batch")?;
            let max_queue = args.get_usize("max-queue")?;
            let stream = args.flag("stream");
            let metrics_addr = args.get("metrics-addr").map(|s| s.to_string());
            let handle = if stream || args.flag("plan") {
                // Compiler path: ingest the float MLP, calibrate on the
                // training prefix, lower + place onto a pool, serve the plan.
                use cimsim::compiler::{compile, CompileOptions, Graph};
                use cimsim::nn::tensor::Tensor;
                let workers = args.get_usize("workers")?;
                let graph = Graph::from_mlp(&mlp);
                let cal_t: Vec<Tensor> = cal
                    .iter()
                    .map(|x| Tensor::from_vec(&[x.len()], x.clone()))
                    .collect();
                let opts = CompileOptions { workers, ..Default::default() };
                let plan = compile(graph, &cal_t, &c, &opts).map_err(std::io::Error::other)?;
                println!("{}", plan.cost_report().table(&c).to_markdown());
                let h = ServeConfig::builder()
                    .max_batch(max_batch)
                    .max_queue(max_queue)
                    .workers(workers)
                    .stream(stream)
                    .metrics_addr_opt(metrics_addr.clone())
                    .serve(ServeFrontend::Plan(plan))?;
                println!(
                    "serving on {} (graph-compiled plan{})",
                    h.addr,
                    if stream { ", streamed" } else { "" }
                );
                h
            } else if args.flag("pipeline") {
                let workers = args.get_usize("workers")?;
                let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
                let h = ServeConfig::builder()
                    .max_batch(max_batch)
                    .max_queue(max_queue)
                    .workers(workers)
                    .metrics_addr_opt(metrics_addr.clone())
                    .serve(ServeFrontend::Pipeline { deployment: dep, sim: c.clone() })?;
                println!("serving on {} (pooled pipeline)", h.addr);
                h
            } else {
                let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
                let backend = Box::new(NativeBackend::new(c.clone()));
                let h = ServeConfig::builder()
                    .max_batch(max_batch)
                    .max_queue(max_queue)
                    .metrics_addr_opt(metrics_addr.clone())
                    .serve(ServeFrontend::Backend { deployment: dep, backend })?;
                println!("serving on {}", h.addr);
                h
            };
            if let Some(m) = handle.metrics_addr() {
                println!("metrics on http://{m}/metrics (JSON at /metrics.json)");
            }
            let n_req = args.get_usize("requests")?;
            let addr = handle.addr;
            let mut clients: Vec<std::thread::JoinHandle<usize>> = Vec::new();
            for _ in 0..4usize {
                let reqs: Vec<(Vec<f32>, usize)> = d
                    .batch(n_req / 4)
                    .into_iter()
                    .map(|s| (s.image.data, s.label))
                    .collect();
                clients.push(std::thread::spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    let mut correct = 0;
                    for (x, y) in &reqs {
                        let l = c.infer(x).expect("infer");
                        if cimsim::coordinator::deployment::argmax(&l) == *y {
                            correct += 1;
                        }
                    }
                    correct
                }));
            }
            let correct: usize = clients.into_iter().map(|j| j.join().unwrap()).sum();
            let m = handle.shutdown();
            println!(
                "CIM accuracy under serving: {:.1}% over {} requests",
                100.0 * correct as f64 / n_req as f64,
                n_req
            );
            println!("{}", m.report(c.mac.clock_mhz * 1e6).render());
        }
        "trace" => {
            use cimsim::compiler::{compile, CompileOptions, Graph};
            use cimsim::nn::tensor::Tensor;
            let mut c = cfg.clone();
            c.enhance = EnhanceConfig::both();
            let batch = args.get_usize("batch")?;
            let workers = args.get_usize("workers")?;
            let out_path = args.get_string("trace-out");
            println!("training a small MLP (144-32-10) to trace...");
            let mut d = BlobDataset::new(12, 0.05, c.sim.seed);
            let data: Vec<(Vec<f32>, usize)> =
                d.batch(200).into_iter().map(|s| (s.image.data, s.label)).collect();
            let mut mlp = Mlp::new(&[144, 32, 10], c.sim.seed ^ 1);
            train(&mut mlp, &data, 4, 0.05, c.sim.seed ^ 2);
            let cal_t: Vec<Tensor> = data
                .iter()
                .take(40)
                .map(|(x, _)| Tensor::from_vec(&[x.len()], x.clone()))
                .collect();
            let graph = Graph::from_mlp(&mlp);
            let opts = CompileOptions { workers, ..Default::default() };
            let mut plan = compile(graph, &cal_t, &c, &opts).map_err(std::io::Error::other)?;
            let inputs: Vec<Vec<f32>> =
                data.iter().take(batch).map(|(x, _)| x.clone()).collect();
            // Spans record only between enable/disable; the run itself is
            // the ordinary streamed plan path.
            cimsim::telemetry::trace::clear();
            cimsim::telemetry::trace::set_enabled(true);
            plan.run_streamed_flat(&inputs).map_err(std::io::Error::other)?;
            cimsim::telemetry::trace::set_enabled(false);
            let spans = cimsim::telemetry::trace::len();
            std::fs::write(&out_path, cimsim::telemetry::trace::export_chrome_json())?;
            println!(
                "wrote {spans} spans to {out_path} — load it at ui.perfetto.dev or chrome://tracing"
            );
        }
        "explore" => {
            use cimsim::explore::{run_sweep, SweepSpace, Workload};
            let wname = args.get_string("workload");
            let workload = Workload::from_name(&wname).ok_or_else(|| {
                std::io::Error::other(format!(
                    "unknown workload `{wname}` (mlp | resnet20 | transformer | decode)"
                ))
            })?;
            let space = match args.get("space") {
                Some(path) => SweepSpace::parse(&std::fs::read_to_string(path)?)?,
                None => SweepSpace::default_grid(),
            };
            println!(
                "sweeping {} candidate hardware points on `{}` (analytic cost model)...",
                space.len(),
                workload.name()
            );
            let result = run_sweep(workload, &space)?;
            if !result.skipped.is_empty() {
                println!("skipped {} invalid candidate(s):", result.skipped.len());
                for (label, reason) in &result.skipped {
                    println!("  {label}: {reason}");
                }
            }
            println!(
                "{:<52} {:>9} {:>11} {:>9} {:>8}",
                "candidate", "TOPS/W", "latency ms", "mm2", "eff bits"
            );
            let frontier_only = args.flag("frontier-only");
            for pt in &result.points {
                if frontier_only && !pt.on_frontier {
                    continue;
                }
                println!(
                    "{:<52} {:>9.1} {:>11.3} {:>9.3} {:>8.2}{}",
                    pt.label,
                    pt.tops_w,
                    pt.latency_ms,
                    pt.area_mm2,
                    pt.accuracy_bits,
                    if pt.on_frontier { "  *" } else { "" }
                );
            }
            println!(
                "{} of {} points on the Pareto frontier (*)",
                result.n_frontier,
                result.points.len()
            );
            let out_path = match args.get("json-out") {
                Some(p) => std::path::PathBuf::from(p),
                None => Path::new(&cfg.sim.out_dir)
                    .join(format!("explore_{}.json", workload.name())),
            };
            if let Some(dir) = out_path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(&out_path, result.to_json())?;
            println!("wrote {}", out_path.display());
        }
        "selftest" => {
            let mut c = cfg.clone();
            c.noise.enabled = false;
            let mut sim = cimsim::cim::MacroSim::new(c.clone());
            let w: Vec<Vec<i64>> = (0..c.mac.rows)
                .map(|r| (0..c.mac.engines).map(|e| ((r + e) % 15) as i64 - 7).collect())
                .collect();
            sim.load_core(0, &w)?;
            let acts: Vec<i64> = (0..c.mac.rows).map(|r| (r % 16) as i64).collect();
            let mut rng = cimsim::util::rng::Xoshiro256::seeded(1);
            let got = sim.core_op(0, &acts, &mut rng)?;
            let want = sim.ideal_codes(0, &acts)?;
            assert_eq!(got.codes, want, "noise-free chip must match golden");
            println!("selftest OK: codes {:?}", &got.codes[..4]);
        }
        other => unreachable!("unknown command {other}"),
    }
    Ok(())
}

/// `serve --decode`: autoregressive generation over the wire. Builds a
/// small randomly-initialized GPT-style decoder, compiles it into a
/// `DecodePlan` (static weights resident, per-session KV caches), serves
/// it with token-level continuous batching, and drives demo clients whose
/// requests join and leave mid-generation.
fn serve_decode_demo(args: &Args, c: &Config) -> Result<(), Box<dyn std::error::Error>> {
    use cimsim::compiler::DecodePlan;
    use cimsim::nn::transformer::DecoderModel;
    use cimsim::util::rng::{Rng, Xoshiro256};

    let n_gen = args.get_usize("gen")?.max(1);
    let layers = args.get_usize("layers")?.max(1);
    let p_len = args.get_usize("prompt-len")?.max(1);
    let n_req = args.get_usize("requests")?.max(1);
    let max_batch = args.get_usize("batch")?;
    let max_queue = args.get_usize("max-queue")?;
    let stream = args.flag("stream");
    let metrics_addr = args.get("metrics-addr").map(|s| s.to_string());

    let vocab = 32usize;
    let max_seq = p_len + n_gen; // steps per request = p_len + n_gen - 1
    println!("building a {layers}-layer decoder (d_model 16, vocab {vocab}, max_seq {max_seq})...");
    let model = DecoderModel::new(16, 2, 32, vocab, layers, max_seq, c.sim.seed);
    let mut rng = Xoshiro256::seeded(c.sim.seed ^ 5);
    let cal: Vec<Vec<usize>> = (0..4)
        .map(|_| (0..max_seq.min(8)).map(|_| rng.next_below(vocab as u64) as usize).collect())
        .collect();
    let plan = DecodePlan::new(model, &cal, c, None).map_err(std::io::Error::other)?;
    println!(
        "placed {} static weight tiles; {} noise sites per token step",
        plan.static_tiles(),
        plan.sites()
    );

    let handle = ServeConfig::builder()
        .max_batch(max_batch)
        .max_queue(max_queue)
        .stream(stream)
        .metrics_addr_opt(metrics_addr)
        .serve(ServeFrontend::Decode(plan))?;
    println!(
        "serving decode on {} ({} slots{})",
        handle.addr,
        max_batch,
        if stream { ", streamed rounds" } else { "" }
    );
    if let Some(m) = handle.metrics_addr() {
        println!("metrics on http://{m}/metrics (JSON at /metrics.json)");
    }

    // Demo clients: two connections whose requests overlap, so sequences
    // join and finish mid-generation (continuous batching in action).
    let addr = handle.addr;
    let mut joins: Vec<std::thread::JoinHandle<usize>> = Vec::new();
    for t in 0..2u64 {
        let reqs = n_req.div_ceil(2);
        let seed = c.sim.seed ^ (t + 9);
        joins.push(std::thread::spawn(move || {
            let mut cl = Client::connect(addr).expect("connect");
            let mut rng = Xoshiro256::seeded(seed);
            let mut tokens = 0usize;
            for _ in 0..reqs {
                let mut req = vec![n_gen as f32];
                for _ in 0..p_len {
                    req.push(rng.next_below(vocab as u64) as f32);
                }
                tokens += cl.infer(&req).expect("decode").len();
            }
            tokens
        }));
    }
    let generated: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let m = handle.shutdown();
    println!("generated {generated} tokens over {n_req} requests");
    println!("{}", m.report(c.mac.clock_mhz * 1e6).render());
    Ok(())
}
