//! Per-sequence quantized K/V slabs for autoregressive decoding
//! (DESIGN.md §13).
//!
//! Attention at decode step `p` multiplies the new query against every
//! cached key (`q·Kᵀ`, a `d_h × (p+1)` ragged shape) and the softmax
//! probabilities against every cached value (`probs·V`, `(p+1) × d_h`).
//! Both operands are runtime tensors that *grow by one vector per step* —
//! the shape [`crate::pipeline::DynamicLinear`] cannot express with its
//! fixed-K×N per-call reload. [`KvCache`] closes the gap: a full-size
//! `max_seq` grid placed once, a float slab mirroring it, and an append
//! path that requantizes **incrementally**:
//!
//! * The weight scale is a *running max-abs* over every vector appended so
//!   far — monotone, so it either stays put or grows.
//! * Scale unchanged ⇒ every previously-written element requantizes to its
//!   exact previous code (quantization is a pure function of value and
//!   params), so only the new row/column strip reloads
//!   ([`DynamicLinear::reload_region`]) — the per-token reload cost is one
//!   tile strip, not the whole grid.
//! * Scale grew ⇒ the whole live region reloads under the new scale.
//! * The dead region is zeros, which quantize to code 0 under any scale,
//!   so ragged runs ([`DynamicLinear::run_ragged`]) skip those tiles
//!   entirely and still match a full-grid run bit for bit.
//!
//! A keys cache stores vectors as **columns** of a `[d_h][max_seq]` grid
//! (so `run` computes `q·Kᵀ` scores over the live positions, fully live in
//! K); a values cache stores them as **rows** of a `[max_seq][d_h]` grid
//! (so `run` computes `probs·V`, fully live in N). The values boundary must
//! be zero-point-free (softmax probabilities, `unsigned(1.0)`): dead
//! positions pad with code 0 and contribute nothing.

use crate::cim::MacroError;
use crate::config::Config;
use crate::mapping::executor::CimLinear;
use crate::mapping::{ExecStats, MapError};
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;
use crate::pipeline::batch::{StreamCtx, StreamKey};
use crate::pipeline::dynamic::DynamicLinear;

/// Which axis of the placed grid an appended vector occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Append {
    /// Keys: vector `p` is column `p` of a `[d_h][max_seq]` grid.
    Col,
    /// Values: vector `p` is row `p` of a `[max_seq][d_h]` grid.
    Row,
}

/// One sequence's quantized K or V slab on its own dedicated grid.
pub struct KvCache {
    grid: DynamicLinear,
    /// Float mirror of the resident grid (`[k][n]`, dead region zeros).
    slab: Tensor,
    a_params: QuantParams,
    axis: Append,
    /// Vector length `d_h`.
    d: usize,
    max_seq: usize,
    /// Vectors appended so far (the live sequence length).
    live: usize,
    /// Running max-abs over every element appended so far (monotone).
    running_max: f32,
    /// Grid-scale requantizations forced by a running-max growth.
    rescales: u64,
}

impl KvCache {
    fn place(
        cfg: &Config,
        shape: [usize; 2],
        axis: Append,
        d: usize,
        max_seq: usize,
        fab_base: usize,
        a_params: QuantParams,
    ) -> Result<Self, MacroError> {
        let slab = Tensor::zeros(&shape);
        let stage = CimLinear::with_params(
            &slab,
            vec![0.0; shape[1]],
            QuantParams::signed(0.0, cfg.mac.weight_bits),
            a_params,
            cfg,
        );
        let grid = DynamicLinear::place(stage, cfg, fab_base)?;
        Ok(Self { grid, slab, a_params, axis, d, max_seq, live: 0, running_max: 0.0, rescales: 0 })
    }

    /// A keys cache: `[d_h][max_seq]` grid, one appended key per column.
    /// `a_params` is the query boundary (signed is fine — K is fully live).
    pub fn keys(
        cfg: &Config,
        d_h: usize,
        max_seq: usize,
        fab_base: usize,
        a_params: QuantParams,
    ) -> Result<Self, MacroError> {
        Self::place(cfg, [d_h, max_seq], Append::Col, d_h, max_seq, fab_base, a_params)
    }

    /// A values cache: `[max_seq][d_h]` grid, one appended value per row.
    /// `a_params` must be zero-point-free (softmax probabilities,
    /// `unsigned`): ragged runs pad dead positions with code 0.
    pub fn values(
        cfg: &Config,
        d_h: usize,
        max_seq: usize,
        fab_base: usize,
        a_params: QuantParams,
    ) -> Result<Self, MacroError> {
        assert_eq!(
            a_params.zero_point(),
            0,
            "values cache needs a zero-point-free activation boundary"
        );
        Self::place(cfg, [max_seq, d_h], Append::Row, d_h, max_seq, fab_base, a_params)
    }

    /// Live sequence length (vectors appended so far).
    pub fn live(&self) -> usize {
        self.live
    }

    /// The resident grid (counters, current `CimLinear`).
    pub fn grid(&self) -> &DynamicLinear {
        &self.grid
    }

    /// The resident weight params (running-max scale of the last append).
    pub fn w_params(&self) -> QuantParams {
        self.grid.linear().w_params
    }

    /// Running max-abs over everything appended so far.
    pub fn running_max(&self) -> f32 {
        self.running_max
    }

    /// Appends that forced a whole-live-region requantization.
    pub fn rescales(&self) -> u64 {
        self.rescales
    }

    /// Quantize an activation vector at this cache's boundary.
    pub fn quantize_acts(&self, x: &[f32]) -> Vec<i64> {
        self.grid.linear().quantize_acts(x)
    }

    /// Append one vector at the next position: update the float slab and
    /// the running max, requantize under the (possibly grown) running-max
    /// scale, and reload only what changed — the new strip when the scale
    /// held, the whole live region when it grew (DESIGN.md §13). Returns
    /// the position the vector landed on; reload cycles/energy/loads are
    /// charged to `stats`.
    pub fn append(&mut self, v: &[f32], stats: &mut ExecStats) -> Result<usize, MacroError> {
        assert_eq!(v.len(), self.d, "appended vector length vs d_h");
        assert!(self.live < self.max_seq, "KV cache overflow: max_seq {}", self.max_seq);
        let p = self.live;
        match self.axis {
            Append::Col => {
                for (r, &x) in v.iter().enumerate() {
                    *self.slab.at2_mut(r, p) = x;
                }
            }
            Append::Row => {
                for (c, &x) in v.iter().enumerate() {
                    *self.slab.at2_mut(p, c) = x;
                }
            }
        }
        let vec_max = v.iter().fold(0f32, |m, x| m.max(x.abs()));
        self.running_max = self.running_max.max(vec_max);
        let wp = QuantParams::signed(self.running_max, self.grid.pool().cfg().mac.weight_bits);
        let grew = wp.scale != self.grid.linear().w_params.scale;
        self.live = p + 1;
        let (rows, cols) = match (self.axis, grew) {
            // Scale held: the dirty strip is just the new vector.
            (Append::Col, false) => (0..self.d, p..p + 1),
            (Append::Row, false) => (p..p + 1, 0..self.d),
            // Scale grew: every live code changes.
            (Append::Col, true) => (0..self.d, 0..self.live),
            (Append::Row, true) => (0..self.live, 0..self.d),
        };
        if grew {
            self.rescales += 1;
        }
        self.grid.reload_region(&self.slab, wp, self.a_params, rows, cols, stats)?;
        Ok(p)
    }

    /// Run one quantized activation vector against the live region: scores
    /// `q·Kᵀ[..live]` for a keys cache, `probs·V[..live]` for values.
    pub fn run(
        &self,
        key: StreamKey,
        acts_q: &[i64],
        ctx: &mut StreamCtx,
        stats: &mut ExecStats,
    ) -> Result<Vec<f32>, MapError> {
        let (live_k, live_n) = match self.axis {
            Append::Col => (self.d, self.live),
            Append::Row => (self.live, self.d),
        };
        self.grid.run_ragged(key, acts_q, live_k, live_n, ctx, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::mapping::NativeBackend;
    use crate::util::rng::{Rng, Xoshiro256};

    fn rand_vec(rng: &mut Xoshiro256, d: usize, amp: f32) -> Vec<f32> {
        (0..d).map(|_| (rng.next_f32() - 0.5) * amp).collect()
    }

    /// After every append, the keys cache's live scores equal a fresh
    /// full-K×live CimLinear over the same vectors (noise-free) — the
    /// incremental requantize+partial-reload path introduces no drift at
    /// matching scales.
    #[test]
    fn keys_cache_matches_fresh_layer_at_every_position() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let (d, max_seq) = (16, 40);
        let ap = QuantParams::signed_acts(1.0, cfg.mac.act_bits);
        let mut kv = KvCache::keys(&cfg, d, max_seq, 900, ap).unwrap();
        let mut rng = Xoshiro256::seeded(4);
        let mut stats = ExecStats::default();
        let mut ctx = StreamCtx::new(&cfg);
        let mut cols: Vec<Vec<f32>> = Vec::new();
        for step in 0..10usize {
            let kvec = rand_vec(&mut rng, d, 1.0 + step as f32 * 0.1);
            cols.push(kvec.clone());
            kv.append(&kvec, &mut stats).unwrap();
            assert_eq!(kv.live(), step + 1);

            let q = rand_vec(&mut rng, d, 1.0);
            let acts = kv.quantize_acts(&q);
            let key = StreamKey { seed: 3, epoch: step as u64, item: 0 };
            let got = kv.run(key, &acts, &mut ctx, &mut stats).unwrap();

            // Oracle: a fresh layer over exactly the live columns, under
            // the cache's (running-max) weight params.
            let mut w = Tensor::zeros(&[d, step + 1]);
            for (c, col) in cols.iter().enumerate() {
                for (r, &x) in col.iter().enumerate() {
                    *w.at2_mut(r, c) = x;
                }
            }
            let fresh =
                CimLinear::with_params(&w, vec![0.0; step + 1], kv.w_params(), ap, &cfg);
            let mut nat = NativeBackend::new(cfg.clone());
            let want = fresh.run_batch(&mut nat, &[q]).unwrap().remove(0);
            assert_eq!(got, want, "step {step}");
        }
        assert!(stats.weight_loads > 0);
    }

    /// Values cache: probs·V at growing positions matches the fresh-layer
    /// oracle, and appends under a held scale reload exactly one strip.
    #[test]
    fn values_cache_matches_fresh_layer_and_amortizes_reloads() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let (d, max_seq) = (16, 80);
        let ap = QuantParams::unsigned(1.0, cfg.mac.act_bits);
        let mut kv = KvCache::values(&cfg, d, max_seq, 901, ap).unwrap();
        let mut rng = Xoshiro256::seeded(9);
        let mut stats = ExecStats::default();
        let mut ctx = StreamCtx::new(&cfg);
        let mut vals: Vec<Vec<f32>> = Vec::new();
        // The first vector pins the running max at exactly 1.0; later ones
        // stay strictly inside it, so the scale holds and each append
        // reloads exactly one strip.
        for step in 0..8usize {
            let vvec: Vec<f32> = if step == 0 {
                (0..d).map(|i| if i % 2 == 0 { 1.0f32 } else { -1.0 }).collect()
            } else {
                rand_vec(&mut rng, d, 1.5) // |x| ≤ 0.75 < 1.0
            };
            vals.push(vvec.clone());
            let before = stats.weight_loads;
            kv.append(&vvec, &mut stats).unwrap();
            if step > 0 {
                let strip_tiles = (d as u64).div_ceil(cfg.mac.engines as u64);
                assert_eq!(
                    stats.weight_loads - before,
                    strip_tiles,
                    "held scale must reload one row strip (step {step})"
                );
            }

            let live = step + 1;
            let probs: Vec<f32> = (0..live).map(|i| 1.0 / (i + 1) as f32).collect();
            let acts = kv.quantize_acts(&probs);
            let key = StreamKey { seed: 7, epoch: step as u64, item: 0 };
            let got = kv.run(key, &acts, &mut ctx, &mut stats).unwrap();

            let mut w = Tensor::zeros(&[live, d]);
            for (r, row) in vals.iter().enumerate() {
                for (c, &x) in row.iter().enumerate() {
                    *w.at2_mut(r, c) = x;
                }
            }
            let fresh = CimLinear::with_params(&w, vec![0.0; d], kv.w_params(), ap, &cfg);
            let mut nat = NativeBackend::new(cfg.clone());
            let want = fresh.run_batch(&mut nat, &[probs]).unwrap().remove(0);
            assert_eq!(got, want, "step {step}");
        }
        assert_eq!(kv.rescales(), 1, "only the first append should grow the scale");
    }

    /// The running-max scale is monotone and, once every vector is in,
    /// bit-equal to a one-shot calibration of the full sequence.
    #[test]
    fn running_scale_converges_to_one_shot() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let (d, n) = (8, 12);
        let ap = QuantParams::signed_acts(1.0, cfg.mac.act_bits);
        let mut kv = KvCache::keys(&cfg, d, 16, 902, ap).unwrap();
        let mut rng = Xoshiro256::seeded(1);
        let mut stats = ExecStats::default();
        let mut all: Vec<f32> = Vec::new();
        let mut prev_scale = 0.0f32;
        for _ in 0..n {
            let v = rand_vec(&mut rng, d, 2.0);
            all.extend(&v);
            kv.append(&v, &mut stats).unwrap();
            assert!(kv.w_params().scale >= prev_scale, "running scale is monotone");
            prev_scale = kv.w_params().scale;
        }
        let one_shot = QuantParams::signed(
            all.iter().fold(0f32, |m, x| m.max(x.abs())),
            cfg.mac.weight_bits,
        );
        assert_eq!(kv.w_params().scale, one_shot.scale, "final scale is the one-shot scale");
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn append_past_max_seq_panics() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        let ap = QuantParams::unsigned(1.0, cfg.mac.act_bits);
        let mut kv = KvCache::values(&cfg, 4, 2, 903, ap).unwrap();
        let mut stats = ExecStats::default();
        for _ in 0..3 {
            kv.append(&[0.1, 0.2, 0.3, 0.4], &mut stats).unwrap();
        }
    }
}
