//! Batched, sharded inference pipeline — the macro-level analogue of the
//! paper's core argument. One cell-embedded readout amortizes over 64-way
//! analog accumulation *inside* a macro; this module amortizes weight
//! loading and per-op software overheads *across* a pool of macros:
//!
//! * [`MacroPool`] — N weight-stationary [`crate::cim::MacroSim`] shards.
//!   Every tile of a layer is pinned to one `(shard, core)` slot, so weights
//!   load exactly once and activations stream.
//! * [`PlacedLinear`] — a [`crate::mapping::executor::CimLinear`] whose
//!   row/column tiles have been placed on pool slots.
//! * [`BatchExecutor`] — runs a `[batch][features]` activation matrix across
//!   the resident tiles with `util::threadpool::parallel_chunks`, one
//!   reusable [`batch::StreamCtx`] (kernel scratch + op buffers) per
//!   worker, so the per-op hot path performs zero allocations.
//! * [`PipelineDeployment`] — the two-layer MLP deployment on a pool: the
//!   batched serve loop's engine (`coordinator::server::serve_pipeline`).
//!   Since the graph compiler landed this is one instance of a
//!   [`crate::compiler::CompiledPlan`] (the deployment's unit-scale graph).
//! * [`PoolBackend`] — the pool exposed as one virtual macro with
//!   `shards × cores` cores through the [`crate::mapping::CimBackend`]
//!   trait, so every existing tiled executor runs on the pool unchanged.
//! * [`DynamicLinear`] — the dynamic-weight escape hatch (DESIGN.md §10):
//!   a placed tile grid on dedicated shards whose weights are runtime
//!   tensors, re-quantized and swapped per call through
//!   [`MacroPool::reload_slot`] — the substrate of the compiler's
//!   attention/`MatMul` lowering.
//!
//! Determinism contract: with noise disabled the batched pipeline is
//! bit-identical to the sequential single-macro path (asserted by
//! `tests/pipeline_equivalence.rs`). With noise enabled, every op draws
//! from the substream keyed `(seed, epoch, item, tile)`
//! ([`batch::noise_stream`], DESIGN.md §9): results are independent of the
//! worker count and of how a batch is split or streamed — the property the
//! streaming scheduler's bit-identity rests on — while each `run_q` call
//! advances the epoch so repeated batches never replay one frozen noise
//! realization.
//!
//! Per-op work runs on the bit-plane fast-path kernel (DESIGN.md §4): each
//! row tile's activations are prepared once ([`crate::cim::OpScratch`]) and
//! every column tile walks its core's precomputed
//! [`crate::cim::BitPlanes`] — bit-identical to the scalar reference kernel
//! (`tests/kernel_equivalence.rs`), measured in `BENCH_kernel.json`.
//!
//! See [`MacroPool`] for a run-to-first-logits example; `cargo bench --bench
//! pipeline_throughput` measures per-request vs pooled serving on your
//! machine (README "Performance").

pub mod backend;
pub mod batch;
pub mod deploy;
pub mod dynamic;
pub mod kv_cache;
pub mod pool;

pub use backend::PoolBackend;
pub use batch::{
    noise_stream, run_vector, run_vector_into, run_vector_ragged, run_vector_ragged_into,
    BatchExecutor, StreamCtx, StreamKey,
};
pub use deploy::PipelineDeployment;
pub use dynamic::DynamicLinear;
pub use kv_cache::KvCache;
pub use pool::{MacroPool, PlacedLinear};
