//! The batch executor: stream a `[batch][K]` activation matrix through a
//! placed layer, batch-parallel across worker threads.
//!
//! Parallelism is over *batch items*, not tiles: every worker walks the full
//! tile grid for its slice of the batch, so each output row's partial sums
//! accumulate in the exact same (row-tile ascending) order as the sequential
//! executor — which is what makes the noise-free output bit-identical to
//! `CimLinear::run_batch_q` on a single macro. Each worker carries one
//! [`StreamCtx`] (kernel scratch, reusable [`CoreOpResult`], folded-MAC
//! buffer), so the per-op hot path performs zero allocations; with
//! `enhance.boost` on it recomputes the golden folded MAC per op for the
//! clipping counter, exactly like every other backend
//! (`mapping::account_core_op_into`).
//!
//! The per-op kernel is the bit-plane fast path (DESIGN.md §4): each row
//! tile's activations are [`OpScratch::prepare`]d once — validation,
//! folding, row bitmasks, nominal pulse widths — and every column tile
//! walks the preparation through its core's precomputed
//! [`crate::cim::BitPlanes`], bit-identical to the scalar reference kernel.
//!
//! When the layer is noise-free and inside the popcount exactness envelope
//! ([`KernelScratch::closed_form_capable`], DESIGN.md §11), a worker's whole
//! chunk additionally runs through the batch-transposed kernel: one
//! [`OpScratch::prepare_batch`] per row tile serves every item in the chunk,
//! and each column tile answers all items from a single cached weight-plane
//! pass ([`MacroPool::op_batch_prepared_into`]). Per-item outputs accumulate
//! in the same `(row-tile asc, col-tile asc, engine asc)` order as
//! [`run_vector`], so the batched outputs stay bit-identical; only the f64
//! energy tallies may reassociate (integer counters are order-free).
//!
//! **Noise-substream contract (DESIGN.md §9).** Every op's dynamic noise
//! draw comes from [`noise_stream`]`(seed, epoch, item, tile)` — a pure
//! function of the executor seed, the layer invocation's epoch, the item's
//! global index within the batch, and the tile index. Draws therefore do
//! not depend on the worker count, on how the batch was chunked across
//! workers, or on whether the items ran together or one at a time — which
//! is exactly what makes the streaming scheduler
//! (`compiler::CompiledPlan::run_streamed`) bit-identical to this barrier
//! path, noise on or off. Epochs advance once per `run_q` call (one layer
//! invocation); a streamed run reserves one epoch per layer up front via
//! [`BatchExecutor::reserve_epochs`] and replays the same assignment.

use crate::cim::{CoreOpResult, KernelScratch, OpScratch};
use crate::config::Config;
use crate::mapping::{account_core_op_into, ExecStats, MapError};
use crate::pipeline::pool::{MacroPool, PlacedLinear};
use crate::util::rng::{SplitMix64, Xoshiro256};
use crate::util::threadpool::{default_workers, parallel_chunks};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Derive the dynamic-noise substream for one core op, keyed on
/// `(seed, epoch, item, tile)` — the determinism contract of DESIGN.md §9.
///
/// The key components are absorbed through a SplitMix64 finalizer chain (a
/// standard avalanche-per-word hash), then expanded into a full xoshiro
/// state; with noise disabled the stream is never consumed, so noise-free
/// outputs are independent of this function entirely.
pub fn noise_stream(seed: u64, epoch: u64, item: u64, tile: u64) -> Xoshiro256 {
    let mut k = seed;
    for v in [epoch, item, tile] {
        k = SplitMix64::new(k ^ v).next_u64();
    }
    Xoshiro256::seeded(k)
}

/// The noise-substream key of one activation vector (DESIGN.md §9): every
/// op it runs draws from `noise_stream(seed, epoch, item, tile)`.
#[derive(Clone, Copy, Debug)]
pub struct StreamKey {
    /// The executor's substream seed.
    pub seed: u64,
    /// The layer invocation's epoch (one per `run_q` call / per streamed
    /// stage, assigned in node order).
    pub epoch: u64,
    /// The vector's global index within the barrier batch
    /// (`item × vectors_per_input + row` for streamed conv rows).
    pub item: u64,
}

/// Reusable per-worker buffers for the vector hot path: one per thread
/// (executor worker or scheduler stage), never shared across
/// differently-shaped configurations.
#[derive(Debug)]
pub struct StreamCtx {
    scratch: OpScratch,
    op: CoreOpResult,
    tile_acts: Vec<i64>,
    folded: Vec<i64>,
    /// Per-item padded row tiles for the batch-transposed kernel path
    /// (`run_vectors_closed_form`): `[item][rows]`.
    tile_acts_b: Vec<Vec<i64>>,
    /// Per-item op results of one batched column-tile op.
    ops: Vec<CoreOpResult>,
}

impl StreamCtx {
    pub fn new(cfg: &Config) -> Self {
        Self {
            scratch: OpScratch::new(&cfg.mac),
            op: CoreOpResult::default(),
            tile_acts: Vec::new(),
            folded: Vec::new(),
            tile_acts_b: Vec::new(),
            ops: Vec::new(),
        }
    }
}

/// Run ONE quantized activation vector through the placed tile grid with
/// the prepare-once kernel path: the bit-plane kernel is
/// [`OpScratch::prepare`]d once per row tile and every column tile of that
/// row streams through the preparation (the scheduler's `(item, row-tile)`
/// work unit). Returns the dequantized partial sums plus bias.
///
/// `key` names the noise substreams ([`noise_stream`]): the draws consumed
/// here are a pure function of `(seed, epoch, item, tile)`, independent of
/// worker assignment and batch composition — the barrier executor and the
/// streaming scheduler call this same routine with the same keys and are
/// therefore bit-identical (DESIGN.md §9).
pub fn run_vector(
    pool: &MacroPool,
    layer: &PlacedLinear,
    key: StreamKey,
    acts: &[i64],
    ctx: &mut StreamCtx,
    stats: &mut ExecStats,
) -> Result<Vec<f32>, MapError> {
    let mut out = Vec::new();
    run_vector_into(pool, layer, key, acts, ctx, stats, &mut out)?;
    Ok(out)
}

/// [`run_vector`] writing into a caller-owned buffer (`out` is resized to
/// `N` and zero-filled): the warm serve loop reuses one reply row per
/// connection and performs no allocations (DESIGN.md §14).
pub fn run_vector_into(
    pool: &MacroPool,
    layer: &PlacedLinear,
    key: StreamKey,
    acts: &[i64],
    ctx: &mut StreamCtx,
    stats: &mut ExecStats,
    out: &mut Vec<f32>,
) -> Result<(), MapError> {
    let lin = layer.linear();
    let (k, n) = (lin.k, lin.n);
    if acts.len() != k {
        return Err(MapError::Shape(format!("activation length {} vs layer K {k}", acts.len())));
    }
    let rows = lin.rows_per_tile();
    let engines = lin.engines_per_tile();
    let (n_rt, n_ct) = (lin.n_row_tiles(), lin.n_col_tiles());
    let deq = lin.a_params.scale * lin.w_params.scale;

    ctx.tile_acts.resize(rows, 0);
    out.resize(n, 0.0);
    out.fill(0.0);
    for rt in 0..n_rt {
        // Tile-granularity span. Disabled cost is one relaxed load per row
        // tile; the guard never touches `rng`, so noisy outputs stay
        // bit-identical either way (tests/telemetry_hotpath.rs).
        let _span = crate::span!("row_tile", "rt" => rt, "item" => key.item);
        let r0 = rt * rows;
        let upper = (r0 + rows).min(k);
        ctx.tile_acts.fill(0);
        ctx.tile_acts[..upper - r0].copy_from_slice(&acts[r0..upper]);
        // Prepare the bit-plane kernel once per row tile: validation,
        // folding, row masks and pulse widths are shared by every column
        // tile (shard-independent).
        ctx.scratch.prepare(pool.cfg(), &ctx.tile_acts)?;
        for ct in 0..n_ct {
            let slot = layer.slot(rt, ct);
            let mut rng = noise_stream(key.seed, key.epoch, key.item, (rt * n_ct + ct) as u64);
            pool.op_prepared_into(slot, &mut rng, &mut ctx.scratch, &mut ctx.op)?;
            let c0 = ct * engines;
            for (e, &v) in ctx.op.values.iter().enumerate() {
                let col = c0 + e;
                if col < n {
                    out[col] += v as f32 * deq;
                }
            }
            // Shared per-op accounting (counters, energy, and the boosted-
            // clipping scan) — one source of truth with every other
            // backend, reusing the worker's buffer.
            let (sh, co) = pool.locate(slot);
            let w = pool.shard(sh).core_weights(co)?;
            account_core_op_into(
                pool.cfg(),
                w,
                &ctx.tile_acts,
                &ctx.op.stats,
                stats,
                &mut ctx.folded,
            );
        }
    }
    // Signed-activation zero-point restore (`zp·Σw` per column), then bias
    // — the exact expression order `CimLinear::run_batch_q` uses, so the
    // pooled and sequential executors stay bit-identical (DESIGN.md §10).
    let zp = lin.act_zero();
    if zp != 0 {
        for (col, o) in out.iter_mut().enumerate() {
            *o -= (zp * lin.col_sum(col)) as f32 * deq;
        }
    }
    for (o, b) in out.iter_mut().zip(&lin.bias) {
        *o += b;
    }
    Ok(())
}

/// [`run_vector`] over the *live* top-left `live_k × live_n` region of a
/// placed grid whose resident K×N is larger — the KV-cache ragged-shape
/// path (DESIGN.md §13). Dead row/column tiles are skipped entirely: no
/// ops, no cycles, no noise draws. `acts` holds exactly the `live_k` live
/// activation codes.
///
/// Tile noise keys use the **full-grid** column stride (`rt·n_ct + ct`), so
/// a tile keeps the same substream index as the live region grows — which
/// is what makes a ragged run over the live prefix bit-identical to the
/// same-keyed run at any later (larger) live size, and keeps step-by-step
/// decode replayable (DESIGN.md §9/§13).
///
/// A signed activation boundary (`zero_point() != 0`) requires
/// `live_k == K`: the `zp·Σw` restore sums weight codes over all K rows,
/// which only cancels the padding when every row tile actually ran.
/// (Decode satisfies this by construction: score grids are fully live in K
/// = d_h, and context grids carry zp=0 softmax-probability params.)
pub fn run_vector_ragged(
    pool: &MacroPool,
    layer: &PlacedLinear,
    key: StreamKey,
    acts: &[i64],
    live_k: usize,
    live_n: usize,
    ctx: &mut StreamCtx,
    stats: &mut ExecStats,
) -> Result<Vec<f32>, MapError> {
    let mut out = Vec::new();
    run_vector_ragged_into(pool, layer, key, acts, live_k, live_n, ctx, stats, &mut out)?;
    Ok(out)
}

/// [`run_vector_ragged`] writing into a caller-owned buffer (resized to
/// `live_n` and zero-filled) — the decode steady state reuses its reply
/// rows the same way the serve loop does (DESIGN.md §14).
#[allow(clippy::too_many_arguments)]
pub fn run_vector_ragged_into(
    pool: &MacroPool,
    layer: &PlacedLinear,
    key: StreamKey,
    acts: &[i64],
    live_k: usize,
    live_n: usize,
    ctx: &mut StreamCtx,
    stats: &mut ExecStats,
    out: &mut Vec<f32>,
) -> Result<(), MapError> {
    let lin = layer.linear();
    let (k, n) = (lin.k, lin.n);
    if live_k == 0 || live_k > k || live_n == 0 || live_n > n {
        return Err(MapError::Shape(format!(
            "live region {live_k}×{live_n} vs placed grid {k}×{n}"
        )));
    }
    if acts.len() != live_k {
        return Err(MapError::Shape(format!(
            "activation length {} vs live K {live_k}",
            acts.len()
        )));
    }
    let zp = lin.act_zero();
    if zp != 0 && live_k != k {
        return Err(MapError::Shape(format!(
            "signed boundary (zp={zp}) needs a fully-live K ({live_k} vs {k})"
        )));
    }
    let rows = lin.rows_per_tile();
    let engines = lin.engines_per_tile();
    let n_ct = lin.n_col_tiles();
    let n_rt_live = live_k.div_ceil(rows);
    let n_ct_live = live_n.div_ceil(engines);
    let deq = lin.a_params.scale * lin.w_params.scale;

    ctx.tile_acts.resize(rows, 0);
    out.resize(live_n, 0.0);
    out.fill(0.0);
    for rt in 0..n_rt_live {
        let _span = crate::span!("row_tile", "rt" => rt, "item" => key.item);
        let r0 = rt * rows;
        let upper = (r0 + rows).min(live_k);
        ctx.tile_acts.fill(0);
        ctx.tile_acts[..upper - r0].copy_from_slice(&acts[r0..upper]);
        ctx.scratch.prepare(pool.cfg(), &ctx.tile_acts)?;
        for ct in 0..n_ct_live {
            let slot = layer.slot(rt, ct);
            // Full-grid tile stride: stable keys as the live region grows.
            let mut rng = noise_stream(key.seed, key.epoch, key.item, (rt * n_ct + ct) as u64);
            pool.op_prepared_into(slot, &mut rng, &mut ctx.scratch, &mut ctx.op)?;
            let c0 = ct * engines;
            for (e, &v) in ctx.op.values.iter().enumerate() {
                let col = c0 + e;
                if col < live_n {
                    out[col] += v as f32 * deq;
                }
            }
            let (sh, co) = pool.locate(slot);
            let w = pool.shard(sh).core_weights(co)?;
            account_core_op_into(
                pool.cfg(),
                w,
                &ctx.tile_acts,
                &ctx.op.stats,
                stats,
                &mut ctx.folded,
            );
        }
    }
    // Same zero-point + bias tail as `run_vector`, over the live columns.
    if zp != 0 {
        for (col, o) in out.iter_mut().enumerate() {
            *o -= (zp * lin.col_sum(col)) as f32 * deq;
        }
    }
    for (o, b) in out.iter_mut().zip(&lin.bias) {
        *o += b;
    }
    Ok(())
}

/// Run a worker's whole chunk of activation vectors through the
/// batch-transposed popcount kernel (DESIGN.md §11): one
/// [`OpScratch::prepare_batch`] per row tile serves every item, and each
/// column tile streams its cached weight planes against all items in one
/// pass ([`MacroPool::op_batch_prepared_into`]).
///
/// Noise-free only — batched ops cannot replay the per-`(item, tile)` noise
/// substreams — and gated on the popcount exactness envelope by the caller.
/// Per-item partial sums accumulate in the same `(rt, ct, engine)` order as
/// [`run_vector`], so outputs are bit-identical to the per-item path; the
/// f64 energy tallies in `stats` may reassociate across items (integer
/// counters are order-independent sums either way).
fn run_vectors_closed_form_into(
    pool: &MacroPool,
    layer: &PlacedLinear,
    acts_chunk: &[Vec<i64>],
    ctx: &mut StreamCtx,
    stats: &mut ExecStats,
    out: &mut [Vec<f32>],
) -> Result<(), MapError> {
    let lin = layer.linear();
    let (k, n) = (lin.k, lin.n);
    debug_assert_eq!(out.len(), acts_chunk.len(), "one output row per item");
    // Item-order shape validation, so the first bad vector reports exactly
    // as it would from the per-item path.
    for acts in acts_chunk {
        if acts.len() != k {
            return Err(MapError::Shape(format!(
                "activation length {} vs layer K {k}",
                acts.len()
            )));
        }
    }
    let rows = lin.rows_per_tile();
    let engines = lin.engines_per_tile();
    let (n_rt, n_ct) = (lin.n_row_tiles(), lin.n_col_tiles());
    let deq = lin.a_params.scale * lin.w_params.scale;
    let b = acts_chunk.len();

    for row in out.iter_mut() {
        row.resize(n, 0.0);
        row.fill(0.0);
    }
    ctx.tile_acts_b.resize_with(b, Vec::new);
    for rt in 0..n_rt {
        let r0 = rt * rows;
        let upper = (r0 + rows).min(k);
        for (tile, acts) in ctx.tile_acts_b.iter_mut().zip(acts_chunk) {
            tile.resize(rows, 0);
            tile.fill(0);
            tile[..upper - r0].copy_from_slice(&acts[r0..upper]);
        }
        // One batch-transposed prepare per row tile: validation, folding,
        // act-bit planes and stats templates shared by every column tile.
        ctx.scratch.prepare_batch(pool.cfg(), &ctx.tile_acts_b[..b])?;
        for ct in 0..n_ct {
            let slot = layer.slot(rt, ct);
            pool.op_batch_prepared_into(slot, &mut ctx.scratch, &mut ctx.ops)?;
            let c0 = ct * engines;
            let (sh, co) = pool.locate(slot);
            let w = pool.shard(sh).core_weights(co)?;
            for (i, op) in ctx.ops.iter().enumerate() {
                for (e, &v) in op.values.iter().enumerate() {
                    let col = c0 + e;
                    if col < n {
                        out[i][col] += v as f32 * deq;
                    }
                }
                account_core_op_into(
                    pool.cfg(),
                    w,
                    &ctx.tile_acts_b[i],
                    &op.stats,
                    stats,
                    &mut ctx.folded,
                );
            }
        }
    }
    // Same zero-point + bias tail as `run_vector`, per item.
    let zp = lin.act_zero();
    for o_row in out.iter_mut() {
        if zp != 0 {
            for (col, o) in o_row.iter_mut().enumerate() {
                *o -= (zp * lin.col_sum(col)) as f32 * deq;
            }
        }
        for (o, bias) in o_row.iter_mut().zip(&lin.bias) {
            *o += bias;
        }
    }
    Ok(())
}

/// Batch-parallel runner over a [`MacroPool`]. Each `run_q` call advances
/// an epoch that keys every op's noise substream ([`noise_stream`]), so
/// successive batches (and successive layers within one batch) draw fresh,
/// decorrelated noise rather than replaying one frozen realization — while
/// staying a pure function of `(seed, epoch, item, tile)`, independent of
/// the worker count (DESIGN.md §9).
#[derive(Debug)]
pub struct BatchExecutor {
    workers: usize,
    seed: u64,
    epoch: AtomicU64,
    /// Kernel tier override applied to every context this executor hands
    /// out (`None` runs the dispatched tier). Benches sweep tiers with
    /// [`BatchExecutor::set_tier`]; the tier-equivalence tests pin the
    /// batched path; serving leaves it unset.
    tier: Option<crate::cim::simd::KernelTier>,
    /// Reusable [`StreamCtx`]s, one acquired per run (or per worker chunk):
    /// after warmup every run reuses a pooled context instead of
    /// reallocating scratch state, which is what keeps the serve steady
    /// state allocation-free (DESIGN.md §14, `tests/alloc_steady_state.rs`).
    ctxs: Mutex<Vec<StreamCtx>>,
}

impl BatchExecutor {
    /// `workers == 0` selects `util::threadpool::default_workers()`.
    pub fn new(workers: usize, seed: u64) -> Self {
        let workers = if workers == 0 { default_workers() } else { workers };
        Self { workers, seed, epoch: AtomicU64::new(0), tier: None, ctxs: Mutex::new(Vec::new()) }
    }

    /// Pin every op this executor runs to `tier` (which must be available
    /// on this host — [`crate::cim::OpScratch::set_tier`] panics otherwise).
    /// Tiers without a batched kernel arm (scalar, walk) route every batch
    /// through the per-item path.
    pub fn set_tier(&mut self, tier: crate::cim::simd::KernelTier) {
        self.tier = Some(tier);
        // Drop pooled contexts so none keeps a previously-pinned tier.
        self.ctxs.lock().expect("ctx pool poisoned").clear();
    }

    /// The kernel tier this executor's ops run on.
    pub fn tier(&self) -> crate::cim::simd::KernelTier {
        self.tier.unwrap_or_else(crate::cim::simd::kernel_tier)
    }

    /// Take a context from the pool (or build the pool's first few during
    /// warmup). Contexts are returned via [`BatchExecutor::release_ctx`]
    /// even on error paths, so the pool converges to one context per
    /// concurrently-running worker and then stops allocating.
    pub(crate) fn acquire_ctx(&self, cfg: &Config) -> StreamCtx {
        let pooled = self.ctxs.lock().expect("ctx pool poisoned").pop();
        let mut ctx = pooled.unwrap_or_else(|| StreamCtx::new(cfg));
        if let Some(t) = self.tier {
            ctx.scratch.set_tier(t);
        }
        ctx
    }

    pub(crate) fn release_ctx(&self, ctx: StreamCtx) {
        self.ctxs.lock().expect("ctx pool poisoned").push(ctx);
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The substream seed every op key derives from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Reserve `n` consecutive epochs and return the first. A barrier
    /// `run_q` reserves one per call; a streamed plan run reserves one per
    /// layer up front so layer `l` uses `base + l` — the same assignment
    /// the barrier path would have made (DESIGN.md §9).
    pub fn reserve_epochs(&self, n: u64) -> u64 {
        self.epoch.fetch_add(n, Ordering::Relaxed)
    }

    /// Rewind (or fast-forward) the epoch counter. Replaying an epoch
    /// replays its exact noise draws — used by the determinism tests and
    /// the bench to compare barrier and streamed execution draw for draw.
    pub fn set_epoch(&self, epoch: u64) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    /// Run quantized activation vectors (each of length `K`) through the
    /// placed layer. Returns the `[batch][N]` dequantized partial sums plus
    /// bias, and the merged device counters of every op.
    pub fn run_q(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        acts_q: &[Vec<i64>],
    ) -> Result<(Vec<Vec<f32>>, ExecStats), MapError> {
        let epoch = self.reserve_epochs(1);
        self.run_q_at(pool, layer, acts_q, epoch, 0)
    }

    /// [`BatchExecutor::run_q`] writing into caller-owned buffers: `outs`
    /// is resized to one row per item (rows reused across calls), and the
    /// op counters are merged into `stats` without clearing it. After
    /// warmup this path performs zero allocations per call at `workers == 1`
    /// (DESIGN.md §14, proven by `tests/alloc_steady_state.rs`) — the serve
    /// loop's steady state.
    pub fn run_q_into(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        acts_q: &[Vec<i64>],
        outs: &mut Vec<Vec<f32>>,
        stats: &mut ExecStats,
    ) -> Result<(), MapError> {
        let epoch = self.reserve_epochs(1);
        self.run_q_at_into(pool, layer, acts_q, epoch, 0, outs, stats)
    }

    /// [`BatchExecutor::run_q`] with an explicit epoch and a base item
    /// index: vector `i` of `acts_q` uses substream key
    /// `(seed, epoch, item_base + i, tile)`. The streaming scheduler calls
    /// this per item with `item_base = item × vectors_per_input` to land on
    /// the exact keys the barrier path assigns across a whole batch.
    pub fn run_q_at(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        acts_q: &[Vec<i64>],
        epoch: u64,
        item_base: u64,
    ) -> Result<(Vec<Vec<f32>>, ExecStats), MapError> {
        let mut outs = Vec::new();
        let mut stats = ExecStats::default();
        self.run_q_at_into(pool, layer, acts_q, epoch, item_base, &mut outs, &mut stats)?;
        Ok((outs, stats))
    }

    /// [`BatchExecutor::run_q_at`] into caller-owned buffers (see
    /// [`BatchExecutor::run_q_into`]). Bit-identical to the allocating form
    /// for every worker count: chunking, substream keys, and accumulation
    /// order are unchanged.
    pub fn run_q_at_into(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        acts_q: &[Vec<i64>],
        epoch: u64,
        item_base: u64,
        outs: &mut Vec<Vec<f32>>,
        stats: &mut ExecStats,
    ) -> Result<(), MapError> {
        // Off the per-op path: one counter add + one span guard per run_q
        // call (a whole batch chunk), nothing per item or per tile.
        crate::telemetry::device().exec_items.add(acts_q.len() as u64);
        let _span = crate::span!(
            "exec_run_q",
            "items" => acts_q.len(),
            "epoch" => epoch,
        );
        // Noise-free layers inside the popcount exactness envelope route each
        // worker's chunk through the batch-transposed kernel (DESIGN.md §11)
        // — provided the dispatched tier has a batched arm; noisy layers must
        // replay per-(item, tile) substreams and stay on the per-item path.
        let batch_ok = !pool.cfg().noise.enabled
            && KernelScratch::closed_form_capable(pool.cfg())
            && self.tier().batched();
        outs.resize_with(acts_q.len(), Vec::new);

        if self.workers == 1 || acts_q.len() <= 1 {
            // Sequential: run inline on a pooled context instead of going
            // through `parallel_chunks` (whose single-chunk path still
            // allocates a result Vec) — this is the allocation-free steady
            // state (DESIGN.md §14).
            let mut ctx = self.acquire_ctx(pool.cfg());
            let res = if batch_ok && acts_q.len() > 1 {
                run_vectors_closed_form_into(pool, layer, acts_q, &mut ctx, stats, outs)
            } else {
                let mut res = Ok(());
                for (i, acts) in acts_q.iter().enumerate() {
                    let key = StreamKey { seed: self.seed, epoch, item: item_base + i as u64 };
                    res = run_vector_into(pool, layer, key, acts, &mut ctx, stats, &mut outs[i]);
                    if res.is_err() {
                        break;
                    }
                }
                res
            };
            self.release_ctx(ctx);
            return res;
        }

        let chunks = parallel_chunks(acts_q.len(), self.workers, |_w, start, end| {
            let mut ctx = self.acquire_ctx(pool.cfg());
            let mut stats = ExecStats::default();
            let mut out_rows: Vec<Vec<f32>> = Vec::new();
            let res = if batch_ok && end - start > 1 {
                out_rows.resize_with(end - start, Vec::new);
                run_vectors_closed_form_into(
                    pool,
                    layer,
                    &acts_q[start..end],
                    &mut ctx,
                    &mut stats,
                    &mut out_rows,
                )
            } else {
                let mut res = Ok(());
                for (i, acts) in acts_q[start..end].iter().enumerate() {
                    let key = StreamKey {
                        seed: self.seed,
                        epoch,
                        item: item_base + (start + i) as u64,
                    };
                    let mut row = Vec::new();
                    res = run_vector_into(pool, layer, key, acts, &mut ctx, &mut stats, &mut row);
                    if res.is_err() {
                        break;
                    }
                    out_rows.push(row);
                }
                res
            };
            self.release_ctx(ctx);
            res.map(|()| (out_rows, stats))
        });

        let mut idx = 0;
        for chunk in chunks {
            let (rows_out, s) = chunk?;
            for row in rows_out {
                outs[idx] = row;
                idx += 1;
            }
            stats.merge(&s);
        }
        Ok(())
    }

    /// Float convenience: quantize with the layer's activation params first.
    pub fn run(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        xs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, ExecStats), MapError> {
        let q: Vec<Vec<i64>> = xs.iter().map(|x| layer.linear().quantize_acts(x)).collect();
        self.run_q(pool, layer, &q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, EnhanceConfig};
    use crate::mapping::executor::CimLinear;
    use crate::mapping::NativeBackend;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::{Rng, Xoshiro256};

    fn rand_layer(cfg: &Config, k: usize, n: usize, seed: u64) -> CimLinear {
        let mut rng = Xoshiro256::seeded(seed);
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.next_f32() - 0.5).collect());
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        CimLinear::new(&w, bias, 1.0, cfg)
    }

    /// Noise-free: the batched pool output is bit-identical to the
    /// sequential single-macro executor, for every worker count.
    #[test]
    fn batched_bitwise_equals_sequential_noise_free() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (130, 20);
        let lin = rand_layer(&cfg, k, n, 7);
        let mut rng = Xoshiro256::seeded(13);
        let xs: Vec<Vec<f32>> =
            (0..12).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();

        let mut nat = NativeBackend::new(cfg.clone());
        let want = lin.run_batch(&mut nat, &xs).unwrap();

        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        for workers in [1usize, 2, 5] {
            let exec = BatchExecutor::new(workers, 99);
            let (got, stats) = exec.run(&pool, &placed, &xs).unwrap();
            assert_eq!(got.len(), want.len());
            for (rg, rw) in got.iter().zip(&want) {
                assert_eq!(rg, rw, "workers = {workers}");
            }
            assert_eq!(stats.core_ops as usize, placed.n_tiles() * xs.len());
            assert!(stats.energy_fj() > 0.0);
            // Boosted-clipping accounting matches the sequential backend
            // (same ops, same golden scan).
            assert_eq!(stats.clipped, nat.stats().clipped, "workers = {workers}");
        }
    }

    /// With noise on, the batched output is a pure function of
    /// `(seed, epoch, item, tile)`: independent of the worker count, and of
    /// whether items run together or one at a time — the streaming
    /// determinism contract at executor level.
    #[test]
    fn noisy_output_is_worker_and_split_invariant() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (130, 20);
        let lin = rand_layer(&cfg, k, n, 3);
        let mut rng = Xoshiro256::seeded(5);
        let xs: Vec<Vec<i64>> = (0..9)
            .map(|_| (0..k).map(|_| rng.next_range_i64(0, 15)).collect())
            .collect();
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();

        let exec1 = BatchExecutor::new(1, 42);
        let (want, stats) = exec1.run_q(&pool, &placed, &xs).unwrap();
        assert_eq!(stats.core_ops as usize, placed.n_tiles() * xs.len());

        // Same seed + epoch, different worker count: identical draws.
        let exec4 = BatchExecutor::new(4, 42);
        let (got, _) = exec4.run_q(&pool, &placed, &xs).unwrap();
        assert_eq!(got, want, "worker count must not change noisy output");

        // Same keys, items one at a time via run_q_at: identical draws.
        let exec_solo = BatchExecutor::new(1, 42);
        for (i, acts) in xs.iter().enumerate() {
            let (row, _) = exec_solo
                .run_q_at(&pool, &placed, std::slice::from_ref(acts), 0, i as u64)
                .unwrap();
            assert_eq!(row[0], want[i], "item {i} split off the batch must match");
        }

        // A later epoch draws different noise (no frozen realization).
        let (other, _) = exec1.run_q(&pool, &placed, &xs).unwrap();
        assert_ne!(other, want, "successive epochs must decorrelate");
    }

    /// With noise on, the batched path still produces code-quantized results
    /// near the ideal, and counters add up.
    #[test]
    fn noisy_batch_runs_and_counts() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (64, 16);
        let lin = rand_layer(&cfg, k, n, 3);
        let mut rng = Xoshiro256::seeded(5);
        let xs: Vec<Vec<f32>> =
            (0..8).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        let exec = BatchExecutor::new(0, 1);
        let (got, stats) = exec.run(&pool, &placed, &xs).unwrap();
        assert_eq!(got.len(), 8);
        assert!(got.iter().flatten().all(|v| v.is_finite()));
        assert_eq!(stats.core_ops, 8);
        assert!(stats.total_cycles > 0);
    }

    #[test]
    fn shape_errors_are_reported() {
        let cfg = Config::default();
        let lin = rand_layer(&cfg, 64, 16, 1);
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        let exec = BatchExecutor::new(1, 1);
        let bad = vec![vec![0i64; 63]];
        assert!(matches!(
            exec.run_q(&pool, &placed, &bad),
            Err(MapError::Shape(_))
        ));
    }

    /// Fully-live ragged run is bit-identical to `run_vector` — same tiles,
    /// same noise keys — noise on or off.
    #[test]
    fn ragged_fully_live_equals_run_vector() {
        for noise in [false, true] {
            let mut cfg = Config::default();
            cfg.noise.enabled = noise;
            cfg.enhance = EnhanceConfig::both();
            let (k, n) = (130, 20);
            let lin = rand_layer(&cfg, k, n, 11);
            let acts = lin.quantize_acts(
                &(0..k).map(|i| (i as f32 * 0.17).sin().abs()).collect::<Vec<_>>(),
            );
            let mut pool = MacroPool::new(cfg.clone());
            let placed = PlacedLinear::place(lin, &mut pool).unwrap();
            let key = StreamKey { seed: 5, epoch: 2, item: 3 };
            let mut ctx = StreamCtx::new(&cfg);
            let mut s1 = ExecStats::default();
            let want = run_vector(&pool, &placed, key, &acts, &mut ctx, &mut s1).unwrap();
            let mut s2 = ExecStats::default();
            let got =
                run_vector_ragged(&pool, &placed, key, &acts, k, n, &mut ctx, &mut s2).unwrap();
            assert_eq!(got, want, "noise={noise}");
            assert_eq!(s1.core_ops, s2.core_ops);
        }
    }

    /// Noise-free, zp=0: a ragged run over the live prefix of a grid whose
    /// dead region is zero weights matches the full run truncated — and
    /// skips the dead tiles' ops entirely.
    #[test]
    fn ragged_live_prefix_matches_truncated_full_run() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (130, 40); // 3×3 tile grid (64-row, 16-engine tiles)
        let (live_k, live_n) = (64, 20); // 1×2 live tiles
        let mut rng = Xoshiro256::seeded(77);
        let mut data = vec![0f32; k * n];
        for r in 0..live_k {
            for c in 0..live_n {
                data[r * n + c] = rng.next_f32() - 0.5;
            }
        }
        let wp = crate::nn::quant::QuantParams::signed(0.5, cfg.mac.weight_bits);
        let ap = crate::nn::quant::QuantParams::unsigned(1.0, cfg.mac.act_bits); // zp = 0
        let lin = CimLinear::with_params(
            &Tensor::from_vec(&[k, n], data),
            vec![0.0; n],
            wp,
            ap,
            &cfg,
        );
        let mut acts = vec![0i64; k];
        for (i, a) in acts.iter_mut().enumerate().take(live_k) {
            *a = (i % 15) as i64;
        }
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        let key = StreamKey { seed: 1, epoch: 0, item: 0 };
        let mut ctx = StreamCtx::new(&cfg);
        let mut s_full = ExecStats::default();
        let full = run_vector(&pool, &placed, key, &acts, &mut ctx, &mut s_full).unwrap();
        let mut s_rag = ExecStats::default();
        let got = run_vector_ragged(
            &pool,
            &placed,
            key,
            &acts[..live_k],
            live_k,
            live_n,
            &mut ctx,
            &mut s_rag,
        )
        .unwrap();
        assert_eq!(got.as_slice(), &full[..live_n]);
        assert_eq!(s_full.core_ops, 9, "full run touches every tile");
        assert_eq!(s_rag.core_ops, 2, "ragged run touches only live tiles");
        assert!(s_rag.total_cycles < s_full.total_cycles);
    }

    /// Ragged shape contract: bad live bounds and signed boundaries with a
    /// partial K are rejected.
    #[test]
    fn ragged_shape_errors_are_reported() {
        let cfg = Config::default();
        let w = Tensor::from_vec(&[64, 16], vec![0.01; 64 * 16]);
        let lin = CimLinear::with_params(
            &w,
            vec![0.0; 16],
            crate::nn::quant::QuantParams::signed(0.01, cfg.mac.weight_bits),
            crate::nn::quant::QuantParams::signed_acts(1.0, cfg.mac.act_bits), // zp ≠ 0
            &cfg,
        );
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        let key = StreamKey { seed: 0, epoch: 0, item: 0 };
        let mut ctx = StreamCtx::new(&cfg);
        let mut stats = ExecStats::default();
        let acts = vec![1i64; 32];
        assert!(matches!(
            run_vector_ragged(&pool, &placed, key, &acts, 32, 8, &mut ctx, &mut stats),
            Err(MapError::Shape(_))
        ), "zp != 0 with partial K must be refused");
        assert!(matches!(
            run_vector_ragged(&pool, &placed, key, &acts, 0, 8, &mut ctx, &mut stats),
            Err(MapError::Shape(_))
        ));
        assert!(matches!(
            run_vector_ragged(&pool, &placed, key, &acts, 64, 17, &mut ctx, &mut stats),
            Err(MapError::Shape(_))
        ));
    }

    #[test]
    fn noise_streams_are_stable_and_distinct() {
        let a: Vec<u64> = {
            let mut r = noise_stream(1, 2, 3, 4);
            (0..4).map(|_| crate::util::rng::Rng::next_u64(&mut r)).collect()
        };
        let b: Vec<u64> = {
            let mut r = noise_stream(1, 2, 3, 4);
            (0..4).map(|_| crate::util::rng::Rng::next_u64(&mut r)).collect()
        };
        assert_eq!(a, b, "keys are a pure function of their components");
        for other in [(0, 2, 3, 4), (1, 3, 3, 4), (1, 2, 4, 4), (1, 2, 3, 5)] {
            let mut r = noise_stream(other.0, other.1, other.2, other.3);
            let c: Vec<u64> = (0..4).map(|_| crate::util::rng::Rng::next_u64(&mut r)).collect();
            assert_ne!(a, c, "changing any key component must change the stream");
        }
    }
}
