//! The batch executor: stream a `[batch][K]` activation matrix through a
//! placed layer, batch-parallel across worker threads.
//!
//! Parallelism is over *batch items*, not tiles: every worker walks the full
//! tile grid for its contiguous slice of the batch, so each output row's
//! partial sums accumulate in the exact same (row-tile ascending) order as
//! the sequential executor — which is what makes the noise-free output
//! bit-identical to `CimLinear::run_batch_q` on a single macro. Each worker
//! carries one RNG substream, one [`OpScratch`], one reusable
//! [`CoreOpResult`] and one folded-MAC scratch, so the per-op hot path
//! performs zero allocations; with `enhance.boost` on it recomputes the
//! golden folded MAC per op for the clipping counter, exactly like every
//! other backend (`mapping::account_core_op_into`).
//!
//! The per-op kernel is the bit-plane fast path (DESIGN.md §4): each row
//! tile's activations are [`OpScratch::prepare`]d once — validation,
//! folding, row bitmasks, nominal pulse widths — and every column tile
//! walks the preparation through its core's precomputed
//! [`crate::cim::BitPlanes`], bit-identical to the scalar reference kernel
//! (noise draws are consumed op for op in the same order, so noisy batches
//! match the sequential path exactly too).

use crate::cim::{CoreOpResult, OpScratch};
use crate::mapping::{account_core_op_into, ExecStats, MapError};
use crate::pipeline::pool::{MacroPool, PlacedLinear};
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::{default_workers, parallel_chunks};
use std::sync::atomic::{AtomicU64, Ordering};

/// Batch-parallel runner over a [`MacroPool`]. Each `run_q` call advances an
/// epoch that is mixed into every worker's RNG substream, so successive
/// batches (and successive layers within one batch) draw fresh, decorrelated
/// noise rather than replaying one frozen realization.
#[derive(Debug)]
pub struct BatchExecutor {
    workers: usize,
    seed: u64,
    epoch: AtomicU64,
}

impl BatchExecutor {
    /// `workers == 0` selects `util::threadpool::default_workers()`.
    pub fn new(workers: usize, seed: u64) -> Self {
        let workers = if workers == 0 { default_workers() } else { workers };
        Self { workers, seed, epoch: AtomicU64::new(0) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run quantized activation vectors (each of length `K`) through the
    /// placed layer. Returns the `[batch][N]` dequantized partial sums plus
    /// bias, and the merged device counters of every op.
    pub fn run_q(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        acts_q: &[Vec<i64>],
    ) -> Result<(Vec<Vec<f32>>, ExecStats), MapError> {
        let lin = layer.linear();
        let (k, n) = (lin.k, lin.n);
        let rows = lin.rows_per_tile();
        let engines = lin.engines_per_tile();
        let (n_rt, n_ct) = (lin.n_row_tiles(), lin.n_col_tiles());
        let deq = lin.a_params.scale * lin.w_params.scale;

        let epoch = self.epoch.fetch_add(1, Ordering::Relaxed);
        let chunks = parallel_chunks(acts_q.len(), self.workers, |w, start, end| {
            let mut rng = Xoshiro256::seeded(
                self.seed
                    ^ epoch.wrapping_add(1).wrapping_mul(0xA076_1D64_78BD_642F)
                    ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(w as u64 + 1),
            );
            let mut scratch = OpScratch::new(&pool.cfg().mac);
            let mut op = CoreOpResult::default();
            let mut tile_acts = vec![0i64; rows];
            let mut folded = Vec::new();
            let mut stats = ExecStats::default();
            let mut out_rows: Vec<Vec<f32>> = Vec::with_capacity(end - start);
            for acts in &acts_q[start..end] {
                if acts.len() != k {
                    return Err(MapError::Shape(format!(
                        "activation length {} vs layer K {k}",
                        acts.len()
                    )));
                }
                let mut out = vec![0f32; n];
                for rt in 0..n_rt {
                    let r0 = rt * rows;
                    let upper = (r0 + rows).min(k);
                    tile_acts.fill(0);
                    tile_acts[..upper - r0].copy_from_slice(&acts[r0..upper]);
                    // Prepare the bit-plane kernel once per row tile:
                    // validation, folding, row masks and pulse widths are
                    // shared by every column tile (shard-independent).
                    scratch.prepare(pool.cfg(), &tile_acts)?;
                    for ct in 0..n_ct {
                        let slot = layer.slot(rt, ct);
                        pool.op_prepared_into(slot, &mut rng, &mut scratch, &mut op)?;
                        let c0 = ct * engines;
                        for (e, &v) in op.values.iter().enumerate() {
                            let col = c0 + e;
                            if col < n {
                                out[col] += v as f32 * deq;
                            }
                        }
                        // Shared per-op accounting (counters, energy, and the
                        // boosted-clipping scan) — one source of truth with
                        // every other backend, reusing the worker's buffer.
                        let (sh, co) = pool.locate(slot);
                        let w = pool.shard(sh).core_weights(co)?;
                        account_core_op_into(
                            pool.cfg(),
                            w,
                            &tile_acts,
                            &op.stats,
                            &mut stats,
                            &mut folded,
                        );
                    }
                }
                for (o, b) in out.iter_mut().zip(&lin.bias) {
                    *o += b;
                }
                out_rows.push(out);
            }
            Ok((out_rows, stats))
        });

        let mut all = Vec::with_capacity(acts_q.len());
        let mut stats = ExecStats::default();
        for chunk in chunks {
            let (rows_out, s) = chunk?;
            all.extend(rows_out);
            stats.merge(&s);
        }
        Ok((all, stats))
    }

    /// Float convenience: quantize with the layer's activation params first.
    pub fn run(
        &self,
        pool: &MacroPool,
        layer: &PlacedLinear,
        xs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, ExecStats), MapError> {
        let q: Vec<Vec<i64>> = xs.iter().map(|x| layer.linear().quantize_acts(x)).collect();
        self.run_q(pool, layer, &q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, EnhanceConfig};
    use crate::mapping::executor::CimLinear;
    use crate::mapping::NativeBackend;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::{Rng, Xoshiro256};

    fn rand_layer(cfg: &Config, k: usize, n: usize, seed: u64) -> CimLinear {
        let mut rng = Xoshiro256::seeded(seed);
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.next_f32() - 0.5).collect());
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
        CimLinear::new(&w, bias, 1.0, cfg)
    }

    /// Noise-free: the batched pool output is bit-identical to the
    /// sequential single-macro executor, for every worker count.
    #[test]
    fn batched_bitwise_equals_sequential_noise_free() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (130, 20);
        let lin = rand_layer(&cfg, k, n, 7);
        let mut rng = Xoshiro256::seeded(13);
        let xs: Vec<Vec<f32>> =
            (0..12).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();

        let mut nat = NativeBackend::new(cfg.clone());
        let want = lin.run_batch(&mut nat, &xs).unwrap();

        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        for workers in [1usize, 2, 5] {
            let exec = BatchExecutor::new(workers, 99);
            let (got, stats) = exec.run(&pool, &placed, &xs).unwrap();
            assert_eq!(got.len(), want.len());
            for (rg, rw) in got.iter().zip(&want) {
                assert_eq!(rg, rw, "workers = {workers}");
            }
            assert_eq!(stats.core_ops as usize, placed.n_tiles() * xs.len());
            assert!(stats.energy_fj() > 0.0);
            // Boosted-clipping accounting matches the sequential backend
            // (same ops, same golden scan).
            assert_eq!(stats.clipped, nat.stats().clipped, "workers = {workers}");
        }
    }

    /// With noise on, the batched path still produces code-quantized results
    /// near the ideal, and counters add up.
    #[test]
    fn noisy_batch_runs_and_counts() {
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (64, 16);
        let lin = rand_layer(&cfg, k, n, 3);
        let mut rng = Xoshiro256::seeded(5);
        let xs: Vec<Vec<f32>> =
            (0..8).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        let exec = BatchExecutor::new(0, 1);
        let (got, stats) = exec.run(&pool, &placed, &xs).unwrap();
        assert_eq!(got.len(), 8);
        assert!(got.iter().flatten().all(|v| v.is_finite()));
        assert_eq!(stats.core_ops, 8);
        assert!(stats.total_cycles > 0);
    }

    #[test]
    fn shape_errors_are_reported() {
        let cfg = Config::default();
        let lin = rand_layer(&cfg, 64, 16, 1);
        let mut pool = MacroPool::new(cfg.clone());
        let placed = PlacedLinear::place(lin, &mut pool).unwrap();
        let exec = BatchExecutor::new(1, 1);
        let bad = vec![vec![0i64; 63]];
        assert!(matches!(
            exec.run_q(&pool, &placed, &bad),
            Err(MapError::Shape(_))
        ));
    }
}
