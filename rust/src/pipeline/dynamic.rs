//! Dynamic-weight execution: a placed layer whose weights are runtime
//! tensors, swapped between calls (DESIGN.md §10).
//!
//! The paper's deployment story is weight-stationary — weights load once,
//! activations stream. Attention breaks that: Q·Kᵀ and attn·V multiply two
//! runtime tensors, so one operand must be written into the array *during*
//! inference. [`DynamicLinear`] packages that pattern: a same-shape tile
//! grid placed once on **dedicated shards** (its own [`MacroPool`], so a
//! swap never invalidates a co-resident weight-stationary tile and the
//! shared board's placement balance is undisturbed), plus a
//! [`DynamicLinear::reload`] path that re-quantizes the per-call operand
//! (max-abs signed, the "per-call requantization step") and swaps every
//! tile through [`crate::pipeline::PlacedLinear::reload`] →
//! [`MacroPool::reload_slot`] — the existing load-time path, so the
//! precomputed `BitPlanes` rebuild and the bit-plane kernel is untouched.
//!
//! Reloads are charged to the device counters like any other work:
//! `tiles × `[`crate::cim::timing::weight_load_cycles`] cycles and
//! `tiles × `[`crate::energy::weight_load_energy`] fJ per swap, which is
//! what makes the compiler's reload-vs-compute cost split exact.

use crate::cim::timing::weight_load_cycles;
use crate::cim::MacroError;
use crate::config::Config;
use crate::energy::weight_load_energy;
use crate::mapping::executor::CimLinear;
use crate::mapping::{ExecStats, MapError};
use crate::nn::quant::QuantParams;
use crate::nn::tensor::Tensor;
use crate::pipeline::batch::{run_vector, run_vector_ragged, StreamCtx, StreamKey};
use crate::pipeline::pool::{MacroPool, PlacedLinear};

/// A placed tile grid with swappable weights on its own dedicated shards.
pub struct DynamicLinear {
    pool: MacroPool,
    placed: PlacedLinear,
    reloads: u64,
}

impl DynamicLinear {
    /// Place `lin`'s tile grid on a fresh dedicated pool (fabrication drawn
    /// as dies `fab_base, fab_base+1, …` so dedicated boards decorrelate
    /// from the shared one) and load the staging weights once.
    pub fn place(lin: CimLinear, cfg: &Config, fab_base: usize) -> Result<Self, MacroError> {
        let mut pool = MacroPool::with_fab_base(cfg.clone(), fab_base);
        let placed = PlacedLinear::place(lin, &mut pool)?;
        Ok(Self { pool, placed, reloads: 0 })
    }

    /// The dedicated pool the tiles live on.
    pub fn pool(&self) -> &MacroPool {
        &self.pool
    }

    /// The placed tile grid (the unit `pipeline::batch::run_vector` runs).
    pub fn placed(&self) -> &PlacedLinear {
        &self.placed
    }

    /// The currently resident quantized layer (last reload's staging).
    pub fn linear(&self) -> &CimLinear {
        self.placed.linear()
    }

    /// Weight swaps performed so far.
    pub fn reloads(&self) -> u64 {
        self.reloads
    }

    /// Reload cycles one swap of this grid costs.
    pub fn reload_cycles(&self) -> u64 {
        self.placed.n_tiles() as u64 * weight_load_cycles(self.pool.cfg())
    }

    /// Swap in a per-call operand: quantize `w_cols` (`[K][N]`, column per
    /// output) max-abs signed at the macro's weight precision, stage it as
    /// a fresh [`CimLinear`] under `a_params` (the layer's activation
    /// boundary, so dequantization folds both scales), and reload every
    /// tile in place. Charges the swap's cycles/energy/weight-load counters
    /// to `stats` (DESIGN.md §10).
    pub fn reload(
        &mut self,
        w_cols: &Tensor,
        a_params: QuantParams,
        stats: &mut ExecStats,
    ) -> Result<(), MacroError> {
        let n = self.placed.linear().n;
        let w_params = QuantParams::signed(w_cols.max_abs(), self.pool.cfg().mac.weight_bits);
        // The cfg borrow ends when staging returns, freeing `self.pool`
        // for the mutable reload — no per-call Config clone on this path.
        let lin =
            CimLinear::with_params(w_cols, vec![0.0; n], w_params, a_params, self.pool.cfg());
        self.placed.reload(&mut self.pool, lin)?;
        self.reloads += 1;
        let tiles = self.placed.n_tiles() as u64;
        stats.weight_loads += tiles;
        stats.total_cycles += tiles * weight_load_cycles(self.pool.cfg());
        stats.energy.add(&weight_load_energy(self.pool.cfg(), tiles));
        Ok(())
    }

    /// Partial swap for the KV-cache append path (DESIGN.md §13): stage
    /// `w_cols` under **caller-chosen** weight params (the cache's running
    /// max-abs scale, monotone across appends) and reload only the tiles
    /// covering the element region `rows × cols`. When the scale is
    /// unchanged, every element outside the dirty strip quantizes to its
    /// previous code, so the narrow reload is bit-equal to a full one; the
    /// cache reloads everything live whenever its scale grows. Charges only
    /// the tiles actually written.
    pub fn reload_region(
        &mut self,
        w_cols: &Tensor,
        w_params: QuantParams,
        a_params: QuantParams,
        rows: std::ops::Range<usize>,
        cols: std::ops::Range<usize>,
        stats: &mut ExecStats,
    ) -> Result<u64, MacroError> {
        let n = self.placed.linear().n;
        let lin =
            CimLinear::with_params(w_cols, vec![0.0; n], w_params, a_params, self.pool.cfg());
        let rpt = lin.rows_per_tile();
        let ept = lin.engines_per_tile();
        let rts = rows.start / rpt..rows.end.div_ceil(rpt);
        let cts = cols.start / ept..cols.end.div_ceil(ept);
        let written = self.placed.reload_tiles(&mut self.pool, lin, rts, cts)?;
        self.reloads += 1;
        stats.weight_loads += written;
        stats.total_cycles += written * weight_load_cycles(self.pool.cfg());
        stats.energy.add(&weight_load_energy(self.pool.cfg(), written));
        Ok(written)
    }

    /// Run one quantized vector over the live `live_k × live_n` corner of
    /// the resident grid ([`run_vector_ragged`]): the KV-cache MatMul whose
    /// live shape grows with the decode position while the placed grid
    /// stays `K×N`-stationary.
    pub fn run_ragged(
        &self,
        key: StreamKey,
        acts: &[i64],
        live_k: usize,
        live_n: usize,
        ctx: &mut StreamCtx,
        stats: &mut ExecStats,
    ) -> Result<Vec<f32>, MapError> {
        run_vector_ragged(&self.pool, &self.placed, key, acts, live_k, live_n, ctx, stats)
    }

    /// One dynamic-weight item, reload-to-results under a single `&mut`
    /// borrow: swap in `w_cols` ([`DynamicLinear::reload`]) and stream every
    /// quantized row of the item through the freshly resident grid. Row `r`
    /// uses substream key `(seed, epoch, item_base + r, tile)`.
    ///
    /// This is the per-(item, tile) reload barrier the compiled plans rely
    /// on (DESIGN.md §10/§13): because the reload and all of the item's row
    /// ops happen inside one exclusive borrow, the borrow checker makes it
    /// impossible for a second stream sharing this layer (behind the
    /// `CompiledLayer` Mutex) to interleave its own reload between this
    /// item's swap and its ops — the contention property pinned by
    /// `tests/dynamic_contention.rs`.
    #[allow(clippy::too_many_arguments)]
    pub fn run_item(
        &mut self,
        w_cols: &Tensor,
        a_params: QuantParams,
        rows_q: &[Vec<i64>],
        seed: u64,
        epoch: u64,
        item_base: u64,
        ctx: &mut StreamCtx,
        stats: &mut ExecStats,
    ) -> Result<Vec<Vec<f32>>, MapError> {
        self.reload(w_cols, a_params, stats)?;
        let mut out = Vec::with_capacity(rows_q.len());
        for (r, acts) in rows_q.iter().enumerate() {
            let key = StreamKey { seed, epoch, item: item_base + r as u64 };
            out.push(run_vector(&self.pool, &self.placed, key, acts, ctx, stats)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::mapping::NativeBackend;
    use crate::pipeline::batch::{run_vector, StreamCtx, StreamKey};
    use crate::util::rng::{Rng, Xoshiro256};

    fn rand_cols(k: usize, n: usize, seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seeded(seed);
        Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.next_f32() - 0.5).collect())
    }

    /// A reloaded dynamic layer computes exactly what a fresh `CimLinear`
    /// on a sequential macro computes (noise-free), and the swap is
    /// charged: cycles, energy and weight loads all move.
    #[test]
    fn reload_matches_fresh_sequential_layer() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let (k, n) = (100, 20);
        let a_params = QuantParams::signed_acts(1.0, cfg.mac.act_bits);
        let stage = CimLinear::with_params(
            &Tensor::zeros(&[k, n]),
            vec![0.0; n],
            QuantParams::signed(0.0, cfg.mac.weight_bits),
            a_params,
            &cfg,
        );
        let mut dl = DynamicLinear::place(stage, &cfg, 3).unwrap();
        assert_eq!(dl.reloads(), 0);

        let mut stats = ExecStats::default();
        let mut ctx = StreamCtx::new(&cfg);
        for call in 0..3u64 {
            let w = rand_cols(k, n, 50 + call);
            dl.reload(&w, a_params, &mut stats).unwrap();
            let x: Vec<f32> = (0..k).map(|i| ((i as f32 * 0.13).sin())).collect();
            let acts = dl.linear().quantize_acts(&x);
            let key = StreamKey { seed: 9, epoch: call, item: 0 };
            let got =
                run_vector(dl.pool(), dl.placed(), key, &acts, &mut ctx, &mut stats).unwrap();

            let wp = QuantParams::signed(w.max_abs(), cfg.mac.weight_bits);
            let fresh = CimLinear::with_params(&w, vec![0.0; n], wp, a_params, &cfg);
            let mut nat = NativeBackend::new(cfg.clone());
            let want = fresh.run_batch(&mut nat, &[x]).unwrap().remove(0);
            assert_eq!(got, want, "call {call}");
        }
        assert_eq!(dl.reloads(), 3);
        let tiles = dl.placed().n_tiles() as u64;
        assert_eq!(stats.weight_loads, 3 * tiles);
        assert!(stats.total_cycles >= 3 * dl.reload_cycles());
        assert!(stats.energy_fj() > 0.0);
    }
}
