//! [`PoolBackend`]: a macro pool exposed through the [`CimBackend`] trait as
//! ONE virtual macro with `n_shards × cores` cores. The tiled executors in
//! `mapping::executor` read the core count from `config()`, so with enough
//! virtual cores every tile of a layer lands on its own resident slot and
//! weights load exactly once per `run_batch_q` call — the tile→shard
//! placement story without changing a line of executor code.

use crate::cim::{CoreOpResult, MacroError, OpScratch};
use crate::config::Config;
use crate::mapping::{account_core_op, CimBackend, ExecStats, MapError};
use crate::pipeline::pool::MacroPool;
use crate::util::rng::Xoshiro256;

/// A fixed-size pool behind the single-macro backend interface. Virtual
/// core `v` maps to shard `v / cores`, core `v % cores`.
pub struct PoolBackend {
    vcfg: Config,
    pool: MacroPool,
    rng: Xoshiro256,
    scratch: OpScratch,
    op: CoreOpResult,
    stats: ExecStats,
}

impl PoolBackend {
    pub fn new(cfg: Config, n_shards: usize) -> Self {
        assert!(n_shards > 0, "pool needs at least one shard");
        let pool = MacroPool::with_shards(cfg.clone(), n_shards);
        let mut vcfg = cfg;
        vcfg.mac.cores *= n_shards;
        // Same RNG stream as NativeBackend: a 1-shard PoolBackend replays
        // the single-macro backend's noise draws op for op.
        let rng = Xoshiro256::seeded(vcfg.sim.seed ^ 0xBACC_E4D);
        let scratch = OpScratch::new(&vcfg.mac);
        Self {
            vcfg,
            pool,
            rng,
            scratch,
            op: CoreOpResult::default(),
            stats: ExecStats::default(),
        }
    }

    pub fn pool(&self) -> &MacroPool {
        &self.pool
    }
}

impl CimBackend for PoolBackend {
    /// The virtual config: identical to the shard config except `mac.cores`,
    /// which is multiplied by the shard count.
    fn config(&self) -> &Config {
        &self.vcfg
    }

    fn load_core(&mut self, core: usize, w: &[Vec<i64>]) -> Result<(), MapError> {
        if core >= self.pool.total_cores() {
            return Err(MapError::Macro(MacroError::BadCore(core)));
        }
        self.pool.load_slot(core, w)?;
        self.stats.weight_loads += 1;
        Ok(())
    }

    fn core_op(&mut self, core: usize, acts: &[i64]) -> Result<Vec<f64>, MapError> {
        self.pool
            .op_into(core, acts, &mut self.rng, &mut self.scratch, &mut self.op)?;
        let (s, c) = self.pool.locate(core);
        let w = self.pool.shard(s).core_weights(c)?;
        account_core_op(self.pool.cfg(), w, acts, &self.op.stats, &mut self.stats);
        Ok(self.op.values.clone())
    }

    fn stats(&self) -> &ExecStats {
        &self.stats
    }

    fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::mapping::executor::CimLinear;
    use crate::mapping::NativeBackend;
    use crate::nn::tensor::Tensor;
    use crate::util::rng::{Rng, Xoshiro256};

    /// The executor on a PoolBackend with enough virtual cores never reloads
    /// a tile, and (noise-free) returns the exact single-macro results.
    #[test]
    fn executor_on_pool_backend_is_weight_stationary_and_exact() {
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::fold_only();
        let (k, n) = (130, 33); // 3 × 3 = 9 tiles > 4 cores
        let mut rng = Xoshiro256::seeded(2);
        let w = Tensor::from_vec(&[k, n], (0..k * n).map(|_| rng.next_f32() - 0.5).collect());
        let lin = CimLinear::new(&w, vec![0.0; n], 1.0, &cfg);
        let xs: Vec<Vec<f32>> =
            (0..5).map(|_| (0..k).map(|_| rng.next_f32()).collect()).collect();

        let mut nat = NativeBackend::new(cfg.clone());
        let want = lin.run_batch(&mut nat, &xs).unwrap();

        // 3 shards × 4 cores = 12 virtual cores ≥ 9 tiles.
        let mut pb = PoolBackend::new(cfg.clone(), 3);
        assert_eq!(pb.config().mac.cores, 12);
        let got = lin.run_batch(&mut pb, &xs).unwrap();
        assert_eq!(got, want);
        assert_eq!(pb.stats().weight_loads as usize, lin.ops_per_vector());
        assert_eq!(
            pb.stats().core_ops as usize,
            lin.ops_per_vector() * xs.len()
        );
    }

    #[test]
    fn bad_virtual_core_is_rejected() {
        let cfg = Config::default();
        let mut pb = PoolBackend::new(cfg.clone(), 2);
        let w = vec![vec![0i64; cfg.mac.engines]; cfg.mac.rows];
        assert!(pb.load_core(7, &w).is_ok());
        assert!(matches!(
            pb.load_core(8, &w),
            Err(MapError::Macro(MacroError::BadCore(8)))
        ));
    }
}
