//! The two-layer MLP deployment on a macro pool — since the graph compiler
//! landed, this is simply one instance of a [`CompiledPlan`]: the
//! deployment's unit-scale graph ([`crate::compiler::Graph::from_deployment`])
//! compiled onto a fresh pool. The wrapper keeps the serving-oriented API
//! (`run_batch` on flat vectors, cumulative stats) that
//! `coordinator::server::serve_pipeline` drives.
//!
//! The deployment graph's arithmetic mirrors
//! [`MlpDeployment::run_native`] expression for expression, so with noise
//! disabled the compiled pipeline's logits are bit-identical to the
//! sequential path (the concurrency test relies on this).

use crate::compiler::{compile, CompileError, CompileOptions, CompiledPlan, Graph};
use crate::config::Config;
use crate::coordinator::deployment::MlpDeployment;
use crate::mapping::{ExecStats, MapError};
use crate::pipeline::pool::MacroPool;

/// A quantized MLP resident on a [`MacroPool`], ready for batched serving.
pub struct PipelineDeployment {
    dep: MlpDeployment,
    plan: CompiledPlan,
}

impl PipelineDeployment {
    /// Compile the deployment graph onto a fresh pool. `workers == 0`
    /// selects the thread-pool default. Weights load exactly once, here.
    pub fn new(dep: MlpDeployment, cfg: Config, workers: usize) -> Result<Self, MapError> {
        let graph = Graph::from_deployment(&dep);
        let opts = CompileOptions {
            workers,
            seed: Some(cfg.sim.seed ^ 0x0051_A6ED),
            ..CompileOptions::default()
        };
        // The deployment graph carries explicit quantization params
        // everywhere, so compilation needs no calibration inputs. Device
        // faults keep their classification; structural faults are shapes.
        let plan = compile(graph, &[], &cfg, &opts).map_err(|e| match e {
            CompileError::Macro(m) => MapError::Macro(m),
            other => MapError::Shape(format!("deployment compile: {other}")),
        })?;
        Ok(Self { dep, plan })
    }

    pub fn config(&self) -> &Config {
        self.plan.config()
    }

    pub fn deployment(&self) -> &MlpDeployment {
        &self.dep
    }

    /// The underlying compiled plan (placement report, per-layer counters).
    pub fn plan(&self) -> &CompiledPlan {
        &self.plan
    }

    pub fn pool(&self) -> &MacroPool {
        self.plan.pool()
    }

    pub fn workers(&self) -> usize {
        self.plan.workers()
    }

    /// Cumulative device counters over every batch served.
    pub fn stats(&self) -> &ExecStats {
        self.plan.stats()
    }

    pub fn reset_stats(&mut self) {
        self.plan.reset_stats();
    }

    /// Total tiles resident on the pool (both layers).
    pub fn n_tiles(&self) -> usize {
        self.plan.total_tiles()
    }

    /// Batched inference: input quantization → layer 1 on the pool → ReLU +
    /// hidden requantization → layer 2 on the pool → dequantized logits.
    pub fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.plan.run_flat(xs)
    }

    /// Streamed (layer-pipelined) form of [`PipelineDeployment::run_batch`]:
    /// bit-identical outputs, items flow through the two layers as a
    /// pipeline instead of a barrier (DESIGN.md §9).
    pub fn run_batch_streamed(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, MapError> {
        self.plan.run_streamed_flat(xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnhanceConfig;
    use crate::mapping::NativeBackend;
    use crate::nn::dataset::BlobDataset;
    use crate::nn::mlp::{train, Mlp};

    fn small_deployment(seed: u64) -> (MlpDeployment, Vec<Vec<f32>>) {
        let mut d = BlobDataset::new(12, 0.05, seed);
        let data: Vec<(Vec<f32>, usize)> =
            d.batch(150).into_iter().map(|s| (s.image.data, s.label)).collect();
        let mut mlp = Mlp::new(&[144, 32, 10], seed ^ 1);
        train(&mut mlp, &data, 4, 0.05, seed ^ 2);
        let cal: Vec<Vec<f32>> = data.iter().take(30).map(|(x, _)| x.clone()).collect();
        let dep = MlpDeployment::quantize(&mlp, &cal, 1.0);
        let xs: Vec<Vec<f32>> = data.iter().take(20).map(|(x, _)| x.clone()).collect();
        (dep, xs)
    }

    /// Noise-free, the compiled pipeline's logits are bit-identical to the
    /// sequential `run_native` path, independent of worker count.
    #[test]
    fn pipeline_matches_run_native_noise_free() {
        let (dep, xs) = small_deployment(41);
        let mut cfg = Config::default();
        cfg.noise.enabled = false;
        cfg.enhance = EnhanceConfig::both();
        let want = {
            let mut be = NativeBackend::new(cfg.clone());
            dep.run_native(&mut be, &xs).unwrap()
        };
        for workers in [1usize, 4] {
            let mut pipe = PipelineDeployment::new(dep.clone(), cfg.clone(), workers).unwrap();
            let got = pipe.run_batch(&xs).unwrap();
            assert_eq!(got, want, "workers = {workers}");
        }
    }

    #[test]
    fn stats_accumulate_across_batches() {
        let (dep, xs) = small_deployment(43);
        let mut cfg = Config::default();
        cfg.enhance = EnhanceConfig::both();
        let mut pipe = PipelineDeployment::new(dep, cfg, 2).unwrap();
        let tiles = pipe.n_tiles();
        // 144×32 → 3×2 = 6 tiles; 32×10 → 1×1 = 1 tile.
        assert_eq!(tiles, 7);
        assert_eq!(pipe.stats().weight_loads as usize, tiles);
        pipe.run_batch(&xs[..4]).unwrap();
        let ops1 = pipe.stats().core_ops;
        assert_eq!(ops1 as usize, 4 * tiles);
        pipe.run_batch(&xs[4..8]).unwrap();
        assert_eq!(pipe.stats().core_ops, 2 * ops1);
        assert!(pipe.stats().energy_fj() > 0.0);
        // Weights were never reloaded on the hot path.
        assert_eq!(pipe.stats().weight_loads as usize, tiles);
    }

    /// The deployment plan reports a placement: both layers' tiles resident,
    /// the second layer reusing the first's partially-filled shard.
    #[test]
    fn deployment_is_a_compiled_plan() {
        let (dep, _) = small_deployment(47);
        let cfg = Config::default();
        let pipe = PipelineDeployment::new(dep, cfg, 1).unwrap();
        let report = pipe.plan().cost_report();
        assert_eq!(report.layers.len(), 2);
        assert_eq!(report.total_tiles, 7);
        assert_eq!(report.n_shards, 2); // 7 tiles on 4-core shards
        assert_eq!(pipe.pool().slots_loaded(), 7);
        assert!(report.total_est_cycles_per_input() > 0);
    }
}
